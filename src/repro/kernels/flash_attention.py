"""Flash attention forward (Pallas TPU kernel): causal, sliding-window, GQA.

IO-aware attention for the LM architectures: never materializes the
[Tq, Tk] score matrix in HBM. Online softmax with running (m, l) statistics;
K/V are streamed block-by-block through VMEM via the innermost grid
dimension (sequential on TPU), the output block is revisited and finalized
on the last K block.

Supports:
  * causal masking with end-alignment (decode: Tq < Tk aligns to the end),
  * sliding-window attention (Mistral/Mixtral-style SWA, `window` > 0),
  * grouped-query attention (Hq a multiple of Hkv) via the K/V index map.

Backward is delegated to the XLA reference (``ops.flash_attention`` wires a
custom_vjp whose bwd recomputes with the jnp oracle) — the training path in
this framework defaults to XLA attention; the kernel is the serving-path
fast forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret

NEG_INF = -1e30
MIN_LANE = 128


def _make_kernel(*, bq: int, bk: int, nk: int, tq: int, tk: int,
                 causal: bool, window: int, scale: float):
    def kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        ik = pl.program_id(3)
        iq = pl.program_id(2)

        @pl.when(ik == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + (tk - tq)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < tk
        if causal:
            mask &= k_pos <= q_pos
        if window and window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]                   # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = corr * l_ref[...][:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

        @pl.when(ik == nk - 1)
        def _finalize():
            l = l_ref[...][:, :1]
            o_ref[0, 0] = jnp.where(
                l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0
            ).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "scale", "block_q",
                                    "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] -> [B, Hq, Tq, D]."""
    interpret = resolve_interpret(interpret)
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    scale = float(scale) if scale is not None else float(1.0 / (D ** 0.5))

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    pad_q = (-Tq) % bq
    pad_k = (-Tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk

    grid = (B, Hq, nq, nk)
    out = pl.pallas_call(
        _make_kernel(bq=bq, bk=bk, nk=nk, tq=Tq, tk=Tk, causal=causal,
                     window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),        # acc
            pltpu.VMEM((bq, MIN_LANE), jnp.float32),  # running max m
            pltpu.VMEM((bq, MIN_LANE), jnp.float32),  # running denom l
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Tq]
