"""Segmented-top-k ranking fast path: the two Pallas kernels (TPU).

The segmented ranking cycle (``core/ranking.py``) is three stages:

  1. an **elementwise table pass** — read-time lazy decay of the pair
     weight, the four association-score lanes and their linear combination
     (``assoc_score.score_body``), and the evidence gates, producing one
     gated score lane (``-inf`` where gated);
  2. **grouping** — prefix-sum compaction of gate-passing row ids plus one
     flat u32 sort on (bucket id | coarse score), laying the rows out as a
     dense ``[buckets, L]`` grid;
  3. **per-bucket partial selection** — ``top_k`` rounds of masked argmax
     along each bucket's L-row arena.

``score_gate`` fuses stage 1 into ONE pass: each (8, 128) table tile is
read into VMEM once and the whole decay -> score -> gate chain runs on it
in-register, instead of XLA materializing the decayed weight, four score
lanes, the combined score and the gate mask as separate [C] HBM arrays.
``bucket_topk`` runs stage 3: each block of bucket rows sits in VMEM while
the K argmax rounds run fully vectorized along the lane axis — no sort and
no scatter in the selection itself. Stage 2 (compaction scatter + flat
sort) is scatter/sort-shaped and stays on XLA, which is exactly the
efficient cut for a TPU: Pallas kernels have no efficient cross-tile
scatter. Dispatch in ``ops.score_gate`` / ``ops.bucket_topk``, oracles in
``ref.py``.

Layout mirrors decay_prune: (C/1024, 8, 128) tiles, 1-D grid for
``score_gate``; (rows, 128-padded lanes) blocks for ``bucket_topk``. The
in-kernel lazy decay covers the (default) exponential kind; other kinds
pre-decay in jnp before the call.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret
from .assoc_score import score_body
from .decay_prune import LANE, SUBLANE, TILE, ROWS_PER_BLOCK


def _make_kernel(coefs: Tuple[float, float, float, float],
                 min_pair_weight: float, min_src_weight: float,
                 min_pair_count: float, half_life: Optional[float]):
    coefs = tuple(float(c) for c in coefs)   # compile-time literals
    mpw = float(min_pair_weight)
    msw = float(min_src_weight)
    mpc = float(min_pair_count)

    def kernel(*refs):
        if half_life is not None:
            (w_ab_ref, c_ab_ref, w_a_ref, w_b_ref, c_a_ref, c_b_ref,
             ok_ref, lt_ref, tw_ref, tc_ref, now_ref, out_ref) = refs
            dt = jnp.maximum(now_ref[0] - lt_ref[...], 0.0)
            w_ab = w_ab_ref[...] * jnp.exp2(-dt / jnp.float32(half_life))
        else:
            (w_ab_ref, c_ab_ref, w_a_ref, w_b_ref, c_a_ref, c_b_ref,
             ok_ref, tw_ref, tc_ref, out_ref) = refs
            w_ab = w_ab_ref[...]
        c_ab = c_ab_ref[...]
        w_a = w_a_ref[...]
        score = score_body(w_ab, c_ab, w_a, w_b_ref[...], c_a_ref[...],
                           c_b_ref[...], tw_ref[0], tc_ref[0], coefs)
        ok = ((ok_ref[...] > 0) & (w_ab >= mpw) & (c_ab >= mpc)
              & (w_a >= msw))
        out_ref[...] = jnp.where(ok, score, -jnp.inf)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "coefs", "min_pair_weight", "min_src_weight", "min_pair_count",
    "half_life", "interpret", "block_rows"))
def score_gate(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, last_tick, total_w,
               total_c, now, *, coefs: Tuple[float, float, float, float],
               min_pair_weight: float, min_src_weight: float,
               min_pair_count: float, half_life: Optional[float] = None,
               interpret: bool | None = None,
               block_rows: int | None = None) -> jax.Array:
    """Fused lazy-decay + association-scoring + gating over full lanes.

    ``half_life`` (static) enables in-kernel exponential read-time decay of
    ``w_ab`` from ``last_tick`` to ``now``; pass None when the caller
    already holds the effective pair weight (eager policy, or a non-exp
    decay pre-applied in jnp). Returns the gated combined score, ``-inf``
    where any evidence gate fails. ``block_rows`` overrides the tile rows
    per grid step (a ``TunedPlan.score_block_rows`` knob — in interpret
    mode fewer, larger blocks amortize per-step interpreter overhead).
    """
    interpret = resolve_interpret(interpret)
    C = w_ab.shape[0]
    assert C % TILE == 0
    rows = C // TILE
    blk = min(ROWS_PER_BLOCK if block_rows is None else block_rows, rows)
    assert rows % blk == 0, (rows, blk)
    grid = rows // blk
    shape3 = (rows, SUBLANE, LANE)

    spec = pl.BlockSpec((blk, SUBLANE, LANE), lambda i: (i, 0, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    args = [x.astype(jnp.float32).reshape(shape3)
            for x in (w_ab, c_ab, w_a, w_b, c_a, c_b, ok)]
    scalars = [jnp.asarray(total_w, jnp.float32).reshape(1),
               jnp.asarray(total_c, jnp.float32).reshape(1)]
    if half_life is not None:
        args.append(last_tick.astype(jnp.float32).reshape(shape3))
        scalars.append(jnp.asarray(now, jnp.float32).reshape(1))

    out = pl.pallas_call(
        _make_kernel(coefs, min_pair_weight, min_src_weight, min_pair_count,
                     None if half_life is None else float(half_life)),
        grid=(grid,),
        in_specs=[spec] * len(args) + [sspec] * len(scalars),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape3, jnp.float32),
        interpret=interpret,
    )(*args, *scalars)
    return out.reshape(C)


# ---------------------------------------------------------------------------
# bucket_topk: per-bucket iterated masked argmax over the [R, L] grid.
# ---------------------------------------------------------------------------

_BUCKET_BLOCK = 128   # bucket rows per grid step


def _make_bucket_kernel(K: int, Lp: int, Kp: int):
    def kernel(g_ref, vals_ref, args_ref):
        g = g_ref[...]                                   # (BR, Lp)
        iota = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1)
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
        args_ref[...] = jnp.full(args_ref.shape, Lp, jnp.int32)
        for k in range(K):
            m = jnp.max(g, axis=1, keepdims=True)
            hit = (g == m) & (m > -jnp.inf)
            am = jnp.min(jnp.where(hit, iota, Lp), axis=1, keepdims=True)
            vals_ref[:, k] = m[:, 0]
            args_ref[:, k] = am[:, 0]
            g = jnp.where(iota == am, -jnp.inf, g)       # retire the winner

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def bucket_topk(grid: jax.Array, k: int, *, interpret: bool | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Top-k of each bucket row of ``grid`` [R, L] by K rounds of masked
    argmax — each block of bucket rows stays in VMEM for all K rounds.

    Ties resolve to the lowest column (identical to ``lax.top_k``).
    Returns (vals f32[R, k], args i32[R, k]); exhausted rounds yield
    ``-inf`` vals and the sentinel column ``Lp`` (the padded width).
    """
    interpret = resolve_interpret(interpret)
    R, L = grid.shape
    Lp = ((max(L, 1) + LANE - 1) // LANE) * LANE
    Kp = ((max(k, 1) + LANE - 1) // LANE) * LANE
    BR = min(_BUCKET_BLOCK, max(SUBLANE, R))
    Rp = ((R + BR - 1) // BR) * BR
    gp = jnp.full((Rp, Lp), -jnp.inf, jnp.float32)
    gp = gp.at[:R, :L].set(grid.astype(jnp.float32))

    spec_in = pl.BlockSpec((BR, Lp), lambda i: (i, 0))
    spec_out = pl.BlockSpec((BR, Kp), lambda i: (i, 0))
    vals, args = pl.pallas_call(
        _make_bucket_kernel(int(k), Lp, Kp),
        grid=(Rp // BR,),
        in_specs=[spec_in],
        out_specs=[spec_out, spec_out],
        out_shape=[jax.ShapeDtypeStruct((Rp, Kp), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Kp), jnp.int32)],
        interpret=interpret,
    )(gp)
    return vals[:R, :k], args[:R, :k]


# ---------------------------------------------------------------------------
# region_rank: the region layout's ONE fused pass — lazy decay + scoring +
# gating + per-region top-k, reading the [n_regions, width] grid (a pure
# reshape of the store) straight from HBM tiles. No intermediate [C] score
# array ever materializes: each block of region rows is read once into
# VMEM, scored in-register, and leaves only its K winners.
# ---------------------------------------------------------------------------


def _make_region_kernel(K: int, Wp: int,
                        coefs: Tuple[float, float, float, float],
                        min_pair_weight: float, min_src_weight: float,
                        min_pair_count: float, half_life: Optional[float]):
    coefs = tuple(float(c) for c in coefs)
    mpw = float(min_pair_weight)
    msw = float(min_src_weight)
    mpc = float(min_pair_count)

    def kernel(*refs):
        if half_life is not None:
            (w_ab_ref, c_ab_ref, w_a_ref, w_b_ref, c_a_ref, c_b_ref,
             ok_ref, lt_ref, tw_ref, tc_ref, now_ref,
             vals_ref, args_ref, npass_ref) = refs
            dt = jnp.maximum(now_ref[0] - lt_ref[...], 0.0)
            w_ab = w_ab_ref[...] * jnp.exp2(-dt / jnp.float32(half_life))
        else:
            (w_ab_ref, c_ab_ref, w_a_ref, w_b_ref, c_a_ref, c_b_ref,
             ok_ref, tw_ref, tc_ref, vals_ref, args_ref, npass_ref) = refs
            w_ab = w_ab_ref[...]
        c_ab = c_ab_ref[...]
        w_a = w_a_ref[...]
        score = score_body(w_ab, c_ab, w_a, w_b_ref[...], c_a_ref[...],
                           c_b_ref[...], tw_ref[0], tc_ref[0], coefs)
        ok = ((ok_ref[...] > 0) & (w_ab >= mpw) & (c_ab >= mpc)
              & (w_a >= msw))
        npass_ref[...] = jnp.sum(ok.astype(jnp.int32), axis=1)
        g = jnp.where(ok, score, -jnp.inf)
        iota = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1)
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
        args_ref[...] = jnp.full(args_ref.shape, Wp, jnp.int32)
        for k in range(K):
            m = jnp.max(g, axis=1, keepdims=True)
            hit = (g == m) & (m > -jnp.inf)
            am = jnp.min(jnp.where(hit, iota, Wp), axis=1, keepdims=True)
            vals_ref[:, k] = m[:, 0]
            args_ref[:, k] = am[:, 0]
            g = jnp.where(iota == am, -jnp.inf, g)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "k", "coefs", "min_pair_weight", "min_src_weight", "min_pair_count",
    "half_life", "interpret"))
def region_rank(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, last_tick, total_w,
                total_c, now, *, k: int,
                coefs: Tuple[float, float, float, float],
                min_pair_weight: float, min_src_weight: float,
                min_pair_count: float, half_life: Optional[float] = None,
                interpret: bool | None = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused score+gate+top-k over the region grid: all inputs ``[R, W]``
    (source marginals pre-broadcast along W by the caller — XLA fuses the
    broadcast into the feed). Ties resolve to the lowest slot position
    (insertion order). Returns (vals f32[R, k], args i32[R, k],
    npass i32[R] — gate-passing slots per region, the caller's overflow
    accounting, so no second jnp gate pass over the store is needed);
    exhausted rounds yield ``-inf`` and the padded-width sentinel."""
    interpret = resolve_interpret(interpret)
    R, W = w_ab.shape
    Wp = ((max(W, 1) + LANE - 1) // LANE) * LANE
    Kp = ((max(k, 1) + LANE - 1) // LANE) * LANE
    BR = min(_BUCKET_BLOCK, max(SUBLANE, R))
    Rp = ((R + BR - 1) // BR) * BR

    def pad(x, fill=0.0):
        buf = jnp.full((Rp, Wp), fill, jnp.float32)
        return buf.at[:R, :W].set(x.astype(jnp.float32))

    args = [pad(a) for a in (w_ab, c_ab, w_a, w_b, c_a, c_b, ok)]
    scalars = [jnp.asarray(total_w, jnp.float32).reshape(1),
               jnp.asarray(total_c, jnp.float32).reshape(1)]
    if half_life is not None:
        args.append(pad(last_tick))
        scalars.append(jnp.asarray(now, jnp.float32).reshape(1))

    spec_in = pl.BlockSpec((BR, Wp), lambda i: (i, 0))
    spec_out = pl.BlockSpec((BR, Kp), lambda i: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    npass_spec = pl.BlockSpec((BR,), lambda i: (i,))
    vals, cols, npass = pl.pallas_call(
        _make_region_kernel(int(k), Wp, coefs, min_pair_weight,
                            min_src_weight, min_pair_count,
                            None if half_life is None else float(half_life)),
        grid=(Rp // BR,),
        in_specs=[spec_in] * len(args) + [sspec] * len(scalars),
        out_specs=[spec_out, spec_out, npass_spec],
        out_shape=[jax.ShapeDtypeStruct((Rp, Kp), jnp.float32),
                   jax.ShapeDtypeStruct((Rp, Kp), jnp.int32),
                   jax.ShapeDtypeStruct((Rp,), jnp.int32)],
        interpret=interpret,
    )(*args, *scalars)
    return vals[:R, :k], cols[:R, :k], npass[:R]
