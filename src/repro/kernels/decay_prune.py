"""Fused decay + prune + occupancy sweep (Pallas TPU kernel).

The paper's decay/prune cycle (§4.3) touches every store entry: decay all
weights, clear entries under the prune threshold, and (for monitoring /
§4.4 memory control) report live occupancy and total weight. Done naively
this is three full HBM passes over the table (decay write, prune write,
stats read); the fused kernel does ONE read + ONE write per lane plus a
per-block stats reduction.

TPU layout: the 1-D table arrays (capacity C, a power of two) are viewed as
(C/1024, 8, 128) so each block is an aligned (8, 128) VPU tile; the grid
walks row-blocks of ROWS_PER_BLOCK tiles. Stats are accumulated per grid
step into a small (grid,)-shaped output and reduced on the host side of the
call (one extra tiny pass).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE            # 1024 elements per tile
ROWS_PER_BLOCK = 16              # 16 tiles = 16KiB f32 per lane per block


def _kernel(key_hi_ref, key_lo_ref, w_ref, f_ref, t_ref,
            out_hi_ref, out_lo_ref, out_w_ref, live_ref, tot_ref):
    f = f_ref[0]
    thresh = t_ref[0]
    k_hi = key_hi_ref[...]
    k_lo = key_lo_ref[...]
    w = w_ref[...]
    live = (k_hi != 0) | (k_lo != 0)
    w2 = w * f
    keep = live & (w2 >= thresh)
    w_out = jnp.where(keep, w2, 0.0)
    out_hi_ref[...] = jnp.where(keep, k_hi, jnp.uint32(0))
    out_lo_ref[...] = jnp.where(keep, k_lo, jnp.uint32(0))
    out_w_ref[...] = w_out
    live_ref[0] = jnp.sum(keep.astype(jnp.float32))
    tot_ref[0] = jnp.sum(w_out)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decay_prune(key_hi: jax.Array, key_lo: jax.Array, weight: jax.Array,
                decay_factor: jax.Array, threshold: jax.Array,
                *, interpret: bool = True
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused sweep over (key_hi, key_lo, weight) table arrays.

    Returns (key_hi', key_lo', weight', live_count i32[], total_weight f32[]).
    Auxiliary lanes of the store are cleared by the caller using the
    returned keys (a pruned slot has key (0,0)).
    """
    C = key_hi.shape[0]
    assert C % TILE == 0, "table capacity must be a multiple of 1024"
    rows = C // TILE
    blk = min(ROWS_PER_BLOCK, rows)
    assert rows % blk == 0
    grid = rows // blk

    shape3 = (rows, SUBLANE, LANE)
    kh = key_hi.reshape(shape3)
    kl = key_lo.reshape(shape3)
    w = weight.reshape(shape3)
    f = jnp.asarray(decay_factor, jnp.float32).reshape(1)
    t = jnp.asarray(threshold, jnp.float32).reshape(1)

    spec = pl.BlockSpec((blk, SUBLANE, LANE), lambda i: (i, 0, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,), memory_space=pl.ANY) \
        if False else pl.BlockSpec((1,), lambda i: (0,))
    stat_spec = pl.BlockSpec((1,), lambda i: (i,))

    out_hi, out_lo, out_w, live_p, tot_p = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, sspec, sspec],
        out_specs=[spec, spec, spec, stat_spec, stat_spec],
        out_shape=[
            jax.ShapeDtypeStruct(shape3, jnp.uint32),
            jax.ShapeDtypeStruct(shape3, jnp.uint32),
            jax.ShapeDtypeStruct(shape3, jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=interpret,
    )(kh, kl, w, f, t)

    return (out_hi.reshape(C), out_lo.reshape(C), out_w.reshape(C),
            jnp.sum(live_p).astype(jnp.int32), jnp.sum(tot_p))
