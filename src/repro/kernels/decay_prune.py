"""Fused decay + prune + occupancy sweep (Pallas TPU kernel).

The paper's decay/prune cycle (§4.3) touches every store entry: decay all
weights, clear entries under the prune threshold, and (for monitoring /
§4.4 memory control) report live occupancy and total weight. Done naively
this is three full HBM passes over the table (decay write, prune write,
stats read); the fused kernel does ONE read + ONE write per lane plus a
per-block stats reduction.

``decay_prune_multi`` sweeps **every** store lane in that single pass: any
number of weight lanes (decayed then pruned together) plus any number of
auxiliary lanes (counts, timestamps, endpoint fingerprints — cleared on
pruned slots, passed through otherwise). The engine's decay cycle therefore
costs one read + one write of the whole table, with no follow-up jnp passes
per aux lane.

TPU layout: the 1-D table arrays (capacity C, a power of two) are viewed as
(C/1024, 8, 128) so each block is an aligned (8, 128) VPU tile; the grid
walks row-blocks of ROWS_PER_BLOCK tiles. Stats are accumulated per grid
step into a small (grid,)-shaped output and reduced on the host side of the
call (one extra tiny pass).

``interpret`` defaults to auto-detection: the kernel compiles for real on a
TPU backend and falls back to the Pallas interpreter elsewhere (CPU CI).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE            # 1024 elements per tile
ROWS_PER_BLOCK = 16              # 16 tiles = 16KiB f32 per lane per block

# Back-compat alias: the auto-detect now lives in kernels/__init__ (the one
# shared copy); older call sites imported it from here.
_resolve_interpret = resolve_interpret


def _make_kernel(n_w: int, n_aux: int):
    """Build the fused sweep kernel for n_w weight lanes + n_aux aux lanes.

    Ref order: inputs  [f, thresh, key_hi, key_lo, w_0..w_{n_w-1}, a_0..]
               outputs [key_hi', key_lo', w'_0.., a'_0.., live, tot]
    """
    def kernel(*refs):
        f = refs[0][0]
        thresh = refs[1][0]
        k_hi = refs[2][...]
        k_lo = refs[3][...]
        w_ins = [refs[4 + i][...] for i in range(n_w)]
        a_ins = [refs[4 + n_w + i][...] for i in range(n_aux)]
        o = 4 + n_w + n_aux
        out_hi_ref, out_lo_ref = refs[o], refs[o + 1]
        w_out_refs = [refs[o + 2 + i] for i in range(n_w)]
        a_out_refs = [refs[o + 2 + n_w + i] for i in range(n_aux)]
        live_ref = refs[o + 2 + n_w + n_aux]
        tot_ref = refs[o + 3 + n_w + n_aux]

        live = (k_hi != 0) | (k_lo != 0)
        w0 = w_ins[0] * f
        keep = live & (w0 >= thresh)
        w0 = jnp.where(keep, w0, 0.0)
        out_hi_ref[...] = jnp.where(keep, k_hi, jnp.uint32(0))
        out_lo_ref[...] = jnp.where(keep, k_lo, jnp.uint32(0))
        w_out_refs[0][...] = w0
        for i in range(1, n_w):
            w_out_refs[i][...] = jnp.where(keep, w_ins[i] * f, 0.0)
        for a_in, a_out in zip(a_ins, a_out_refs):
            a_out[...] = jnp.where(keep, a_in, jnp.zeros_like(a_in))
        live_ref[0] = jnp.sum(keep.astype(jnp.float32))
        tot_ref[0] = jnp.sum(w0)

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def decay_prune_multi(
    key_hi: jax.Array,
    key_lo: jax.Array,
    weight_lanes: Tuple[jax.Array, ...],
    aux_lanes: Tuple[jax.Array, ...],
    decay_factor: jax.Array,
    threshold: jax.Array,
    *,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, ...],
           Tuple[jax.Array, ...], jax.Array, jax.Array]:
    """Full-lane fused sweep over a store's dense arrays.

    ``weight_lanes[0]`` is the primary lane: it decides pruning against
    ``threshold`` after decay. Further weight lanes decay by the same factor;
    ``aux_lanes`` are cleared on pruned slots and passed through otherwise.
    All lanes must be 1-D of the same capacity (a multiple of 1024).

    Returns (key_hi', key_lo', weight_lanes', aux_lanes',
             live_count i32[], total_weight f32[]).
    """
    assert len(weight_lanes) >= 1
    C = key_hi.shape[0]
    assert C % TILE == 0, "table capacity must be a multiple of 1024"
    rows = C // TILE
    blk = min(ROWS_PER_BLOCK, rows)
    assert rows % blk == 0
    grid = rows // blk

    shape3 = (rows, SUBLANE, LANE)
    view = lambda a: a.reshape(shape3)
    f = jnp.asarray(decay_factor, jnp.float32).reshape(1)
    t = jnp.asarray(threshold, jnp.float32).reshape(1)

    n_w, n_aux = len(weight_lanes), len(aux_lanes)
    spec = pl.BlockSpec((blk, SUBLANE, LANE), lambda i: (i, 0, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    stat_spec = pl.BlockSpec((1,), lambda i: (i,))

    lane_out = lambda a: jax.ShapeDtypeStruct(shape3, a.dtype)
    outs = pl.pallas_call(
        _make_kernel(n_w, n_aux),
        grid=(grid,),
        in_specs=[sspec, sspec, spec, spec] + [spec] * (n_w + n_aux),
        out_specs=[spec, spec] + [spec] * (n_w + n_aux) + [stat_spec, stat_spec],
        out_shape=[
            jax.ShapeDtypeStruct(shape3, jnp.uint32),
            jax.ShapeDtypeStruct(shape3, jnp.uint32),
            *[lane_out(w) for w in weight_lanes],
            *[lane_out(a) for a in aux_lanes],
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(f, t, view(key_hi), view(key_lo),
      *[view(w) for w in weight_lanes], *[view(a) for a in aux_lanes])

    out_hi, out_lo = outs[0], outs[1]
    w_out = tuple(o.reshape(C) for o in outs[2:2 + n_w])
    a_out = tuple(o.reshape(C) for o in outs[2 + n_w:2 + n_w + n_aux])
    live_p, tot_p = outs[-2], outs[-1]
    return (out_hi.reshape(C), out_lo.reshape(C), w_out, a_out,
            jnp.sum(live_p).astype(jnp.int32), jnp.sum(tot_p))


@functools.partial(jax.jit, static_argnames=("interpret",))
def decay_prune(key_hi: jax.Array, key_lo: jax.Array, weight: jax.Array,
                decay_factor: jax.Array, threshold: jax.Array,
                *, interpret: bool | None = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-lane sweep over (key_hi, key_lo, weight) table arrays.

    Returns (key_hi', key_lo', weight', live_count i32[], total_weight f32[]).
    Auxiliary lanes of the store are cleared by the caller using the
    returned keys (a pruned slot has key (0,0)) — or fused directly via
    :func:`decay_prune_multi`.
    """
    kh, kl, (w,), _, live, tot = decay_prune_multi(
        key_hi, key_lo, (weight,), (), decay_factor, threshold,
        interpret=interpret)
    return kh, kl, w, live, tot
