"""Pallas kernels for the paper's hot loops, with jnp reference twins.

Execution-mode policy (THE one place it is decided)
---------------------------------------------------

Every kernel in this package takes ``interpret: bool | None = None`` and
resolves it through :func:`resolve_interpret` below: ``None`` means
"compile for real on a native-Pallas backend (TPU), run under the Pallas
interpreter everywhere else (CPU CI)". The per-file copies of this
auto-detect were deduplicated here so backend selection is decided — and
testable — in exactly one place.

The interpreter is a *correctness* vehicle, not an execution strategy: on
CPU it loses to plain jnp by 10-100x (it re-enters XLA per grid step).
Production dispatch therefore never trusts a blind flag; it consults a
measured :class:`repro.core.plan.TunedPlan` built by
``repro.launch.autotune`` (see below).

Kernel-dispatch table
---------------------

Hot-path call sites and the ``TunedPlan`` field each one consults::

    call site                                   plan field      candidates
    ------------------------------------------- --------------- -----------------
    core/ranking._score_and_gate                score_gate      ops.score_gate | assoc_scores_jnp
    core/ranking.ranking_cycle (selection)      bucket_topk     ops.bucket_topk | lax.top_k
    core/ranking.ranking_cycle_region           region_rank     ops.region_rank | jnp score+top_k
    core/stores.region_insert_accumulate        chain_find      ops.chain_find | _chain_find_jnp
    core/decay.sweep_decay_prune                decay_prune     ops.decay_prune_table | jnp sweep
    core/engine step/ingest_many dispatch       ingest_chunk    events fused per device dispatch
    kernels/topk_select.score_gate tiling       score_block_rows tile rows per grid step

Resolution order at every site: an explicit legacy ``use_kernel`` bool
(``EngineConfig.use_kernel`` / ``RankConfig.use_kernel``) wins; otherwise
the attached plan's choice; otherwise the jnp reference path. The
**shape-class key** for a plan is ``repro.core.plan.shape_class(cfg)``
(backend + device kind + log2 store capacities + cooc layout + region
width) and tuned plans are cached on disk under
``$REPRO_AUTOTUNE_CACHE`` (default ``~/.cache/repro-autotune``), one JSON
per shape class. Plan choices are *result-invariant* by construction —
the tuner only picks between paths property-tested to produce bit-exact
engine states (tuning may change speed, never results).
"""
from __future__ import annotations

import jax

# Backends where pl.pallas_call compiles natively. Everywhere else the
# kernels run under the Pallas interpreter (correct, but slow — see the
# module docstring; the autotuner measures it and routes around it).
KERNEL_NATIVE_BACKENDS = ("tpu",)


def kernels_native(backend: str | None = None) -> bool:
    """Is ``backend`` (default: the default jax backend) native Pallas?"""
    b = backend if backend is not None else jax.default_backend()
    return b in KERNEL_NATIVE_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """The ONE interpret-mode auto-detect: ``None`` -> interpret everywhere
    except a native-Pallas (TPU) backend; an explicit bool is honored."""
    if interpret is None:
        return not kernels_native()
    return bool(interpret)
