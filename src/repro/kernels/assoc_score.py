"""Fused association scoring (Pallas TPU kernel) — the ranking-cycle hot loop.

One pass over the cooccurrence store computes all four association lanes
(conditional probability, PMI, log-likelihood ratio, chi-squared — paper
§2.4) AND their linear combination. Unfused, XLA materializes several
intermediate [C]-sized lanes in HBM; fused, each of the six input lanes is
read once and one output lane is written.

Layout mirrors decay_prune: (C/1024, 8, 128) tiles, 1-D grid.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret
from .decay_prune import LANE, SUBLANE, TILE, ROWS_PER_BLOCK


def _xlogx(x):
    return jnp.where(x > 0, x * jnp.log(jnp.maximum(x, 1e-30)), 0.0)


def score_body(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c,
               coefs: Tuple[float, float, float, float]):
    """The fused association-scoring body, on block *values* (not refs).

    Shared by this kernel and the segmented-top-k select kernel
    (``topk_select.py``), which folds gating and lazy decay around it.
    ``coefs`` must be python floats so they stay compile-time literals.
    """
    c0, c1, c2, c3 = coefs
    eps = jnp.float32(1e-9)
    w_a = jnp.maximum(w_a, 0.0)
    w_b = jnp.maximum(w_b, 0.0)
    condprob = jnp.where(w_a > 0, w_ab / jnp.maximum(w_a, eps), 0.0)
    pmi = jnp.where(
        (w_ab > 0) & (w_a > 0) & (w_b > 0),
        jnp.log(jnp.maximum(w_ab * jnp.maximum(total_w, eps), eps)
                / jnp.maximum(w_a * w_b, eps)),
        0.0)
    k11 = c_ab
    k12 = jnp.maximum(c_a - c_ab, 0.0)
    k21 = jnp.maximum(c_b - c_ab, 0.0)
    k22 = jnp.maximum(total_c - c_a - c_b + c_ab, 0.0)
    n = jnp.maximum(k11 + k12 + k21 + k22, eps)
    r1, r2 = k11 + k12, k21 + k22
    q1, q2 = k11 + k21, k12 + k22
    llr = 2.0 * (_xlogx(k11) + _xlogx(k12) + _xlogx(k21) + _xlogx(k22)
                 - _xlogx(r1) - _xlogx(r2) - _xlogx(q1) - _xlogx(q2)
                 + _xlogx(n))
    llr = jnp.maximum(llr, 0.0)
    chi2 = n * (k11 * k22 - k12 * k21) ** 2 / jnp.maximum(r1 * r2 * q1 * q2, eps)
    valid = c_ab > 0
    condprob = jnp.where(valid, condprob, 0.0)
    pmi = jnp.where(valid, pmi, 0.0)
    llr = jnp.where(valid, llr, 0.0)
    chi2 = jnp.where(valid, chi2, 0.0)
    return (c0 * condprob + c1 * jax.nn.sigmoid(pmi)
            + c2 * jnp.log1p(llr) + c3 * jnp.log1p(chi2))


def _make_kernel(coefs: Tuple[float, float, float, float]):
    coefs = tuple(float(c) for c in coefs)  # python literals, not arrays

    def kernel(w_ab_ref, c_ab_ref, w_a_ref, w_b_ref, c_a_ref, c_b_ref,
               tw_ref, tc_ref, out_ref):
        out_ref[...] = score_body(
            w_ab_ref[...], c_ab_ref[...], w_a_ref[...], w_b_ref[...],
            c_a_ref[...], c_b_ref[...], tw_ref[0], tc_ref[0], coefs)

    return kernel


@functools.partial(jax.jit, static_argnames=("coefs", "interpret",
                                             "block_rows"))
def assoc_score(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c,
                *, coefs: Tuple[float, float, float, float],
                interpret: bool | None = None,
                block_rows: int | None = None) -> jax.Array:
    interpret = resolve_interpret(interpret)
    C = w_ab.shape[0]
    assert C % TILE == 0
    rows = C // TILE
    blk = min(ROWS_PER_BLOCK if block_rows is None else block_rows, rows)
    assert rows % blk == 0, (rows, blk)
    grid = rows // blk
    shape3 = (rows, SUBLANE, LANE)

    spec = pl.BlockSpec((blk, SUBLANE, LANE), lambda i: (i, 0, 0))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    args = [x.astype(jnp.float32).reshape(shape3)
            for x in (w_ab, c_ab, w_a, w_b, c_a, c_b)]
    tw = jnp.asarray(total_w, jnp.float32).reshape(1)
    tc = jnp.asarray(total_c, jnp.float32).reshape(1)

    out = pl.pallas_call(
        _make_kernel(coefs),
        grid=(grid,),
        in_specs=[spec] * 6 + [sspec, sspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape3, jnp.float32),
        interpret=interpret,
    )(*args, tw, tc)
    return out.reshape(C)
