"""Region-probe Pallas kernel: in-VMEM region-tile find for the
source-major cooccurrence store (closes the ROADMAP "Pallas probe kernel"
item for the layout that replaced global open addressing).

The region layout (``core/stores.RegionTable``) turns the store's find
step from K rounds of random [capacity]-wide gathers into a *chain scan*:
each pair's source names its region chain directly (region id = qstore
slot), and a find only has to match the destination key against the W
contiguous slots of each chain region. ``chain_find_depth`` is that scan
as a Pallas kernel: the grid walks the batch, and a scalar-prefetched
region id steers the BlockSpec index map so each step DMAs exactly ONE
region tile — ``(1, W)`` rows of the key lanes — from HBM into VMEM,
matches the pair's key against the whole tile in-register, and emits the
match position. The probe working set is one region tile, never the whole
table; consecutive batch rows that hit the same region re-use the block.

``chain_find`` wraps the kernel over the (short) spill chain: one call per
chain depth, folding hits into the running found-slot vector exactly like
the jnp reference (``stores._chain_find_jnp``).

Layout note: on a real TPU the tile wants ``W`` to be a multiple of the
128 lane width (the engine default ``region_width=32`` is interpreted /
CPU-CI friendly; pick 128 for TPU deployments). ``interpret=None``
auto-detects like the other kernels in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import resolve_interpret


def _find_kernel(W: int):
    def kernel(reg_ref, khi_ref, klo_ref, dhi_ref, dlo_ref, out_ref):
        # reg_ref is the scalar-prefetch operand (already consumed by the
        # index maps); the key refs hold ONE region tile in VMEM.
        m = (khi_ref[...] == dhi_ref[0]) & (klo_ref[...] == dlo_ref[0])
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        out_ref[0] = jnp.min(jnp.where(m, iota, W))

    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def chain_find_depth(key_hi_r: jax.Array, key_lo_r: jax.Array,
                     region_ids: jax.Array, dst_hi: jax.Array,
                     dst_lo: jax.Array, *, interpret: bool | None = None
                     ) -> jax.Array:
    """Match ``dst`` keys against one region tile per batch row.

    ``key_hi_r``/``key_lo_r`` are the store's key lanes viewed as
    ``[n_regions, W]``; ``region_ids`` i32[B] picks each row's tile (must
    be pre-clipped to a valid region). Returns i32[B]: the in-region match
    position, or ``W`` when the key is absent from that tile.
    """
    R, W = key_hi_r.shape
    B = dst_hi.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i, reg: (reg[i], 0)),
            pl.BlockSpec((1, W), lambda i, reg: (reg[i], 0)),
            pl.BlockSpec((1,), lambda i, reg: (i,)),
            pl.BlockSpec((1,), lambda i, reg: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, reg: (i,)),
    )
    return pl.pallas_call(
        _find_kernel(W),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(region_ids.astype(jnp.int32), key_hi_r, key_lo_r, dst_hi, dst_lo)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chain_find(key_hi_r: jax.Array, key_lo_r: jax.Array, regs: jax.Array,
               dst_hi: jax.Array, dst_lo: jax.Array, active: jax.Array,
               *, interpret: bool | None = None) -> jax.Array:
    """Full chain scan: ``regs`` i32[B, max_chain] (-1 = no region at that
    depth) — one :func:`chain_find_depth` pass per depth, first hit wins.
    Returns the *global* slot (region * W + pos), or -1. Semantics are
    identical to the jnp reference ``stores._chain_find_jnp``."""
    R, W = key_hi_r.shape
    B, MC = regs.shape
    found = jnp.full((B,), -1, jnp.int32)
    for d in range(MC):
        col = regs[:, d]
        has = active & (col >= 0) & (found < 0)
        # early exit like the jnp reference: once every row is resolved (or
        # out of chain), the remaining depths skip their kernel launch —
        # steady-state chains are one region deep.
        pos = jax.lax.cond(
            jnp.any(has),
            lambda: chain_find_depth(key_hi_r, key_lo_r,
                                     jnp.where(col >= 0, col, 0),
                                     dst_hi, dst_lo, interpret=interpret),
            lambda: jnp.full((B,), W, jnp.int32))
        hit = has & (pos < W)
        found = jnp.where(hit, jnp.where(col >= 0, col, 0) * W + pos, found)
    return found
