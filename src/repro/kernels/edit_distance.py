"""Batched weighted edit distance (Pallas TPU kernel) — spelling correction.

The paper's spelling path computes "a pairwise edit distance variant
calculation between all queries observed within a long span of time" (§4.5).
The variant here (identical to ``ref.edit_distance_ref``):

  * adjacent transpositions are one edit (optimal string alignment),
  * edits touching the FIRST character of either string cost
    ``first_char_cost`` (mistakes cluster on internal characters),
  * strings are byte arrays, zero-padded to a fixed L (<= 24 for queries).

TPU adaptation: the textbook row-major DP is sequential in both i and j.
We run the **anti-diagonal wavefront**: diagonal d holds D[i][d-i]; each of
the 2L diagonals is computed as a vector op over i (and over the pair batch),
keeping a 4-deep ring of diagonals in VMEM (the transposition term needs
d-4). One kernel instance processes a PAIR_BLOCK of pairs; arrays are
(PAIR_BLOCK, L+1) f32 — a few KiB, comfortably VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import resolve_interpret

PAIR_BLOCK = 128


def _make_kernel(L: int, first_char_cost: float):
    fc = float(first_char_cost)   # python literals (closure constants)
    BIG = 1e9

    def kernel(a_ref, al_ref, b_ref, bl_ref, out_ref):
        a = a_ref[...].astype(jnp.int32)      # (B, L)
        b = b_ref[...].astype(jnp.int32)
        al = al_ref[...].astype(jnp.int32)    # (B,)
        bl = bl_ref[...].astype(jnp.int32)
        B = a.shape[0]
        ii = jnp.arange(L + 1, dtype=jnp.int32)          # i lane
        col0 = jnp.where(ii == 0, 0.0, fc + (ii - 1.0))  # D[i][0]
        row0 = col0                                       # D[0][j] symmetric

        def boundary(d):
            """Diagonal holding only boundary-consistent values."""
            # cell (i, j=d-i): i==0 -> row0[d]; i==d -> col0[d]; else filled in
            return jnp.zeros((B, L + 1), jnp.float32)

        def diag_step(d, carry):
            dm1, dm2, dm3, dm4, out = carry
            j = d - ii                                    # per-lane j
            valid = (ii >= jnp.maximum(0, d - L)) & (ii <= jnp.minimum(d, L))
            # gather a[i-1], b[j-1] per lane
            a_i = jnp.take_along_axis(
                a, jnp.clip(ii - 1, 0, L - 1)[None, :].repeat(B, 0), axis=1)
            bj_idx = jnp.clip(j - 1, 0, L - 1)
            b_j = jnp.take_along_axis(b, bj_idx[None, :].repeat(B, 0), axis=1)

            # neighbor diagonals (shift in i)
            dm1_im1 = jnp.roll(dm1, 1, axis=1)            # D[i-1][j]   (d-1)
            dm2_im1 = jnp.roll(dm2, 1, axis=1)            # D[i-1][j-1] (d-2)
            dm4_im2 = jnp.roll(dm4, 2, axis=1)            # D[i-2][j-2] (d-4)

            sub_w = jnp.where((ii == 1) | (j == 1), fc, 1.0)
            ins_w = jnp.where(j == 1, fc, 1.0)
            del_w = jnp.where(ii == 1, fc, 1.0)
            sub = dm2_im1 + jnp.where(a_i == b_j, 0.0, sub_w)[...]
            ins = dm1 + ins_w
            dele = dm1_im1 + del_w
            dnew = jnp.minimum(jnp.minimum(sub, ins), dele)

            # transposition
            a_im1 = jnp.take_along_axis(
                a, jnp.clip(ii - 2, 0, L - 1)[None, :].repeat(B, 0), axis=1)
            b_jm1 = jnp.take_along_axis(
                b, jnp.clip(j - 2, 0, L - 1)[None, :].repeat(B, 0), axis=1)
            can_t = (ii >= 2) & (j >= 2)
            tw = jnp.where((ii == 2) | (j == 2), fc, 1.0)
            tmatch = can_t & (a_im1 == b_j) & (a_i == b_jm1)
            dnew = jnp.minimum(dnew, jnp.where(tmatch, dm4_im2 + tw, BIG))

            # boundaries
            dnew = jnp.where(ii == 0, row0[jnp.clip(d, 0, L)], dnew)
            dnew = jnp.where(j == 0, col0[jnp.clip(d, 0, L)], dnew)
            dnew = jnp.where(valid[None, :], dnew, BIG)

            # capture result when d == al + bl (one-hot gather at i == al)
            hit = (d == al + bl)
            sel = jnp.sum(jnp.where(ii[None, :] == al[:, None], dnew, 0.0), axis=1)
            out = jnp.where(hit, sel, out)
            return (dnew, dm1, dm2, dm3, out)

        # d = 0 diagonal: single cell D[0][0] = 0
        d0 = jnp.where(ii[None, :] == 0, 0.0, BIG) * jnp.ones((B, 1), jnp.float32)
        neg = jnp.full((B, L + 1), BIG, jnp.float32)
        out = jnp.where(al + bl == 0, 0.0, BIG).astype(jnp.float32)
        carry = (d0, neg, neg, neg, out)
        carry = jax.lax.fori_loop(1, 2 * L + 1, diag_step, carry)
        out_ref[...] = carry[4]

    return kernel


@functools.partial(jax.jit, static_argnames=("first_char_cost", "interpret"))
def edit_distance(a_chars, a_len, b_chars, b_len, *,
                  first_char_cost: float = 1.5,
                  interpret: bool | None = None) -> jax.Array:
    """Weighted OSA distance per pair. a_chars/b_chars u8[B, L]."""
    interpret = resolve_interpret(interpret)
    B, L = a_chars.shape
    blk = min(PAIR_BLOCK, B)
    pad = (-B) % blk
    if pad:
        a_chars = jnp.pad(a_chars, ((0, pad), (0, 0)))
        b_chars = jnp.pad(b_chars, ((0, pad), (0, 0)))
        a_len = jnp.pad(a_len, (0, pad))
        b_len = jnp.pad(b_len, (0, pad))
    Bp = B + pad
    grid = Bp // blk

    spec2 = pl.BlockSpec((blk, L), lambda i: (i, 0))
    spec1 = pl.BlockSpec((blk,), lambda i: (i,))
    out = pl.pallas_call(
        _make_kernel(L, first_char_cost),
        grid=(grid,),
        in_specs=[spec2, spec1, spec2, spec1],
        out_specs=spec1,
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(a_chars, jnp.asarray(a_len, jnp.int32), b_chars, jnp.asarray(b_len, jnp.int32))
    return out[:B]
