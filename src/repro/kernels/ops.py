"""Public jit'd wrappers for the Pallas kernels, with ref fallbacks.

Execution mode is decided once, in ``kernels.resolve_interpret``: kernels
compile for real on a native-Pallas backend (TPU) and run under the Pallas
interpreter elsewhere (CPU CI). Whether a hot path runs its kernel *at
all* is the ``TunedPlan``'s call (see the package docstring) — these
wrappers keep kernel-vs-oracle shape handling in ONE place so the
engine/models just call ops.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import decay_prune as _dp
from . import assoc_score as _as
from . import edit_distance as _ed
from . import flash_attention as _fa
from . import topk_select as _tk

# None = let each kernel auto-resolve via kernels.resolve_interpret.
_INTERPRET = None
# The blocked sweeps require 1024-multiple capacities.
_TILE = _dp.TILE


def decay_prune_table(table, dticks, *, cfg, weight_lanes: Tuple[str, ...]):
    """Fused decay/prune sweep over a HashTable (engine decay cycle).

    Every 1-D lane rides the single Pallas read+write pass: weight lanes are
    decayed+pruned, aux lanes cleared on pruned slots, all in-kernel. Only
    ragged capacities or multi-dim lanes fall back to jnp masking.
    """
    primary = weight_lanes[0]
    f = cfg.factor(dticks)
    lanes = dict(table.lanes)
    aux_1d = [n for n, lane in table.lanes.items()
              if n not in weight_lanes and lane.ndim == 1]
    if table.capacity % _TILE:
        # ragged capacity: fall back to the jnp path semantics
        kh, kl, w, keep, live, tot = ref.decay_prune_ref(
            table.key_hi, table.key_lo, table.lanes[primary], f,
            cfg.prune_threshold)
        lanes[primary] = w
        for name in weight_lanes[1:]:
            lanes[name] = jnp.where(keep, lanes[name] * f, 0.0)
        for name in aux_1d:
            lanes[name] = jnp.where(keep, lanes[name],
                                    jnp.zeros_like(lanes[name]))
    else:
        kh, kl, w_out, a_out, live, tot = _dp.decay_prune_multi(
            table.key_hi, table.key_lo,
            tuple(table.lanes[n] for n in weight_lanes),
            tuple(table.lanes[n] for n in aux_1d),
            f, jnp.float32(cfg.prune_threshold), interpret=_INTERPRET)
        keep = (kh != 0) | (kl != 0)
        for name, w in zip(weight_lanes, w_out):
            lanes[name] = w
        for name, a in zip(aux_1d, a_out):
            lanes[name] = a
        # Recompute the scalar totals with the same jnp reductions as the
        # reference sweep (``decay._apply_decay_prune``): the in-kernel
        # per-block partial sums round differently, and these two scalars
        # were the ONLY leaves breaking bit-exact kernel-vs-jnp engine
        # parity. The lanes themselves are exact.
        live = jnp.sum(keep.astype(jnp.int32))
        tot = jnp.sum(lanes[primary])
    # multi-dim lanes (none in the engine stores today) still need a mask
    for name, lane in lanes.items():
        if name not in weight_lanes and lane.ndim > 1:
            kb = keep.reshape(keep.shape + (1,) * (lane.ndim - 1))
            lanes[name] = jnp.where(kb, lane, jnp.zeros_like(lane))
    return table._replace(key_hi=kh, key_lo=kl, lanes=lanes), live, tot


def assoc_score(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c, *,
                coefs: Tuple[float, float, float, float]):
    """Fused association scoring over full store lanes."""
    if w_ab.shape[0] % _TILE:
        return ref.assoc_score_ref(w_ab, c_ab, w_a, w_b, c_a, c_b,
                                   total_w, total_c, coefs)
    return _as.assoc_score(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c,
                           coefs=tuple(float(c) for c in coefs),
                           interpret=_INTERPRET)


def score_gate(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, total_w, total_c, *,
               coefs: Tuple[float, float, float, float],
               min_pair_weight: float, min_src_weight: float,
               min_pair_count: float,
               decay_cfg=None, last_tick=None, now=None,
               block_rows: int | None = None):
    """Fused (lazy decay +) scoring + gating — the elementwise stage of the
    segmented-top-k ranking cycle.

    One Pallas pass per table tile: optional read-time exponential decay of
    ``w_ab`` from ``last_tick``, the four association lanes + linear
    combination, and the evidence gates, emitting one gated score lane
    (``-inf`` where gated). Non-exp decay kinds and ragged capacities
    pre-decay / fall back in jnp with identical semantics.
    """
    coefs = tuple(float(c) for c in coefs)
    C = w_ab.shape[0]
    half_life = None
    if decay_cfg is not None:
        if decay_cfg.kind == "exp" and C % _TILE == 0:
            half_life = float(decay_cfg.half_life_ticks)
        else:
            w_ab = w_ab * decay_cfg.factor(jnp.maximum(now - last_tick, 0))
    if C % _TILE:
        return ref.score_gate_ref(w_ab, c_ab, w_a, w_b, c_a, c_b, ok,
                                  total_w, total_c, coefs,
                                  min_pair_weight, min_src_weight,
                                  min_pair_count)
    return _tk.score_gate(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, last_tick,
                          total_w, total_c, now, coefs=coefs,
                          min_pair_weight=float(min_pair_weight),
                          min_src_weight=float(min_src_weight),
                          min_pair_count=float(min_pair_count),
                          half_life=half_life, interpret=_INTERPRET,
                          block_rows=block_rows)


def bucket_topk(grid, k: int):
    """Per-bucket top-k over the segmented-ranking [R, L] grid (values +
    in-bucket columns), via K rounds of in-VMEM masked argmax. Same tie
    rule as ``lax.top_k`` (lowest column wins)."""
    return _tk.bucket_topk(grid, int(k), interpret=_INTERPRET)


def chain_find(key_hi_r, key_lo_r, regs, dst_hi, dst_lo, active):
    """Region-layout chain find (insert fast path): one scalar-prefetched
    region tile in VMEM per batch row, one pass per chain depth. Returns
    the global slot of each pair's key, or -1 (same contract as the jnp
    reference ``stores._chain_find_jnp``)."""
    from . import region_probe as _rp
    return _rp.chain_find(key_hi_r, key_lo_r, regs, dst_hi, dst_lo, active,
                          interpret=_INTERPRET)


def region_rank(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, total_w, total_c, *,
                k: int, coefs: Tuple[float, float, float, float],
                min_pair_weight: float, min_src_weight: float,
                min_pair_count: float,
                decay_cfg=None, last_tick=None, now=None):
    """The region ranking cycle's ONE fused Pallas pass: (lazy decay +)
    association scoring + evidence gates + per-region top-k, reading the
    ``[n_regions, width]`` grid — a pure reshape of the store — straight
    from HBM tiles. Exponential decay runs in-kernel; other kinds
    pre-decay in jnp with identical semantics. Returns (vals, args,
    npass) — npass i32[R] is the per-region gate-pass count for overflow
    accounting, emitted by the same pass."""
    coefs = tuple(float(c) for c in coefs)
    half_life = None
    if decay_cfg is not None:
        if decay_cfg.kind == "exp":
            half_life = float(decay_cfg.half_life_ticks)
        else:
            w_ab = w_ab * decay_cfg.factor(jnp.maximum(now - last_tick, 0))
    return _tk.region_rank(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, last_tick,
                           total_w, total_c, now, k=int(k), coefs=coefs,
                           min_pair_weight=float(min_pair_weight),
                           min_src_weight=float(min_src_weight),
                           min_pair_count=float(min_pair_count),
                           half_life=half_life, interpret=_INTERPRET)


def edit_distance(a_chars, a_len, b_chars, b_len, *,
                  first_char_cost: float = 1.5, use_kernel: bool = True):
    """Batched weighted OSA edit distance."""
    a_chars = jnp.asarray(a_chars)
    b_chars = jnp.asarray(b_chars)
    a_len = jnp.asarray(a_len, jnp.int32)
    b_len = jnp.asarray(b_len, jnp.int32)
    if not use_kernel:
        return ref.edit_distance_ref(a_chars, a_len, b_chars, b_len,
                                     first_char_cost)
    return _ed.edit_distance(a_chars, a_len, b_chars, b_len,
                             first_char_cost=float(first_char_cost),
                             interpret=_INTERPRET)


# ---------------------------------------------------------------------------
# flash attention with a custom_vjp: Pallas forward, oracle backward.
# ---------------------------------------------------------------------------

def _fa_fwd_impl(q, k, v, causal, window):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_INTERPRET)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    return _fa_fwd_impl(q, k, v, causal, window)


def _fa_fwd(q, k, v, causal, window):
    return _fa_fwd_impl(q, k, v, causal, window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
