"""Pure-jnp oracles for every Pallas kernel in this package.

Each function defines the exact semantics its kernel must reproduce; kernel
tests sweep shapes/dtypes and ``assert_allclose`` against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.ranking import assoc_scores_jnp


# ---------------------------------------------------------------------------
# decay_prune: fused decay + prune + occupancy stats over a store's arrays.
# ---------------------------------------------------------------------------

def decay_prune_ref(key_hi, key_lo, weight, decay_factor, threshold):
    """Returns (key_hi', key_lo', weight', keep_mask, live_count, total_w)."""
    live = (key_hi != 0) | (key_lo != 0)
    w = weight * decay_factor
    keep = live & (w >= threshold)
    w = jnp.where(keep, w, 0.0)
    return (jnp.where(keep, key_hi, 0), jnp.where(keep, key_lo, 0), w, keep,
            jnp.sum(keep.astype(jnp.int32)), jnp.sum(w))


def decay_prune_multi_ref(key_hi, key_lo, weight_lanes, aux_lanes,
                          decay_factor, threshold):
    """Multi-lane oracle: decay every weight lane, prune on the primary,
    clear aux lanes on pruned slots. Mirrors ``decay_prune_multi``.

    Returns (key_hi', key_lo', weight_lanes', aux_lanes', live_count, total_w).
    """
    live = (key_hi != 0) | (key_lo != 0)
    w0 = weight_lanes[0] * decay_factor
    keep = live & (w0 >= threshold)
    w_out = tuple(jnp.where(keep, w * decay_factor, 0.0) for w in weight_lanes)
    a_out = tuple(jnp.where(keep, a, jnp.zeros_like(a)) for a in aux_lanes)
    return (jnp.where(keep, key_hi, 0), jnp.where(keep, key_lo, 0),
            w_out, a_out, jnp.sum(keep.astype(jnp.int32)), jnp.sum(w_out[0]))


# ---------------------------------------------------------------------------
# assoc_score: fused association scoring (ranking-cycle hot loop).
# ---------------------------------------------------------------------------

def assoc_score_ref(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c,
                    coefs: Tuple[float, float, float, float]):
    condprob, pmi, llr, chi2 = assoc_scores_jnp(
        w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c)
    c0, c1, c2, c3 = coefs
    return (c0 * condprob + c1 * jax.nn.sigmoid(pmi)
            + c2 * jnp.log1p(llr) + c3 * jnp.log1p(chi2))


# ---------------------------------------------------------------------------
# score_gate: fused (lazy decay +) scoring + evidence gating — the
# elementwise stage of the segmented-top-k ranking cycle (topk_select.py).
# ---------------------------------------------------------------------------

def score_gate_ref(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, total_w, total_c,
                   coefs: Tuple[float, float, float, float],
                   min_pair_weight: float, min_src_weight: float,
                   min_pair_count: float):
    """Gated combined score; ``-inf`` where any evidence gate fails.

    ``w_ab`` is the *effective* pair weight — under the lazy decay policy
    the caller decays it to `now` first (the kernel fuses that in-pass).
    """
    score = assoc_score_ref(w_ab, c_ab, w_a, w_b, c_a, c_b,
                            total_w, total_c, coefs)
    gate = (ok & (w_ab >= min_pair_weight) & (c_ab >= min_pair_count)
            & (w_a >= min_src_weight))
    return jnp.where(gate, score, -jnp.inf)


def bucket_topk_ref(grid, k: int):
    """Per-bucket top-k oracle: ``lax.top_k`` row-wise (lowest column wins
    ties — the same rule as the kernel's min-iota masked argmax)."""
    return jax.lax.top_k(grid, k)


# ---------------------------------------------------------------------------
# edit_distance: batched weighted Damerau (OSA) distance, first-char penalty.
# ---------------------------------------------------------------------------

def edit_distance_ref(a_chars, a_len, b_chars, b_len, first_char_cost=1.5):
    """Weighted optimal-string-alignment distance.

    a_chars/b_chars: u8[B, L] zero-padded; lengths i32[B]. Edits touching the
    first character of either string cost ``first_char_cost``; all other
    edits cost 1. Adjacent transpositions are a single edit.
    Returns f32[B].
    """
    B, L = a_chars.shape
    a = a_chars.astype(jnp.int32)
    b = b_chars.astype(jnp.int32)
    big = jnp.float32(1e9)

    # D has shape (B, L+1, L+1); row-by-row scan (rows are i over `a`).
    j_idx = jnp.arange(L + 1, dtype=jnp.float32)
    # first-char-weighted boundary: D[0, j] = fc + (j-1) for j >= 1
    fc = jnp.float32(first_char_cost)
    row0 = jnp.where(j_idx == 0, 0.0, fc + (j_idx - 1.0))

    def cost_at(i, j_is_1):
        # an edit consuming position i of `a` (i is 1-based) or the first
        # char of `b` is weighted.
        return jnp.where((i == 1) | j_is_1, fc, 1.0)

    def row_step(carry, i):
        prev2, prev1 = carry  # rows i-2 and i-1, each (B, L+1)
        ai = a[:, i - 1]                       # (B,)
        del_cost = jnp.where(i == 1, fc, 1.0)
        # D[i][0]
        d0 = jnp.where(i == 1, fc, prev1[:, 0] + 1.0)

        # j-loop must be sequential (insertion dep) -> inner scan over j.
        def col_step(dprev, j):
            # dprev: (B,) = D[i][j-1]
            bj = b[:, j - 1]
            sub_w = jnp.where((i == 1) | (j == 1), fc, 1.0)
            ins_w = jnp.where(j == 1, fc, 1.0)
            del_w = jnp.where(i == 1, fc, 1.0)
            sub = prev1[:, j - 1] + jnp.where(ai == bj, 0.0, sub_w)
            ins = dprev + ins_w
            dele = prev1[:, j] + del_w
            d = jnp.minimum(jnp.minimum(sub, ins), dele)
            # transposition: a[i-2]==b[j-1] and a[i-1]==b[j-2]
            can_t = (i >= 2) & (j >= 2)
            tw = jnp.where((i == 2) | (j == 2), fc, 1.0)  # touches first char
            at2 = a[:, jnp.maximum(i - 2, 0)]
            bt2 = b[:, jnp.maximum(j - 2, 0)]
            tmatch = can_t & (at2 == bj) & (ai == bt2)
            trans = jnp.where(tmatch, prev2[:, jnp.maximum(j - 2, 0)] + tw, big)
            d = jnp.minimum(d, trans)
            return d, d

        _, cols = jax.lax.scan(col_step, d0, jnp.arange(1, L + 1))
        row = jnp.concatenate([d0[:, None], cols.T], axis=1)  # (B, L+1)
        return (prev1, row), row

    init = (jnp.broadcast_to(row0, (B, L + 1)),
            jnp.broadcast_to(row0, (B, L + 1)))
    (_, _), rows = jax.lax.scan(row_step, init, jnp.arange(1, L + 1))
    # rows: (L, B, L+1); full table with row 0 prepended
    table = jnp.concatenate(
        [jnp.broadcast_to(row0, (1, B, L + 1)), rows], axis=0)  # (L+1, B, L+1)
    out = table[a_len, jnp.arange(B), b_len]
    # identical strings -> 0; empty-vs-empty -> 0
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# flash_attention: causal / sliding-window / GQA attention forward.
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: [B, Hq, Tq, D]; k/v: [B, Hkv, Tk, D]; GQA via Hq % Hkv == 0.

    window > 0 => sliding-window attention of that width (causal).
    Returns [B, Hq, Tq, D] in q.dtype (accumulation in f32).
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    kf = jnp.repeat(k, rep, axis=1)
    vf = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(D))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    Tk = k.shape[2]
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)   # align ends (decode-friendly)
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)
