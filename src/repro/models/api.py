"""Unified architecture API: one entry point for init / train / serve /
abstract input specs across the three families (lm, gnn, recsys).

Everything the launcher, dry-run, smoke tests and benchmarks need:

  * ``abstract_params(spec)``         — ShapeDtypeStructs via eval_shape
  * ``init_params(rng, spec)``        — real parameters
  * ``make_step(spec, shape_cell)``   — the jittable step fn for a cell
  * ``input_specs(spec, shape_cell)`` — ShapeDtypeStruct inputs for a cell
  * ``make_inputs(rng, spec, cell)``  — materialized random inputs (smoke)
  * ``sharding_rules(spec)``          — param path-regex -> logical axes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gnn, recsys, transformer as tr
from .moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) cell of the assignment matrix."""
    name: str
    kind: str                      # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    skip: Optional[str] = None     # reason if inapplicable (recorded, not run)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys
    model: str                     # lm | gat | bst | xdeepfm | bert4rec | twotower
    config: Any
    smoke_config: Any
    shapes: Tuple[ShapeCell, ...]
    source: str = ""

    def cell(self, name: str) -> ShapeCell:
        for c in self.shapes:
            if c.name == name:
                return c
        raise KeyError(name)


# ---------------------------------------------------------------------------
# init / params
# ---------------------------------------------------------------------------

def init_params(rng, spec_or_cfg, model: Optional[str] = None):
    cfg = spec_or_cfg.config if isinstance(spec_or_cfg, ArchSpec) else spec_or_cfg
    model = model or (spec_or_cfg.model if isinstance(spec_or_cfg, ArchSpec) else None)
    if isinstance(cfg, tr.LMConfig):
        return tr.init_params(rng, cfg)
    if isinstance(cfg, gnn.GATConfig):
        return gnn.init_params(rng, cfg)
    if isinstance(cfg, recsys.BSTConfig):
        return recsys.bst_init(rng, cfg)
    if isinstance(cfg, recsys.XDeepFMConfig):
        return recsys.xdeepfm_init(rng, cfg)
    if isinstance(cfg, recsys.Bert4RecConfig):
        return recsys.bert4rec_init(rng, cfg)
    if isinstance(cfg, recsys.TwoTowerConfig):
        return recsys.twotower_init(rng, cfg)
    raise TypeError(type(cfg))


def abstract_params(cfg) -> Any:
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def sharding_rules(cfg):
    if isinstance(cfg, tr.LMConfig):
        return tr.params_sharding_rules()
    if isinstance(cfg, gnn.GATConfig):
        return []  # tiny params: fully replicated
    # recsys: embedding tables row-sharded over tp
    return [
        (r"(item_emb|user_emb|profile_emb|emb|linear_w)$", ("tp",)),
        (r"mlp/w0$", (None, "tp")),
        (r"mlp/w1$", ("tp", None)),
    ]


def _param_bytes(cfg) -> int:
    shapes = jax.tree.leaves(abstract_params(cfg))
    return int(sum(np.prod(s.shape) * s.dtype.itemsize for s in shapes))


def serve_rules(cfg):
    """Param sharding for SERVING (§Perf iterations A1/B1):

    * dense LMs fit a tp-row at bf16 -> keep 1D Megatron rules (2D serve
      sharding costs a dp-axis weight all-gather per layer per step);
    * MoE LMs need 2D (weights >> HBM*tp);
    * small recsys models (< 2 GB total) REPLICATE at serve: the embedding
      gather becomes local and the score matmuls lose their collectives;
      the 10M-item two-tower table stays row-sharded.
    """
    if isinstance(cfg, tr.LMConfig):
        return tr.serve_sharding_rules() if cfg.moe else tr.params_sharding_rules()
    if isinstance(cfg, gnn.GATConfig):
        return []
    if _param_bytes(cfg) < 2 << 30:
        return []   # fully replicated serving copy
    return sharding_rules(cfg)


def batch_axis_for(cfg, cell: ShapeCell) -> str:
    """Small recsys models replicate at serve and do redundant compute per
    model-axis row unless the batch shards over the WHOLE mesh ('all').
    Measured (EXPERIMENTS.md §Perf F3): confirmed for bst/xdeepfm/bert4rec
    (useful ratio 0.06->0.99); REFUTED for two-tower — its 10 GB tp-sharded
    item table turns 'all'-sharded batches into gather storms
    (retrieval frac 0.315->0.132), so it keeps 'dp'."""
    if isinstance(cfg, (recsys.BSTConfig, recsys.XDeepFMConfig,
                        recsys.Bert4RecConfig)):
        return "all"
    return "dp"


# ---------------------------------------------------------------------------
# loss / step builders
# ---------------------------------------------------------------------------

def loss_fn(cfg) -> Callable:
    if isinstance(cfg, tr.LMConfig):
        return lambda p, b: tr.loss_fn(p, b, cfg)
    if isinstance(cfg, gnn.GATConfig):
        return lambda p, b: gnn.loss_fn(p, b, cfg)
    if isinstance(cfg, recsys.BSTConfig):
        return lambda p, b: recsys.bce_loss(recsys.bst_forward(p, b, cfg),
                                            b["labels"])
    if isinstance(cfg, recsys.XDeepFMConfig):
        return lambda p, b: recsys.bce_loss(recsys.xdeepfm_forward(p, b, cfg),
                                            b["labels"])
    if isinstance(cfg, recsys.Bert4RecConfig):
        if cfg.n_items > 100_000:   # production vocab -> sampled softmax
            return lambda p, b: recsys.bert4rec_sampled_loss(p, b, cfg)
        return lambda p, b: recsys.bert4rec_loss(p, b, cfg)
    if isinstance(cfg, recsys.TwoTowerConfig):
        return lambda p, b: recsys.twotower_loss(p, b, cfg)
    raise TypeError(type(cfg))


def serve_fn(cfg, cell: ShapeCell) -> Callable:
    """Forward-only step for serve/prefill/decode/retrieval cells."""
    if isinstance(cfg, tr.LMConfig):
        if cell.kind == "prefill":
            return lambda p, caches, tokens: tr.prefill(p, tokens, cfg, caches)
        if cell.kind == "decode":
            return lambda p, caches, tokens: tr.decode_step(p, tokens, cfg, caches)
        raise ValueError(cell.kind)
    if isinstance(cfg, recsys.TwoTowerConfig):
        if cell.kind == "retrieval":
            return lambda p, b: recsys.retrieval_scores(p, b, cfg)
        return lambda p, b: (recsys.user_tower(p, b, cfg)
                             * recsys.item_tower(p, b["pos_item"], cfg)).sum(-1)
    if isinstance(cfg, recsys.BSTConfig):
        if cell.kind == "retrieval":
            def bst_retr(p, b):
                n = b["cand_ids"].shape[0]
                bb = {"hist": jnp.broadcast_to(b["hist"],
                                               (n,) + b["hist"].shape[1:]),
                      "target": b["cand_ids"],
                      "profile": jnp.broadcast_to(b["profile"],
                                                  (n,) + b["profile"].shape[1:])}
                return recsys.bst_forward(p, bb, cfg)
            return bst_retr
        return lambda p, b: recsys.bst_forward(p, b, cfg)
    if isinstance(cfg, recsys.XDeepFMConfig):
        if cell.kind == "retrieval":
            def xd_retr(p, b):
                n = b["cand_ids"].shape[0]
                ctx = jnp.broadcast_to(b["fields_ctx"],
                                       (n, cfg.n_fields - 1))
                item = (b["cand_ids"] % cfg.field_vocab
                        + (cfg.n_fields - 1) * cfg.field_vocab)
                fields = jnp.concatenate([ctx, item[:, None]], axis=1)
                return recsys.xdeepfm_forward(p, {"fields": fields}, cfg)
            return xd_retr
        return lambda p, b: recsys.xdeepfm_forward(p, b, cfg)
    if isinstance(cfg, recsys.Bert4RecConfig):
        if cell.kind == "retrieval" or (cell.kind == "serve"
                                        and cfg.n_items > 100_000):
            return lambda p, b: recsys.bert4rec_topk_serve(p, b, cfg)
        return lambda p, b: recsys.bert4rec_forward(p, b, cfg)
    raise TypeError(type(cfg))


def adapt_lm_config(cfg: tr.LMConfig, cell: ShapeCell, dp_size: int = 1
                    ) -> tr.LMConfig:
    """Per-cell config tweaks: MoE dispatch groups must divide the token
    count and align with the dp axis."""
    if not isinstance(cfg, tr.LMConfig) or cfg.moe is None:
        return cfg
    d = cell.dims
    if cell.kind == "train":
        n_tok = d["batch"] * d["seq"]
    elif cell.kind == "prefill":
        n_tok = d["batch"] * d["seq"]
    else:
        n_tok = d["batch"]
    g = dp_size
    while g > 1 and n_tok % g:
        g -= 1
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, groups=g))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct) + materialization
# ---------------------------------------------------------------------------

def input_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    """Abstract input pytree for a cell (weak-type-correct, no allocation)."""
    S = jax.ShapeDtypeStruct
    d = cell.dims

    if isinstance(cfg, tr.LMConfig):
        if cell.kind == "train":
            return {"batch": {"tokens": S((d["batch"], d["seq"] + 1), jnp.int32)}}
        cache_len = d.get("cache_len", d["seq"])
        caches = jax.eval_shape(
            lambda: tr.init_caches(cfg, d["batch"], cache_len))
        if cell.kind == "prefill":
            return {"caches": caches,
                    "tokens": S((d["batch"], d["seq"]), jnp.int32)}
        return {"caches": caches, "tokens": S((d["batch"], 1), jnp.int32)}

    if isinstance(cfg, gnn.GATConfig):
        n = d["n_nodes"]
        e = d.get("n_edges_padded", d["n_edges"])
        b = {"x": S((n, d["d_feat"]), jnp.float32),
             "src": S((e,), jnp.int32), "dst": S((e,), jnp.int32),
             "labels": S((n,), jnp.int32),
             "label_mask": S((n,), jnp.bool_),
             "edge_valid": S((e,), jnp.bool_)}
        return {"batch": b}

    B = d.get("batch", 1)
    if isinstance(cfg, recsys.BSTConfig):
        if cell.kind == "retrieval":
            return {"batch": {
                "hist": S((1, cfg.seq_len - 1), jnp.int32),
                "profile": S((1, cfg.n_profile_fields), jnp.int32),
                "cand_ids": S((d["n_candidates"],), jnp.int32)}}
        b = {"hist": S((B, cfg.seq_len - 1), jnp.int32),
             "target": S((B,), jnp.int32),
             "profile": S((B, cfg.n_profile_fields), jnp.int32)}
        if cell.kind == "train":
            b["labels"] = S((B,), jnp.int32)
        return {"batch": b}
    if isinstance(cfg, recsys.XDeepFMConfig):
        if cell.kind == "retrieval":
            return {"batch": {
                "fields_ctx": S((1, cfg.n_fields - 1), jnp.int32),
                "cand_ids": S((d["n_candidates"],), jnp.int32)}}
        b = {"fields": S((B, cfg.n_fields), jnp.int32)}
        if cell.kind == "train":
            b["labels"] = S((B,), jnp.int32)
        return {"batch": b}
    if isinstance(cfg, recsys.Bert4RecConfig):
        b = {"items": S((B, cfg.seq_len), jnp.int32)}
        if cell.kind == "train":
            if cfg.n_items > 100_000:   # sampled softmax inputs
                M = max(1, int(0.15 * cfg.seq_len))
                b["mask_pos"] = S((B, M), jnp.int32)
                b["labels"] = S((B, M), jnp.int32)
                b["neg_ids"] = S((8192,), jnp.int32)
            else:
                b["labels"] = S((B, cfg.seq_len), jnp.int32)
                b["loss_mask"] = S((B, cfg.seq_len), jnp.bool_)
        return {"batch": b}
    if isinstance(cfg, recsys.TwoTowerConfig):
        b = {"user_id": S((B,), jnp.int32),
             "hist": S((B, cfg.hist_len), jnp.int32)}
        if cell.kind == "train":
            b["pos_item"] = S((B,), jnp.int32)
            b["item_logq"] = S((B,), jnp.float32)
        elif cell.kind == "retrieval":
            b["cand_ids"] = S((d["n_candidates"],), jnp.int32)
        else:
            b["pos_item"] = S((B,), jnp.int32)
        return {"batch": b}
    raise TypeError(type(cfg))


def make_inputs(rng: np.random.Generator, cfg, cell: ShapeCell) -> Dict:
    """Materialize random inputs matching input_specs (for smoke/bench)."""
    specs = input_specs(cfg, cell)

    def fill(s):
        if s.dtype == jnp.int32:
            hi = 100
            return jnp.asarray(rng.integers(0, hi, s.shape), jnp.int32)
        if s.dtype == jnp.bool_:
            return jnp.asarray(rng.random(s.shape) < 0.5)
        return jnp.asarray(rng.standard_normal(s.shape), s.dtype)

    out = jax.tree.map(fill, specs,
                       is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # token/id ranges must respect vocab sizes
    def clampi(x, hi):
        return jnp.asarray(np.asarray(x) % hi, jnp.int32)
    if isinstance(cfg, tr.LMConfig):
        if "batch" in out:
            out["batch"]["tokens"] = clampi(out["batch"]["tokens"], cfg.vocab_size)
        else:
            out["tokens"] = clampi(out["tokens"], cfg.vocab_size)
            d = cell.dims
            out["caches"] = tr.init_caches(cfg, d["batch"],
                                           d.get("cache_len", d["seq"]))
    if isinstance(cfg, gnn.GATConfig):
        n = cell.dims["n_nodes"]
        out["batch"]["src"] = clampi(out["batch"]["src"], n)
        out["batch"]["dst"] = clampi(out["batch"]["dst"], n)
        out["batch"]["labels"] = clampi(out["batch"]["labels"], cfg.n_classes)
    if isinstance(cfg, recsys.BSTConfig):
        out["batch"]["hist"] = clampi(out["batch"]["hist"], cfg.n_items)
        out["batch"]["profile"] = clampi(out["batch"]["profile"], cfg.profile_vocab)
        for k in ("target", "cand_ids"):
            if k in out["batch"]:
                out["batch"][k] = clampi(out["batch"][k], cfg.n_items)
        if "labels" in out["batch"]:
            out["batch"]["labels"] = clampi(out["batch"]["labels"], 2)
    if isinstance(cfg, recsys.XDeepFMConfig):
        if "fields" in out["batch"]:
            f = np.asarray(out["batch"]["fields"]) % cfg.field_vocab
            f = f + np.arange(cfg.n_fields)[None] * cfg.field_vocab
            out["batch"]["fields"] = jnp.asarray(f, jnp.int32)
        if "fields_ctx" in out["batch"]:
            f = np.asarray(out["batch"]["fields_ctx"]) % cfg.field_vocab
            f = f + np.arange(cfg.n_fields - 1)[None] * cfg.field_vocab
            out["batch"]["fields_ctx"] = jnp.asarray(f, jnp.int32)
            out["batch"]["cand_ids"] = clampi(out["batch"]["cand_ids"],
                                              cfg.total_vocab)
        if "labels" in out["batch"]:
            out["batch"]["labels"] = clampi(out["batch"]["labels"], 2)
    if isinstance(cfg, recsys.Bert4RecConfig):
        out["batch"]["items"] = clampi(out["batch"]["items"], cfg.vocab)
        if "labels" in out["batch"]:
            out["batch"]["labels"] = clampi(out["batch"]["labels"], cfg.vocab)
        if "mask_pos" in out["batch"]:
            out["batch"]["mask_pos"] = clampi(out["batch"]["mask_pos"],
                                              cfg.seq_len)
            out["batch"]["neg_ids"] = clampi(out["batch"]["neg_ids"], cfg.vocab)
    if isinstance(cfg, recsys.TwoTowerConfig):
        out["batch"]["user_id"] = clampi(out["batch"]["user_id"], cfg.n_users)
        out["batch"]["hist"] = clampi(out["batch"]["hist"], cfg.n_items)
        for k in ("pos_item", "cand_ids"):
            if k in out["batch"]:
                out["batch"][k] = clampi(out["batch"][k], cfg.n_items)
    return out


# ---------------------------------------------------------------------------
# MODEL_BYTES — analytic HBM-traffic model for the §Roofline memory term.
#
# The CPU backend's HLO byte metrics do not transfer to TPU (pre-fusion
# operand counting / micro-fusions), so the memory term is derived
# analytically from the cell structure; the HLO numbers are kept as
# diagnostic columns. Formulas (bf16=2B, f32=4B):
#
#  LM train:  36*P (params fwd+bwd reads, f32 grads, master/m/v R+W)
#             + L*T*(28*d + 24*ff_eff)*2  (residual save + remat recompute
#               + bwd intermediate traffic; ff_eff folds MoE top-k+shared)
#             + 6*T*Vpad*2  (logits write + bwd read + grad)
#  LM prefill: 2*P + L*T*(15*d + 9*ff_eff)*2 + KV writes
#  LM decode:  2*P (weights stream once per token)  + KV cache read/write
#  GNN:        per layer: edge gather+scatter of [E,H,D] messages (x3 lanes)
#              + node features; train = 3x fwd
#  recsys:     embedding gathers + widest interaction tensors + MLP acts
# ---------------------------------------------------------------------------

def model_bytes(cfg, cell: ShapeCell) -> float:
    d_ = cell.dims
    if isinstance(cfg, tr.LMConfig):
        P = cfg.param_count()
        d = cfg.d_model
        if cfg.moe:
            ff_eff = (cfg.moe.top_k * cfg.moe.d_ff * 1.5
                      + cfg.moe.n_shared_experts * cfg.moe.shared_d_ff)
        else:
            ff_eff = cfg.d_ff
        if cell.kind == "train":
            T = d_["batch"] * d_["seq"]
            act = cfg.n_layers * T * (28 * d + 24 * ff_eff) * 2.0
            logits = 6.0 * T * cfg.padded_vocab * 2.0
            return 36.0 * P + act + logits
        if cell.kind == "prefill":
            T = d_["batch"] * d_["seq"]
            act = cfg.n_layers * T * (15 * d + 9 * ff_eff) * 2.0
            kv = cfg.n_layers * T * 2 * cfg.n_kv_heads * cfg.hd * 2.0
            return 2.0 * P + act + kv
        # decode: one token/seq; weights stream once, KV cache read+write
        B = d_["batch"]
        ctx = min(d_.get("cache_len", d_["seq"]),
                  cfg.window if cfg.window > 0 else d_["seq"])
        kv = cfg.n_layers * B * ctx * 2 * cfg.n_kv_heads * cfg.hd * 2.0
        act = cfg.n_layers * B * (15 * d + 9 * ff_eff) * 2.0
        return 2.0 * P + kv + act
    if isinstance(cfg, gnn.GATConfig):
        E, N = d_["n_edges"], d_["n_nodes"]
        msg = cfg.n_layers * 3.0 * E * cfg.n_heads * cfg.d_hidden * 4.0
        nodes = 2.0 * N * d_["d_feat"] * 4.0
        f = msg + nodes
        return 3.0 * f if cell.kind == "train" else f
    B = d_.get("batch", 1)
    if isinstance(cfg, recsys.TwoTowerConfig):
        emb = 2.0 * B * (cfg.hist_len + 1) * cfg.embed_dim * 4.0
        mlp_t = 2.0 * B * sum(cfg.tower_mlp) * 4.0 * 2
        f = emb + mlp_t
        if cell.kind == "retrieval":
            n = d_["n_candidates"]
            f += 2.0 * n * (cfg.embed_dim + sum(cfg.tower_mlp)) * 4.0
            f += 2.0 * B * n * 4.0
        if cell.kind == "train":
            f = 3.0 * f + 2.0 * B * B * 4.0
        return f
    if isinstance(cfg, recsys.XDeepFMConfig):
        m, D = cfg.n_fields, cfg.embed_dim
        emb = 2.0 * B * m * D * 4.0
        z = sum(2.0 * B * h * m * D * 4.0 for h in cfg.cin_layers)
        dnn = 2.0 * B * sum(cfg.dnn_dims) * 4.0
        f = emb + z + dnn
        return 3.0 * f if cell.kind == "train" else f
    if isinstance(cfg, recsys.BSTConfig):
        T, D = cfg.seq_len, cfg.embed_dim
        act = 2.0 * B * (T * D * 10 + sum(cfg.mlp_dims)) * 4.0
        return 3.0 * act if cell.kind == "train" else act
    if isinstance(cfg, recsys.Bert4RecConfig):
        T, D = cfg.seq_len, cfg.embed_dim
        act = 2.0 * B * T * D * 10 * cfg.n_blocks * 4.0
        if cell.kind == "train" and cfg.n_items > 100_000:
            act += 2.0 * B * int(0.15 * T) * 8192 * 4.0   # sampled logits
            act *= 3.0
        elif cell.kind == "train":
            act = 3.0 * (act + 2.0 * B * T * cfg.vocab * 4.0)
        else:
            act += 2.0 * B * cfg.vocab * 4.0               # top-k scores
        return act
    raise TypeError(type(cfg))


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful compute" numerator for §Roofline)
# ---------------------------------------------------------------------------

def model_flops(cfg, cell: ShapeCell) -> float:
    d = cell.dims
    if isinstance(cfg, tr.LMConfig):
        n = cfg.active_param_count() if cfg.moe else cfg.param_count()
        if cell.kind == "train":
            return 6.0 * n * d["batch"] * d["seq"]
        if cell.kind == "prefill":
            return 2.0 * n * d["batch"] * d["seq"]
        return 2.0 * n * d["batch"]  # decode: one token per sequence
    if isinstance(cfg, gnn.GATConfig):
        # per edge per layer: attention score + message (2 * H * D flops-ish)
        e = d["n_edges"]
        n = d["n_nodes"]
        h, dd = cfg.n_heads, cfg.d_hidden
        proj = 2.0 * n * cfg.d_in * h * dd
        msg = 6.0 * e * h * dd
        f = cfg.n_layers * (proj + msg)
        return 3.0 * f if cell.kind == "train" else f
    # recsys: dominated by MLP/interaction + embedding gathers
    B = d.get("batch", 1)
    if isinstance(cfg, recsys.TwoTowerConfig):
        dims = (2 * cfg.embed_dim,) + cfg.tower_mlp
        fl = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
        dims_i = (cfg.embed_dim,) + cfg.tower_mlp
        fl += sum(2.0 * a * b for a, b in zip(dims_i[:-1], dims_i[1:]))
        f = B * fl
        if cell.kind == "retrieval":
            f += 2.0 * B * d["n_candidates"] * cfg.tower_mlp[-1]
            dims_i = (cfg.embed_dim,) + cfg.tower_mlp
            f += d["n_candidates"] * sum(2.0 * a * b
                                         for a, b in zip(dims_i[:-1], dims_i[1:]))
        if cell.kind == "train":
            f = 3.0 * f + 2.0 * B * B * cfg.tower_mlp[-1]
        return f
    if isinstance(cfg, recsys.XDeepFMConfig):
        if cell.kind == "retrieval":
            B = d["n_candidates"]   # broadcast-forward over candidates
        m, D = cfg.n_fields, cfg.embed_dim
        h_prev, cin = m, 0.0
        for h in cfg.cin_layers:
            cin += 2.0 * h_prev * m * D * h
            h_prev = h
        dims = (m * D,) + cfg.dnn_dims + (1,)
        dnn = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
        f = B * (cin + dnn)
        return 3.0 * f if cell.kind == "train" else f
    if isinstance(cfg, recsys.BSTConfig):
        if cell.kind == "retrieval":
            B = d["n_candidates"]
        T, D = cfg.seq_len, cfg.embed_dim
        attn = cfg.n_blocks * (8.0 * T * D * D + 4.0 * T * T * D
                               + 4.0 * T * D * cfg.d_ff)
        dims = (T * D + cfg.n_profile_fields * D,) + cfg.mlp_dims + (1,)
        head = sum(2.0 * a * b for a, b in zip(dims[:-1], dims[1:]))
        f = B * (attn + head)
        return 3.0 * f if cell.kind == "train" else f
    if isinstance(cfg, recsys.Bert4RecConfig):
        T, D = cfg.seq_len, cfg.embed_dim
        enc = cfg.n_blocks * (8.0 * T * D * D + 4.0 * T * T * D
                              + 4.0 * T * D * cfg.d_ff)
        if cell.kind == "train":
            if cfg.n_items > 100_000:   # sampled softmax over K+1 candidates
                M = max(1, int(0.15 * T))
                out = 2.0 * M * D * (8192 + 1)
            else:
                out = 2.0 * T * D * cfg.vocab
            return 3.0 * B * (enc + out)
        # serve/retrieval: encoder + LAST-position scores over the vocab
        out = 2.0 * D * cfg.vocab
        return B * (enc + out)
    raise TypeError(type(cfg))
