"""RecSys architectures: BST, xDeepFM (CIN), BERT4Rec, two-tower retrieval.

JAX has no native EmbeddingBag — it is built here from ``jnp.take`` +
masked reduction (taxonomy B.6/B.11), with id 0 reserved as padding.
Embedding tables are the huge tensors: they shard row-wise over 'tp';
batches shard over 'dp'.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import Params, init_linear, init_mlp, layer_norm, mlp


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------

def _n_layers(mlp_params: Dict) -> int:
    return sum(1 for k in mlp_params if k.startswith("w"))


def init_embedding(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.05).astype(dtype)


def embedding_bag(table: jax.Array, idx: jax.Array, *, mode: str = "mean",
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """table: [V, D]; idx: [..., bag] int32 (0 = padding) -> [..., D]."""
    emb = jnp.take(table, idx, axis=0)                    # [..., bag, D]
    m = (idx != 0).astype(emb.dtype)[..., None]
    if weights is not None:
        m = m * weights[..., None]
    s = jnp.sum(emb * m, axis=-2)
    if mode == "sum":
        return s
    cnt = jnp.maximum(jnp.sum(m, axis=-2), 1e-9)
    return s / cnt


# ---------------------------------------------------------------------------
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 1_000_000
    n_profile_fields: int = 8
    profile_vocab: int = 100_000
    embed_dim: int = 32
    seq_len: int = 20               # history (seq_len - 1) + target
    n_blocks: int = 1
    n_heads: int = 8
    d_ff: int = 128
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def bst_init(rng, cfg: BSTConfig) -> Params:
    ks = jax.random.split(rng, 8 + cfg.n_blocks)
    D = cfg.embed_dim
    p = {
        "item_emb": init_embedding(ks[0], cfg.n_items, D, cfg.jdtype),
        "pos_emb": init_embedding(ks[1], cfg.seq_len, D, cfg.jdtype),
        "profile_emb": init_embedding(ks[2], cfg.profile_vocab, D, cfg.jdtype),
        "blocks": [],
    }
    for b in range(cfg.n_blocks):
        kb = jax.random.split(ks[3 + b], 6)
        p["blocks"].append({
            "wq": init_linear(kb[0], D, D, cfg.jdtype),
            "wk": init_linear(kb[1], D, D, cfg.jdtype),
            "wv": init_linear(kb[2], D, D, cfg.jdtype),
            "wo": init_linear(kb[3], D, D, cfg.jdtype),
            "ln1_s": jnp.ones((D,), cfg.jdtype), "ln1_b": jnp.zeros((D,), cfg.jdtype),
            "ln2_s": jnp.ones((D,), cfg.jdtype), "ln2_b": jnp.zeros((D,), cfg.jdtype),
            "ff1": init_linear(kb[4], D, cfg.d_ff, cfg.jdtype),
            "ff2": init_linear(kb[5], cfg.d_ff, D, cfg.jdtype),
        })
    d_flat = cfg.seq_len * D + cfg.n_profile_fields * D
    dims = (d_flat,) + cfg.mlp_dims + (1,)
    p["mlp"] = init_mlp(ks[-1], dims, cfg.jdtype)
    return p


def _tiny_mha(blk, x, n_heads):
    B, T, D = x.shape
    hd = D // n_heads
    q = (x @ blk["wq"]).reshape(B, T, n_heads, hd)
    k = (x @ blk["wk"]).reshape(B, T, n_heads, hd)
    v = (x @ blk["wv"]).reshape(B, T, n_heads, hd)
    logit = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / np.sqrt(hd)
    probs = jax.nn.softmax(logit, axis=-1)
    o = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return (o.reshape(B, T, D).astype(x.dtype)) @ blk["wo"]


def bst_forward(params: Params, batch: Dict, cfg: BSTConfig) -> jax.Array:
    """batch: {hist [B, seq-1], target [B], profile [B, F]} -> logits [B]."""
    seq = jnp.concatenate([batch["hist"], batch["target"][:, None]], axis=1)
    x = jnp.take(params["item_emb"], seq, axis=0)
    x = constrain(x, "dp", None, None)
    x = x + params["pos_emb"][None]
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_s"], blk["ln1_b"])
        x = x + _tiny_mha(blk, h, cfg.n_heads)
        h = layer_norm(x, blk["ln2_s"], blk["ln2_b"])
        x = x + jax.nn.gelu(h @ blk["ff1"]) @ blk["ff2"]
    prof = jnp.take(params["profile_emb"], batch["profile"], axis=0)
    flat = jnp.concatenate(
        [x.reshape(x.shape[0], -1), prof.reshape(prof.shape[0], -1)], axis=1)
    out = mlp(params["mlp"], flat, _n_layers(params["mlp"]),
              act=jax.nn.leaky_relu)
    return out[:, 0]


# ---------------------------------------------------------------------------
# xDeepFM — CIN + DNN + linear (arXiv:1803.05170)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    field_vocab: int = 200_000       # rows per field (single offset table)
    embed_dim: int = 10
    cin_layers: Tuple[int, ...] = (200, 200, 200)
    dnn_dims: Tuple[int, ...] = (400, 400)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def total_vocab(self):
        return self.n_fields * self.field_vocab


def xdeepfm_init(rng, cfg: XDeepFMConfig) -> Params:
    ks = jax.random.split(rng, 4 + len(cfg.cin_layers))
    p = {
        "emb": init_embedding(ks[0], cfg.total_vocab, cfg.embed_dim, cfg.jdtype),
        "linear_w": (jax.random.normal(ks[1], (cfg.total_vocab,), jnp.float32)
                     * 0.01).astype(cfg.jdtype),
        "cin": [],
        "bias": jnp.zeros((), cfg.jdtype),
    }
    h_prev = cfg.n_fields
    for i, h in enumerate(cfg.cin_layers):
        p["cin"].append(init_linear(ks[2 + i], h_prev * cfg.n_fields, h,
                                    cfg.jdtype))
        h_prev = h
    d_dnn = cfg.n_fields * cfg.embed_dim
    dims = (d_dnn,) + cfg.dnn_dims + (1,)
    p["dnn"] = init_mlp(ks[-1], dims, cfg.jdtype)
    p["cin_out"] = init_linear(ks[-2], sum(cfg.cin_layers), 1, cfg.jdtype)
    return p


def xdeepfm_forward(params: Params, batch: Dict, cfg: XDeepFMConfig) -> jax.Array:
    """batch: {fields [B, n_fields] int32 (already offset per field)}."""
    ids = batch["fields"]
    x0 = jnp.take(params["emb"], ids, axis=0)           # [B, m, D]
    x0 = constrain(x0, "dp", None, None)
    B, m, D = x0.shape
    # linear term
    lin = jnp.sum(jnp.take(params["linear_w"], ids, axis=0), axis=1)
    # CIN
    xk = x0
    pooled = []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)          # [B, Hk, m, D]
        z = z.reshape(B, -1, D)                          # [B, Hk*m, D]
        xk = jnp.einsum("bzd,zh->bhd", z, w)             # [B, Hk+1, D]
        xk = jax.nn.relu(xk)
        pooled.append(jnp.sum(xk, axis=-1))              # [B, Hk+1]
    cin_feat = jnp.concatenate(pooled, axis=1)
    cin_logit = (cin_feat @ params["cin_out"])[:, 0]
    # DNN
    dnn_logit = mlp(params["dnn"], x0.reshape(B, -1), _n_layers(params["dnn"]))[:, 0]
    return lin + cin_logit + dnn_logit + params["bias"]


# ---------------------------------------------------------------------------
# BERT4Rec — bidirectional masked item prediction (arXiv:1904.06690)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    n_items: int = 60_000            # + 1 mask token + 0 pad
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def mask_id(self):
        return self.n_items + 1

    @property
    def vocab(self):
        return self.n_items + 2


def bert4rec_init(rng, cfg: Bert4RecConfig) -> Params:
    ks = jax.random.split(rng, 2 + cfg.n_blocks)
    D = cfg.embed_dim
    p = {
        "item_emb": init_embedding(ks[0], cfg.vocab, D, cfg.jdtype),
        "pos_emb": init_embedding(ks[1], cfg.seq_len, D, cfg.jdtype),
        "blocks": [],
        "out_bias": jnp.zeros((cfg.vocab,), cfg.jdtype),
    }
    for b in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + b], 6)
        p["blocks"].append({
            "wq": init_linear(kb[0], D, D, cfg.jdtype),
            "wk": init_linear(kb[1], D, D, cfg.jdtype),
            "wv": init_linear(kb[2], D, D, cfg.jdtype),
            "wo": init_linear(kb[3], D, D, cfg.jdtype),
            "ln1_s": jnp.ones((D,), cfg.jdtype), "ln1_b": jnp.zeros((D,), cfg.jdtype),
            "ln2_s": jnp.ones((D,), cfg.jdtype), "ln2_b": jnp.zeros((D,), cfg.jdtype),
            "ff1": init_linear(kb[4], D, cfg.d_ff, cfg.jdtype),
            "ff2": init_linear(kb[5], cfg.d_ff, D, cfg.jdtype),
        })
    return p


def bert4rec_forward(params: Params, batch: Dict, cfg: Bert4RecConfig) -> jax.Array:
    """batch: {items [B, T]} -> logits [B, T, vocab] (tied output embedding)."""
    x = jnp.take(params["item_emb"], batch["items"], axis=0)
    x = constrain(x, "dp", None, None)
    x = x + params["pos_emb"][None]
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_s"], blk["ln1_b"])
        x = x + _tiny_mha(blk, h, cfg.n_heads)
        h = layer_norm(x, blk["ln2_s"], blk["ln2_b"])
        x = x + jax.nn.gelu(h @ blk["ff1"]) @ blk["ff2"]
    logits = x @ params["item_emb"].T + params["out_bias"]
    return constrain(logits, "dp", None, "tp")


def bert4rec_sampled_loss(params: Params, batch: Dict, cfg: Bert4RecConfig):
    """Sampled-softmax masked-item loss for production vocab sizes.

    batch: {items [B, T], mask_pos [B, M], labels [B, M], neg_ids [K]}.
    The label item competes against K shared negatives (logQ omitted: the
    sampler is uniform in the synthetic pipeline).
    """
    x = jnp.take(params["item_emb"], batch["items"], axis=0)
    x = constrain(x, "dp", None, None)
    x = x + params["pos_emb"][None]
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_s"], blk["ln1_b"])
        x = x + _tiny_mha(blk, h, cfg.n_heads)
        h = layer_norm(x, blk["ln2_s"], blk["ln2_b"])
        x = x + jax.nn.gelu(h @ blk["ff1"]) @ blk["ff2"]
    hm = jnp.take_along_axis(x, batch["mask_pos"][..., None], axis=1)  # [B,M,D]
    lab_e = jnp.take(params["item_emb"], batch["labels"], axis=0)      # [B,M,D]
    neg_e = jnp.take(params["item_emb"], batch["neg_ids"], axis=0)     # [K,D]
    pos_logit = jnp.sum(hm * lab_e, axis=-1, dtype=jnp.float32) \
        + params["out_bias"][batch["labels"]]
    neg_logit = jnp.einsum("bmd,kd->bmk", hm.astype(jnp.float32),
                           neg_e.astype(jnp.float32)) \
        + params["out_bias"][batch["neg_ids"]][None, None, :]
    lse = jax.nn.logsumexp(
        jnp.concatenate([pos_logit[..., None], neg_logit], axis=-1), axis=-1)
    nll = jnp.mean(lse - pos_logit)
    return nll, {"nll": nll}


def bert4rec_topk_serve(params: Params, batch: Dict, cfg: Bert4RecConfig,
                        top_k: int = 100, n_chunks: int = 16):
    """Next-item top-k for the last position, hierarchical over vocab chunks
    (keeps the [B, V] score matrix tp-sharded instead of all-gathered)."""
    x = jnp.take(params["item_emb"], batch["items"], axis=0)
    x = constrain(x, "dp", None, None)
    x = x + params["pos_emb"][None]
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_s"], blk["ln1_b"])
        x = x + _tiny_mha(blk, h, cfg.n_heads)
        h = layer_norm(x, blk["ln2_s"], blk["ln2_b"])
        x = x + jax.nn.gelu(h @ blk["ff1"]) @ blk["ff2"]
    hl = x[:, -1]                                         # [B, D]
    V = cfg.vocab
    pad = (-V) % n_chunks
    emb = params["item_emb"]
    bias = params["out_bias"]
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
        bias = jnp.pad(bias, (0, pad), constant_values=-1e30)
    Vc = emb.shape[0] // n_chunks
    emb_c = emb.reshape(n_chunks, Vc, -1)
    bias_c = bias.reshape(n_chunks, Vc)
    scores = jnp.einsum("bd,cvd->bcv", hl.astype(jnp.float32),
                        emb_c.astype(jnp.float32)) + bias_c[None]
    scores = constrain(scores, "all", None, None)
    v1, i1 = jax.lax.top_k(scores, min(top_k, Vc))        # [B, C, K]
    i1 = i1 + jnp.arange(n_chunks, dtype=jnp.int32)[None, :, None] * Vc
    v1 = v1.reshape(v1.shape[0], -1)
    i1 = i1.reshape(i1.shape[0], -1)
    v2, sel = jax.lax.top_k(v1, top_k)
    return v2, jnp.take_along_axis(i1, sel, axis=1)


def bert4rec_loss(params: Params, batch: Dict, cfg: Bert4RecConfig):
    """Masked-position cross entropy. batch: items, labels, loss_mask."""
    logits = bert4rec_forward(params, batch, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    m = batch["loss_mask"].astype(jnp.float32)
    nll = jnp.sum((lse - ll) * m) / jnp.maximum(jnp.sum(m), 1.0)
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# Two-tower retrieval with sampled softmax (Yi et al., RecSys'19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_items: int = 10_000_000
    n_users: int = 10_000_000
    hist_len: int = 50
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    logq_correction: bool = True
    temperature: float = 0.05
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def twotower_init(rng, cfg: TwoTowerConfig) -> Params:
    ks = jax.random.split(rng, 6)
    D = cfg.embed_dim
    u_dims = (2 * D,) + cfg.tower_mlp
    i_dims = (D,) + cfg.tower_mlp
    p = {
        "user_emb": init_embedding(ks[0], cfg.n_users, D, cfg.jdtype),
        "item_emb": init_embedding(ks[1], cfg.n_items, D, cfg.jdtype),
        "user_mlp": init_mlp(ks[2], u_dims, cfg.jdtype),
        "item_mlp": init_mlp(ks[3], i_dims, cfg.jdtype),
    }
    return p


def user_tower(params, batch, cfg: TwoTowerConfig) -> jax.Array:
    u = jnp.take(params["user_emb"], batch["user_id"], axis=0)
    h = embedding_bag(params["item_emb"], batch["hist"], mode="mean")
    x = jnp.concatenate([u, h], axis=-1)
    x = mlp(params["user_mlp"], x, _n_layers(params["user_mlp"]), act=jax.nn.relu)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def item_tower(params, item_ids, cfg: TwoTowerConfig) -> jax.Array:
    x = jnp.take(params["item_emb"], item_ids, axis=0)
    x = mlp(params["item_mlp"], x, _n_layers(params["item_mlp"]), act=jax.nn.relu)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params: Params, batch: Dict, cfg: TwoTowerConfig):
    """In-batch sampled softmax with logQ correction.

    batch: {user_id [B], hist [B, H], pos_item [B], item_logq [B]}.
    """
    u = user_tower(params, batch, cfg)                   # [B, D]
    v = item_tower(params, batch["pos_item"], cfg)       # [B, D]
    logits = (u @ v.T) / cfg.temperature                 # [B, B]
    # rows follow the fully-sharded batch; columns need the gathered v
    logits = constrain(logits, "all", None)
    if cfg.logq_correction and "item_logq" in batch:
        logits = logits - batch["item_logq"][None, :]
    logits = logits.astype(jnp.float32)
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.mean(lse - ll)
    return nll, {"nll": nll}


def retrieval_scores(params: Params, batch: Dict, cfg: TwoTowerConfig,
                     top_k: int = 100):
    """Score 1 query against n_candidates via batched dot; returns top-k.

    batch: {user_id [B], hist [B, H], cand_ids [N]} — the candidate tower
    runs over the (sharded) candidate id set; no loops.
    """
    u = user_tower(params, batch, cfg)                   # [B, D]
    cand = item_tower(params, batch["cand_ids"], cfg)    # [N, D] ('tp'-sharded)
    cand = constrain(cand, "tp", None)
    scores = u @ cand.T                                  # [B, N]
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx


# binary-cross-entropy helper shared by BST / xDeepFM
def bce_loss(logits: jax.Array, labels: jax.Array):
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    nll = jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    return nll, {"nll": nll}
