"""GAT (Velickovic et al., arXiv:1710.10903) with segment-op message passing.

JAX has no sparse SpMM beyond BCOO, so message passing is built from the
primitives the taxonomy mandates: gather over an edge index, edge-softmax
via ``segment_max``/``segment_sum`` (numerically stable), and scatter-sum
aggregation. Edges are the only large tensors — they shard over 'dp'.

Includes the host-side fanout neighbor sampler required by the
``minibatch_lg`` shape (GraphSAGE-style layered sampling, padded to static
shapes for jit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import init_linear

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    d_in: int
    d_hidden: int           # per head
    n_heads: int
    n_layers: int
    n_classes: int
    dtype: str = "float32"
    negative_slope: float = 0.2

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def init_params(rng, cfg: GATConfig) -> Params:
    ks = jax.random.split(rng, cfg.n_layers * 3)
    layers = []
    d_in = cfg.d_in
    for l in range(cfg.n_layers):
        last = l == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": init_linear(ks[3 * l], d_in, heads * d_out, cfg.jdtype),
            "a_src": (jax.random.normal(ks[3 * l + 1], (heads, d_out),
                                        jnp.float32) * 0.1).astype(cfg.jdtype),
            "a_dst": (jax.random.normal(ks[3 * l + 2], (heads, d_out),
                                        jnp.float32) * 0.1).astype(cfg.jdtype),
        })
        d_in = heads * d_out if not last else d_out
    return {"layers": layers}


def gat_layer(p: Params, x, src, dst, n_nodes: int, heads: int, d_out: int,
              edge_valid=None, slope: float = 0.2, last: bool = False):
    """x: [N, d_in]; src/dst: [E] int32 (message src -> dst)."""
    h = (x @ p["w"]).reshape(-1, heads, d_out)             # [N, H, D]
    e_src = jnp.sum(h * p["a_src"][None], axis=-1)         # [N, H]
    e_dst = jnp.sum(h * p["a_dst"][None], axis=-1)
    # per-edge unnormalized attention
    logits = jax.nn.leaky_relu(e_src[src] + e_dst[dst], slope)  # [E, H]
    logits = constrain(logits, "dp", None)
    if edge_valid is not None:
        logits = jnp.where(edge_valid[:, None], logits, -1e30)
        safe_dst = jnp.where(edge_valid, dst, n_nodes - 1)
    else:
        safe_dst = dst
    # segment softmax over incoming edges of each dst (f32, max-shifted)
    lmax = jax.ops.segment_max(logits.astype(jnp.float32), safe_dst,
                               num_segments=n_nodes)       # [N, H]
    lmax = jnp.where(jnp.isfinite(lmax), lmax, 0.0)
    ex = jnp.exp(logits.astype(jnp.float32) - lmax[safe_dst])
    if edge_valid is not None:
        ex = jnp.where(edge_valid[:, None], ex, 0.0)
    denom = jax.ops.segment_sum(ex, safe_dst, num_segments=n_nodes)
    alpha = ex / jnp.maximum(denom[safe_dst], 1e-16)       # [E, H]
    msg = h[src].astype(jnp.float32) * alpha[..., None]    # [E, H, D]
    msg = constrain(msg, "dp", None, None)
    out = jax.ops.segment_sum(msg, safe_dst, num_segments=n_nodes)  # [N,H,D]
    if last:
        out = jnp.mean(out, axis=1)                        # average heads
    else:
        out = jax.nn.elu(out.reshape(n_nodes, heads * d_out))
    return out.astype(x.dtype)


def forward(params: Params, batch: Dict, cfg: GATConfig) -> jax.Array:
    """batch: {x [N, F], src [E], dst [E], edge_valid? [E]} -> logits [N, C]."""
    x = batch["x"].astype(cfg.jdtype)
    src, dst = batch["src"], batch["dst"]
    ev = batch.get("edge_valid")
    n = x.shape[0]
    for l, p in enumerate(params["layers"]):
        last = l == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        x = gat_layer(p, x, src, dst, n, heads, d_out, ev,
                      cfg.negative_slope, last)
    return x


def loss_fn(params: Params, batch: Dict, cfg: GATConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    labels = batch["labels"]
    mask = batch.get("label_mask", jnp.ones_like(labels, bool))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.sum(jnp.where(mask, lse - ll, 0.0)) / jnp.maximum(
        jnp.sum(mask.astype(jnp.float32)), 1.0)
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# Neighbor sampler (host-side, GraphSAGE-style fanout sampling)
# ---------------------------------------------------------------------------

class CSRGraph(NamedTuple):
    indptr: np.ndarray   # [N+1]
    indices: np.ndarray  # [E] — in-neighbors of each node


def build_csr(n_nodes: int, src: np.ndarray, dst: np.ndarray) -> CSRGraph:
    """CSR over incoming edges (dst -> its srcs)."""
    order = np.argsort(dst, kind="stable")
    s_dst = dst[order]
    s_src = src[order]
    counts = np.bincount(s_dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=s_src.astype(np.int64))


def sample_subgraph(g: CSRGraph, feats: np.ndarray, seed_nodes: np.ndarray,
                    fanouts: List[int], rng: np.random.Generator
                    ) -> Dict[str, np.ndarray]:
    """Layered fanout sampling; returns padded static-shape arrays.

    Output nodes are renumbered 0..N_sub; seeds occupy [0, len(seeds)).
    Shapes: nodes = seeds * prod(1 + fanouts...) upper bound; edges padded
    with edge_valid mask.
    """
    n_seeds = len(seed_nodes)
    max_nodes = n_seeds
    layer_sizes = [n_seeds]
    for f in fanouts:
        layer_sizes.append(layer_sizes[-1] * f)
        max_nodes += layer_sizes[-1]
    max_edges = sum(layer_sizes[1:])

    node_ids = list(seed_nodes)
    node_pos = {int(n): i for i, n in enumerate(seed_nodes)}
    src_l, dst_l = [], []
    frontier = list(seed_nodes)
    for f in fanouts:
        nxt = []
        for n in frontier:
            lo, hi = g.indptr[n], g.indptr[n + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, int(deg))
            picks = g.indices[lo + rng.choice(deg, size=take, replace=False)]
            for p in picks:
                p = int(p)
                if p not in node_pos:
                    node_pos[p] = len(node_ids)
                    node_ids.append(p)
                src_l.append(node_pos[p])
                dst_l.append(node_pos[int(n)])
                nxt.append(p)
        frontier = nxt

    n_sub = len(node_ids)
    x = np.zeros((max_nodes, feats.shape[1]), feats.dtype)
    x[:n_sub] = feats[np.asarray(node_ids, np.int64)]
    E = len(src_l)
    src = np.full(max_edges, max_nodes - 1, np.int32)
    dst = np.full(max_edges, max_nodes - 1, np.int32)
    src[:E] = src_l
    dst[:E] = dst_l
    ev = np.zeros(max_edges, bool)
    ev[:E] = True
    return {"x": x, "src": src, "dst": dst, "edge_valid": ev,
            "node_ids": np.asarray(node_ids[:n_sub], np.int64),
            "n_sub": n_sub, "n_seeds": n_seeds}
