"""Dense / MoE causal LM: scan-over-layers, GQA/SWA/qk-norm, prefill/decode.

Compile-time discipline: the layer stack is a single ``lax.scan`` over
stacked block parameters, so HLO size (and CPU compile time at 512 virtual
devices) is O(1) in depth. Activation checkpointing wraps the scan body
(policy from config). All matmuls run in the config dtype (bf16 by default)
with f32 softmax/norm accumulations.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from . import kv_cache as kvc
from .layers import (AttentionConfig, Params, attention, init_attention,
                     init_swiglu, rms_norm, swiglu)
from .moe import MoEConfig, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    window: int = 0                   # sliding-window attention width
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"
    remat: str = "full"               # none | full | dots
    use_pallas_attention: bool = False
    tie_embeddings: bool = False
    scan_unroll: int = 1              # lax.scan unroll (cost probes use =L)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            qk_norm=self.qk_norm, window=self.window,
            rope_theta=self.rope_theta, causal=True,
            use_pallas=self.use_pallas_attention)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to 256 so the vocab dim shards evenly
        over any tp size; padded logits are masked to -inf (never selected,
        never targets)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Total parameters (N for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe:
            ff = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
            if self.moe.n_shared_experts:
                ff += 3 * d * self.moe.shared_d_ff * self.moe.n_shared_experts + d
        else:
            ff = 3 * d * self.d_ff
        norms = 2 * d
        per_layer = attn + ff + norms
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Active parameters per token (N_active for MoE MODEL_FLOPS)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        attn = d * self.hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        ff = 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
        if self.moe.n_shared_experts:
            ff += 3 * d * self.moe.shared_d_ff * self.moe.n_shared_experts + d
        per_layer = attn + ff + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(rng, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    p = {
        "ln_attn": jnp.ones((cfg.d_model,), cfg.jdtype),
        "ln_ffn": jnp.ones((cfg.d_model,), cfg.jdtype),
        "attn": init_attention(k1, cfg.attn, cfg.jdtype),
    }
    if cfg.moe:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe, cfg.jdtype)
    else:
        p["ffn"] = init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.jdtype)
    return p


def init_params(rng, cfg: LMConfig) -> Params:
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    p = {
        "embed": (jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(cfg.jdtype),
        "blocks": blocks,
        "norm_f": jnp.ones((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.padded_vocab),
                                          jnp.float32)
                        / np.sqrt(cfg.d_model)).astype(cfg.jdtype)
    return p


def params_sharding_rules():
    """(path_regex, logical axes per dim) — Megatron-style TP (training).

    Block params are scan-STACKED: leading dim is the layer index, so every
    blocks/ rule starts with None for the L dim."""
    return [
        (r"embed", ("tp", None)),                      # vocab-sharded
        (r"lm_head", (None, "tp")),
        (r"attn/w[qkv]$", (None, None, "tp")),         # [L, d, H*hd]
        (r"attn/wo$", (None, "tp", None)),             # [L, H*hd, d]
        (r"ffn/w_(gate|up)$", (None, None, "tp")),     # [L, d, ff]
        (r"ffn/w_down$", (None, "tp", None)),          # [L, ff, d]
        # Expert weights are the memory monster (Mixtral: 141B params,
        # 1.7TB of f32 optimizer state) — FSDP-style 2D sharding (dp x tp):
        # stored fully sharded, gathered per layer on use.
        (r"moe/router$", (None, None, None)),
        (r"moe/w_(gate|up)$", (None, None, "dp", "tp")),   # [L, E, d, f]
        (r"moe/w_down$", (None, None, "tp", "dp")),        # [L, E, f, d]
        (r"moe/shared/w_(gate|up)$", (None, "dp", "tp")),
        (r"moe/shared/w_down$", (None, "tp", "dp")),
    ]


def serve_sharding_rules():
    """2D (dp x tp) weight sharding for serving.

    At serve time there is no optimizer keeping params hot per dp replica;
    fully sharding weights over BOTH axes is what lets a 141B-param MoE fit
    a 16GB/chip pod (weights are (all-)gathered per layer as used — the
    collective term in the roofline carries that cost)."""
    return [
        (r"embed", ("tp", "dp")),
        (r"lm_head", ("dp", "tp")),
        (r"attn/w[qkv]$", (None, "dp", "tp")),
        (r"attn/wo$", (None, "tp", "dp")),
        (r"ffn/w_(gate|up)$", (None, "dp", "tp")),
        (r"ffn/w_down$", (None, "tp", "dp")),
        (r"moe/router$", (None, None, None)),
        (r"moe/w_(gate|up)$", (None, None, "dp", "tp")),
        (r"moe/w_down$", (None, None, "tp", "dp")),
        (r"moe/shared/w_(gate|up)$", (None, "dp", "tp")),
        (r"moe/shared/w_down$", (None, "tp", "dp")),
    ]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _constrain_block(block: Params) -> Params:
    """Sharding constraints on the per-layer param slice INSIDE the scan
    body. Critical for training: with_sharding_constraint transposes onto
    the cotangents, which keeps the backward scan's stacked-gradient carry
    sharded (without this XLA materializes full [L, d, ff] f32 gradient
    carries per device — observed 8.4GB/tensor on granite-3-8b)."""
    from ..distributed.sharding import constrain as c
    b = dict(block)
    b["attn"] = dict(block["attn"])
    for k in ("wq", "wk", "wv"):
        b["attn"][k] = c(block["attn"][k], None, "tp")
    b["attn"]["wo"] = c(block["attn"]["wo"], "tp", None)
    if "ffn" in b:
        b["ffn"] = {
            "w_gate": c(block["ffn"]["w_gate"], None, "tp"),
            "w_up": c(block["ffn"]["w_up"], None, "tp"),
            "w_down": c(block["ffn"]["w_down"], "tp", None),
        }
    if "moe" in b:
        # keep expert weights (and their bwd cotangent carries) 2D-sharded;
        # compute-side gathers are inserted where the einsums need them
        m = dict(block["moe"])
        m["w_gate"] = c(m["w_gate"], None, "dp", "tp")
        m["w_up"] = c(m["w_up"], None, "dp", "tp")
        m["w_down"] = c(m["w_down"], None, "tp", "dp")
        if "shared" in m:
            s = dict(m["shared"])
            s["w_gate"] = c(s["w_gate"], "dp", "tp")
            s["w_up"] = c(s["w_up"], "dp", "tp")
            s["w_down"] = c(s["w_down"], "tp", "dp")
            m["shared"] = s
        b["moe"] = m
    return b


def _block_apply(block: Params, x, positions, cfg: LMConfig,
                 cache: Optional[Dict]):
    block = _constrain_block(block)
    h, new_cache = attention(block["attn"], rms_norm(x, block["ln_attn"]),
                             cfg.attn, positions, cache)
    x = x + h
    if cfg.moe:
        h, aux = moe_ffn(block["moe"], rms_norm(x, block["ln_ffn"]), cfg.moe)
    else:
        h, aux = swiglu(block["ffn"], rms_norm(x, block["ln_ffn"])), 0.0
    return x + h, new_cache, aux


def _remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params: Params, tokens, cfg: LMConfig, *,
            positions=None, caches: Optional[Dict] = None
            ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """tokens: [B, T] -> (logits [B, T, V], caches', aux_loss)."""
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = params["embed"][tokens].astype(cfg.jdtype)
    x = constrain(x, "dp", None, None)

    if caches is None:
        def body(x, block):
            y, _, aux = _block_apply(block, x, positions, cfg, None)
            return y, aux
        x, auxs = jax.lax.scan(_remat(body, cfg), x, params["blocks"],
                               unroll=cfg.scan_unroll)
    else:
        def body(x, blk_cache):
            block, cache = blk_cache
            y, new_cache, aux = _block_apply(block, x, positions, cfg, cache)
            return y, (new_cache, aux)
        x, (caches, auxs) = jax.lax.scan(body, x, (params["blocks"], caches),
                                         unroll=cfg.scan_unroll)

    x = rms_norm(x, params["norm_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.padded_vocab != cfg.vocab_size:   # mask padded vocab rows
        pad_mask = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30
        ).astype(logits.dtype)
        logits = logits + pad_mask
    logits = constrain(logits, "dp", None, "tp")
    return logits, caches, jnp.mean(auxs)


def loss_fn(params: Params, batch: Dict, cfg: LMConfig) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy; batch = {tokens [B, T+1]} or tokens/labels."""
    if "labels" in batch:
        tokens, labels = batch["tokens"], batch["labels"]
    else:
        tokens, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
    logits, _, aux = forward(params, tokens, cfg)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    total = nll + (cfg.moe.aux_weight * aux if cfg.moe else 0.0)
    return total, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg: LMConfig, batch: int, max_len: int) -> Dict:
    """Stacked (n_layers-leading) cache pytree for lax.scan."""
    one = kvc.init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, cfg.jdtype,
                         window=cfg.window)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def prefill(params: Params, tokens, cfg: LMConfig, caches: Dict):
    """Run the prompt through the model, filling caches. [B, T] tokens.

    Sliding-window models chunk the prompt to the window size (a ring cache
    can only absorb <= W tokens per update without overwriting keys that
    same-call queries still need).
    """
    B, T = tokens.shape
    chunk = cfg.window if cfg.window > 0 else T
    if T <= chunk:
        logits, caches, _ = forward(params, tokens, cfg, caches=caches)
        return logits, caches
    assert T % chunk == 0, f"prompt {T} not a multiple of window {chunk}"
    logits = None
    for i in range(T // chunk):
        seg = tokens[:, i * chunk:(i + 1) * chunk]
        pos = jnp.broadcast_to(
            jnp.arange(i * chunk, (i + 1) * chunk, dtype=jnp.int32), (B, chunk))
        logits, caches, _ = forward(params, seg, cfg, positions=pos,
                                    caches=caches)
    return logits, caches


def decode_step(params: Params, tokens, cfg: LMConfig, caches: Dict):
    """One new token per sequence. tokens: [B, 1]."""
    pos = caches["pos"][0][:, None]  # layer 0's positions [B, 1]
    logits, caches, _ = forward(params, tokens, cfg, positions=pos,
                                caches=caches)
    return logits[:, -1], caches
