"""Shared model layers: norms, RoPE, GQA attention (full / sliding-window /
qk-norm), SwiGLU MLP. Pure-functional: params are plain dict pytrees.

Sharding: activations/params use logical axes via ``distributed.sharding``
('dp' batch, 'tp' heads / ffn). Attention math runs in f32 accumulation
regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain

Params = Dict[str, Any]


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def init_linear(rng, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., T, H, D]; positions: [..., T] int32."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, D/2]
    cos = jnp.cos(ang)[..., None, :]                 # [..., T, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: int = 0          # >0: sliding-window attention
    rope_theta: float = 10000.0
    causal: bool = True
    use_pallas: bool = False  # route the fwd through the flash kernel


def init_attention(rng, cfg: AttentionConfig, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": init_linear(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": init_linear(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


ATTN_Q_CHUNK = 1024  # q-block size above which attention is chunked


def _attn_block(q, k, v, cfg: AttentionConfig, q_positions, k_positions,
                k_valid=None):
    """One q-block: q [B,T,Hq,D], k/v [B,S,Hkv,D] -> [B,T,Hq,D] (f32 acc)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qh = q.reshape(B, T, Hkv, rep, D)
    logits = jnp.einsum("bthrd,bshd->bhrts", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    mask = jnp.ones((B, T, S), bool)
    if cfg.causal:
        mask &= k_positions[:, None, :] <= q_positions[:, :, None]
    if cfg.window > 0:
        mask &= k_positions[:, None, :] > q_positions[:, :, None] - cfg.window
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrts,bshd->bthrd", probs, v.astype(jnp.float32))
    return out.reshape(B, T, Hq, D).astype(q.dtype)


def _attn_piece(q, k, v, cfg: AttentionConfig, q_positions, k_positions,
                k_valid=None):
    """One piece of a split-KV attention: returns UNNORMALIZED
    (o, m, l) — exp-weighted values, per-query running max and denom —
    for online-softmax merging across pieces (flash-style)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qh = q.reshape(B, T, Hkv, rep, D)
    logits = jnp.einsum("bthrd,bshd->bhrts", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    mask = jnp.ones((B, T, S), bool)
    if cfg.causal:
        mask &= k_positions[:, None, :] <= q_positions[:, :, None]
    if cfg.window > 0:
        mask &= k_positions[:, None, :] > q_positions[:, :, None] - cfg.window
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    m = jnp.max(logits, axis=-1)                      # (B,h,r,T)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhrts,bshd->bthrd", p, v.astype(jnp.float32))
    perm = lambda x: x.transpose(0, 3, 1, 2).reshape(B, T, Hq)
    return (o.reshape(B, T, Hq, D), perm(m), perm(l))


def _attn_math(q, k, v, cfg: AttentionConfig, q_positions, k_positions,
               k_valid=None):
    """Attention with q-axis chunking (XLA 'flash-at-block-level'): never
    materializes more than [B, chunk, S] logits; the chunk loop is a scan
    with rematerialized body, so backward recomputes block logits instead
    of saving [T, S]."""
    B, T = q.shape[:2]
    chunk = ATTN_Q_CHUNK
    if T <= chunk or T % chunk:
        return _attn_block(q, k, v, cfg, q_positions, k_positions, k_valid)
    nb = T // chunk
    qc = q.reshape(B, nb, chunk, *q.shape[2:]).swapaxes(0, 1)
    pc = q_positions.reshape(B, nb, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(_, inp):
        qb, pb = inp
        ob = _attn_block(qb, k, v, cfg, pb, k_positions, k_valid)
        return None, ob
    _, out = jax.lax.scan(body, None, (qc, pc))
    return out.swapaxes(0, 1).reshape(q.shape)


def attention(params: Params, x, cfg: AttentionConfig, positions,
              cache: Optional[Dict] = None) -> Tuple[jax.Array, Optional[Dict]]:
    """x: [B, T, d]. With a cache dict, runs a decode/prefill step and
    returns the updated cache (see kv_cache.py for the cache layout)."""
    B, T, _ = x.shape
    q = (x @ params["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if cfg.use_pallas:
            from ..kernels import ops as kops
            o = kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), cfg.causal, cfg.window)
            o = o.transpose(0, 2, 1, 3)
        else:
            o = _attn_math(q, k, v, cfg, positions, positions)
        new_cache = None
    elif T <= 16:
        # DECODE: two-piece attention — cache piece + fresh piece merged by
        # online softmax. NO concat of [cache, fresh]: concatenating a
        # tp-sharded cache with fresh tokens made GSPMD all-gather the
        # whole cache every decode step (EXPERIMENTS.md §Perf cell-1:
        # tX 6.7 ms -> 5.6 us on granite-3-8b/decode_32k).
        from .kv_cache import cache_read_state, cache_write
        pre_kpos, pre_valid = cache_read_state(cache)
        o1, m1, l1 = _attn_piece(q, cache["k"], cache["v"], cfg, positions,
                                 pre_kpos, pre_valid)
        o2, m2, l2 = _attn_piece(q, k, v, cfg, positions, positions, None)
        m = jnp.maximum(m1, m2)
        s1 = jnp.exp(m1 - m)        # o_i is already exp-weighted: rescale
        s2 = jnp.exp(m2 - m)        # by exp(m_i - m) only, denom uses l_i
        denom = jnp.maximum(l1 * s1 + l2 * s2, 1e-30)
        o = ((o1 * s1[..., None] + o2 * s2[..., None]) / denom[..., None]
             ).astype(q.dtype)
        new_cache = cache_write(cache, k, v, positions)
    else:
        # PREFILL: the concat cost amortizes over the whole chunk and the
        # q-chunked _attn_math bounds the logits working set.
        from .kv_cache import cache_update_and_read
        k_all, v_all, k_pos, k_valid, new_cache = cache_update_and_read(
            cache, k, v, positions)
        o = _attn_math(q, k_all, v_all, cfg, positions, k_pos, k_valid)

    o = o.reshape(B, T, cfg.n_heads * cfg.head_dim)
    out = o @ params["wo"]
    return constrain(out, "dp", None, None), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(rng, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": init_linear(ks[0], d_model, d_ff, dtype),
        "w_up": init_linear(ks[1], d_model, d_ff, dtype),
        "w_down": init_linear(ks[2], d_ff, d_model, dtype),
    }


def swiglu(params: Params, x) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "dp", None, "tp")
    return constrain(h @ params["w_down"], "dp", None, None)


def init_mlp(rng, dims, dtype, bias=True) -> Params:
    """Plain MLP given [d_in, h1, ..., d_out]."""
    ks = jax.random.split(rng, len(dims) - 1)
    p = {}
    for i in range(len(dims) - 1):
        p[f"w{i}"] = init_linear(ks[i], dims[i], dims[i + 1], dtype)
        if bias:
            p[f"b{i}"] = jnp.zeros((dims[i + 1],), dtype)
    return p


def mlp(params: Params, x, n_layers: int, act=jax.nn.relu,
        final_act: bool = False) -> jax.Array:
    for i in range(n_layers):
        x = x @ params[f"w{i}"]
        if f"b{i}" in params:
            x = x + params[f"b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x
