"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort dispatch,
optional shared experts (Qwen-MoE style), Switch-style aux loss.

Dispatch is **group-local**: tokens are reshaped to (G, N/G, d) with G
aligned to the data-parallel axis, and routing/sort/scatter happen within a
group — no cross-device sort, no [T, E, C] one-hot dispatch tensor. Each
group keeps an (E, C, d) buffer; C = ceil(top_k * N_g / E * capacity_factor).
Overflowed tokens fall through with zero update (standard capacity drop).

Expert weights are TP-sharded on the ffn dim ('tp'); experts themselves are
replicated across data shards (every group computes only its own tokens, so
FLOPs are not duplicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import init_linear

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert ffn width
    n_shared_experts: int = 0      # Qwen-style always-on experts
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    groups: int = 1                # dispatch groups (align to dp size)


def init_moe(rng, d_model: int, cfg: MoEConfig, dtype) -> Params:
    ks = jax.random.split(rng, 6)
    E, F = cfg.n_experts, cfg.d_ff
    s = 1.0 / np.sqrt(d_model)
    p = {
        "router": init_linear(ks[0], d_model, E, jnp.float32),  # fp32 router
        "w_gate": (jax.random.normal(ks[1], (E, d_model, F), jnp.float32) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, F), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d_model), jnp.float32)
                   / np.sqrt(F)).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        SF = cfg.shared_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": init_linear(ks[4], d_model, SF, dtype),
            "w_up": init_linear(ks[5], d_model, SF, dtype),
            "w_down": init_linear(ks[0], SF, d_model, dtype),
            "gate": init_linear(ks[1], d_model, 1, dtype),
        }
    return p


def _dispatch_all_groups(xf, router_logits, cfg: MoEConfig,
                        w_gate, w_up, w_down):
    """xf: (G, Ng, d); router_logits: (G, Ng, E) f32 -> (out, aux).

    Explicit group dim (no vmap) so the dispatch buffers can carry sharding
    constraints: groups shard over 'dp', the capacity dim over 'tp' — the
    (G, E, C, d) buffer is the big MoE tensor and must never replicate.
    """
    G, Ng, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = int(np.ceil(k * Ng / E * cfg.capacity_factor))
    C = C + (-C) % 8   # pad capacity to a tileable size

    top_v, top_i = jax.lax.top_k(router_logits, k)          # (G, Ng, k)
    gates = jax.nn.softmax(top_v, axis=-1)
    probs = jax.nn.softmax(router_logits, axis=-1)
    me = jnp.mean(probs, axis=1)                             # (G, E)
    ce = jnp.zeros((G, E), jnp.float32).at[
        jnp.arange(G)[:, None, None], top_i].add(1.0) / (Ng * k)
    aux = jnp.mean(E * jnp.sum(me * ce, axis=-1))

    flat_e = top_i.reshape(G, Ng * k).astype(jnp.int32)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Ng, dtype=jnp.int32), k)[None], (G, Ng * k))
    flat_g = gates.reshape(G, Ng * k)
    order = jnp.argsort(flat_e, axis=-1)                     # stable, per group
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    idx = jnp.broadcast_to(jnp.arange(Ng * k, dtype=jnp.int32)[None], (G, Ng * k))
    seg = se + jnp.arange(G, dtype=jnp.int32)[:, None] * E   # global segment id
    seg_start = jax.ops.segment_min(idx.reshape(-1), seg.reshape(-1),
                                    num_segments=G * E).reshape(G, E)
    pos = idx - jnp.take_along_axis(seg_start, se, axis=-1)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)              # OOB => dropped

    g_idx = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None],
                             (G, Ng * k))
    slot_tok = jnp.full((G, E * C), -1, jnp.int32).at[g_idx, slot].set(
        st, mode="drop")
    slot_gate = jnp.zeros((G, E * C), jnp.float32).at[g_idx, slot].set(
        sg, mode="drop")
    valid = slot_tok >= 0
    h_in = jnp.take_along_axis(
        xf, jnp.maximum(slot_tok, 0)[..., None], axis=1)     # (G, E*C, d)
    h_in = jnp.where(valid[..., None], h_in, 0).reshape(G, E, C, d)
    h_in = constrain(h_in, "dp", None, "tp", None)

    g = jnp.einsum("gecd,edf->gecf", h_in, w_gate)
    u = jnp.einsum("gecd,edf->gecf", h_in, w_up)
    h = jax.nn.silu(g) * u
    h = constrain(h, "dp", None, None, "tp")
    out_e = jnp.einsum("gecf,efd->gecd", h, w_down).reshape(G, E * C, d)
    out_e = constrain(out_e, "dp", "tp", None)

    g_slot = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None],
                              (G, E * C))
    out = jnp.zeros((G, Ng, d), xf.dtype).at[
        g_slot, jnp.where(valid, slot_tok, Ng)].add(
        (out_e * slot_gate[..., None]).astype(xf.dtype), mode="drop")
    return out, aux


def moe_ffn(params: Params, x, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (out, aux_loss). Group-local dispatch."""
    B, T, d = x.shape
    N = B * T
    G = cfg.groups
    assert N % G == 0, f"tokens {N} not divisible by groups {G}"
    xf = x.reshape(G, N // G, d)
    xf = constrain(xf, "dp", None, None)
    logits = (xf.astype(jnp.float32) @ params["router"])      # (G, Ng, E)

    out, aux = _dispatch_all_groups(xf, logits, cfg, params["w_gate"],
                                    params["w_up"], params["w_down"])
    out = out.reshape(B, T, d)

    if "shared" in params:
        sp = params["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        h = constrain(h, "dp", None, "tp")
        shared_out = h @ sp["w_down"]
        gate = jax.nn.sigmoid(x @ sp["gate"])
        out = out + gate * shared_out
    return constrain(out, "dp", None, None), aux
