"""Decode KV caches: full-length and sliding-window (ring buffer).

Cache pytree layout (per layer; the transformer scans over a stacked
leading layer dim):

  full:    {"k": [B, T_max, Hkv, D], "v": same, "pos": [B] int32}
  window:  {"k": [B, W, Hkv, D], "v": same, "pos": [B] int32}  (ring)

``pos`` is the number of tokens already written (the next write index).
A sliding-window cache keeps only the last W tokens — constant memory for
arbitrarily long decodes (the sub-quadratic state required by long_500k).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
               dtype, *, window: int = 0) -> Dict:
    L = window if window > 0 else max_len
    return {
        "k": jnp.zeros((batch, L, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, L, n_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "window": jnp.array(window, jnp.int32),  # 0 => full cache
    }


def cache_read_state(cache: Dict) -> Tuple[jax.Array, jax.Array]:
    """Absolute positions + validity of the PRE-write cache slots.
    Used by the two-piece (online-softmax) attention path, which never
    concatenates the cache with fresh keys."""
    B, L = cache["k"].shape[0], cache["k"].shape[1]
    window = cache["window"]
    is_ring = window > 0
    pre_pos = cache["pos"][:, None]
    slot = jnp.arange(L, dtype=jnp.int32)[None, :]
    ring_age = jnp.mod(pre_pos - 1 - slot, L)
    ring_abs = pre_pos - 1 - ring_age
    full_abs = jnp.broadcast_to(slot, (B, L))
    kpos = jnp.where(is_ring, ring_abs, full_abs)
    valid = (kpos >= 0) & (kpos < pre_pos)
    return kpos, valid


def cache_write(cache: Dict, k_new, v_new, positions) -> Dict:
    """Scatter T fresh tokens into the cache (ring: last min(T, W) survive)."""
    B, T = k_new.shape[0], k_new.shape[1]
    L = cache["k"].shape[1]
    window = cache["window"]
    is_ring = window > 0
    new_pos = positions[:, -1:] + 1
    survive = (~is_ring) | (positions >= new_pos - L)
    in_range = is_ring | (positions < L)
    write_idx = jnp.where(is_ring, jnp.mod(positions, L), positions)
    write_idx = jnp.where(survive & in_range, write_idx, L)   # OOB => drop
    b_idx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, T))
    out = dict(cache)
    out["k"] = cache["k"].at[b_idx, write_idx].set(k_new, mode="drop")
    out["v"] = cache["v"].at[b_idx, write_idx].set(v_new, mode="drop")
    out["pos"] = new_pos[:, 0]
    return out


def cache_update_and_read(cache: Dict, k_new, v_new, positions
                          ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Dict]:
    """Write T new tokens, return (k_all, v_all, k_positions, k_valid, cache').

    positions: [B, T] absolute positions of the new tokens (== pos .. pos+T-1).
    Write-then-read: the new tokens land in the buffer and attention reads
    the buffer directly (no concat copy — decode updates are in-place under
    buffer donation). REQUIREMENT for the ring layout: T <= W per call —
    ``transformer.prefill`` chunks long prompts accordingly.
    """
    B, T = k_new.shape[0], k_new.shape[1]
    L = cache["k"].shape[1]
    window = cache["window"]
    is_ring = window > 0

    # ---- READ the pre-write state (early queries of this chunk need keys
    # the write below would evict from a ring buffer) ----
    pre_pos = cache["pos"][:, None]                              # [B, 1]
    slot = jnp.arange(L, dtype=jnp.int32)[None, :]               # [1, L]
    ring_age = jnp.mod(pre_pos - 1 - slot, L)                    # [B, L]
    ring_abs = pre_pos - 1 - ring_age
    full_abs = jnp.broadcast_to(slot, (B, L))
    pre_kpos = jnp.where(is_ring, ring_abs, full_abs)
    pre_valid = (pre_kpos >= 0) & (pre_kpos < pre_pos)

    k_all = jnp.concatenate([cache["k"], k_new], axis=1)         # [B, L+T, ...]
    v_all = jnp.concatenate([cache["v"], v_new], axis=1)
    k_positions = jnp.concatenate([pre_kpos, positions], axis=1)
    k_valid = jnp.concatenate(
        [pre_valid, jnp.ones((B, T), bool)], axis=1)

    # ---- WRITE: for a ring, only the last min(T, L) tokens survive ----
    new_pos = positions[:, -1:] + 1                               # [B, 1]
    survive = (~is_ring) | (positions >= new_pos - L)
    in_range = is_ring | (positions < L)
    write_idx = jnp.where(is_ring, jnp.mod(positions, L), positions)
    write_idx = jnp.where(survive & in_range, write_idx, L)      # OOB => drop
    b_idx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None], (B, T))
    k = cache["k"].at[b_idx, write_idx].set(k_new, mode="drop")
    v = cache["v"].at[b_idx, write_idx].set(v_new, mode="drop")

    new_cache = dict(cache)
    new_cache["k"] = k
    new_cache["v"] = v
    new_cache["pos"] = new_pos[:, 0]
    return k_all, v_all, k_positions, k_valid, new_cache
