"""Architecture registry: ``get_arch(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.api import ArchSpec

_ARCH_MODULES = {
    "granite-3-8b": "granite_3_8b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "gat-cora": "gat_cora",
    "bst": "bst",
    "xdeepfm": "xdeepfm",
    "bert4rec": "bert4rec",
    "two-tower-retrieval": "two_tower_retrieval",
}


def get_arch(arch_id: str) -> ArchSpec:
    mod = import_module(f".{_ARCH_MODULES[arch_id]}", __package__)
    return mod.SPEC


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)
