"""qwen3-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from ..models.api import ArchSpec
from ..models.transformer import LMConfig
from .base import lm_shapes

CONFIG = LMConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6, dtype="bfloat16")

SMOKE = LMConfig(
    name="qwen3-8b-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, qk_norm=True, dtype="float32",
    remat="none")

SPEC = ArchSpec(arch_id="qwen3-8b", family="lm", model="lm",
                config=CONFIG, smoke_config=SMOKE, shapes=lm_shapes(swa=False),
                source="hf:Qwen/Qwen3-8B; hf")
