"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155, GQA. [hf:ibm-granite; shapes as assigned]"""
from ..models.api import ArchSpec
from ..models.transformer import LMConfig
from .base import lm_shapes

CONFIG = LMConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab_size=49155, head_dim=128, dtype="bfloat16")

SMOKE = LMConfig(
    name="granite-3-8b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, d_ff=320, vocab_size=512, head_dim=16, dtype="float32",
    remat="none")

SPEC = ArchSpec(arch_id="granite-3-8b", family="lm", model="lm",
                config=CONFIG, smoke_config=SMOKE,
                shapes=lm_shapes(swa=False),
                source="hf:ibm-granite/granite-3.0; hf")
