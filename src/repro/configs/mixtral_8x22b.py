"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from ..models.api import ArchSpec
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .base import lm_shapes

CONFIG = LMConfig(
    name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=0, vocab_size=32768, head_dim=128, window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384, capacity_factor=1.25),
    dtype="bfloat16")

SMOKE = LMConfig(
    name="mixtral-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=0, vocab_size=512, head_dim=16, window=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=96), dtype="float32",
    remat="none")

SPEC = ArchSpec(arch_id="mixtral-8x22b", family="lm", model="lm",
                config=CONFIG, smoke_config=SMOKE, shapes=lm_shapes(swa=True),
                source="arXiv:2401.04088; hf")
