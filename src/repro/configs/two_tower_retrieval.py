"""two-tower-retrieval [recsys] — embed_dim=256, tower MLP 1024-512-256,
dot interaction, in-batch sampled softmax with logQ correction.
[RecSys'19 (YouTube); unverified] — 10M-item catalog."""
from ..models.api import ArchSpec
from ..models.recsys import TwoTowerConfig
from .base import recsys_shapes

CONFIG = TwoTowerConfig(name="two-tower-retrieval", n_items=10_000_000,
                        n_users=10_000_000, hist_len=50, embed_dim=256,
                        tower_mlp=(1024, 512, 256), logq_correction=True)

SMOKE = TwoTowerConfig(name="two-tower-smoke", n_items=2000, n_users=1000,
                       hist_len=8, embed_dim=32, tower_mlp=(64, 32))

SPEC = ArchSpec(arch_id="two-tower-retrieval", family="recsys",
                model="twotower", config=CONFIG, smoke_config=SMOKE,
                shapes=recsys_shapes(), source="RecSys'19 (YouTube); unverified")
