"""Shared shape-cell builders for the assigned architecture matrix."""
from __future__ import annotations

from typing import Optional, Tuple

from ..models.api import ShapeCell

FULL_ATTN_SKIP = ("long_500k needs sub-quadratic attention state; this arch "
                  "is pure full attention (see DESIGN.md §3)")


def lm_shapes(*, swa: bool) -> Tuple[ShapeCell, ...]:
    """The 4 assigned LM shapes. long_500k only runs for SWA archs (ring
    KV cache => constant decode state)."""
    return (
        ShapeCell("train_4k", "train", {"batch": 256, "seq": 4096}),
        ShapeCell("prefill_32k", "prefill",
                  {"batch": 32, "seq": 32768, "cache_len": 32768}),
        ShapeCell("decode_32k", "decode",
                  {"batch": 128, "seq": 32768, "cache_len": 32768}),
        ShapeCell("long_500k", "decode",
                  {"batch": 1, "seq": 524288, "cache_len": 524288},
                  skip=None if swa else FULL_ATTN_SKIP),
    )


def recsys_shapes(n_candidates: int = 1_000_000) -> Tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_batch", "train", {"batch": 65536}),
        ShapeCell("serve_p99", "serve", {"batch": 512}),
        ShapeCell("serve_bulk", "serve", {"batch": 262144}),
        ShapeCell("retrieval_cand", "retrieval",
                  {"batch": 1, "n_candidates": n_candidates}),
    )
