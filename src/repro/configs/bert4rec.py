"""bert4rec [recsys] — embed_dim=64, 2 blocks, 2 heads, seq_len=200,
bidirectional masked-item modeling. [arXiv:1904.06690; paper]

Item vocab sized to 1M (production catalog; the retrieval_cand shape scores
1M candidates). Training uses sampled softmax (8192 shared negatives) —
a full 1M-way softmax over 65k x 200 positions is not a real workload."""
from ..models.api import ArchSpec
from ..models.recsys import Bert4RecConfig
from .base import recsys_shapes

CONFIG = Bert4RecConfig(name="bert4rec", n_items=1_000_000, embed_dim=64,
                        n_blocks=2, n_heads=2, seq_len=200, d_ff=256)

SMOKE = Bert4RecConfig(name="bert4rec-smoke", n_items=500, embed_dim=32,
                       n_blocks=2, n_heads=2, seq_len=16, d_ff=64)

SPEC = ArchSpec(arch_id="bert4rec", family="recsys", model="bert4rec",
                config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
                source="arXiv:1904.06690; paper")
