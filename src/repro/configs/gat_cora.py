"""gat-cora [gnn] — 2L d_hidden=8 n_heads=8 attention aggregator.
[arXiv:1710.10903; paper]

The four assigned graph shapes span three regimes: full-batch small (Cora),
fanout-sampled training (Reddit-scale), full-batch large (ogbn-products),
and batched small graphs (molecules). Input feature width / class count
follow each dataset; the GAT body (2L, 8 heads x 8) is fixed per the
assignment. Sampled-subgraph sizes are the static padded bounds produced by
``models.gnn.sample_subgraph`` for batch_nodes=1024, fanout 15-10.
"""
from ..models.api import ArchSpec, ShapeCell
from ..models.gnn import GATConfig

CONFIG = GATConfig(name="gat-cora", d_in=1433, d_hidden=8, n_heads=8,
                   n_layers=2, n_classes=7)

SMOKE = GATConfig(name="gat-smoke", d_in=32, d_hidden=4, n_heads=2,
                  n_layers=2, n_classes=5)

_SEEDS = 1024
_L1 = _SEEDS * 15
_L2 = _L1 * 10

def _pad256(e: int) -> int:
    """Edge arrays pad to a 256 multiple so the edge ('dp') sharding always
    divides — otherwise GSPMD silently replicates the whole edge pipeline
    (observed on ogb_products: 61,859,140 % 16 != 0)."""
    return e + (-e) % 256


SHAPES = (
    ShapeCell("full_graph_sm", "train",
              {"n_nodes": 2708, "n_edges": 10556,
               "n_edges_padded": _pad256(10556), "d_feat": 1433,
               "n_classes": 7}),
    ShapeCell("minibatch_lg", "train",
              {"n_nodes": _SEEDS + _L1 + _L2, "n_edges": _L1 + _L2,
               "n_edges_padded": _pad256(_L1 + _L2),
               "d_feat": 602, "n_classes": 41, "sampled": 1}),
    ShapeCell("ogb_products", "train",
              {"n_nodes": 2449029, "n_edges": 61859140,
               "n_edges_padded": _pad256(61859140), "d_feat": 100,
               "n_classes": 47}),
    ShapeCell("molecule", "train",
              {"n_nodes": 30 * 128, "n_edges": 64 * 128,
               "n_edges_padded": 64 * 128, "d_feat": 32,
               "n_classes": 8, "batched": 128}),
)

SPEC = ArchSpec(arch_id="gat-cora", family="gnn", model="gat",
                config=CONFIG, smoke_config=SMOKE, shapes=SHAPES,
                source="arXiv:1710.10903; paper")


def adapt_config(cfg: GATConfig, cell: ShapeCell) -> GATConfig:
    """Feature width / class count follow the shape's dataset."""
    import dataclasses
    return dataclasses.replace(cfg, d_in=cell.dims["d_feat"],
                               n_classes=cell.dims["n_classes"])
