"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared (shared ffn 4x1408=5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from ..models.api import ArchSpec
from ..models.moe import MoEConfig
from ..models.transformer import LMConfig
from .base import lm_shapes

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=0, vocab_size=151936, head_dim=128,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff=1408, n_shared_experts=4,
                  shared_d_ff=1408, capacity_factor=1.25),
    dtype="bfloat16")

SMOKE = LMConfig(
    name="qwen2-moe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512, head_dim=32,
    moe=MoEConfig(n_experts=6, top_k=2, d_ff=64, n_shared_experts=1,
                  shared_d_ff=64), dtype="float32", remat="none")

SPEC = ArchSpec(arch_id="qwen2-moe-a2.7b", family="lm", model="lm",
                config=CONFIG, smoke_config=SMOKE, shapes=lm_shapes(swa=False),
                source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf")
