"""bst [recsys] — Behavior Sequence Transformer (Alibaba): embed_dim=32,
seq_len=20, 1 block, 8 heads, MLP 1024-512-256. [arXiv:1905.06874; paper]

Item table sized to a production catalog (4M items, Taobao-scale in the
paper's deployment)."""
from ..models.api import ArchSpec
from ..models.recsys import BSTConfig
from .base import recsys_shapes

CONFIG = BSTConfig(name="bst", n_items=4_000_000, n_profile_fields=8,
                   profile_vocab=100_000, embed_dim=32, seq_len=20,
                   n_blocks=1, n_heads=8, d_ff=128,
                   mlp_dims=(1024, 512, 256))

SMOKE = BSTConfig(name="bst-smoke", n_items=1000, n_profile_fields=4,
                  profile_vocab=200, embed_dim=16, seq_len=8, n_blocks=1,
                  n_heads=4, d_ff=32, mlp_dims=(64, 32))

SPEC = ArchSpec(arch_id="bst", family="recsys", model="bst",
                config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
                source="arXiv:1905.06874; paper")
