"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from ..models.api import ArchSpec
from ..models.transformer import LMConfig
from .base import lm_shapes

CONFIG = LMConfig(
    name="h2o-danube-1.8b", n_layers=24, d_model=2560, n_heads=32,
    n_kv_heads=8, d_ff=6912, vocab_size=32000, head_dim=80,
    window=4096, dtype="bfloat16")

SMOKE = LMConfig(
    name="h2o-danube-smoke", n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, window=16, dtype="float32",
    remat="none")

SPEC = ArchSpec(arch_id="h2o-danube-1.8b", family="lm", model="lm",
                config=CONFIG, smoke_config=SMOKE, shapes=lm_shapes(swa=True),
                source="arXiv:2401.16818; hf")
