"""xdeepfm [recsys] — n_sparse=39 embed_dim=10 CIN 200-200-200 MLP 400-400.
[arXiv:1803.05170; paper] — Criteo-style field vocabs (2e5 rows/field)."""
from ..models.api import ArchSpec
from ..models.recsys import XDeepFMConfig
from .base import recsys_shapes

CONFIG = XDeepFMConfig(name="xdeepfm", n_fields=39, field_vocab=200_000,
                       embed_dim=10, cin_layers=(200, 200, 200),
                       dnn_dims=(400, 400))

SMOKE = XDeepFMConfig(name="xdeepfm-smoke", n_fields=8, field_vocab=100,
                      embed_dim=6, cin_layers=(16, 16), dnn_dims=(32, 32))

SPEC = ArchSpec(arch_id="xdeepfm", family="recsys", model="xdeepfm",
                config=CONFIG, smoke_config=SMOKE, shapes=recsys_shapes(),
                source="arXiv:1803.05170; paper")
