"""Elastic scaling: reshard a checkpoint onto a different mesh.

Checkpoints store host (unsharded) arrays; loading for a new mesh is a
device_put with the target NamedShardings derived from the same logical
sharding rules — so a run checkpointed on a (16, 16) single pod restarts
unchanged on (2, 16, 16), (8, 8), or 1 device. The only requirement is
that sharded dims remain divisible by the new axis sizes (checked here,
with clear errors)."""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .sharding import params_shardings, use_mesh


def validate_divisibility(tree: Any, shardings: Any, mesh: Mesh) -> List[str]:
    problems = []

    def check(path, leaf, sh):
        if not isinstance(sh, NamedSharding):
            return
        for dim, axes in enumerate(sh.spec):
            if axes is None:
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
            if leaf.shape[dim] % size:
                problems.append(
                    f"{'/'.join(map(str, path))}: dim {dim} ({leaf.shape[dim]})"
                    f" not divisible by {size}")
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), tree, shardings)
    return problems


def reshard_for_mesh(host_tree: Any, mesh: Mesh,
                     rules: Sequence[Tuple[str, Tuple]]) -> Any:
    """Place a host pytree onto ``mesh`` under the logical rules."""
    shape_tree = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        host_tree)
    shardings = params_shardings(mesh, shape_tree, rules)
    problems = validate_divisibility(shape_tree, shardings, mesh)
    if problems:
        raise ValueError("cannot reshard: " + "; ".join(problems))
    with use_mesh(mesh):
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), host_tree, shardings)


# ---------------------------------------------------------------------------
# Live shard split/merge driver (elastic scaling of the SHARDED engine).
#
# The replicated fleet (distributed.fleet) scales availability; this scales
# capacity: ShardAutoscaler turns metrics pressure (region freelist, replay
# lag, routing drops) into split/merge decisions with hysteresis, and
# live_reshard performs the zero-downtime handoff — re-partition the state
# (core.sharded_engine.reshard_sharded_state), then replay the ticks that
# arrived during the repartition window from the shared firehose log, so
# the new shard layout is bit-exact with a run that resharded with the
# world stopped. The old state keeps serving until the new one is caught
# up; the swap is a pointer flip.
# ---------------------------------------------------------------------------
import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    min_shards: int = 1
    max_shards: int = 64
    split_free_frac: float = 0.10   # split when free-region fraction < this
    split_lag_ticks: float = 8.0    # ... or replay lag exceeds this
    merge_free_frac: float = 0.60   # merge when free fraction > this ...
    merge_lag_ticks: float = 1.0    # ... and lag is at most this
    hold_ticks: int = 3             # hysteresis: pressure must persist


class ShardAutoscaler:
    """Hysteresis-gated split/merge decisions off the serving metrics.

    Feed it one observation per tick; it returns the proposed shard count
    (== current when no action). A single spiky tick never reshards: the
    split signal must persist ``hold_ticks`` consecutive observations, and
    the merge signal likewise — mirroring the overload ladder's up-fast /
    down-slow asymmetry (splits use the same hold, merges also reset on
    any pressure)."""

    def __init__(self, cfg: AutoscaleConfig = AutoscaleConfig()):
        self.cfg = cfg
        self._hot = 0
        self._cold = 0

    def observe(self, n_shards: int, *, free_region_frac: Optional[float],
                lag_ticks: float = 0.0, route_drop_rate: float = 0.0) -> int:
        c = self.cfg
        pressured = ((free_region_frac is not None
                      and free_region_frac < c.split_free_frac)
                     or lag_ticks > c.split_lag_ticks
                     or route_drop_rate > 0.0)
        idle = ((free_region_frac is None
                 or free_region_frac > c.merge_free_frac)
                and lag_ticks <= c.merge_lag_ticks
                and route_drop_rate == 0.0)
        self._hot = self._hot + 1 if pressured else 0
        self._cold = self._cold + 1 if (idle and not pressured) else 0
        if self._hot >= c.hold_ticks and 2 * n_shards <= c.max_shards:
            self._hot = self._cold = 0
            return 2 * n_shards
        if self._cold >= c.hold_ticks and n_shards >= 2 \
                and n_shards // 2 >= c.min_shards:
            self._cold = 0
            return n_shards // 2
        return n_shards


def sharded_pressure(state, base_cfg) -> Dict[str, float]:
    """The autoscaler's inputs from a ShardedState: worst-shard region
    freelist fraction (region layout; None otherwise) and the routing drop
    count since the last reshard."""
    import numpy as np
    n = state.n_route_drop.shape[0]
    free_frac = None
    if base_cfg.region_cooc:
        owner = np.asarray(state.cooc.region_owner)
        per = owner.shape[0] // n
        frac = [(owner[i * per:(i + 1) * per] < 0).mean() for i in range(n)]
        free_frac = float(min(frac))
    return {"free_region_frac": free_frac,
            "route_drop": int(np.asarray(state.n_route_drop).sum())}


def live_reshard(cfg, state, new_n: int, mesh, *, log_dir: Optional[str] = None,
                 log_name: str = "firehose", chunk_ticks: int = 8,
                 axis: str = "shard"):
    """Split/merge a live sharded engine with zero-downtime handoff.

    Re-partitions ``state`` to ``new_n`` shards, then (when ``log_dir`` is
    given) catches the new state up through the shared firehose log's tail
    — the ticks that arrived while the repartition ran and the old state
    kept serving them. Returns ``(new_state, stats)``; the caller swaps
    serving over once ``stats["replayed_ticks"]`` has covered its head.
    ``mesh`` must span ``new_n`` devices along ``axis`` — the replay runs
    under the new layout's fused scan, whose per-tick state mutations are
    identical to the live sharded tick step (that is the bit-exactness
    property the handoff leans on).
    """
    from ..core.hashing import split_fp
    from ..core.sharded_engine import (make_sharded_ingest_many,
                                      reshard_sharded_state)
    assert mesh.shape[axis] == new_n, \
        f"mesh has {mesh.shape[axis]} shards along {axis!r}, want {new_n}"
    new_state, stats = reshard_sharded_state(cfg, state, new_n)
    stats["replayed_ticks"] = 0
    if log_dir is not None:
        from ..streaming.log import FirehoseLogReader
        reader = FirehoseLogReader(log_dir, name=log_name)
        head = reader.last_tick()
        t0 = int(jnp.asarray(new_state.tick))
        if head is not None and head + 1 > t0:
            ingest = make_sharded_ingest_many(cfg, mesh, axis)
            for chunk in reader.read_chunks(t0, chunk_ticks,
                                            upto_tick=head + 1):
                s_hi, s_lo = split_fp(chunk.sess_fp)
                q_hi, q_lo = split_fp(chunk.q_fp)
                new_state = ingest(
                    new_state, jnp.asarray(s_hi), jnp.asarray(s_lo),
                    jnp.asarray(q_hi), jnp.asarray(q_lo),
                    jnp.asarray(chunk.src, jnp.int32),
                    jnp.asarray(chunk.q_valid))
                stats["replayed_ticks"] += chunk.n_ticks
    return new_state, stats
