"""Elastic scaling: reshard a checkpoint onto a different mesh.

Checkpoints store host (unsharded) arrays; loading for a new mesh is a
device_put with the target NamedShardings derived from the same logical
sharding rules — so a run checkpointed on a (16, 16) single pod restarts
unchanged on (2, 16, 16), (8, 8), or 1 device. The only requirement is
that sharded dims remain divisible by the new axis sizes (checked here,
with clear errors)."""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .sharding import params_shardings, use_mesh


def validate_divisibility(tree: Any, shardings: Any, mesh: Mesh) -> List[str]:
    problems = []

    def check(path, leaf, sh):
        if not isinstance(sh, NamedSharding):
            return
        for dim, axes in enumerate(sh.spec):
            if axes is None:
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
            if leaf.shape[dim] % size:
                problems.append(
                    f"{'/'.join(map(str, path))}: dim {dim} ({leaf.shape[dim]})"
                    f" not divisible by {size}")
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), tree, shardings)
    return problems


def reshard_for_mesh(host_tree: Any, mesh: Mesh,
                     rules: Sequence[Tuple[str, Tuple]]) -> Any:
    """Place a host pytree onto ``mesh`` under the logical rules."""
    shape_tree = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        host_tree)
    shardings = params_shardings(mesh, shape_tree, rules)
    problems = validate_divisibility(shape_tree, shardings, mesh)
    if problems:
        raise ValueError("cannot reshard: " + "; ".join(problems))
    with use_mesh(mesh):
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), host_tree, shardings)
