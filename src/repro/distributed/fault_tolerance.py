"""Fault tolerance: checkpoint/restore, leader election, replica failover.

Mirrors the paper's §4.2 persistence design: "the [replicated] instances
perform leader election using ZooKeeper, and the winner proceeds to write
its results" every five minutes; frontends poll for updated results; on a
cold restart they serve the most-recently persisted state immediately.

Implementation: atomic-rename checkpoints (npz payload + json manifest),
keep-N retention, deterministic leader election over live replica ids (the
ZooKeeper-less equivalent: lowest live id wins — same liveness semantics,
suitable for the single-writer persistence pattern), and crash-recovery
restore that accepts any pytree template (elastic resharding lives in
``elastic.py``).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 tmp_ttl_s: float = 3600.0):
        self.dir = directory
        self.keep_n = keep_n
        # ``.tmp_*`` dirs older than this are debris from crashed writers
        # (a live writer holds its tmp dir only for the duration of one
        # save); retention removes them.
        self.tmp_ttl_s = tmp_ttl_s
        os.makedirs(directory, exist_ok=True)

    # -- paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: Optional[int] = None) -> Dict:
        """The manifest of a checkpoint (its ``meta`` carries the log
        offset for §4.2-style catch-up recovery)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            return json.load(f)

    # -- save/restore --
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
        """Atomic: write into a tmp dir, fsync, rename into place."""
        leaves, treedef = jax.tree.flatten(tree)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            arrays = {}
            dtypes = {}
            for i, x in enumerate(leaves):
                a = np.asarray(x)
                if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
                    # npz cannot store ml_dtypes (bf16 etc): raw-view them
                    dtypes[f"leaf_{i}"] = a.dtype.name
                    a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
                arrays[f"leaf_{i}"] = a
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "n_leaves": len(leaves),
                "raw_dtypes": dtypes,
                "time": time.time(),
                "meta": meta or {},
            }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return self._step_dir(step)

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the dtype/placement of ``template``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        import ml_dtypes  # noqa: F401  (dtype registry for raw views)
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            manifest = json.load(f)
            raw_dtypes = manifest.get("raw_dtypes", {})
        with np.load(os.path.join(self._step_dir(step), "arrays.npz")) as z:
            leaves, treedef = jax.tree.flatten(template)
            n_saved = manifest.get("n_leaves", len(leaves))
            if n_saved != len(leaves):
                raise ValueError(
                    f"checkpoint step {step} holds {n_saved} leaves but the "
                    f"restore template has {len(leaves)} — engine config / "
                    f"store layout mismatch (e.g. hash vs region cooc)?")
            new = []
            for i, leaf in enumerate(leaves):
                a = z[f"leaf_{i}"]
                if f"leaf_{i}" in raw_dtypes:
                    a = a.view(np.dtype(raw_dtypes[f"leaf_{i}"]))
                new.append(jax.numpy.asarray(
                    a, leaf.dtype if hasattr(leaf, "dtype") else None))
        return jax.tree.unflatten(treedef, new), step

    def restore_host(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(os.path.join(self._step_dir(step), "arrays.npz")) as z:
            return {k: z[k] for k in z.files}

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # stale ``.tmp_*`` dirs left by crashed writers: a successful save
        # renames its tmp dir away, a failed one rmtree's it — anything
        # still here past the TTL belongs to a dead process.
        now = time.time()
        for name in os.listdir(self.dir):
            if not name.startswith(".tmp"):
                continue
            path = os.path.join(self.dir, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue
            if age >= self.tmp_ttl_s:
                shutil.rmtree(path, ignore_errors=True)


# ---------------------------------------------------------------------------
# Leader election + replica group (paper §4.2 persistence pattern)
# ---------------------------------------------------------------------------

def elect_leader(live_replicas: Iterable[int]) -> Optional[int]:
    """Deterministic single-writer election: lowest live replica id."""
    live = sorted(live_replicas)
    return live[0] if live else None


class ReplicaGroup:
    """Replicated backend instances with single-writer persistence.

    Every replica holds the full engine state (the paper's replicated-not-
    sharded backend); each persistence cycle, the elected leader writes.
    ``fail``/``recover`` drive failure injection in tests; a recovered
    replica cold-starts from the latest checkpoint (paper: "upon a cold
    restart, the frontend caches can serve the most recently persisted
    results immediately").
    """

    def __init__(self, n_replicas: int, ckpt: CheckpointManager):
        self.alive = {i: True for i in range(n_replicas)}
        self.ckpt = ckpt

    def live(self) -> List[int]:
        return [i for i, ok in self.alive.items() if ok]

    def leader(self) -> Optional[int]:
        return elect_leader(self.live())

    def fail(self, rid: int) -> None:
        self.alive[rid] = False

    def recover(self, rid: int) -> Optional[int]:
        """Rejoin; returns the checkpoint step to cold-start from."""
        self.alive[rid] = True
        return self.ckpt.latest_step()

    def persist(self, rid: int, step: int, tree: Any,
                meta: Optional[Dict] = None) -> bool:
        """Only the leader's write goes through (single-writer)."""
        if rid != self.leader():
            return False
        self.ckpt.save(step, tree, meta)
        return True

    def log_append(self, rid: int, writer: Any, *args, **kwargs) -> bool:
        """Leader-elected single WRITER for the durable firehose log.

        Every replica consumes the hoses (paper §4.2: replicated, not
        sharded), but only the elected leader appends to the shared durable
        log — the same single-writer pattern as ``persist``. Non-leader
        appends are dropped (return False); on failover the new leader's
        appends continue the log seamlessly because ticks, not writers,
        define the offset space, and a (possibly long-standby) writer
        re-syncs its manifest view at every segment start.
        """
        if rid != self.leader():
            return False
        writer.append(*args, **kwargs)
        return True


# ---------------------------------------------------------------------------
# Straggler mitigation notes (mechanisms live where the work happens):
#  * fixed-size micro-batching (core/engine.py) — per-step work is constant,
#    the Zipf skew that stretched the paper's reduce tasks cannot stretch a
#    device step;
#  * hot-key salting (core/sharded_engine.py) — heavy hitters are split
#    across shards, bounding the max per-shard update volume;
#  * capacity-bounded routing/dispatch (sharded engine buckets, MoE
#    capacity) — a skewed key/expert cannot inflate a neighbor's step time,
#    overflow is dropped and counted instead of straggling.
# ---------------------------------------------------------------------------
