"""Fault tolerance: checkpoint/restore, leader election, replica failover.

Mirrors the paper's §4.2 persistence design: "the [replicated] instances
perform leader election using ZooKeeper, and the winner proceeds to write
its results" every five minutes; frontends poll for updated results; on a
cold restart they serve the most-recently persisted state immediately.

Implementation: atomic-rename checkpoints (npz payload + json manifest),
keep-N retention, deterministic leader election over live replica ids (the
ZooKeeper-less equivalent: lowest live id wins — same liveness semantics,
suitable for the single-writer persistence pattern), and crash-recovery
restore that accepts any pytree template (elastic resharding lives in
``elastic.py``).

**Incremental (delta) snapshots** — the snapshot-chain format. A snapshot
step is either a *full* checkpoint (every leaf written whole) or a *delta*
against the immediately preceding snapshot (changed leading slots only —
MillWheel-style low-watermark checkpointing over the stores' known-dirty
slots; see ``core.stores.diff_leading_rows``). One manifest per step dir:

    MANIFEST.json = {
      "step":      int,
      "kind":      "full" | "delta",
      "base_step": int | null,   # delta only: the previous snapshot in the
                                 # chain (full or delta) it was diffed against
      "n_leaves":  int,          # pytree width (layout-mismatch guard)
      "raw_dtypes": {...},       # npz-unstorable dtypes, raw-viewed
      "sha256":    hex,          # over the arrays.npz bytes (torn/corrupt
                                 # detection during the chain walk)
      "nbytes":    int,          # arrays.npz size (delta-vs-full accounting)
      "time":      float, "meta": {...},
    }

arrays.npz holds ``leaf_{i}`` whole for a full (and for 0-d leaves always);
a delta stores ``leaf_{i}_idx`` (changed leading indices, i64) +
``leaf_{i}_val`` (the rows at those indices) per array leaf. Both full and
delta payloads are wrapped in a ``streaming.codec`` compressed container
(manifest ``codec``/``raw_sha256``/``raw_nbytes``; ``sha256``/``nbytes``
stay over the on-disk bytes so torn-write detection and the
``corrupt_snapshot`` injector are codec-oblivious); pre-codec raw-npz
checkpoints restore transparently.

Restore **chain-walk**: resolve the requested step back through
``base_step`` links to its base full (verifying each member's sha256), then
apply the deltas oldest-first onto the full's arrays. **Fallback rule**: a
torn/corrupt/missing chain member falls back to the newest *intact full*
snapshot at ``step <= requested`` — the caller observes an older restored
step and simply replays a longer firehose-log tail (``streaming.replay``
handles this transparently); only when no full verifies does restore raise.
**Retention rule**: the newest ``keep_n`` steps are kept, *expanded* by
every chain base a kept delta references — a full is never unlinked while a
retained delta still needs it, and a delta is never retained without its
base chain.

``full_interval=1`` (the default) disables deltas entirely — every save is
a full checkpoint, byte-identical behavior to the pre-delta manager. With
``full_interval=F``, each full is followed by up to ``F-1`` deltas. The
delta diff runs against an in-memory shadow of the last-saved leaves, so a
freshly constructed manager (e.g. after a process restart) always writes a
full first.

The manager is layout-agnostic: sharded engines route their shard-stacked
leaves (``core.sharded_engine.save_sharded_snapshot``) through the same
delta chains with no special casing, and live-serving snapshots taken
under overload control carry the controller's shed/latency counters in
``meta["overload"]`` so a restart resumes with its accounting intact.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from ..core.stores import apply_row_delta, diff_leading_rows


def _codec():
    # Lazy: ``streaming.replay`` imports this module at its top level, so a
    # top-level import of ``streaming.codec`` here would make the package
    # import order circular. By first call, both packages are initialized.
    from ..streaming import codec as c
    return c


def _raw_view(a: np.ndarray) -> Tuple[np.ndarray, Optional[str]]:
    """npz cannot store ml_dtypes (bf16 etc): raw-view them, remember why."""
    if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
        name = a.dtype.name
        return a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8), name
    return a, None


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 tmp_ttl_s: float = 3600.0, full_interval: int = 1,
                 codec: str = "zlib"):
        assert full_interval >= 1
        self.dir = directory
        self.keep_n = keep_n
        # payload codec (``streaming.codec``): full AND delta arrays.npz
        # blobs are wrapped in a compressed container; the manifest's
        # ``sha256``/``nbytes`` describe the on-disk (compressed) bytes —
        # ``corrupt_snapshot`` and the chain walk's integrity pass operate
        # on file bytes exactly as before — while ``raw_sha256``/
        # ``raw_nbytes`` describe the npz body inside. ``codec="raw"``
        # restores the pre-codec byte-identical format; either decodes.
        self.codec = codec
        # ``.tmp_*`` dirs older than this are debris from crashed writers
        # (a live writer holds its tmp dir only for the duration of one
        # save); retention removes them.
        self.tmp_ttl_s = tmp_ttl_s
        # delta-snapshot chain: every ``full_interval``-th save is a full,
        # the rest are deltas against the previous save (1 = fulls only).
        self.full_interval = full_interval
        self._shadow: Optional[List[np.ndarray]] = None  # last-saved leaves
        self._shadow_step: Optional[int] = None
        self._since_full = 0
        self.last_save_kind: Optional[str] = None
        self.last_save_bytes = 0
        # last restore's provenance: {requested, restored, chain_len,
        # fell_back} — ``fell_back`` means a torn/corrupt chain member was
        # skipped and an older intact full was used instead.
        self.last_restore: Dict[str, Any] = {}
        os.makedirs(directory, exist_ok=True)

    # -- paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:012d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "MANIFEST.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: Optional[int] = None) -> Dict:
        """The manifest of a checkpoint (its ``meta`` carries the log
        offset for §4.2-style catch-up recovery)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            return json.load(f)

    # -- save/restore --
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
        """Atomic: write into a tmp dir, fsync, rename into place.

        With ``full_interval > 1`` the manager writes *delta* snapshots
        (changed leading slots only, diffed against the in-memory shadow of
        the previous save) between fulls — see the module docstring for the
        chain format. The decision is internal: callers keep calling
        ``save`` and the manifest records what was written.
        """
        leaves, treedef = jax.tree.flatten(tree)
        np_leaves = [np.asarray(x) for x in leaves]
        kind, base_step = "full", None
        if (self.full_interval > 1 and self._shadow is not None
                and self._shadow_step is not None
                and step > self._shadow_step
                and self._since_full < self.full_interval - 1
                and len(np_leaves) == len(self._shadow)
                and all(a.shape == b.shape and a.dtype == b.dtype
                        for a, b in zip(np_leaves, self._shadow))):
            kind, base_step = "delta", self._shadow_step
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            arrays: Dict[str, np.ndarray] = {}
            dtypes: Dict[str, str] = {}
            for i, a in enumerate(np_leaves):
                if kind == "delta" and a.ndim >= 1:
                    idx = diff_leading_rows(self._shadow[i], a)
                    val, raw = _raw_view(a[idx])
                    if raw is not None:
                        dtypes[f"leaf_{i}"] = raw
                    arrays[f"leaf_{i}_idx"] = idx
                    arrays[f"leaf_{i}_val"] = val
                else:   # full leaf; 0-d leaves are always written whole
                    whole, raw = _raw_view(a)
                    if raw is not None:
                        dtypes[f"leaf_{i}"] = raw
                    arrays[f"leaf_{i}"] = whole
            blob, cinfo = _codec().encode_payload(arrays, codec=self.codec,
                                                  fp_lanes=())
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            manifest = {
                "step": step,
                "kind": kind,
                "base_step": base_step,
                "n_leaves": len(leaves),
                "raw_dtypes": dtypes,
                "sha256": hashlib.sha256(blob).hexdigest(),
                "nbytes": len(blob),
                "codec": cinfo["codec"],
                "raw_sha256": cinfo.get("raw_sha256"),
                "raw_nbytes": cinfo.get("raw_nbytes"),
                "time": time.time(),
                "meta": meta or {},
            }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        # the shadow must hold the as-saved CONTENT: np.asarray of a numpy
        # leaf aliases the caller's live buffer (an in-place mutation
        # before the next save would diff the array against itself and
        # silently record an empty delta) — copy those; jax buffers are
        # immutable and safe to hold by reference.
        self._shadow = [a if isinstance(x, jax.Array) else np.array(a)
                        for x, a in zip(leaves, np_leaves)]
        self._shadow_step = step
        self._since_full = 0 if kind == "full" else self._since_full + 1
        self.last_save_kind, self.last_save_bytes = kind, len(blob)
        self._gc()
        return self._step_dir(step)

    # -- chain-walk loading --
    def _verified_arrays(self, step: int, manifest: Dict
                         ) -> Optional[Dict[str, np.ndarray]]:
        """Load + sha256-verify one step's arrays.npz; None when torn."""
        path = os.path.join(self._step_dir(step), "arrays.npz")
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except OSError:
            return None
        want = manifest.get("sha256")
        if want is not None and hashlib.sha256(blob).hexdigest() != want:
            return None
        try:
            # decodes compressed containers and legacy raw npz alike; a
            # CodecError (torn container / failed raw_sha256) means torn
            payload, _info = _codec().decode_payload(blob)
            return payload
        except Exception:   # noqa: BLE001 — short/garbled blob
            return None

    def _collect_chain(self, step: int) -> Optional[List[Tuple[int, Dict,
                                                               Dict]]]:
        """Walk ``step`` back to its base full, verifying every member.
        Returns [(step, manifest, arrays), ...] full-first, or None the
        moment any link is missing/torn/corrupt (caller falls back)."""
        chain: List[Tuple[int, Dict, Dict]] = []
        s: Optional[int] = step
        seen = set()
        while True:
            if s is None or s in seen:
                return None        # dangling or cyclic base pointer
            seen.add(s)
            try:
                man = self.manifest(s)
            except (OSError, json.JSONDecodeError):
                return None
            arrs = self._verified_arrays(s, man)
            if arrs is None:
                return None
            chain.append((s, man, arrs))
            if man.get("kind", "full") == "full":
                chain.reverse()
                return chain
            s = man.get("base_step")

    def load_arrays(self, step: Optional[int] = None
                    ) -> Tuple[Dict[str, np.ndarray], Dict, int]:
        """Chain-walk load with torn/corrupt-delta fallback.

        Returns ``(arrays, manifest, restored_step)`` where ``arrays`` is
        the composed ``leaf_{i}`` dict (full + deltas applied oldest-first)
        and ``manifest`` belongs to ``restored_step``. When the requested
        step's chain is broken, falls back to the newest *intact full* at
        ``step <= requested`` (recorded in ``self.last_restore``); raises
        ``FileNotFoundError`` only when nothing verifies.
        """
        requested = step if step is not None else self.latest_step()
        if requested is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        self.last_restore = {"requested": requested, "restored": None,
                             "chain_len": 0, "fell_back": False}
        chain = self._collect_chain(requested)
        if chain is None:
            # fallback: newest verifiable full at or before the request.
            self.last_restore["fell_back"] = True
            for s in reversed([x for x in self.steps() if x <= requested]):
                try:
                    man = self.manifest(s)
                except (OSError, json.JSONDecodeError):
                    continue
                if man.get("kind", "full") != "full":
                    continue
                arrs = self._verified_arrays(s, man)
                if arrs is not None:
                    chain = [(s, man, arrs)]
                    break
            if chain is None:
                raise FileNotFoundError(
                    f"snapshot chain for step {requested} is torn and no "
                    f"intact full snapshot <= {requested} exists in "
                    f"{self.dir}")
        base_step, base_man, arrays = chain[0]
        n_leaves = base_man.get("n_leaves", 0)
        for s, man, delta in chain[1:]:
            for i in range(n_leaves):
                if f"leaf_{i}" in delta:      # 0-d / whole-leaf record
                    arrays[f"leaf_{i}"] = delta[f"leaf_{i}"]
                else:
                    arrays[f"leaf_{i}"] = apply_row_delta(
                        arrays[f"leaf_{i}"], delta[f"leaf_{i}_idx"],
                        delta[f"leaf_{i}_val"])
        top_step, top_man, _ = chain[-1]
        self.last_restore.update({"restored": top_step,
                                  "chain_len": len(chain)})
        return arrays, top_man, top_step

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the dtype/placement of ``template``.

        Walks the delta chain (see ``load_arrays``); the returned step is
        the *actually restored* one — older than requested when a torn or
        corrupt chain member forced the fallback to the newest intact full
        (the caller then replays a longer log tail).
        """
        import ml_dtypes  # noqa: F401  (dtype registry for raw views)
        arrays, manifest, step = self.load_arrays(step)
        raw_dtypes = manifest.get("raw_dtypes", {})
        leaves, treedef = jax.tree.flatten(template)
        n_saved = manifest.get("n_leaves", len(leaves))
        if n_saved != len(leaves):
            raise ValueError(
                f"checkpoint step {step} holds {n_saved} leaves but the "
                f"restore template has {len(leaves)} — engine config / "
                f"store layout mismatch (e.g. hash vs region cooc)?")
        new = []
        for i, leaf in enumerate(leaves):
            a = arrays[f"leaf_{i}"]
            if f"leaf_{i}" in raw_dtypes:
                a = a.view(np.dtype(raw_dtypes[f"leaf_{i}"]))
            new.append(jax.numpy.asarray(
                a, leaf.dtype if hasattr(leaf, "dtype") else None))
        return jax.tree.unflatten(treedef, new), step

    def restore_host(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        arrays, _, _ = self.load_arrays(step)
        return arrays

    def _gc(self) -> None:
        steps = self.steps()
        keep = set(steps) if self.keep_n <= 0 else set(steps[-self.keep_n:])
        # chain protection: a kept delta pins its whole base chain — a full
        # is never unlinked while a retained delta still references it.
        for s in list(keep):
            cur = s
            for _ in range(len(steps) + 1):
                try:
                    man = self.manifest(cur)
                except (OSError, json.JSONDecodeError):
                    break
                if man.get("kind", "full") == "full":
                    break
                base = man.get("base_step")
                if base is None or base == cur:
                    break
                keep.add(base)
                cur = base
        for s in steps:
            if s not in keep:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # stale ``.tmp_*`` dirs left by crashed writers: a successful save
        # renames its tmp dir away, a failed one rmtree's it — anything
        # still here past the TTL belongs to a dead process.
        now = time.time()
        for name in os.listdir(self.dir):
            if not name.startswith(".tmp"):
                continue
            path = os.path.join(self.dir, name)
            try:
                age = now - os.path.getmtime(path)
            except OSError:
                continue
            if age >= self.tmp_ttl_s:
                shutil.rmtree(path, ignore_errors=True)


def corrupt_snapshot(ckpt: CheckpointManager, step: int,
                     keep_fraction: float = 0.5) -> None:
    """Failure injection: truncate a snapshot's ``arrays.npz`` in place (a
    torn write on a non-atomic filesystem). The chain walk's sha256 pass
    must reject it and fall back to the newest intact full snapshot."""
    path = os.path.join(ckpt._step_dir(step), "arrays.npz")
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        f.write(blob[: max(1, int(len(blob) * keep_fraction))])


# ---------------------------------------------------------------------------
# Leader election + replica group (paper §4.2 persistence pattern)
# ---------------------------------------------------------------------------

def elect_leader(live_replicas: Iterable[int]) -> Optional[int]:
    """Deterministic single-writer election: lowest live replica id."""
    live = sorted(live_replicas)
    return live[0] if live else None


class ReplicaGroup:
    """Replicated backend instances with single-writer persistence.

    Every replica holds the full engine state (the paper's replicated-not-
    sharded backend); each persistence cycle, the elected leader writes.
    ``fail``/``recover`` drive failure injection in tests; a recovered
    replica cold-starts from the latest checkpoint (paper: "upon a cold
    restart, the frontend caches can serve the most recently persisted
    results immediately").
    """

    def __init__(self, n_replicas: int, ckpt: CheckpointManager):
        self.alive = {i: True for i in range(n_replicas)}
        self.ckpt = ckpt
        # leadership epoch: bumped on EVERY leadership change (fail of the
        # leader, or a lower-id replica rejoining and re-winning the
        # deterministic election). The fencing token for the shared log:
        # the winner stamps it into the log manifest
        # (``FirehoseLogWriter.assume_epoch``) before its first append, so
        # a zombie ex-leader's stray appends are rejected.
        self.epoch = 0
        self._last_leader = self.leader()

    def live(self) -> List[int]:
        return [i for i, ok in self.alive.items() if ok]

    def leader(self) -> Optional[int]:
        return elect_leader(self.live())

    def _note_leadership(self) -> Optional[int]:
        lead = self.leader()
        if lead != self._last_leader:
            self.epoch += 1
            self._last_leader = lead
        return lead

    def fail(self, rid: int) -> None:
        self.alive[rid] = False
        self._note_leadership()

    def recover(self, rid: int) -> Optional[int]:
        """Rejoin; returns the checkpoint step to cold-start from.

        Rejoining may retake leadership (lowest live id wins) — that too is
        a leadership change and bumps the epoch, so the previous leader's
        writer is fenced the moment the rejoiner stamps the manifest."""
        self.alive[rid] = True
        self._note_leadership()
        return self.ckpt.latest_step()

    def persist(self, rid: int, step: int, tree: Any,
                meta: Optional[Dict] = None) -> bool:
        """Only the leader's write goes through (single-writer)."""
        if rid != self.leader():
            return False
        self.ckpt.save(step, tree, meta)
        return True

    def log_append(self, rid: int, writer: Any, *args, **kwargs) -> bool:
        """Leader-elected single WRITER for the durable firehose log.

        Every replica consumes the hoses (paper §4.2: replicated, not
        sharded), but only the elected leader appends to the shared durable
        log — the same single-writer pattern as ``persist``. Non-leader
        appends are dropped (return False); on failover the new leader's
        appends continue the log seamlessly because ticks, not writers,
        define the offset space, and a (possibly long-standby) writer
        re-syncs its manifest view at every segment start.

        Election alone cannot stop a partitioned/paused ex-leader that
        still believes it leads — that is what the epoch fence is for: the
        new leader calls ``writer.assume_epoch(group.epoch)`` before its
        first append, and the zombie's next append/flush raises
        ``streaming.log.WriterFencedError`` (see ``distributed.fleet`` for
        the full failover choreography).
        """
        if rid != self.leader():
            return False
        writer.append(*args, **kwargs)
        return True


# ---------------------------------------------------------------------------
# Straggler mitigation notes (mechanisms live where the work happens):
#  * fixed-size micro-batching (core/engine.py) — per-step work is constant,
#    the Zipf skew that stretched the paper's reduce tasks cannot stretch a
#    device step;
#  * hot-key salting (core/sharded_engine.py) — heavy hitters are split
#    across shards, bounding the max per-shard update volume;
#  * capacity-bounded routing/dispatch (sharded engine buckets, MoE
#    capacity) — a skewed key/expert cannot inflate a neighbor's step time,
#    overflow is dropped and counted instead of straggling.
# ---------------------------------------------------------------------------
