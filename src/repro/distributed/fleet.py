"""Self-healing replicated serving fleet (paper §4.2, operationalized).

The paper's production story is a *fleet*: backend instances are
"replicated for fault tolerance, but not sharded", leader election picks
the single writer, frontends keep serving "the most recently persisted
results" while a restarted instance rewinds into the hose and catches up
faster than real time. PR 3–6 built each ingredient (durable log, bit-exact
``recover_service``, delta snapshots, ``ReplicaGroup`` election, overload
ladder); this module is the robustness control plane that stitches them
into a fleet that keeps answering through node deaths:

  * **heartbeat failure detection** — a replica heartbeats by processing
    ticks; one that has not stepped for ``heartbeat_timeout`` ticks is
    declared dead (``ReplicaGroup.fail``). Detection is tick-clocked, so
    the whole fleet is deterministic under test.
  * **epoch-fenced leader failover** — the leader is the single durable-log
    writer. Every leadership change bumps ``ReplicaGroup.epoch``; the new
    leader stamps that epoch into the log manifest
    (``FirehoseLogWriter.assume_epoch``) *before* its first append, so a
    paused/partitioned ex-leader that wakes up and tries to append is
    rejected with ``WriterFencedError`` — its stray segment never lands.
  * **log heal on failover** — ticks the dead leader had buffered (or that
    arrived while its death went undetected) never reached the manifest.
    Every replica keeps a short in-memory ring of recent raw ticks; the
    new leader re-appends the missing range from its ring, so the durable
    log stays gap-free and recovery stays bit-exact. Only if the outage
    outlives the ring does the fleet lose ticks (the paper's stance:
    losing a little state is tolerable — and here it is *counted*).
  * **self-healing** — a dead replica restarts after ``restart_after``
    ticks via ``streaming.replay.recover_service`` (snapshot restore +
    faster-than-real-time log-tail replay), then catches up incrementally
    (``catchup_budget_ticks`` per fleet tick) and is readmitted to query
    routing only once its lag is <= ``readmit_lag`` ticks.
  * **hedged query routing** — ``serverset()`` wraps the replicas in
    ``serving.serve.ServerSet``: freshest-first ordering, retry/backoff,
    hedged second requests and per-replica circuit breakers. A crashed-
    but-undetected replica surfaces as a connection error that the hedge
    absorbs: client requests keep succeeding through kills and failovers.

Elastic *sharded* scaling (live shard split/merge) is the sibling control
plane in ``distributed.elastic`` — this module scales out replicas of the
whole state, that one re-partitions one state across shards.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Any, Dict, List, Optional, Tuple

from ..core.background import AssistanceService, background_config
from ..core.engine import EngineConfig
from ..core.hashing import fingerprint
from ..streaming.compaction import CompactionConfig, LogCompactor
from ..streaming.log import (FirehoseLogReader, FirehoseLogWriter,
                             WriterFencedError, kill_writer_mid_segment)
from ..streaming.replay import (CatchUpController, ReplayConfig,
                                recover_service)
from .fault_tolerance import CheckpointManager, ReplicaGroup


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_replicas: int = 3
    heartbeat_timeout: int = 2   # missed ticks before a replica is declared dead
    restart_after: int = 1       # ticks dead before the self-heal restart kicks in
    readmit_lag: int = 0         # max lag_ticks to rejoin query routing
    catchup_budget_ticks: Optional[int] = None  # replay ticks per fleet tick
                                                # while recovering (None = all)
    snapshot_every: int = 8      # leader persists both engines at this cadence
    ticks_per_segment: int = 4
    keep_segments: int = 0       # 0 = retain the whole log (bit-exact restarts)
    full_interval: int = 1       # delta-snapshot chain interval
    recent_ticks: int = 32       # log-heal ring length (raw ticks per replica)
    chunk_ticks: int = 8         # fused replay chunk size
    rank_lag_ticks: int = 4
    alpha: float = 0.7
    log_name: str = "firehose"
    compact_every: int = 0       # fold the log into a base snapshot at this
                                 # cadence (0 = no compaction); leader-only,
                                 # epoch-fenced like the writer
    keep_bases: int = 2          # compaction fallback depth (old bases +
                                 # their log tail retained after each swap)


class _Replica:
    __slots__ = ("rid", "service", "writer", "status", "last_heartbeat",
                 "down_since", "recent", "n_restarts", "last_recovery")

    def __init__(self, rid: int, service: AssistanceService, recent_ticks: int):
        self.rid = rid
        self.service: Optional[AssistanceService] = service
        self.writer: Optional[FirehoseLogWriter] = None
        self.status = "live"            # live | dead | recovering
        self.last_heartbeat = -1
        self.down_since: Optional[int] = None
        self.recent: collections.deque = collections.deque(
            maxlen=recent_ticks)    # (tick, events, tweets) log-heal ring
        self.n_restarts = 0
        self.last_recovery: Optional[Dict] = None   # recover_service stats


class ReplicaHandle:
    """The frontend-facing view of one fleet replica, duck-typed for
    ``ServerSet`` (``alive`` / ``related`` / ``freshness_tick``).

    ``alive`` reflects the *detected* membership view (a dead or still-
    catching-up replica is skipped outright); a crashed replica whose
    death has not been detected yet still looks alive — exactly like a
    real serverset — and its ``related`` raises ``ConnectionError``, which
    the router's hedge absorbs. Queries may be query strings or raw
    query fingerprints; suggestions come back as (dst_fp, score) pairs.
    """

    def __init__(self, fleet: "ServingFleet", rid: int):
        self._fleet = fleet
        self.rid = rid

    @property
    def alive(self) -> bool:
        return self._fleet._replicas[self.rid].status == "live"

    def freshness_tick(self) -> Optional[int]:
        rep = self._fleet._replicas[self.rid]
        if rep.service is None:
            return None
        return int(rep.service.rt.state.tick)

    def related(self, query, k: int = 8) -> List[Tuple[int, float]]:
        rep = self._fleet._replicas[self.rid]
        if rep.service is None:
            raise ConnectionError(f"replica {self.rid} is down")
        fp = (fingerprint(" ".join(query.lower().split()))
              if isinstance(query, str) else int(query))
        return rep.service.suggest_fp(fp, k)


class ServingFleet:
    """N replicated serving stacks + one durable log + shared snapshots.

    Drive it with ``offer_tick(t, events, tweets)`` once per micro-batch
    tick; inject failures with ``kill``; route queries through
    ``serverset()``. All liveness decisions are tick-clocked (no wall
    time), so a chaos run is exactly reproducible — and the surviving /
    recovered replicas' engine states are bit-exact against an
    uninterrupted single-service run over the same stream.
    """

    def __init__(self, root_dir: str, rt_cfg: EngineConfig,
                 cfg: FleetConfig = FleetConfig(), *,
                 bg_cfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.rt_cfg = rt_cfg
        self.bg_cfg = bg_cfg if bg_cfg is not None \
            else background_config(rt_cfg)
        self.log_dir = os.path.join(root_dir, "log")
        self.rt_ckpt = CheckpointManager(os.path.join(root_dir, "rt"),
                                         full_interval=cfg.full_interval)
        self.bg_ckpt = CheckpointManager(os.path.join(root_dir, "bg"),
                                         full_interval=cfg.full_interval)
        self.group = ReplicaGroup(cfg.n_replicas, self.rt_ckpt)
        self.rcfg = ReplayConfig(chunk_ticks=cfg.chunk_ticks,
                                 rank_lag_ticks=cfg.rank_lag_ticks)
        self._replicas = [
            _Replica(i, AssistanceService(rt_cfg, alpha=cfg.alpha,
                                          bg_cfg=self.bg_cfg),
                     cfg.recent_ticks)
            for i in range(cfg.n_replicas)]
        self.handles = [ReplicaHandle(self, i) for i in range(cfg.n_replicas)]
        self._reader = FirehoseLogReader(self.log_dir, name=cfg.log_name)
        # compaction (leader-only; the compactor re-adopts the group epoch
        # before every cycle so a deposed leader's fold can never swap the
        # manifest — see streaming.compaction)
        self.compactor: Optional[LogCompactor] = None
        if cfg.compact_every > 0:
            self.compactor = LogCompactor(
                self.log_dir, {"rt": rt_cfg, "bg": self.bg_cfg},
                name=cfg.log_name,
                cfg=CompactionConfig(keep_bases=cfg.keep_bases,
                                     chunk_ticks=cfg.chunk_ticks))
        self.n_compactions = 0
        self.last_compaction: Optional[Dict] = None
        # counters (the chaos bench reads these)
        self.n_failovers = 0
        self.n_deaths_detected = 0
        self.n_recoveries = 0
        self.n_healed_ticks = 0
        self.n_lost_ticks = 0
        self.n_unlogged_pending = 0   # ticks awaiting log heal right now
        self._ensure_leader()

    # ---- membership / leadership ----
    def leader(self) -> Optional[int]:
        return self.group.leader()

    def _new_writer(self) -> FirehoseLogWriter:
        return FirehoseLogWriter(self.log_dir,
                                 ticks_per_segment=self.cfg.ticks_per_segment,
                                 keep_segments=self.cfg.keep_segments,
                                 name=self.cfg.log_name)

    def _ensure_leader(self) -> Optional[_Replica]:
        """Make sure the elected leader owns a writer stamped at the
        current epoch; heal the log from its recent-tick ring on takeover."""
        lead = self.group.leader()
        if lead is None:
            return None
        rep = self._replicas[lead]
        if rep.writer is None:
            rep.writer = self._new_writer()
        if rep.writer.epoch != self.group.epoch:
            rep.writer.assume_epoch(self.group.epoch)   # the fence lands here
            self.n_failovers += 1
            self._heal_log(rep)
        return rep

    def _heal_log(self, rep: _Replica) -> None:
        """Re-append ticks the old leader never sealed, from the new
        leader's in-memory ring — the durable log stays gap-free so
        recovery stays bit-exact. Ticks older than the ring are lost
        (counted, paper §4.2 stance)."""
        w = rep.writer
        last = w.last_tick
        start = 0 if last is None else last + 1
        ring = {t: (ev, tw) for t, ev, tw in rep.recent}
        if ring:
            missing = [t for t in range(start, max(ring) + 1)]
            for t in missing:
                if t in ring:
                    ev, tw = ring[t]
                    w.append(t, ev, tw)
                    self.n_healed_ticks += 1
                else:
                    self.n_lost_ticks += 1

    def detect(self, t: int) -> List[int]:
        """Tick-clocked failure detection: declare replicas dead after
        ``heartbeat_timeout`` missed ticks; fail over leadership (epoch
        bump + fence + log heal) when the dead one led."""
        died = []
        for rep in self._replicas:
            if rep.status == "live" and rep.service is None \
                    and t - rep.last_heartbeat > self.cfg.heartbeat_timeout:
                rep.status = "dead"
                rep.down_since = t
                self.group.fail(rep.rid)
                self.n_deaths_detected += 1
                died.append(rep.rid)
        if died:
            self._ensure_leader()
        return died

    # ---- failure injection ----
    def kill(self, rid: int, mid_segment: bool = False) -> Optional[str]:
        """Crash a replica: its memory-resident engines are gone, its
        heartbeats stop (death is *detected* later, by timeout). With
        ``mid_segment`` (leader only) the writer dies mid-segment write,
        leaving a torn unmanifested file — ``kill_writer_mid_segment``.
        Returns the torn file name, if any."""
        rep = self._replicas[rid]
        torn = None
        if mid_segment and rep.writer is not None:
            torn = kill_writer_mid_segment(rep.writer)
        rep.service = None
        rep.recent.clear()
        rep.writer = None if not mid_segment else rep.writer
        if rep.status == "recovering":
            # crashed again mid catch-up: already out of membership, so no
            # detection round-trip — straight back to dead, restart later
            rep.status = "dead"
        return torn

    # ---- the tick loop ----
    def offer_tick(self, t: int, events=None, tweets=None) -> Dict:
        """One fleet tick: detect failures, append to the fenced log,
        step every live replica, heal the dead ones, persist on cadence."""
        info: Dict[str, Any] = {"tick": t, "died": [], "recovered": [],
                                "appended": False}
        info["died"] = self.detect(t)

        # durable append first (leader-elected single writer, fenced) —
        # durability precedes state mutation, same ordering as the
        # overload controller's admitted-stream logging.
        lead = self.group.leader()
        if lead is not None:
            rep = self._ensure_leader()
            try:
                info["appended"] = self.group.log_append(
                    lead, rep.writer, t, events, tweets)
            except WriterFencedError:
                raise   # a fenced fleet-driven append is a logic error
            except RuntimeError:
                # crashed-but-undetected leader: its writer is dead. The
                # tick reaches every live replica's heal ring and the log
                # is healed at failover.
                info["appended"] = False
        if not info["appended"]:
            self.n_unlogged_pending += 1
        else:
            self.n_unlogged_pending = 0

        # every live replica consumes the hose (replicated, not sharded)
        for rep in self._replicas:
            if rep.status == "live" and rep.service is not None:
                assert int(rep.service.rt.state.tick) == t, \
                    f"replica {rep.rid} out of phase"
                rep.service.step(events, tweets)
                rep.recent.append((t, events, tweets))
                rep.last_heartbeat = t

        # self-healing: restart the dead, top up the recovering, readmit
        info["recovered"] = self._heal_replicas(t)

        # leader persists both engines on cadence (single-writer persist)
        if info["appended"] and self.cfg.snapshot_every > 0 \
                and (t + 1) % self.cfg.snapshot_every == 0:
            leader_rep = self._replicas[self.group.leader()]
            if leader_rep.service is not None:
                leader_rep.service.save_snapshot(self.rt_ckpt, self.bg_ckpt)

        # leader folds the sealed log into a base on cadence: retention
        # becomes [base, head] while replay-from-zero stays possible. Only
        # an *appending* leader compacts (same single-writer discipline),
        # and the compactor re-adopts the current epoch so its manifest
        # swap is fenced against any failover since the fold started.
        if self.compactor is not None and info["appended"] \
                and self.cfg.compact_every > 0 \
                and (t + 1) % self.cfg.compact_every == 0:
            self.compactor.assume_epoch(self.group.epoch)
            stats = self.compactor.compact()
            self.last_compaction = stats
            if not stats.get("noop"):
                self.n_compactions += 1
                info["compacted"] = stats["floor"]
        return info

    def _catchup_target(self, cur: int, head: Optional[int]) -> Optional[int]:
        if head is None:
            return cur
        budget = self.cfg.catchup_budget_ticks
        return head + 1 if budget is None else min(head + 1, cur + budget)

    def _heal_replicas(self, t: int) -> List[int]:
        readmitted = []
        for rep in self._replicas:
            if rep.status == "dead" and rep.down_since is not None \
                    and t - rep.down_since >= self.cfg.restart_after:
                self._restart(rep)
            elif rep.status == "recovering":
                self._continue_catchup(rep, t)
            if rep.status == "recovering" and self._lag(rep, t) \
                    <= self.cfg.readmit_lag:
                # lag cleared: rejoin membership AND query routing
                rep.status = "live"
                rep.last_heartbeat = t
                rep.down_since = None
                self.group.recover(rep.rid)
                self._ensure_leader()   # may retake leadership (epoch bump)
                rep.service.refresh_cache()
                self.n_recoveries += 1
                readmitted.append(rep.rid)
        return readmitted

    def _restart(self, rep: _Replica) -> None:
        """Cold restart via the PR 5 whole-stack recovery path: snapshot
        restore + fused log-tail replay, ranking suppressed until the lag
        clears. The replica is NOT yet routed to (status ``recovering``)."""
        service, stats = recover_service(
            self.rt_cfg, self.rt_ckpt, self.bg_ckpt, self.log_dir,
            self.rcfg, bg_cfg=self.bg_cfg, alpha=self.cfg.alpha,
            log_name=self.cfg.log_name)
        rep.service = service
        rep.status = "recovering"
        rep.n_restarts += 1
        rep.last_recovery = stats

    def _continue_catchup(self, rep: _Replica, t: int) -> None:
        self._reader.refresh()
        head = self._reader.last_tick()
        for eng in (rep.service.rt, rep.service.bg):
            cur = int(eng.state.tick)
            target = self._catchup_target(cur, head)
            if target > cur:
                CatchUpController(eng, self._reader, self.rcfg).catch_up(
                    target, refresh=False)
        # no heal-ring refill here: a recovering replica only learns ticks
        # FROM the log, so its ring could never heal anything the log lacks.
        # It re-arms the ring with live ticks once readmitted.

    def _lag(self, rep: _Replica, t: int) -> int:
        if rep.service is None:
            return t + 1
        return (t + 1) - int(rep.service.rt.state.tick)

    # ---- client side ----
    def serverset(self, **kw):
        """A hedged, circuit-broken ``ServerSet`` over the fleet replicas."""
        from ..serving.serve import ServerSet
        return ServerSet(self.handles, **kw)

    # ---- observability ----
    def metrics(self) -> Dict:
        self._reader.refresh()
        head = self._reader.last_tick()
        reps = {}
        for rep in self._replicas:
            reps[rep.rid] = {
                "status": rep.status,
                "last_heartbeat": rep.last_heartbeat,
                "tick": (None if rep.service is None
                         else int(rep.service.rt.state.tick)),
                "n_restarts": rep.n_restarts,
            }
        return {
            "leader": self.group.leader(),
            "epoch": self.group.epoch,
            "log_head_tick": head,
            "log_floor_tick": self._reader.floor_tick(),
            "n_log_bases": len(self._reader.bases),
            "n_compactions": self.n_compactions,
            "n_failovers": self.n_failovers,
            "n_deaths_detected": self.n_deaths_detected,
            "n_recoveries": self.n_recoveries,
            "n_healed_ticks": self.n_healed_ticks,
            "n_lost_ticks": self.n_lost_ticks,
            "replicas": reps,
        }

    def states(self) -> Dict[int, Tuple[Any, Any]]:
        """Per-replica (rt, bg) engine states (bit-exactness assertions)."""
        return {rep.rid: (rep.service.rt.state, rep.service.bg.state)
                for rep in self._replicas if rep.service is not None}
