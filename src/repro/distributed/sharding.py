"""Sharding rules + mesh-context plumbing.

Models are written against LOGICAL axis names:

  * ``dp``    — pure data parallelism (maps to ('pod', 'data') or ('data',))
  * ``tp``    — tensor/model parallelism (maps to ('model',))

``constrain(x, *logical_axes)`` applies a with_sharding_constraint only when
a mesh context is active (set by the launcher / dryrun via ``use_mesh``), so
the same model code runs unsharded on a laptop and sharded on a pod.

A per-model "sharding rules" table maps parameter-tree path patterns to
PartitionSpecs; ``params_shardings`` walks a params pytree and produces the
NamedSharding tree for jit in_shardings.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def logical_axes() -> Dict[str, Tuple[str, ...]]:
    """Logical -> physical axis mapping for the current mesh."""
    mesh = current_mesh()
    if mesh is None:
        return {}
    names = mesh.axis_names
    if "pod" in names:
        return {"dp": ("pod", "data"), "tp": ("model",),
                "all": ("pod", "data", "model")}
    if "data" in names:
        return {"dp": ("data",), "tp": ("model",),
                "all": ("data", "model")}
    # single-axis meshes (e.g. the sharded engine's ("shard",))
    return {"dp": (names[0],), "tp": (), "all": (names[0],)}


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_ctx, "mesh", None)
    _ctx.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _ctx.mesh = prev


def _axis_size(mesh: Mesh, phys) -> int:
    if isinstance(phys, tuple):
        return int(np.prod([mesh.shape[a] for a in phys]))
    return int(mesh.shape[phys])


def resolve(*logical: Optional[str], shape: Optional[Tuple[int, ...]] = None,
            unconstrained_fallback: bool = False) -> P:
    """Logical axis names -> PartitionSpec under the current mesh.

    With ``shape``, axes that do not evenly divide their dim are DROPPED
    (GSPMD rejects uneven shardings): replaced by UNCONSTRAINED inside jit
    constraints (let propagation decide) or None for in/out shardings.
    """
    table = logical_axes()
    mesh = current_mesh()
    out = []
    for i, ax in enumerate(logical):
        if ax is None:
            out.append(None)
            continue
        phys = table.get(ax, ())
        if len(phys) == 0:
            out.append(None)
            continue
        entry = phys[0] if len(phys) == 1 else phys
        if shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, entry):
                out.append(P.UNCONSTRAINED if unconstrained_fallback else None)
                continue
        out.append(entry)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Sharding constraint by logical axes; no-op without a mesh context.
    Non-divisible dims are left unconstrained (propagation decides)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve(*logical, shape=x.shape, unconstrained_fallback=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical: Optional[str],
                   shape: Optional[Tuple[int, ...]] = None
                   ) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical, shape=shape))


# ---------------------------------------------------------------------------
# Parameter sharding rules: path-regex -> logical axes per dim.
# ---------------------------------------------------------------------------

def params_shardings(mesh: Mesh, params_shape, rules: Sequence[Tuple[str, Tuple]]):
    """Build a NamedSharding tree for a params pytree.

    rules: list of (path_regex, logical_axes_tuple). First match wins; a
    non-matching leaf is fully replicated. logical axes use 'dp'/'tp'/None.
    """
    with use_mesh(mesh):
        def leaf_spec(path, leaf):
            pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            for pat, axes in rules:
                if re.search(pat, pstr):
                    # pad axes to leaf rank; drop non-divisible axes
                    ax = tuple(axes) + (None,) * (leaf.ndim - len(axes))
                    return NamedSharding(
                        mesh, resolve(*ax[: leaf.ndim], shape=leaf.shape))
            return NamedSharding(mesh, P())
        return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def batch_shardings(mesh: Mesh, batch_shape, batch_axis: str = "dp"):
    """Shard every batch leaf on its leading dim over dp (others replicated)."""
    with use_mesh(mesh):
        def leaf_spec(leaf):
            if leaf.ndim == 0:
                return NamedSharding(mesh, P())
            return NamedSharding(
                mesh, resolve(batch_axis, *([None] * (leaf.ndim - 1)),
                              shape=leaf.shape))
        return jax.tree_util.tree_map(leaf_spec, batch_shape)
