"""Train-step factory: value_and_grad + AdamW + optional microbatch
accumulation + optional int8 error-feedback gradient compression."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import optimizer as optim
from .grad_compression import compress_with_error_feedback, init_error_feedback


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optim.AdamWConfig = optim.AdamWConfig()
    grad_accum: int = 1            # microbatches per step
    compress_grads: bool = False   # int8 + error feedback


def init_train_state(params, cfg: TrainConfig) -> Dict[str, Any]:
    st = {"opt": optim.init_state(params, cfg.opt)}
    if cfg.compress_grads:
        st["ef"] = init_error_feedback(params)
    return st


def make_train_step(loss_fn: Callable, cfg: TrainConfig) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics). Returns
    step(params, state, batch) -> (params, state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step(params, state, batch):
        if cfg.grad_accum > 1:
            # batch leaves are [accum * micro, ...] -> scan microbatches
            def reshape(x):
                n = cfg.grad_accum
                return x.reshape((n, x.shape[0] // n) + x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def body(acc, mb):
                (loss, metrics), g = grads_of(params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), metrics
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            (grads, loss_sum), _ = jax.lax.scan(body, (zero_g, 0.0), micro)
            grads = jax.tree.map(lambda g: g / cfg.grad_accum, grads)
            loss = loss_sum / cfg.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = grads_of(params, batch)

        new_state = dict(state)
        if cfg.compress_grads:
            grads, new_state["ef"] = compress_with_error_feedback(
                grads, state["ef"])
        params, new_state["opt"], opt_m = optim.apply_updates(
            params, grads, state["opt"], cfg.opt)
        out = {"loss": loss, **opt_m}
        for k, v in (metrics or {}).items():
            out[k] = v
        return params, new_state, out

    return step
