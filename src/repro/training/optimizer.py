"""AdamW + LR schedules + global-norm clipping, from scratch (optax is not
available in this container). Functional API over param pytrees.

Mixed precision: moments are f32; with ``master_weights`` the fp32 master
copy lives in the optimizer state and model params are the cast-down view
(standard bf16 training setup).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine | linear | constant
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_weights: bool = True


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * t
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    st = {"m": zeros,
          "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
          "step": jnp.zeros((), jnp.int32)}
    if cfg.master_weights:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    src = state.get("master", params)

    def upd(p32, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p32 = p32.astype(jnp.float32)
        new = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return new, m, v

    flat_p, treedef = jax.tree.flatten(src)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new32 = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])

    new_params = jax.tree.map(lambda n, p: n.astype(p.dtype), new32, params)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.master_weights:
        new_state["master"] = new32
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
