"""Int8 gradient compression with error feedback (1-bit-Adam-family trick).

For cross-pod data parallelism the gradient all-reduce over the (slow)
pod-interconnect dominates; int8 per-tensor-scaled quantization cuts those
bytes 4x (vs f32) / 2x (vs bf16). Error feedback accumulates the residual
so the compression bias vanishes over steps (Karimireddy et al., 2019).

Two integration points:
  * pjit path — quantize->dequantize around the optimizer models the
    numerics (XLA owns the actual collective);
  * shard_map path — ``compressed_psum`` performs the real psum on int8
    payloads + per-shard scales (the wire-format saving).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32/bf16 -> (int8, scale). Symmetric per-tensor scaling."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_error_feedback(grads, ef):
    """Returns (compressed-then-decompressed grads, new error feedback)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return deq, target - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """psum of an int8 payload inside shard_map (the real wire saving).

    Shards agree on a shared scale via a (scalar) psum-max first, then the
    int8 payloads are summed in int16 lanes — 4x fewer bytes than f32 on
    the big tensor; only the scalar scale travels at full precision.
    """
    g32 = g.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int16), axis_name)
    return (q_sum.astype(jnp.float32) * scale).astype(g.dtype)
