"""Log compaction: fold the firehose-log tail into base snapshots.

The paper keeps the entire working state in memory and treats the
persisted stream as the source of truth for rebuilds (§4.2) — which only
works if the stream stays replayable. Raw keep-N retention breaks that:
the moment the writer trims a segment, "replay from zero" dies, and
without trimming, storage grows linearly with uptime. Kafka-style log
compaction closes the gap: periodically **fold** the retained log prefix
into a new *base* snapshot (engine state reflecting every tick below some
floor, produced by the exact same ``ingest_many`` replay the recovery
path uses), advertise it in the log manifest, and swap retention to
``[base, head]``. Replay-from-zero then means "restore the newest base ≤
your target, replay the short tail" — possible forever, with on-disk
bytes bounded by the working-set size instead of uptime.

Layout — bases live INSIDE the log directory, one ``CheckpointManager``
snapshot chain per engine consuming the log::

    <log_dir>/<log_name>-compact/<engine_name>/step_<tick>/...

so "the log" (segments + manifest + bases) remains one self-contained
replayable unit: copy the directory, get a restorable service.

Contract (also documented in ``streaming.__init__``):

  * **who compacts**: the fleet leader only — the compactor is epoch-
    fenced exactly like the writer. ``assume_epoch`` rejects rewinds;
    every ``compact()`` re-reads the manifest epoch before folding AND
    again immediately before the manifest swap, so a zombie compactor
    (deposed mid-fold) raises :class:`WriterFencedError` without touching
    the manifest. Base snapshots a zombie managed to write before losing
    the race are inert orphans — never advertised, eventually GC'd by the
    next legitimate compaction's ``CheckpointManager`` retention.
  * **crash safety**: base snapshots go through ``CheckpointManager``
    (tmp dir + fsync + rename), the manifest swap through the same
    tmp + rename as the writer. A crash before the swap leaves orphan
    snapshot dirs and the old manifest — readers see the old floor, and a
    torn base fails its sha256 during restore and falls back. A crash
    after the swap but before old-segment unlink leaves unmanifested
    segment files, counted by ``FirehoseLogReader.refresh()`` and removed
    by ``repair()``.
  * **fallback**: ``keep_bases`` bases are retained, and segment
    retention keeps everything from the OLDEST retained base onward —
    so a corrupt newest base (``corrupt_base`` injection, torn write)
    degrades to "restore the previous base + replay a longer tail",
    counted in ``last_restore['fell_back']``, never a dead log.
  * **exactness**: the fold replays with the engine's own cadence
    authority through ``engine.step_many`` (the fused ``ingest_many``
    scan) and runs NO rank cycles — rank cycles read state, never mutate
    it, so the folded state is bit-for-bit what an uninterrupted engine
    held at the floor tick (property-tested at every compaction
    boundary).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.engine import EngineConfig, SearchAssistanceEngine
from ..distributed.fault_tolerance import CheckpointManager, corrupt_snapshot
from .codec import DEFAULT_CODEC
from .log import (FirehoseLogReader, Segment, WriterFencedError,
                  _load_manifest_doc, _manifest_path, newest_base_tick)
from .replay import chunk_to_stack


def base_dir(log_dir: str, engine_name: str, log_name: str = "firehose"
             ) -> str:
    """Where one engine's base-snapshot chain lives (inside the log dir)."""
    return os.path.join(log_dir, f"{log_name}-compact", engine_name)


def base_manager(log_dir: str, engine_name: str, log_name: str = "firehose",
                 keep_bases: int = 2) -> CheckpointManager:
    """The ``CheckpointManager`` over one engine's bases. ``full_interval``
    is pinned to 1: a base must restore standalone (it IS the floor — a
    delta chain would re-introduce the torn-chain replay dependency that
    compaction exists to bound)."""
    return CheckpointManager(base_dir(log_dir, engine_name, log_name),
                             keep_n=keep_bases, full_interval=1)


@dataclasses.dataclass(frozen=True)
class CompactionConfig:
    keep_bases: int = 2        # fallback depth: old bases (and their log
                               # tail) retained after a swap
    chunk_ticks: int = 16      # fold replay chunking (one scan dispatch)
    codec: str = DEFAULT_CODEC  # carried for observability; bases compress
                               # via CheckpointManager's own codec


class LogCompactor:
    """Folds the sealed log prefix into per-engine base snapshots and
    atomically advances the manifest's replay floor.

    One compactor instance serves every engine consuming the log (the
    rt + bg serving stack): a base entry is only advertised once ALL
    engines' folds at that floor are durably written, so the floor never
    splits across engines.
    """

    def __init__(self, log_dir: str, engines: Dict[str, EngineConfig], *,
                 name: str = "firehose", epoch: int = 0,
                 cfg: CompactionConfig = CompactionConfig()):
        assert engines, "compactor needs at least one engine config"
        self.dir = log_dir
        self.name = name
        self.engines = dict(engines)
        self.cfg = cfg
        self.epoch = int(epoch)
        self._dead = False
        self.ckpts = {e: base_manager(log_dir, e, name, cfg.keep_bases)
                      for e in self.engines}
        # observability
        self.n_compactions = 0
        self.n_noop = 0
        self.n_base_fallbacks = 0     # folds that started from an older
                                      # base (newest was torn/corrupt)
        self.last_stats: Dict[str, Any] = {}

    # -- fencing ----------------------------------------------------------
    def assume_epoch(self, epoch: int) -> "LogCompactor":
        """Adopt leadership ``epoch``. Rejects rewinds against the on-disk
        manifest, like ``FirehoseLogWriter.assume_epoch`` — but does NOT
        bump the manifest itself: the writer owns the epoch stamp; the
        compactor only ever swaps a manifest it re-validated under it."""
        cur = int(_load_manifest_doc(self.dir, self.name).get("epoch", 0))
        if int(epoch) < cur:
            raise WriterFencedError(
                f"compactor cannot assume epoch {epoch}: manifest already "
                f"at {cur}")
        self.epoch = int(epoch)
        self._dead = False
        return self

    def _check_fence(self) -> Dict:
        doc = _load_manifest_doc(self.dir, self.name)
        if int(doc.get("epoch", 0)) > self.epoch:
            self._dead = True
            raise WriterFencedError(
                f"compactor (epoch {self.epoch}) fenced by manifest epoch "
                f"{doc.get('epoch')}: a newer leader owns log "
                f"'{self.name}'")
        return doc

    # -- fold -------------------------------------------------------------
    def _fold_engine(self, ename: str, reader: FirehoseLogReader,
                     upto: int) -> Tuple[int, Dict]:
        """Replay engine ``ename`` to state covering every tick < upto,
        starting from its newest intact base (or cold from zero), and save
        the result as a new base snapshot step. Returns (saved step,
        per-engine stats)."""
        cfg = self.engines[ename]
        ckpt = self.ckpts[ename]
        eng = SearchAssistanceEngine(cfg, ename)
        start, fell_back = 0, False
        prior = [s for s in ckpt.steps() if s <= upto]
        if prior:
            # restore's chain walk verifies sha256 and falls back to the
            # newest intact base <= the request on its own — a corrupt
            # newest base costs a longer fold replay, never a failed fold
            eng.state, got = ckpt.restore(eng.state, prior[-1])
            start = got
            fell_back = bool(ckpt.last_restore.get("fell_back")) \
                or got < prior[-1]
        if fell_back:
            self.n_base_fallbacks += 1
        n_ticks = 0
        for chunk in reader.read_chunks(start, self.cfg.chunk_ticks,
                                        upto_tick=upto):
            expect = int(eng.state.tick)
            if int(chunk.ticks[0]) != expect:
                # the fold NEVER skips: a base must cover exactly
                # [0, upto) or the floor would silently lose ticks
                raise ValueError(
                    f"compaction fold gap for engine '{ename}': expected "
                    f"tick {expect}, log chunk starts at "
                    f"{int(chunk.ticks[0])}")
            eng.step_many(chunk_to_stack(chunk))
            n_ticks += chunk.n_ticks
        if int(eng.state.tick) != upto:
            raise ValueError(
                f"compaction fold for engine '{ename}' stopped at tick "
                f"{int(eng.state.tick)}, wanted {upto} (log hole below "
                f"the proposed floor)")
        eng.save_snapshot(ckpt, extra_meta={"kind": "compaction-base",
                                            "floor_tick": upto})
        return upto, {"start": start, "n_ticks": n_ticks,
                      "fell_back": fell_back,
                      "base_bytes": ckpt.last_save_bytes}

    # -- the compaction cycle ---------------------------------------------
    def compact(self, upto_tick: Optional[int] = None) -> Dict:
        """One compaction cycle: fold → advertise → trim. Returns stats.

        ``upto_tick`` proposes the new floor (exclusive fold bound);
        default is one past the newest SEALED tick — the buffered tail a
        live writer holds is never folded. No-ops (with a counted stat)
        when the floor would not advance.
        """
        if self._dead:
            raise WriterFencedError("compactor was fenced; re-assume_epoch")
        t0 = time.perf_counter()
        # fold phase reads only sealed, verified segments
        reader = FirehoseLogReader(self.dir, name=self.name)
        self._check_fence()
        head = reader.last_tick()
        floor = newest_base_tick(reader.bases)
        upto = (head + 1 if head is not None else 0) \
            if upto_tick is None else int(upto_tick)
        if head is None or upto > head + 1:
            upto = head + 1 if head is not None else 0
        if upto <= 0 or (floor is not None and upto <= floor):
            self.n_noop += 1
            self.last_stats = {"noop": True, "floor": floor, "upto": upto}
            return self.last_stats
        # ---- fold every engine to the proposed floor (crash here: orphan
        # snapshot steps, manifest untouched) ----
        per_engine: Dict[str, Dict] = {}
        steps: Dict[str, int] = {}
        for ename in sorted(self.engines):
            step, st = self._fold_engine(ename, reader, upto)
            steps[ename] = step
            per_engine[ename] = st
        # ---- swap: re-validate fence, advertise the base, trim retention
        # to [oldest retained base, head] (atomic manifest rename) ----
        doc = self._check_fence()
        segments = [Segment(**s) for s in doc.get("segments", [])]
        bases = list(doc.get("bases", []))
        bases.append({"tick": upto, "epoch": self.epoch, "engines": steps,
                      "time": time.time()})
        bases.sort(key=lambda b: int(b["tick"]))
        if self.cfg.keep_bases > 0:
            bases = bases[-self.cfg.keep_bases:]
        # segments holding any tick >= the OLDEST retained base stay: they
        # are the fallback replay tail if a newer base turns out torn
        retain_floor = min(int(b["tick"]) for b in bases)
        keep = [s for s in segments if s.last >= retain_floor]
        drop = [s for s in segments if s.last < retain_floor]
        out = {"name": doc.get("name", self.name),
               "version": doc.get("version", 1),
               "epoch": int(doc.get("epoch", 0)),
               "segments": [dataclasses.asdict(s) for s in keep],
               "bases": bases}
        fd, tmp = tempfile.mkstemp(dir=self.dir,
                                   prefix=f".tmp_{self.name}_man_")
        with os.fdopen(fd, "w") as f:
            json.dump(out, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, _manifest_path(self.dir, self.name))
        # ---- old segment files leave disk only after the manifest stopped
        # listing them (crash between: unmanifested debris, repair()-able)
        n_unlinked = 0
        for seg in drop:
            try:
                os.unlink(os.path.join(self.dir, seg.file))
                n_unlinked += 1
            except OSError:
                pass
        self.n_compactions += 1
        self.last_stats = {
            "noop": False, "floor": upto, "prev_floor": floor,
            "retain_floor": retain_floor, "n_bases": len(bases),
            "n_segments_dropped": len(drop), "n_unlinked": n_unlinked,
            "engines": per_engine,
            "wall_s": time.perf_counter() - t0,
        }
        return self.last_stats


# ---------------------------------------------------------------------------
# Tiered restore: the read side of the bases
# ---------------------------------------------------------------------------

def restore_from_base(log_dir: str, engine_name: str, template: Any,
                      max_tick: Optional[int] = None,
                      log_name: str = "firehose"
                      ) -> Optional[Tuple[Any, int, Dict]]:
    """Restore engine state from the newest advertised base ≤ ``max_tick``.

    Returns ``(state, base_tick, info)`` or None when no usable base is
    advertised (no bases, none ≤ max_tick for this engine, or every
    candidate's snapshot is torn — the caller then replays from its own
    snapshot/zero as before). A torn newest base falls back THROUGH the
    manager's chain walk to the previous retained base, counted in
    ``info['fell_back']``; the returned ``base_tick`` is always the tick
    the restored state actually covers (replay resumes there).
    """
    reader = FirehoseLogReader(log_dir, name=log_name, verify=False)
    cands = [b for b in reader.bases
             if (max_tick is None or int(b["tick"]) <= int(max_tick))
             and engine_name in b.get("engines", {})]
    if not cands:
        return None
    cands.sort(key=lambda b: int(b["tick"]))
    requested = int(cands[-1]["tick"])
    ckpt = base_manager(log_dir, engine_name, log_name)
    advertised = {int(b["engines"][engine_name]): int(b["tick"])
                  for b in cands}
    for want in reversed(cands):
        try:
            state, got = ckpt.restore(template,
                                      int(want["engines"][engine_name]))
        except FileNotFoundError:
            continue               # torn + nothing older intact: next entry
        except ValueError:
            return None            # layout/template mismatch — structural
        if got in advertised:
            tick = advertised[got]
            return state, tick, {"requested": requested, "restored": tick,
                                 "fell_back": tick != requested}
        # the chain walk landed on a step no base entry advertises (a
        # zombie's orphan): don't trust its offset, try the next older
        # advertised base explicitly
    return None


def corrupt_base(log_dir: str, engine_name: str, tick: Optional[int] = None,
                 log_name: str = "firehose",
                 keep_fraction: float = 0.5) -> int:
    """Failure injection: tear the compressed base blob for ``engine_name``
    at the base advertised for ``tick`` (default: the newest). Restore must
    fall back to the previous retained base + a longer replay. Returns the
    snapshot step that was torn."""
    bases = FirehoseLogReader(log_dir, name=log_name, verify=False).bases
    cands = [b for b in bases if engine_name in b.get("engines", {})
             and (tick is None or int(b["tick"]) == int(tick))]
    if not cands:
        raise FileNotFoundError(
            f"no advertised base for engine '{engine_name}'"
            + (f" at tick {tick}" if tick is not None else ""))
    step = int(max(cands, key=lambda b: int(b["tick"]))
               ["engines"][engine_name])
    corrupt_snapshot(base_manager(log_dir, engine_name, log_name), step,
                     keep_fraction)
    return step
