"""Overload control for the live serving path (§1, §4: survive the spike).

The paper's motivating workload is the breaking-news flash crowd: query
volume spikes 10-100x within minutes, and the backend must stay fresh *and*
stay up. PR 3/5 made the stack crash-recoverable; this module makes it
overload-tolerant, with the two defining fast-data mechanisms (Kejariwal
et al. 1708.02621, §load shedding; 1403.3375 §admission control):

**Adaptive micro-batching** (:class:`AdaptiveMicroBatcher` inside
:class:`OverloadController`): when lag builds, live ticks are buffered and
dispatched as ONE fused ``engine.ingest_many`` scan — the catch-up replay
primitive reused live (bench_recovery: the fused scan sustains ~2x the
per-tick dispatch rate). The batch size K adapts to lag, quantized to
powers of two up to ``batch_max`` so the jitted scan compiles for a tiny
set of shapes. At zero lag K=1 and the path degenerates to per-tick
dispatch (minimum latency).

**Degradation ladder** (:class:`DegradationLadder`) — shed the cheapest
freshness first, never correctness, and never silently:

  ====  =============  ====================================================
  lvl   name           behavior added at this level
  ====  =============  ====================================================
  0     normal         full service
  1     shed_rank      rt ranking cycles shed (frontends serve the last
                       persisted tables — the §4.2 staleness stance)
  2     stretch_bg     bg ranking cadence stretched ``bg_stretch``x
                       (1 in N due cycles runs)
  3     sample_ingest  tweet-firehose ingest shed entirely; tail-source
                       query events (``src >= tail_src``, the low §4.2
                       source weights) hash-sampled down to ``tail_keep``
  ====  =============  ====================================================

Triggers (any): effective lag >= ``up_lag`` ticks; step-latency p95 over
``slo_ms``; region-freelist pressure under ``freelist_min``. Hysteresis:
a level moves only after ``up_ticks`` consecutive hot observations (up) or
``down_ticks`` consecutive cool ones (down), one rung at a time, so the
ladder cannot flap. Every shed decision is counted (``stats_snapshot``),
never silent.

**Bit-exact shedding** — the crash-recovery contract survives every level:
admission runs BEFORE the durable log append, so the log records exactly
the admitted stream; sampling is a pure hash of the event fingerprints
(:func:`admit_events` — no RNG, no clock), so the same events are admitted
no matter when the process restarts; maintenance cadences are never
touched (only read-only ranking is shed). Replaying the log therefore
reproduces the degraded run bit for bit, mid-shed crash included.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..data.stream import QueryEvents, TweetBatch
from .log import LogChunk, _LANES, _record_arrays
from .workload import bucket_size, _mix64

LEVEL_NAMES = ("normal", "shed_rank", "stretch_bg", "sample_ingest")

# fixed salt: admission must be a pure function of the event fingerprints
_SHED_SALT = np.uint64(0x5EDD1C7A7E5EED11)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Knobs of the overload-control layer (all cadences in ticks)."""
    slo_ms: float = 50.0         # step-latency target (p95 per tick)
    latency_window: int = 256    # latency samples kept for percentiles
    # micro-batcher
    batch_max: int = 8           # max ticks fused into one dispatch
    lag_batch: float = 1.5       # batching starts past this lag
    # ladder triggers + hysteresis
    up_lag: float = 4.0          # hot when effective lag >= this
    down_lag: float = 1.0        # cool when effective lag <= this
    up_ticks: int = 3            # consecutive hot ticks to go up a rung
    down_ticks: int = 6          # consecutive cool ticks to come down
    freelist_min: float = 0.05   # hot when free-region fraction below
    # level-2: bg ranking cadence stretch (1 in N due cycles runs)
    bg_stretch: int = 4
    # level-3 admission control
    tail_src: int = 2            # sources >= this are tail (§4.2 hashtag
                                 # click); 0 = sample the whole hose
    tail_keep: float = 0.25      # keep fraction of tail-source events
    compact_min: int = 64        # smallest compacted event bucket


class LatencyTracker:
    """Sliding-window step-latency percentiles (host wall clock, ms)."""

    def __init__(self, window: int = 256):
        self._buf: deque = deque(maxlen=window)

    def record(self, ms: float, n: int = 1) -> None:
        """Record ``n`` ticks that each cost ``ms`` (a fused flush of n
        ticks attributes the amortized per-tick latency to every tick)."""
        self._buf.extend([float(ms)] * int(n))

    def percentile(self, p: float) -> Optional[float]:
        if not self._buf:
            return None
        return float(np.percentile(np.fromiter(self._buf, float), p))

    def snapshot(self) -> Dict[str, Optional[float]]:
        return {"p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "n_samples": len(self._buf)}


class DegradationLadder:
    """Hysteretic 4-level ladder (see module docstring for the rungs).

    ``observe()`` once per offered tick moves at most one rung after the
    configured number of consecutive confirmations. ``force(level)`` pins
    the level (chaos/property tests script deterministic shed schedules
    with it); ``force(None)`` unpins.
    """

    def __init__(self, cfg: SLOConfig):
        self.cfg = cfg
        self.level = 0
        self.n_escalations = 0
        self.n_deescalations = 0
        self.level_ticks = [0, 0, 0, 0]
        self._hot = 0
        self._cool = 0
        self._forced: Optional[int] = None

    @property
    def name(self) -> str:
        return LEVEL_NAMES[self.level]

    def force(self, level: Optional[int]) -> None:
        if level is not None:
            assert 0 <= level < len(LEVEL_NAMES)
            self.level = level
        self._forced = level

    def observe(self, *, lag: float, p95_ms: Optional[float] = None,
                free_frac: Optional[float] = None) -> int:
        if self._forced is not None:
            self.level = self._forced
            self.level_ticks[self.level] += 1
            return self.level
        cfg = self.cfg
        hot = (lag >= cfg.up_lag
               or (p95_ms is not None and p95_ms > cfg.slo_ms)
               or (free_frac is not None and free_frac < cfg.freelist_min))
        cool = (lag <= cfg.down_lag
                and (p95_ms is None or p95_ms <= 0.8 * cfg.slo_ms)
                and (free_frac is None
                     or free_frac >= min(1.0, 2.0 * cfg.freelist_min)))
        if hot:
            self._hot += 1
            self._cool = 0
        elif cool:
            self._cool += 1
            self._hot = 0
        else:
            self._hot = self._cool = 0
        if self._hot >= cfg.up_ticks and self.level < 3:
            self.level += 1
            self.n_escalations += 1
            self._hot = 0
        elif self._cool >= cfg.down_ticks and self.level > 0:
            self.level -= 1
            self.n_deescalations += 1
            self._cool = 0
        self.level_ticks[self.level] += 1
        return self.level


# ---------------------------------------------------------------------------
# Admission control (level 3) — deterministic, pre-log, physically compacting
# ---------------------------------------------------------------------------

def admit_events(ev: Optional[QueryEvents], level: int, cfg: SLOConfig
                 ) -> Tuple[Optional[QueryEvents], int]:
    """Admission-control one tick's query events at ``level``.

    Below level 3 this is the identity. At level 3, tail-source events
    (``src >= cfg.tail_src`` — source ids order head to tail, so lowering
    ``tail_src`` widens the sampled band, ``tail_src=0`` samples the whole
    hose) are kept with probability ``cfg.tail_keep`` by a
    pure hash of ``q_fp ^ sess_fp`` (splitmix64 vs a fixed threshold): the
    SAME events are shed on every run — which is what keeps replay of the
    admitted log bit-exact. Survivors are physically compacted into the
    smallest power-of-4 bucket >= ``cfg.compact_min`` (order preserved),
    so shedding reduces device work, not just the valid mask.

    Returns ``(admitted_events, n_shed)``.
    """
    if ev is None:
        return None, 0
    valid = np.asarray(ev.valid, bool)
    if level < 3:
        return ev, 0
    keep = valid.copy()
    tail = valid & (np.asarray(ev.src) >= cfg.tail_src)
    if tail.any():
        h = _mix64(np.asarray(ev.q_fp, np.uint64)
                   ^ np.asarray(ev.sess_fp, np.uint64) ^ _SHED_SALT)
        thr = np.uint64(int(cfg.tail_keep * float(np.iinfo(np.uint64).max)))
        keep &= ~tail | (h < thr)
    n_shed = int(valid.sum()) - int(keep.sum())
    if n_shed == 0:
        return ev, 0
    idx = np.nonzero(keep)[0]
    B = bucket_size(len(idx), cfg.compact_min, valid.shape[0])
    out = QueryEvents(
        sess_fp=_take(np.asarray(ev.sess_fp, np.uint64), idx, B),
        q_fp=_take(np.asarray(ev.q_fp, np.uint64), idx, B),
        src=_take(np.asarray(ev.src, np.int32), idx, B),
        valid=_valid_mask(len(idx), B))
    return out, n_shed


def admit_tweets(tw: Optional[TweetBatch], level: int, cfg: SLOConfig
                 ) -> Tuple[Optional[TweetBatch], int]:
    """Level 3 sheds the tweet firehose entirely (the T*G*G pair blowup is
    the most expensive per-tick work and the lowest-weight signal,
    ``tweet_weight``); below level 3, identity. Returns ``(tw, n_shed)``."""
    if tw is None or level < 3:
        return tw, 0
    return None, int(np.asarray(tw.valid, bool).sum())


def _take(a: np.ndarray, idx: np.ndarray, size: int) -> np.ndarray:
    out = np.zeros((size,) + a.shape[1:], a.dtype)
    out[: len(idx)] = a[idx]
    return out


def _valid_mask(n: int, size: int) -> np.ndarray:
    v = np.zeros(size, bool)
    v[:n] = True
    return v


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------

class AdaptiveMicroBatcher:
    """Buffers admitted ticks; flushes stackable runs of K ticks.

    K follows lag, quantized to powers of two capped at ``batch_max`` (a
    tiny shape alphabet for the jitted scan). A shape change flushes first
    (a stack must be stackable — same rule as the log's segment rotation
    and the reader's chunk merging).
    """

    def __init__(self, cfg: SLOConfig):
        self.cfg = cfg
        self._buf: List[Dict[str, np.ndarray]] = []
        self._ticks: List[int] = []

    def __len__(self) -> int:
        return len(self._buf)

    def k_target(self, lag: float) -> int:
        if lag <= self.cfg.lag_batch:
            return 1
        k = 1
        while k < self.cfg.batch_max and k < lag:
            k *= 2
        return min(k, self.cfg.batch_max)

    def add(self, tick: int, ev: Optional[QueryEvents],
            tw: Optional[TweetBatch]) -> Optional[LogChunk]:
        """Buffer one admitted tick; returns a chunk to dispatch when the
        new tick's shapes are incompatible with the buffered run."""
        rec = _record_arrays(tick, ev, tw)
        out = None
        if self._buf and any(rec[k].shape != self._buf[-1][k].shape
                             for k in _LANES[1:]):
            out = self.take()
        self._buf.append(rec)
        self._ticks.append(int(tick))
        return out

    def take(self) -> Optional[LogChunk]:
        """Pop the buffered run as one stacked chunk (None if empty)."""
        if not self._buf:
            return None
        chunk = LogChunk(**{k: np.stack([r[k] for r in self._buf])
                            for k in _LANES})
        self._buf, self._ticks = [], []
        return chunk


class OverloadController:
    """SLO-driven live ingestion for one :class:`AssistanceService`.

    ``offer(events, tweets)`` replaces per-tick ``service.step``: it runs
    the ladder, admission-controls the tick, appends the ADMITTED batch to
    the durable log (``log_append`` callback — ordered before ingestion so
    the log is always a superset of engine state), buffers it, and
    dispatches fused ``ingest_many`` flushes when the adaptive batch size
    is reached. Ranking is governed here (shed/stretched per the ladder;
    ranking reads state but never mutates it, so this cannot perturb the
    replay-equality contract). ``mirrors`` are extra follower rt engines
    (replica failover targets) fed the same flushed stacks.

    Accounting invariant (property-tested): at every level, after
    ``drain()``, offered events == ingested events + counted-shed events,
    for the query hose and the tweet firehose separately.
    """

    def __init__(self, service, cfg: SLOConfig,
                 mirrors: Sequence = ()):
        from ..core.engine import rank_due   # late: keep import acyclic
        self._rank_due = rank_due
        self.svc = service
        self.cfg = cfg
        self.ladder = DegradationLadder(cfg)
        self.latency = LatencyTracker(cfg.latency_window)
        self.batcher = AdaptiveMicroBatcher(cfg)
        self.mirrors = list(mirrors)
        self.counters: Dict[str, int] = {
            "n_offered_events": 0, "n_ingested_events": 0,
            "n_shed_events": 0,
            "n_offered_tweets": 0, "n_ingested_tweets": 0,
            "n_shed_tweets": 0,
            "n_rank_run_rt": 0, "n_shed_rank_rt": 0,
            "n_rank_run_bg": 0, "n_shed_rank_bg": 0,
            "n_flushes": 0, "n_flush_ticks": 0,
        }
        self._bg_due_seen = 0
        self.last_flush: Dict = {}

    # -- signals --
    def _free_frac(self) -> Optional[float]:
        eng = self.svc.rt
        if not eng.cfg.region_cooc:
            return None
        fr = eng.last_maintenance.get("c_free_regions")
        if fr is None:
            return None
        total = max(eng.cfg.cooc_capacity // eng.cfg.region_w, 1)
        return float(fr) / total

    # -- the live path --
    def offer(self, events: Optional[QueryEvents] = None,
              tweets: Optional[TweetBatch] = None, *,
              log_append: Optional[Callable] = None,
              lag_hint: float = 0.0) -> Optional[Dict]:
        """Process one offered tick; returns rank stats iff a flush ranked."""
        backlog = len(self.batcher)
        tick = int(self.svc.rt.state.tick) + backlog
        lag = backlog + max(float(lag_hint), 0.0)
        level = self.ladder.observe(lag=lag,
                                    p95_ms=self.latency.percentile(95),
                                    free_frac=self._free_frac())

        if events is not None:
            self.counters["n_offered_events"] += \
                int(np.asarray(events.valid, bool).sum())
        if tweets is not None:
            self.counters["n_offered_tweets"] += \
                int(np.asarray(tweets.valid, bool).sum())
        ev, shed_q = admit_events(events, level, self.cfg)
        tw, shed_t = admit_tweets(tweets, level, self.cfg)
        self.counters["n_shed_events"] += shed_q
        self.counters["n_shed_tweets"] += shed_t

        # log-append FIRST (durability precedes ingestion): the log records
        # exactly the admitted stream, so crash recovery mid-shed replays
        # the degraded run bit for bit.
        if log_append is not None:
            log_append(tick, ev, tw)

        out = None
        rotated = self.batcher.add(tick, ev, tw)
        if rotated is not None:                 # shape change forced it out
            out = self._dispatch(rotated, level)
        if len(self.batcher) >= self.batcher.k_target(lag):
            r = self._dispatch(self.batcher.take(), level)
            out = r if out is None else out
        return out

    def drain(self) -> Optional[Dict]:
        """Flush whatever is buffered (shutdown / end of stream)."""
        chunk = self.batcher.take()
        if chunk is None:
            return None
        return self._dispatch(chunk, self.ladder.level)

    # -- flush --
    def _dispatch(self, chunk: LogChunk, level: int) -> Optional[Dict]:
        from .replay import chunk_to_stack     # late: keep import acyclic
        t0 = time.perf_counter()
        stack = chunk_to_stack(chunk)
        self.svc.rt.step_many(stack)
        self.svc.bg.step_many(stack)
        for m in self.mirrors:
            m.step_many(stack)
        n = chunk.n_ticks
        lo, hi = int(chunk.ticks[0]), int(chunk.ticks[-1]) + 1
        rank = self._govern_ranking(lo, hi, level)
        ms = (time.perf_counter() - t0) * 1e3 / n
        self.latency.record(ms, n)
        self.counters["n_flushes"] += 1
        self.counters["n_flush_ticks"] += n
        self.counters["n_ingested_events"] += int(chunk.q_valid.sum())
        self.counters["n_ingested_tweets"] += int(chunk.t_valid.sum())
        self.last_flush = {"n_ticks": n, "ms_per_tick": ms, "level": level}
        return rank

    def _govern_ranking(self, lo: int, hi: int, level: int
                        ) -> Optional[Dict]:
        """Run/shed the rank cycles due in [lo, hi) per the ladder.

        Batching runs at most one cycle per engine per flush (the catch-up
        controller's run-one pattern — extra dues in a fused flush are
        counted shed); level >= 1 sheds rt cycles outright; level >= 2
        runs only 1 in ``bg_stretch`` bg dues. Counted, never silent.
        """
        c = self.counters
        rt_due = [t for t in range(lo, hi)
                  if self._rank_due(self.svc.rt.cfg, t)]
        bg_due = [t for t in range(lo, hi)
                  if self._rank_due(self.svc.bg.cfg, t)]
        r1 = r2 = None
        if rt_due:
            if level >= 1:
                c["n_shed_rank_rt"] += len(rt_due)
            else:
                r1 = self.svc.rt.run_rank_cycle()
                c["n_rank_run_rt"] += 1
                c["n_shed_rank_rt"] += len(rt_due) - 1
        run_bg = 0
        for _ in bg_due:
            if level >= 2:
                if self._bg_due_seen % self.cfg.bg_stretch == 0:
                    run_bg = 1
                self._bg_due_seen += 1
            else:
                self._bg_due_seen += 1
                run_bg = 1
        if bg_due:
            if run_bg:
                r2 = self.svc.bg.run_rank_cycle()
                c["n_rank_run_bg"] += 1
            c["n_shed_rank_bg"] += len(bg_due) - run_bg
        if r1 is not None or r2 is not None:
            self.svc.refresh_cache()
            return {"rt": r1, "bg": r2}
        return None

    # -- observability --
    def stats_snapshot(self) -> Dict:
        """JSON-serializable overload state — rides into snapshot meta and
        out through ``SuggestFrontend.metrics()``. Every shed path above
        has a counter here: nothing is shed silently."""
        out: Dict = dict(self.counters)
        out["level"] = self.ladder.level
        out["level_name"] = self.ladder.name
        out["level_ticks"] = list(self.ladder.level_ticks)
        out["n_escalations"] = self.ladder.n_escalations
        out["n_deescalations"] = self.ladder.n_deescalations
        out["n_shed_total"] = (out["n_shed_events"] + out["n_shed_tweets"]
                               + out["n_shed_rank_rt"]
                               + out["n_shed_rank_bg"])
        out["slo_ms"] = self.cfg.slo_ms
        out.update({f"step_{k}_ms": v for k, v in
                    self.latency.snapshot().items() if k != "n_samples"})
        out["backlog_ticks"] = len(self.batcher)
        return out
