"""Durable firehose log + faster-than-real-time catch-up replay (§4.2).

The paper's backend is deliberately volatile: durability comes from
persisting results periodically and from the ability of a (re)started
instance to *rewind into the firehose* and consume messages faster than
real time until it catches up, while frontends serve the last persisted
tables in the meantime. This package is that recovery subsystem:

  * :mod:`.log` — an append-only, segmented micro-batch log (npz segments +
    json manifest, atomic rename, rotation by tick count, keep-N retention,
    seek-by-tick reader, torn-tail detection), standing in for the
    replayable message queue the paper rewinds into;
  * :mod:`.replay` — the catch-up controller: restore the newest snapshot
    (checkpoint + log offset), replay the log tail through the fused
    ``engine.ingest_many`` scan step, hand off to live ingestion.
    ``recover_service`` extends this to the whole serving stack: rt engine
    + background engine + interpolation cache, each engine replaying the
    shared log from its own offset under its own cadence authority.

Snapshots themselves may be *incremental*: ``CheckpointManager`` (see
``distributed.fault_tolerance`` for the manifest format) writes delta
snapshots — changed store slots only — chained to the last full snapshot
(``kind``/``base_step``/``sha256`` in the manifest). Restore chain-walks
the deltas onto the base full; a torn or corrupt chain member falls back
to the newest intact full, and replay covers the difference from the log
(a broken chain costs tail length, never recoverability). Retention never
unlinks a full while a retained delta still references it. The shrunken
write volume is what lets the snapshot cadence drop ~4x — and the replay
tail (time-to-fresh after a crash) with it (bench_recovery rows
``recovery_snapshot_*``).

**Overload control** (:mod:`.overload`) — the live-path twin of the
recovery story (§1: the flash crowd is the paper's motivating workload).
An :class:`~repro.streaming.overload.OverloadController` in front of the
serving stack micro-batches live ticks through the same fused
``ingest_many`` scan that replay uses (batch size K adapts to lag,
quantized powers of two up to ``SLOConfig.batch_max``) and walks an
explicit **degradation ladder** when batching alone cannot hold the SLO:

  =====  =============  ==================================================
  level  name           what is shed at this rung (cumulative)
  =====  =============  ==================================================
  0      normal         nothing — full service
  1      shed_rank      rt ranking cycles (frontends serve the last
                        persisted tables, the §4.2 staleness stance)
  2      stretch_bg     bg ranking cadence stretched ``bg_stretch``x
  3      sample_ingest  tweet-firehose ingest dropped; tail-source query
                        events (``src >= tail_src``) sampled to ``tail_keep``
  =====  =============  ==================================================

*Triggers* (any): effective lag >= ``up_lag`` ticks, step-latency p95 over
``slo_ms``, region-freelist fraction under ``freelist_min``. *Hysteresis*:
one rung per ``up_ticks`` consecutive hot / ``down_ticks`` consecutive
cool observations — the ladder cannot flap. *SLO knobs* live on
:class:`~repro.streaming.overload.SLOConfig` (``slo_ms``, ``batch_max``,
``lag_batch``, hysteresis, ``bg_stretch``, ``tail_src``/``tail_keep``).
Every shed decision is counted in ``stats_snapshot()`` — never silent —
and admission runs *before* the durable log append with a pure-hash
sampler, so crash -> restore -> replay stays bit-exact mid-shed.
:mod:`.workload` generates the firehose traffic (Zipf + topic drift,
breaking-news flash crowds, spam bursts, multilingual sessions) that the
benches and the chaos harness (``kill_writer_mid_segment`` /
``corrupt_segment`` / ``corrupt_snapshot`` / :func:`~repro.streaming.log.slow_io`
/ :func:`~repro.streaming.log.flaky_io`) drive this machinery with.

**Failure model & fleet operations** — the fleet control plane
(``distributed.fleet.ServingFleet``) composes this package into a
replicated serving story. Who writes the log: exactly one replica — the
``ReplicaGroup``-elected leader — appends; every leadership change bumps
an **epoch** that the new leader stamps into the log manifest
(``FirehoseLogWriter.assume_epoch``) *before* its first append. Fencing
semantics: a writer whose epoch is older than the manifest's raises
:class:`~repro.streaming.log.WriterFencedError` at its next segment seal
and is permanently dead — a paused/partitioned ex-leader can never land a
stray segment, so split-brain on the durable log is structurally
impossible (``log_epoch`` reads the current fence token). Transient I/O:
the reader retries each segment read up to ``io_retries`` times with
exponential backoff before surfacing the error, so an NFS blip during
catch-up replay does not become a failed recovery (``flaky_io`` injects
exactly this fault class). What each state means for answer staleness:
a *live* replica answers at the current tick; the degradation ladder's
``shed_rank``/``stretch_bg`` rungs serve last-persisted rankings (§4.2:
stale-but-fast beats fresh-but-late); a *dead* replica is skipped by the
router and its requests hedge to the next-freshest survivor; a
*recovering* replica (snapshot restore + log-tail replay) is not routed
to until its lag clears, so clients never observe a rewound tick. Every
answer is tagged with its serving tick and staleness vs the freshest live
replica (``serving.serve.RouteResult``) — degraded answers are honest.

**Compaction contract** (:mod:`.compaction` + :mod:`.codec`) — the
storage tier under all of the above. Sealed segments and checkpoint
payloads are compressed (fingerprint lanes XOR-delta encoded first; codec
id + uncompressed sha256 in the manifest, on-disk sha256 unchanged), and
a :class:`~repro.streaming.compaction.LogCompactor` periodically folds
the sealed log prefix into per-engine **base snapshots** advertised in
the log manifest's ``bases`` list. *Who may compact*: only the current
leader — the compactor adopts the leadership epoch
(``LogCompactor.assume_epoch``) and re-validates it against the manifest
immediately before its atomic manifest swap, so a deposed (zombie)
compactor raises ``WriterFencedError`` without touching the manifest;
its orphaned fold output is never advertised and gets GC'd. *What the
replay floor means*: a base at tick T holds engine state covering every
tick < T — recovery (``recover_engine``/``recover_service``), fleet
restarts and ``elastic.live_reshard``'s log-tail replay all start from
the newest base ≤ their target instead of zero, so trimming segments
below the floor is safe and "replay from zero" stays possible forever
with bounded disk. Fleet **log-healing is floor-oblivious**: healing
re-appends missing ticks at the head, compaction trims the tail — the
two never touch the same segments. ``keep_bases`` old bases (plus the
log tail from the oldest retained base) remain on disk, so a torn or
corrupt newest base (``corrupt_base`` injection) degrades to the
previous base + a longer replay — counted, never a dead log. The
writer's blunt ``keep_segments`` retention warns-and-clamps rather than
trim a segment at/after the newest base.
"""
from .codec import (CodecError, decode_payload, encode_payload,
                    xor_delta_decode, xor_delta_encode)
from .compaction import (CompactionConfig, LogCompactor, corrupt_base,
                         restore_from_base)
from .log import (FirehoseLogReader, FirehoseLogWriter, LogChunk,
                  WriterFencedError, corrupt_segment, flaky_io,
                  kill_writer_mid_segment, log_bases, log_epoch, slow_io)
from .overload import (DegradationLadder, LatencyTracker, OverloadController,
                       SLOConfig, admit_events, admit_tweets)
from .replay import (CatchUpController, ReplayConfig, chunk_to_stack,
                     recover_engine, recover_service)
from .workload import (FirehoseWorkload, SpamSpec, SpikeSpec, WorkloadConfig,
                       bucket_size)

__all__ = [
    "FirehoseLogReader", "FirehoseLogWriter", "LogChunk",
    "WriterFencedError", "corrupt_segment", "flaky_io",
    "kill_writer_mid_segment", "log_bases", "log_epoch", "slow_io",
    "CodecError", "decode_payload", "encode_payload",
    "xor_delta_decode", "xor_delta_encode",
    "CompactionConfig", "LogCompactor", "corrupt_base",
    "restore_from_base",
    "CatchUpController", "ReplayConfig", "chunk_to_stack", "recover_engine",
    "recover_service",
    "OverloadController", "SLOConfig", "DegradationLadder", "LatencyTracker",
    "admit_events", "admit_tweets",
    "FirehoseWorkload", "WorkloadConfig", "SpikeSpec", "SpamSpec",
    "bucket_size",
]
