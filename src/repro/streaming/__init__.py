"""Durable firehose log + faster-than-real-time catch-up replay (§4.2).

The paper's backend is deliberately volatile: durability comes from
persisting results periodically and from the ability of a (re)started
instance to *rewind into the firehose* and consume messages faster than
real time until it catches up, while frontends serve the last persisted
tables in the meantime. This package is that recovery subsystem:

  * :mod:`.log` — an append-only, segmented micro-batch log (npz segments +
    json manifest, atomic rename, rotation by tick count, keep-N retention,
    seek-by-tick reader, torn-tail detection), standing in for the
    replayable message queue the paper rewinds into;
  * :mod:`.replay` — the catch-up controller: restore the newest snapshot
    (checkpoint + log offset), replay the log tail through the fused
    ``engine.ingest_many`` scan step, hand off to live ingestion.
    ``recover_service`` extends this to the whole serving stack: rt engine
    + background engine + interpolation cache, each engine replaying the
    shared log from its own offset under its own cadence authority.

Snapshots themselves may be *incremental*: ``CheckpointManager`` (see
``distributed.fault_tolerance`` for the manifest format) writes delta
snapshots — changed store slots only — chained to the last full snapshot
(``kind``/``base_step``/``sha256`` in the manifest). Restore chain-walks
the deltas onto the base full; a torn or corrupt chain member falls back
to the newest intact full, and replay covers the difference from the log
(a broken chain costs tail length, never recoverability). Retention never
unlinks a full while a retained delta still references it. The shrunken
write volume is what lets the snapshot cadence drop ~4x — and the replay
tail (time-to-fresh after a crash) with it (bench_recovery rows
``recovery_snapshot_*``).
"""
from .log import (FirehoseLogReader, FirehoseLogWriter, LogChunk,
                  corrupt_segment, kill_writer_mid_segment)
from .replay import (CatchUpController, ReplayConfig, chunk_to_stack,
                     recover_engine, recover_service)

__all__ = [
    "FirehoseLogReader", "FirehoseLogWriter", "LogChunk",
    "corrupt_segment", "kill_writer_mid_segment",
    "CatchUpController", "ReplayConfig", "chunk_to_stack", "recover_engine",
    "recover_service",
]
