"""Durable firehose log + faster-than-real-time catch-up replay (§4.2).

The paper's backend is deliberately volatile: durability comes from
persisting results periodically and from the ability of a (re)started
instance to *rewind into the firehose* and consume messages faster than
real time until it catches up, while frontends serve the last persisted
tables in the meantime. This package is that recovery subsystem:

  * :mod:`.log` — an append-only, segmented micro-batch log (npz segments +
    json manifest, atomic rename, rotation by tick count, keep-N retention,
    seek-by-tick reader, torn-tail detection), standing in for the
    replayable message queue the paper rewinds into;
  * :mod:`.replay` — the catch-up controller: restore the newest snapshot
    (checkpoint + log offset), replay the log tail through the fused
    ``engine.ingest_many`` scan step, hand off to live ingestion.
"""
from .log import (FirehoseLogReader, FirehoseLogWriter, LogChunk,
                  corrupt_segment, kill_writer_mid_segment)
from .replay import (CatchUpController, ReplayConfig, chunk_to_stack,
                     recover_engine)

__all__ = [
    "FirehoseLogReader", "FirehoseLogWriter", "LogChunk",
    "corrupt_segment", "kill_writer_mid_segment",
    "CatchUpController", "ReplayConfig", "chunk_to_stack", "recover_engine",
]
