"""Append-only segmented firehose log (the §4.2 "rewindable hose").

The paper's recovery story leans on the message queue: "a (re)started
instance can rewind to an earlier point in the [fire]hose and consume
messages at a faster rate than real time to catch up to the present". The
deployed system got that property from the firehose infrastructure itself;
here the hose is synthetic, so we make it rewindable with a durable log.

Design — one log = one directory (several named logs may share it):

  * **segments**: ``<name>-<first>-<last>.npz`` files, each holding a stack
    of consecutive micro-batch ticks (query events + tweet grams). Segments
    are written whole: serialized to memory, checksummed, written to a
    ``.tmp_*`` file, fsynced, then atomically renamed into place.
  * **manifest**: ``<name>-MANIFEST.json`` lists the sealed segments (file,
    tick range, sha256). It is rewritten atomically after every seal, so
    readers always see a consistent prefix of the log.
  * **rotation** by tick count (``ticks_per_segment``), and also whenever
    the micro-batch shapes change (a segment is one stackable block).
  * **codec**: sealed segment blobs go through ``streaming.codec`` —
    fingerprint lanes XOR-delta encoded, then zlib (exact round-trip).
    The manifest records the codec id plus BOTH digests: ``sha256`` over
    the on-disk (compressed) bytes — what the reader's integrity pass and
    ``corrupt_segment`` operate on, unchanged — and ``raw_sha256`` over
    the uncompressed npz body, re-verified at decode time. ``codec="raw"``
    writes plain npz; readers decode either transparently.
  * **retention**: ``keep_segments`` newest segments are kept; older ones
    leave the manifest first, then their files are unlinked — a reader can
    never observe a manifested-but-deleted segment. Without a compaction
    base in the manifest, retention must cover the oldest snapshot offset
    recovery may restore from: with delta snapshots
    (``CheckpointManager.full_interval > 1``) a torn chain falls back to
    the last *full* snapshot, so size ``keep_segments`` for a
    full-snapshot interval of ticks, not a delta interval. Once a
    ``LogCompactor`` advertises a base (replay floor) in the manifest,
    the guard below applies: ``_retain`` will never trim a segment that
    holds ticks at/after the newest base — it warns and keeps the
    segment instead of silently making replay-from-base impossible.
  * **compaction bases**: the manifest's ``bases`` list advertises folded
    base snapshots (``{"tick", "epoch", "engines": {name: step}}``):
    engine state reflecting every tick ``< tick``, written through
    ``CheckpointManager`` by ``streaming.compaction.LogCompactor``. The
    newest base ≤ a requested tick is the replay floor: readers/recovery
    restore it and replay only ``[tick, head]``. Only the compactor
    rewrites ``bases`` (epoch-fenced, same manifest rename as the
    writer); the writer carries them through untouched on every
    manifest rewrite.
  * **torn-tail detection**: a crashed writer can leave (a) ``.tmp_*``
    scratch files, (b) a partial segment file at its final name that never
    made the manifest, or (c) — with non-atomic filesystems — a manifested
    segment whose bytes are short/corrupt. The reader validates checksums
    in order and truncates the log at the first bad segment: everything up
    to the last complete segment replays, the torn tail is ignored (the
    paper's stance: losing a little state is tolerable, §4.2).
  * **epoch fencing**: the manifest carries a monotonic leadership
    ``epoch``. A failing-over leader calls ``assume_epoch(e)`` — which
    re-syncs its segment view and durably rewrites the manifest at the new
    epoch BEFORE any of its appends — and from then on any writer still
    holding an older epoch is a *zombie*: its ``append``/``flush`` re-reads
    the on-disk epoch and raises :class:`WriterFencedError` without writing
    a segment or touching the manifest. The fencing token thus rides in the
    same atomically-renamed manifest that defines log visibility, so "the
    manifest the new leader owns" and "the manifest readers trust" are one
    object (``distributed.fault_tolerance.ReplicaGroup`` bumps the epoch on
    every leadership change).

The reader seeks by tick and yields stacked chunks ready for the fused
``engine.ingest_many`` replay step.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import tempfile
import warnings
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

import numpy as np

from ..data.stream import QueryEvents, TweetBatch
from .codec import DEFAULT_CODEC, decode_payload, encode_payload

_FMT = "{name}-{first:012d}-{last:012d}.npz"
_SEG_RE = re.compile(r"^(?P<name>.+)-(?P<first>\d{12})-(?P<last>\d{12})\.npz$")

# npz lanes of one segment (leading dim R = ticks in the segment)
_LANES = ("ticks", "sess_fp", "q_fp", "src", "q_valid", "grams", "t_valid")


class LogChunk(NamedTuple):
    """A stack of consecutive logged ticks (host numpy, ready to replay)."""
    ticks: np.ndarray     # i64[R]
    sess_fp: np.ndarray   # u64[R, B]
    q_fp: np.ndarray      # u64[R, B]
    src: np.ndarray       # i32[R, B]
    q_valid: np.ndarray   # bool[R, B]
    grams: np.ndarray     # u64[R, T, G]
    t_valid: np.ndarray   # bool[R, T]

    @property
    def n_ticks(self) -> int:
        return self.ticks.shape[0]

    def query_events(self, i: int) -> Optional[QueryEvents]:
        if self.q_fp.shape[1] == 0:
            return None
        return QueryEvents(sess_fp=self.sess_fp[i], q_fp=self.q_fp[i],
                           src=self.src[i], valid=self.q_valid[i])

    def tweet_batch(self, i: int) -> Optional[TweetBatch]:
        if self.grams.shape[1] == 0 or self.grams.shape[2] == 0:
            return None
        return TweetBatch(grams=self.grams[i], valid=self.t_valid[i])


@dataclasses.dataclass(frozen=True)
class Segment:
    file: str
    first: int
    last: int
    n_ticks: int
    sha256: str               # over the on-disk (possibly compressed) bytes
    codec: str = "raw"        # pre-codec manifests decode as raw npz
    raw_sha256: Optional[str] = None   # over the uncompressed npz body


def newest_base_tick(bases: List[Dict]) -> Optional[int]:
    """Replay floor of a manifest's ``bases`` list: the newest advertised
    base tick (state covers every tick strictly below it), or None."""
    return max((int(b["tick"]) for b in bases), default=None)


class WriterFencedError(RuntimeError):
    """A zombie ex-leader's append/flush was rejected: the on-disk manifest
    carries a newer leadership epoch than this writer holds. Nothing was
    written — neither segment bytes nor manifest."""


def _record_arrays(tick: int, events: Optional[QueryEvents],
                   tweets: Optional[TweetBatch]) -> Dict[str, np.ndarray]:
    if events is None:
        sess = q = np.zeros((0,), np.uint64)
        src = np.zeros((0,), np.int32)
        qv = np.zeros((0,), bool)
    else:
        sess = np.asarray(events.sess_fp, np.uint64)
        q = np.asarray(events.q_fp, np.uint64)
        src = np.asarray(events.src, np.int32)
        qv = np.asarray(events.valid, bool)
    if tweets is None:
        grams = np.zeros((0, 0), np.uint64)
        tv = np.zeros((0,), bool)
    else:
        grams = np.asarray(tweets.grams, np.uint64)
        tv = np.asarray(tweets.valid, bool)
    return {"ticks": np.int64(tick), "sess_fp": sess, "q_fp": q, "src": src,
            "q_valid": qv, "grams": grams, "t_valid": tv}


class FirehoseLogWriter:
    """Single-writer append path (leader-elected in a replica group —
    see ``distributed.fault_tolerance.ReplicaGroup.log_append``)."""

    def __init__(self, directory: str, ticks_per_segment: int = 8,
                 keep_segments: int = 0, name: str = "firehose",
                 epoch: int = 0, codec: str = DEFAULT_CODEC):
        assert ticks_per_segment > 0
        self.dir = directory
        self.name = name
        self.ticks_per_segment = ticks_per_segment
        self.keep_segments = keep_segments  # 0 = keep everything
        self.codec = codec
        # leadership epoch this writer believes it holds; appends are fenced
        # against the manifest's epoch (see ``assume_epoch``)
        self.epoch = int(epoch)
        os.makedirs(directory, exist_ok=True)
        self._buf: List[Dict[str, np.ndarray]] = []
        self._buf_ticks: List[int] = []
        self._dead = False
        doc = _load_manifest_doc(directory, name)
        self.segments: List[Segment] = [Segment(**s)
                                        for s in doc.get("segments", [])]
        # compaction bases are owned by the LogCompactor; the writer only
        # carries them through its manifest rewrites
        self.bases: List[Dict] = list(doc.get("bases", []))

    # -- state --
    @property
    def last_tick(self) -> Optional[int]:
        if self._buf_ticks:
            return self._buf_ticks[-1]
        return self.segments[-1].last if self.segments else None

    def _manifest_path(self) -> str:
        return _manifest_path(self.dir, self.name)

    # -- leadership epoch / fencing --
    def assume_epoch(self, epoch: int) -> "FirehoseLogWriter":
        """Take over as the single log writer at leadership ``epoch``.

        Re-syncs the segment view from disk, verifies the epoch is not
        older than the manifest's, then durably rewrites the manifest at
        the new epoch — BEFORE any append. That ordering is the fence: the
        moment the bump lands, a zombie ex-leader's next ``append``/
        ``flush`` observes ``manifest.epoch > writer.epoch`` and is
        rejected, even if the new leader has not sealed a segment yet.
        """
        doc = _load_manifest_doc(self.dir, self.name)
        cur = int(doc.get("epoch", 0))
        if int(epoch) < cur:
            raise WriterFencedError(
                f"cannot assume epoch {epoch}: manifest already at {cur}")
        self.segments = [Segment(**s) for s in doc.get("segments", [])]
        self.bases = list(doc.get("bases", []))
        self.epoch = int(epoch)
        self._dead = False
        self._write_manifest()
        return self

    def _check_fence(self) -> None:
        cur = int(_load_manifest_doc(self.dir, self.name).get("epoch", 0))
        if cur > self.epoch:
            # fenced writers stay fenced: drop the buffer so a later retry
            # cannot resurrect the stray ticks either
            self._buf, self._buf_ticks = [], []
            self._dead = True
            raise WriterFencedError(
                f"writer (epoch {self.epoch}) fenced by manifest epoch "
                f"{cur}: a newer leader owns log '{self.name}'")

    def _sync_from_disk(self) -> None:
        """Fence-check, then adopt the on-disk manifest as truth. Called at
        segment start AND before every seal: a ``LogCompactor`` may have
        rewritten the manifest (new bases, floor-trimmed segments) between
        this writer's appends, and a stale cached view would resurrect
        segments whose files were already unlinked."""
        self._check_fence()
        doc = _load_manifest_doc(self.dir, self.name)
        self.segments = [Segment(**s) for s in doc.get("segments", [])]
        self.bases = list(doc.get("bases", []))

    # -- append path --
    def append(self, tick: int, events: Optional[QueryEvents],
               tweets: Optional[TweetBatch]) -> None:
        """Append one tick's micro-batches. Ticks must be increasing."""
        if self._dead:
            raise RuntimeError("writer was killed (failure injection)")
        if not self._buf:
            # segment start: re-sync from the on-disk manifest. A standby
            # replica's writer may have been constructed long before it won
            # leadership (ReplicaGroup.log_append failover); without the
            # re-sync its stale cached view would both accept duplicate
            # ticks and rewrite the manifest without the old leader's
            # segments. One small json read per segment — which doubles as
            # the fencing read: a zombie is rejected before it buffers.
            self._sync_from_disk()
        tick = int(tick)
        last = self.last_tick
        if last is not None and tick <= last:
            raise ValueError(f"non-monotonic append: tick {tick} <= {last}")
        rec = _record_arrays(tick, events, tweets)
        if self._buf and any(
                rec[k].shape != self._buf[-1][k].shape for k in _LANES[1:]):
            self.flush()   # shape change: rotate so segments stay stackable
        self._buf.append(rec)
        self._buf_ticks.append(tick)
        if len(self._buf) >= self.ticks_per_segment:
            self.flush()

    def _serialize_buffer(self) -> Tuple[bytes, str, Dict]:
        """The segment wire format, shared with the failure injector (one
        definition — torn-tail tests must tear exactly what flush writes).
        Returns (encoded blob, final segment file name, codec info)."""
        payload = {k: np.stack([r[k] for r in self._buf]) for k in _LANES}
        blob, info = encode_payload(payload, codec=self.codec)
        fname = _FMT.format(name=self.name, first=self._buf_ticks[0],
                            last=self._buf_ticks[-1])
        return blob, fname, info

    def flush(self) -> Optional[Segment]:
        """Seal the buffered ticks as one segment (atomic rename).

        Fenced: the manifest epoch is re-read first — a zombie ex-leader's
        seal raises :class:`WriterFencedError` before any bytes land."""
        if not self._buf:
            return None
        self._sync_from_disk()
        blob, fname, info = self._serialize_buffer()
        digest = hashlib.sha256(blob).hexdigest()
        fd, tmp = tempfile.mkstemp(dir=self.dir,
                                   prefix=f".tmp_{self.name}_seg_")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, os.path.join(self.dir, fname))
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        seg = Segment(fname, self._buf_ticks[0], self._buf_ticks[-1],
                      len(self._buf), digest, codec=info["codec"],
                      raw_sha256=info.get("raw_sha256"))
        self.segments.append(seg)
        self._buf, self._buf_ticks = [], []
        self._write_manifest()
        self._retain()
        return seg

    def close(self) -> None:
        self.flush()

    # -- manifest + retention --
    def _write_manifest(self) -> None:
        doc = {"name": self.name, "version": 1, "epoch": self.epoch,
               "segments": [dataclasses.asdict(s) for s in self.segments],
               "bases": self.bases}
        fd, tmp = tempfile.mkstemp(dir=self.dir,
                                   prefix=f".tmp_{self.name}_man_")
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, self._manifest_path())

    def _retain(self) -> None:
        if self.keep_segments <= 0 or len(self.segments) <= self.keep_segments:
            return
        n_drop = len(self.segments) - self.keep_segments
        floor = newest_base_tick(self.bases)
        if floor is not None:
            # Guard: with a compaction base advertised, replay starts at the
            # base tick — a segment holding any tick >= the newest base is
            # load-bearing for replay-from-base and must never be trimmed by
            # blunt keep-N retention (segments are tick-ordered, so the
            # droppable ones form a prefix). Warn-and-clamp rather than
            # raise: the leader's append path must keep the hose moving.
            safe = sum(1 for s in self.segments if s.last < floor)
            if n_drop > safe:
                warnings.warn(
                    f"keep_segments={self.keep_segments} would trim "
                    f"{n_drop - safe} segment(s) at/after the newest "
                    f"compaction base (tick {floor}) of log "
                    f"'{self.name}'; keeping them — rely on the "
                    f"LogCompactor's floor-based retention instead",
                    RuntimeWarning, stacklevel=2)
                n_drop = safe
        if n_drop <= 0:
            return
        drop, self.segments = (self.segments[:n_drop],
                               self.segments[n_drop:])
        self._write_manifest()   # readers stop seeing them first
        for seg in drop:
            try:
                os.unlink(os.path.join(self.dir, seg.file))
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Failure injection (the bench/test harness "kills" the writer mid-segment).
# ---------------------------------------------------------------------------

def kill_writer_mid_segment(writer: FirehoseLogWriter,
                            torn_fraction: float = 0.5) -> Optional[str]:
    """Simulate a writer crash mid-segment write.

    The buffered (unsealed) ticks are flushed as a TORN segment: a partial
    npz byte prefix written directly at its final name, never recorded in
    the manifest — what a crashed non-atomic writer leaves behind. The
    writer is dead afterwards (appends raise). Returns the torn file name
    (None if the buffer was empty — the crash then tore nothing).
    """
    fname = None
    if writer._buf:
        blob, fname, _info = writer._serialize_buffer()
        n = max(1, int(len(blob) * torn_fraction))
        with open(os.path.join(writer.dir, fname), "wb") as f:
            f.write(blob[:n])
        writer._buf, writer._buf_ticks = [], []
    writer._dead = True
    return fname


def slow_io(obj, methods: Tuple[str, ...], delay_s: float):
    """Latency injector: wrap the named bound methods of ``obj`` so each
    call first sleeps ``delay_s`` (a degraded disk / network filesystem).

    Chaos-harness companion to :func:`kill_writer_mid_segment` /
    :func:`corrupt_segment`: those test crash recovery, this tests the
    overload ladder — slow segment seals inflate step latency, which the
    SLO tracker must absorb by batching/shedding instead of stalling the
    hose. Works on any object (writers, readers, checkpoint managers).
    Returns ``obj``; restore by calling the returned undo callable kept at
    ``obj._slow_io_undo`` (last injection wins).
    """
    import time as _time
    originals = [(m, getattr(obj, m)) for m in methods]

    def _wrap(fn):
        def slowed(*a, **kw):
            _time.sleep(delay_s)
            return fn(*a, **kw)
        return slowed

    for m, fn in originals:
        setattr(obj, m, _wrap(fn))

    def undo():
        for m, fn in originals:
            setattr(obj, m, fn)

    obj._slow_io_undo = undo
    return obj


def flaky_io(obj, methods: Tuple[str, ...], n_failures: int = 1,
             exc=OSError):
    """Transient-fault injector: wrap the named bound methods of ``obj`` so
    the first ``n_failures`` calls (counted across all wrapped methods)
    raise ``exc`` before the real call runs — an NFS hiccup / EINTR-style
    blip rather than ``slow_io``'s latency or ``corrupt_segment``'s
    permanent damage. The reader's bounded retry must absorb these.
    Returns ``obj``; restore via ``obj._flaky_io_undo`` (last wins)."""
    originals = [(m, getattr(obj, m)) for m in methods]
    budget = {"left": int(n_failures), "raised": 0}

    def _wrap(fn):
        def flaked(*a, **kw):
            if budget["left"] > 0:
                budget["left"] -= 1
                budget["raised"] += 1
                raise exc("injected transient I/O failure")
            return fn(*a, **kw)
        return flaked

    for m, fn in originals:
        setattr(obj, m, _wrap(fn))

    def undo():
        for m, fn in originals:
            setattr(obj, m, fn)

    obj._flaky_io_undo = undo
    obj._flaky_io_stats = budget
    return obj


def corrupt_segment(directory: str, seg: Segment,
                    keep_fraction: float = 0.5) -> None:
    """Truncate a sealed segment's bytes in place (torn write on a
    non-atomic filesystem). The reader's checksum pass must drop it and
    everything after it."""
    path = os.path.join(directory, seg.file)
    with open(path, "rb") as f:
        blob = f.read()
    with open(path, "wb") as f:
        f.write(blob[: max(1, int(len(blob) * keep_fraction))])


# ---------------------------------------------------------------------------
# Reader
# ---------------------------------------------------------------------------

def _manifest_path(directory: str, name: str) -> str:
    return os.path.join(directory, f"{name}-MANIFEST.json")


def _load_manifest_doc(directory: str, name: str) -> Dict:
    """The full manifest document (segments + leadership epoch)."""
    path = _manifest_path(directory, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def _load_manifest(directory: str, name: str) -> List[Segment]:
    return [Segment(**s)
            for s in _load_manifest_doc(directory, name).get("segments", [])]


def log_epoch(directory: str, name: str = "firehose") -> int:
    """The current leadership epoch recorded in the log manifest."""
    return int(_load_manifest_doc(directory, name).get("epoch", 0))


def log_bases(directory: str, name: str = "firehose") -> List[Dict]:
    """The compaction bases advertised in the log manifest (tick order)."""
    return list(_load_manifest_doc(directory, name).get("bases", []))


class FirehoseLogReader:
    """Seek-by-tick reader with torn-tail truncation.

    ``refresh()`` re-validates the manifest against the files on disk:
    segments are accepted in order while their bytes verify (sha256); the
    first bad/missing segment truncates the readable log there. Files at
    segment names that the manifest does not list (a crashed writer's torn
    tail) are counted and ignored.

    Transient I/O errors (an NFS blip mid-replay) are absorbed by a
    bounded retry-with-backoff around every segment read: up to
    ``io_retries`` re-reads, sleeping ``io_backoff_s * 2**attempt`` between
    attempts (``n_io_retries`` counts them). Only after the budget is
    exhausted does the error surface — as a bad segment during
    verification (truncating the readable log there, same as corruption)
    or as the raised ``OSError`` during a chunk read.
    """

    def __init__(self, directory: str, name: str = "firehose",
                 verify: bool = True, io_retries: int = 2,
                 io_backoff_s: float = 0.005):
        self.dir = directory
        self.name = name
        self.verify = verify
        self.io_retries = int(io_retries)
        self.io_backoff_s = float(io_backoff_s)
        self.segments: List[Segment] = []
        self.bases: List[Dict] = []     # compaction bases (replay floors)
        self.n_truncated_segments = 0   # manifested but failed verification
        self.n_unmanifested_files = 0   # torn tail beyond the manifest
        self.n_io_retries = 0           # transient read errors absorbed
        self.refresh()

    def refresh(self) -> "FirehoseLogReader":
        if not os.path.isdir(self.dir):
            # no log yet (e.g. a frontend starting before the backend's
            # writer): an empty log, not an error
            self.segments = []
            self.bases = []
            self.n_truncated_segments = self.n_unmanifested_files = 0
            return self
        doc = _load_manifest_doc(self.dir, self.name)
        self.bases = list(doc.get("bases", []))
        manifested = [Segment(**s) for s in doc.get("segments", [])]
        good: List[Segment] = []
        for seg in manifested:
            path = os.path.join(self.dir, seg.file)
            if not os.path.exists(path) or not self._ok(path, seg):
                break
            good.append(seg)
        self.n_truncated_segments = len(manifested) - len(good)
        self.segments = good
        listed = {s.file for s in manifested}
        self.n_unmanifested_files = sum(
            1 for f in os.listdir(self.dir)
            if _SEG_RE.match(f) and _SEG_RE.match(f).group("name") == self.name
            and f not in listed)
        return self

    def _read_bytes(self, path: str) -> bytes:
        """The one raw segment read (injection point for ``flaky_io``)."""
        with open(path, "rb") as f:
            return f.read()

    def _read_bytes_retry(self, path: str) -> bytes:
        """Bounded retry-with-backoff over ``_read_bytes``: a transient
        hiccup must not surface as a hard replay failure."""
        import time as _time
        for attempt in range(self.io_retries + 1):
            try:
                return self._read_bytes(path)
            except OSError:
                if attempt >= self.io_retries:
                    raise
                self.n_io_retries += 1
                if self.io_backoff_s > 0:
                    _time.sleep(self.io_backoff_s * (2 ** attempt))
        raise AssertionError("unreachable")

    def _ok(self, path: str, seg: Segment) -> bool:
        if not self.verify:
            return True
        try:
            blob = self._read_bytes_retry(path)
        except OSError:
            return False
        return hashlib.sha256(blob).hexdigest() == seg.sha256

    # -- seek info --
    def first_tick(self) -> Optional[int]:
        return self.segments[0].first if self.segments else None

    def last_tick(self) -> Optional[int]:
        return self.segments[-1].last if self.segments else None

    def floor_tick(self) -> Optional[int]:
        """Newest advertised compaction base tick (replay floor), or None."""
        return newest_base_tick(self.bases)

    def newest_base(self, max_tick: Optional[int] = None) -> Optional[Dict]:
        """The newest base entry whose tick is ≤ ``max_tick`` (None = any):
        the cheapest legitimate replay start for a target at that tick."""
        cands = [b for b in self.bases
                 if max_tick is None or int(b["tick"]) <= int(max_tick)]
        return max(cands, key=lambda b: int(b["tick"])) if cands else None

    # -- reads --
    def _load_segment(self, seg: Segment) -> LogChunk:
        blob = self._read_bytes_retry(os.path.join(self.dir, seg.file))
        payload, _info = decode_payload(blob)
        return LogChunk(**{k: payload[k] for k in _LANES})

    def read_chunks(self, from_tick: int, chunk_ticks: Optional[int] = None,
                    upto_tick: Optional[int] = None) -> Iterator[LogChunk]:
        """Yield stacked chunks covering ticks in [from_tick, upto_tick).

        Without ``chunk_ticks``, yields one chunk per segment (sliced at the
        seek point). With it, re-chunks across segment boundaries into
        uniform ``chunk_ticks``-sized stacks (plus a final remainder) so the
        replay step compiles for at most two distinct shapes.
        """
        pend: Optional[LogChunk] = None
        for seg in self.segments:
            if seg.last < from_tick:
                continue
            if upto_tick is not None and seg.first >= upto_tick:
                break
            chunk = self._load_segment(seg)
            m = chunk.ticks >= from_tick
            if upto_tick is not None:
                m &= chunk.ticks < upto_tick
            if not m.all():
                chunk = LogChunk(*(a[m] for a in chunk))
            if chunk.n_ticks == 0:
                continue
            if chunk_ticks is None:
                yield chunk
                continue
            if pend is not None:
                # merge only consecutive, shape-compatible ticks: a chunk
                # must never hide a tick gap inside it (replay decides per
                # chunk whether skipping a gap is allowed)
                if (int(pend.ticks[-1]) + 1 == int(chunk.ticks[0])
                        and all(p.shape[1:] == c.shape[1:]
                                for p, c in zip(pend, chunk))):
                    chunk = LogChunk(*(np.concatenate([p, c])
                                       for p, c in zip(pend, chunk)))
                else:          # gap or shape break: emit what we have
                    yield pend
                pend = None
            off = 0
            while chunk.n_ticks - off >= chunk_ticks:
                yield LogChunk(*(a[off:off + chunk_ticks] for a in chunk))
                off += chunk_ticks
            if off < chunk.n_ticks:
                pend = LogChunk(*(a[off:] for a in chunk))
        if pend is not None:
            yield pend

    def read_ticks(self, from_tick: int, upto_tick: Optional[int] = None
                   ) -> Iterator[Tuple[int, Optional[QueryEvents],
                                       Optional[TweetBatch]]]:
        """Per-tick view (live-rate handoff / reference comparisons)."""
        for chunk in self.read_chunks(from_tick, upto_tick=upto_tick):
            for i in range(chunk.n_ticks):
                yield (int(chunk.ticks[i]), chunk.query_events(i),
                       chunk.tweet_batch(i))

    def repair(self) -> int:
        """Delete THIS log's torn-tail debris (unmanifested segment files
        + its name-scoped tmp scratch) so a restarted writer starts clean.
        Never touches other named logs sharing the directory — their
        writer may hold a tmp file mid-seal. Returns #files."""
        if not os.path.isdir(self.dir):
            return 0
        listed = {s.file for s in _load_manifest(self.dir, self.name)}
        n = 0
        for f in os.listdir(self.dir):
            m = _SEG_RE.match(f)
            torn = (m and m.group("name") == self.name and f not in listed)
            if torn or f.startswith(f".tmp_{self.name}_"):
                try:
                    os.unlink(os.path.join(self.dir, f))
                    n += 1
                except OSError:
                    pass
        self.refresh()
        return n
