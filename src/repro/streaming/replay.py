"""Catch-up replay: restore a snapshot, rewind into the log, outrun time.

Paper §4.2: "since the stores are memory-resident, their contents do not
survive restarts ... a (re)started instance can rewind to an earlier point
in the hose and consume messages at a faster rate than real time to catch
up to the present; in the meantime, the frontends serve the most recently
persisted results". This module is that loop:

  1. **restore** the newest ``EngineState`` snapshot — a
     ``CheckpointManager`` checkpoint whose manifest records the log offset
     (``log_tick``) replay must resume from;
  2. **replay** the firehose-log tail *faster than real time*: chunks of
     stacked micro-batches go through the fused ``engine.ingest_many``
     ``lax.scan`` step — one device dispatch per chunk, no per-tick host
     sync. Replay-mode overrides: ranking cycles are suppressed while the
     lag to the log head is >= ``rank_lag_ticks`` (the frontend is serving
     stale tables anyway), while the decay/prune maintenance keeps its
     exact live cadence inside the scan (state equality depends on it);
  3. **hand off** to live ingestion once caught up (and run the rank cycle
     the live engine would have been due for).

Replayed state is bit-for-bit identical to an uninterrupted run (tested at
every segment boundary), exact under the lazy/exponential decay policy.

**Whole-stack recovery** (:func:`recover_service`): the serving stack is
rt engine + background engine + interpolation cache (``core.background``);
both engines consume the same hose, so one durable log serves both. Each
engine restores from its *own* snapshot chain (its own log offset) and
replays the shared tail under its *own* cadence authority — the fused
``ingest_many`` scan takes the engine's config, so the bg engine's slow
decay/prune cadences replay exactly as they would have run live. Ranking
stays suppressed per engine until that engine's lag clears.

**Snapshot chains + fallback** (``distributed.fault_tolerance``): a
snapshot step may be a *delta* (changed slots only) chained to the last
full snapshot via its manifest (``kind``/``base_step``/``sha256``). The
restore chain-walk verifies every member; a torn or corrupt delta falls
back to the newest intact full — recovery then simply resumes replay from
that older snapshot's ``log_tick``, i.e. a broken chain costs a longer
replay tail, never a failed recovery (as long as one full verifies and the
log retains the tail). ``stats["restore"]`` records the fallback.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..core.engine import (EngineConfig, SearchAssistanceEngine, TickStack)
from ..core.hashing import split_fp
from ..distributed.fault_tolerance import CheckpointManager
from .log import FirehoseLogReader, LogChunk


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    chunk_ticks: int = 16       # ticks fused into one ingest_many dispatch
    rank_lag_ticks: int = 4     # resume ranking once lag drops below this
    allow_gap: bool = False     # snapshot older than log retention: skip
                                # to the log start (documented state loss)
                                # instead of raising


def chunk_to_stack(chunk: LogChunk) -> TickStack:
    """Host log chunk -> device TickStack (u64 fps split into u32 lanes)."""
    s_hi, s_lo = split_fp(chunk.sess_fp)
    q_hi, q_lo = split_fp(chunk.q_fp)
    g_hi, g_lo = split_fp(chunk.grams)
    return TickStack(
        sess_hi=jnp.asarray(s_hi), sess_lo=jnp.asarray(s_lo),
        q_hi=jnp.asarray(q_hi), q_lo=jnp.asarray(q_lo),
        src=jnp.asarray(chunk.src, jnp.int32),
        q_valid=jnp.asarray(chunk.q_valid),
        g_hi=jnp.asarray(g_hi), g_lo=jnp.asarray(g_lo),
        t_valid=jnp.asarray(chunk.t_valid))


class CatchUpController:
    """Drives one engine from its restored offset to the log head."""

    def __init__(self, engine: SearchAssistanceEngine,
                 reader: FirehoseLogReader,
                 rcfg: ReplayConfig = ReplayConfig()):
        self.engine = engine
        self.reader = reader
        self.rcfg = rcfg

    def catch_up(self, target_tick: Optional[int] = None,
                 refresh: bool = True) -> Dict:
        """Replay [engine.tick, target) from the log; default target is one
        past the log head. Returns replay stats (ticks, chunks, wall time,
        suppressed/run rank cycles, events replayed). ``refresh=False``
        skips re-validating the log (pass it when the reader was freshly
        constructed — its ``__init__`` already checksummed every segment,
        and doing it twice doubles the restart-critical disk pass)."""
        eng, rcfg = self.engine, self.rcfg
        if refresh:
            self.reader.refresh()
        start = int(eng.state.tick)
        head = self.reader.last_tick()
        end = target_tick if target_tick is not None else (
            head + 1 if head is not None else start)
        stats = {"start_tick": start, "end_tick": end, "n_ticks": 0,
                 "n_chunks": 0, "n_events": 0, "n_rank_suppressed": 0,
                 "n_rank_run": 0, "n_skipped_gap_ticks": 0, "wall_s": 0.0}
        t0 = time.perf_counter()
        rank_every = eng.cfg.rank_every
        if end > start:
            first = self.reader.first_tick()
            if first is not None and first > start:
                if not rcfg.allow_gap:
                    raise ValueError(
                        f"snapshot at tick {start} predates log retention "
                        f"(log starts at {first}); pass allow_gap to skip "
                        f"ahead")
                stats["n_skipped_gap_ticks"] = first - start
                eng.state = eng.state._replace(tick=jnp.int32(first))
                start = first
            for chunk in self.reader.read_chunks(start, rcfg.chunk_ticks,
                                                 upto_tick=end):
                # a chunk is normally one consecutive run; tick holes (a
                # crash tore ticks a newer snapshot had covered, or the
                # writer skipped ticks) split it into runs, each replayed
                # after an allow_gap fast-forward — skipping is safe-but-
                # lossy (§4.2: losing a little state is tolerable)
                tks = chunk.ticks
                breaks = np.nonzero(tks[1:] - tks[:-1] != 1)[0] + 1
                n_due = 0
                for run in np.split(np.arange(tks.shape[0]), breaks):
                    sub = (chunk if len(run) == tks.shape[0]
                           else LogChunk(*(a[run] for a in chunk)))
                    expect = int(eng.state.tick)
                    gap = int(sub.ticks[0]) - expect
                    if gap < 0 or (gap > 0 and not rcfg.allow_gap):
                        raise ValueError(
                            f"log gap: replay expected tick {expect}, run "
                            f"covers [{int(sub.ticks[0])}, "
                            f"{int(sub.ticks[-1])}]"
                            + ("" if gap < 0 else "; pass allow_gap to "
                               "skip the missing ticks"))
                    if gap > 0:
                        stats["n_skipped_gap_ticks"] += gap
                        eng.state = eng.state._replace(
                            tick=jnp.int32(int(sub.ticks[0])))
                    eng.step_many(chunk_to_stack(sub))
                    stats["n_ticks"] += sub.n_ticks
                    stats["n_events"] += int(sub.q_valid.sum()) \
                        + int(sub.t_valid.sum())
                    # rank boundaries crossed (tick t ranks after ingesting
                    # t, i.e. t in [run.first, run.last])
                    n_due += sum(
                        1 for t in range(int(sub.ticks[0]),
                                         int(sub.ticks[-1]) + 1)
                        if rank_every > 0 and t > 0
                        and t % rank_every == 0)
                stats["n_chunks"] += 1
                if rank_every > 0:
                    lag = end - int(eng.state.tick)
                    if lag >= rcfg.rank_lag_ticks:
                        stats["n_rank_suppressed"] += n_due
                    elif n_due:
                        # caught up enough: serve fresh tables from here on
                        eng.run_rank_cycle()
                        stats["n_rank_run"] += 1
                        stats["n_rank_suppressed"] += n_due - 1
        # handoff: if no cycle ran at the head, run one now so the frontend
        # gets fresh tables immediately (rank cycles read state, never
        # mutate it — running extra ones cannot break replay exactness).
        # This must also cover the 0-tick replay case: a snapshot can be
        # newer than the log's surviving tail (the torn segment held the
        # ticks between them) and the restored stores still deserve tables;
        # repeated catch-up calls on an already-fresh engine stay no-ops.
        if rank_every > 0 and stats["n_rank_run"] == 0 \
                and (stats["n_ticks"] > 0 or not eng.suggestions):
            eng.run_rank_cycle()
            stats["n_rank_run"] += 1
        stats["wall_s"] = time.perf_counter() - t0
        return stats


def _check_snapshot_layout(cfg: EngineConfig, ckpt: CheckpointManager,
                           step: Optional[int]) -> None:
    try:
        meta = ckpt.manifest(step).get("meta", {})
    except FileNotFoundError:
        raise                      # no checkpoints at all: fail loudly
    except (OSError, json.JSONDecodeError):
        # torn/garbled manifest: leave it to the restore chain walk, which
        # falls back to the newest intact full instead of failing here
        return
    snap_layout = meta.get("layout")
    if snap_layout is not None and snap_layout != cfg.cooc_layout:
        raise ValueError(
            f"snapshot was written under cooc_layout={snap_layout!r} but "
            f"the restoring config uses {cfg.cooc_layout!r}; region "
            f"metadata (chain directory, fills, freelist) is part of the "
            f"checkpoint and cannot be reinterpreted")


def _maybe_restore_base(engine: SearchAssistanceEngine,
                        reader: FirehoseLogReader,
                        target_tick: Optional[int]) -> Optional[Dict]:
    """Tiered restore: when the log manifest advertises a compaction base
    NEWER than the engine's current offset (and ≤ the replay target), jump
    the engine onto it before replaying. This is what keeps replay-from-
    zero alive under compaction — the log below the floor may no longer
    exist on disk — and even when it does, the base is the cheaper
    legitimate start. A torn newest base transparently falls back to an
    older retained one (``info['fell_back']``); no usable base at all
    leaves the engine untouched (the pre-compaction gap rules apply)."""
    if not reader.bases:
        return None
    from .compaction import restore_from_base   # lazy: avoids import cycle
    head = reader.last_tick()
    end = target_tick if target_tick is not None else (
        head + 1 if head is not None else None)
    res = restore_from_base(reader.dir, engine.name, engine.state,
                            max_tick=end, log_name=reader.name)
    if res is None:
        return None
    state, tick, info = res
    if tick <= int(engine.state.tick):
        return None         # own snapshot is fresher than any base
    engine.state = state
    return dict(info, base_tick=tick)


def _restore_and_catch_up(cfg: EngineConfig, ckpt: CheckpointManager,
                          reader: FirehoseLogReader,
                          rcfg: ReplayConfig, name: str,
                          target_tick: Optional[int],
                          step: Optional[int]) -> tuple:
    """Restore one engine (fresh when no snapshot exists — cold engines
    replay the whole retained log, hopping onto the newest compaction base
    first when one is advertised) and replay its tail from the shared,
    already-validated reader."""
    if step is None and ckpt.latest_step() is None:
        engine, log_tick = SearchAssistanceEngine(cfg, name), None
    else:
        _check_snapshot_layout(cfg, ckpt, step)
        engine, log_tick = SearchAssistanceEngine.restore_from_snapshot(
            cfg, ckpt, step=step, name=name)
        assert int(engine.state.tick) == log_tick, "snapshot offset mismatch"
    restore_info = dict(ckpt.last_restore)
    base_info = _maybe_restore_base(engine, reader, target_tick)
    stats = CatchUpController(engine, reader, rcfg).catch_up(target_tick,
                                                             refresh=False)
    stats["restored_step"] = log_tick
    stats["restore"] = restore_info
    stats["base"] = base_info
    return engine, stats


def recover_engine(cfg: EngineConfig, ckpt: CheckpointManager, log_dir: str,
                   rcfg: ReplayConfig = ReplayConfig(), name: str = "rt",
                   log_name: str = "firehose",
                   target_tick: Optional[int] = None,
                   step: Optional[int] = None
                   ) -> tuple:
    """The full crash-recovery path: snapshot restore + catch-up replay.

    Returns ``(engine, stats)``; the engine is caught up to the log head
    (or ``target_tick``) and ready for live ingestion. ``step`` picks a
    specific snapshot (default: the newest). The restore walks the
    snapshot's delta chain; a torn/corrupt chain member silently falls
    back to the newest intact full snapshot (``stats["restore"]``) and the
    replay tail grows to cover the difference. Under log compaction, a
    base newer than the restored snapshot is hopped onto before replay
    (``stats["base"]``) — mandatory when the log tail below the floor was
    trimmed, cheaper even when it was not.
    """
    _check_snapshot_layout(cfg, ckpt, step)
    engine, log_tick = SearchAssistanceEngine.restore_from_snapshot(
        cfg, ckpt, step=step, name=name)
    assert int(engine.state.tick) == log_tick, "snapshot offset mismatch"
    reader = FirehoseLogReader(log_dir, name=log_name)
    restore_info = dict(ckpt.last_restore)
    base_info = _maybe_restore_base(engine, reader, target_tick)
    stats = CatchUpController(engine, reader, rcfg).catch_up(target_tick,
                                                             refresh=False)
    stats["restored_step"] = log_tick
    stats["restore"] = restore_info
    stats["base"] = base_info
    return engine, stats


def recover_service(rt_cfg: EngineConfig, rt_ckpt: CheckpointManager,
                    bg_ckpt: CheckpointManager, log_dir: str,
                    rcfg: ReplayConfig = ReplayConfig(), *,
                    bg_cfg: Optional[EngineConfig] = None,
                    alpha: float = 0.7, log_name: str = "firehose",
                    target_tick: Optional[int] = None,
                    rt_step: Optional[int] = None,
                    bg_step: Optional[int] = None) -> tuple:
    """Crash-recover the WHOLE serving stack (rt + bg + interpolation).

    Restores the real-time and background engines from their respective
    snapshot directories (each records its own ``log_tick`` offset) and
    replays the shared firehose-log tail for each — the bg engine reuses
    the same fused ``ingest_many`` scan under *its* cadence authority
    (slow decay/prune cadences replay exactly as live), with ranking
    suppressed per-engine until that engine's lag clears; each engine
    ranks at its own handoff. An engine with no snapshot yet (crash before
    its first persist) cold-starts and replays the whole retained log.
    Finally the interpolation cache is rebuilt from both fresh tables.

    Returns ``(service, stats)`` with per-engine stats under ``stats["rt"]``
    and ``stats["bg"]``. The result is bit-exact vs. an uninterrupted
    service run (property-tested at every log-segment boundary).
    """
    from ..core.background import AssistanceService, background_config
    bg_cfg = bg_cfg if bg_cfg is not None else background_config(rt_cfg)
    # ONE reader validates the log once; both engines replay from it.
    reader = FirehoseLogReader(log_dir, name=log_name)
    rt_eng, rt_stats = _restore_and_catch_up(
        rt_cfg, rt_ckpt, reader, rcfg, "rt", target_tick, rt_step)
    bg_eng, bg_stats = _restore_and_catch_up(
        bg_cfg, bg_ckpt, reader, rcfg, "bg", target_tick, bg_step)
    service = AssistanceService(rt_cfg, alpha=alpha, bg_cfg=bg_cfg,
                                rt=rt_eng, bg=bg_eng)
    service.refresh_cache()
    return service, {"rt": rt_stats, "bg": bg_stats}
