"""Firehose workload generator — millions-of-users traffic shapes (§1, §2).

The paper's whole reason to exist is the breaking-news flash crowd: query
volume spikes 10-100x within minutes and the system must stay fresh *and*
stay up. ``data/stream.py`` models the *statistical* structure (Zipf,
sessions, hockey-puck events) at a fixed per-tick volume; this module
models the *load* structure on top of it, as the standard bench/chaos
harness for the overload-control layer (``streaming/overload.py``):

  * **Zipf base traffic with topic drift** — per-topic popularity drifts
    smoothly over time (deterministic per-topic phase curves), so the head
    of the distribution churns the way §2.3 measures;
  * **breaking-news flash crowds** (:class:`SpikeSpec`) — a hockey-puck
    *volume multiplier* (10-100x the base event rate), with the added
    traffic focused on a small set of event terms (Figure 1's shape);
  * **spam bursts** (:class:`SpamSpec`) — periodic bursts of near-identical
    payload queries/tweets from a small pool of bot sessions (the traffic
    the paper's rate-limiting stance exists for);
  * **multilingual sessions** — disjoint per-language vocabularies; each
    user sticks to one language, so sessions never mix languages and the
    cooccurrence signal stays language-local.

Volume scaling is *physical*: a tick's arrays are sized to a power-of-
``bucket_factor`` bucket that fits the tick's event count (valid-masked
padding), so a 50x spike really costs ~50x device work — which is what
makes overload, admission control and shedding measurable instead of
cosmetic. The small bucket alphabet keeps the compiled-shape count bounded
for the fused ``ingest_many`` replay/micro-batch paths.

``gen_tick(t)`` is a pure function of ``(seed, t)``: any tick can be
regenerated independently (replay comparisons, chaos schedules that revisit
ticks), and two generators with the same seed agree tick for tick.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..data.stream import QueryEvents, TweetBatch
from ..data.tokenizer import NGramTokenizer

_WORDS = [
    "news", "video", "live", "score", "game", "music", "photo", "trend",
    "world", "tech", "movie", "series", "stream", "update", "launch", "team",
]


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized), output != 0."""
    x = np.asarray(x, np.uint64).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return np.where(x == 0, np.uint64(1), x)


def bucket_size(n: int, min_bucket: int, max_bucket: int,
                factor: int = 4) -> int:
    """Smallest power-of-``factor`` multiple of ``min_bucket`` >= n,
    clamped to ``max_bucket``. The coarse (factor-4 by default) alphabet
    bounds how many distinct micro-batch shapes the jitted ingest paths
    ever compile for, spike or no spike."""
    b = max(min_bucket, 1)
    while b < n and b < max_bucket:
        b *= factor
    return min(b, max_bucket)


@dataclasses.dataclass(frozen=True)
class SpikeSpec:
    """A breaking-news flash crowd: a volume spike focused on few terms."""
    t_start: int
    mult: float = 50.0            # added query volume at peak, x base rate
    ramp_ticks: float = 3.0       # rise time constant (§2.2 hockey puck)
    plateau_ticks: float = 10.0   # time near peak
    decay_ticks: float = 12.0     # die-off constant
    focus: float = 0.7            # share of spike traffic on event terms
    n_terms: int = 5              # distinct breaking terms
    term_lag: float = 2.0         # per-term onset lag (Figure 1)


@dataclasses.dataclass(frozen=True)
class SpamSpec:
    """Periodic near-duplicate payload bursts from a small bot pool."""
    period: int = 29              # a burst starts every ``period`` ticks
    burst_ticks: int = 3
    mult: float = 2.0             # added volume during a burst, x base rate
    n_payloads: int = 4           # distinct spam strings per burst
    n_bots: int = 8               # bot sessions emitting them


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    vocab_per_lang: int = 1024
    n_langs: int = 3              # multilingual: disjoint vocabularies
    zipf_s: float = 1.07
    n_topics: int = 32
    drift_scale: float = 0.6      # log-amplitude of topic-popularity drift
    drift_period: float = 96.0    # slowest drift period, in ticks
    n_users: int = 50_000
    session_ticks: int = 24       # session epoch length
    topic_stickiness: float = 0.7
    base_queries_per_tick: int = 256
    base_tweets_per_tick: int = 32
    tweet_words: int = 4
    tweet_grams: int = 8
    min_bucket: int = 256         # smallest query-array bucket
    max_queries_per_tick: int = 1 << 14   # hard array cap (bucket ceiling)
    min_tweet_bucket: int = 32
    max_tweets_per_tick: int = 1 << 11
    bucket_factor: int = 4
    tick_seconds: float = 10.0    # one tick of simulated wall time
    source_probs: Tuple[float, float, float] = (0.70, 0.22, 0.08)
    spikes: Tuple[SpikeSpec, ...] = ()
    spam: Optional[SpamSpec] = None


class FirehoseWorkload:
    """Deterministic generator: ``gen_tick(t)`` is pure in ``(seed, t)``."""

    def __init__(self, cfg: WorkloadConfig, tok: Optional[NGramTokenizer] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.tok = tok or NGramTokenizer()
        self.seed = seed
        rr = np.random.default_rng(seed + 1)

        # --- per-language vocabularies (disjoint by a language prefix) ---
        self.vocab: List[str] = []
        self.lang_slice: List[slice] = []
        for lang in range(cfg.n_langs):
            start = len(self.vocab)
            seen = set()
            while len(self.vocab) - start < cfg.vocab_per_lang:
                w1 = _WORDS[rr.integers(len(_WORDS))]
                w2 = f"{_WORDS[rr.integers(len(_WORDS))]}{rr.integers(4000)}"
                q = f"l{lang} {w1} {w2}"
                if q not in seen:
                    seen.add(q)
                    self.vocab.append(q)
            self.lang_slice.append(slice(start, len(self.vocab)))
        self.fps = np.array([self.tok.query_fp(q) for q in self.vocab],
                            np.uint64)

        # Zipf base probabilities within each language + topic assignment
        ranks = np.arange(1, cfg.vocab_per_lang + 1, dtype=np.float64)
        self._zipf = ranks ** (-cfg.zipf_s)
        self._zipf /= self._zipf.sum()
        self.topic = rr.integers(0, cfg.n_topics,
                                 size=cfg.n_langs * cfg.vocab_per_lang)
        # topic drift: two incommensurate phase curves per topic
        self._ph = rr.random((2, cfg.n_topics))

        # --- spike event terms (language 0 — breaking news breaks in one) ---
        self.spike_terms: List[np.ndarray] = []
        for si, sp in enumerate(cfg.spikes):
            idx = []
            for k in range(sp.n_terms):
                term = f"breaking{si} term{k}"
                self.vocab.append(term)
                self.fps = np.append(self.fps, np.uint64(self.tok.query_fp(term)))
                idx.append(len(self.vocab) - 1)
            self.spike_terms.append(np.array(idx))

        # --- spam payload pool ---
        self.spam_idx = np.zeros((0,), np.int64)
        if cfg.spam is not None:
            idx = []
            for k in range(cfg.spam.n_payloads):
                term = f"win prize{k} now"
                self.vocab.append(term)
                self.fps = np.append(self.fps, np.uint64(self.tok.query_fp(term)))
                idx.append(len(self.vocab) - 1)
            self.spam_idx = np.array(idx)

    # ------------------------------------------------------------------
    # intensity model
    # ------------------------------------------------------------------
    def spike_mult(self, t: int) -> np.ndarray:
        """Per-spike added-volume multiplier at tick t (hockey puck)."""
        out = []
        for sp in self.cfg.spikes:
            dt = t - sp.t_start
            if dt < 0:
                out.append(0.0)
                continue
            rise = 1.0 - np.exp(-((dt / sp.ramp_ticks) ** 2))
            fall = np.exp(-max(0.0, dt - sp.plateau_ticks) / sp.decay_ticks)
            out.append(sp.mult * rise * fall)
        return np.array(out)

    def spam_mult(self, t: int) -> float:
        sp = self.cfg.spam
        if sp is None or (t % sp.period) >= sp.burst_ticks:
            return 0.0
        return sp.mult

    def volume_mult(self, t: int) -> float:
        """Total query-volume multiplier at tick t (1.0 = calm baseline)."""
        return float(1.0 + self.spike_mult(t).sum() + self.spam_mult(t))

    def arrival_s(self, t: int) -> float:
        """Simulated arrival time of tick t (for SLO pacing/lag)."""
        return t * self.cfg.tick_seconds

    def _topic_weights(self, t: int) -> np.ndarray:
        """Drifted per-topic popularity multipliers (smooth, deterministic)."""
        cfg = self.cfg
        ph = self._ph
        a = np.sin(2 * np.pi * (t / cfg.drift_period + ph[0]))
        b = np.sin(2 * np.pi * (t / (cfg.drift_period / 2.7) + ph[1]))
        return np.exp(cfg.drift_scale * (a + 0.5 * b))

    def _lang_probs(self, lang: int, t: int) -> np.ndarray:
        w = self._zipf * self._topic_weights(t)[
            self.topic[self.lang_slice[lang]]]
        return w / w.sum()

    def _spike_term_probs(self, si: int, t: int) -> np.ndarray:
        sp = self.cfg.spikes[si]
        dt = t - sp.t_start
        w = np.array([
            0.0 if dt < k * sp.term_lag else
            (2.0 if k == 0 else 1.0)
            * (1 - np.exp(-((dt - k * sp.term_lag + 1) / sp.ramp_ticks)))
            for k in range(sp.n_terms)])
        s = w.sum()
        return w / s if s > 0 else np.ones(sp.n_terms) / sp.n_terms

    # ------------------------------------------------------------------
    # tick generation
    # ------------------------------------------------------------------
    def gen_tick(self, t: int) -> Tuple[QueryEvents, TweetBatch]:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, 0xF1AE, t))
        spikes = self.spike_mult(t)
        spam_m = self.spam_mult(t)

        n_q = int(round(cfg.base_queries_per_tick
                        * (1.0 + spikes.sum() + spam_m)))
        B = bucket_size(n_q, cfg.min_bucket, cfg.max_queries_per_tick,
                        cfg.bucket_factor)
        n_q = min(n_q, B)

        # --- base traffic: multilingual topical sessions ---
        users = rng.integers(0, cfg.n_users, size=n_q)
        epoch = t // cfg.session_ticks
        with np.errstate(over="ignore"):
            sess = _mix64(users.astype(np.uint64)
                          * np.uint64(0x9E3779B97F4A7C15)
                          ^ np.uint64((epoch * 0xC2B2AE3D27D4EB4F)
                                      % (1 << 64)))
        lang = users % cfg.n_langs
        sess_topic = (users + epoch * 7919) % cfg.n_topics
        q_idx = np.zeros(n_q, np.int64)
        sticky = rng.random(n_q) < cfg.topic_stickiness
        for lg in range(cfg.n_langs):
            lm = lang == lg
            if not lm.any():
                continue
            p = self._lang_probs(lg, t)
            base = self.lang_slice[lg].start
            loose = lm & ~sticky
            if loose.any():
                q_idx[loose] = base + rng.choice(cfg.vocab_per_lang,
                                                 size=int(loose.sum()), p=p)
            for tpc in np.unique(sess_topic[lm & sticky]):
                m = lm & sticky & (sess_topic == tpc)
                pt = p * (self.topic[self.lang_slice[lg]] == tpc)
                s = pt.sum()
                pt = pt / s if s > 0 else p
                q_idx[m] = base + rng.choice(cfg.vocab_per_lang,
                                             size=int(m.sum()), p=pt)

        # --- flash crowd: overwrite the spike's share of the stream ---
        total_m = 1.0 + spikes.sum() + spam_m
        u = rng.random(n_q)
        cursor = 0.0
        for si, sm in enumerate(spikes):
            share = (sm / total_m) * self.cfg.spikes[si].focus
            pick = (u >= cursor) & (u < cursor + share)
            cursor += share
            if pick.any():
                tp = self._spike_term_probs(si, t)
                q_idx[pick] = self.spike_terms[si][
                    rng.choice(len(tp), size=int(pick.sum()), p=tp)]
        # --- spam burst: identical payloads from a small bot pool ---
        if spam_m > 0.0 and len(self.spam_idx):
            share = spam_m / total_m
            pick = (u >= cursor) & (u < cursor + share)
            cursor += share
            if pick.any():
                n = int(pick.sum())
                q_idx[pick] = rng.choice(self.spam_idx, size=n)
                bots = rng.integers(0, cfg.spam.n_bots, size=n)
                sess[pick] = _mix64(bots.astype(np.uint64)
                                    + np.uint64(0xBAD5EED))

        src = rng.choice(3, size=n_q, p=cfg.source_probs).astype(np.int32)
        ev = QueryEvents(
            sess_fp=_pad(sess, B), q_fp=_pad(self.fps[q_idx], B),
            src=_pad(src, B), valid=_valid(n_q, B))

        # --- tweets: over-index on breaking news, spam payload floods ---
        n_t = int(round(cfg.base_tweets_per_tick
                        * (1.0 + 2.0 * spikes.sum() + spam_m)))
        T = bucket_size(n_t, cfg.min_tweet_bucket, cfg.max_tweets_per_tick,
                        cfg.bucket_factor)
        n_t = min(n_t, T)
        W = cfg.tweet_words
        tw_idx = np.zeros((n_t, W), np.int64)
        tu = rng.random(n_t)
        cursor = 0.0
        assigned = np.zeros(n_t, bool)
        for si, sm in enumerate(spikes):
            share = min(2.0 * sm / max(total_m, 1.0), 0.9)
            pick = (~assigned) & (tu >= cursor) & (tu < cursor + share)
            cursor += share
            if pick.any():
                tp = self._spike_term_probs(si, t)
                tw_idx[pick] = self.spike_terms[si][
                    rng.choice(len(tp), size=(int(pick.sum()), W), p=tp)]
                assigned |= pick
        if spam_m > 0.0 and len(self.spam_idx):
            share = min(spam_m / total_m, 0.9 - cursor)
            pick = (~assigned) & (tu >= cursor) & (tu < cursor + share)
            if pick.any():   # a flood of the SAME payload
                tw_idx[pick] = rng.choice(self.spam_idx)
                assigned |= pick
        rest = ~assigned
        if rest.any():
            lgs = rng.integers(0, cfg.n_langs, size=int(rest.sum()))
            picks = np.empty((int(rest.sum()), W), np.int64)
            for i, lg in enumerate(lgs):
                picks[i] = self.lang_slice[lg].start + rng.choice(
                    cfg.vocab_per_lang, size=W, p=self._lang_probs(lg, t))
            tw_idx[rest] = picks
        grams = np.zeros((T, cfg.tweet_grams), np.uint64)
        g = min(W, cfg.tweet_grams)
        grams[:n_t, :g] = self.fps[tw_idx[:, :g]]
        tw = TweetBatch(grams=grams, valid=_valid(n_t, T))
        return ev, tw


def _pad(a: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros((n,) + a.shape[1:], a.dtype)
    out[: a.shape[0]] = a
    return out


def _valid(n: int, size: int) -> np.ndarray:
    v = np.zeros(size, bool)
    v[:n] = True
    return v
