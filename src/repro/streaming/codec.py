"""Segment codec: fingerprint delta-encoding + compressed blob container.

Sealed firehose-log segments and ``CheckpointManager`` payloads are npz
blobs of mostly-integer lanes. Sessions repeat heavily (a user issues many
queries inside one session window) and the query fingerprints themselves
follow the Zipf head, so the u64 fingerprint lanes are highly redundant —
but only *exactly* redundant: the replay contract is bit-for-bit, so any
encoding here must round-trip exactly.

Two layers, both exact:

  * **fingerprint transform** (``xor_delta_encode``): each u64 lane is
    XORed with its predecessor in flattened order (sort-free — the lane
    order IS the log order, which replay depends on). A repeated
    fingerprint becomes a zero word; a near-repeat (same session, new
    query) becomes a low-entropy word. The inverse is a cumulative XOR.
    This is the "offset-vs-previous-occurrence" family from delta-encoded
    postings, without the sort that would destroy replay order.
  * **compression** (zlib, stdlib — the container records the codec id so
    an lz4/zstd codec can slot in without a format change).

Wire format of an encoded blob::

    b"FHC1" | u32 header_len | header json (utf-8) | zlib body

    header = {"codec": str, "raw_sha256": hex, "raw_nbytes": int,
              "transforms": {lane_name: "xor64"}}

``raw_sha256`` is the digest of the *uncompressed* npz body — verified on
every decode, so a decompression that "succeeds" on corrupt bytes still
cannot hand back silently-wrong arrays. The on-disk manifest keeps its own
sha256 over the final (compressed) blob, so the reader's integrity pass
and the ``corrupt_segment``/``corrupt_snapshot`` failure injectors work on
file bytes exactly as before.

A blob that does not start with the magic is treated as a legacy raw npz —
old logs and old snapshot dirs decode transparently.
"""
from __future__ import annotations

import hashlib
import io
import json
import struct
import zlib
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

MAGIC = b"FHC1"

#: codec ids -> (compress, decompress). "raw" bypasses the container.
RAW = "raw"
ZLIB = "zlib"                 # container + zlib, no lane transform
FP_ZLIB = "fpx-zlib"          # xor-delta the named fp lanes, then zlib
DEFAULT_CODEC = FP_ZLIB
CODECS = (RAW, ZLIB, FP_ZLIB)

#: the firehose-log lanes that hold u64 fingerprints (see log._LANES)
FP_LANES = ("sess_fp", "q_fp", "grams")


class CodecError(ValueError):
    """A blob failed structural or integrity validation during decode."""


# ---------------------------------------------------------------------------
# Exact integer transforms
# ---------------------------------------------------------------------------

def xor_delta_encode(a: np.ndarray) -> np.ndarray:
    """XOR each element with its predecessor in flattened order.

    Exact for any integer dtype; repeated values become zeros (sessions
    and head queries repeat heavily), which the byte compressor then
    collapses. Sort-free: element order — the log order — is untouched.
    """
    flat = np.ascontiguousarray(a).reshape(-1)
    out = flat.copy()
    if out.size > 1:
        out[1:] ^= flat[:-1]
    return out.reshape(a.shape)


def xor_delta_decode(a: np.ndarray) -> np.ndarray:
    """Inverse of :func:`xor_delta_encode` (cumulative XOR)."""
    flat = np.ascontiguousarray(a).reshape(-1)
    if flat.size > 1:
        flat = np.bitwise_xor.accumulate(flat)
    return flat.reshape(a.shape).astype(a.dtype, copy=False)


_TRANSFORMS = {"xor64": (xor_delta_encode, xor_delta_decode)}


# ---------------------------------------------------------------------------
# Payload <-> blob
# ---------------------------------------------------------------------------

def _savez(payload: Dict[str, np.ndarray]) -> bytes:
    bio = io.BytesIO()
    np.savez(bio, **payload)
    return bio.getvalue()


def encode_payload(payload: Dict[str, np.ndarray],
                   codec: str = DEFAULT_CODEC,
                   fp_lanes: Iterable[str] = FP_LANES
                   ) -> Tuple[bytes, Dict]:
    """Serialize ``payload`` under ``codec``. Returns ``(blob, info)``.

    ``info`` carries ``codec``, ``raw_sha256`` (digest of the uncompressed
    npz body — what the log manifest records next to the on-disk digest)
    and ``raw_nbytes``/``nbytes`` for compression accounting.
    """
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r} (have {CODECS})")
    if codec == RAW:
        blob = _savez(payload)
        sha = hashlib.sha256(blob).hexdigest()
        return blob, {"codec": RAW, "raw_sha256": sha,
                      "raw_nbytes": len(blob), "nbytes": len(blob)}
    transforms: Dict[str, str] = {}
    if codec == FP_ZLIB:
        payload = dict(payload)
        for lane in fp_lanes:
            a = payload.get(lane)
            if a is not None and a.dtype.kind in "ui" and a.size:
                payload[lane] = xor_delta_encode(a)
                transforms[lane] = "xor64"
    body_raw = _savez(payload)
    raw_sha = hashlib.sha256(body_raw).hexdigest()
    header = {"codec": codec, "raw_sha256": raw_sha,
              "raw_nbytes": len(body_raw), "transforms": transforms}
    hdr = json.dumps(header, sort_keys=True).encode()
    body = zlib.compress(body_raw, 6)
    blob = MAGIC + struct.pack("<I", len(hdr)) + hdr + body
    return blob, {"codec": codec, "raw_sha256": raw_sha,
                  "raw_nbytes": len(body_raw), "nbytes": len(blob)}


def decode_payload(blob: bytes) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Decode a blob written by :func:`encode_payload` — or a legacy raw
    npz blob (no magic). Returns ``(payload, info)``; raises
    :class:`CodecError` on a structurally bad or integrity-failing blob.
    """
    if not blob.startswith(MAGIC):
        try:
            with np.load(io.BytesIO(blob)) as z:
                payload = {k: z[k] for k in z.files}
        except Exception as e:  # noqa: BLE001 — short/garbled npz
            raise CodecError(f"not a codec container nor a loadable npz: "
                             f"{e}") from e
        return payload, {"codec": RAW, "raw_nbytes": len(blob),
                         "nbytes": len(blob)}
    try:
        (hdr_len,) = struct.unpack("<I", blob[4:8])
        header = json.loads(blob[8:8 + hdr_len].decode())
        body = zlib.decompress(blob[8 + hdr_len:])
    except Exception as e:  # noqa: BLE001 — torn header/body
        raise CodecError(f"corrupt codec container: {e}") from e
    want = header.get("raw_sha256")
    if want is not None and hashlib.sha256(body).hexdigest() != want:
        raise CodecError("decompressed body fails raw_sha256 integrity")
    try:
        with np.load(io.BytesIO(body)) as z:
            payload = {k: z[k] for k in z.files}
    except Exception as e:  # noqa: BLE001
        raise CodecError(f"container body is not a loadable npz: {e}") from e
    for lane, tname in header.get("transforms", {}).items():
        if lane in payload:
            payload[lane] = _TRANSFORMS[tname][1](payload[lane])
    return payload, {"codec": header.get("codec", ZLIB),
                     "raw_nbytes": header.get("raw_nbytes"),
                     "nbytes": len(blob)}


def lane_compression_report(payload: Dict[str, np.ndarray],
                            codec: str = DEFAULT_CODEC,
                            fp_lanes: Iterable[str] = FP_LANES
                            ) -> Dict[str, Dict[str, float]]:
    """Per-lane raw/encoded byte counts (bench observability: which lane
    the transform actually pays for)."""
    out: Dict[str, Dict[str, float]] = {}
    for k, a in payload.items():
        blob, info = encode_payload({k: a}, codec=codec, fp_lanes=fp_lanes)
        raw = int(np.asarray(a).nbytes)
        out[k] = {"raw_bytes": raw, "encoded_bytes": len(blob),
                  "ratio": (raw / len(blob)) if len(blob) else 0.0}
    return out
