"""Count-min sketch store — the probabilistic point on the paper's
coverage↔memory tradeoff curve (§4.4).

"We can reduce memory consumption by only keeping track of
frequently-occurring query terms (above a threshold), but at the cost of
coverage." A count-min sketch inverts the tradeoff: every key is tracked
(full coverage of counts, within overestimation error) in O(d·w) memory
independent of the key cardinality — at the cost of not being enumerable
(it cannot drive ranking cycles alone; the engine uses it as a pre-filter
for query-likeness and as a memory-bounded heavy-hitter detector feeding
the hot-key salting in ``sharded_engine``).

Supports the same exponential decay as the exact stores (multiply the whole
sketch — a dense elementwise op).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import _mix32

_SALTS = jnp.array([0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F,
                    0x165667B1, 0xD3A2646C, 0xFD7046C5, 0xB55A4F09],
                   dtype=jnp.uint32)


class CountMinSketch(NamedTuple):
    table: jax.Array   # f32[depth, width]

    @property
    def depth(self) -> int:
        return self.table.shape[0]

    @property
    def width(self) -> int:
        return self.table.shape[1]


def make_sketch(depth: int = 4, width: int = 1 << 16) -> CountMinSketch:
    assert width & (width - 1) == 0
    assert depth <= _SALTS.shape[0]
    return CountMinSketch(jnp.zeros((depth, width), jnp.float32))


def _rows(sk_depth: int, width: int, key_hi, key_lo):
    """Per-depth bucket indices for a batch of keys -> i32[depth, B]."""
    idx = []
    for d in range(sk_depth):
        h = _mix32(key_hi ^ _SALTS[d]) ^ _mix32(key_lo * _SALTS[d])
        idx.append((h & jnp.uint32(width - 1)).astype(jnp.int32))
    return jnp.stack(idx)


@jax.jit
def sketch_update(sk: CountMinSketch, key_hi, key_lo, weights, valid
                  ) -> CountMinSketch:
    D, W = sk.table.shape
    idx = _rows(D, W, key_hi, key_lo)                 # [D, B]
    w = jnp.where(valid, weights, 0.0)
    table = sk.table
    for d in range(D):
        table = table.at[d, idx[d]].add(w)
    return CountMinSketch(table)


@jax.jit
def sketch_query(sk: CountMinSketch, key_hi, key_lo) -> jax.Array:
    D, W = sk.table.shape
    idx = _rows(D, W, key_hi, key_lo)
    vals = jnp.stack([sk.table[d, idx[d]] for d in range(D)])
    return jnp.min(vals, axis=0)


@jax.jit
def sketch_decay(sk: CountMinSketch, factor) -> CountMinSketch:
    return CountMinSketch(sk.table * factor)
