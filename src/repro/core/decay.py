"""Temporal decay of accumulated statistics (paper §2.4, §4.3).

The paper decays observed counts over time so that correlation statistics
gradually forget stale evidence, and prunes entries whose weight falls under
a threshold to bound the memory footprint (§4.4). Decay function choices
(exponential / linear / step) are all supported; exponential is the default.

Two execution policies:

  * ``sweep``  — paper-faithful periodic decay cycle: one full pass over the
    table multiplying every weight lane and clearing pruned slots. This is a
    purely memory-bound pass and is the target of the fused Pallas kernel in
    ``kernels/decay_prune.py`` (one HBM read+write instead of three).
  * ``lazy``   — beyond-paper: store ``last_tick`` per entry and apply
    ``w * factor(now - last_tick)`` at read time; the sweep then only needs
    to run for pruning at a much lower cadence. Turns O(capacity) work per
    cycle into O(touched entries).

Lazy cadence model (wired end to end since the segmented-top-k PR):

  * **reads** (``stores.lookup``, ``ranking_cycle``) pass ``decay_cfg`` +
    ``now`` and see the decayed view per row — no table writes;
  * **writes** (``stores.insert_accumulate``) rebase the stored weight to
    its decayed value before adding, then re-anchor ``last_tick = now``;
  * the engine's per-``decay_every`` full sweep disappears; only
    :func:`prune_sweep` runs, at the much longer ``EngineConfig.prune_every``
    cadence, to reclaim slots whose decayed weight fell under the threshold
    (and to stop f32 underflow by materializing the decay it observed).

Exactness: exponential decay is memoryless (``f(a)*f(b) == f(a+b)``), so
read-time views, write-time rebases and prune-time materialization compose
to exactly the eager sweep sequence. ``linear``/``step`` decay are *not*
memoryless — under the lazy policy they decay by total elapsed ticks since
the last touch, which is a (documented) semantic difference from repeated
eager sweeps.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .stores import HashTable, RegionTable, region_chain_state

EXP, LINEAR, STEP = "exp", "linear", "step"


@dataclasses.dataclass(frozen=True)
class DecayConfig:
    kind: str = EXP            # exp | linear | step
    half_life_ticks: float = 36.0   # exp: ticks to halve a weight
    linear_slope: float = 0.01      # linear: weight lost per tick
    step_every: int = 72            # step: every N ticks ...
    step_factor: float = 0.5        # ... multiply by this
    prune_threshold: float = 0.05   # drop entries below this weight
    policy: str = "sweep"           # sweep | lazy

    def factor(self, dticks) -> jax.Array:
        """Multiplicative decay factor for an elapsed number of ticks."""
        dt = jnp.asarray(dticks, jnp.float32)
        if self.kind == EXP:
            return jnp.exp2(-dt / self.half_life_ticks)
        if self.kind == LINEAR:
            # linear decay of the *fraction* retained, floored at 0
            return jnp.maximum(1.0 - self.linear_slope * dt, 0.0)
        if self.kind == STEP:
            return self.step_factor ** jnp.floor(dt / self.step_every)
        raise ValueError(self.kind)

    def factor_py(self, dticks: float) -> float:
        if self.kind == EXP:
            return 2.0 ** (-dticks / self.half_life_ticks)
        if self.kind == LINEAR:
            return max(1.0 - self.linear_slope * dticks, 0.0)
        if self.kind == STEP:
            return self.step_factor ** math.floor(dticks / self.step_every)
        raise ValueError(self.kind)


def _apply_decay_prune(table: HashTable, f, cfg: DecayConfig,
                       weight_lanes: Tuple[str, ...],
                       tick_override=None, tick_lane: str = "last_tick"):
    """Shared sweep epilogue: decay the weight lanes by ``f`` (scalar or
    per-row), prune below ``cfg.prune_threshold`` on the primary lane,
    clear every other lane and the keys on pruned slots; optionally
    re-anchor ``tick_lane`` to ``tick_override`` on survivors (the lazy
    prune sweep). Returns (table, live_count, total_weight-after)."""
    lanes = dict(table.lanes)
    primary = weight_lanes[0]
    decayed = {name: lanes[name] * f for name in weight_lanes}
    live = table.live_mask
    keep = live & (decayed[primary] >= cfg.prune_threshold)
    for name in weight_lanes:
        lanes[name] = jnp.where(keep, decayed[name], 0.0)
    if tick_override is not None:
        lanes[tick_lane] = jnp.where(
            keep,
            jnp.broadcast_to(
                jnp.asarray(tick_override, lanes[tick_lane].dtype),
                keep.shape),
            jnp.zeros_like(lanes[tick_lane]))
    for name, lane in lanes.items():
        if name in weight_lanes or (tick_override is not None
                                    and name == tick_lane):
            continue
        keep_b = keep.reshape(keep.shape + (1,) * (lane.ndim - 1))
        lanes[name] = jnp.where(keep_b, lane, jnp.zeros_like(lane))
    new = table._replace(
        key_hi=jnp.where(keep, table.key_hi, 0),
        key_lo=jnp.where(keep, table.key_lo, 0),
        lanes=lanes,
    )
    return new, jnp.sum(keep.astype(jnp.int32)), jnp.sum(lanes[primary])


@partial(jax.jit, static_argnames=("weight_lanes", "cfg", "use_kernel"))
def sweep_decay_prune(
    table: HashTable,
    dticks: jax.Array,
    *,
    cfg: DecayConfig,
    weight_lanes: Tuple[str, ...] = ("weight",),
    use_kernel: bool = False,
) -> Tuple[HashTable, jax.Array, jax.Array]:
    """Paper-faithful decay/prune cycle over the whole table.

    Returns (table, live_count, total_weight-after). ``use_kernel`` routes the
    fused pass through the Pallas kernel (see kernels/ops.py); the jnp path
    below is the reference semantics.
    """
    if use_kernel:
        from ..kernels import ops as kops
        return kops.decay_prune_table(table, dticks, cfg=cfg, weight_lanes=weight_lanes)

    return _apply_decay_prune(table, cfg.factor(dticks), cfg, weight_lanes)


def lazy_decayed(cfg: DecayConfig, weight: jax.Array, last_tick: jax.Array,
                 now: jax.Array) -> jax.Array:
    """Read-time decayed view of a weight lane under the lazy policy."""
    return weight * cfg.factor(jnp.maximum(now - last_tick, 0))


@partial(jax.jit, static_argnames=("weight_lanes", "tick_lane", "cfg"))
def prune_sweep(
    table: HashTable,
    now: jax.Array,
    *,
    cfg: DecayConfig,
    weight_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
) -> Tuple[HashTable, jax.Array, jax.Array, jax.Array]:
    """Prune-only sweep for the **lazy** policy (runs at ``prune_every``).

    Materializes each entry's read-time decayed view (per-row factor from
    ``tick_lane``), prunes entries whose decayed primary weight fell under
    ``cfg.prune_threshold``, and re-anchors ``tick_lane = now`` on the
    survivors so future reads decay from the materialized base. For
    exponential decay this is exactly equivalent to never sweeping at all
    (modulo f32 rounding); it exists to reclaim slots and bound the
    store's memory footprint (§4.4), not to apply decay.

    Returns (table, live_count, total_weight-after, reclaimed_slots).
    ``reclaimed_slots`` — how many live slots this sweep freed — is the
    engine's freelist-pressure signal (surfaced through maintenance stats
    into ``SuggestFrontend.metrics()``).
    """
    live_before = jnp.sum(table.live_mask.astype(jnp.int32))
    f = cfg.factor(jnp.maximum(now - table.lanes[tick_lane], 0))
    new, live, tot = _apply_decay_prune(table, f, cfg, weight_lanes,
                                        tick_override=now,
                                        tick_lane=tick_lane)
    return new, live, tot, live_before - live


# ---------------------------------------------------------------------------
# Region-layout sweeps (source-major cooccurrence store).
# ---------------------------------------------------------------------------

def _region_sweep(table: RegionTable, qstore: HashTable, f, cfg: DecayConfig,
                  weight_lanes: Tuple[str, ...], tick_override, tick_lane):
    """Shared region sweep: decay + prune per slot, then restore the three
    region-layout invariants — compact every region live-first (slot reuse
    for later inserts), recount ``region_fill``, reclaim orphaned chains
    (source pruned from the qstore, or its slot re-claimed by another
    fingerprint), unlink emptied regions from their chains and return them
    to the freelist. Returns (table, live, total_weight, reclaimed)."""
    R, W, Q = table.n_regions, table.width, table.dir_slots
    assert Q == qstore.capacity
    lanes = dict(table.lanes)
    primary = weight_lanes[0]
    live = table.live_mask
    live_before = jnp.sum(live.astype(jnp.int32))
    decayed = {name: lanes[name] * f for name in weight_lanes}
    keep = live & (decayed[primary] >= cfg.prune_threshold)

    # chain validity vs the qstore: if the qstore no longer holds the
    # recorded fp at a slot, the whole chain is dead (its source can never
    # pass the ranking gates; a new slot owner starts a fresh chain).
    _, ent_ok, referenced = region_chain_state(table, qstore)
    ent = table.chain_region
    keep = keep & jnp.repeat(referenced, W)

    # apply decay/prune to lanes (cleared slots MUST zero every lane — a
    # freed slot's last_tick feeds later rebase-on-write).
    for name in weight_lanes:
        lanes[name] = jnp.where(keep, decayed[name], 0.0)
    if tick_override is not None:
        lanes[tick_lane] = jnp.where(
            keep, jnp.broadcast_to(
                jnp.asarray(tick_override, lanes[tick_lane].dtype),
                keep.shape),
            jnp.zeros_like(lanes[tick_lane]))
    for name, lane in lanes.items():
        if name in weight_lanes or (tick_override is not None
                                    and name == tick_lane):
            continue
        lanes[name] = jnp.where(keep, lane, jnp.zeros_like(lane))

    # compact each region live-first (stable => insertion order kept).
    keep2 = keep.reshape(R, W)
    order = jnp.argsort(~keep2, axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a.reshape(R, W), order,
                                         axis=1).reshape(-1)
    key_hi = take(jnp.where(keep, table.key_hi, 0))
    key_lo = take(jnp.where(keep, table.key_lo, 0))
    lanes = {name: take(lane) for name, lane in lanes.items()}
    fill = jnp.sum(keep2.astype(jnp.int32), axis=1)
    owner = jnp.where(fill > 0, table.region_owner, -1)

    # unlink emptied regions; close the hole so chains stay a prefix.
    fill_at_ent = jnp.where(ent_ok, fill[jnp.clip(ent, 0, R - 1)], 0)
    ent_keep = ent_ok & (fill_at_ent > 0)
    eorder = jnp.argsort(~ent_keep, axis=1, stable=True)
    chain_region = jnp.take_along_axis(
        jnp.where(ent_keep, ent, -1), eorder, axis=1)

    new = table._replace(key_hi=key_hi, key_lo=key_lo, lanes=lanes,
                         chain_region=chain_region, region_fill=fill,
                         region_owner=owner)
    live_after = jnp.sum(keep.astype(jnp.int32))
    return new, live_after, jnp.sum(lanes[primary]), live_before - live_after


@partial(jax.jit, static_argnames=("weight_lanes", "tick_lane", "cfg"))
def region_prune_sweep(
    table: RegionTable,
    qstore: HashTable,
    now: jax.Array,
    *,
    cfg: DecayConfig,
    weight_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
) -> Tuple[RegionTable, jax.Array, jax.Array, jax.Array]:
    """:func:`prune_sweep` for the region layout (lazy policy): per-slot
    read-time decay materialization + prune, plus the region maintenance
    of :func:`_region_sweep` (compaction, fill recount, orphan/empty
    region reclamation). Returns (table, live, total_weight, reclaimed)."""
    f = cfg.factor(jnp.maximum(now - table.lanes[tick_lane], 0))
    return _region_sweep(table, qstore, f, cfg, weight_lanes, now, tick_lane)


@partial(jax.jit, static_argnames=("weight_lanes", "cfg"))
def region_decay_sweep(
    table: RegionTable,
    qstore: HashTable,
    dticks: jax.Array,
    *,
    cfg: DecayConfig,
    weight_lanes: Tuple[str, ...] = ("weight",),
) -> Tuple[RegionTable, jax.Array, jax.Array, jax.Array]:
    """:func:`sweep_decay_prune` for the region layout (eager policy):
    scalar decay factor, same prune + region maintenance."""
    return _region_sweep(table, qstore, cfg.factor(dticks), cfg,
                         weight_lanes, None, "last_tick")
