"""Beyond-paper: the sharded search-assistance backend.

The paper's backend is "replicated for fault tolerance, but not sharded
(each instance independently holds the entire state)" and names the two
scalability walls (§4.4): every instance must consume the full hoses, and
store memory bounds coverage. This module shards the engine over a mesh
axis and removes the memory wall:

  * **query store**: replicated (it is orders of magnitude smaller than the
    pair space — the paper's own observation) so ranking marginals and
    query-likeness checks stay local;
  * **sessions store**: sharded by session hash — pair *generation* is local
    to the session owner;
  * **cooccurrence store**: sharded by *source-query* hash, so one ranking
    cycle shard holds every pair of its source queries and top-k is local;
  * **hot-key salting**: Zipf-skewed sources (the same skew that produced
    the paper's Hadoop stragglers, §3.2) are split across ``n_salts``
    shards via a salt on the destination hash; the frontend merges the
    per-salt top-k lists. Hotness is decided against the replicated query
    store at routing time (count >= hot_threshold).
  * pair routing: fixed-capacity bucketization + ``all_to_all`` along the
    shard axis (overflow is dropped *and counted*, mirroring the paper's
    rate-limiting stance).

State lives as arrays with a leading shard axis, sharded with shard_map;
the same single-device store/ranking code runs per shard.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import ranking, stores
from .decay import (prune_sweep, region_decay_sweep, region_prune_sweep,
                    sweep_decay_prune)
from .engine import (EngineConfig, cooc_insert_pairs, maintenance_cadence,
                     make_cooc_store, _Q_MODES)
from .hashing import combine_fp_device, probe_hash
from .ranking import RankConfig, SuggestionTable
from .stores import HashTable, SessionTable


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    base: EngineConfig
    n_salts: int = 4
    hot_threshold: float = 50.0     # count above which a src key is "hot"
    route_capacity: int = 4096      # per-destination bucket capacity


class ShardedState(NamedTuple):
    qstore: HashTable        # replicated
    cooc: HashTable          # leading dim = shard
    sessions: SessionTable   # leading dim = shard
    tick: jax.Array
    n_route_drop: jax.Array  # routed pairs dropped on bucket overflow


def _stack_shards(tree, n):
    """Concatenate n per-shard tables along dim 0 (shard_map blocks dim 0).

    Scalars (per-shard counters) become shape (n,) -> (1,) per device.
    Every shard starts as a copy of the freshly initialized per-shard
    table — broadcast+reshape == n concatenated copies, which preserves
    non-zero initial values (the region layout's -1 sentinels).
    """
    def f(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n,))
        return jnp.broadcast_to(x, (n,) + x.shape).reshape(
            (n * x.shape[0],) + x.shape[1:])
    return jax.tree.map(f, tree)


def init_sharded_state(cfg: ShardedConfig, mesh: Mesh, axis: str = "shard"
                       ) -> ShardedState:
    n = mesh.shape[axis]
    base = cfg.base
    qstore = stores.make_table(base.query_capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})
    # region layout: each shard gets its own region pool + a full-Q chain
    # directory (the qstore is replicated, so slot ids are global).
    cooc = make_cooc_store(base, capacity=base.cooc_capacity // n)
    sessions = stores.make_session_table(base.session_capacity // n,
                                         base.session_window)
    return ShardedState(
        qstore=qstore,
        cooc=_stack_shards(cooc, n),
        sessions=_stack_shards(sessions, n),
        tick=jnp.zeros((), jnp.int32),
        n_route_drop=jnp.zeros((n,), jnp.int32),
    )


def _route(pairs_key_hi, pairs_key_lo, owner, payload: Dict[str, jax.Array],
           valid, n_shards: int, cap: int, axis: str):
    """Bucketize by owner shard and all_to_all. Returns routed flat arrays.

    All arrays are per-device (inside shard_map). Overflow beyond ``cap``
    per destination bucket is dropped and counted.
    """
    Bp = pairs_key_hi.shape[0]
    owner = jnp.where(valid, owner, n_shards)  # invalid -> sentinel bucket
    order = jnp.argsort(owner)                  # stable
    o_sorted = owner[order]
    # position within each owner run
    idx = jnp.arange(Bp, dtype=jnp.int32)
    seg_start = jax.ops.segment_min(
        idx, jnp.clip(o_sorted, 0, n_shards).astype(jnp.int32),
        num_segments=n_shards + 1)
    pos = idx - seg_start[jnp.clip(o_sorted, 0, n_shards)]
    ok = (o_sorted < n_shards) & (pos < cap)
    dropped = jnp.sum(((o_sorted < n_shards) & (pos >= cap)).astype(jnp.int32))

    dest_row = jnp.where(ok, o_sorted.astype(jnp.int32), n_shards)
    dest_pos = jnp.where(ok, pos, 0)

    def bucketize(x, fill=0):
        buf = jnp.full((n_shards, cap) + x.shape[1:], fill, x.dtype)
        return buf.at[dest_row, dest_pos].set(x[order], mode="drop")

    b_hi = bucketize(pairs_key_hi)
    b_lo = bucketize(pairs_key_lo)
    b_payload = {k: bucketize(v) for k, v in payload.items()}
    b_valid = jnp.zeros((n_shards, cap), bool).at[dest_row, dest_pos].set(
        ok, mode="drop")

    # exchange: axis 0 is the destination shard
    t_hi = jax.lax.all_to_all(b_hi, axis, 0, 0, tiled=False)
    t_lo = jax.lax.all_to_all(b_lo, axis, 0, 0, tiled=False)
    t_val = jax.lax.all_to_all(b_valid, axis, 0, 0, tiled=False)
    t_payload = {k: jax.lax.all_to_all(v, axis, 0, 0, tiled=False)
                 for k, v in b_payload.items()}
    flat = lambda x: x.reshape((n_shards * cap,) + x.shape[2:])
    return (flat(t_hi), flat(t_lo), {k: flat(v) for k, v in t_payload.items()},
            flat(t_val), dropped)


def _ingest_body(cfg: ShardedConfig, n: int, axis: str):
    """The per-device query-path ingest body (shared by the one-tick step
    and the fused multi-tick replay scan)."""
    base = cfg.base

    def body(state: ShardedState, s_hi, s_lo, q_hi, q_lo, src, valid):
        me = jax.lax.axis_index(axis)
        B = q_hi.shape[0]
        tick_vec = jnp.full((B,), state.tick, jnp.int32)
        sw = jnp.asarray(base.source_weights, jnp.float32)
        w = sw[jnp.clip(src, 0, len(base.source_weights) - 1)]
        # lazy decay policy: same rebase-on-write as the unsharded engine
        dkw = (dict(decay_cfg=base.decay, now=state.tick)
               if base.lazy_decay else {})

        # --- replicated query store: every shard applies the full batch ---
        qstore = stores.insert_accumulate(
            state.qstore, q_hi, q_lo,
            {"weight": w, "count": jnp.ones((B,), jnp.float32),
             "last_tick": tick_vec},
            valid, modes=_Q_MODES, probe_rounds=base.probe_rounds, **dkw)

        # --- sessions: filter to my shard (owner = hash(sess) % n) ---
        sess_owner = (probe_hash(s_hi, s_lo) % jnp.uint32(n)).astype(jnp.int32)
        mine = valid & (sess_owner == me)
        sessions, pairs = stores.update_sessions(
            state.sessions, s_hi, s_lo, q_hi, q_lo, src, state.tick, mine,
            probe_rounds=base.probe_rounds)

        # --- route pairs to cooccurrence owner: hash(src) (+ salt if hot) ---
        svals, sfound, _ = stores.lookup(qstore, pairs.src_hi, pairs.src_lo,
                                         probe_rounds=base.probe_rounds)
        hot = sfound & (svals["count"] >= cfg.hot_threshold)
        salt = jnp.where(
            hot, (probe_hash(pairs.dst_hi, pairs.dst_lo)
                  % jnp.uint32(cfg.n_salts)).astype(jnp.uint32),
            jnp.uint32(0))
        owner = ((probe_hash(pairs.src_hi, pairs.src_lo) + salt)
                 % jnp.uint32(n)).astype(jnp.int32)
        w_src = sw[jnp.clip(pairs.src_code, 0, len(base.source_weights) - 1)]
        w_dst = sw[jnp.clip(pairs.dst_code, 0, len(base.source_weights) - 1)]
        w_pair = jnp.sqrt(w_src * w_dst)
        payload = {"src_hi": pairs.src_hi, "src_lo": pairs.src_lo,
                   "dst_hi": pairs.dst_hi, "dst_lo": pairs.dst_lo,
                   "w": w_pair}
        r_hi, r_lo, r_pl, r_valid, drop = _route(
            pairs.src_hi, pairs.src_lo, owner, payload, pairs.valid,
            n, cfg.route_capacity, axis)
        cooc = cooc_insert_pairs(
            state.cooc, qstore, r_pl["src_hi"], r_pl["src_lo"],
            r_pl["dst_hi"], r_pl["dst_lo"], r_pl["w"], r_valid, state.tick,
            base, dkw)

        return ShardedState(qstore, cooc, sessions, state.tick,
                            state.n_route_drop + drop[None])

    return body


def make_sharded_step(cfg: ShardedConfig, mesh: Mesh, axis: str = "shard"):
    """Build the jitted sharded ingest step (query path)."""
    n = mesh.shape[axis]
    body = _ingest_body(cfg, n, axis)
    rep = P()
    state_spec = _state_spec(cfg, axis)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, rep, rep, rep, rep, rep, rep),
                   out_specs=state_spec,
                   check_rep=False)
    return jax.jit(fn)


def _tick_maintenance(state: ShardedState, base: EngineConfig
                      ) -> ShardedState:
    """Per-tick maintenance on the sharded state: the shared
    ``engine.maintenance_cadence`` ladder (ONE copy of the cadence
    semantics) with sharded branch bodies — lazy: prune-only sweeps at
    ``prune_every``, session eviction at ``decay_every``; eager: full
    decay/prune + eviction at ``decay_every``. Runs inside the replay scan
    so replayed ticks mutate state exactly as live ones do."""

    def evict_only(s: ShardedState) -> ShardedState:
        sessions = stores.evict_sessions(s.sessions, s.tick, base.session_ttl)
        return s._replace(sessions=sessions)

    def prune_fn(s: ShardedState) -> ShardedState:
        qstore, _, _, _ = prune_sweep(s.qstore, s.tick, cfg=base.decay)
        if base.region_cooc:
            cooc, _, _, _ = region_prune_sweep(s.cooc, qstore, s.tick,
                                               cfg=base.decay)
        else:
            cooc, _, _, _ = prune_sweep(s.cooc, s.tick, cfg=base.decay)
        return evict_only(s._replace(qstore=qstore, cooc=cooc))

    def decay_fn(s: ShardedState) -> ShardedState:
        qstore, _, _ = sweep_decay_prune(
            s.qstore, jnp.int32(base.decay_every), cfg=base.decay,
            use_kernel=base.kernel_on("decay_prune"))
        if base.region_cooc:
            cooc, _, _, _ = region_decay_sweep(
                s.cooc, qstore, jnp.int32(base.decay_every), cfg=base.decay)
        else:
            cooc, _, _ = sweep_decay_prune(
                s.cooc, jnp.int32(base.decay_every), cfg=base.decay,
                use_kernel=base.kernel_on("decay_prune"))
        return evict_only(s._replace(qstore=qstore, cooc=cooc))

    return maintenance_cadence(state, state.tick, base,
                               prune_fn=prune_fn, evict_fn=evict_only,
                               decay_fn=decay_fn)


def make_sharded_tick_step(cfg: ShardedConfig, mesh: Mesh,
                           axis: str = "shard"):
    """One full live tick (ingest + cadence maintenance + tick advance) —
    the sharded equivalent of ``SearchAssistanceEngine.step``'s state
    mutations, so drivers using it replay exactly under
    ``make_sharded_ingest_many``."""
    n = mesh.shape[axis]
    base = cfg.base
    ingest = _ingest_body(cfg, n, axis)

    def body(state: ShardedState, s_hi, s_lo, q_hi, q_lo, src, valid):
        state = ingest(state, s_hi, s_lo, q_hi, q_lo, src, valid)
        state = _tick_maintenance(state, base)
        return state._replace(tick=state.tick + 1)

    rep = P()
    state_spec = _state_spec(cfg, axis)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(state_spec, rep, rep, rep, rep, rep, rep),
                   out_specs=state_spec, check_rep=False)
    return jax.jit(fn)


def make_sharded_ingest_many(cfg: ShardedConfig, mesh: Mesh,
                             axis: str = "shard"):
    """Fused catch-up replay over the sharded engine (§4.2).

    Each shard consumes the full logged hose (the paper's replicated-
    consumption design), so ONE shared firehose log serves every shard and
    replay is parallel by construction: a single ``lax.scan`` dispatch
    advances all shards through a chunk of R logged ticks — per-tick
    routing ``all_to_all``s included — with the cadence maintenance run
    in-scan (identical state mutations to the live tick step above).

    Takes stacked query-hose arrays ``[R, B]``; returns the advanced state.
    """
    n = mesh.shape[axis]
    base = cfg.base
    ingest = _ingest_body(cfg, n, axis)

    def many(state: ShardedState, s_hi, s_lo, q_hi, q_lo, src, valid):
        def scan_body(st, xs):
            st = ingest(st, *xs)
            st = _tick_maintenance(st, base)
            return st._replace(tick=st.tick + 1), None

        state, _ = jax.lax.scan(
            scan_body, state, (s_hi, s_lo, q_hi, q_lo, src, valid))
        return state

    rep = P()
    state_spec = _state_spec(cfg, axis)
    fn = shard_map(many, mesh=mesh,
                   in_specs=(state_spec, rep, rep, rep, rep, rep, rep),
                   out_specs=state_spec, check_rep=False)
    return jax.jit(fn)


def make_sharded_decay(cfg: ShardedConfig, mesh: Mesh, axis: str = "shard"):
    base = cfg.base

    def body(state: ShardedState, dticks):
        # same fast paths as the unsharded engine: cfg.use_kernel routes the
        # per-shard sweep through the fused multi-lane Pallas kernel; under
        # the lazy policy this degrades to the prune-only sweep (run it at
        # the prune_every cadence, not decay_every).
        if base.lazy_decay:
            qstore, _, _, _ = prune_sweep(state.qstore, state.tick,
                                          cfg=base.decay)
            if base.region_cooc:
                cooc, _, _, _ = region_prune_sweep(
                    state.cooc, qstore, state.tick, cfg=base.decay)
            else:
                cooc, _, _, _ = prune_sweep(state.cooc, state.tick,
                                            cfg=base.decay)
        else:
            qstore, _, _ = sweep_decay_prune(
                state.qstore, dticks, cfg=base.decay,
                use_kernel=base.kernel_on("decay_prune"))
            if base.region_cooc:
                cooc, _, _, _ = region_decay_sweep(
                    state.cooc, qstore, dticks, cfg=base.decay)
            else:
                cooc, _, _ = sweep_decay_prune(
                    state.cooc, dticks, cfg=base.decay,
                    use_kernel=base.kernel_on("decay_prune"))
        sessions = stores.evict_sessions(state.sessions, state.tick,
                                         base.session_ttl)
        return ShardedState(qstore, cooc, sessions, state.tick + 0,
                            state.n_route_drop)

    rep, sh = P(), P(axis)
    state_spec = _state_spec(cfg, axis)
    fn = shard_map(body, mesh=mesh, in_specs=(state_spec, rep),
                   out_specs=state_spec, check_rep=False)
    return jax.jit(fn)


def make_sharded_rank(cfg: ShardedConfig, mesh: Mesh, axis: str = "shard"):
    def body(state: ShardedState):
        dkw = (dict(decay_cfg=cfg.base.decay, now=state.tick)
               if cfg.base.lazy_decay else {})
        cycle = (ranking.ranking_cycle_region if cfg.base.region_cooc
                 else ranking.ranking_cycle)
        t = cycle(state.cooc, state.qstore, cfg.base.rank, **dkw)
        # scalars -> (1,) per shard
        return t._replace(n_rows=t.n_rows[None], n_overflow=t.n_overflow[None])

    state_spec = _state_spec(cfg, axis)
    out_spec = SuggestionTable(*([P(axis)] * 5), n_rows=P(axis),
                               n_overflow=P(axis))
    fn = shard_map(body, mesh=mesh, in_specs=(state_spec,),
                   out_specs=out_spec, check_rep=False)
    return jax.jit(fn)


def _state_spec(cfg: ShardedConfig, axis: str) -> ShardedState:
    rep, sh = P(), P(axis)
    if cfg.base.region_cooc:
        cooc_tmpl = stores.make_region_table(4, 2, 2, 2, {
            "weight": jnp.float32, "count": jnp.float32,
            "last_tick": jnp.int32})
    else:
        cooc_tmpl = stores.make_table(
            2, {"weight": jnp.float32, "count": jnp.float32,
                "last_tick": jnp.int32, "src_hi": jnp.uint32,
                "src_lo": jnp.uint32, "dst_hi": jnp.uint32,
                "dst_lo": jnp.uint32})
    return ShardedState(
        qstore=jax.tree.map(lambda _: rep, stores.make_table(
            2, {"weight": jnp.float32, "count": jnp.float32,
                "last_tick": jnp.int32})),
        cooc=jax.tree.map(lambda _: sh, cooc_tmpl),
        sessions=jax.tree.map(lambda _: sh, stores.make_session_table(2, 2)),
        tick=rep,
        n_route_drop=sh,
    )


def save_sharded_snapshot(state: ShardedState, ckpt, meta=None) -> str:
    """Snapshot = checkpoint + log offset for the sharded engine.

    The whole ``ShardedState`` pytree (every shard's stores) goes into one
    checkpoint; the manifest records the shared-log replay offset.

    The save routes through the manager's delta-snapshot chain exactly
    like the unsharded engines: with ``CheckpointManager.full_interval >
    1`` only the changed leading rows of each (fully-addressable, host-
    readable) shard-stacked leaf are written between fulls, chained to the
    last full via the manifest (``kind``/``base_step``). Sharded stores are
    where this pays off most — per-shard capacity shrinks with the shard
    count, so between snapshots each shard touches few rows of its lane.
    ``restore_sharded_snapshot`` sees the composed state transparently
    (chain walk + fallback live in the manager)."""
    tick = int(np.asarray(state.tick))
    m = {"log_tick": tick, "engine": "sharded"}
    if meta:
        m.update(meta)
    return ckpt.save(tick, state, meta=m)


def restore_sharded_snapshot(cfg: ShardedConfig, mesh: Mesh, ckpt,
                             step=None, axis: str = "shard"
                             ) -> Tuple[ShardedState, int]:
    """Cold-start a sharded instance: returns (state, log_tick) — every
    shard restores in one pass, then all replay the shared log in parallel
    via ``make_sharded_ingest_many``."""
    template = init_sharded_state(cfg, mesh, axis)
    state, step = ckpt.restore(template, step)
    meta = ckpt.manifest(step).get("meta", {})
    return state, int(meta.get("log_tick", step))


def merge_sharded_suggestions(table: SuggestionTable, top_k: int
                              ) -> Dict[int, List[Tuple[int, float]]]:
    """Host-side merge of per-shard suggestion tables (salted srcs appear in
    up to n_salts shards)."""
    from .hashing import join_fp
    src_hi = np.asarray(table.src_hi).reshape(-1)
    src_lo = np.asarray(table.src_lo).reshape(-1)
    K = table.score.shape[-1]
    dst_hi = np.asarray(table.dst_hi).reshape(-1, K)
    dst_lo = np.asarray(table.dst_lo).reshape(-1, K)
    score = np.asarray(table.score).reshape(-1, K)
    merged: Dict[int, Dict[int, float]] = {}
    # skip empty rows AND the lexsort path's all-ones filler src key
    # explicitly (same guard as suggestions_to_host)
    mask = ((src_hi != 0) | (src_lo != 0)) \
        & ~((src_hi == 0xFFFFFFFF) & (src_lo == 0xFFFFFFFF))
    src_fp = join_fp(src_hi, src_lo)
    dst_fp = join_fp(dst_hi, dst_lo)
    for i in np.nonzero(mask)[0]:
        d = merged.setdefault(int(src_fp[i]), {})
        for j in range(K):
            if score[i, j] > 0.0:
                fp = int(dst_fp[i, j])
                d[fp] = max(d.get(fp, 0.0), float(score[i, j]))
    return {s: sorted(d.items(), key=lambda t: (-t[1], t[0]))[:top_k]
            for s, d in merged.items()}


# ---------------------------------------------------------------------------
# Live shard split/merge (elastic scaling).
#
# Re-partitions a running ShardedState across a different shard count
# without losing state: every live cooccurrence pair and session is
# exported to a canonical host-side form, merged (the same (src, dst) pair
# can legitimately live in several old shards — a source that crossed
# hot_threshold mid-run salted its later inserts), then re-inserted into
# freshly initialized per-shard stores under the NEW ownership rule — the
# exact rule the live ingest path routes by, so post-reshard inserts land
# on the rows the reshard placed. The qstore is replicated and copied
# verbatim, which also keeps every region-directory slot id valid.
#
# The reshard is a pure function of the state content: two runs that
# reshard at the same tick from bit-identical states produce bit-identical
# new states, which is what makes the zero-downtime handoff testable
# (serve from the old state while ticks keep arriving, replay the interim
# ticks from the shared log into the new state, compare against a clean
# run — see distributed.elastic.live_reshard).
# ---------------------------------------------------------------------------

_SET_PAIR_MODES = (("weight", "set"), ("count", "set"), ("last_tick", "set"))
_SET_HASH_MODES = _SET_PAIR_MODES + (("src_hi", "set"), ("src_lo", "set"),
                                     ("dst_hi", "set"), ("dst_lo", "set"))
_PAIR_COLS = ("src_hi", "src_lo", "dst_hi", "dst_lo",
              "weight", "count", "last_tick")
_SESS_COLS = ("key_hi", "key_lo", "ring_hi", "ring_lo", "ring_src",
              "cursor", "filled", "last_tick")


def _shard_view(tree, i: int, n: int, scalar_fields=("n_dropped",)):
    """Slice shard ``i`` out of a shard-stacked store tree (inverse of
    ``_stack_shards`` for one shard): leading dims are n x per-shard, the
    named scalar counters are stacked to (n,)."""
    def f(path, x):
        name = path[-1].name if hasattr(path[-1], "name") else str(path[-1])
        if name in scalar_fields and x.ndim == 1 and x.shape[0] == n:
            return x[i]
        m = x.shape[0] // n
        return x[i * m:(i + 1) * m]
    return jax.tree_util.tree_map_with_path(f, tree)


def _stack_trees(trees):
    """Stack per-shard store trees back into the leading-dim layout
    (scalars -> (n,), arrays concatenated — same layout as _stack_shards)."""
    return jax.tree.map(
        lambda *xs: (jnp.stack(xs, 0) if xs[0].ndim == 0
                     else jnp.concatenate(xs, 0)), *trees)


def _export_hash_pairs(tab: HashTable) -> Dict[str, np.ndarray]:
    e = stores.export_live(tab)
    return {k: e[k] for k in _PAIR_COLS}


def _export_region_pairs(tab, qstore: HashTable) -> Dict[str, np.ndarray]:
    """Live pairs of one region-layout shard: walk the packed region pool
    under the shared chain-validity invariant (orphaned chains and stale
    directory rows export nothing, exactly as ranking skips them)."""
    _, _, referenced = stores.region_chain_state(tab, qstore)
    referenced = np.asarray(referenced)
    fill = np.asarray(tab.region_fill)
    owner = np.asarray(tab.region_owner)
    chain_hi = np.asarray(tab.chain_hi)
    chain_lo = np.asarray(tab.chain_lo)
    khi, klo = np.asarray(tab.key_hi), np.asarray(tab.key_lo)
    W, C = tab.width, tab.capacity
    slot = np.arange(C)
    reg, pos = slot // W, slot % W
    live = referenced[reg] & (pos < fill[reg]) & ((khi != 0) | (klo != 0))
    idx = np.nonzero(live)[0]
    src_slot = owner[reg[idx]]
    out = {"src_hi": chain_hi[src_slot], "src_lo": chain_lo[src_slot],
           "dst_hi": khi[idx], "dst_lo": klo[idx]}
    for name in ("weight", "count", "last_tick"):
        out[name] = np.asarray(tab.lanes[name])[idx]
    return out


def _merge_duplicate_pairs(base: EngineConfig, e: Dict[str, np.ndarray]
                           ) -> Dict[str, np.ndarray]:
    """Canonical-sort and merge multi-shard duplicates of a (src, dst) pair.

    Under the lazy decay policy the duplicates' (weight, last_tick)
    encodings differ; each weight is rebased to the group's max last_tick
    with the SAME decay formula the device reads use, so the merged entry
    decays to the same effective value as the duplicates summed."""
    if e["src_hi"].size == 0:
        return e
    order = np.lexsort((e["dst_lo"], e["dst_hi"], e["src_lo"], e["src_hi"]))
    s = {k: v[order] for k, v in e.items()}
    key = np.stack([s["src_hi"], s["src_lo"], s["dst_hi"], s["dst_lo"]], 1)
    new_grp = np.any(key[1:] != key[:-1], axis=1)
    starts = np.concatenate([[0], np.nonzero(new_grp)[0] + 1])
    seg = np.concatenate([[0], np.cumsum(new_grp.astype(np.int64))])
    lt_max = np.maximum.reduceat(s["last_tick"], starts)
    w = s["weight"].astype(np.float32)
    if base.lazy_decay:
        dt = (lt_max[seg] - s["last_tick"]).astype(np.float32)
        f = np.asarray(base.decay.factor(dt), np.float32)
        w = (w * f).astype(np.float32)
    out = {k: s[k][starts] for k in ("src_hi", "src_lo", "dst_hi", "dst_lo")}
    out["weight"] = np.add.reduceat(w, starts).astype(np.float32)
    out["count"] = np.add.reduceat(
        s["count"].astype(np.float32), starts).astype(np.float32)
    out["last_tick"] = lt_max.astype(np.int32)
    return out


def export_sharded_pairs(cfg: ShardedConfig, state: ShardedState
                         ) -> Dict[str, np.ndarray]:
    """All live (src -> dst) pairs across shards, canonical order, merged."""
    n = state.n_route_drop.shape[0]
    cols: Dict[str, list] = {k: [] for k in _PAIR_COLS}
    for i in range(n):
        tab = _shard_view(state.cooc, i, n)
        e = (_export_region_pairs(tab, state.qstore)
             if cfg.base.region_cooc else _export_hash_pairs(tab))
        for k in _PAIR_COLS:
            cols[k].append(e[k])
    merged = {k: np.concatenate(v) for k, v in cols.items()}
    return _merge_duplicate_pairs(cfg.base, merged)


def export_sharded_sessions(state: ShardedState) -> Dict[str, np.ndarray]:
    """All live sessions across shards, full rows, canonical key order.
    Session ownership is total (one owner per key), so no merging."""
    n = state.n_route_drop.shape[0]
    cols: Dict[str, list] = {k: [] for k in _SESS_COLS}
    for i in range(n):
        t = _shard_view(state.sessions, i, n)
        mask = np.asarray((t.key_hi != 0) | (t.key_lo != 0))
        for k in _SESS_COLS:
            cols[k].append(np.asarray(getattr(t, k))[mask])
    e = {k: np.concatenate(v) for k, v in cols.items()}
    order = np.lexsort((e["key_lo"], e["key_hi"]))
    return {k: v[order] for k, v in e.items()}


def _fill_cooc_shard(cfg: ShardedConfig, new_n: int, qstore: HashTable,
                     pairs: Dict[str, np.ndarray], idx: np.ndarray):
    base = cfg.base
    tab = make_cooc_store(base, capacity=base.cooc_capacity // new_n)
    if idx.size == 0:
        return tab, 0
    upd = {k: jnp.asarray(pairs[k][idx])
           for k in ("weight", "count", "last_tick")}
    valid = jnp.ones((idx.size,), bool)
    s_hi, s_lo = jnp.asarray(pairs["src_hi"][idx]), \
        jnp.asarray(pairs["src_lo"][idx])
    d_hi, d_lo = jnp.asarray(pairs["dst_hi"][idx]), \
        jnp.asarray(pairs["dst_lo"][idx])
    # all-SET modes, no decay kwargs: the merged (weight, last_tick) pairs
    # are copied bit-exactly, which preserves lazy-decay semantics.
    if base.region_cooc:
        tab = stores.region_insert_accumulate(
            tab, qstore, s_hi, s_lo, d_hi, d_lo, upd, valid,
            modes=_SET_PAIR_MODES, probe_rounds=base.probe_rounds,
            use_kernel=base.use_kernel, plan=base.plan)
    else:
        p_hi, p_lo = combine_fp_device(s_hi, s_lo, d_hi, d_lo)
        upd.update({"src_hi": s_hi, "src_lo": s_lo,
                    "dst_hi": d_hi, "dst_lo": d_lo})
        tab = stores.insert_accumulate(
            tab, p_hi, p_lo, upd, valid, modes=_SET_HASH_MODES,
            probe_rounds=base.probe_rounds)
    return tab, int(np.asarray(tab.n_dropped))


def _fill_session_shard(base: EngineConfig, new_n: int,
                        sess: Dict[str, np.ndarray], idx: np.ndarray):
    cap = base.session_capacity // new_n
    tab = stores.make_session_table(cap, base.session_window)
    if idx.size == 0:
        return tab, 0
    kh, kl = jnp.asarray(sess["key_hi"][idx]), jnp.asarray(sess["key_lo"][idx])
    alive = jnp.ones((idx.size,), bool)
    # probe-consistent placement (later live update_sessions probes must
    # FIND these rows) + direct full-row scatter: update_sessions cannot
    # reproduce per-session last_tick (its tick argument is a scalar), and
    # the ring/cursor/filled triple must carry over verbatim.
    key_hi, key_lo, slot, placed, dropped = stores._find_or_claim(
        tab.key_hi, tab.key_lo, kh, kl, alive, base.probe_rounds)
    drop_slot = jnp.where(placed, slot, cap)

    def put(lane, col):
        return lane.at[drop_slot].set(jnp.asarray(sess[col][idx]),
                                      mode="drop")

    tab = tab._replace(
        key_hi=key_hi, key_lo=key_lo,
        ring_hi=put(tab.ring_hi, "ring_hi"),
        ring_lo=put(tab.ring_lo, "ring_lo"),
        ring_src=put(tab.ring_src, "ring_src"),
        cursor=put(tab.cursor, "cursor"),
        filled=put(tab.filled, "filled"),
        last_tick=put(tab.last_tick, "last_tick"),
        n_dropped=tab.n_dropped + dropped)
    return tab, int(np.asarray(dropped))


def reshard_sharded_state(cfg: ShardedConfig, state: ShardedState,
                          new_n: int) -> Tuple[ShardedState, Dict]:
    """Re-partition a live sharded state across ``new_n`` shards.

    Deterministic in the state content (no RNG, canonical ordering
    throughout); ``tick`` and the replicated qstore carry over unchanged,
    so the new state replays the shared log from the same offset. Routing
    hotness is re-decided against the current qstore — the same decision
    the live ingest path would make next tick. Per-shard drop counters
    restart at the insertion drops (old totals are returned in stats).
    """
    base = cfg.base
    old_n = state.n_route_drop.shape[0]
    assert new_n >= 1 and new_n & (new_n - 1) == 0, \
        f"new_n must be a power of two, got {new_n}"
    assert base.cooc_capacity % new_n == 0 \
        and base.cooc_capacity // new_n >= base.region_w, \
        "cooc capacity does not divide into new_n region-layout shards"
    assert base.session_capacity % new_n == 0, \
        "session capacity not divisible by new_n"

    pairs = export_sharded_pairs(cfg, state)
    sess = export_sharded_sessions(state)

    # ownership under new_n — the SAME rule as the live ingest path
    s_hi, s_lo = jnp.asarray(pairs["src_hi"]), jnp.asarray(pairs["src_lo"])
    d_hi, d_lo = jnp.asarray(pairs["dst_hi"]), jnp.asarray(pairs["dst_lo"])
    svals, sfound, _ = stores.lookup(state.qstore, s_hi, s_lo,
                                     probe_rounds=base.probe_rounds)
    hot = np.asarray(sfound) & (np.asarray(svals["count"])
                                >= cfg.hot_threshold)
    salt = np.where(hot,
                    np.asarray(probe_hash(d_hi, d_lo)) % np.uint32(
                        max(cfg.n_salts, 1)),
                    np.uint32(0)).astype(np.uint64)
    owner = ((np.asarray(probe_hash(s_hi, s_lo)).astype(np.uint64) + salt)
             % new_n).astype(np.int64)
    sess_owner = (np.asarray(
        probe_hash(jnp.asarray(sess["key_hi"]),
                   jnp.asarray(sess["key_lo"]))).astype(np.uint64)
        % new_n).astype(np.int64)

    coocs, sessions, n_pair_drop, n_sess_drop = [], [], 0, 0
    for j in range(new_n):
        c, dc = _fill_cooc_shard(cfg, new_n, state.qstore, pairs,
                                 np.nonzero(owner == j)[0])
        s, ds = _fill_session_shard(base, new_n, sess,
                                    np.nonzero(sess_owner == j)[0])
        coocs.append(c)
        sessions.append(s)
        n_pair_drop += dc
        n_sess_drop += ds

    new_state = ShardedState(
        qstore=state.qstore,
        cooc=_stack_trees(coocs),
        sessions=_stack_trees(sessions),
        tick=state.tick,
        n_route_drop=jnp.zeros((new_n,), jnp.int32))
    # hand back UNCOMMITTED arrays: leaves assembled here inherit the OLD
    # mesh's placement (and the qstore its old replication), which the new
    # layout's shard_map would reject — round-tripping through host leaves
    # the new mesh free to place them.
    new_state = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), new_state)
    stats = {"old_n": old_n, "new_n": new_n,
             "n_pairs": int(pairs["src_hi"].size),
             "n_sessions": int(sess["key_hi"].size),
             "n_pair_drop": n_pair_drop, "n_sess_drop": n_sess_drop,
             "old_route_drop": int(np.asarray(state.n_route_drop).sum()),
             "tick": int(np.asarray(state.tick))}
    return new_state, stats


def split_shards(cfg: ShardedConfig, state: ShardedState
                 ) -> Tuple[ShardedState, Dict]:
    """Double the shard count (scale out under lag/memory pressure)."""
    return reshard_sharded_state(cfg, state,
                                 2 * state.n_route_drop.shape[0])


def merge_shards(cfg: ShardedConfig, state: ShardedState
                 ) -> Tuple[ShardedState, Dict]:
    """Halve the shard count (scale in when shards run underfilled)."""
    n = state.n_route_drop.shape[0]
    assert n % 2 == 0, "cannot merge an odd shard count"
    return reshard_sharded_state(cfg, state, n // 2)
