"""Spelling correction via a pairwise edit-distance variant (paper §4.5).

The paper runs a periodic batch job computing "a pairwise edit distance
variant calculation between all queries observed within a long span of time",
with spelling-specific twists:

  * mistakes are more frequently observed in *internal* characters than at
    the beginning or end of a word -> edits at the first character are
    penalised (cost 1.5 instead of 1.0), so "justin biber" ~ "justin bieber"
    scores closer than "mustin bieber";
  * Twitter specifics: @mentions and hashtags are compared on their bare
    text (leading sigils stripped);
  * adjacent transpositions count as a single edit (Damerau).

A correction A -> B is emitted when the weighted distance is small and B is
substantially more frequent than A ("especially if A returns far fewer
results than B", §2.4).

The batched banded DP is the Pallas kernel in ``kernels/edit_distance.py``;
``kernels/ref.py`` holds the jnp oracle. This module is the host-side
orchestration: string prep, tiling over the all-pairs space, and filtering.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

MAX_QUERY_CHARS = 24


@dataclasses.dataclass(frozen=True)
class SpellConfig:
    max_len: int = MAX_QUERY_CHARS
    max_distance: float = 2.0      # weighted-edit acceptance threshold
    min_len: int = 4               # too-short strings are too noisy
    freq_boost: float = 3.0        # weight(B) must exceed boost * weight(A)
    first_char_cost: float = 1.5   # the paper's positional weighting
    tile: int = 256                # pair tile per device call
    use_kernel: bool = True


def normalize_query(text: str) -> str:
    """Strip Twitter sigils; lowercase; collapse whitespace."""
    toks = []
    for tok in text.lower().split():
        while tok[:1] in ("@", "#"):
            tok = tok[1:]
        if tok:
            toks.append(tok)
    return " ".join(toks)


def encode_strings(texts: List[str], max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """-> (chars u8[N, max_len] zero-padded, lengths i32[N])."""
    n = len(texts)
    chars = np.zeros((n, max_len), np.uint8)
    lens = np.zeros((n,), np.int32)
    for i, t in enumerate(texts):
        b = t.encode("utf-8")[:max_len]
        chars[i, : len(b)] = np.frombuffer(b, np.uint8)
        lens[i] = len(b)
    return chars, lens


def spelling_cycle(
    fps: np.ndarray,
    texts: List[str],
    weights: np.ndarray,
    cfg: SpellConfig = SpellConfig(),
) -> Dict[int, Tuple[int, float]]:
    """All-pairs weighted edit distance over the given queries.

    Returns {misspelled_fp: (corrected_fp, weighted_distance)} keeping, per
    source, the lowest-distance candidate (frequency used as tie-break).
    """
    from ..kernels import ops as kops

    norm = [normalize_query(t) for t in texts]
    chars, lens = encode_strings(norm, cfg.max_len)
    n = len(texts)
    out: Dict[int, Tuple[int, float]] = {}
    order = np.argsort(-weights)  # scan high-frequency candidates first
    chars_s, lens_s = chars[order], lens[order]
    w_s, fp_s = weights[order], fps[order]

    best_d = np.full((n,), np.inf, np.float64)
    # tile the (source x candidate) pair space
    for a0 in range(0, n, cfg.tile):
        a1 = min(a0 + cfg.tile, n)
        for b0 in range(0, n, cfg.tile):
            b1 = min(b0 + cfg.tile, n)
            ai = np.arange(a0, a1)
            bi = np.arange(b0, b1)
            # quick pruning: candidates must be notably more frequent
            pair_ok = (w_s[bi][None, :] >= cfg.freq_boost * w_s[ai][:, None])
            pair_ok &= (lens_s[ai][:, None] >= cfg.min_len)
            pair_ok &= np.abs(lens_s[ai][:, None] - lens_s[bi][None, :]) <= int(cfg.max_distance)
            if not pair_ok.any():
                continue
            aa, bb = np.nonzero(pair_ok)
            d = kops.edit_distance(
                chars_s[ai[aa]], lens_s[ai[aa]],
                chars_s[bi[bb]], lens_s[bi[bb]],
                first_char_cost=cfg.first_char_cost,
                use_kernel=cfg.use_kernel,
            )
            d = np.asarray(d)
            for k in range(len(aa)):
                i_src = a0 + aa[k]
                dk = float(d[k])
                if 0.0 < dk <= cfg.max_distance and dk < best_d[i_src]:
                    best_d[i_src] = dk
                    out[int(fp_s[i_src])] = (int(fp_s[b0 + bb[k]]), dk)
    return out
