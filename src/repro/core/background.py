"""Background models + serve-time interpolation (paper §4.5).

"The first [mechanism] involves running the same search assistance backend,
except over data spanning much longer periods of time, but with different
parameter settings (decay, pruning, etc.)" — we instantiate a second engine
with a slow decay config and a lower ranking cadence; the frontend
interpolates its suggestions with the real-time engine's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .decay import DecayConfig
from .engine import EngineConfig, SearchAssistanceEngine
from .ranking import RankConfig


def background_config(rt_cfg: EngineConfig, *, half_life_mult: float = 24.0,
                      rank_every_mult: int = 12) -> EngineConfig:
    """Derive the slow-moving background config from the real-time one."""
    slow_decay = dataclasses.replace(
        rt_cfg.decay,
        half_life_ticks=rt_cfg.decay.half_life_ticks * half_life_mult,
        prune_threshold=rt_cfg.decay.prune_threshold * 0.5,
    )
    return dataclasses.replace(
        rt_cfg,
        decay=slow_decay,
        rank_every=rt_cfg.rank_every * rank_every_mult,
        decay_every=rt_cfg.decay_every * 4,
    )


def interpolate(
    rt: Dict[int, List[Tuple[int, float]]],
    bg: Dict[int, List[Tuple[int, float]]],
    alpha: float = 0.7,
    k: int = 8,
) -> Dict[int, List[Tuple[int, float]]]:
    """Frontend interpolation of real-time and background suggestion tables.

    score = alpha * rt + (1 - alpha) * bg, union over candidates.
    """
    out: Dict[int, List[Tuple[int, float]]] = {}
    for src in set(rt) | set(bg):
        merged: Dict[int, float] = {}
        for dst, s in rt.get(src, []):
            merged[dst] = merged.get(dst, 0.0) + alpha * s
        for dst, s in bg.get(src, []):
            merged[dst] = merged.get(dst, 0.0) + (1.0 - alpha) * s
        ranked = sorted(merged.items(), key=lambda t: (-t[1], t[0]))[:k]
        if ranked:
            out[src] = ranked
    return out


class AssistanceService:
    """Real-time engine + background engine + interpolating frontend.

    Both engines consume the *same* hoses (and therefore the same durable
    firehose log), each under its own cadence authority — which is what
    makes the whole service restartable: ``streaming.replay.recover_service``
    restores each engine from its own snapshot chain and replays the shared
    log tail per engine (``rt`` from its offset at the rt cadences, ``bg``
    from its offset at the bg cadences), then rebuilds this cache.
    ``rt``/``bg`` can be injected for exactly that recovery path.

    With ``slo`` set (a ``streaming.overload.SLOConfig``), ``step`` routes
    through an :class:`~repro.streaming.overload.OverloadController`:
    lag-adaptive micro-batching over the fused ``ingest_many`` scan plus
    the degradation ladder (shed rt ranking -> stretch bg ranking ->
    admission-control ingest), every shed counted. ``mirrors`` are extra
    follower rt engines fed the same flushed stacks (replica failover).
    """

    def __init__(self, rt_cfg: Optional[EngineConfig] = None,
                 alpha: float = 0.7,
                 bg_cfg: Optional[EngineConfig] = None,
                 rt: Optional[SearchAssistanceEngine] = None,
                 bg: Optional[SearchAssistanceEngine] = None,
                 slo=None, mirrors=()):
        assert rt is not None or rt_cfg is not None
        self.rt = rt if rt is not None \
            else SearchAssistanceEngine(rt_cfg, name="rt")
        if bg is None:
            # derive the slow config from the injected engine's cfg when
            # only `rt` was passed
            bg_cfg = bg_cfg or background_config(
                rt_cfg if rt_cfg is not None else self.rt.cfg)
            bg = SearchAssistanceEngine(bg_cfg, name="bg")
        self.bg = bg
        self.alpha = alpha
        self._cache: Dict[int, List[Tuple[int, float]]] = {}
        self.overload = None
        if slo is not None:
            from ..streaming.overload import OverloadController
            self.overload = OverloadController(self, slo, mirrors=mirrors)

    def step(self, query_events=None, tweets=None, *, log_append=None,
             lag_hint: float = 0.0) -> Optional[Dict]:
        """Feed one tick to both engines; returns the per-engine rank-cycle
        stats (``{"rt": ..., "bg": ...}``) when either engine ranked.

        ``log_append(tick, events, tweets)`` is called BEFORE ingestion in
        both paths (durability precedes state mutation — under overload
        control it receives the admission-controlled batch, which is what
        makes mid-shed crash recovery bit-exact). ``lag_hint`` is the
        caller's external backlog estimate in ticks (arrival tick minus
        ingested tick under simulated pacing); the overload controller
        max-combines it with its own buffer backlog.
        """
        if self.overload is not None:
            return self.overload.offer(query_events, tweets,
                                       log_append=log_append,
                                       lag_hint=lag_hint)
        if log_append is not None:
            log_append(int(self.rt.state.tick), query_events, tweets)
        r1 = self.rt.step(query_events, tweets)
        r2 = self.bg.step(query_events, tweets)
        if r1 is not None or r2 is not None:
            self.refresh_cache()
            return {"rt": r1, "bg": r2}
        return None

    def drain(self) -> Optional[Dict]:
        """Flush any ticks the overload micro-batcher still buffers (no-op
        without overload control)."""
        if self.overload is not None:
            return self.overload.drain()
        return None

    def refresh_cache(self) -> None:
        self._cache = interpolate(self.rt.suggestions, self.bg.suggestions,
                                  self.alpha)

    @property
    def suggestions(self) -> Dict[int, List[Tuple[int, float]]]:
        """The interpolated suggestion table the frontend serves."""
        return self._cache

    def suggest_fp(self, fp: int, k: int = 8) -> List[Tuple[int, float]]:
        return self._cache.get(int(fp), [])[:k]

    # ---- persistence: the whole stack snapshots, not just the rt half ----
    def save_snapshot(self, rt_ckpt, bg_ckpt,
                      extra_meta: Optional[Dict] = None) -> Tuple[str, str]:
        """Snapshot BOTH engines (each = checkpoint + its log offset).

        Each manager may be delta-chained (``CheckpointManager.full_interval
        > 1``): the bg engine's slow-moving long-horizon state is where
        delta snapshots pay off most — few slots change per interval, so
        the chain lets the snapshot cadence shrink without a write-volume
        blowup, and the replay tail (time-to-fresh) shrinks with it.

        Under overload control the controller's stats ride along in the
        meta (``overload`` key) so frontends can surface the degradation
        level and shed counters of the backend that produced the tables.
        """
        if self.overload is not None:
            extra_meta = dict(extra_meta or {})
            extra_meta.setdefault("overload",
                                  self.overload.stats_snapshot())
        return (self.rt.save_snapshot(rt_ckpt, extra_meta),
                self.bg.save_snapshot(bg_ckpt, extra_meta))
