"""64-bit fingerprints and table hashing, without jax x64.

JAX defaults to 32-bit integer types (x64 disabled); enabling x64 globally
would perturb every model's dtypes. We therefore represent 64-bit
fingerprints as two uint32 lanes ``(hi, lo)`` everywhere on device, and
compute probe positions with 32-bit avalanche mixing of both lanes.

Host-side fingerprinting (strings -> fp64) uses FNV-1a, implemented both for
scalars (python ints) and numpy batches so the tokenizer can vectorize.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a_64(data: bytes) -> int:
    """FNV-1a 64-bit hash of a byte string. fp 0 is reserved -> remapped to 1."""
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & _MASK64
    return h or 1


def fingerprint(text: str) -> int:
    return fnv1a_64(text.encode("utf-8"))


def combine_fp(a: int, b: int) -> int:
    """Order-sensitive 64-bit combine of two fingerprints (directed pairs)."""
    h = (a ^ 0x9E3779B97F4A7C15) & _MASK64
    h = (h * FNV_PRIME) & _MASK64
    h ^= b
    h = (h * FNV_PRIME) & _MASK64
    h ^= h >> 29
    return h or 1


def split_fp(fp) -> tuple:
    """fp64 -> (hi, lo) uint32 pair. Works on python ints and numpy arrays."""
    if isinstance(fp, (int, np.integer)):
        return np.uint32((fp >> 32) & 0xFFFFFFFF), np.uint32(fp & 0xFFFFFFFF)
    fp = np.asarray(fp, dtype=np.uint64)
    return (fp >> np.uint64(32)).astype(np.uint32), (fp & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def join_fp(hi, lo) -> np.ndarray:
    """(hi, lo) uint32 -> fp64 numpy uint64 (host-side only)."""
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | np.asarray(lo, np.uint64)


# ---------------------------------------------------------------------------
# Device-side (jnp) 32-bit mixing.
# ---------------------------------------------------------------------------

def _mix32(x):
    """murmur3 fmix32 finalizer — avalanche a uint32 lane."""
    x = jnp.asarray(x, jnp.uint32)
    x ^= x >> 16
    x = x * jnp.uint32(0x85EBCA6B)
    x ^= x >> 13
    x = x * jnp.uint32(0xC2B2AE35)
    x ^= x >> 16
    return x


def probe_hash(hi, lo):
    """Initial probe position hash from a (hi, lo) fingerprint pair."""
    return _mix32(jnp.asarray(hi, jnp.uint32) * jnp.uint32(0x9E3779B9) ^ _mix32(lo))


def combine_fp_device(a_hi, a_lo, b_hi, b_lo):
    """Device-side order-sensitive pair fingerprint -> (hi, lo) uint32.

    Not bit-identical to ``combine_fp`` (host); collision-equivalent quality.
    Both sides of the system (reference engine & JAX engine) must use the SAME
    combine — the reference calls this via numpy, see ``combine_fp_np``.
    """
    h1 = _mix32(jnp.asarray(a_hi, jnp.uint32) ^ jnp.uint32(0x9E3779B9))
    h1 = _mix32(h1 * jnp.uint32(0x85EBCA6B) ^ jnp.asarray(b_hi, jnp.uint32))
    h2 = _mix32(jnp.asarray(a_lo, jnp.uint32) * jnp.uint32(0xC2B2AE35) ^ jnp.uint32(0x27D4EB2F))
    h2 = _mix32(h2 ^ jnp.asarray(b_lo, jnp.uint32) * jnp.uint32(0x165667B1))
    # reserve (0, 0) as the empty marker
    h2 = jnp.where((h1 == 0) & (h2 == 0), jnp.uint32(1), h2)
    return h1, h2


def _mix32_np(x):
    x = np.asarray(x, np.uint32).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint32(16)
        x = (x * np.uint32(0x85EBCA6B)).astype(np.uint32)
        x ^= x >> np.uint32(13)
        x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
        x ^= x >> np.uint32(16)
    return x


def combine_fp_np(a_hi, a_lo, b_hi, b_lo):
    """numpy mirror of combine_fp_device (used by the reference engine)."""
    with np.errstate(over="ignore"):
        h1 = _mix32_np(np.asarray(a_hi, np.uint32) ^ np.uint32(0x9E3779B9))
        h1 = _mix32_np((h1 * np.uint32(0x85EBCA6B)).astype(np.uint32) ^ np.asarray(b_hi, np.uint32))
        h2 = _mix32_np((np.asarray(a_lo, np.uint32) * np.uint32(0xC2B2AE35)).astype(np.uint32) ^ np.uint32(0x27D4EB2F))
        h2 = _mix32_np(h2 ^ (np.asarray(b_lo, np.uint32) * np.uint32(0x165667B1)).astype(np.uint32))
    h2 = np.where((h1 == 0) & (h2 == 0), np.uint32(1), h2)
    return h1, h2
