"""The search assistance engine (paper §4.2–§4.3).

Each backend instance consists of
  * the **stats collector** — consumes the query hose and the firehose
    (here: micro-batched event arrays from ``data/stream.py``),
  * three **in-memory stores** (``stores.py``),
  * **rankers** — periodic ranking cycles over the stores (``ranking.py``),
plus the periodic **decay/prune cycles** and persistence hooks.

The data flow mirrors §4.3 exactly:

Query path (per query event):
  1. query statistics store: raw count + source-weighted score update,
  2. sessions store: append to the session's sliding window,
  3. a cooccurrence is formed with each previous query in the session.

Tweet path (per tweet): n-grams that are "query-like" (observed often enough
as standalone queries) are processed like the query path, with the tweet
itself as the session (all ordered pairs among its query-like n-grams).

Decay/prune cycles and ranking cycles run at configurable tick cadences.

Under the lazy decay policy (``DecayConfig.policy == "lazy"``) the
per-``decay_every`` full sweep disappears entirely: reads (ranking, lookup)
apply the decayed view per row, writes rebase-then-add, and only a
prune-only sweep runs, every ``prune_every`` ticks (see ``decay.py``).

Durability (paper §4.2): the engine itself is deliberately volatile — "the
importance of individual messages decreases over time, so losing a little
bit of state is tolerable ... a (re)started instance can rewind to an
earlier point in the [fire]hose and consume messages at a faster rate than
real time to catch up to the present". :func:`ingest_many` is the catch-up
primitive: one ``lax.scan`` over a stack of logged micro-batches (including
the in-scan decay/prune maintenance at the exact live cadences), one device
dispatch per chunk instead of one per tick. ``streaming/`` provides the
durable log and the replay controller built on it; snapshots ride on
``distributed/fault_tolerance.CheckpointManager`` with the log offset
recorded in the manifest (snapshot = checkpoint + log offset). Snapshots
may be *incremental*: a manager with ``full_interval > 1`` writes delta
checkpoints (changed store slots only) chained to the last full one, which
shrinks the write volume enough to snapshot ~4x more often — and with the
cadence, the replay tail a restart must cover. The whole serving stack
(rt + background engine + interpolation, ``core/background.py``) recovers
through the same path: ``streaming.replay.recover_service``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ranking, stores
from .decay import (DecayConfig, prune_sweep, region_decay_sweep,
                    region_prune_sweep, sweep_decay_prune)
from .hashing import combine_fp_device, split_fp
from .plan import TunedPlan, default_region_width
from .ranking import RankConfig, SuggestionTable
from .stores import HashTable, RegionTable, SessionTable


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # store capacities (powers of two)
    query_capacity: int = 1 << 16
    cooc_capacity: int = 1 << 18
    session_capacity: int = 1 << 15
    session_window: int = 5
    probe_rounds: int = 16
    # source weighting (paper §4.2: typed > related click > hashtag click)
    source_weights: Tuple[float, ...] = (1.0, 0.5, 0.7)
    tweet_weight: float = 0.25
    min_querylike_count: float = 3.0   # tweet n-gram must be a real query
    max_tweet_grams: int = 16
    # cycles (in ticks; a tick is one micro-batch ~ cfg.tick_seconds of data)
    decay_every: int = 6
    rank_every: int = 30               # ~5 sim-minutes at 10 s ticks (§2.3)
    # lazy decay policy only: full sweeps leave the per-``decay_every`` path
    # entirely (reads decay themselves); a prune-only sweep reclaims slots
    # at this much longer cadence. Tuned via the (prune_every, decay_every)
    # sweeps in bench_churn/bench_memory_coverage: suggestion churn and
    # coverage are cadence-INVARIANT under the lazy policy (read-time decay
    # is exact), so the cadence only trades live-slot load / probe-failure
    # drops against sweep cost — 24 matches 48's quality with lower table
    # load (0.24 vs 0.31 live at the sweep's pressure point) and ~7x fewer
    # drops under capacity pressure.
    prune_every: int = 24
    session_ttl: int = 360
    decay: DecayConfig = DecayConfig()
    rank: RankConfig = RankConfig()
    # Legacy kernel override: None (default) defers each hot path to the
    # tuned ``plan`` below; an explicit bool forces every store/decay hot
    # path to its kernel (True) or jnp (False) variant regardless of plan.
    use_kernel: Optional[bool] = None
    # The measured per-hot-path dispatch plan (``core/plan.TunedPlan``,
    # built by ``launch/autotune``). None = all-jnp reference dispatch.
    # Rides snapshot meta so a recovered engine keeps its tuning. Plans are
    # result-invariant: any two plans produce bit-exact engine states.
    plan: Optional[TunedPlan] = None
    # The semantic ingest slice: step()/ingest_many ALWAYS break a query
    # micro-batch larger than this into sequential quantum-sized slices
    # (plan-INDEPENDENT, so tuning cannot change results; the plan's
    # ``ingest_chunk`` only fuses quantum slices into one dispatch). This
    # is the large-batch-cliff fix: insert_accumulate's conflict-resolve
    # rounds degrade superlinearly past ~4k events. 0 disables slicing.
    ingest_quantum: int = 4096
    # cooccurrence-store layout: "hash" = open addressing keyed by the pair
    # fingerprint; "region" = source-major region layout (fixed-width
    # per-source regions, chain directory indexed by qstore slot — see
    # stores.RegionTable). The region layout makes every ranking bucket a
    # pure reshape and drops the four endpoint lanes from the store.
    cooc_layout: str = "hash"
    # pairs per region; None derives from cooc capacity via
    # ``plan.default_region_width`` ({2^16: 16, 2^18: 32, 2^20: 64} — read
    # it through ``region_w``). Real-TPU deployments want 128.
    region_width: Optional[int] = None
    region_chain: int = 8              # max spill-chain regions per source

    def __post_init__(self):
        if self.cooc_layout not in ("hash", "region"):
            raise ValueError(
                f"unknown cooc_layout {self.cooc_layout!r} "
                f"(expected 'hash' or 'region')")
        # the ranking hot paths read the plan off RankConfig; attach it so
        # callers only ever set EngineConfig.plan. An explicitly planned
        # RankConfig wins (it was set on purpose).
        if self.plan is not None and self.rank.plan is None:
            object.__setattr__(
                self, "rank", dataclasses.replace(self.rank, plan=self.plan))

    @property
    def lazy_decay(self) -> bool:
        return self.decay.policy == "lazy"

    @property
    def region_cooc(self) -> bool:
        return self.cooc_layout == "region"

    @property
    def region_w(self) -> int:
        """Effective region width (explicit override or capacity-derived)."""
        if self.region_width is not None:
            return self.region_width
        return default_region_width(self.cooc_capacity)

    def kernel_on(self, op: str) -> bool:
        """Kernel-vs-jnp resolution for one hot path: the legacy
        ``use_kernel`` bool wins; else the tuned plan; else jnp."""
        if self.use_kernel is not None:
            return self.use_kernel
        if self.plan is not None:
            return self.plan.uses_kernel(op)
        return False


class EngineState(NamedTuple):
    qstore: HashTable
    cooc: HashTable
    sessions: SessionTable
    tick: jax.Array  # i32


def make_cooc_store(cfg: EngineConfig, capacity: Optional[int] = None):
    """The cooccurrence store under ``cfg.cooc_layout`` (``capacity``
    overrides ``cfg.cooc_capacity`` — the sharded engine divides it)."""
    cap = capacity if capacity is not None else cfg.cooc_capacity
    if cfg.region_cooc:
        return stores.make_region_table(
            cap, cfg.region_w, cfg.query_capacity, cfg.region_chain, {
                "weight": jnp.float32, "count": jnp.float32,
                "last_tick": jnp.int32})
    return stores.make_table(cap, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
        "src_hi": jnp.uint32, "src_lo": jnp.uint32,
        "dst_hi": jnp.uint32, "dst_lo": jnp.uint32,
    })


def init_state(cfg: EngineConfig) -> EngineState:
    qstore = stores.make_table(cfg.query_capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
    })
    cooc = make_cooc_store(cfg)
    sessions = stores.make_session_table(cfg.session_capacity, cfg.session_window)
    return EngineState(qstore, cooc, sessions, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# jitted step functions
# ---------------------------------------------------------------------------

_Q_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))
_C_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"),
            ("src_hi", "set"), ("src_lo", "set"),
            ("dst_hi", "set"), ("dst_lo", "set"))
_R_MODES = _Q_MODES   # region layout: endpoints live in keys/directory


def cooc_insert_pairs(cooc, qstore: HashTable, src_hi, src_lo, dst_hi,
                      dst_lo, w_pair, valid, tick, cfg: EngineConfig, dkw):
    """Layout dispatch for one micro-batch of (src -> dst) pair updates —
    shared by the query path, the tweet path and the sharded engine."""
    P = src_hi.shape[0]
    count = jnp.ones((P,), jnp.float32)
    lt = jnp.full((P,), tick, jnp.int32)
    if cfg.region_cooc:
        return stores.region_insert_accumulate(
            cooc, qstore, src_hi, src_lo, dst_hi, dst_lo,
            {"weight": w_pair, "count": count, "last_tick": lt},
            valid, modes=_R_MODES, probe_rounds=cfg.probe_rounds,
            use_kernel=cfg.use_kernel, plan=cfg.plan, **dkw)
    p_hi, p_lo = combine_fp_device(src_hi, src_lo, dst_hi, dst_lo)
    return stores.insert_accumulate(
        cooc, p_hi, p_lo,
        {"weight": w_pair, "count": count, "last_tick": lt,
         "src_hi": src_hi, "src_lo": src_lo,
         "dst_hi": dst_hi, "dst_lo": dst_lo},
        valid, modes=_C_MODES, probe_rounds=cfg.probe_rounds, **dkw)


@partial(jax.jit, static_argnames=("cfg",))
def ingest_queries(
    state: EngineState,
    sess_hi: jax.Array, sess_lo: jax.Array,
    q_hi: jax.Array, q_lo: jax.Array,
    src: jax.Array, valid: jax.Array,
    *, cfg: EngineConfig,
) -> EngineState:
    """The query path of §4.3 for one micro-batch."""
    sw = jnp.asarray(cfg.source_weights, jnp.float32)
    w = sw[jnp.clip(src, 0, len(cfg.source_weights) - 1)]
    B = q_hi.shape[0]
    tick_vec = jnp.full((B,), state.tick, jnp.int32)
    # lazy policy: rebase-on-write so refreshing last_tick never un-decays
    dkw = dict(decay_cfg=cfg.decay, now=state.tick) if cfg.lazy_decay else {}

    qstore = stores.insert_accumulate(
        state.qstore, q_hi, q_lo,
        {"weight": w, "count": jnp.ones((B,), jnp.float32), "last_tick": tick_vec},
        valid, modes=_Q_MODES, probe_rounds=cfg.probe_rounds, **dkw)

    sessions, pairs = stores.update_sessions(
        state.sessions, sess_hi, sess_lo, q_hi, q_lo, src, state.tick, valid,
        probe_rounds=cfg.probe_rounds)

    # pair weight: geometric mean of the two interaction-source weights
    w_src = sw[jnp.clip(pairs.src_code, 0, len(cfg.source_weights) - 1)]
    w_dst = sw[jnp.clip(pairs.dst_code, 0, len(cfg.source_weights) - 1)]
    w_pair = jnp.sqrt(w_src * w_dst)
    cooc = cooc_insert_pairs(state.cooc, qstore, pairs.src_hi, pairs.src_lo,
                             pairs.dst_hi, pairs.dst_lo, w_pair, pairs.valid,
                             state.tick, cfg, dkw)

    return EngineState(qstore, cooc, sessions, state.tick)


def quantum_slices(B: int, quantum: int) -> List[Tuple[int, int]]:
    """THE statement of where an oversized query micro-batch is cut.

    ``EngineConfig.ingest_quantum`` is semantic: slice boundaries depend
    only on (B, quantum) — never on the tuned plan — so live ``step()``,
    the replay scan and every plan produce identical ingest sequences.
    """
    if quantum <= 0 or B <= quantum:
        return [(0, B)]
    return [(off, min(off + quantum, B)) for off in range(0, B, quantum)]


@partial(jax.jit, static_argnames=("cfg",))
def ingest_queries_stack(state: EngineState, sess_hi, sess_lo, q_hi, q_lo,
                         src, valid, *, cfg: EngineConfig) -> EngineState:
    """K same-tick quantum slices (leading dim K) in ONE device dispatch:
    a ``lax.scan`` whose body is exactly :func:`ingest_queries`, so the
    result is bit-identical to K separate dispatches — the plan's
    ``ingest_chunk`` buys dispatch amortization only."""
    def body(st, xs):
        return ingest_queries(st, *xs, cfg=cfg), None

    state, _ = jax.lax.scan(body, state,
                            (sess_hi, sess_lo, q_hi, q_lo, src, valid))
    return state


@partial(jax.jit, static_argnames=("cfg",))
def ingest_tweets(
    state: EngineState,
    g_hi: jax.Array, g_lo: jax.Array,   # [T, G]
    valid: jax.Array,                    # [T]
    *, cfg: EngineConfig,
) -> EngineState:
    """The tweet path of §4.3 for one micro-batch of tweets."""
    T, G = g_hi.shape
    flat_hi, flat_lo = g_hi.reshape(-1), g_lo.reshape(-1)
    vals, found, _ = stores.lookup(state.qstore, flat_hi, flat_lo,
                                   probe_rounds=cfg.probe_rounds)
    querylike = (found & (vals["count"] >= cfg.min_querylike_count)
                 & valid[:, None].repeat(G, 1).reshape(-1))
    B = T * G
    tick_vec = jnp.full((B,), state.tick, jnp.int32)
    w = jnp.full((B,), cfg.tweet_weight, jnp.float32)
    dkw = dict(decay_cfg=cfg.decay, now=state.tick) if cfg.lazy_decay else {}
    qstore = stores.insert_accumulate(
        state.qstore, flat_hi, flat_lo,
        {"weight": w, "count": jnp.ones((B,), jnp.float32), "last_tick": tick_vec},
        querylike, modes=_Q_MODES, probe_rounds=cfg.probe_rounds, **dkw)

    # all ordered pairs among query-like grams of the same tweet
    ql = querylike.reshape(T, G)
    src_hi = jnp.broadcast_to(g_hi[:, :, None], (T, G, G)).reshape(-1)
    src_lo = jnp.broadcast_to(g_lo[:, :, None], (T, G, G)).reshape(-1)
    dst_hi = jnp.broadcast_to(g_hi[:, None, :], (T, G, G)).reshape(-1)
    dst_lo = jnp.broadcast_to(g_lo[:, None, :], (T, G, G)).reshape(-1)
    ok = (ql[:, :, None] & ql[:, None, :]).reshape(-1)
    same = (src_hi == dst_hi) & (src_lo == dst_lo)
    ok = ok & ~same
    P = src_hi.shape[0]
    cooc = cooc_insert_pairs(
        state.cooc, qstore, src_hi, src_lo, dst_hi, dst_lo,
        jnp.full((P,), cfg.tweet_weight, jnp.float32), ok, state.tick,
        cfg, dkw)
    return EngineState(qstore, cooc, state.sessions, state.tick)


@partial(jax.jit, static_argnames=("cfg",))
def decay_cycle(state: EngineState, dticks: jax.Array, *, cfg: EngineConfig
                ) -> Tuple[EngineState, Dict[str, jax.Array]]:
    """Decay/prune cycle (§4.3): decay all weights, prune small entries and
    stale sessions. Runs every ``decay_every`` ticks under the (paper
    faithful) eager "sweep" policy only."""
    qstore, q_live, q_tot = sweep_decay_prune(
        state.qstore, dticks, cfg=cfg.decay, weight_lanes=("weight",),
        use_kernel=cfg.kernel_on("decay_prune"))
    stats: Dict[str, jax.Array] = {"q_live": q_live, "q_total_w": q_tot}
    if cfg.region_cooc:
        # region maintenance validates chains against the post-sweep
        # qstore, so chains of just-pruned sources free immediately.
        cooc, c_live, c_tot, c_rec = region_decay_sweep(
            state.cooc, qstore, dticks, cfg=cfg.decay)
        stats["c_reclaimed"] = c_rec
        stats["c_free_regions"] = cooc.free_regions()
    else:
        cooc, c_live, c_tot = sweep_decay_prune(
            state.cooc, dticks, cfg=cfg.decay, weight_lanes=("weight",),
            use_kernel=cfg.kernel_on("decay_prune"))
    sessions = stores.evict_sessions(state.sessions, state.tick, cfg.session_ttl)
    stats.update({"c_live": c_live, "c_total_w": c_tot})
    return EngineState(qstore, cooc, sessions, state.tick), stats


@partial(jax.jit, static_argnames=("cfg",))
def evict_sessions_cycle(state: EngineState, *, cfg: EngineConfig
                         ) -> EngineState:
    """Session-TTL eviction alone — an O(session_capacity) mask, no weight
    sweep. Under the lazy policy this keeps eviction on the ``decay_every``
    cadence (TTL semantics are unrelated to weight-decay laziness) while
    the store sweeps move to ``prune_every``."""
    sessions = stores.evict_sessions(state.sessions, state.tick,
                                     cfg.session_ttl)
    return state._replace(sessions=sessions)


@partial(jax.jit, static_argnames=("cfg",))
def prune_cycle(state: EngineState, *, cfg: EngineConfig
                ) -> Tuple[EngineState, Dict[str, jax.Array]]:
    """Lazy policy's slow-cadence maintenance: prune-only sweep (decay is
    amortized into reads/writes), every ``prune_every`` ticks. Stats
    report the reclaimed-slot counts (and, under the region layout, the
    freelist pressure) so the engine can surface them to the frontends."""
    qstore, q_live, q_tot, q_rec = prune_sweep(state.qstore, state.tick,
                                               cfg=cfg.decay)
    if cfg.region_cooc:
        cooc, c_live, c_tot, c_rec = region_prune_sweep(
            state.cooc, qstore, state.tick, cfg=cfg.decay)
    else:
        cooc, c_live, c_tot, c_rec = prune_sweep(state.cooc, state.tick,
                                                 cfg=cfg.decay)
    sessions = stores.evict_sessions(state.sessions, state.tick, cfg.session_ttl)
    stats = {"q_live": q_live, "q_total_w": q_tot,
             "c_live": c_live, "c_total_w": c_tot,
             "q_reclaimed": q_rec, "c_reclaimed": c_rec}
    if cfg.region_cooc:
        stats["c_free_regions"] = cooc.free_regions()
    return EngineState(qstore, cooc, sessions, state.tick), stats


@jax.jit
def advance_tick(state: EngineState) -> EngineState:
    return state._replace(tick=state.tick + 1)


# ---------------------------------------------------------------------------
# Fused multi-tick ingestion (the §4.2 catch-up primitive)
# ---------------------------------------------------------------------------

class TickStack(NamedTuple):
    """A stack of R consecutive micro-batches (leading dim = tick).

    Shapes: query lanes are [R, B] (B may be 0: no query hose), tweet grams
    are [R, T, G] with valid [R, T] (T or G may be 0: no firehose).
    """
    sess_hi: jax.Array
    sess_lo: jax.Array
    q_hi: jax.Array
    q_lo: jax.Array
    src: jax.Array
    q_valid: jax.Array
    g_hi: jax.Array
    g_lo: jax.Array
    t_valid: jax.Array

    @property
    def n_ticks(self) -> int:
        return self.sess_hi.shape[0]


def rank_due(cfg: EngineConfig, tick: int) -> bool:
    """Is a ranking cycle due at ``tick``? The single statement of the
    rank cadence, shared by live ``step()``, the catch-up replay counting
    (``streaming/replay.py``) and the overload controller's rank
    governance (``streaming/overload.py``) — shed/suppressed cycles are
    counted against exactly this predicate."""
    return cfg.rank_every > 0 and tick > 0 and tick % cfg.rank_every == 0


def cadence_due(cfg: EngineConfig, tick: int) -> Optional[str]:
    """Which maintenance cycle is due at ``tick`` (host-side, concrete).

    THE single statement of the cadence semantics: ``step()`` branches on
    it live, ``step_many()`` counts cycle crossings with it, and
    ``maintenance_cadence`` below is its traced twin for the replay scans
    (the crash→restore→replay bit-for-bit property test pins the two
    together). Lazy policy: "prune" at ``prune_every`` wins over "evict"
    at ``decay_every`` (the prune cycle evicts sessions itself); eager
    policy: "decay" at ``decay_every``.
    """
    if tick <= 0:
        return None
    if cfg.lazy_decay:
        if cfg.prune_every > 0 and tick % cfg.prune_every == 0:
            return "prune"
        if cfg.decay_every > 0 and tick % cfg.decay_every == 0:
            return "evict"
        return None
    if cfg.decay_every > 0 and tick % cfg.decay_every == 0:
        return "decay"
    return None


def maintenance_cadence(state, tick: jax.Array, cfg: EngineConfig,
                        prune_fn, evict_fn, decay_fn):
    """Traced twin of :func:`cadence_due` as ``lax.cond``s, shared by the
    unsharded and sharded replay scans — same prune-wins/evict/decay
    ladder, same ``tick > 0`` guard. ``state`` may be any pytree the
    branch callables accept.
    """
    ident = lambda s: s
    if cfg.lazy_decay:
        prune_on = cfg.prune_every > 0
        evict_on = cfg.decay_every > 0
        do_prune = ((tick > 0) & (tick % max(cfg.prune_every, 1) == 0)
                    if prune_on else None)
        do_evict = ((tick > 0) & (tick % max(cfg.decay_every, 1) == 0)
                    if evict_on else None)
        if prune_on and evict_on:
            return jax.lax.cond(
                do_prune, prune_fn,
                lambda s: jax.lax.cond(do_evict, evict_fn, ident, s), state)
        if prune_on:
            return jax.lax.cond(do_prune, prune_fn, ident, state)
        if evict_on:
            return jax.lax.cond(do_evict, evict_fn, ident, state)
        return state
    if cfg.decay_every > 0:
        do_decay = (tick > 0) & (tick % cfg.decay_every == 0)
        return jax.lax.cond(do_decay, decay_fn, ident, state)
    return state


def tick_maintenance(state: EngineState, cfg: EngineConfig) -> EngineState:
    """Traced equivalent of the host-side cadence logic in ``step()``.

    Runs the decay/prune/evict cycle due at ``state.tick`` (if any) so a
    replayed tick performs exactly the same state mutations as a live one —
    the crash→restore→replay == uninterrupted-run property depends on it.
    Ranking is deliberately absent: rank cycles read state but never mutate
    it, so replay may suppress them freely (§4.2: serve stale tables while
    catching up).
    """
    return maintenance_cadence(
        state, state.tick, cfg,
        prune_fn=lambda s: prune_cycle(s, cfg=cfg)[0],
        evict_fn=lambda s: evict_sessions_cycle(s, cfg=cfg),
        decay_fn=lambda s: decay_cycle(s, jnp.int32(cfg.decay_every),
                                       cfg=cfg)[0])


@partial(jax.jit, static_argnames=("cfg",))
def ingest_many(state: EngineState, stack: TickStack, *, cfg: EngineConfig
                ) -> EngineState:
    """Replay R logged ticks in ONE device dispatch (``lax.scan``).

    Per scan iteration this performs exactly what one live ``step()`` does to
    ``EngineState`` — query-path ingest, tweet-path ingest, then the cadence
    maintenance, then the tick advance — so replaying a logged tail is
    bit-for-bit identical to having lived through it. The win over live
    stepping is dispatch amortization: no per-tick host sync, one fused XLA
    program per chunk — which is what lets a restarted instance "consume
    messages at a faster rate than real time" (§4.2).
    """
    have_q = stack.q_hi.shape[1] > 0
    have_t = stack.g_hi.shape[1] > 0 and stack.g_hi.shape[2] > 0

    def body(st: EngineState, xs: TickStack):
        if have_q:
            # oversized tick batches cut at the SAME quantum boundaries as
            # live step() (statically unrolled inside the one scan dispatch)
            for lo, hi in quantum_slices(stack.q_hi.shape[1],
                                         cfg.ingest_quantum):
                st = ingest_queries(st, xs.sess_hi[lo:hi], xs.sess_lo[lo:hi],
                                    xs.q_hi[lo:hi], xs.q_lo[lo:hi],
                                    xs.src[lo:hi], xs.q_valid[lo:hi], cfg=cfg)
        if have_t:
            st = ingest_tweets(st, xs.g_hi, xs.g_lo, xs.t_valid, cfg=cfg)
        st = tick_maintenance(st, cfg)
        return advance_tick(st), None

    state, _ = jax.lax.scan(body, state, stack)
    return state


# ---------------------------------------------------------------------------
# Host orchestrator
# ---------------------------------------------------------------------------

class SearchAssistanceEngine:
    """Host-side driver of one backend instance (paper Figure 4).

    Call :meth:`step` once per tick with the tick's micro-batches; the engine
    runs decay and ranking cycles at their configured cadences and keeps the
    latest suggestion table for the frontend.
    """

    def __init__(self, cfg: EngineConfig, name: str = "rt"):
        self.cfg = cfg
        self.name = name
        self.state = init_state(cfg)
        self.suggestions: Dict[int, List[Tuple[int, float]]] = {}
        self.last_rank_tick: int = -1
        self.n_rank_cycles = 0
        self.n_decay_cycles = 0
        self.n_prune_cycles = 0
        # last maintenance-cycle stats (reclaimed slots, freelist
        # pressure); rides into snapshot meta -> SuggestFrontend.metrics().
        self.last_maintenance: Dict[str, float] = {}

    # ---- ingestion ----
    def step(self, query_events=None, tweets=None) -> Optional[Dict]:
        """Process one tick. Returns rank-cycle stats when a cycle ran."""
        out = None
        if query_events is not None:
            s_hi, s_lo = split_fp(query_events.sess_fp)
            q_hi, q_lo = split_fp(query_events.q_fp)
            self._ingest_query_batch(
                jnp.asarray(s_hi), jnp.asarray(s_lo),
                jnp.asarray(q_hi), jnp.asarray(q_lo),
                jnp.asarray(query_events.src, jnp.int32),
                jnp.asarray(query_events.valid))
        if tweets is not None:
            g_hi, g_lo = split_fp(tweets.grams)
            self.state = ingest_tweets(
                self.state, jnp.asarray(g_hi), jnp.asarray(g_lo),
                jnp.asarray(tweets.valid), cfg=self.cfg)

        tick = int(self.state.tick)
        # one cadence authority for live, counters, and replay: cadence_due
        # (lazy: decay is amortized into reads/writes, only the prune-only
        # sweep remains at the longer prune cadence; session TTL eviction
        # stays on decay_every — a cheap mask with time-based semantics).
        due = cadence_due(self.cfg, tick)
        if due == "evict":
            self.state = evict_sessions_cycle(self.state, cfg=self.cfg)
        elif due == "prune":   # prune_cycle evicts sessions itself
            self.state, stats = prune_cycle(self.state, cfg=self.cfg)
            self.n_prune_cycles += 1
            self.last_maintenance = {k: float(v) for k, v in stats.items()}
        elif due == "decay":
            self.state, stats = decay_cycle(
                self.state, jnp.int32(self.cfg.decay_every), cfg=self.cfg)
            self.n_decay_cycles += 1
            self.last_maintenance = {k: float(v) for k, v in stats.items()}
        if rank_due(self.cfg, tick):
            out = self.run_rank_cycle()
        self.state = advance_tick(self.state)
        return out

    def _ingest_query_batch(self, *arrs) -> None:
        """Live side of the large-batch-cliff fix: cut the batch at the
        shared :func:`quantum_slices` boundaries, then fuse up to
        ``plan.ingest_chunk // quantum`` full slices into one dispatch via
        :func:`ingest_queries_stack`. The cut points are plan-independent;
        the fusion width changes dispatch count only, so any two plans
        leave bit-identical state."""
        cfg = self.cfg
        Q = cfg.ingest_quantum
        cuts = quantum_slices(arrs[2].shape[0], Q)
        if len(cuts) == 1:
            self.state = ingest_queries(self.state, *arrs, cfg=cfg)
            return
        chunk = cfg.plan.ingest_chunk if cfg.plan is not None else 0
        k = max(1, chunk // Q) if chunk > 0 else 1
        i = 0
        while i < len(cuts):
            lo, hi = cuts[i]
            n = 1
            if k > 1 and hi - lo == Q:
                while (i + n < len(cuts) and n < k
                       and cuts[i + n][1] - cuts[i + n][0] == Q):
                    n += 1
            if n > 1:
                sub = tuple(a[lo:lo + n * Q].reshape(n, Q) for a in arrs)
                self.state = ingest_queries_stack(self.state, *sub, cfg=cfg)
            else:
                self.state = ingest_queries(
                    self.state, *(a[lo:hi] for a in arrs), cfg=cfg)
            i += n

    def run_rank_cycle(self) -> Dict:
        dkw = (dict(decay_cfg=self.cfg.decay, now=self.state.tick)
               if self.cfg.lazy_decay else {})
        cycle = (ranking.ranking_cycle_region if self.cfg.region_cooc
                 else ranking.ranking_cycle)
        table = cycle(self.state.cooc, self.state.qstore,
                      self.cfg.rank, **dkw)
        self.suggestions = ranking.suggestions_to_host(table)
        self.last_rank_tick = int(self.state.tick)
        self.n_rank_cycles += 1
        return {"tick": self.last_rank_tick,
                "n_rows": int(table.n_rows),
                "n_overflow": int(table.n_overflow),
                "n_suggest": len(self.suggestions)}

    def step_many(self, stack: TickStack) -> None:
        """Fused multi-tick ingestion (catch-up replay / bulk live ingest).

        Applies :func:`ingest_many` and keeps the host-side cycle counters
        consistent with what the equivalent ``step()`` loop would have done.
        Ranking cycles are NOT run (the caller decides when lag is low
        enough to resume them — see ``streaming/replay.py``).
        """
        t0 = int(self.state.tick)
        self.state = ingest_many(self.state, stack, cfg=self.cfg)
        t1 = int(self.state.tick)
        due = [cadence_due(self.cfg, t) for t in range(t0, t1)]
        self.n_prune_cycles += sum(d == "prune" for d in due)
        self.n_decay_cycles += sum(d == "decay" for d in due)

    # ---- serving-side reads (the frontend cache pulls these) ----
    def suggest_fp(self, fp: int, k: int = 8) -> List[Tuple[int, float]]:
        return self.suggestions.get(int(fp), [])[:k]

    # ---- persistence (every rank cycle the leader persists, §4.2) ----
    def save_snapshot(self, ckpt, extra_meta: Optional[Dict] = None) -> str:
        """Snapshot = checkpoint + log offset (§4.2 rewind/catch-up).

        The manifest records ``log_tick`` — the first tick a restarted
        instance must replay from the firehose log to catch up to where
        this snapshot left off. Whether the manager writes a full
        checkpoint or a delta against the previous snapshot (changed slots
        only) is the manager's decision (``CheckpointManager.full_interval``);
        either way ``restore_from_snapshot`` sees the composed state.
        """
        tick = int(self.state.tick)
        meta = {"log_tick": tick, "engine": self.name,
                "layout": self.cfg.cooc_layout}
        if self.cfg.plan is not None:
            # the tuned plan rides the snapshot so a recovered engine keeps
            # its tuning without re-benchmarking (restore re-attaches it)
            meta["plan"] = self.cfg.plan.to_json()
        if self.last_maintenance:
            meta["maintenance"] = self.last_maintenance
        if extra_meta:
            meta.update(extra_meta)
        return ckpt.save(tick, self.state, meta=meta)

    @classmethod
    def restore_from_snapshot(cls, cfg: EngineConfig, ckpt,
                              step: Optional[int] = None, name: str = "rt"
                              ) -> Tuple["SearchAssistanceEngine", int]:
        """Cold-start from the newest (or a given) snapshot.

        Returns ``(engine, log_tick)``: the engine holds the restored
        ``EngineState`` and ``log_tick`` is the offset to resume replaying
        the firehose log from. The restore walks the snapshot's delta
        chain; when a torn/corrupt chain member forces the fallback to an
        older intact full snapshot (``ckpt.last_restore["fell_back"]``),
        the returned ``log_tick`` is that older snapshot's offset — replay
        simply covers the longer tail.
        """
        eng = cls(cfg, name)
        eng.state, step = ckpt.restore(eng.state, step)
        meta = ckpt.manifest(step).get("meta", {})
        if cfg.plan is None and meta.get("plan"):
            # re-attach the tuning that rode the snapshot (an explicitly
            # configured plan wins — the caller may have re-tuned)
            eng.cfg = dataclasses.replace(
                cfg, plan=TunedPlan.from_json(meta["plan"]))
        return eng, int(meta.get("log_tick", step))

    def state_arrays(self) -> Dict[str, np.ndarray]:
        leaves, treedef = jax.tree.flatten(self.state)
        return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}

    def load_state_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        leaves, treedef = jax.tree.flatten(self.state)
        new_leaves = [jnp.asarray(arrays[f"leaf_{i}"]) for i in range(len(leaves))]
        self.state = jax.tree.unflatten(treedef, new_leaves)
