"""The search assistance engine (paper §4.2–§4.3).

Each backend instance consists of
  * the **stats collector** — consumes the query hose and the firehose
    (here: micro-batched event arrays from ``data/stream.py``),
  * three **in-memory stores** (``stores.py``),
  * **rankers** — periodic ranking cycles over the stores (``ranking.py``),
plus the periodic **decay/prune cycles** and persistence hooks.

The data flow mirrors §4.3 exactly:

Query path (per query event):
  1. query statistics store: raw count + source-weighted score update,
  2. sessions store: append to the session's sliding window,
  3. a cooccurrence is formed with each previous query in the session.

Tweet path (per tweet): n-grams that are "query-like" (observed often enough
as standalone queries) are processed like the query path, with the tweet
itself as the session (all ordered pairs among its query-like n-grams).

Decay/prune cycles and ranking cycles run at configurable tick cadences.

Under the lazy decay policy (``DecayConfig.policy == "lazy"``) the
per-``decay_every`` full sweep disappears entirely: reads (ranking, lookup)
apply the decayed view per row, writes rebase-then-add, and only a
prune-only sweep runs, every ``prune_every`` ticks (see ``decay.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ranking, stores
from .decay import DecayConfig, prune_sweep, sweep_decay_prune
from .hashing import combine_fp_device, split_fp
from .ranking import RankConfig, SuggestionTable
from .stores import HashTable, SessionTable


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # store capacities (powers of two)
    query_capacity: int = 1 << 16
    cooc_capacity: int = 1 << 18
    session_capacity: int = 1 << 15
    session_window: int = 5
    probe_rounds: int = 16
    # source weighting (paper §4.2: typed > related click > hashtag click)
    source_weights: Tuple[float, ...] = (1.0, 0.5, 0.7)
    tweet_weight: float = 0.25
    min_querylike_count: float = 3.0   # tweet n-gram must be a real query
    max_tweet_grams: int = 16
    # cycles (in ticks; a tick is one micro-batch ~ cfg.tick_seconds of data)
    decay_every: int = 6
    rank_every: int = 30               # ~5 sim-minutes at 10 s ticks (§2.3)
    # lazy decay policy only: full sweeps leave the per-``decay_every`` path
    # entirely (reads decay themselves); a prune-only sweep reclaims slots
    # at this much longer cadence.
    prune_every: int = 48
    session_ttl: int = 360
    decay: DecayConfig = DecayConfig()
    rank: RankConfig = RankConfig()
    use_kernel: bool = False           # fused Pallas decay/prune + scoring

    @property
    def lazy_decay(self) -> bool:
        return self.decay.policy == "lazy"


class EngineState(NamedTuple):
    qstore: HashTable
    cooc: HashTable
    sessions: SessionTable
    tick: jax.Array  # i32


def init_state(cfg: EngineConfig) -> EngineState:
    qstore = stores.make_table(cfg.query_capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
    })
    cooc = stores.make_table(cfg.cooc_capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
        "src_hi": jnp.uint32, "src_lo": jnp.uint32,
        "dst_hi": jnp.uint32, "dst_lo": jnp.uint32,
    })
    sessions = stores.make_session_table(cfg.session_capacity, cfg.session_window)
    return EngineState(qstore, cooc, sessions, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# jitted step functions
# ---------------------------------------------------------------------------

_Q_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))
_C_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"),
            ("src_hi", "set"), ("src_lo", "set"),
            ("dst_hi", "set"), ("dst_lo", "set"))


@partial(jax.jit, static_argnames=("cfg",))
def ingest_queries(
    state: EngineState,
    sess_hi: jax.Array, sess_lo: jax.Array,
    q_hi: jax.Array, q_lo: jax.Array,
    src: jax.Array, valid: jax.Array,
    *, cfg: EngineConfig,
) -> EngineState:
    """The query path of §4.3 for one micro-batch."""
    sw = jnp.asarray(cfg.source_weights, jnp.float32)
    w = sw[jnp.clip(src, 0, len(cfg.source_weights) - 1)]
    B = q_hi.shape[0]
    tick_vec = jnp.full((B,), state.tick, jnp.int32)
    # lazy policy: rebase-on-write so refreshing last_tick never un-decays
    dkw = dict(decay_cfg=cfg.decay, now=state.tick) if cfg.lazy_decay else {}

    qstore = stores.insert_accumulate(
        state.qstore, q_hi, q_lo,
        {"weight": w, "count": jnp.ones((B,), jnp.float32), "last_tick": tick_vec},
        valid, modes=_Q_MODES, probe_rounds=cfg.probe_rounds, **dkw)

    sessions, pairs = stores.update_sessions(
        state.sessions, sess_hi, sess_lo, q_hi, q_lo, src, state.tick, valid,
        probe_rounds=cfg.probe_rounds)

    # pair weight: geometric mean of the two interaction-source weights
    w_src = sw[jnp.clip(pairs.src_code, 0, len(cfg.source_weights) - 1)]
    w_dst = sw[jnp.clip(pairs.dst_code, 0, len(cfg.source_weights) - 1)]
    w_pair = jnp.sqrt(w_src * w_dst)
    p_hi, p_lo = combine_fp_device(pairs.src_hi, pairs.src_lo,
                                   pairs.dst_hi, pairs.dst_lo)
    P = p_hi.shape[0]
    cooc = stores.insert_accumulate(
        state.cooc, p_hi, p_lo,
        {"weight": w_pair, "count": jnp.ones((P,), jnp.float32),
         "last_tick": jnp.full((P,), state.tick, jnp.int32),
         "src_hi": pairs.src_hi, "src_lo": pairs.src_lo,
         "dst_hi": pairs.dst_hi, "dst_lo": pairs.dst_lo},
        pairs.valid, modes=_C_MODES, probe_rounds=cfg.probe_rounds, **dkw)

    return EngineState(qstore, cooc, sessions, state.tick)


@partial(jax.jit, static_argnames=("cfg",))
def ingest_tweets(
    state: EngineState,
    g_hi: jax.Array, g_lo: jax.Array,   # [T, G]
    valid: jax.Array,                    # [T]
    *, cfg: EngineConfig,
) -> EngineState:
    """The tweet path of §4.3 for one micro-batch of tweets."""
    T, G = g_hi.shape
    flat_hi, flat_lo = g_hi.reshape(-1), g_lo.reshape(-1)
    vals, found, _ = stores.lookup(state.qstore, flat_hi, flat_lo,
                                   probe_rounds=cfg.probe_rounds)
    querylike = (found & (vals["count"] >= cfg.min_querylike_count)
                 & valid[:, None].repeat(G, 1).reshape(-1))
    B = T * G
    tick_vec = jnp.full((B,), state.tick, jnp.int32)
    w = jnp.full((B,), cfg.tweet_weight, jnp.float32)
    dkw = dict(decay_cfg=cfg.decay, now=state.tick) if cfg.lazy_decay else {}
    qstore = stores.insert_accumulate(
        state.qstore, flat_hi, flat_lo,
        {"weight": w, "count": jnp.ones((B,), jnp.float32), "last_tick": tick_vec},
        querylike, modes=_Q_MODES, probe_rounds=cfg.probe_rounds, **dkw)

    # all ordered pairs among query-like grams of the same tweet
    ql = querylike.reshape(T, G)
    src_hi = jnp.broadcast_to(g_hi[:, :, None], (T, G, G)).reshape(-1)
    src_lo = jnp.broadcast_to(g_lo[:, :, None], (T, G, G)).reshape(-1)
    dst_hi = jnp.broadcast_to(g_hi[:, None, :], (T, G, G)).reshape(-1)
    dst_lo = jnp.broadcast_to(g_lo[:, None, :], (T, G, G)).reshape(-1)
    ok = (ql[:, :, None] & ql[:, None, :]).reshape(-1)
    same = (src_hi == dst_hi) & (src_lo == dst_lo)
    ok = ok & ~same
    p_hi, p_lo = combine_fp_device(src_hi, src_lo, dst_hi, dst_lo)
    P = p_hi.shape[0]
    cooc = stores.insert_accumulate(
        state.cooc, p_hi, p_lo,
        {"weight": jnp.full((P,), cfg.tweet_weight, jnp.float32),
         "count": jnp.ones((P,), jnp.float32),
         "last_tick": jnp.full((P,), state.tick, jnp.int32),
         "src_hi": src_hi, "src_lo": src_lo, "dst_hi": dst_hi, "dst_lo": dst_lo},
        ok, modes=_C_MODES, probe_rounds=cfg.probe_rounds, **dkw)
    return EngineState(qstore, cooc, state.sessions, state.tick)


@partial(jax.jit, static_argnames=("cfg",))
def decay_cycle(state: EngineState, dticks: jax.Array, *, cfg: EngineConfig
                ) -> Tuple[EngineState, Dict[str, jax.Array]]:
    """Decay/prune cycle (§4.3): decay all weights, prune small entries and
    stale sessions. Runs every ``decay_every`` ticks under the (paper
    faithful) eager "sweep" policy only."""
    qstore, q_live, q_tot = sweep_decay_prune(
        state.qstore, dticks, cfg=cfg.decay, weight_lanes=("weight",),
        use_kernel=cfg.use_kernel)
    cooc, c_live, c_tot = sweep_decay_prune(
        state.cooc, dticks, cfg=cfg.decay, weight_lanes=("weight",),
        use_kernel=cfg.use_kernel)
    sessions = stores.evict_sessions(state.sessions, state.tick, cfg.session_ttl)
    stats = {"q_live": q_live, "q_total_w": q_tot,
             "c_live": c_live, "c_total_w": c_tot}
    return EngineState(qstore, cooc, sessions, state.tick), stats


@partial(jax.jit, static_argnames=("cfg",))
def evict_sessions_cycle(state: EngineState, *, cfg: EngineConfig
                         ) -> EngineState:
    """Session-TTL eviction alone — an O(session_capacity) mask, no weight
    sweep. Under the lazy policy this keeps eviction on the ``decay_every``
    cadence (TTL semantics are unrelated to weight-decay laziness) while
    the store sweeps move to ``prune_every``."""
    sessions = stores.evict_sessions(state.sessions, state.tick,
                                     cfg.session_ttl)
    return state._replace(sessions=sessions)


@partial(jax.jit, static_argnames=("cfg",))
def prune_cycle(state: EngineState, *, cfg: EngineConfig
                ) -> Tuple[EngineState, Dict[str, jax.Array]]:
    """Lazy policy's slow-cadence maintenance: prune-only sweep (decay is
    amortized into reads/writes), every ``prune_every`` ticks."""
    qstore, q_live, q_tot = prune_sweep(state.qstore, state.tick, cfg=cfg.decay)
    cooc, c_live, c_tot = prune_sweep(state.cooc, state.tick, cfg=cfg.decay)
    sessions = stores.evict_sessions(state.sessions, state.tick, cfg.session_ttl)
    stats = {"q_live": q_live, "q_total_w": q_tot,
             "c_live": c_live, "c_total_w": c_tot}
    return EngineState(qstore, cooc, sessions, state.tick), stats


@jax.jit
def advance_tick(state: EngineState) -> EngineState:
    return state._replace(tick=state.tick + 1)


# ---------------------------------------------------------------------------
# Host orchestrator
# ---------------------------------------------------------------------------

class SearchAssistanceEngine:
    """Host-side driver of one backend instance (paper Figure 4).

    Call :meth:`step` once per tick with the tick's micro-batches; the engine
    runs decay and ranking cycles at their configured cadences and keeps the
    latest suggestion table for the frontend.
    """

    def __init__(self, cfg: EngineConfig, name: str = "rt"):
        self.cfg = cfg
        self.name = name
        self.state = init_state(cfg)
        self.suggestions: Dict[int, List[Tuple[int, float]]] = {}
        self.last_rank_tick: int = -1
        self.n_rank_cycles = 0
        self.n_decay_cycles = 0
        self.n_prune_cycles = 0

    # ---- ingestion ----
    def step(self, query_events=None, tweets=None) -> Optional[Dict]:
        """Process one tick. Returns rank-cycle stats when a cycle ran."""
        out = None
        if query_events is not None:
            s_hi, s_lo = split_fp(query_events.sess_fp)
            q_hi, q_lo = split_fp(query_events.q_fp)
            self.state = ingest_queries(
                self.state, jnp.asarray(s_hi), jnp.asarray(s_lo),
                jnp.asarray(q_hi), jnp.asarray(q_lo),
                jnp.asarray(query_events.src, jnp.int32),
                jnp.asarray(query_events.valid), cfg=self.cfg)
        if tweets is not None:
            g_hi, g_lo = split_fp(tweets.grams)
            self.state = ingest_tweets(
                self.state, jnp.asarray(g_hi), jnp.asarray(g_lo),
                jnp.asarray(tweets.valid), cfg=self.cfg)

        tick = int(self.state.tick)
        if self.cfg.lazy_decay:
            # decay is amortized into reads/writes; only the prune-only
            # sweep remains, at the (much longer) prune cadence. Session
            # TTL eviction stays on the decay_every cadence — it is a
            # cheap mask, and its semantics are time-based, not decay.
            pruning = (self.cfg.prune_every > 0 and tick > 0
                       and tick % self.cfg.prune_every == 0)
            if (not pruning and self.cfg.decay_every > 0 and tick > 0
                    and tick % self.cfg.decay_every == 0):
                self.state = evict_sessions_cycle(self.state, cfg=self.cfg)
            if pruning:   # prune_cycle evicts sessions itself
                self.state, stats = prune_cycle(self.state, cfg=self.cfg)
                self.n_prune_cycles += 1
        elif self.cfg.decay_every > 0 and tick > 0 and tick % self.cfg.decay_every == 0:
            self.state, stats = decay_cycle(
                self.state, jnp.int32(self.cfg.decay_every), cfg=self.cfg)
            self.n_decay_cycles += 1
        if self.cfg.rank_every > 0 and tick > 0 and tick % self.cfg.rank_every == 0:
            out = self.run_rank_cycle()
        self.state = advance_tick(self.state)
        return out

    def run_rank_cycle(self) -> Dict:
        dkw = (dict(decay_cfg=self.cfg.decay, now=self.state.tick)
               if self.cfg.lazy_decay else {})
        table = ranking.ranking_cycle(self.state.cooc, self.state.qstore,
                                      self.cfg.rank, **dkw)
        self.suggestions = ranking.suggestions_to_host(table)
        self.last_rank_tick = int(self.state.tick)
        self.n_rank_cycles += 1
        return {"tick": self.last_rank_tick,
                "n_rows": int(table.n_rows),
                "n_overflow": int(table.n_overflow),
                "n_suggest": len(self.suggestions)}

    # ---- serving-side reads (the frontend cache pulls these) ----
    def suggest_fp(self, fp: int, k: int = 8) -> List[Tuple[int, float]]:
        return self.suggestions.get(int(fp), [])[:k]

    # ---- persistence (every rank cycle the leader persists, §4.2) ----
    def state_arrays(self) -> Dict[str, np.ndarray]:
        leaves, treedef = jax.tree.flatten(self.state)
        return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}

    def load_state_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        leaves, treedef = jax.tree.flatten(self.state)
        new_leaves = [jnp.asarray(arrays[f"leaf_{i}"]) for i in range(len(leaves))]
        self.state = jax.tree.unflatten(treedef, new_leaves)
