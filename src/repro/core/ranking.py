"""Association scoring + ranking cycles (paper §2.4, §4.3 "Ranking cycles").

The ranker periodically traverses the entire cooccurrence store, scores each
(A -> B) pair with several association statistics computed against the query
store marginals, combines them linearly (the paper's "simplest workable
strategy ... linear combination with hand-tuned weights"), and emits top-k
suggestions per source query.

Score lanes (all named in §2.4):
  * conditional relative frequency   P(B|A) = w_ab / W_a
  * pointwise mutual information     log( w_ab * T / (W_a * W_b) )
  * log-likelihood ratio             Dunning's G² over the 2x2 count table
  * chi-squared                      χ² over the same 2x2 table
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import stores
from .stores import HashTable


@dataclasses.dataclass(frozen=True)
class RankConfig:
    top_k: int = 8
    # linear combination coefficients over (condprob, pmi, llr, chi2)
    coef_condprob: float = 1.0
    coef_pmi: float = 0.15
    coef_llr: float = 0.02
    coef_chi2: float = 0.0
    # evidence gates: "accumulating sufficient evidence" (§2.2)
    min_pair_weight: float = 0.25
    min_src_weight: float = 0.5
    min_pair_count: float = 1.0
    use_kernel: bool = False   # route scoring through the Pallas kernel
    # compact gated rows before the (expensive) 3-key lexsort: the sort then
    # runs over compact_frac * capacity rows instead of the full table. The
    # prune policy keeps stores <= 50% live (§4.4), so 0.5 is lossless in
    # steady state; if more rows pass the gates, the globally lowest-scoring
    # pairs are cut and counted in SuggestionTable.n_overflow. >= 1.0
    # disables compaction entirely.
    compact_frac: float = 0.5


def _xlogx(x):
    return jnp.where(x > 0, x * jnp.log(jnp.maximum(x, 1e-30)), 0.0)


def assoc_scores_jnp(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c):
    """Reference (pure jnp) association score lanes. All inputs f32 arrays.

    Returns (condprob, pmi, llr, chi2); invalid/degenerate entries -> 0.
    """
    eps = 1e-9
    w_a = jnp.maximum(w_a, 0.0)
    w_b = jnp.maximum(w_b, 0.0)
    condprob = jnp.where(w_a > 0, w_ab / jnp.maximum(w_a, eps), 0.0)
    pmi = jnp.where(
        (w_ab > 0) & (w_a > 0) & (w_b > 0),
        jnp.log(jnp.maximum(w_ab * jnp.maximum(total_w, eps), eps)
                / jnp.maximum(w_a * w_b, eps)),
        0.0,
    )
    # 2x2 contingency over raw counts: events where A precedes B.
    k11 = c_ab
    k12 = jnp.maximum(c_a - c_ab, 0.0)
    k21 = jnp.maximum(c_b - c_ab, 0.0)
    k22 = jnp.maximum(total_c - c_a - c_b + c_ab, 0.0)
    n = jnp.maximum(k11 + k12 + k21 + k22, eps)
    row1, row2 = k11 + k12, k21 + k22
    col1, col2 = k11 + k21, k12 + k22
    llr = 2.0 * (
        _xlogx(k11) + _xlogx(k12) + _xlogx(k21) + _xlogx(k22)
        - _xlogx(row1) - _xlogx(row2) - _xlogx(col1) - _xlogx(col2)
        + _xlogx(n)
    )
    llr = jnp.maximum(llr, 0.0)
    denom = jnp.maximum(row1 * row2 * col1 * col2, eps)
    chi2 = n * (k11 * k22 - k12 * k21) ** 2 / denom
    valid = c_ab > 0
    return (jnp.where(valid, condprob, 0.0), jnp.where(valid, pmi, 0.0),
            jnp.where(valid, llr, 0.0), jnp.where(valid, chi2, 0.0))


def combine_scores(cfg: RankConfig, condprob, pmi, llr, chi2):
    """The paper's linear-combination ranker (hand-tuned coefficients)."""
    return (cfg.coef_condprob * condprob
            + cfg.coef_pmi * jax.nn.sigmoid(pmi)          # squash unbounded lanes
            + cfg.coef_llr * jnp.log1p(llr)
            + cfg.coef_chi2 * jnp.log1p(chi2))


class SuggestionTable(NamedTuple):
    """Dense top-k suggestion output of one ranking cycle."""
    src_hi: jax.Array    # u32[M]
    src_lo: jax.Array    # u32[M]
    dst_hi: jax.Array    # u32[M, K]
    dst_lo: jax.Array    # u32[M, K]
    score: jax.Array     # f32[M, K]  (0 => empty slot)
    n_rows: jax.Array    # i32[]
    n_overflow: jax.Array  # i32[] — gate-passing rows beyond the compaction cap


@partial(jax.jit, static_argnames=("cfg",))
def ranking_cycle(
    cooc: HashTable,
    qstore: HashTable,
    cfg: RankConfig,
) -> SuggestionTable:
    """One full ranking cycle over the cooccurrence store."""
    C = cooc.capacity
    live = cooc.live_mask
    src_hi = cooc.lanes["src_hi"]
    src_lo = cooc.lanes["src_lo"]
    dst_hi = cooc.lanes["dst_hi"]
    dst_lo = cooc.lanes["dst_lo"]
    w_ab = cooc.lanes["weight"]
    c_ab = cooc.lanes["count"]

    src_vals, src_found, _ = stores.lookup(qstore, src_hi, src_lo)
    dst_vals, dst_found, _ = stores.lookup(qstore, dst_hi, dst_lo)
    total_w = jnp.sum(qstore.lanes["weight"])
    total_c = jnp.sum(qstore.lanes["count"])

    if cfg.use_kernel:
        from ..kernels import ops as kops
        score = kops.assoc_score(
            w_ab, c_ab, src_vals["weight"], dst_vals["weight"],
            src_vals["count"], dst_vals["count"], total_w, total_c,
            coefs=(cfg.coef_condprob, cfg.coef_pmi, cfg.coef_llr, cfg.coef_chi2))
    else:
        lanes = assoc_scores_jnp(w_ab, c_ab, src_vals["weight"], dst_vals["weight"],
                                 src_vals["count"], dst_vals["count"], total_w, total_c)
        score = combine_scores(cfg, *lanes)

    ok = (live & src_found & dst_found
          & (w_ab >= cfg.min_pair_weight)
          & (c_ab >= cfg.min_pair_count)
          & (src_vals["weight"] >= cfg.min_src_weight))
    score = jnp.where(ok, score, -jnp.inf)

    # ---- compact gate-passing rows so the 3-key lexsort runs over M << C
    # rows. Evidence gates + the <=50% prune policy keep the survivor count
    # far below capacity; overflow beyond M is counted, not silent. ----
    if cfg.compact_frac >= 1.0:
        M = C
        c_src_hi, c_src_lo = src_hi, src_lo
        c_dst_hi, c_dst_lo = dst_hi, dst_lo
        c_score, c_ok = score, ok
        n_overflow = jnp.zeros((), jnp.int32)
    else:
        M = min(C, max(cfg.top_k, int(C * cfg.compact_frac)))
        # single-key sort by descending score: gate-passing rows (finite
        # score) land before gated rows (-inf), so sel = the M *best* rows.
        # If more than M rows pass the gates, the overflow cut removes the
        # globally lowest-scoring pairs — counted, and never a source's top
        # suggestion before its worse ones.
        sel = jnp.argsort(-score)[:M]
        c_score = score[sel]
        c_ok = c_score > -jnp.inf
        gath = lambda a, fill: jnp.where(c_ok, a[sel], fill)
        # filler rows get an all-ones src key so they cluster in their own
        # (never-emitted) run after the sort instead of merging with a real
        # source's run.
        c_src_hi = gath(src_hi, jnp.uint32(0xFFFFFFFF))
        c_src_lo = gath(src_lo, jnp.uint32(0xFFFFFFFF))
        c_dst_hi = gath(dst_hi, jnp.uint32(0))
        c_dst_lo = gath(dst_lo, jnp.uint32(0))
        n_overflow = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - M, 0)

    # group by src, descending score: stable lexsort, last key is primary.
    order = jnp.lexsort((-c_score, c_src_lo, c_src_hi))
    s_hi, s_lo = c_src_hi[order], c_src_lo[order]
    s_dhi, s_dlo = c_dst_hi[order], c_dst_lo[order]
    s_score = c_score[order]
    s_ok = c_ok[order]

    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_lo[:-1]])
    is_new = (s_hi != prev_hi) | (s_lo != prev_lo)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    first_idx = jax.ops.segment_min(jnp.arange(M, dtype=jnp.int32), seg_id,
                                    num_segments=M)
    pos = jnp.arange(M, dtype=jnp.int32) - first_idx[seg_id]

    K = cfg.top_k
    keep = s_ok & (pos < K)
    row = seg_id
    out_src_hi = jnp.zeros((M,), jnp.uint32).at[jnp.where(is_new & s_ok, row, M)].set(s_hi, mode="drop")
    out_src_lo = jnp.zeros((M,), jnp.uint32).at[jnp.where(is_new & s_ok, row, M)].set(s_lo, mode="drop")
    r_idx = jnp.where(keep, row, M)
    p_idx = jnp.where(keep, pos, 0)
    out_dst_hi = jnp.zeros((M, K), jnp.uint32).at[r_idx, p_idx].set(s_dhi, mode="drop")
    out_dst_lo = jnp.zeros((M, K), jnp.uint32).at[r_idx, p_idx].set(s_dlo, mode="drop")
    out_score = jnp.zeros((M, K), jnp.float32).at[r_idx, p_idx].set(
        jnp.where(keep, s_score, 0.0), mode="drop")
    n_rows = jnp.sum((is_new & s_ok).astype(jnp.int32))
    return SuggestionTable(out_src_hi, out_src_lo, out_dst_hi, out_dst_lo,
                           out_score, n_rows, n_overflow)


def suggestions_to_host(table: SuggestionTable) -> dict:
    """Export a SuggestionTable to host numpy dict keyed by src fp64."""
    from .hashing import join_fp
    src_hi = np.asarray(table.src_hi)
    src_lo = np.asarray(table.src_lo)
    mask = (src_hi != 0) | (src_lo != 0)
    out = {}
    dst_fp = join_fp(np.asarray(table.dst_hi), np.asarray(table.dst_lo))
    score = np.asarray(table.score)
    for i in np.nonzero(mask)[0]:
        fp = int(join_fp(src_hi[i], src_lo[i]))
        row = [(int(d), float(s)) for d, s in zip(dst_fp[i], score[i]) if s > 0.0]
        if row:
            out[fp] = row
    return out
