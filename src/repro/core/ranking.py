"""Association scoring + ranking cycles (paper §2.4, §4.3 "Ranking cycles").

The ranker periodically traverses the entire cooccurrence store, scores each
(A -> B) pair with several association statistics computed against the query
store marginals, combines them linearly (the paper's "simplest workable
strategy ... linear combination with hand-tuned weights"), and emits top-k
suggestions per source query.

Score lanes (all named in §2.4):
  * conditional relative frequency   P(B|A) = w_ab / W_a
  * pointwise mutual information     log( w_ab * T / (W_a * W_b) )
  * log-likelihood ratio             Dunning's G² over the 2x2 count table
  * chi-squared                      χ² over the same 2x2 table

Selection — three implementations of the same per-source top-k contract:

  * :func:`ranking_cycle_region` — **region layout** (source-major store,
    see ``stores.RegionTable``). The store is already partitioned into
    per-source regions at insert time, so the ``[n_regions, width]``
    bucket grid is a **pure reshape** of the live table: no prefix-sum
    compaction, no grouping sort, no gathers before selection. Source
    marginals come from ONE direct index per region (region id = qstore
    slot — no per-pair qstore probing for the source side), per-region
    top-k reads the grid rows straight from HBM tiles (``lax.top_k`` or
    the fused ``kernels/topk_select.region_rank`` Pallas pass), and a
    source's spill-chain regions are merged by a second tiny top-k over
    ``max_chain * K`` candidates. Every live pair is in exactly one
    region, so selection itself never cuts: ``n_overflow`` counts only
    gate-passing pairs of sources beyond the ``max_sources`` cap.

  * :func:`ranking_cycle` (default) — **segmented top-k**. Every
    gate-passing pair is bucketed by its *source query's qstore slot* (the
    open-addressing placement is a hash-derived bucket that is collision-free
    across live keys, so no two sources share a bucket). Gate-passing rows
    are stream-compacted (prefix-sum scatter, no sort) into a selection
    arena, grouped by ONE flat u32 key — bucket id in the high bits, coarse
    inverted score bits below, so each bucket's best rows lead its run —
    and laid out as a dense ``[buckets, L]`` grid by pure gathers. The
    per-bucket partial selection (top-k / iterated masked argmax along the
    L axis, Pallas kernel variant in ``kernels/topk_select.py``) then runs
    fully vectorized. The capacity-sized f32 ``argsort`` and the 3-key
    lexsort of the old pipeline are both gone: the only remaining sort is
    the single flat u32 grouping key over the compacted arena, so cycle
    cost scales with gate-passing rows, not table capacity.
  * :func:`ranking_cycle_lexsort` — the pre-segmented reference pipeline
    (compact-by-argsort + 3-key lexsort + run extraction), kept verbatim for
    parity tests and before/after benchmark rows.

Exactness: selection within a bucket uses exact scores (``lax.top_k`` over
the gathered grid). Rows beyond the per-bucket arena ``L`` are cut by
*coarse-score* order, so a true top-k member is lost only when >= L rows of
one bucket land in the same coarse-score quantum — and every cut row is
counted in ``SuggestionTable.n_overflow``, never silent.

Cadence model under the **lazy** decay policy (``DecayConfig.policy ==
"lazy"``): the ranking cycle is a *read*, so it applies the read-time decayed
view per row — ``w * factor(now - last_tick)`` for pair weights, source and
destination marginals, and the query-store totals — instead of relying on a
periodic full decay sweep. The engine then only runs a prune-only sweep at
the much longer ``EngineConfig.prune_every`` cadence (see ``decay.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import stores
from .decay import lazy_decayed
from .plan import TunedPlan
from .stores import HashTable, RegionTable


@dataclasses.dataclass(frozen=True)
class RankConfig:
    top_k: int = 8
    # linear combination coefficients over (condprob, pmi, llr, chi2)
    coef_condprob: float = 1.0
    coef_pmi: float = 0.15
    coef_llr: float = 0.02
    coef_chi2: float = 0.0
    # evidence gates: "accumulating sufficient evidence" (§2.2)
    min_pair_weight: float = 0.25
    min_src_weight: float = 0.5
    min_pair_count: float = 1.0
    # Legacy kernel override (None = defer to ``plan``): an explicit bool
    # forces score/gate + selection through Pallas (True) or jnp (False).
    use_kernel: Optional[bool] = None
    # Measured dispatch plan — normally attached from ``EngineConfig.plan``
    # (its ``__post_init__`` copies it here); standalone ranking callers
    # can set it directly.
    plan: Optional[TunedPlan] = None
    # lexsort path only: compact gated rows by argsort before the 3-key
    # lexsort; cuts the globally lowest-scoring pairs on overflow (counted).
    # >= 1.0 disables compaction entirely.
    compact_frac: float = 0.5
    # segmented path: the selection arena holds seg_arena_frac * capacity
    # gate-passing rows (sort-free prefix-sum compaction). Unlike the
    # lexsort path's score-ordered cut, arena overflow is cut by table
    # position — so the default matches the <=50% prune policy (§4.4):
    # positional cuts can only happen when more than half the table passes
    # the gates, the same regime where the old default overflowed. Always
    # counted in n_overflow. >= 1.0 disables compaction.
    seg_arena_frac: float = 0.5
    # segmented path: per-bucket arena width L — a source's gate-passing
    # rows beyond its L coarse-score-best are cut and counted.
    bucket_rows: int = 64
    # max sources emitted per cycle (grid height cap; sources beyond it are
    # cut and counted in n_overflow). 0 (the default) derives the cap from
    # the query store's capacity — a store can never hold more live sources
    # than qstore slots, so the derived cap cuts nothing while a fixed
    # default would silently cap large stores at its value.
    max_sources: int = 0

    def source_cap(self, qstore_capacity: int) -> int:
        return (self.max_sources if self.max_sources > 0
                else qstore_capacity)

    def kernel_on(self, op: str) -> bool:
        """Kernel-vs-jnp resolution for one ranking hot path: the legacy
        ``use_kernel`` bool wins; else the tuned plan; else jnp."""
        if self.use_kernel is not None:
            return self.use_kernel
        if self.plan is not None:
            return self.plan.uses_kernel(op)
        return False


def _xlogx(x):
    return jnp.where(x > 0, x * jnp.log(jnp.maximum(x, 1e-30)), 0.0)


def assoc_scores_jnp(w_ab, c_ab, w_a, w_b, c_a, c_b, total_w, total_c):
    """Reference (pure jnp) association score lanes. All inputs f32 arrays.

    Returns (condprob, pmi, llr, chi2); invalid/degenerate entries -> 0.
    """
    eps = 1e-9
    w_a = jnp.maximum(w_a, 0.0)
    w_b = jnp.maximum(w_b, 0.0)
    condprob = jnp.where(w_a > 0, w_ab / jnp.maximum(w_a, eps), 0.0)
    pmi = jnp.where(
        (w_ab > 0) & (w_a > 0) & (w_b > 0),
        jnp.log(jnp.maximum(w_ab * jnp.maximum(total_w, eps), eps)
                / jnp.maximum(w_a * w_b, eps)),
        0.0,
    )
    # 2x2 contingency over raw counts: events where A precedes B.
    k11 = c_ab
    k12 = jnp.maximum(c_a - c_ab, 0.0)
    k21 = jnp.maximum(c_b - c_ab, 0.0)
    k22 = jnp.maximum(total_c - c_a - c_b + c_ab, 0.0)
    n = jnp.maximum(k11 + k12 + k21 + k22, eps)
    row1, row2 = k11 + k12, k21 + k22
    col1, col2 = k11 + k21, k12 + k22
    llr = 2.0 * (
        _xlogx(k11) + _xlogx(k12) + _xlogx(k21) + _xlogx(k22)
        - _xlogx(row1) - _xlogx(row2) - _xlogx(col1) - _xlogx(col2)
        + _xlogx(n)
    )
    llr = jnp.maximum(llr, 0.0)
    denom = jnp.maximum(row1 * row2 * col1 * col2, eps)
    chi2 = n * (k11 * k22 - k12 * k21) ** 2 / denom
    valid = c_ab > 0
    return (jnp.where(valid, condprob, 0.0), jnp.where(valid, pmi, 0.0),
            jnp.where(valid, llr, 0.0), jnp.where(valid, chi2, 0.0))


def combine_scores(cfg: RankConfig, condprob, pmi, llr, chi2):
    """The paper's linear-combination ranker (hand-tuned coefficients)."""
    return (cfg.coef_condprob * condprob
            + cfg.coef_pmi * jax.nn.sigmoid(pmi)          # squash unbounded lanes
            + cfg.coef_llr * jnp.log1p(llr)
            + cfg.coef_chi2 * jnp.log1p(chi2))


class SuggestionTable(NamedTuple):
    """Dense top-k suggestion output of one ranking cycle."""
    src_hi: jax.Array    # u32[M]
    src_lo: jax.Array    # u32[M]
    dst_hi: jax.Array    # u32[M, K]
    dst_lo: jax.Array    # u32[M, K]
    score: jax.Array     # f32[M, K]  (0 => empty slot)
    n_rows: jax.Array    # i32[]
    n_overflow: jax.Array  # i32[] — gate-passing rows beyond the compaction cap


def _score_and_gate(cooc: HashTable, qstore: HashTable, cfg: RankConfig,
                    decay_cfg, now):
    """Shared ranking prologue: marginals lookup, association scoring and
    evidence gating — with the read-time decayed view under the lazy policy.

    Returns (score [-inf where gated], ok mask, src qstore slot, key lanes).
    """
    live = cooc.live_mask
    src_hi = cooc.lanes["src_hi"]
    src_lo = cooc.lanes["src_lo"]
    dst_hi = cooc.lanes["dst_hi"]
    dst_lo = cooc.lanes["dst_lo"]
    w_ab = cooc.lanes["weight"]
    c_ab = cooc.lanes["count"]

    dkw = dict(decay_cfg=decay_cfg, now=now) if decay_cfg is not None else {}
    src_vals, src_found, src_slot = stores.lookup(qstore, src_hi, src_lo, **dkw)
    dst_vals, dst_found, _ = stores.lookup(qstore, dst_hi, dst_lo, **dkw)
    if decay_cfg is not None:
        total_w = jnp.sum(lazy_decayed(decay_cfg, qstore.lanes["weight"],
                                       qstore.lanes["last_tick"], now))
    else:
        total_w = jnp.sum(qstore.lanes["weight"])
    total_c = jnp.sum(qstore.lanes["count"])

    base_ok = live & src_found & dst_found
    if cfg.kernel_on("score_gate"):
        from ..kernels import ops as kops
        score = kops.score_gate(
            w_ab, c_ab, src_vals["weight"], dst_vals["weight"],
            src_vals["count"], dst_vals["count"], base_ok, total_w, total_c,
            coefs=(cfg.coef_condprob, cfg.coef_pmi, cfg.coef_llr, cfg.coef_chi2),
            min_pair_weight=cfg.min_pair_weight,
            min_src_weight=cfg.min_src_weight,
            min_pair_count=cfg.min_pair_count,
            decay_cfg=decay_cfg, last_tick=cooc.lanes["last_tick"], now=now,
            block_rows=(cfg.plan.score_block_rows
                        if cfg.plan is not None else None))
        ok = score > -jnp.inf
    else:
        if decay_cfg is not None:
            w_ab = lazy_decayed(decay_cfg, w_ab, cooc.lanes["last_tick"], now)
        lanes = assoc_scores_jnp(w_ab, c_ab, src_vals["weight"],
                                 dst_vals["weight"], src_vals["count"],
                                 dst_vals["count"], total_w, total_c)
        score = combine_scores(cfg, *lanes)
        ok = (base_ok
              & (w_ab >= cfg.min_pair_weight)
              & (c_ab >= cfg.min_pair_count)
              & (src_vals["weight"] >= cfg.min_src_weight))
        score = jnp.where(ok, score, -jnp.inf)
    return score, ok, src_slot, (src_hi, src_lo, dst_hi, dst_lo)


def _sortable_f32(x: jax.Array) -> jax.Array:
    """Monotonic f32 -> u32 bit transform (IEEE total order)."""
    sb = jax.lax.bitcast_convert_type(x, jnp.int32)
    return jnp.where(sb >= 0, sb.astype(jnp.uint32) + jnp.uint32(0x80000000),
                     (~sb).astype(jnp.uint32))


@partial(jax.jit, static_argnames=("cfg", "decay_cfg"))
def ranking_cycle(
    cooc: HashTable,
    qstore: HashTable,
    cfg: RankConfig,
    *,
    decay_cfg=None,
    now=None,
) -> SuggestionTable:
    """One full ranking cycle — segmented top-k (the fast path).

    Pipeline (see module docstring): score+gate -> prefix-sum compaction of
    gate-passing row ids into an arena of M rows -> ONE flat u32 grouping
    sort (bucket id | coarse inverted score) -> dense [R, L] bucket grid by
    gathers -> exact per-bucket top-k. Output rows are indexed by bucket
    run, so the table has ``min(Q, M, cfg.max_sources)`` rows; empty rows
    keep the (0, 0) src key and are skipped by :func:`suggestions_to_host`.
    Pass ``decay_cfg``/``now`` under the lazy decay policy to rank against
    the read-time decayed view.
    """
    C = cooc.capacity
    Q = qstore.capacity
    K = cfg.top_k
    L = max(cfg.bucket_rows, K)
    score, ok, src_slot, keys = _score_and_gate(cooc, qstore, cfg,
                                                decay_cfg, now)
    src_hi, src_lo, dst_hi, dst_lo = keys

    # ---- sort-free stream compaction of gate-passing ROW IDS (one scatter;
    # payloads stay in place and are gathered on demand). Overflow beyond
    # the arena is cut by table position — counted, never silent. ----
    if cfg.seg_arena_frac >= 1.0:
        M = C
        idx = jnp.arange(C, dtype=jnp.int32)
        arena_spill = jnp.zeros((), jnp.int32)
        s = jnp.where(ok, score, -jnp.inf)
        seg = jnp.where(ok, src_slot, Q)
    else:
        M = min(C, max(K, int(C * cfg.seg_arena_frac)))
        pos = jnp.cumsum(ok.astype(jnp.int32)) - 1
        tgt = jnp.where(ok & (pos < M), pos, M)
        idx = jnp.full((M,), C, jnp.int32).at[tgt].set(
            jnp.arange(C, dtype=jnp.int32), mode="drop")
        arena_spill = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - M, 0)
        filled = idx < C
        safe_idx = jnp.clip(idx, 0, C - 1)
        s = jnp.where(filled, score[safe_idx], -jnp.inf)
        seg = jnp.where(filled, src_slot[safe_idx], Q)

    # ---- ONE flat u32 grouping key: bucket id (with one extra bit for the
    # empty/gated sentinel Q) above coarse inverted score bits, so each
    # bucket's rows are contiguous, best-first by coarse score. ----
    bbits = Q.bit_length()            # log2(Q) + 1: room for the sentinel
    qbits = 32 - bbits
    key = (seg.astype(jnp.uint32) << jnp.uint32(qbits)) \
        | ((~_sortable_f32(s)) >> jnp.uint32(bbits))
    skey, sidx = jax.lax.sort((key, idx), num_keys=1, is_stable=True)
    sseg = skey >> jnp.uint32(qbits)
    valid_row = sseg < Q
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sseg[1:] != sseg[:-1]]) & valid_row
    run_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    ar = jnp.arange(M, dtype=jnp.int32)
    pos_in_run = ar - jax.lax.cummax(jnp.where(is_new, ar, 0))

    # ---- dense [R, L] bucket grid, built by gathers only. run_id is
    # non-decreasing, so run starts come from a vectorized binary search. --
    R = min(Q, M, max(cfg.source_cap(Q), 1))
    run_start = jnp.searchsorted(run_id, jnp.arange(R + 1, dtype=jnp.int32)
                                 ).astype(jnp.int32)
    cell = run_start[:R, None] + jnp.arange(L, dtype=jnp.int32)[None, :]
    in_run = cell < run_start[1:, None]   # next run's start bounds this run
    cell_c = jnp.clip(cell, 0, M - 1)
    # sorted position -> original table row (sidx carries the permuted row
    # ids; C is the empty-arena-slot sentinel) -> exact score.
    cell_orig = sidx[cell_c]
    grid = jnp.where(in_run & (cell_orig < C),
                     score[jnp.clip(cell_orig, 0, C - 1)], -jnp.inf)
    if cfg.kernel_on("bucket_topk"):
        from ..kernels import ops as kops
        vals, args = kops.bucket_topk(grid, K)
    else:
        vals, args = jax.lax.top_k(grid, K)
    good = vals > -jnp.inf

    win_sorted = jnp.clip(run_start[:R, None] + args, 0, M - 1)
    win_orig = jnp.clip(sidx[win_sorted], 0, C - 1)
    out_dst_hi = jnp.where(good, dst_hi[win_orig], jnp.uint32(0))
    out_dst_lo = jnp.where(good, dst_lo[win_orig], jnp.uint32(0))
    out_score = jnp.where(good, vals, 0.0)
    has_run = run_start[:R] < M
    head_orig = jnp.clip(sidx[jnp.clip(run_start[:R], 0, M - 1)], 0, C - 1)
    out_src_hi = jnp.where(has_run, src_hi[head_orig], jnp.uint32(0))
    out_src_lo = jnp.where(has_run, src_lo[head_orig], jnp.uint32(0))

    n_rows = jnp.sum(has_run.astype(jnp.int32))   # rows actually emitted
    select_spill = jnp.sum(
        (valid_row & ((pos_in_run >= L) | (run_id >= R))).astype(jnp.int32))
    return SuggestionTable(out_src_hi, out_src_lo, out_dst_hi, out_dst_lo,
                           out_score, n_rows, arena_spill + select_spill)


@partial(jax.jit, static_argnames=("cfg", "decay_cfg"))
def ranking_cycle_lexsort(
    cooc: HashTable,
    qstore: HashTable,
    cfg: RankConfig,
    *,
    decay_cfg=None,
    now=None,
) -> SuggestionTable:
    """Pre-segmented reference ranking cycle (compact-by-argsort + 3-key
    lexsort). Kept for parity tests and before/after benchmark rows,
    mirroring the ``insert_accumulate_twopass`` pattern; not used by the
    engine."""
    C = cooc.capacity
    score, ok, _, keys = _score_and_gate(cooc, qstore, cfg, decay_cfg, now)
    src_hi, src_lo, dst_hi, dst_lo = keys

    # ---- compact gate-passing rows so the 3-key lexsort runs over M << C
    # rows. Evidence gates + the <=50% prune policy keep the survivor count
    # far below capacity; overflow beyond M is counted, not silent. ----
    if cfg.compact_frac >= 1.0:
        M = C
        c_src_hi, c_src_lo = src_hi, src_lo
        c_dst_hi, c_dst_lo = dst_hi, dst_lo
        c_score, c_ok = score, ok
        n_overflow = jnp.zeros((), jnp.int32)
    else:
        M = min(C, max(cfg.top_k, int(C * cfg.compact_frac)))
        # single-key sort by descending score: gate-passing rows (finite
        # score) land before gated rows (-inf), so sel = the M *best* rows.
        # If more than M rows pass the gates, the overflow cut removes the
        # globally lowest-scoring pairs — counted, and never a source's top
        # suggestion before its worse ones.
        sel = jnp.argsort(-score)[:M]
        c_score = score[sel]
        c_ok = c_score > -jnp.inf
        gath = lambda a, fill: jnp.where(c_ok, a[sel], fill)
        # filler rows get an all-ones src key so they cluster in their own
        # (never-emitted) run after the sort instead of merging with a real
        # source's run.
        c_src_hi = gath(src_hi, jnp.uint32(0xFFFFFFFF))
        c_src_lo = gath(src_lo, jnp.uint32(0xFFFFFFFF))
        c_dst_hi = gath(dst_hi, jnp.uint32(0))
        c_dst_lo = gath(dst_lo, jnp.uint32(0))
        n_overflow = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - M, 0)

    # group by src, descending score: stable lexsort, last key is primary.
    order = jnp.lexsort((-c_score, c_src_lo, c_src_hi))
    s_hi, s_lo = c_src_hi[order], c_src_lo[order]
    s_dhi, s_dlo = c_dst_hi[order], c_dst_lo[order]
    s_score = c_score[order]
    s_ok = c_ok[order]

    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_lo[:-1]])
    is_new = (s_hi != prev_hi) | (s_lo != prev_lo)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    first_idx = jax.ops.segment_min(jnp.arange(M, dtype=jnp.int32), seg_id,
                                    num_segments=M)
    pos = jnp.arange(M, dtype=jnp.int32) - first_idx[seg_id]

    K = cfg.top_k
    keep = s_ok & (pos < K)
    row = seg_id
    out_src_hi = jnp.zeros((M,), jnp.uint32).at[jnp.where(is_new & s_ok, row, M)].set(s_hi, mode="drop")
    out_src_lo = jnp.zeros((M,), jnp.uint32).at[jnp.where(is_new & s_ok, row, M)].set(s_lo, mode="drop")
    r_idx = jnp.where(keep, row, M)
    p_idx = jnp.where(keep, pos, 0)
    out_dst_hi = jnp.zeros((M, K), jnp.uint32).at[r_idx, p_idx].set(s_dhi, mode="drop")
    out_dst_lo = jnp.zeros((M, K), jnp.uint32).at[r_idx, p_idx].set(s_dlo, mode="drop")
    out_score = jnp.zeros((M, K), jnp.float32).at[r_idx, p_idx].set(
        jnp.where(keep, s_score, 0.0), mode="drop")
    n_rows = jnp.sum((is_new & s_ok).astype(jnp.int32))
    return SuggestionTable(out_src_hi, out_src_lo, out_dst_hi, out_dst_lo,
                           out_score, n_rows, n_overflow)


@partial(jax.jit, static_argnames=("cfg", "decay_cfg"))
def ranking_cycle_region(
    cooc: RegionTable,
    qstore: HashTable,
    cfg: RankConfig,
    *,
    decay_cfg=None,
    now=None,
) -> SuggestionTable:
    """One full ranking cycle over the **source-major region layout**.

    The bucket grid is ``score.reshape(n_regions, width)`` — a pure view
    of the live table, built with zero sorts, zero compaction scatters and
    zero pre-selection gathers. Source marginals are read by direct index
    (region id = qstore slot), destination marginals by one batched qstore
    lookup over the key lanes. Selection is per-region top-k (grid rows
    stream straight from HBM; ``cfg.use_kernel`` routes the fused
    score+gate+select Pallas pass in ``kernels/topk_select.region_rank``)
    followed by a per-source merge of the spill chain's ``max_chain * K``
    candidates. Tie order (documented): within a region, the lower slot
    position wins (insertion order); across a chain, the earlier chain
    region wins — both may differ from the segmented path's coarse-score
    arena order on exact ties.

    Every live pair sits in exactly one region, so selection never cuts;
    ``n_overflow`` counts gate-passing pairs of sources beyond
    ``cfg.max_sources`` (derived from the qstore capacity by default,
    i.e. normally zero).
    """
    C, R, W, MC = cooc.capacity, cooc.n_regions, cooc.width, cooc.max_chain
    Q = cooc.dir_slots
    K = cfg.top_k
    assert Q == qstore.capacity, "directory must be indexed by qstore slot"

    live = cooc.live_mask
    w_ab = cooc.lanes["weight"]
    c_ab = cooc.lanes["count"]

    # dst marginals: the key lanes ARE the destination fingerprints.
    dkw = dict(decay_cfg=decay_cfg, now=now) if decay_cfg is not None else {}
    dst_vals, dst_found, _ = stores.lookup(qstore, cooc.key_hi, cooc.key_lo,
                                           **dkw)
    if decay_cfg is not None:
        total_w = jnp.sum(lazy_decayed(decay_cfg, qstore.lanes["weight"],
                                       qstore.lanes["last_tick"], now))
    else:
        total_w = jnp.sum(qstore.lanes["weight"])
    total_c = jnp.sum(qstore.lanes["count"])

    # src marginals: ONE direct index per region — no per-pair probing.
    # region_chain_state is the ONE statement of chain validity (shared
    # with the sweeps in decay.py).
    row_valid, ent_ok, referenced = stores.region_chain_state(cooc, qstore)
    ent = cooc.chain_region
    o = jnp.clip(cooc.region_owner, 0, Q - 1)
    w_a_r = qstore.lanes["weight"][o]
    c_a_r = qstore.lanes["count"][o]
    if decay_cfg is not None:
        w_a_r = lazy_decayed(decay_cfg, w_a_r,
                             qstore.lanes["last_tick"][o], now)

    # ---- [R, W] grid scoring: the pure-reshape bucket grid. ----
    shape = (R, W)
    w_ab2 = w_ab.reshape(shape)
    c_ab2 = c_ab.reshape(shape)
    w_b2 = dst_vals["weight"].reshape(shape)
    c_b2 = dst_vals["count"].reshape(shape)
    base_ok = (live & dst_found).reshape(shape) & referenced[:, None]
    w_a_b = jnp.broadcast_to(w_a_r[:, None], shape)
    c_a_b = jnp.broadcast_to(c_a_r[:, None], shape)
    # a single region holds at most W pairs: per-region selection takes
    # min(K, W) winners and the chain merge below restores K (a source's
    # top-k beyond W can only come from its spill regions).
    K1 = min(K, W)
    if cfg.kernel_on("region_rank"):
        from ..kernels import ops as kops
        vals, args, npass_r = kops.region_rank(
            w_ab2, c_ab2, w_a_b, w_b2, c_a_b, c_b2, base_ok, total_w,
            total_c, k=K1,
            coefs=(cfg.coef_condprob, cfg.coef_pmi, cfg.coef_llr,
                   cfg.coef_chi2),
            min_pair_weight=cfg.min_pair_weight,
            min_src_weight=cfg.min_src_weight,
            min_pair_count=cfg.min_pair_count,
            decay_cfg=decay_cfg,
            last_tick=cooc.lanes["last_tick"].reshape(shape), now=now)
    else:
        w_eff = w_ab2 if decay_cfg is None else lazy_decayed(
            decay_cfg, w_ab, cooc.lanes["last_tick"], now).reshape(shape)
        lanes_s = assoc_scores_jnp(w_eff, c_ab2, w_a_b, w_b2, c_a_b, c_b2,
                                   total_w, total_c)
        score = combine_scores(cfg, *lanes_s)
        pass_mask = base_ok & (w_eff >= cfg.min_pair_weight) \
            & (c_ab2 >= cfg.min_pair_count) \
            & (w_a_b >= cfg.min_src_weight)
        grid = jnp.where(pass_mask, score, -jnp.inf)
        vals, args = jax.lax.top_k(grid, K1)
        npass_r = jnp.sum(pass_mask.astype(jnp.int32), axis=1)

    # ---- per-source chain merge: top-k over max_chain * K candidates. --
    S = min(Q, R, max(cfg.source_cap(Q), 1))
    act = row_valid
    posq = jnp.cumsum(act.astype(jnp.int32)) - 1
    slot_of_row = jnp.full((S,), Q, jnp.int32).at[
        jnp.where(act & (posq < S), posq, S)].set(
        jnp.arange(Q, dtype=jnp.int32), mode="drop")
    has_slot = slot_of_row < Q
    slot_safe = jnp.where(has_slot, slot_of_row, 0)
    ch = jnp.where(has_slot[:, None], cooc.chain_region[slot_safe], -1)
    cand = jnp.where((ch >= 0)[:, :, None],
                     vals[jnp.clip(ch, 0, R - 1)],
                     -jnp.inf).reshape(S, MC * K1)
    if MC * K1 < K:   # K exceeds the whole chain's candidate pool
        cand = jnp.pad(cand, ((0, 0), (0, K - MC * K1)),
                       constant_values=-jnp.inf)
    fvals, fidx = jax.lax.top_k(cand, K)
    depth = jnp.minimum(fidx // K1, MC - 1)
    reg_w = jnp.take_along_axis(ch, depth, axis=1)
    col = args[jnp.clip(reg_w, 0, R - 1), fidx % K1]
    gslot = jnp.clip(reg_w, 0, R - 1) * W + jnp.clip(col, 0, W - 1)
    good = fvals > -jnp.inf
    out_dst_hi = jnp.where(good, cooc.key_hi[gslot], jnp.uint32(0))
    out_dst_lo = jnp.where(good, cooc.key_lo[gslot], jnp.uint32(0))
    out_score = jnp.where(good, fvals, 0.0)
    has_out = jnp.any(good, axis=1)
    out_src_hi = jnp.where(has_out, cooc.chain_hi[slot_safe], jnp.uint32(0))
    out_src_lo = jnp.where(has_out, cooc.chain_lo[slot_safe], jnp.uint32(0))
    n_rows = jnp.sum(has_out.astype(jnp.int32))

    npass_row = jnp.sum(jnp.where(ent_ok, npass_r[jnp.clip(ent, 0, R - 1)],
                                  0), axis=1)
    n_overflow = jnp.sum(jnp.where(act & (posq >= S), npass_row, 0))
    return SuggestionTable(out_src_hi, out_src_lo, out_dst_hi, out_dst_lo,
                           out_score, n_rows, n_overflow)


def suggestions_to_host(table: SuggestionTable) -> dict:
    """Export a SuggestionTable to host numpy dict keyed by src fp64.

    Skips empty rows (src key (0, 0)) AND the all-ones filler src key that
    the lexsort path assigns to compaction-overflow filler rows — explicitly,
    rather than relying on every filler entry carrying score 0.
    """
    from .hashing import join_fp
    src_hi = np.asarray(table.src_hi)
    src_lo = np.asarray(table.src_lo)
    mask = ((src_hi != 0) | (src_lo != 0)) \
        & ~((src_hi == 0xFFFFFFFF) & (src_lo == 0xFFFFFFFF))
    out = {}
    dst_fp = join_fp(np.asarray(table.dst_hi), np.asarray(table.dst_lo))
    score = np.asarray(table.score)
    for i in np.nonzero(mask)[0]:
        fp = int(join_fp(src_hi[i], src_lo[i]))
        row = [(int(d), float(s)) for d, s in zip(dst_fp[i], score[i]) if s > 0.0]
        if row:
            out[fp] = row
    return out
