"""In-memory statistics stores as fixed-capacity, dense-array hash tables.

The paper's backend holds three in-memory stores (§4.2): the *query
statistics store*, the *query cooccurrence statistics store*, and the
*sessions store*. The deployed Twitter engine used JVM hash-maps mutated
event-at-a-time; the TPU-native adaptation here uses **open-addressing hash
tables laid out as dense JAX arrays** updated by *micro-batches* of events:

  * keys are 64-bit fingerprints stored as two uint32 lanes (no jax x64),
  * a batch of updates is deduplicated with a stable lexsort + segment-sum,
  * existing keys are found with a K-round triangular probe (all rounds are
    always scanned, which makes lookups correct in the presence of pruned
    slots without tombstones),
  * new keys claim the first empty slot on their probe sequence through a
    scatter-max "claim" race (unique keys after dedup => at most one winner
    per key, losers retry the next round),
  * keys that fail to place after K rounds are *dropped and counted* — the
    paper's engine likewise rate-limits/prunes to bound memory (§4.4).

All operations are functional (table in, table out) and jit-compatible.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import probe_hash, combine_fp_device

# Lane reduction modes.
ADD = "add"    # accumulate (weights, counts)
SET = "set"    # last-writer-wins (timestamps, language, src/dst fps)
MAX = "max"    # running max


class HashTable(NamedTuple):
    """Open-addressing hash table over (hi, lo) uint32 fingerprint pairs."""
    key_hi: jax.Array          # u32[C]; (0,0) == empty slot
    key_lo: jax.Array          # u32[C]
    lanes: Dict[str, jax.Array]   # each [C] or [C, ...]
    n_dropped: jax.Array       # i32[] — updates dropped due to probe failure

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def live_mask(self) -> jax.Array:
        return (self.key_hi != 0) | (self.key_lo != 0)

    def live_count(self) -> jax.Array:
        return jnp.sum(self.live_mask.astype(jnp.int32))


def make_table(capacity: int, lane_specs: Dict[str, Any]) -> HashTable:
    """lane_specs: name -> dtype or (dtype, trailing_shape)."""
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    lanes = {}
    for name, spec in lane_specs.items():
        if isinstance(spec, tuple):
            dtype, trailing = spec
            lanes[name] = jnp.zeros((capacity, *trailing), dtype=dtype)
        else:
            lanes[name] = jnp.zeros((capacity,), dtype=spec)
    return HashTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        lanes=lanes,
        n_dropped=jnp.zeros((), jnp.int32),
    )


def _probe_slot(h0: jax.Array, r: int, capacity: int) -> jax.Array:
    """Triangular probing: h0 + r(r+1)/2 mod C covers all slots for C=2^k."""
    return (h0 + jnp.uint32(r * (r + 1) // 2)) & jnp.uint32(capacity - 1)


def _dedup_sorted(key_hi, key_lo, valid):
    """Stable lexsort by (hi, lo); returns (perm, seg_id, rep_mask, run_start).

    rep_mask marks the LAST row of each equal-key run in sorted order, so
    SET lanes naturally take the final (batch-order latest) value. Invalid
    rows have key (0,0) and sort first; they form segment(s) that callers
    mask out via the key-!=0 check.
    """
    perm = jnp.lexsort((key_lo, key_hi))  # lexsort is stable
    s_hi, s_lo = key_hi[perm], key_lo[perm]
    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_lo[:-1]])
    is_new = (s_hi != prev_hi) | (s_lo != prev_lo)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    nxt_new = jnp.concatenate([is_new[1:], jnp.ones((1,), bool)])
    rep_mask = nxt_new & ((s_hi != 0) | (s_lo != 0)) & valid[perm]
    return perm, seg_id, rep_mask


@partial(jax.jit, static_argnames=("modes", "probe_rounds"))
def insert_accumulate(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
) -> HashTable:
    """Batched insert-or-accumulate of (key -> lane updates).

    modes: tuple of (lane_name, ADD|SET|MAX) — a hashable static spec.
    """
    C = table.capacity
    mode_map = dict(modes)
    # Invalid rows get the empty key so they collapse into a masked run.
    key_hi = jnp.where(valid, key_hi, 0).astype(jnp.uint32)
    key_lo = jnp.where(valid, key_lo, 0).astype(jnp.uint32)

    B = key_hi.shape[0]
    perm, seg_id, rep_mask = _dedup_sorted(key_hi, key_lo, valid)
    s_hi, s_lo = key_hi[perm], key_lo[perm]

    # Per-segment reductions of each lane, landed on the representative row.
    agg: Dict[str, jax.Array] = {}
    for name, upd in updates.items():
        upd_s = upd[perm]
        mode = mode_map[name]
        if mode == ADD:
            seg = jax.ops.segment_sum(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        elif mode == MAX:
            seg = jax.ops.segment_max(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        else:  # SET — representative row is the last of the run already.
            agg[name] = upd_s

    alive = rep_mask
    h0 = probe_hash(s_hi, s_lo)

    # -- Pass 1: find existing slots across ALL probe rounds (prune-safe). --
    found_slot = jnp.full((B,), -1, jnp.int32)
    for r in range(probe_rounds):
        slot = _probe_slot(h0, r, C)
        t_hi = table.key_hi[slot]
        t_lo = table.key_lo[slot]
        hit = alive & (found_slot < 0) & (t_hi == s_hi) & (t_lo == s_lo)
        found_slot = jnp.where(hit, slot.astype(jnp.int32), found_slot)

    key_hi_tab, key_lo_tab = table.key_hi, table.key_lo
    placed = found_slot >= 0
    write_slot = found_slot

    # -- Pass 2: unplaced keys claim the first empty slot on their sequence. --
    for r in range(probe_rounds):
        want = alive & ~placed
        slot = _probe_slot(h0, r, C)
        empty = (key_hi_tab[slot] == 0) & (key_lo_tab[slot] == 0)
        contend = want & empty
        claim = jnp.full((C,), -1, jnp.int32)
        claim = claim.at[slot].max(jnp.where(contend, jnp.arange(B, dtype=jnp.int32), -1))
        won = contend & (claim[slot] == jnp.arange(B, dtype=jnp.int32))
        # OOB sentinel + mode='drop': losers must not scatter at all (a
        # masked write of the *old* value could race a genuine winner).
        drop_slot = jnp.where(won, slot.astype(jnp.int32), C)
        key_hi_tab = key_hi_tab.at[drop_slot].set(s_hi, mode="drop")
        key_lo_tab = key_lo_tab.at[drop_slot].set(s_lo, mode="drop")
        write_slot = jnp.where(won, slot.astype(jnp.int32), write_slot)
        placed = placed | won

    dropped = jnp.sum((alive & ~placed).astype(jnp.int32))

    # -- Apply lane updates at write_slot (unique keys => unique slots). --
    ok = placed & alive
    safe = jnp.where(ok, write_slot, 0)
    drop = jnp.where(ok, write_slot, C)
    new_lanes = dict(table.lanes)
    for name, upd in agg.items():
        lane = new_lanes[name]
        mode = mode_map[name]
        if mode == ADD:
            zeros = jnp.zeros_like(upd)
            add = jnp.where(_bmask(ok, upd), upd, zeros)
            new_lanes[name] = lane.at[safe].add(add)
        elif mode == MAX:
            cur = lane[safe]
            new_lanes[name] = lane.at[drop].set(jnp.maximum(cur, upd), mode="drop")
        else:  # SET
            new_lanes[name] = lane.at[drop].set(upd, mode="drop")

    return HashTable(key_hi_tab, key_lo_tab, new_lanes, table.n_dropped + dropped)


def _bmask(mask: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a [B] mask against a [B, ...] lane update."""
    return mask.reshape(mask.shape + (1,) * (ref.ndim - 1))


@partial(jax.jit, static_argnames=("probe_rounds",))
def lookup(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    *,
    probe_rounds: int = 16,
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Batched lookup. Returns (lanes_at_key, found_mask, slot)."""
    C = table.capacity
    key_hi = jnp.asarray(key_hi, jnp.uint32)
    key_lo = jnp.asarray(key_lo, jnp.uint32)
    h0 = probe_hash(key_hi, key_lo)
    B = key_hi.shape[0]
    found_slot = jnp.full((B,), -1, jnp.int32)
    for r in range(probe_rounds):
        slot = _probe_slot(h0, r, C)
        hit = (found_slot < 0) & (table.key_hi[slot] == key_hi) & (table.key_lo[slot] == key_lo) \
            & ((key_hi != 0) | (key_lo != 0))
        found_slot = jnp.where(hit, slot.astype(jnp.int32), found_slot)
    found = found_slot >= 0
    safe = jnp.where(found, found_slot, 0)
    out = {}
    for name, lane in table.lanes.items():
        v = lane[safe]
        out[name] = jnp.where(_bmask(found, v), v, jnp.zeros_like(v))
    return out, found, found_slot


def export_live(table: HashTable) -> Dict[str, np.ndarray]:
    """Host-side export of live entries (for persistence / suggestion build)."""
    mask = np.asarray(table.live_mask)
    out = {
        "key_hi": np.asarray(table.key_hi)[mask],
        "key_lo": np.asarray(table.key_lo)[mask],
    }
    for name, lane in table.lanes.items():
        out[name] = np.asarray(lane)[mask]
    return out


# ---------------------------------------------------------------------------
# Sessions store: per-session sliding window ring buffers (paper §4.2).
# ---------------------------------------------------------------------------

class SessionTable(NamedTuple):
    key_hi: jax.Array    # u32[S]
    key_lo: jax.Array    # u32[S]
    ring_hi: jax.Array   # u32[S, W] — recent query fingerprints
    ring_lo: jax.Array   # u32[S, W]
    ring_src: jax.Array  # i32[S, W] — interaction source code per entry
    cursor: jax.Array    # i32[S] — next write position
    filled: jax.Array    # i32[S] — number of valid ring entries (<= W)
    last_tick: jax.Array  # i32[S]
    n_dropped: jax.Array  # i32[]

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def window(self) -> int:
        return self.ring_hi.shape[1]


def make_session_table(capacity: int, window: int) -> SessionTable:
    assert capacity & (capacity - 1) == 0
    return SessionTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        ring_hi=jnp.zeros((capacity, window), jnp.uint32),
        ring_lo=jnp.zeros((capacity, window), jnp.uint32),
        ring_src=jnp.zeros((capacity, window), jnp.int32),
        cursor=jnp.zeros((capacity,), jnp.int32),
        filled=jnp.zeros((capacity,), jnp.int32),
        last_tick=jnp.zeros((capacity,), jnp.int32),
        n_dropped=jnp.zeros((), jnp.int32),
    )


class PairBatch(NamedTuple):
    """Emitted (predecessor -> new query) cooccurrence pairs, [B*W] flat."""
    src_hi: jax.Array
    src_lo: jax.Array
    src_code: jax.Array
    dst_hi: jax.Array
    dst_lo: jax.Array
    dst_code: jax.Array
    valid: jax.Array


@partial(jax.jit, static_argnames=("probe_rounds",))
def update_sessions(
    table: SessionTable,
    sess_hi: jax.Array,
    sess_lo: jax.Array,
    q_hi: jax.Array,
    q_lo: jax.Array,
    src_code: jax.Array,
    tick: jax.Array,
    valid: jax.Array,
    *,
    probe_rounds: int = 16,
) -> Tuple[SessionTable, PairBatch]:
    """Append a micro-batch of queries to their sessions; emit pairs.

    Exact order semantics: events are processed in batch order *per session*
    (stable sort groups a session's events while preserving arrival order);
    a new query pairs with the W most recent predecessors, drawing first from
    earlier same-batch events, then from the pre-batch ring window.
    """
    S, W = table.capacity, table.window
    B = q_hi.shape[0]
    sess_hi = jnp.where(valid, sess_hi, 0).astype(jnp.uint32)
    sess_lo = jnp.where(valid, sess_lo, 0).astype(jnp.uint32)

    perm = jnp.lexsort((sess_lo, sess_hi))  # stable
    e_shi, e_slo = sess_hi[perm], sess_lo[perm]
    e_qhi, e_qlo = q_hi[perm], q_lo[perm]
    e_src = src_code[perm]
    e_valid = valid[perm] & ((e_shi != 0) | (e_slo != 0))

    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_shi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_slo[:-1]])
    is_new_run = (e_shi != prev_hi) | (e_slo != prev_lo)
    seg_id = jnp.cumsum(is_new_run.astype(jnp.int32)) - 1
    pos_in_run = jnp.arange(B, dtype=jnp.int32) - jax.ops.segment_min(
        jnp.arange(B, dtype=jnp.int32), seg_id, num_segments=B)[seg_id]
    run_len = jax.ops.segment_sum(jnp.ones((B,), jnp.int32), seg_id, num_segments=B)[seg_id]

    # ---- find/create the session row: probe with run representatives. ----
    rep = is_new_run & e_valid
    h0 = probe_hash(e_shi, e_slo)
    found_slot = jnp.full((B,), -1, jnp.int32)
    for r in range(probe_rounds):
        slot = _probe_slot(h0, r, S)
        hit = rep & (found_slot < 0) & (table.key_hi[slot] == e_shi) & (table.key_lo[slot] == e_slo)
        found_slot = jnp.where(hit, slot.astype(jnp.int32), found_slot)
    key_hi_tab, key_lo_tab = table.key_hi, table.key_lo
    placed = found_slot >= 0
    row = found_slot
    for r in range(probe_rounds):
        want = rep & ~placed
        slot = _probe_slot(h0, r, S)
        empty = (key_hi_tab[slot] == 0) & (key_lo_tab[slot] == 0)
        contend = want & empty
        claim = jnp.full((S,), -1, jnp.int32)
        claim = claim.at[slot].max(jnp.where(contend, jnp.arange(B, dtype=jnp.int32), -1))
        won = contend & (claim[slot] == jnp.arange(B, dtype=jnp.int32))
        drop_slot = jnp.where(won, slot.astype(jnp.int32), S)
        key_hi_tab = key_hi_tab.at[drop_slot].set(e_shi, mode="drop")
        key_lo_tab = key_lo_tab.at[drop_slot].set(e_slo, mode="drop")
        row = jnp.where(won, slot.astype(jnp.int32), row)
        placed = placed | won
    dropped = jnp.sum((rep & ~placed).astype(jnp.int32))
    # Broadcast the representative's row to every event in its run.
    rep_row = jax.ops.segment_max(jnp.where(rep, row, -1), seg_id, num_segments=B)
    row = rep_row[seg_id]
    e_ok = e_valid & (row >= 0)
    safe_row = jnp.where(e_ok, row, 0)

    pre_cursor = table.cursor[safe_row]
    pre_filled = table.filled[safe_row]

    # ---- emit pairs: d-th most recent predecessor, d = 1..W. ----
    n_intra = jnp.minimum(pos_in_run, W)
    pair_src_hi = jnp.zeros((B, W), jnp.uint32)
    pair_src_lo = jnp.zeros((B, W), jnp.uint32)
    pair_src_code = jnp.zeros((B, W), jnp.int32)
    pair_ok = jnp.zeros((B, W), bool)
    idx = jnp.arange(B, dtype=jnp.int32)
    for d in range(1, W + 1):
        take_intra = (d <= n_intra)
        j = jnp.maximum(idx - d, 0)
        intra_hi, intra_lo, intra_src = e_qhi[j], e_qlo[j], e_src[j]
        age = d - 1 - n_intra  # >= 0 when not intra
        ring_ok = (~take_intra) & (age < jnp.minimum(W - n_intra, pre_filled))
        ring_pos = jnp.mod(pre_cursor - 1 - age, W)
        r_hi = table.ring_hi[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_lo = table.ring_lo[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_src = table.ring_src[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        s_hi = jnp.where(take_intra, intra_hi, r_hi)
        s_lo = jnp.where(take_intra, intra_lo, r_lo)
        s_sc = jnp.where(take_intra, intra_src, r_src)
        ok = e_ok & (take_intra | ring_ok) & ((s_hi != 0) | (s_lo != 0))
        # drop self-pairs (identical consecutive queries)
        ok = ok & ~((s_hi == e_qhi) & (s_lo == e_qlo))
        pair_src_hi = pair_src_hi.at[:, d - 1].set(s_hi)
        pair_src_lo = pair_src_lo.at[:, d - 1].set(s_lo)
        pair_src_code = pair_src_code.at[:, d - 1].set(s_sc)
        pair_ok = pair_ok.at[:, d - 1].set(ok)

    # ---- write the last min(W, run_len) events of each run into the ring. ----
    should_write = e_ok & (pos_in_run >= run_len - W)
    wpos = jnp.mod(pre_cursor + pos_in_run, W)
    w_row = jnp.where(should_write, safe_row, S)  # OOB => dropped
    ring_hi = table.ring_hi.at[w_row, wpos].set(e_qhi, mode="drop")
    ring_lo = table.ring_lo.at[w_row, wpos].set(e_qlo, mode="drop")
    ring_src = table.ring_src.at[w_row, wpos].set(e_src, mode="drop")

    # cursor/filled advance once per run (apply at the run's last event).
    is_last = jnp.concatenate([is_new_run[1:], jnp.ones((1,), bool)])
    adv = e_ok & is_last
    a_row = jnp.where(adv, safe_row, S)
    new_cursor = jnp.mod(pre_cursor + run_len, W)
    new_filled = jnp.minimum(pre_filled + run_len, W)
    cursor = table.cursor.at[a_row].set(new_cursor, mode="drop")
    filled = table.filled.at[a_row].set(new_filled, mode="drop")
    last_tick = table.last_tick.at[a_row].set(
        jnp.full((B,), tick, jnp.int32), mode="drop")

    new_table = SessionTable(key_hi_tab, key_lo_tab, ring_hi, ring_lo, ring_src,
                             cursor, filled, last_tick, table.n_dropped + dropped)

    pairs = PairBatch(
        src_hi=pair_src_hi.reshape(-1),
        src_lo=pair_src_lo.reshape(-1),
        src_code=pair_src_code.reshape(-1),
        dst_hi=jnp.broadcast_to(e_qhi[:, None], (B, W)).reshape(-1),
        dst_lo=jnp.broadcast_to(e_qlo[:, None], (B, W)).reshape(-1),
        dst_code=jnp.broadcast_to(e_src[:, None], (B, W)).reshape(-1),
        valid=pair_ok.reshape(-1),
    )
    return new_table, pairs


@jax.jit
def evict_sessions(table: SessionTable, tick: jax.Array, ttl: int) -> SessionTable:
    """Prune sessions with no recent activity (paper's decay/prune cycle)."""
    live = (table.key_hi != 0) | (table.key_lo != 0)
    stale = live & ((tick - table.last_tick) > ttl)
    keep = ~stale
    return table._replace(
        key_hi=jnp.where(keep, table.key_hi, 0),
        key_lo=jnp.where(keep, table.key_lo, 0),
        cursor=jnp.where(keep, table.cursor, 0),
        filled=jnp.where(keep, table.filled, 0),
    )
