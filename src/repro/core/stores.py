"""In-memory statistics stores as fixed-capacity, dense-array hash tables.

The paper's backend holds three in-memory stores (§4.2): the *query
statistics store*, the *query cooccurrence statistics store*, and the
*sessions store*. The deployed Twitter engine used JVM hash-maps mutated
event-at-a-time; the TPU-native adaptation here uses **open-addressing hash
tables laid out as dense JAX arrays** updated by *micro-batches* of events:

  * keys are 64-bit fingerprints stored as two uint32 lanes (no jax x64),
  * a batch of updates is deduplicated with a stable lexsort + segment-sum,
  * existing keys are found with a K-round triangular probe (all rounds are
    scanned when a key may be absent, which makes lookups correct in the
    presence of pruned slots without tombstones; the sweep early-exits the
    moment every key is resolved),
  * finds and claims share ONE fused sweep (``_find_or_claim``): the find
    rounds also record each row's empty-slot candidates as a bitmask, then
    claim rounds resolve conflicts *batch-locally* — contenders for a slot
    are ordered by a single packed (slot, batch idx) key and the first of
    each slot-run wins, O(B log B) per round instead of a capacity-sized
    scatter-max race (unique keys after dedup => at most one winner per
    key, losers fall to their next bit); packing the batch index into the
    sort key makes the winner *deterministic-by-arrival* rather than a
    property of the sort's stability,
  * keys that fail to place after K rounds are *dropped and counted* — the
    paper's engine likewise rate-limits/prunes to bound memory (§4.4).

All operations are functional (table in, table out) and jit-compatible.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import probe_hash, combine_fp_device

# Lane reduction modes.
ADD = "add"    # accumulate (weights, counts)
SET = "set"    # last-writer-wins (timestamps, language, src/dst fps)
MAX = "max"    # running max


class HashTable(NamedTuple):
    """Open-addressing hash table over (hi, lo) uint32 fingerprint pairs."""
    key_hi: jax.Array          # u32[C]; (0,0) == empty slot
    key_lo: jax.Array          # u32[C]
    lanes: Dict[str, jax.Array]   # each [C] or [C, ...]
    n_dropped: jax.Array       # i32[] — updates dropped due to probe failure

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def live_mask(self) -> jax.Array:
        return (self.key_hi != 0) | (self.key_lo != 0)

    def live_count(self) -> jax.Array:
        return jnp.sum(self.live_mask.astype(jnp.int32))


def make_table(capacity: int, lane_specs: Dict[str, Any]) -> HashTable:
    """lane_specs: name -> dtype or (dtype, trailing_shape)."""
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    lanes = {}
    for name, spec in lane_specs.items():
        if isinstance(spec, tuple):
            dtype, trailing = spec
            lanes[name] = jnp.zeros((capacity, *trailing), dtype=dtype)
        else:
            lanes[name] = jnp.zeros((capacity,), dtype=spec)
    return HashTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        lanes=lanes,
        n_dropped=jnp.zeros((), jnp.int32),
    )


def _probe_slot(h0: jax.Array, r: int, capacity: int) -> jax.Array:
    """Triangular probing: h0 + r(r+1)/2 mod C covers all slots for C=2^k."""
    return (h0 + jnp.uint32(r * (r + 1) // 2)) & jnp.uint32(capacity - 1)


def _probe_slot_dyn(h0: jax.Array, r: jax.Array, capacity: int) -> jax.Array:
    """`_probe_slot` with a *traced* round index (uint32 scalar or [B])."""
    r = r.astype(jnp.uint32)
    return (h0 + ((r * (r + 1)) >> 1)) & jnp.uint32(capacity - 1)


def _claim_winners(slot: jax.Array, contend: jax.Array, B: int, C: int
                   ) -> jax.Array:
    """First-of-each-slot-run claim resolution, deterministic-by-arrival.

    Packs ``(slot, batch idx)`` into ONE uint32 sort key whenever
    ``log2(C) + ceil_log2(B) <= 31`` (the common case), so the winner of
    every contended slot is the lowest batch index *by key value* — no
    reliance on sort stability. When the packed key would overflow 31 bits,
    falls back to a two-key lexsort over (idx, slot); the (slot, idx) pairs
    are unique, so any correct sort yields the same winners.

    Returns a [B] bool mask of winning rows (at most one per slot).
    """
    idx = jnp.arange(B, dtype=jnp.uint32)
    bits_b = max((B - 1).bit_length(), 1)
    if (C - 1).bit_length() + bits_b <= 31:
        sent = jnp.uint32(0xFFFFFFFF)
        packed = jnp.where(
            contend, (slot << jnp.uint32(bits_b)) | idx, sent)
        order = jnp.argsort(packed)
        po = packed[order]
        pslot = po >> jnp.uint32(bits_b)
        first = jnp.concatenate(
            [jnp.ones((1,), bool), pslot[1:] != pslot[:-1]])
        return jnp.zeros((B,), bool).at[order].set(first & (po != sent))
    skey = jnp.where(contend, slot.astype(jnp.int32), C)
    order = jnp.lexsort((idx.astype(jnp.int32), skey))
    so = skey[order]
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    return jnp.zeros((B,), bool).at[order].set(first & (so < C))


def _find_or_claim(
    key_hi_tab: jax.Array,
    key_lo_tab: jax.Array,
    s_hi: jax.Array,
    s_lo: jax.Array,
    alive: jax.Array,
    probe_rounds: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-sweep find-or-claim over unique keys (the store hot path).

    One probe sweep records, per row, the slot already holding its key (the
    full ``probe_rounds`` sequence is scanned so lookups stay correct in the
    presence of pruned slots) **and** a bitmask of empty slots along the
    sequence. A second `while_loop` then resolves insertions *batch-locally*:
    each round, every unplaced row proposes its next empty-at-snapshot slot,
    contenders for the same slot are resolved by sorting a single packed
    (slot, batch idx) key (first of each slot-run wins — O(B log B), never
    O(capacity), and deterministic-by-arrival; see ``_claim_winners``),
    and losers fall through to their next candidate bit. Both loops early-exit
    the moment every row is served, so the accumulate-heavy steady state costs
    a couple of probe rounds instead of 2 x ``probe_rounds`` full passes.

    Requires ``alive`` rows to carry *unique* keys (callers dedup first).
    Returns (key_hi_tab, key_lo_tab, slot, placed, n_dropped); ``slot`` is -1
    for rows that were not placed.
    """
    assert probe_rounds <= 32, "empty-slot bitmask is uint32"
    C = key_hi_tab.shape[0]
    B = s_hi.shape[0]
    h0 = probe_hash(s_hi, s_lo)

    # -- Sweep 1: find existing slots, record empty candidates as bits. --
    def find_cond(st):
        r, found, _ = st
        return (r < probe_rounds) & jnp.any(alive & (found < 0))

    def find_body(st):
        r, found, emp = st
        slot = _probe_slot_dyn(h0, r, C)
        t_hi = key_hi_tab[slot]
        t_lo = key_lo_tab[slot]
        hit = alive & (found < 0) & (t_hi == s_hi) & (t_lo == s_lo)
        found = jnp.where(hit, slot.astype(jnp.int32), found)
        empty = (t_hi == 0) & (t_lo == 0)
        bit = jnp.left_shift(jnp.uint32(1), r.astype(jnp.uint32))
        emp = emp | jnp.where(empty, bit, jnp.uint32(0))
        return r + 1, found, emp

    _, found_slot, emp_bits = jax.lax.while_loop(
        find_cond, find_body,
        (jnp.uint32(0), jnp.full((B,), -1, jnp.int32),
         jnp.zeros((B,), jnp.uint32)))

    placed = found_slot >= 0
    write_slot = found_slot

    # -- Sweep 2: claim rounds. Slots empty at snapshot time can only be
    # consumed (the table never loses keys mid-insert), so re-checking the
    # proposal against the *current* table keeps claims race-free. --
    def claim_cond(st):
        kh, kl, placed, _, emp = st
        return jnp.any(alive & ~placed & (emp != 0))

    def claim_body(st):
        kh, kl, placed, wslot, emp = st
        want = alive & ~placed & (emp != 0)
        low = emp & (~emp + jnp.uint32(1))                    # lowest candidate bit
        r = jax.lax.population_count(low - jnp.uint32(1))     # its round index
        slot = _probe_slot_dyn(h0, jnp.where(want, r, 0), C)
        still_empty = (kh[slot] == 0) & (kl[slot] == 0)
        contend = want & still_empty
        # batch-local conflict resolution: one packed (slot, idx) sort key,
        # first row of each slot-run wins (deterministic-by-arrival).
        won = _claim_winners(slot, contend, B, C)
        drop_slot = jnp.where(won, slot.astype(jnp.int32), C)
        kh = kh.at[drop_slot].set(s_hi, mode="drop")
        kl = kl.at[drop_slot].set(s_lo, mode="drop")
        wslot = jnp.where(won, slot.astype(jnp.int32), wslot)
        placed = placed | won
        # every examined candidate is consumed (won, lost, or stale)
        emp = jnp.where(want, emp & ~low, emp)
        return kh, kl, placed, wslot, emp

    key_hi_tab, key_lo_tab, placed, write_slot, _ = jax.lax.while_loop(
        claim_cond, claim_body,
        (key_hi_tab, key_lo_tab, placed, write_slot, emp_bits))

    dropped = jnp.sum((alive & ~placed).astype(jnp.int32))
    return key_hi_tab, key_lo_tab, write_slot, placed, dropped


def _dedup_sorted(key_hi, key_lo, valid):
    """Stable lexsort by (hi, lo); returns (perm, seg_id, rep_mask, run_start).

    rep_mask marks the LAST row of each equal-key run in sorted order, so
    SET lanes naturally take the final (batch-order latest) value. Invalid
    rows have key (0,0) and sort first; they form segment(s) that callers
    mask out via the key-!=0 check.
    """
    perm = jnp.lexsort((key_lo, key_hi))  # lexsort is stable
    s_hi, s_lo = key_hi[perm], key_lo[perm]
    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_lo[:-1]])
    is_new = (s_hi != prev_hi) | (s_lo != prev_lo)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    nxt_new = jnp.concatenate([is_new[1:], jnp.ones((1,), bool)])
    rep_mask = nxt_new & ((s_hi != 0) | (s_lo != 0)) & valid[perm]
    return perm, seg_id, rep_mask


def _dedup_and_aggregate(key_hi, key_lo, updates, valid, mode_map):
    """Shared insert prologue: mask invalid rows to the empty key, dedup with
    a stable lexsort, land per-segment lane reductions on every row of the
    run. Returns (s_hi, s_lo, agg, alive) in dedup-sorted batch order; alive
    marks each unique key's representative row."""
    key_hi = jnp.where(valid, key_hi, 0).astype(jnp.uint32)
    key_lo = jnp.where(valid, key_lo, 0).astype(jnp.uint32)
    B = key_hi.shape[0]
    perm, seg_id, rep_mask = _dedup_sorted(key_hi, key_lo, valid)
    s_hi, s_lo = key_hi[perm], key_lo[perm]
    agg: Dict[str, jax.Array] = {}
    for name, upd in updates.items():
        upd_s = upd[perm]
        mode = mode_map[name]
        if mode == ADD:
            seg = jax.ops.segment_sum(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        elif mode == MAX:
            seg = jax.ops.segment_max(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        else:  # SET — representative row is the last of the run already.
            agg[name] = upd_s
    return s_hi, s_lo, agg, rep_mask


def _apply_lane_updates(lanes, agg, mode_map, ok, write_slot, C, rebase=None):
    """Shared insert epilogue: apply aggregated updates at write_slot
    (unique keys => unique slots; OOB sentinel C drops masked rows).

    ``rebase`` (lazy decay policy): name -> decayed-current-value [B] for
    ADD lanes that must be *rebased* on write — the slot's stored value is
    replaced by ``decayed_current + update`` instead of accumulated raw, so
    read-time decay from the refreshed ``last_tick`` stays exact.
    """
    safe = jnp.where(ok, write_slot, 0)
    drop = jnp.where(ok, write_slot, C)
    new_lanes = dict(lanes)
    for name, upd in agg.items():
        lane = new_lanes[name]
        mode = mode_map[name]
        if rebase is not None and name in rebase:
            new_lanes[name] = lane.at[drop].set(rebase[name] + upd, mode="drop")
        elif mode == ADD:
            zeros = jnp.zeros_like(upd)
            add = jnp.where(_bmask(ok, upd), upd, zeros)
            new_lanes[name] = lane.at[safe].add(add)
        elif mode == MAX:
            cur = lane[safe]
            new_lanes[name] = lane.at[drop].set(jnp.maximum(cur, upd), mode="drop")
        else:  # SET
            new_lanes[name] = lane.at[drop].set(upd, mode="drop")
    return new_lanes


@partial(jax.jit, static_argnames=("modes", "probe_rounds", "decay_cfg",
                                   "decay_lanes", "tick_lane"))
def insert_accumulate(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
) -> HashTable:
    """Batched insert-or-accumulate of (key -> lane updates).

    modes: tuple of (lane_name, ADD|SET|MAX) — a hashable static spec.

    Lazy decay policy (``decay_cfg`` + ``now``): ``decay_lanes`` are rebased
    on write — the stored value is decayed from the slot's ``tick_lane`` to
    ``now`` *before* the update is added, and the caller's SET of the tick
    lane to ``now`` re-anchors subsequent read-time decay. Without the
    rebase, refreshing ``last_tick`` would silently un-decay the elapsed
    gap. Exact for exponential decay (the factor is memoryless).
    """
    C = table.capacity
    mode_map = dict(modes)
    s_hi, s_lo, agg, alive = _dedup_and_aggregate(
        key_hi, key_lo, updates, valid, mode_map)

    key_hi_tab, key_lo_tab, write_slot, placed, dropped = _find_or_claim(
        table.key_hi, table.key_lo, s_hi, s_lo, alive, probe_rounds)

    ok = placed & alive
    rebase = None
    if decay_cfg is not None:
        safe = jnp.where(ok, write_slot, 0)
        f = decay_cfg.factor(jnp.maximum(now - table.lanes[tick_lane][safe], 0))
        rebase = {name: table.lanes[name][safe] * f for name in decay_lanes
                  if mode_map.get(name) == ADD}

    new_lanes = _apply_lane_updates(table.lanes, agg, mode_map,
                                    ok, write_slot, C, rebase=rebase)
    return HashTable(key_hi_tab, key_lo_tab, new_lanes, table.n_dropped + dropped)


@partial(jax.jit, static_argnames=("modes", "probe_rounds"))
def insert_accumulate_twopass(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
) -> HashTable:
    """Pre-fusion reference probe core (two unrolled probe passes, [C]-sized
    scatter-max claim race), sharing the dedup/aggregate prologue and
    lane-apply epilogue with ``insert_accumulate`` so parity tests compare
    ONLY the probe strategies. Kept for parity tests and before/after
    benchmarking; not used by the engine.
    """
    C = table.capacity
    mode_map = dict(modes)
    s_hi, s_lo, agg, alive = _dedup_and_aggregate(
        key_hi, key_lo, updates, valid, mode_map)
    B = s_hi.shape[0]
    h0 = probe_hash(s_hi, s_lo)

    found_slot = jnp.full((B,), -1, jnp.int32)
    for r in range(probe_rounds):
        slot = _probe_slot(h0, r, C)
        t_hi = table.key_hi[slot]
        t_lo = table.key_lo[slot]
        hit = alive & (found_slot < 0) & (t_hi == s_hi) & (t_lo == s_lo)
        found_slot = jnp.where(hit, slot.astype(jnp.int32), found_slot)

    key_hi_tab, key_lo_tab = table.key_hi, table.key_lo
    placed = found_slot >= 0
    write_slot = found_slot

    for r in range(probe_rounds):
        want = alive & ~placed
        slot = _probe_slot(h0, r, C)
        empty = (key_hi_tab[slot] == 0) & (key_lo_tab[slot] == 0)
        contend = want & empty
        claim = jnp.full((C,), -1, jnp.int32)
        claim = claim.at[slot].max(jnp.where(contend, jnp.arange(B, dtype=jnp.int32), -1))
        won = contend & (claim[slot] == jnp.arange(B, dtype=jnp.int32))
        drop_slot = jnp.where(won, slot.astype(jnp.int32), C)
        key_hi_tab = key_hi_tab.at[drop_slot].set(s_hi, mode="drop")
        key_lo_tab = key_lo_tab.at[drop_slot].set(s_lo, mode="drop")
        write_slot = jnp.where(won, slot.astype(jnp.int32), write_slot)
        placed = placed | won

    dropped = jnp.sum((alive & ~placed).astype(jnp.int32))

    new_lanes = _apply_lane_updates(table.lanes, agg, mode_map,
                                    placed & alive, write_slot, C)
    return HashTable(key_hi_tab, key_lo_tab, new_lanes, table.n_dropped + dropped)


def _bmask(mask: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a [B] mask against a [B, ...] lane update."""
    return mask.reshape(mask.shape + (1,) * (ref.ndim - 1))


@partial(jax.jit, static_argnames=("probe_rounds", "decay_cfg", "decay_lanes",
                                   "tick_lane"))
def lookup(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    *,
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Batched lookup. Returns (lanes_at_key, found_mask, slot).

    Lazy decay policy (``decay_cfg`` + ``now``): the returned ``decay_lanes``
    are the *read-time decayed view* ``w * factor(now - last_tick)`` — the
    store itself is untouched; maintenance is amortized into reads.
    """
    C = table.capacity
    key_hi = jnp.asarray(key_hi, jnp.uint32)
    key_lo = jnp.asarray(key_lo, jnp.uint32)
    h0 = probe_hash(key_hi, key_lo)
    B = key_hi.shape[0]
    nonzero = (key_hi != 0) | (key_lo != 0)

    # while_loop with early exit: most batches resolve in 1-2 rounds (only
    # genuinely-absent nonzero keys force the full prune-safe scan).
    def cond(st):
        r, found = st
        return (r < probe_rounds) & jnp.any(nonzero & (found < 0))

    def body(st):
        r, found = st
        slot = _probe_slot_dyn(h0, r, C)
        hit = nonzero & (found < 0) \
            & (table.key_hi[slot] == key_hi) & (table.key_lo[slot] == key_lo)
        return r + 1, jnp.where(hit, slot.astype(jnp.int32), found)

    _, found_slot = jax.lax.while_loop(
        cond, body, (jnp.uint32(0), jnp.full((B,), -1, jnp.int32)))
    found = found_slot >= 0
    safe = jnp.where(found, found_slot, 0)
    f = None
    if decay_cfg is not None:
        f = decay_cfg.factor(jnp.maximum(now - table.lanes[tick_lane][safe], 0))
    out = {}
    for name, lane in table.lanes.items():
        v = lane[safe]
        if f is not None and name in decay_lanes:
            v = v * f
        out[name] = jnp.where(_bmask(found, v), v, jnp.zeros_like(v))
    return out, found, found_slot


def export_live(table: HashTable) -> Dict[str, np.ndarray]:
    """Host-side export of live entries (for persistence / suggestion build)."""
    mask = np.asarray(table.live_mask)
    out = {
        "key_hi": np.asarray(table.key_hi)[mask],
        "key_lo": np.asarray(table.key_lo)[mask],
    }
    for name, lane in table.lanes.items():
        out[name] = np.asarray(lane)[mask]
    return out


# ---------------------------------------------------------------------------
# Sessions store: per-session sliding window ring buffers (paper §4.2).
# ---------------------------------------------------------------------------

class SessionTable(NamedTuple):
    key_hi: jax.Array    # u32[S]
    key_lo: jax.Array    # u32[S]
    ring_hi: jax.Array   # u32[S, W] — recent query fingerprints
    ring_lo: jax.Array   # u32[S, W]
    ring_src: jax.Array  # i32[S, W] — interaction source code per entry
    cursor: jax.Array    # i32[S] — next write position
    filled: jax.Array    # i32[S] — number of valid ring entries (<= W)
    last_tick: jax.Array  # i32[S]
    n_dropped: jax.Array  # i32[]

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def window(self) -> int:
        return self.ring_hi.shape[1]


def make_session_table(capacity: int, window: int) -> SessionTable:
    assert capacity & (capacity - 1) == 0
    return SessionTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        ring_hi=jnp.zeros((capacity, window), jnp.uint32),
        ring_lo=jnp.zeros((capacity, window), jnp.uint32),
        ring_src=jnp.zeros((capacity, window), jnp.int32),
        cursor=jnp.zeros((capacity,), jnp.int32),
        filled=jnp.zeros((capacity,), jnp.int32),
        last_tick=jnp.zeros((capacity,), jnp.int32),
        n_dropped=jnp.zeros((), jnp.int32),
    )


class PairBatch(NamedTuple):
    """Emitted (predecessor -> new query) cooccurrence pairs, [B*W] flat."""
    src_hi: jax.Array
    src_lo: jax.Array
    src_code: jax.Array
    dst_hi: jax.Array
    dst_lo: jax.Array
    dst_code: jax.Array
    valid: jax.Array


@partial(jax.jit, static_argnames=("probe_rounds",))
def update_sessions(
    table: SessionTable,
    sess_hi: jax.Array,
    sess_lo: jax.Array,
    q_hi: jax.Array,
    q_lo: jax.Array,
    src_code: jax.Array,
    tick: jax.Array,
    valid: jax.Array,
    *,
    probe_rounds: int = 16,
) -> Tuple[SessionTable, PairBatch]:
    """Append a micro-batch of queries to their sessions; emit pairs.

    Exact order semantics: events are processed in batch order *per session*
    (stable sort groups a session's events while preserving arrival order);
    a new query pairs with the W most recent predecessors, drawing first from
    earlier same-batch events, then from the pre-batch ring window.
    """
    S, W = table.capacity, table.window
    B = q_hi.shape[0]
    sess_hi = jnp.where(valid, sess_hi, 0).astype(jnp.uint32)
    sess_lo = jnp.where(valid, sess_lo, 0).astype(jnp.uint32)

    perm = jnp.lexsort((sess_lo, sess_hi))  # stable
    e_shi, e_slo = sess_hi[perm], sess_lo[perm]
    e_qhi, e_qlo = q_hi[perm], q_lo[perm]
    e_src = src_code[perm]
    e_valid = valid[perm] & ((e_shi != 0) | (e_slo != 0))

    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_shi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_slo[:-1]])
    is_new_run = (e_shi != prev_hi) | (e_slo != prev_lo)
    seg_id = jnp.cumsum(is_new_run.astype(jnp.int32)) - 1
    pos_in_run = jnp.arange(B, dtype=jnp.int32) - jax.ops.segment_min(
        jnp.arange(B, dtype=jnp.int32), seg_id, num_segments=B)[seg_id]
    run_len = jax.ops.segment_sum(jnp.ones((B,), jnp.int32), seg_id, num_segments=B)[seg_id]

    # ---- find/create the session row: single fused find-or-claim sweep
    # over the run representatives (unique session keys). ----
    rep = is_new_run & e_valid
    key_hi_tab, key_lo_tab, row, placed, dropped = _find_or_claim(
        table.key_hi, table.key_lo, e_shi, e_slo, rep, probe_rounds)
    # Broadcast the representative's row to every event in its run.
    rep_row = jax.ops.segment_max(jnp.where(rep, row, -1), seg_id, num_segments=B)
    row = rep_row[seg_id]
    e_ok = e_valid & (row >= 0)
    safe_row = jnp.where(e_ok, row, 0)

    pre_cursor = table.cursor[safe_row]
    pre_filled = table.filled[safe_row]

    # ---- emit pairs: d-th most recent predecessor, d = 1..W. ----
    n_intra = jnp.minimum(pos_in_run, W)
    pair_src_hi = jnp.zeros((B, W), jnp.uint32)
    pair_src_lo = jnp.zeros((B, W), jnp.uint32)
    pair_src_code = jnp.zeros((B, W), jnp.int32)
    pair_ok = jnp.zeros((B, W), bool)
    idx = jnp.arange(B, dtype=jnp.int32)
    for d in range(1, W + 1):
        take_intra = (d <= n_intra)
        j = jnp.maximum(idx - d, 0)
        intra_hi, intra_lo, intra_src = e_qhi[j], e_qlo[j], e_src[j]
        age = d - 1 - n_intra  # >= 0 when not intra
        ring_ok = (~take_intra) & (age < jnp.minimum(W - n_intra, pre_filled))
        ring_pos = jnp.mod(pre_cursor - 1 - age, W)
        r_hi = table.ring_hi[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_lo = table.ring_lo[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_src = table.ring_src[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        s_hi = jnp.where(take_intra, intra_hi, r_hi)
        s_lo = jnp.where(take_intra, intra_lo, r_lo)
        s_sc = jnp.where(take_intra, intra_src, r_src)
        ok = e_ok & (take_intra | ring_ok) & ((s_hi != 0) | (s_lo != 0))
        # drop self-pairs (identical consecutive queries)
        ok = ok & ~((s_hi == e_qhi) & (s_lo == e_qlo))
        pair_src_hi = pair_src_hi.at[:, d - 1].set(s_hi)
        pair_src_lo = pair_src_lo.at[:, d - 1].set(s_lo)
        pair_src_code = pair_src_code.at[:, d - 1].set(s_sc)
        pair_ok = pair_ok.at[:, d - 1].set(ok)

    # ---- write the last min(W, run_len) events of each run into the ring. ----
    should_write = e_ok & (pos_in_run >= run_len - W)
    wpos = jnp.mod(pre_cursor + pos_in_run, W)
    w_row = jnp.where(should_write, safe_row, S)  # OOB => dropped
    ring_hi = table.ring_hi.at[w_row, wpos].set(e_qhi, mode="drop")
    ring_lo = table.ring_lo.at[w_row, wpos].set(e_qlo, mode="drop")
    ring_src = table.ring_src.at[w_row, wpos].set(e_src, mode="drop")

    # cursor/filled advance once per run (apply at the run's last event).
    is_last = jnp.concatenate([is_new_run[1:], jnp.ones((1,), bool)])
    adv = e_ok & is_last
    a_row = jnp.where(adv, safe_row, S)
    new_cursor = jnp.mod(pre_cursor + run_len, W)
    new_filled = jnp.minimum(pre_filled + run_len, W)
    cursor = table.cursor.at[a_row].set(new_cursor, mode="drop")
    filled = table.filled.at[a_row].set(new_filled, mode="drop")
    last_tick = table.last_tick.at[a_row].set(
        jnp.full((B,), tick, jnp.int32), mode="drop")

    new_table = SessionTable(key_hi_tab, key_lo_tab, ring_hi, ring_lo, ring_src,
                             cursor, filled, last_tick, table.n_dropped + dropped)

    pairs = PairBatch(
        src_hi=pair_src_hi.reshape(-1),
        src_lo=pair_src_lo.reshape(-1),
        src_code=pair_src_code.reshape(-1),
        dst_hi=jnp.broadcast_to(e_qhi[:, None], (B, W)).reshape(-1),
        dst_lo=jnp.broadcast_to(e_qlo[:, None], (B, W)).reshape(-1),
        dst_code=jnp.broadcast_to(e_src[:, None], (B, W)).reshape(-1),
        valid=pair_ok.reshape(-1),
    )
    return new_table, pairs


@jax.jit
def evict_sessions(table: SessionTable, tick: jax.Array, ttl: int) -> SessionTable:
    """Prune sessions with no recent activity (paper's decay/prune cycle)."""
    live = (table.key_hi != 0) | (table.key_lo != 0)
    stale = live & ((tick - table.last_tick) > ttl)
    keep = ~stale
    return table._replace(
        key_hi=jnp.where(keep, table.key_hi, 0),
        key_lo=jnp.where(keep, table.key_lo, 0),
        cursor=jnp.where(keep, table.cursor, 0),
        filled=jnp.where(keep, table.filled, 0),
    )
