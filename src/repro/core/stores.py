"""In-memory statistics stores as fixed-capacity, dense-array hash tables.

The paper's backend holds three in-memory stores (§4.2): the *query
statistics store*, the *query cooccurrence statistics store*, and the
*sessions store*. The deployed Twitter engine used JVM hash-maps mutated
event-at-a-time; the TPU-native adaptation here uses **open-addressing hash
tables laid out as dense JAX arrays** updated by *micro-batches* of events:

  * keys are 64-bit fingerprints stored as two uint32 lanes (no jax x64),
  * a batch of updates is deduplicated with a stable lexsort + segment-sum,
  * existing keys are found with a K-round triangular probe (all rounds are
    scanned when a key may be absent, which makes lookups correct in the
    presence of pruned slots without tombstones; the sweep early-exits the
    moment every key is resolved),
  * finds and claims share ONE fused sweep (``_find_or_claim``): the find
    rounds also record each row's empty-slot candidates as a bitmask, then
    claim rounds resolve conflicts *batch-locally* — contenders for a slot
    are ordered by a single packed (slot, batch idx) key and the first of
    each slot-run wins, O(B log B) per round instead of a capacity-sized
    scatter-max race (unique keys after dedup => at most one winner per
    key, losers fall to their next bit); packing the batch index into the
    sort key makes the winner *deterministic-by-arrival* rather than a
    property of the sort's stability,
  * keys that fail to place after K rounds are *dropped and counted* — the
    paper's engine likewise rate-limits/prunes to bound memory (§4.4).

**Source-major region layout** (:class:`RegionTable`): the cooccurrence
store can alternatively be partitioned into fixed-width per-source
*regions*, organized for the query it serves — per-source top-k (§4).
Invariants (the region-layout contract, relied on by ``ranking.py``,
``decay.py`` and the checkpoint/replay path):

  * **region id = source qstore slot**: the chain *directory* is indexed by
    the source query's qstore slot (``chain_region[slot]`` lists the pool
    regions owned by the source whose fingerprint is ``chain_hi/lo[slot]``;
    the fingerprint detects slot reuse after qstore pruning). A ranking
    bucket is therefore known at *insert* time — no per-cycle grouping
    sort, the ``[n_regions, width]`` bucket grid is a pure reshape.
  * **spill chain order**: a source's regions are ordered by directory
    position (depth 0 = primary, then spill regions in allocation order);
    within a region, pairs sit at positions ``[0, fill)`` in insertion
    order (dedup-sorted order within a batch). Inserts append into the
    first region with a free tail slot, in chain order.
  * **freelist lifecycle**: regions with ``region_owner < 0`` are free;
    allocation claims them in ascending region-id order (deterministic).
    The prune/decay sweeps compact every region live-first, recount
    ``region_fill``, unlink emptied regions from their chain (closing the
    hole so the chain stays a prefix), clear *orphaned* regions (owner
    slot re-claimed by another source, or source gone from the qstore) and
    return all of them to the freelist.
  * pairs whose source is absent from the qstore at insert time, spill
    chains past ``max_chain`` regions, and allocation failures are all
    *dropped and counted* in ``n_dropped`` — never silent (§4.4 again).

All operations are functional (table in, table out) and jit-compatible.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import probe_hash, combine_fp_device

# Lane reduction modes.
ADD = "add"    # accumulate (weights, counts)
SET = "set"    # last-writer-wins (timestamps, language, src/dst fps)
MAX = "max"    # running max


class HashTable(NamedTuple):
    """Open-addressing hash table over (hi, lo) uint32 fingerprint pairs."""
    key_hi: jax.Array          # u32[C]; (0,0) == empty slot
    key_lo: jax.Array          # u32[C]
    lanes: Dict[str, jax.Array]   # each [C] or [C, ...]
    n_dropped: jax.Array       # i32[] — updates dropped due to probe failure

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def live_mask(self) -> jax.Array:
        return (self.key_hi != 0) | (self.key_lo != 0)

    def live_count(self) -> jax.Array:
        return jnp.sum(self.live_mask.astype(jnp.int32))


def make_table(capacity: int, lane_specs: Dict[str, Any]) -> HashTable:
    """lane_specs: name -> dtype or (dtype, trailing_shape)."""
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    lanes = {}
    for name, spec in lane_specs.items():
        if isinstance(spec, tuple):
            dtype, trailing = spec
            lanes[name] = jnp.zeros((capacity, *trailing), dtype=dtype)
        else:
            lanes[name] = jnp.zeros((capacity,), dtype=spec)
    return HashTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        lanes=lanes,
        n_dropped=jnp.zeros((), jnp.int32),
    )


def _probe_slot(h0: jax.Array, r: int, capacity: int) -> jax.Array:
    """Triangular probing: h0 + r(r+1)/2 mod C covers all slots for C=2^k."""
    return (h0 + jnp.uint32(r * (r + 1) // 2)) & jnp.uint32(capacity - 1)


def _probe_slot_dyn(h0: jax.Array, r: jax.Array, capacity: int) -> jax.Array:
    """`_probe_slot` with a *traced* round index (uint32 scalar or [B])."""
    r = r.astype(jnp.uint32)
    return (h0 + ((r * (r + 1)) >> 1)) & jnp.uint32(capacity - 1)


def _claim_winners(slot: jax.Array, contend: jax.Array, B: int, C: int
                   ) -> jax.Array:
    """First-of-each-slot-run claim resolution, deterministic-by-arrival.

    Packs ``(slot, batch idx)`` into ONE uint32 sort key whenever
    ``log2(C) + ceil_log2(B) <= 31`` (the common case), so the winner of
    every contended slot is the lowest batch index *by key value* — no
    reliance on sort stability. When the packed key would overflow 31 bits,
    falls back to a two-key lexsort over (idx, slot); the (slot, idx) pairs
    are unique, so any correct sort yields the same winners.

    Returns a [B] bool mask of winning rows (at most one per slot).
    """
    idx = jnp.arange(B, dtype=jnp.uint32)
    bits_b = max((B - 1).bit_length(), 1)
    if (C - 1).bit_length() + bits_b <= 31:
        sent = jnp.uint32(0xFFFFFFFF)
        packed = jnp.where(
            contend, (slot << jnp.uint32(bits_b)) | idx, sent)
        order = jnp.argsort(packed)
        po = packed[order]
        pslot = po >> jnp.uint32(bits_b)
        first = jnp.concatenate(
            [jnp.ones((1,), bool), pslot[1:] != pslot[:-1]])
        return jnp.zeros((B,), bool).at[order].set(first & (po != sent))
    skey = jnp.where(contend, slot.astype(jnp.int32), C)
    order = jnp.lexsort((idx.astype(jnp.int32), skey))
    so = skey[order]
    first = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
    return jnp.zeros((B,), bool).at[order].set(first & (so < C))


def _find_or_claim(
    key_hi_tab: jax.Array,
    key_lo_tab: jax.Array,
    s_hi: jax.Array,
    s_lo: jax.Array,
    alive: jax.Array,
    probe_rounds: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-sweep find-or-claim over unique keys (the store hot path).

    One probe sweep records, per row, the slot already holding its key (the
    full ``probe_rounds`` sequence is scanned so lookups stay correct in the
    presence of pruned slots) **and** a bitmask of empty slots along the
    sequence. A second `while_loop` then resolves insertions *batch-locally*:
    each round, every unplaced row proposes its next empty-at-snapshot slot,
    contenders for the same slot are resolved by sorting a single packed
    (slot, batch idx) key (first of each slot-run wins — O(B log B), never
    O(capacity), and deterministic-by-arrival; see ``_claim_winners``),
    and losers fall through to their next candidate bit. Both loops early-exit
    the moment every row is served, so the accumulate-heavy steady state costs
    a couple of probe rounds instead of 2 x ``probe_rounds`` full passes.

    Requires ``alive`` rows to carry *unique* keys (callers dedup first).
    Returns (key_hi_tab, key_lo_tab, slot, placed, n_dropped); ``slot`` is -1
    for rows that were not placed.
    """
    assert probe_rounds <= 32, "empty-slot bitmask is uint32"
    C = key_hi_tab.shape[0]
    B = s_hi.shape[0]
    h0 = probe_hash(s_hi, s_lo)

    # -- Sweep 1: find existing slots, record empty candidates as bits. --
    def find_cond(st):
        r, found, _ = st
        return (r < probe_rounds) & jnp.any(alive & (found < 0))

    def find_body(st):
        r, found, emp = st
        slot = _probe_slot_dyn(h0, r, C)
        t_hi = key_hi_tab[slot]
        t_lo = key_lo_tab[slot]
        hit = alive & (found < 0) & (t_hi == s_hi) & (t_lo == s_lo)
        found = jnp.where(hit, slot.astype(jnp.int32), found)
        empty = (t_hi == 0) & (t_lo == 0)
        bit = jnp.left_shift(jnp.uint32(1), r.astype(jnp.uint32))
        emp = emp | jnp.where(empty, bit, jnp.uint32(0))
        return r + 1, found, emp

    _, found_slot, emp_bits = jax.lax.while_loop(
        find_cond, find_body,
        (jnp.uint32(0), jnp.full((B,), -1, jnp.int32),
         jnp.zeros((B,), jnp.uint32)))

    placed = found_slot >= 0
    write_slot = found_slot

    # -- Sweep 2: claim rounds. Slots empty at snapshot time can only be
    # consumed (the table never loses keys mid-insert), so re-checking the
    # proposal against the *current* table keeps claims race-free. --
    def claim_cond(st):
        kh, kl, placed, _, emp = st
        return jnp.any(alive & ~placed & (emp != 0))

    def claim_body(st):
        kh, kl, placed, wslot, emp = st
        want = alive & ~placed & (emp != 0)
        low = emp & (~emp + jnp.uint32(1))                    # lowest candidate bit
        r = jax.lax.population_count(low - jnp.uint32(1))     # its round index
        slot = _probe_slot_dyn(h0, jnp.where(want, r, 0), C)
        still_empty = (kh[slot] == 0) & (kl[slot] == 0)
        contend = want & still_empty
        # batch-local conflict resolution: one packed (slot, idx) sort key,
        # first row of each slot-run wins (deterministic-by-arrival).
        won = _claim_winners(slot, contend, B, C)
        drop_slot = jnp.where(won, slot.astype(jnp.int32), C)
        kh = kh.at[drop_slot].set(s_hi, mode="drop")
        kl = kl.at[drop_slot].set(s_lo, mode="drop")
        wslot = jnp.where(won, slot.astype(jnp.int32), wslot)
        placed = placed | won
        # every examined candidate is consumed (won, lost, or stale)
        emp = jnp.where(want, emp & ~low, emp)
        return kh, kl, placed, wslot, emp

    key_hi_tab, key_lo_tab, placed, write_slot, _ = jax.lax.while_loop(
        claim_cond, claim_body,
        (key_hi_tab, key_lo_tab, placed, write_slot, emp_bits))

    dropped = jnp.sum((alive & ~placed).astype(jnp.int32))
    return key_hi_tab, key_lo_tab, write_slot, placed, dropped


def _dedup_sorted(key_hi, key_lo, valid):
    """Stable lexsort by (hi, lo); returns (perm, seg_id, rep_mask, run_start).

    rep_mask marks the LAST row of each equal-key run in sorted order, so
    SET lanes naturally take the final (batch-order latest) value. Invalid
    rows have key (0,0) and sort first; they form segment(s) that callers
    mask out via the key-!=0 check.
    """
    perm = jnp.lexsort((key_lo, key_hi))  # lexsort is stable
    s_hi, s_lo = key_hi[perm], key_lo[perm]
    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), s_lo[:-1]])
    is_new = (s_hi != prev_hi) | (s_lo != prev_lo)
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    nxt_new = jnp.concatenate([is_new[1:], jnp.ones((1,), bool)])
    rep_mask = nxt_new & ((s_hi != 0) | (s_lo != 0)) & valid[perm]
    return perm, seg_id, rep_mask


def _dedup_and_aggregate(key_hi, key_lo, updates, valid, mode_map):
    """Shared insert prologue: mask invalid rows to the empty key, dedup with
    a stable lexsort, land per-segment lane reductions on every row of the
    run. Returns (s_hi, s_lo, agg, alive) in dedup-sorted batch order; alive
    marks each unique key's representative row."""
    key_hi = jnp.where(valid, key_hi, 0).astype(jnp.uint32)
    key_lo = jnp.where(valid, key_lo, 0).astype(jnp.uint32)
    B = key_hi.shape[0]
    perm, seg_id, rep_mask = _dedup_sorted(key_hi, key_lo, valid)
    s_hi, s_lo = key_hi[perm], key_lo[perm]
    agg: Dict[str, jax.Array] = {}
    for name, upd in updates.items():
        upd_s = upd[perm]
        mode = mode_map[name]
        if mode == ADD:
            seg = jax.ops.segment_sum(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        elif mode == MAX:
            seg = jax.ops.segment_max(upd_s, seg_id, num_segments=B)
            agg[name] = seg[seg_id]
        else:  # SET — representative row is the last of the run already.
            agg[name] = upd_s
    return s_hi, s_lo, agg, rep_mask


def _apply_lane_updates(lanes, agg, mode_map, ok, write_slot, C, rebase=None):
    """Shared insert epilogue: apply aggregated updates at write_slot
    (unique keys => unique slots; OOB sentinel C drops masked rows).

    ``rebase`` (lazy decay policy): name -> decayed-current-value [B] for
    ADD lanes that must be *rebased* on write — the slot's stored value is
    replaced by ``decayed_current + update`` instead of accumulated raw, so
    read-time decay from the refreshed ``last_tick`` stays exact.
    """
    safe = jnp.where(ok, write_slot, 0)
    drop = jnp.where(ok, write_slot, C)
    new_lanes = dict(lanes)
    for name, upd in agg.items():
        lane = new_lanes[name]
        mode = mode_map[name]
        if rebase is not None and name in rebase:
            new_lanes[name] = lane.at[drop].set(rebase[name] + upd, mode="drop")
        elif mode == ADD:
            zeros = jnp.zeros_like(upd)
            add = jnp.where(_bmask(ok, upd), upd, zeros)
            new_lanes[name] = lane.at[safe].add(add)
        elif mode == MAX:
            cur = lane[safe]
            new_lanes[name] = lane.at[drop].set(jnp.maximum(cur, upd), mode="drop")
        else:  # SET
            new_lanes[name] = lane.at[drop].set(upd, mode="drop")
    return new_lanes


@partial(jax.jit, static_argnames=("modes", "probe_rounds", "decay_cfg",
                                   "decay_lanes", "tick_lane"))
def insert_accumulate(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
) -> HashTable:
    """Batched insert-or-accumulate of (key -> lane updates).

    modes: tuple of (lane_name, ADD|SET|MAX) — a hashable static spec.

    Lazy decay policy (``decay_cfg`` + ``now``): ``decay_lanes`` are rebased
    on write — the stored value is decayed from the slot's ``tick_lane`` to
    ``now`` *before* the update is added, and the caller's SET of the tick
    lane to ``now`` re-anchors subsequent read-time decay. Without the
    rebase, refreshing ``last_tick`` would silently un-decay the elapsed
    gap. Exact for exponential decay (the factor is memoryless).
    """
    C = table.capacity
    mode_map = dict(modes)
    s_hi, s_lo, agg, alive = _dedup_and_aggregate(
        key_hi, key_lo, updates, valid, mode_map)

    key_hi_tab, key_lo_tab, write_slot, placed, dropped = _find_or_claim(
        table.key_hi, table.key_lo, s_hi, s_lo, alive, probe_rounds)

    ok = placed & alive
    rebase = None
    if decay_cfg is not None:
        safe = jnp.where(ok, write_slot, 0)
        f = decay_cfg.factor(jnp.maximum(now - table.lanes[tick_lane][safe], 0))
        rebase = {name: table.lanes[name][safe] * f for name in decay_lanes
                  if mode_map.get(name) == ADD}

    new_lanes = _apply_lane_updates(table.lanes, agg, mode_map,
                                    ok, write_slot, C, rebase=rebase)
    return HashTable(key_hi_tab, key_lo_tab, new_lanes, table.n_dropped + dropped)


@partial(jax.jit, static_argnames=("modes", "probe_rounds"))
def insert_accumulate_twopass(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
) -> HashTable:
    """Pre-fusion reference probe core (two unrolled probe passes, [C]-sized
    scatter-max claim race), sharing the dedup/aggregate prologue and
    lane-apply epilogue with ``insert_accumulate`` so parity tests compare
    ONLY the probe strategies. Kept for parity tests and before/after
    benchmarking; not used by the engine.
    """
    C = table.capacity
    mode_map = dict(modes)
    s_hi, s_lo, agg, alive = _dedup_and_aggregate(
        key_hi, key_lo, updates, valid, mode_map)
    B = s_hi.shape[0]
    h0 = probe_hash(s_hi, s_lo)

    found_slot = jnp.full((B,), -1, jnp.int32)
    for r in range(probe_rounds):
        slot = _probe_slot(h0, r, C)
        t_hi = table.key_hi[slot]
        t_lo = table.key_lo[slot]
        hit = alive & (found_slot < 0) & (t_hi == s_hi) & (t_lo == s_lo)
        found_slot = jnp.where(hit, slot.astype(jnp.int32), found_slot)

    key_hi_tab, key_lo_tab = table.key_hi, table.key_lo
    placed = found_slot >= 0
    write_slot = found_slot

    for r in range(probe_rounds):
        want = alive & ~placed
        slot = _probe_slot(h0, r, C)
        empty = (key_hi_tab[slot] == 0) & (key_lo_tab[slot] == 0)
        contend = want & empty
        claim = jnp.full((C,), -1, jnp.int32)
        claim = claim.at[slot].max(jnp.where(contend, jnp.arange(B, dtype=jnp.int32), -1))
        won = contend & (claim[slot] == jnp.arange(B, dtype=jnp.int32))
        drop_slot = jnp.where(won, slot.astype(jnp.int32), C)
        key_hi_tab = key_hi_tab.at[drop_slot].set(s_hi, mode="drop")
        key_lo_tab = key_lo_tab.at[drop_slot].set(s_lo, mode="drop")
        write_slot = jnp.where(won, slot.astype(jnp.int32), write_slot)
        placed = placed | won

    dropped = jnp.sum((alive & ~placed).astype(jnp.int32))

    new_lanes = _apply_lane_updates(table.lanes, agg, mode_map,
                                    placed & alive, write_slot, C)
    return HashTable(key_hi_tab, key_lo_tab, new_lanes, table.n_dropped + dropped)


def _bmask(mask: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a [B] mask against a [B, ...] lane update."""
    return mask.reshape(mask.shape + (1,) * (ref.ndim - 1))


@partial(jax.jit, static_argnames=("probe_rounds", "decay_cfg", "decay_lanes",
                                   "tick_lane"))
def lookup(
    table: HashTable,
    key_hi: jax.Array,
    key_lo: jax.Array,
    *,
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Batched lookup. Returns (lanes_at_key, found_mask, slot).

    Lazy decay policy (``decay_cfg`` + ``now``): the returned ``decay_lanes``
    are the *read-time decayed view* ``w * factor(now - last_tick)`` — the
    store itself is untouched; maintenance is amortized into reads.
    """
    C = table.capacity
    key_hi = jnp.asarray(key_hi, jnp.uint32)
    key_lo = jnp.asarray(key_lo, jnp.uint32)
    h0 = probe_hash(key_hi, key_lo)
    B = key_hi.shape[0]
    nonzero = (key_hi != 0) | (key_lo != 0)

    # while_loop with early exit: most batches resolve in 1-2 rounds (only
    # genuinely-absent nonzero keys force the full prune-safe scan).
    def cond(st):
        r, found = st
        return (r < probe_rounds) & jnp.any(nonzero & (found < 0))

    def body(st):
        r, found = st
        slot = _probe_slot_dyn(h0, r, C)
        hit = nonzero & (found < 0) \
            & (table.key_hi[slot] == key_hi) & (table.key_lo[slot] == key_lo)
        return r + 1, jnp.where(hit, slot.astype(jnp.int32), found)

    _, found_slot = jax.lax.while_loop(
        cond, body, (jnp.uint32(0), jnp.full((B,), -1, jnp.int32)))
    found = found_slot >= 0
    safe = jnp.where(found, found_slot, 0)
    f = None
    if decay_cfg is not None:
        f = decay_cfg.factor(jnp.maximum(now - table.lanes[tick_lane][safe], 0))
    out = {}
    for name, lane in table.lanes.items():
        v = lane[safe]
        if f is not None and name in decay_lanes:
            v = v * f
        out[name] = jnp.where(_bmask(found, v), v, jnp.zeros_like(v))
    return out, found, found_slot


def diff_leading_rows(prev: np.ndarray, new: np.ndarray) -> np.ndarray:
    """Dirty-slot extraction for incremental (delta) snapshots.

    Returns the leading indices (slots) where ``new`` differs from ``prev``
    — the rows a delta snapshot must record. Under the lazy decay policy
    this set is exactly what the store mutation paths touched since the
    base snapshot: slots written by ``insert_accumulate`` /
    ``region_insert_accumulate`` (rebase-on-write refreshes ``last_tick``,
    so a touched slot always differs) plus slots the prune sweeps reclaimed
    or compacted. Computing it by content-compare instead of threading
    dirty masks through every jitted op keeps it exact under *every*
    policy/layout combination (eager sweeps rewrite all live weights — the
    delta correctly grows to match) and keeps ``EngineState`` free of
    snapshot-cadence-dependent lanes that would break the bit-exact
    crash→restore→replay property. NaN-unsafe compares only ever *add*
    rows (NaN != NaN), never lose one.
    """
    assert prev.shape == new.shape and prev.dtype == new.dtype
    neq = prev != new
    if neq.ndim > 1:
        neq = neq.reshape(neq.shape[0], -1).any(axis=1)
    return np.nonzero(neq)[0].astype(np.int64)


def apply_row_delta(base: np.ndarray, idx: np.ndarray,
                    rows: np.ndarray) -> np.ndarray:
    """Scatter a delta's changed rows back onto the base snapshot's array
    (in place when writable — npz loads are). Inverse of
    :func:`diff_leading_rows` given the base it was diffed against."""
    if not base.flags.writeable:
        base = base.copy()
    base[idx] = rows
    return base


def export_live(table: HashTable) -> Dict[str, np.ndarray]:
    """Host-side export of live entries (for persistence / suggestion build)."""
    mask = np.asarray(table.live_mask)
    out = {
        "key_hi": np.asarray(table.key_hi)[mask],
        "key_lo": np.asarray(table.key_lo)[mask],
    }
    for name, lane in table.lanes.items():
        out[name] = np.asarray(lane)[mask]
    return out


# ---------------------------------------------------------------------------
# Sessions store: per-session sliding window ring buffers (paper §4.2).
# ---------------------------------------------------------------------------

class SessionTable(NamedTuple):
    key_hi: jax.Array    # u32[S]
    key_lo: jax.Array    # u32[S]
    ring_hi: jax.Array   # u32[S, W] — recent query fingerprints
    ring_lo: jax.Array   # u32[S, W]
    ring_src: jax.Array  # i32[S, W] — interaction source code per entry
    cursor: jax.Array    # i32[S] — next write position
    filled: jax.Array    # i32[S] — number of valid ring entries (<= W)
    last_tick: jax.Array  # i32[S]
    n_dropped: jax.Array  # i32[]

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def window(self) -> int:
        return self.ring_hi.shape[1]


def make_session_table(capacity: int, window: int) -> SessionTable:
    assert capacity & (capacity - 1) == 0
    return SessionTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        ring_hi=jnp.zeros((capacity, window), jnp.uint32),
        ring_lo=jnp.zeros((capacity, window), jnp.uint32),
        ring_src=jnp.zeros((capacity, window), jnp.int32),
        cursor=jnp.zeros((capacity,), jnp.int32),
        filled=jnp.zeros((capacity,), jnp.int32),
        last_tick=jnp.zeros((capacity,), jnp.int32),
        n_dropped=jnp.zeros((), jnp.int32),
    )


class PairBatch(NamedTuple):
    """Emitted (predecessor -> new query) cooccurrence pairs, [B*W] flat."""
    src_hi: jax.Array
    src_lo: jax.Array
    src_code: jax.Array
    dst_hi: jax.Array
    dst_lo: jax.Array
    dst_code: jax.Array
    valid: jax.Array


@partial(jax.jit, static_argnames=("probe_rounds",))
def update_sessions(
    table: SessionTable,
    sess_hi: jax.Array,
    sess_lo: jax.Array,
    q_hi: jax.Array,
    q_lo: jax.Array,
    src_code: jax.Array,
    tick: jax.Array,
    valid: jax.Array,
    *,
    probe_rounds: int = 16,
) -> Tuple[SessionTable, PairBatch]:
    """Append a micro-batch of queries to their sessions; emit pairs.

    Exact order semantics: events are processed in batch order *per session*
    (stable sort groups a session's events while preserving arrival order);
    a new query pairs with the W most recent predecessors, drawing first from
    earlier same-batch events, then from the pre-batch ring window.
    """
    S, W = table.capacity, table.window
    B = q_hi.shape[0]
    sess_hi = jnp.where(valid, sess_hi, 0).astype(jnp.uint32)
    sess_lo = jnp.where(valid, sess_lo, 0).astype(jnp.uint32)

    perm = jnp.lexsort((sess_lo, sess_hi))  # stable
    e_shi, e_slo = sess_hi[perm], sess_lo[perm]
    e_qhi, e_qlo = q_hi[perm], q_lo[perm]
    e_src = src_code[perm]
    e_valid = valid[perm] & ((e_shi != 0) | (e_slo != 0))

    prev_hi = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_shi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, jnp.uint32), e_slo[:-1]])
    is_new_run = (e_shi != prev_hi) | (e_slo != prev_lo)
    seg_id = jnp.cumsum(is_new_run.astype(jnp.int32)) - 1
    pos_in_run = jnp.arange(B, dtype=jnp.int32) - jax.ops.segment_min(
        jnp.arange(B, dtype=jnp.int32), seg_id, num_segments=B)[seg_id]
    run_len = jax.ops.segment_sum(jnp.ones((B,), jnp.int32), seg_id, num_segments=B)[seg_id]

    # ---- find/create the session row: single fused find-or-claim sweep
    # over the run representatives (unique session keys). ----
    rep = is_new_run & e_valid
    key_hi_tab, key_lo_tab, row, placed, dropped = _find_or_claim(
        table.key_hi, table.key_lo, e_shi, e_slo, rep, probe_rounds)
    # Broadcast the representative's row to every event in its run.
    rep_row = jax.ops.segment_max(jnp.where(rep, row, -1), seg_id, num_segments=B)
    row = rep_row[seg_id]
    e_ok = e_valid & (row >= 0)
    safe_row = jnp.where(e_ok, row, 0)

    pre_cursor = table.cursor[safe_row]
    pre_filled = table.filled[safe_row]

    # ---- emit pairs: d-th most recent predecessor, d = 1..W. ----
    n_intra = jnp.minimum(pos_in_run, W)
    pair_src_hi = jnp.zeros((B, W), jnp.uint32)
    pair_src_lo = jnp.zeros((B, W), jnp.uint32)
    pair_src_code = jnp.zeros((B, W), jnp.int32)
    pair_ok = jnp.zeros((B, W), bool)
    idx = jnp.arange(B, dtype=jnp.int32)
    for d in range(1, W + 1):
        take_intra = (d <= n_intra)
        j = jnp.maximum(idx - d, 0)
        intra_hi, intra_lo, intra_src = e_qhi[j], e_qlo[j], e_src[j]
        age = d - 1 - n_intra  # >= 0 when not intra
        ring_ok = (~take_intra) & (age < jnp.minimum(W - n_intra, pre_filled))
        ring_pos = jnp.mod(pre_cursor - 1 - age, W)
        r_hi = table.ring_hi[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_lo = table.ring_lo[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        r_src = table.ring_src[safe_row, jnp.where(ring_ok, ring_pos, 0)]
        s_hi = jnp.where(take_intra, intra_hi, r_hi)
        s_lo = jnp.where(take_intra, intra_lo, r_lo)
        s_sc = jnp.where(take_intra, intra_src, r_src)
        ok = e_ok & (take_intra | ring_ok) & ((s_hi != 0) | (s_lo != 0))
        # drop self-pairs (identical consecutive queries)
        ok = ok & ~((s_hi == e_qhi) & (s_lo == e_qlo))
        pair_src_hi = pair_src_hi.at[:, d - 1].set(s_hi)
        pair_src_lo = pair_src_lo.at[:, d - 1].set(s_lo)
        pair_src_code = pair_src_code.at[:, d - 1].set(s_sc)
        pair_ok = pair_ok.at[:, d - 1].set(ok)

    # ---- write the last min(W, run_len) events of each run into the ring. ----
    should_write = e_ok & (pos_in_run >= run_len - W)
    wpos = jnp.mod(pre_cursor + pos_in_run, W)
    w_row = jnp.where(should_write, safe_row, S)  # OOB => dropped
    ring_hi = table.ring_hi.at[w_row, wpos].set(e_qhi, mode="drop")
    ring_lo = table.ring_lo.at[w_row, wpos].set(e_qlo, mode="drop")
    ring_src = table.ring_src.at[w_row, wpos].set(e_src, mode="drop")

    # cursor/filled advance once per run (apply at the run's last event).
    is_last = jnp.concatenate([is_new_run[1:], jnp.ones((1,), bool)])
    adv = e_ok & is_last
    a_row = jnp.where(adv, safe_row, S)
    new_cursor = jnp.mod(pre_cursor + run_len, W)
    new_filled = jnp.minimum(pre_filled + run_len, W)
    cursor = table.cursor.at[a_row].set(new_cursor, mode="drop")
    filled = table.filled.at[a_row].set(new_filled, mode="drop")
    last_tick = table.last_tick.at[a_row].set(
        jnp.full((B,), tick, jnp.int32), mode="drop")

    new_table = SessionTable(key_hi_tab, key_lo_tab, ring_hi, ring_lo, ring_src,
                             cursor, filled, last_tick, table.n_dropped + dropped)

    pairs = PairBatch(
        src_hi=pair_src_hi.reshape(-1),
        src_lo=pair_src_lo.reshape(-1),
        src_code=pair_src_code.reshape(-1),
        dst_hi=jnp.broadcast_to(e_qhi[:, None], (B, W)).reshape(-1),
        dst_lo=jnp.broadcast_to(e_qlo[:, None], (B, W)).reshape(-1),
        dst_code=jnp.broadcast_to(e_src[:, None], (B, W)).reshape(-1),
        valid=pair_ok.reshape(-1),
    )
    return new_table, pairs


@jax.jit
def evict_sessions(table: SessionTable, tick: jax.Array, ttl: int) -> SessionTable:
    """Prune sessions with no recent activity (paper's decay/prune cycle)."""
    live = (table.key_hi != 0) | (table.key_lo != 0)
    stale = live & ((tick - table.last_tick) > ttl)
    keep = ~stale
    return table._replace(
        key_hi=jnp.where(keep, table.key_hi, 0),
        key_lo=jnp.where(keep, table.key_lo, 0),
        cursor=jnp.where(keep, table.cursor, 0),
        filled=jnp.where(keep, table.filled, 0),
    )


# ---------------------------------------------------------------------------
# Source-major region layout for the cooccurrence store.
#
# See the module docstring for the three invariants (region id = source
# qstore slot via the chain directory, spill chain order, freelist
# lifecycle). The per-slot key is the *destination* fingerprint only — the
# region already implies the source, so the four src/dst endpoint lanes of
# the hash layout collapse into the key lanes (≈45% less state per pair).
# ---------------------------------------------------------------------------

class RegionTable(NamedTuple):
    """Source-major cooccurrence store: ``n_regions`` regions of ``width``
    slots; regions are pool-allocated to sources, chained through a
    directory indexed by the source's qstore slot."""
    key_hi: jax.Array        # u32[C] — dst fingerprint; (0,0) == empty slot
    key_lo: jax.Array        # u32[C]
    lanes: Dict[str, jax.Array]   # each [C] (1-D only)
    chain_region: jax.Array  # i32[Q, MC] — region ids, -1 = none (prefix)
    chain_hi: jax.Array      # u32[Q] — source fp owning the chain at slot q
    chain_lo: jax.Array      # u32[Q]
    region_fill: jax.Array   # i32[R] — live pairs, packed at [0, fill)
    region_owner: jax.Array  # i32[R] — owning qstore slot, -1 = free
    n_dropped: jax.Array     # i32[] — src-missing / chain-full / pool-empty

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]

    @property
    def n_regions(self) -> int:
        return self.region_fill.shape[0]

    @property
    def width(self) -> int:
        return self.capacity // self.n_regions

    @property
    def max_chain(self) -> int:
        return self.chain_region.shape[1]

    @property
    def dir_slots(self) -> int:
        return self.chain_region.shape[0]

    @property
    def live_mask(self) -> jax.Array:
        return (self.key_hi != 0) | (self.key_lo != 0)

    def live_count(self) -> jax.Array:
        return jnp.sum(self.live_mask.astype(jnp.int32))

    def free_regions(self) -> jax.Array:
        """Freelist pressure: regions available for allocation."""
        return jnp.sum((self.region_owner < 0).astype(jnp.int32))


def make_region_table(capacity: int, region_width: int, dir_slots: int,
                      max_chain: int, lane_specs: Dict[str, Any]
                      ) -> RegionTable:
    """``dir_slots`` must equal the qstore capacity (region id = qstore
    slot); ``capacity = n_regions * region_width``."""
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    assert region_width & (region_width - 1) == 0 and region_width > 0
    assert capacity % region_width == 0 and capacity >= region_width
    assert max_chain >= 1
    n_regions = capacity // region_width
    lanes = {}
    for name, spec in lane_specs.items():
        assert not isinstance(spec, tuple), "region lanes must be 1-D"
        lanes[name] = jnp.zeros((capacity,), dtype=spec)
    return RegionTable(
        key_hi=jnp.zeros((capacity,), jnp.uint32),
        key_lo=jnp.zeros((capacity,), jnp.uint32),
        lanes=lanes,
        chain_region=jnp.full((dir_slots, max_chain), -1, jnp.int32),
        chain_hi=jnp.zeros((dir_slots,), jnp.uint32),
        chain_lo=jnp.zeros((dir_slots,), jnp.uint32),
        region_fill=jnp.zeros((n_regions,), jnp.int32),
        region_owner=jnp.full((n_regions,), -1, jnp.int32),
        n_dropped=jnp.zeros((), jnp.int32),
    )


def region_chain_state(table: RegionTable, qstore: HashTable
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """THE chain-validity invariant, shared by ranking and the sweeps: a
    directory row is live iff it has a chain head AND its recorded
    fingerprint still owns that qstore slot (slot reuse / source pruning
    otherwise orphans the chain). Returns

      * ``row_valid`` bool[Q]  — directory rows with a live, owned chain,
      * ``ent_ok``   bool[Q, MC] — live chain entries,
      * ``referenced`` bool[R] — regions reachable from a live chain.
    """
    assert table.dir_slots == qstore.capacity
    R = table.n_regions
    row_valid = (table.chain_region[:, 0] >= 0) \
        & (qstore.key_hi == table.chain_hi) \
        & (qstore.key_lo == table.chain_lo) \
        & ((qstore.key_hi != 0) | (qstore.key_lo != 0))
    ent = table.chain_region
    ent_ok = (ent >= 0) & row_valid[:, None]
    referenced = jnp.zeros((R,), bool).at[
        jnp.where(ent_ok, ent, R).reshape(-1)].set(True, mode="drop")
    return row_valid, ent_ok, referenced


def _group_ranks(slot: jax.Array, mask: jax.Array, Q: int) -> jax.Array:
    """Rank (0-based) of each masked row within its slot group, in row
    order — the claim-side analogue of ``_claim_winners``: one packed
    (slot, idx) u32 sort when it fits 31 bits, (idx, slot) lexsort
    otherwise. Unmasked rows get garbage ranks (callers mask)."""
    B = slot.shape[0]
    idx = jnp.arange(B, dtype=jnp.uint32)
    bits_b = max((B - 1).bit_length(), 1)
    if (Q - 1).bit_length() + bits_b <= 31:
        sent = jnp.uint32(0xFFFFFFFF)
        packed = jnp.where(mask,
                           (slot.astype(jnp.uint32) << jnp.uint32(bits_b))
                           | idx, sent)
        order = jnp.argsort(packed)
        pslot = packed[order] >> jnp.uint32(bits_b)
    else:
        skey = jnp.where(mask, slot.astype(jnp.int32), Q)
        order = jnp.lexsort((idx.astype(jnp.int32), skey))
        pslot = skey[order].astype(jnp.uint32)
    is_new = jnp.concatenate([jnp.ones((1,), bool), pslot[1:] != pslot[:-1]])
    ar = jnp.arange(B, dtype=jnp.int32)
    rank_sorted = ar - jax.lax.cummax(jnp.where(is_new, ar, 0))
    return jnp.zeros((B,), jnp.int32).at[order].set(rank_sorted)


def _chain_find_jnp(khi_r, klo_r, regs, dst_hi, dst_lo, active):
    """Early-exit chain scan: depth d gathers each pair's region tile
    ``[B, W]`` (ONE contiguous W-slot row per pair — the locality the
    layout buys) and matches the dst key. Most chains are one region deep,
    so the steady state costs a single round."""
    B, MC = regs.shape
    W = khi_r.shape[1]

    def cond(st):
        d, found = st
        col = jax.lax.dynamic_slice_in_dim(regs, jnp.minimum(d, MC - 1), 1,
                                           axis=1)[:, 0]
        return (d < MC) & jnp.any(active & (found < 0) & (col >= 0))

    def body(st):
        d, found = st
        col = jax.lax.dynamic_slice_in_dim(regs, d, 1, axis=1)[:, 0]
        want = active & (found < 0) & (col >= 0)
        reg_safe = jnp.where(col >= 0, col, 0)
        m = want[:, None] & (khi_r[reg_safe] == dst_hi[:, None]) \
            & (klo_r[reg_safe] == dst_lo[:, None])
        pos = jnp.argmax(m, axis=1).astype(jnp.int32)
        hit = jnp.any(m, axis=1)
        found = jnp.where(hit, reg_safe * W + pos, found)
        return d + 1, found

    _, found = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.full((B,), -1, jnp.int32)))
    return found


@partial(jax.jit, static_argnames=("modes", "probe_rounds", "decay_cfg",
                                   "decay_lanes", "tick_lane", "use_kernel",
                                   "plan"))
def region_insert_accumulate(
    table: RegionTable,
    qstore: HashTable,
    src_hi: jax.Array,
    src_lo: jax.Array,
    dst_hi: jax.Array,
    dst_lo: jax.Array,
    updates: Dict[str, jax.Array],
    valid: jax.Array,
    *,
    modes: Tuple[Tuple[str, str], ...],
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
    use_kernel: Optional[bool] = None,
    plan=None,
) -> RegionTable:
    """Batched insert-or-accumulate of (src -> dst) pairs, region layout.

    The source's qstore slot names its chain directly (no pair-key
    probing): finds scan the chain's region tiles, claims *append* at each
    region's fill tail in chain order, new regions come off the freelist in
    ascending-id order. Accumulation semantics (dedup by the combined pair
    fingerprint, ADD/SET/MAX lane reductions, lazy-decay rebase-on-write)
    match :func:`insert_accumulate` exactly. Drops — source absent from the
    qstore, spill chain exhausted, region pool exhausted — are counted in
    ``n_dropped``.
    """
    C, R, W, MC = (table.capacity, table.n_regions, table.width,
                   table.max_chain)
    Q = table.dir_slots
    assert Q == qstore.capacity, "directory must be indexed by qstore slot"
    mode_map = dict(modes)
    B = src_hi.shape[0]

    # -- dedup by the combined pair fp (same grouping as the hash layout);
    # src/dst ride along as SET lanes so representatives carry them. --
    p_hi, p_lo = combine_fp_device(src_hi, src_lo, dst_hi, dst_lo)
    full_updates = dict(updates)
    full_updates.update({"_src_hi": src_hi, "_src_lo": src_lo,
                         "_dst_hi": dst_hi, "_dst_lo": dst_lo})
    full_modes = dict(mode_map)
    full_modes.update({"_src_hi": SET, "_src_lo": SET,
                       "_dst_hi": SET, "_dst_lo": SET})
    s_hi, s_lo, agg, alive = _dedup_and_aggregate(
        p_hi, p_lo, full_updates, valid, full_modes)
    a_src_hi = agg.pop("_src_hi").astype(jnp.uint32)
    a_src_lo = agg.pop("_src_lo").astype(jnp.uint32)
    a_dst_hi = agg.pop("_dst_hi").astype(jnp.uint32)
    a_dst_lo = agg.pop("_dst_lo").astype(jnp.uint32)

    # -- the source's qstore slot IS the region chain id. --
    _, src_found, qslot = lookup(qstore, a_src_hi, a_src_lo,
                                 probe_rounds=probe_rounds)
    alive2 = alive & src_found
    n_src_miss = jnp.sum((alive & ~src_found).astype(jnp.int32))
    qslot_safe = jnp.where(alive2, qslot, 0)

    chain_ok = alive2 & (table.chain_region[qslot_safe, 0] >= 0) \
        & (table.chain_hi[qslot_safe] == a_src_hi) \
        & (table.chain_lo[qslot_safe] == a_src_lo)
    regs = jnp.where(chain_ok[:, None], table.chain_region[qslot_safe], -1)

    khi_r = table.key_hi.reshape(R, W)
    klo_r = table.key_lo.reshape(R, W)
    # kernel-vs-jnp for the chain find: legacy bool wins, else the tuned
    # plan (``core/plan.TunedPlan``), else the jnp reference. Both paths
    # are bit-exact, so the choice is pure dispatch.
    if use_kernel is None:
        use_kernel = plan.uses_kernel("chain_find") if plan is not None \
            else False
    if use_kernel:
        from ..kernels import ops as kops
        found = kops.chain_find(khi_r, klo_r, regs, a_dst_hi, a_dst_lo,
                                alive2)
    else:
        found = _chain_find_jnp(khi_r, klo_r, regs, a_dst_hi, a_dst_lo,
                                alive2)

    # -- claim: rank new pairs within their source, map ranks onto the
    # chain's free tail space (earlier regions' tails refill first). --
    new = alive2 & (found < 0)
    rank = _group_ranks(qslot_safe, new, Q)
    f_d = jnp.where(regs >= 0,
                    table.region_fill[jnp.clip(regs, 0, R - 1)], 0)
    avail = jnp.int32(W) - f_d            # unallocated depth: W free
    cumavail = jnp.cumsum(avail, axis=1)
    prev_cum = cumavail - avail
    in_d = new[:, None] & (rank[:, None] >= prev_cum) \
        & (rank[:, None] < cumavail)
    d_star = jnp.argmax(in_d, axis=1).astype(jnp.int32)
    has_room = jnp.any(in_d, axis=1)
    take1 = lambda a: jnp.take_along_axis(a, d_star[:, None], axis=1)[:, 0]
    pos = rank - take1(prev_cum) + take1(f_d)
    reg_at = take1(regs)
    n_chain_full = jnp.sum((new & ~has_room).astype(jnp.int32))

    # allocation: one representative per needed (slot, depth), assigned
    # free regions in ascending region-id order, deterministically.
    need_alloc = new & has_room & (reg_at < 0)
    rep = need_alloc & (pos == 0)
    BIG = jnp.int32(np.iinfo(np.int32).max)
    okey = jnp.where(rep, qslot_safe * MC + d_star, BIG)
    order = jnp.argsort(okey)
    t = jnp.zeros((B,), jnp.int32).at[order].set(
        jnp.where(okey[order] < BIG, jnp.arange(B, dtype=jnp.int32), B))
    free = table.region_owner < 0
    n_free = jnp.sum(free.astype(jnp.int32))
    frank = jnp.cumsum(free.astype(jnp.int32)) - 1
    rank2region = jnp.full((R,), -1, jnp.int32).at[
        jnp.where(free, frank, R)].set(jnp.arange(R, dtype=jnp.int32),
                                       mode="drop")
    alloc_region = jnp.where(rep & (t < n_free),
                             rank2region[jnp.clip(t, 0, R - 1)], -1)
    success_rep = rep & (alloc_region >= 0)

    # directory writes: stale/new rows reset wholesale (the previous
    # owner's chain is orphaned; the prune sweep reclaims it), then the
    # allocated entries land, then the owning fp is stamped.
    row_reset = new & ~chain_ok
    cr = table.chain_region.at[jnp.where(row_reset, qslot_safe, Q)].set(
        jnp.full((B, MC), -1, jnp.int32), mode="drop")
    cr = cr.at[jnp.where(success_rep, qslot_safe, Q), d_star].set(
        alloc_region, mode="drop")
    ch_hi = table.chain_hi.at[jnp.where(row_reset, qslot_safe, Q)].set(
        a_src_hi, mode="drop")
    ch_lo = table.chain_lo.at[jnp.where(row_reset, qslot_safe, Q)].set(
        a_src_lo, mode="drop")
    owner = table.region_owner.at[
        jnp.where(success_rep, alloc_region, R)].set(qslot_safe, mode="drop")

    # final placement (re-read the directory: covers freshly allocated
    # regions AND pool-exhaustion failures in one gather).
    reg_final = jnp.where(reg_at >= 0, reg_at, cr[qslot_safe, d_star])
    placed_new = new & has_room & (reg_final >= 0)
    n_pool_full = jnp.sum(
        (new & has_room & (reg_final < 0)).astype(jnp.int32))
    gslot = reg_final * W + pos

    key_hi = table.key_hi.at[jnp.where(placed_new, gslot, C)].set(
        a_dst_hi, mode="drop")
    key_lo = table.key_lo.at[jnp.where(placed_new, gslot, C)].set(
        a_dst_lo, mode="drop")
    fill = table.region_fill.at[jnp.where(placed_new, reg_final, R)].add(
        1, mode="drop")

    write_slot = jnp.where(found >= 0, found,
                           jnp.where(placed_new, gslot, -1))
    ok = alive2 & (write_slot >= 0)
    rebase = None
    if decay_cfg is not None:
        safe = jnp.where(ok, write_slot, 0)
        f = decay_cfg.factor(
            jnp.maximum(now - table.lanes[tick_lane][safe], 0))
        rebase = {name: table.lanes[name][safe] * f for name in decay_lanes
                  if mode_map.get(name) == ADD}
    new_lanes = _apply_lane_updates(table.lanes, agg, mode_map, ok,
                                    write_slot, C, rebase=rebase)
    n_drop = n_src_miss + n_chain_full + n_pool_full
    return RegionTable(key_hi, key_lo, new_lanes, cr, ch_hi, ch_lo, fill,
                       owner, table.n_dropped + n_drop)


@partial(jax.jit, static_argnames=("probe_rounds", "decay_cfg",
                                   "decay_lanes", "tick_lane"))
def region_lookup(
    table: RegionTable,
    qstore: HashTable,
    src_hi: jax.Array,
    src_lo: jax.Array,
    dst_hi: jax.Array,
    dst_lo: jax.Array,
    *,
    probe_rounds: int = 16,
    decay_cfg=None,
    decay_lanes: Tuple[str, ...] = ("weight",),
    tick_lane: str = "last_tick",
    now=None,
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Batched pair lookup under the region layout; mirrors
    :func:`lookup`'s contract (read-time decayed view under the lazy
    policy). Returns (lanes_at_pair, found_mask, global_slot)."""
    R, W = table.n_regions, table.width
    src_hi = jnp.asarray(src_hi, jnp.uint32)
    src_lo = jnp.asarray(src_lo, jnp.uint32)
    dst_hi = jnp.asarray(dst_hi, jnp.uint32)
    dst_lo = jnp.asarray(dst_lo, jnp.uint32)
    nonzero = (src_hi != 0) | (src_lo != 0)
    _, src_found, qslot = lookup(qstore, src_hi, src_lo,
                                 probe_rounds=probe_rounds)
    active = nonzero & src_found
    qslot_safe = jnp.where(active, qslot, 0)
    chain_ok = active & (table.chain_hi[qslot_safe] == src_hi) \
        & (table.chain_lo[qslot_safe] == src_lo)
    regs = jnp.where(chain_ok[:, None], table.chain_region[qslot_safe], -1)
    found_slot = _chain_find_jnp(table.key_hi.reshape(R, W),
                                 table.key_lo.reshape(R, W),
                                 regs, dst_hi, dst_lo, chain_ok)
    found = found_slot >= 0
    safe = jnp.where(found, found_slot, 0)
    f = None
    if decay_cfg is not None:
        f = decay_cfg.factor(
            jnp.maximum(now - table.lanes[tick_lane][safe], 0))
    out = {}
    for name, lane in table.lanes.items():
        v = lane[safe]
        if f is not None and name in decay_lanes:
            v = v * f
        out[name] = jnp.where(found, v, jnp.zeros_like(v))
    return out, found, found_slot
