"""Pure-Python dict-based reference implementation of the engine.

This is the correctness oracle: it mirrors the JVM engine the paper
describes (hash-maps mutated event-at-a-time) and defines the exact
semantics the JAX engine must reproduce at micro-batch granularity:

  * same store lanes (weight/count/last_tick),
  * same session sliding-window pair emission (batch order per session),
  * same decay/prune and ranking math.

Deliberately simple and slow — tests compare it against the vectorized
device engine on identical event streams.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Dict, List, Tuple

import numpy as np

from .decay import DecayConfig
from .engine import EngineConfig
from .ranking import RankConfig


def _sigmoid(x: float) -> float:
    if x >= 0:
        return 1.0 / (1.0 + math.exp(-x))
    e = math.exp(x)
    return e / (1.0 + e)


def _xlogx(x: float) -> float:
    return x * math.log(x) if x > 0 else 0.0


class ReferenceEngine:
    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.q: Dict[int, List[float]] = {}          # fp -> [w, c, last_tick]
        self.cooc: Dict[Tuple[int, int], List[float]] = {}
        self.sessions: Dict[int, deque] = {}         # sess_fp -> deque[(qfp, src)]
        self.sess_tick: Dict[int, int] = {}
        self.tick = 0
        self.suggestions: Dict[int, List[Tuple[int, float]]] = {}

    # ------------------------------------------------------------------
    def _source_w(self, src: int) -> float:
        sw = self.cfg.source_weights
        return sw[min(max(src, 0), len(sw) - 1)]

    def _bump_q(self, fp: int, w: float) -> None:
        e = self.q.setdefault(int(fp), [0.0, 0.0, 0])
        e[0] += w
        e[1] += 1.0
        e[2] = self.tick

    def _bump_cooc(self, a: int, b: int, w: float) -> None:
        e = self.cooc.setdefault((int(a), int(b)), [0.0, 0.0, 0])
        e[0] += w
        e[1] += 1.0
        e[2] = self.tick

    def ingest_queries(self, events) -> None:
        W = self.cfg.session_window
        for sess, q, src, valid in zip(events.sess_fp, events.q_fp,
                                       events.src, events.valid):
            if not valid or int(q) == 0 or int(sess) == 0:
                continue
            sess, q, src = int(sess), int(q), int(src)
            self._bump_q(q, self._source_w(src))
            d = self.sessions.setdefault(sess, deque(maxlen=W))
            for (prev, psrc) in d:
                if prev == q:
                    continue
                w_pair = math.sqrt(self._source_w(psrc) * self._source_w(src))
                self._bump_cooc(prev, q, w_pair)
            d.append((q, src))
            self.sess_tick[sess] = self.tick

    def ingest_tweets(self, tweets) -> None:
        cfg = self.cfg
        # query-likeness snapshot BEFORE this batch's updates
        def querylike(fp: int) -> bool:
            e = self.q.get(int(fp))
            return e is not None and e[1] >= cfg.min_querylike_count
        batches = []
        for grams, valid in zip(tweets.grams, tweets.valid):
            if not valid:
                continue
            ql = [int(g) for g in grams if int(g) != 0 and querylike(g)]
            batches.append(ql)
        for ql in batches:
            for g in ql:
                self._bump_q(g, cfg.tweet_weight)
            for a in ql:
                for b in ql:
                    if a != b:
                        self._bump_cooc(a, b, cfg.tweet_weight)

    def decay_cycle(self, dticks: int) -> None:
        cfg = self.cfg.decay
        f = cfg.factor_py(dticks)
        for d in (self.q, self.cooc):
            dead = []
            for k, e in d.items():
                e[0] *= f
                if e[0] < cfg.prune_threshold:
                    dead.append(k)
            for k in dead:
                del d[k]
        stale = [s for s, t in self.sess_tick.items()
                 if self.tick - t > self.cfg.session_ttl]
        for s in stale:
            self.sessions.pop(s, None)
            self.sess_tick.pop(s, None)

    # ------------------------------------------------------------------
    def rank_cycle(self) -> Dict[int, List[Tuple[int, float]]]:
        cfg: RankConfig = self.cfg.rank
        total_w = sum(e[0] for e in self.q.values())
        total_c = sum(e[1] for e in self.q.values())
        per_src: Dict[int, List[Tuple[float, int]]] = {}
        for (a, b), (w_ab, c_ab, _) in self.cooc.items():
            ea, eb = self.q.get(a), self.q.get(b)
            if ea is None or eb is None:
                continue
            w_a, c_a = ea[0], ea[1]
            w_b, c_b = eb[0], eb[1]
            if (w_ab < cfg.min_pair_weight or c_ab < cfg.min_pair_count
                    or w_a < cfg.min_src_weight):
                continue
            condprob = w_ab / w_a if w_a > 0 else 0.0
            pmi = (math.log(w_ab * max(total_w, 1e-9) / max(w_a * w_b, 1e-9))
                   if w_ab > 0 and w_a > 0 and w_b > 0 else 0.0)
            k11 = c_ab
            k12 = max(c_a - c_ab, 0.0)
            k21 = max(c_b - c_ab, 0.0)
            k22 = max(total_c - c_a - c_b + c_ab, 0.0)
            n = max(k11 + k12 + k21 + k22, 1e-9)
            r1, r2 = k11 + k12, k21 + k22
            c1, c2 = k11 + k21, k12 + k22
            llr = 2.0 * (_xlogx(k11) + _xlogx(k12) + _xlogx(k21) + _xlogx(k22)
                         - _xlogx(r1) - _xlogx(r2) - _xlogx(c1) - _xlogx(c2)
                         + _xlogx(n))
            llr = max(llr, 0.0)
            chi2 = n * (k11 * k22 - k12 * k21) ** 2 / max(r1 * r2 * c1 * c2, 1e-9)
            score = (cfg.coef_condprob * condprob
                     + cfg.coef_pmi * _sigmoid(pmi)
                     + cfg.coef_llr * math.log1p(llr)
                     + cfg.coef_chi2 * math.log1p(chi2))
            per_src.setdefault(a, []).append((score, b))
        out: Dict[int, List[Tuple[int, float]]] = {}
        for a, lst in per_src.items():
            lst.sort(key=lambda t: (-t[0], t[1]))
            out[a] = [(b, s) for (s, b) in lst[: cfg.top_k]]
        self.suggestions = out
        return out

    # ------------------------------------------------------------------
    def step(self, query_events=None, tweets=None) -> None:
        if query_events is not None:
            self.ingest_queries(query_events)
        if tweets is not None:
            self.ingest_tweets(tweets)
        if (self.cfg.decay_every > 0 and self.tick > 0
                and self.tick % self.cfg.decay_every == 0):
            self.decay_cycle(self.cfg.decay_every)
        if (self.cfg.rank_every > 0 and self.tick > 0
                and self.tick % self.cfg.rank_every == 0):
            self.rank_cycle()
        self.tick += 1
