"""TunedPlan — the serializable record of backend-aware kernel tuning.

The engine used to route its hot paths through a blind
``EngineConfig.use_kernel: bool``; on CPU CI that flag sent production
traffic through the Pallas *interpreter* and lost 2-25x to the plain jnp
twins (the ``ranking_cycle_*_pallas`` bench regression). A ``TunedPlan``
replaces the flag with per-hot-path choices *measured* on the running
backend by ``repro.launch.autotune`` and cached to disk keyed by
:func:`shape_class`.

Design constraints (all load-bearing):

* **Hashable + frozen** — ``EngineConfig``/``RankConfig`` are static jit
  arguments, and the plan is embedded in both, so it must hash and
  compare by value.
* **Serializable** — the plan round-trips through JSON (disk cache,
  snapshot/checkpoint meta) so a recovered engine keeps its tuning.
* **Result-invariant** — every field selects between implementations that
  produce bit-exact engine states and suggestion tables; knobs that
  change results (store capacities, ``region_width``, the semantic
  ingest quantum) live in ``EngineConfig`` and are out of bounds for the
  tuner. Tuning may change speed, never results (property-tested in
  ``tests/test_autotune.py``).

This module is deliberately dependency-free (core must import it without
pulling in the launch/tuner machinery).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

# The kernel-vs-jnp hot-path pairs the tuner measures (see the dispatch
# table in ``repro/kernels/__init__.py``).
HOT_PATH_OPS: Tuple[str, ...] = (
    "score_gate", "bucket_topk", "region_rank", "chain_find", "decay_prune")

KERNEL, JNP = "kernel", "jnp"


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """Per-hot-path execution choices. Defaults = the all-jnp reference
    plan (what an untuned engine without the legacy flag runs)."""
    score_gate: str = JNP
    bucket_topk: str = JNP
    region_rank: str = JNP
    chain_find: str = JNP
    decay_prune: str = JNP
    # tile/grid tuning: rows (of 1024 slots) per score_gate/region grid
    # step. In interpret mode fewer, larger blocks amortize the
    # interpreter's per-step XLA re-entry (measured 11x spread on CPU).
    score_block_rows: int = 16
    # events fused per device dispatch when step()/ingest_many chunk an
    # oversized batch into ``EngineConfig.ingest_quantum``-sized slices:
    # chunk = k * quantum means k quantum slices ride ONE lax.scan
    # dispatch. 0 = one dispatch per slice. Pure dispatch scheduling —
    # the slicing itself is plan-independent, so results are identical.
    ingest_chunk: int = 0
    # provenance (not consulted by dispatch)
    backend: str = ""
    shape_class: str = ""

    def __post_init__(self):
        for op in HOT_PATH_OPS:
            v = getattr(self, op)
            if v not in (KERNEL, JNP):
                raise ValueError(f"plan.{op} must be 'kernel' or 'jnp', "
                                 f"got {v!r}")

    def uses_kernel(self, op: str) -> bool:
        if op not in HOT_PATH_OPS:
            raise KeyError(f"unknown hot path {op!r}")
        return getattr(self, op) == KERNEL

    def variants(self) -> Dict[str, str]:
        """op -> chosen variant, for metrics/telemetry surfaces."""
        d = {op: getattr(self, op) for op in HOT_PATH_OPS}
        d["score_block_rows"] = self.score_block_rows
        d["ingest_chunk"] = self.ingest_chunk
        return d

    # ---- serialization (disk cache + snapshot meta) ----
    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict) -> "TunedPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def dumps(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True)

    @classmethod
    def loads(cls, s: str) -> "TunedPlan":
        return cls.from_json(json.loads(s))


#: The all-jnp plan (also the graceful fallback when Pallas is broken or
#: absent: every choice is the reference path).
JNP_PLAN = TunedPlan()


def all_kernel_plan(**overrides) -> TunedPlan:
    """Every hot path through its Pallas kernel (parity testing)."""
    kw = {op: KERNEL for op in HOT_PATH_OPS}
    kw.update(overrides)
    return TunedPlan(**kw)


def default_region_width(cooc_capacity: int) -> int:
    """Default pairs-per-region derived from the cooc capacity.

    The mapping the benches want — {2^16: 16, 2^18: 32, 2^20: 64} — i.e.
    width grows with the square root of capacity (Asadi & Lin's
    skew-aware allocation argument: bigger stores hold fatter heads),
    clamped to the [8, 128] range the region kernels tile well.
    """
    if cooc_capacity <= 0:
        raise ValueError(f"bad cooc_capacity {cooc_capacity}")
    log2c = cooc_capacity.bit_length() - 1
    return 1 << min(7, max(3, log2c // 2 - 4))


def shape_class(cfg, backend: Optional[str] = None,
                device_kind: Optional[str] = None) -> str:
    """The autotune cache key: same string => same cached plan applies.

    Captures everything dispatch-performance depends on — backend +
    device kind, log2 store capacities, cooc layout and region width —
    and nothing results depend on the plan for.
    """
    import jax
    b = backend if backend is not None else jax.default_backend()
    if device_kind is None:
        try:
            device_kind = jax.devices(b)[0].device_kind
        except Exception:
            device_kind = "unknown"
    dk = str(device_kind).replace(" ", "-").replace("/", "-").lower()
    parts = [b, dk,
             f"q{cfg.query_capacity.bit_length() - 1}",
             f"c{cfg.cooc_capacity.bit_length() - 1}",
             f"s{cfg.session_capacity.bit_length() - 1}",
             cfg.cooc_layout]
    if cfg.cooc_layout == "region":
        parts.append(f"w{cfg.region_w}")
    return "-".join(parts)
