"""Serving frontend (paper §4.2, Figure 4).

"Lightweight in-memory caches, which periodically read fresh results from
HDFS, serve as the frontend nodes ... together they form a single
replicated, fault-tolerant service endpoint that can be arbitrarily scaled
out." Request routing in the paper goes through the ServerSet abstraction
(client-side load balancing over live replicas via ZooKeeper).

Here: ``SuggestFrontend`` polls a checkpoint directory for the newest
persisted suggestion tables (real-time + background), interpolates them at
serve time (§4.5), and resolves fingerprints back to strings through the
tokenizer. ``ServerSet`` is the client-side balancer over frontend replicas
with liveness-based failover, staleness-aware ordering (freshest tables
first), bounded retry-with-backoff, hedged second requests, and per-replica
circuit breakers; every response is tagged with the serving replica's tick
and staleness (:class:`RouteResult`).

Staleness (§4.2): during a backend crash + catch-up replay the frontends
keep serving "the most recently persisted results" — deliberately stale.
``SuggestFrontend.metrics()`` quantifies that: the age of the loaded
tables and, when pointed at the durable firehose log, the tick lag between
what the tables reflect and the log head (``catching_up`` flips true while
a restarted backend is still replaying).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.background import interpolate
from ..core.hashing import fingerprint
from ..data.tokenizer import NGramTokenizer
from ..distributed.fault_tolerance import CheckpointManager


def pack_suggestions(sugg: Dict[int, List[Tuple[int, float]]]) -> Dict[str, np.ndarray]:
    """Suggestion dict -> flat arrays for checkpointing."""
    srcs, dsts, scores, offs = [], [], [], [0]
    for s, lst in sugg.items():
        srcs.append(s)
        for d, sc in lst:
            dsts.append(d)
            scores.append(sc)
        offs.append(len(dsts))
    return {"src": np.asarray(srcs, np.uint64),
            "dst": np.asarray(dsts, np.uint64),
            "score": np.asarray(scores, np.float64),
            "offsets": np.asarray(offs, np.int64)}


def unpack_suggestions(arrays) -> Dict[int, List[Tuple[int, float]]]:
    out: Dict[int, List[Tuple[int, float]]] = {}
    src = arrays["src"]
    offs = arrays["offsets"]
    for i, s in enumerate(src):
        lo, hi = int(offs[i]), int(offs[i + 1])
        out[int(s)] = [(int(d), float(sc))
                       for d, sc in zip(arrays["dst"][lo:hi],
                                        arrays["score"][lo:hi])]
    return out


class SuggestFrontend:
    """One frontend cache replica: polls persisted results, serves lookups."""

    def __init__(self, rt_dir: str, bg_dir: Optional[str] = None,
                 tok: Optional[NGramTokenizer] = None, alpha: float = 0.7,
                 spell_dir: Optional[str] = None,
                 log_dir: Optional[str] = None, log_name: str = "firehose",
                 stale_lag_ticks: int = 4):
        self.rt_ckpt = CheckpointManager(rt_dir)
        self.bg_ckpt = CheckpointManager(bg_dir) if bg_dir else None
        self.spell_ckpt = CheckpointManager(spell_dir) if spell_dir else None
        self.tok = tok or NGramTokenizer()
        self.alpha = alpha
        self._rt: Dict = {}
        self._bg: Dict = {}
        self._spell: Dict[int, Tuple[int, float]] = {}
        self._cache: Dict = {}
        self._loaded_steps = (None, None, None)
        self._rt_manifest: Dict = {}
        self._bg_manifest: Dict = {}
        self.stale_lag_ticks = stale_lag_ticks
        self._log_reader = None
        if log_dir is not None:
            from ..streaming.log import FirehoseLogReader
            # verify=False: metrics only needs the manifest tail tick —
            # checksumming every segment on each poll would be O(log bytes)
            self._log_reader = FirehoseLogReader(log_dir, name=log_name,
                                                 verify=False)
        self.alive = True

    def poll(self) -> bool:
        """Load newer persisted results if any (the paper's 1-min poll)."""
        steps = (self.rt_ckpt.latest_step(),
                 self.bg_ckpt.latest_step() if self.bg_ckpt else None,
                 self.spell_ckpt.latest_step() if self.spell_ckpt else None)
        if steps == self._loaded_steps:
            return False
        if steps[0] is not None:
            self._rt = self._load(self.rt_ckpt, steps[0])
            self._rt_manifest = self.rt_ckpt.manifest(steps[0])
        if self.bg_ckpt and steps[1] is not None:
            self._bg = self._load(self.bg_ckpt, steps[1])
            self._bg_manifest = self.bg_ckpt.manifest(steps[1])
        if self.spell_ckpt and steps[2] is not None:
            arrs = self.spell_ckpt.restore_host(steps[2])
            self._spell = {int(a): (int(b), float(d)) for a, b, d in
                           zip(arrs["leaf_0"], arrs["leaf_1"], arrs["leaf_2"])}
        self._cache = interpolate(self._rt, self._bg, self.alpha)
        self._loaded_steps = steps
        return True

    @staticmethod
    def _load(ckpt: CheckpointManager, step: int) -> Dict:
        arrs = ckpt.restore_host(step)
        # saved via pack_suggestions tree order: dst, offsets, score, src
        named = dict(zip(["dst", "offsets", "score", "src"],
                         [arrs[f"leaf_{i}"] for i in range(4)]))
        return unpack_suggestions(named)

    # ---- staleness / lag (§4.2: stale-but-available during catch-up) ----
    @staticmethod
    def _next_tick(meta: Dict) -> Optional[int]:
        # two producer conventions: engine snapshots (``save_snapshot``)
        # record ``log_tick`` = the NEXT tick to replay (tables reflect
        # log_tick - 1); suggestion-table persists (serve_assist) record
        # ``tick`` = the LAST tick reflected.
        if "log_tick" in meta:
            return int(meta["log_tick"])
        if "tick" in meta:
            return int(meta["tick"]) + 1
        return None

    def metrics(self, now: Optional[float] = None) -> Dict:
        """How stale is what this frontend serves — for BOTH halves.

        ``rt_age_s``/``bg_age_s``: wall-clock age of the loaded real-time /
        background tables. ``rt_tick``/``bg_tick``: the engine tick each
        half's tables reflect (from its checkpoint manifest's
        ``log_tick``/``tick`` meta). ``log_head_tick`` and the per-engine
        ``rt_lag_ticks``/``bg_lag_ticks``: with a firehose-log reader
        attached, how far behind the durable log head each half's served
        tables are; ``rt_catching_up``/``bg_catching_up`` flip true while
        that engine's lag exceeds ``stale_lag_ticks`` — i.e. that half of a
        restarted backend is still replaying and this frontend knowingly
        serves its stale suggestions. During whole-stack recovery the two
        halves catch up independently (the bg engine typically snapshots
        less often and replays a longer tail), which is why operators need
        both. ``lag_ticks``/``catching_up`` remain the rt aliases.

        Overload state (when the backend runs under
        ``streaming.overload.OverloadController`` — its stats ride in the
        snapshot meta): ``step_p50_ms``/``step_p95_ms``/``step_p99_ms``
        per-tick step-latency percentiles, ``shed_level`` /
        ``shed_level_name`` the degradation-ladder rung the backend was on,
        ``n_shed_events``/``n_shed_rank``/``n_shed_total`` the shed
        counters (nothing is shed silently), and the full raw counter dict
        under ``overload``. All ``None`` for a backend without overload
        control.
        """
        now = time.time() if now is None else now
        meta = self._rt_manifest.get("meta", {})
        bg_meta = self._bg_manifest.get("meta", {})
        rt_next = self._next_tick(meta)
        bg_next = self._next_tick(bg_meta)
        out: Dict = {
            "rt_step": self._loaded_steps[0],
            "rt_age_s": (now - self._rt_manifest["time"]
                         if "time" in self._rt_manifest else None),
            "rt_tick": None if rt_next is None else rt_next - 1,
            "bg_step": self._loaded_steps[1],
            "bg_age_s": (now - self._bg_manifest["time"]
                         if "time" in self._bg_manifest else None),
            "bg_tick": None if bg_next is None else bg_next - 1,
            "log_head_tick": None,
            "log_floor_tick": None,
            "log_first_tick": None,
            "n_log_bases": 0,
            "lag_ticks": None,
            "rt_lag_ticks": None,
            "bg_lag_ticks": None,
            "catching_up": False,
            "rt_catching_up": False,
            "bg_catching_up": False,
            # backend store health from the snapshot meta: the engine's
            # last maintenance-cycle stats (live/reclaimed slot counts and,
            # under the region cooc layout, freelist pressure as
            # ``c_free_regions``) plus the layout that produced them.
            "store_layout": meta.get("layout"),
            "store": meta.get("maintenance"),
        }
        # tuned kernel-dispatch plan (launch.autotune): which variant each
        # hot path runs on the backend. Rides the snapshot meta, so a
        # recovered backend reports the plan it actually executes;
        # ``None`` for an untuned backend (all-jnp defaults).
        plan = meta.get("plan")
        out["tuned_plan"] = plan
        out["tuned_variants"] = None
        if plan:
            from ..core.plan import TunedPlan
            try:
                out["tuned_variants"] = TunedPlan.from_json(plan).variants()
            except (TypeError, ValueError):
                pass                        # unknown future plan schema
        # backend overload state (streaming.overload): the controller's
        # stats ride in the snapshot meta. Surface the SLO-facing subset
        # flat (step-latency percentiles, degradation level, shed
        # counters) and the full counter dict raw under ``overload``.
        ov = meta.get("overload")
        out["overload"] = ov
        ov = ov or {}
        out["step_p50_ms"] = ov.get("step_p50_ms")
        out["step_p95_ms"] = ov.get("step_p95_ms")
        out["step_p99_ms"] = ov.get("step_p99_ms")
        out["shed_level"] = ov.get("level")
        out["shed_level_name"] = ov.get("level_name")
        out["n_shed_events"] = ov.get("n_shed_events")
        out["n_shed_rank"] = (
            None if ov.get("n_shed_rank_rt") is None
            else ov["n_shed_rank_rt"] + ov.get("n_shed_rank_bg", 0))
        out["n_shed_total"] = ov.get("n_shed_total")
        if self._log_reader is not None:
            self._log_reader.refresh()
            head = self._log_reader.last_tick()
            out["log_head_tick"] = head
            # compacted storage tier: the replay floor (newest advertised
            # base) and how far back the on-disk tail actually reaches —
            # "can this frontend's backend still rebuild from zero, and
            # from where" at a glance.
            out["log_floor_tick"] = self._log_reader.floor_tick()
            out["log_first_tick"] = self._log_reader.first_tick()
            out["n_log_bases"] = len(self._log_reader.bases)
            if head is not None:
                # pending = logged ticks the served tables don't reflect
                out["rt_lag_ticks"] = max(
                    0, head + 1 - (rt_next if rt_next is not None else 0))
                out["rt_catching_up"] = \
                    out["rt_lag_ticks"] > self.stale_lag_ticks
                out["lag_ticks"] = out["rt_lag_ticks"]
                out["catching_up"] = out["rt_catching_up"]
                if self.bg_ckpt is not None:
                    out["bg_lag_ticks"] = max(
                        0, head + 1 - (bg_next if bg_next is not None else 0))
                    out["bg_catching_up"] = \
                        out["bg_lag_ticks"] > self.stale_lag_ticks
        return out

    # ---- request path ----
    def freshness_tick(self) -> Optional[int]:
        """The engine tick this frontend's served tables reflect (the
        router's staleness key — no disk I/O, reads the loaded manifest)."""
        nxt = self._next_tick(self._rt_manifest.get("meta", {}))
        return None if nxt is None else nxt - 1

    def related(self, query: str, k: int = 8) -> List[Tuple[str, float]]:
        fp = fingerprint(" ".join(query.lower().split()))
        return [(self.tok.text(d), s) for d, s in self._cache.get(fp, [])[:k]]

    def spelling(self, query: str) -> Optional[str]:
        fp = fingerprint(" ".join(query.lower().split()))
        hit = self._spell.get(fp)
        return self.tok.text(hit[0]) if hit else None


@dataclasses.dataclass(frozen=True)
class RouteResult:
    """One answered request, tagged so degraded answers are honest."""
    suggestions: List[Tuple[str, float]]
    replica: int                 # index of the replica that answered
    tick: Optional[int]          # freshness tick of that replica's tables
    staleness: Optional[int]     # ticks behind the freshest live replica
    hedged: bool                 # answered by a hedge, not the primary
    attempts: int                # replicas tried (1 = primary answered)


class _Breaker:
    """Per-replica circuit breaker on a deterministic request-count clock:
    ``threshold`` consecutive failures open the circuit for ``cooldown``
    subsequent requests, after which one half-open probe is allowed."""

    def __init__(self, threshold: int, cooldown: int):
        self.threshold = threshold
        self.cooldown = cooldown
        self.failures = 0
        self.open_until = -1

    def allow(self, now: int) -> bool:
        return self.failures < self.threshold or now >= self.open_until

    def record(self, ok: bool, now: int) -> None:
        if ok:
            self.failures = 0
            return
        self.failures += 1
        if self.failures >= self.threshold:
            self.open_until = now + self.cooldown


class ServerSet:
    """Client-side load-balanced access to replicated frontends with
    failover (the paper's ZooKeeper-coordinated ServerSet, simulated).

    Routing is health- and staleness-aware: live replicas are tried
    freshest-first (``freshness_tick()``, missing = oldest; ties rotate
    round-robin so equally-fresh replicas share load). A replica that is
    marked dead, raises, or exceeds ``timeout_s`` fails the attempt and the
    request is *hedged* to the next-freshest replica; a full pass over the
    candidates backs off ``backoff_s * 2**attempt`` and retries, up to
    ``max_retries`` extra passes. Repeated failures open a per-replica
    circuit breaker (``breaker_failures`` consecutive misses skip it for
    ``breaker_cooldown`` requests, then one half-open probe) so a flapping
    replica stops eating the hedge budget. Every response carries the
    serving replica's ``tick`` and its ``staleness`` vs the freshest live
    candidate (:class:`RouteResult`) — stale answers are served, but never
    silently.
    """

    def __init__(self, replicas: List[SuggestFrontend], *,
                 timeout_s: Optional[float] = None, max_retries: int = 1,
                 backoff_s: float = 0.0, breaker_failures: int = 3,
                 breaker_cooldown: int = 16):
        self.replicas = replicas
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self._rr = itertools.count()
        self._clock = 0
        self._breakers = [_Breaker(breaker_failures, breaker_cooldown)
                          for _ in replicas]
        # observability: the chaos bench reads these
        self.n_requests = 0
        self.n_hedged = 0
        self.n_failures = 0     # individual replica attempt failures
        self.n_timeouts = 0
        self.n_breaker_skips = 0

    @staticmethod
    def _fresh(r) -> int:
        f = getattr(r, "freshness_tick", None)
        if f is None:
            return -1
        tick = f()
        return -1 if tick is None else int(tick)

    def _candidates(self) -> Tuple[List[int], int]:
        """Live replica indices in try-order + the freshest live tick.
        Freshest first; round-robin rotation within the leading equal-
        freshness group; breaker-open replicas demoted to last resort."""
        live = [i for i, r in enumerate(self.replicas) if r.alive]
        if not live:
            raise RuntimeError("no live frontend replicas")
        fresh = {i: self._fresh(self.replicas[i]) for i in live}
        live.sort(key=lambda i: (-fresh[i], i))
        top = [i for i in live if fresh[i] == fresh[live[0]]]
        if len(top) > 1:           # spread load over equally-fresh replicas
            rot = next(self._rr) % len(top)
            live[:len(top)] = top[rot:] + top[:rot]
        closed = [i for i in live if self._breakers[i].allow(self._clock)]
        demoted = [i for i in live if i not in closed]
        self.n_breaker_skips += len(demoted)
        return closed + demoted, max(fresh.values())

    def request_info(self, query: str, k: int = 8) -> RouteResult:
        """Route one request; raises RuntimeError only when every live
        replica failed every retry pass (or none is live at all)."""
        self._clock += 1
        self.n_requests += 1
        now = self._clock
        order, max_fresh = self._candidates()
        n_tried = 0
        errors: List[str] = []
        for attempt in range(self.max_retries + 1):
            if attempt > 0 and self.backoff_s > 0:
                time.sleep(self.backoff_s * (2 ** (attempt - 1)))
            for i in order:
                r = self.replicas[i]
                if not r.alive:      # died mid-pass
                    continue
                n_tried += 1
                t0 = time.perf_counter()
                try:
                    sugg = r.related(query, k)
                except Exception as e:   # noqa: BLE001 — any replica fault
                    self.n_failures += 1
                    self._breakers[i].record(False, now)
                    errors.append(f"replica {i}: {type(e).__name__}: {e}")
                    continue
                if (self.timeout_s is not None
                        and time.perf_counter() - t0 > self.timeout_s):
                    # too slow counts as failure: the answer is discarded
                    # and the request hedges to the next-freshest replica
                    self.n_failures += 1
                    self.n_timeouts += 1
                    self._breakers[i].record(False, now)
                    errors.append(f"replica {i}: timeout")
                    continue
                self._breakers[i].record(True, now)
                tick = self._fresh(r)
                hedged = n_tried > 1
                self.n_hedged += int(hedged)
                return RouteResult(
                    suggestions=sugg, replica=i,
                    tick=None if tick < 0 else tick,
                    staleness=(None if tick < 0 or max_fresh < 0
                               else max_fresh - tick),
                    hedged=hedged, attempts=n_tried)
        raise RuntimeError(
            f"no live frontend replicas answered after {n_tried} attempts: "
            + "; ".join(errors[-len(order):]))

    def request(self, query: str, k: int = 8) -> List[Tuple[str, float]]:
        return self.request_info(query, k).suggestions
