"""The "Take One" Hadoop/Pig baseline (paper §3), simulated.

The paper's first implementation computed the same statistics with a cascade
of ~a dozen MapReduce jobs over hourly log directories, and was abandoned
because of end-to-end latency:

  * log import lag: "typically ... a couple of hours, although delays of up
    to six hours are not uncommon" (§3.1); best case with incremental import
    "latencies in the tens of minutes";
  * MR compute: "roughly a dozen MapReduce jobs ... around 15-20 minutes to
    process one hour of log data (without resource contention)" (§3.2);
  * job startup: "tens of seconds for a large job to start up";
  * stragglers: Zipfian key skew makes max task time >> mean task time.

This module reproduces the *computation* (the batch job recomputes the same
statistics from buffered logs — the paper notes the algorithms/UDF code
carried over) and *models* the latency budget with the paper's numbers, so
``benchmarks/bench_latency.py`` can contrast batch vs streaming
time-to-suggestion for the same injected breaking-news event.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.engine import EngineConfig, SearchAssistanceEngine


@dataclasses.dataclass(frozen=True)
class HadoopLatencyModel:
    """Latency budget of the §3 pipeline, in simulated seconds."""
    import_lag_s: float = 2 * 3600.0        # typical "couple of hours"
    import_lag_best_s: float = 20 * 60.0    # best-case incremental import
    mr_minutes_per_log_hour: float = 17.5   # 15-20 min per hour of logs
    n_chained_jobs: int = 12
    startup_s_per_job: float = 20.0         # "tens of seconds"
    straggler_factor: float = 1.25          # max-task vs mean-task stretch
    contention_factor: float = 1.0          # shared-cluster queueing

    def compute_time_s(self, log_hours: float) -> float:
        mr = self.mr_minutes_per_log_hour * 60.0 * log_hours
        startup = self.startup_s_per_job * self.n_chained_jobs
        return (mr * self.straggler_factor + startup) * self.contention_factor

    def end_to_end_s(self, log_hours: float, *, best_case: bool = False) -> float:
        lag = self.import_lag_best_s if best_case else self.import_lag_s
        return lag + self.compute_time_s(log_hours)


@dataclasses.dataclass
class HourlyLogDir:
    """An hour of logs "on HDFS": becomes visible only after the import lag."""
    hour: int
    query_batches: List
    tweet_batches: List
    generated_at_s: float
    available_at_s: float


class BatchPipeline:
    """Oink-scheduled hourly Pig cascade, simulated over the same stream.

    Buffers the stream into hourly log directories, applies the import-lag
    visibility rule, and when an hour becomes available recomputes the full
    suggestion table from the trailing ``window_hours`` of logs using the
    same statistics engine (batch mode: one engine instance re-ingests the
    window from scratch — this is exactly what the Pig cascade did).
    """

    def __init__(self, cfg: EngineConfig, latency: HadoopLatencyModel,
                 tick_seconds: float, window_hours: int = 4):
        self.cfg = dataclasses.replace(cfg, decay_every=0, rank_every=0)
        self.latency = latency
        self.tick_seconds = tick_seconds
        self.window_hours = window_hours
        self.ticks_per_hour = max(int(3600.0 / tick_seconds), 1)
        self.hours: List[HourlyLogDir] = []
        self._cur_q: List = []
        self._cur_t: List = []
        self.tick = 0
        # (suggestions, available_at_s) history of completed batch jobs
        self.results: List[Tuple[Dict, float]] = []

    def ingest_tick(self, query_events, tweets) -> None:
        self._cur_q.append(query_events)
        self._cur_t.append(tweets)
        self.tick += 1
        if self.tick % self.ticks_per_hour == 0:
            hour = self.tick // self.ticks_per_hour - 1
            gen_s = self.tick * self.tick_seconds
            self.hours.append(HourlyLogDir(
                hour=hour, query_batches=self._cur_q, tweet_batches=self._cur_t,
                generated_at_s=gen_s,
                available_at_s=gen_s + self.latency.import_lag_s))
            self._cur_q, self._cur_t = [], []
            self._run_job(hour)

    def _run_job(self, upto_hour: int) -> None:
        """Oink fires the cascade once the hourly directory 'appears'."""
        window = [h for h in self.hours
                  if upto_hour - self.window_hours < h.hour <= upto_hour]
        eng = SearchAssistanceEngine(self.cfg, name=f"batch@h{upto_hour}")
        for h in window:
            for q, t in zip(h.query_batches, h.tweet_batches):
                eng.step(q, t)
        eng.run_rank_cycle()
        log_hours = float(len(window))
        done_s = (max(h.available_at_s for h in window)
                  + self.latency.compute_time_s(log_hours))
        self.results.append((eng.suggestions, done_s))

    def suggestions_at(self, sim_time_s: float) -> Dict:
        """Most recent batch result whose job had completed by sim_time_s."""
        best: Dict = {}
        for sugg, done in self.results:
            if done <= sim_time_s:
                best = sugg
        return best
