"""Token pipeline for LM example training: a synthetic corpus with Zipfian
unigram statistics + Markov bigram structure (so a small LM has signal to
learn), packed into fixed-length training sequences with deterministic
shuffling and epoch/shard bookkeeping (resumable from a step counter)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 8
    n_states: int = 32          # Markov blocks for learnable structure
    seed: int = 0


class SyntheticTokenStream:
    """Deterministic, seekable token batches: batch(i) is pure in (seed, i)."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, S = cfg.vocab_size, cfg.n_states
        # state transition matrix + per-state Zipf emission over a vocab slice
        self.trans = rng.dirichlet(np.ones(S) * 0.3, size=S)
        ranks = np.arange(1, V + 1)
        zipf = ranks ** -1.1
        self.emit = np.stack([
            np.roll(zipf, rng.integers(V)) / zipf.sum() for _ in range(S)])
        self.emit /= self.emit.sum(axis=1, keepdims=True)

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, T = cfg.batch_size, cfg.seq_len + 1
        out = np.zeros((B, T), np.int64)
        state = rng.integers(0, cfg.n_states, size=B)
        for t in range(T):
            for b in range(B):
                out[b, t] = rng.choice(cfg.vocab_size, p=self.emit[state[b]])
            # vectorized-ish state step
            u = rng.random(B)
            cdf = np.cumsum(self.trans[state], axis=1)
            state = (u[:, None] < cdf).argmax(axis=1)
        return out.astype(np.int32)

    def batches(self, start_step: int = 0) -> Iterator[Tuple[int, np.ndarray]]:
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1
