"""Hashing n-gram tokenizer (paper §2.4: "queries are short, and we only
consider n-grams up to n=3").

Host-side: strings -> 64-bit fingerprints, with a reverse dictionary so the
serving frontend (and the spelling job) can map fingerprints back to text.
The device only ever sees fingerprints.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np

from ..core.hashing import fingerprint


class NGramTokenizer:
    def __init__(self, max_n: int = 3):
        self.max_n = max_n
        self.fp_to_text: Dict[int, str] = {}

    def fp(self, text: str) -> int:
        f = fingerprint(text)
        self.fp_to_text.setdefault(f, text)
        return f

    def text(self, fp: int) -> str:
        return self.fp_to_text.get(int(fp), f"<fp:{int(fp):x}>")

    def query_fp(self, query: str) -> int:
        """Fingerprint a whole (normalized) query string."""
        return self.fp(" ".join(query.lower().split()))

    def ngrams(self, text: str) -> List[str]:
        toks = text.lower().split()
        out = []
        for n in range(1, self.max_n + 1):
            for i in range(len(toks) - n + 1):
                out.append(" ".join(toks[i : i + n]))
        return out

    def tweet_ngram_fps(self, tweet: str, max_grams: int) -> np.ndarray:
        """Fingerprints of a tweet's n-grams, padded/truncated to max_grams."""
        fps = [self.fp(g) for g in self.ngrams(tweet)][:max_grams]
        arr = np.zeros((max_grams,), np.uint64)
        arr[: len(fps)] = fps
        return arr
