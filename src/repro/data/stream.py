"""Synthetic firehose + query hose (the engine's two inputs, paper §4.2).

Models the statistical structure the paper describes:

  * a Zipf-distributed base query vocabulary (head/tail split drives the
    churn statistics of §2.3 and the coverage/memory tradeoff of §4.4),
  * topical user sessions (successive queries within a session are
    correlated -> the session co-occurrence signal of §2.4),
  * breaking-news events with "hockey puck" intensity curves (§2.2): a ramp,
    an accelerating rise to a peak share of the query stream, then decay;
    related event terms spike with a short lag after the head term
    (Figure 1's "steve jobs" -> "apple", "stay foolish" shape),
  * misspellings: common queries are corrupted at a configurable rate
    (feeding the spelling-correction path),
  * tweets as bags of n-grams biased to the same topics/events (the tweet
    context of §2.4).

Everything is vectorized numpy keyed by a deterministic seed; fingerprints
for sessions are numeric (mix64) while query fingerprints go through the
tokenizer so the serving layer can recover strings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from .tokenizer import NGramTokenizer

_WORDS = [
    "news", "video", "live", "score", "game", "music", "photo", "trend",
    "world", "tech", "movie", "series", "stream", "update", "launch", "team",
    "play", "final", "award", "storm", "market", "stock", "crypto", "earth",
    "space", "rocket", "phone", "app", "meme", "viral", "dance", "song",
]


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized), output != 0."""
    x = np.asarray(x, np.uint64).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return np.where(x == 0, np.uint64(1), x)


@dataclasses.dataclass(frozen=True)
class EventSpec:
    name: str
    terms: Tuple[str, ...]         # terms[0] is the head query
    t_start: int                   # tick the news breaks
    ramp_ticks: float = 6.0        # rise time constant
    plateau_ticks: float = 24.0    # time near peak
    decay_ticks: float = 72.0      # die-off constant
    peak_share: float = 0.10       # share of the query stream at peak
    term_lag: float = 3.0          # onset lag per related term (Fig. 1)


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab_size: int = 4096
    zipf_s: float = 1.07
    n_topics: int = 64
    n_users: int = 20000
    session_ticks: int = 24            # session epoch length
    topic_stickiness: float = 0.75     # P(query from session topic)
    typo_rate: float = 0.01
    n_misspell_targets: int = 64
    queries_per_tick: int = 2048
    tweets_per_tick: int = 512
    tweet_words: int = 6
    tweet_grams: int = 16
    tick_seconds: float = 10.0          # one tick of simulated wall time
    source_probs: Tuple[float, float, float] = (0.70, 0.22, 0.08)
    events: Tuple[EventSpec, ...] = ()


class QueryEvents(NamedTuple):
    sess_fp: np.ndarray   # u64[B]
    q_fp: np.ndarray      # u64[B]
    src: np.ndarray       # i32[B]: 0 typed, 1 hashtag click, 2 related click
    valid: np.ndarray     # bool[B]


class TweetBatch(NamedTuple):
    grams: np.ndarray     # u64[T, G] n-gram fingerprints (0 padded)
    valid: np.ndarray     # bool[T]


class SyntheticStream:
    def __init__(self, cfg: StreamConfig, tok: Optional[NGramTokenizer] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.tok = tok or NGramTokenizer()
        self.rng = np.random.default_rng(seed)

        # --- vocabulary: two-word queries over a word list (n-gram friendly)
        rr = np.random.default_rng(seed + 1)
        vocab: List[str] = []
        seen = set()
        while len(vocab) < cfg.vocab_size:
            w1 = _WORDS[rr.integers(len(_WORDS))]
            w2 = f"{_WORDS[rr.integers(len(_WORDS))]}{rr.integers(1000)}"
            q = f"{w1} {w2}" if rr.random() < 0.8 else w2
            if q not in seen:
                seen.add(q)
                vocab.append(q)
        self.vocab = vocab
        self.fps = np.array([self.tok.query_fp(q) for q in vocab], np.uint64)

        # Zipf base probabilities
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_s)
        self.base_p = p / p.sum()
        self.topic = rr.integers(0, cfg.n_topics, size=cfg.vocab_size)
        # per-topic sampling distributions
        self._topic_p = []
        for t in range(cfg.n_topics):
            m = (self.topic == t).astype(np.float64) * self.base_p
            s = m.sum()
            self._topic_p.append(m / s if s > 0 else self.base_p)

        # --- events: append their terms to the vocab space
        self.event_term_idx: List[np.ndarray] = []
        for ev in cfg.events:
            idx = []
            for term in ev.terms:
                fp = self.tok.query_fp(term)
                if fp in self.fps:
                    idx.append(int(np.nonzero(self.fps == fp)[0][0]))
                else:
                    self.vocab.append(term)
                    self.fps = np.append(self.fps, np.uint64(fp))
                    idx.append(len(self.vocab) - 1)
            self.event_term_idx.append(np.array(idx))

        # --- misspelling pool for the head of the distribution
        self.misspell_of: Dict[int, int] = {}   # variant idx -> true idx
        self._misspell_variants: List[int] = []
        for i in range(min(cfg.n_misspell_targets, len(vocab))):
            q = self.vocab[i]
            if len(q) < 5:
                continue
            v = self._corrupt(q, rr)
            if v == q:
                continue
            fp = self.tok.query_fp(v)
            self.vocab.append(v)
            self.fps = np.append(self.fps, np.uint64(fp))
            vi = len(self.vocab) - 1
            self.misspell_of[vi] = i
            self._misspell_variants.append(vi)

    @staticmethod
    def _corrupt(q: str, rr) -> str:
        # internal-character typos (the paper's observation)
        pos = int(rr.integers(1, max(2, len(q) - 1)))
        kind = rr.integers(3)
        if kind == 0 and pos + 1 < len(q):   # transpose
            return q[:pos] + q[pos + 1] + q[pos] + q[pos + 2:]
        if kind == 1:                         # delete
            return q[:pos] + q[pos + 1:]
        return q[:pos] + "x" + q[pos + 1:]    # replace

    # ------------------------------------------------------------------
    def event_share(self, t: int) -> np.ndarray:
        """Per-event share of the query stream at tick t (hockey puck)."""
        shares = []
        for ev in self.cfg.events:
            dt = t - ev.t_start
            if dt < 0:
                shares.append(0.0)
                continue
            rise = 1.0 - np.exp(-((dt / ev.ramp_ticks) ** 2))
            fall = np.exp(-max(0.0, dt - ev.plateau_ticks) / ev.decay_ticks)
            shares.append(ev.peak_share * rise * fall)
        return np.array(shares)

    def _event_term_probs(self, ev_i: int, t: int) -> np.ndarray:
        ev = self.cfg.events[ev_i]
        dt = t - ev.t_start
        w = []
        for k in range(len(ev.terms)):
            onset = k * ev.term_lag
            w.append(0.0 if dt < onset else
                     (2.0 if k == 0 else 1.0) * (1 - np.exp(-((dt - onset + 1) / ev.ramp_ticks))))
        w = np.array(w)
        s = w.sum()
        return w / s if s > 0 else np.ones(len(w)) / len(w)

    def gen_tick(self, t: int) -> Tuple[QueryEvents, TweetBatch]:
        cfg, rng = self.cfg, self.rng
        B = cfg.queries_per_tick
        shares = self.event_share(t)
        ev_total = float(shares.sum())

        # choose generator per query: event e / base
        u = rng.random(B)
        q_idx = np.zeros(B, np.int64)
        cursor = 0.0
        assigned = np.zeros(B, bool)
        for e, sh in enumerate(shares):
            pick = (~assigned) & (u >= cursor) & (u < cursor + sh)
            cursor += sh
            if pick.any():
                tp = self._event_term_probs(e, t)
                q_idx[pick] = self.event_term_idx[e][
                    rng.choice(len(tp), size=int(pick.sum()), p=tp)]
                assigned |= pick

        # base queries: topical sessions
        users = rng.integers(0, cfg.n_users, size=B)
        epoch = t // cfg.session_ticks
        with np.errstate(over="ignore"):
            sess_fp = _mix64(
                users.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
                ^ np.uint64((epoch * 0xC2B2AE3D27D4EB4F) % (1 << 64)))
        sess_topic = (users + epoch * 7919) % cfg.n_topics
        base = ~assigned
        n_base = int(base.sum())
        if n_base:
            sticky = rng.random(n_base) < cfg.topic_stickiness
            picks = np.empty(n_base, np.int64)
            bt = sess_topic[base]
            # vectorized-ish: group by topic
            for tpc in np.unique(bt[sticky]):
                m = sticky & (bt == tpc)
                picks[m] = rng.choice(self.cfg.vocab_size, size=int(m.sum()),
                                      p=self._topic_p[tpc])
            if (~sticky).any():
                picks[~sticky] = rng.choice(self.cfg.vocab_size,
                                            size=int((~sticky).sum()), p=self.base_p)
            q_idx[base] = picks

        # typos on head queries
        if self._misspell_variants:
            ty = rng.random(B) < cfg.typo_rate
            if ty.any():
                q_idx[ty] = rng.choice(self._misspell_variants, size=int(ty.sum()))

        # during events, user sessions revisit the event terms (breaking-news
        # sessions mix event queries with their topical queries)
        src = rng.choice(3, size=B, p=cfg.source_probs).astype(np.int32)
        q_fp = self.fps[q_idx]
        events = QueryEvents(sess_fp=sess_fp, q_fp=q_fp, src=src,
                             valid=np.ones(B, bool))

        # ------- tweets -------
        T, W = cfg.tweets_per_tick, cfg.tweet_words
        tw_idx = np.zeros((T, W), np.int64)
        tu = rng.random(T)
        cursor = 0.0
        t_assigned = np.zeros(T, bool)
        for e, sh in enumerate(shares):
            tw_share = min(3.0 * sh, 0.9)  # tweets over-index on breaking news
            pick = (~t_assigned) & (tu >= cursor) & (tu < cursor + tw_share)
            cursor += tw_share
            if pick.any():
                tp = self._event_term_probs(e, t)
                tw_idx[pick] = self.event_term_idx[e][
                    rng.choice(len(tp), size=(int(pick.sum()), W), p=tp)]
                t_assigned |= pick
        rest = ~t_assigned
        if rest.any():
            topics = rng.integers(0, cfg.n_topics, size=int(rest.sum()))
            picks = np.empty((int(rest.sum()), W), np.int64)
            for i, tpc in enumerate(topics):
                picks[i] = rng.choice(self.cfg.vocab_size, size=W, p=self._topic_p[tpc])
            tw_idx[rest] = picks
        grams = np.zeros((T, cfg.tweet_grams), np.uint64)
        g = min(W, cfg.tweet_grams)
        grams[:, :g] = self.fps[tw_idx[:, :g]]
        tweets = TweetBatch(grams=grams, valid=np.ones(T, bool))
        return events, tweets


def steve_jobs_scenario(seed: int = 0, base_cfg: Optional[StreamConfig] = None
                        ) -> Tuple[StreamConfig, EventSpec]:
    """The paper's Figure-1 scenario as a canned event."""
    ev = EventSpec(
        name="steve-jobs",
        terms=("steve jobs", "apple", "stay foolish", "stay hungry", "ipad"),
        t_start=60, ramp_ticks=5.0, plateau_ticks=30.0, decay_ticks=90.0,
        peak_share=0.15, term_lag=4.0,
    )
    cfg = dataclasses.replace(base_cfg or StreamConfig(), events=(ev,))
    return cfg, ev
