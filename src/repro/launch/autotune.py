"""Backend-aware empirical kernel autotuner.

The paper's premise is that the engine must run "as fast as the hardware
allows" (§4) — but which implementation is fastest is a property of the
*backend*, not the code: the fused Pallas kernels win on a TPU and lose
badly under the CPU interpreter (re-entering XLA per grid step), and the
engine's large-batch throughput cliff is a function of the store's
conflict-resolve behaviour at the measured batch size. So instead of a
blind ``use_kernel: bool``, the tuner **measures** each hot-path candidate
pair on the running backend and records the winners in a serializable
:class:`~repro.core.plan.TunedPlan`.

Contract
--------

* :func:`tune` is the entry point: benchmark every hot path applicable to
  the config's layout — kernel vs jnp for ``score_gate``, ``bucket_topk``,
  ``region_rank``, ``chain_find``, ``decay_prune``, the ``score_gate``
  tile shape (``block_rows``), and the ingest dispatch-fusion width
  (``ingest_chunk``) — and return the winning plan.
* Results are cached on disk keyed by :func:`~repro.core.plan.shape_class`
  (backend + device kind + log2 capacities + layout + region width), one
  JSON per shape class, under ``$REPRO_AUTOTUNE_CACHE`` (default
  ``~/.cache/repro-autotune``). A cache hit returns the stored plan with
  NO re-benchmarking.
* Kernel candidates that raise (Pallas unavailable / unsupported backend)
  are recorded as failed and the jnp reference wins — tuning degrades
  gracefully to the all-jnp plan.
* Plans are **result-invariant** by construction: every candidate pair is
  property-tested bit-exact (``tests/test_autotune.py``), so the tuner can
  never change engine states or suggestion tables, only speed.

The plan rides ``EngineConfig.plan`` into every dispatch site (see the
kernel-dispatch table in ``repro/kernels/__init__``), rides snapshot meta
so a recovered engine keeps its tuning, and is surfaced live by
``SuggestFrontend.metrics()``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import ranking, stores
from ..core.decay import sweep_decay_prune
from ..core.plan import (HOT_PATH_OPS, JNP, KERNEL, TunedPlan,
                         default_region_width, shape_class)

__all__ = ["tune", "tune_engine_config", "measure_plan", "cache_dir",
           "cache_path", "hot_path_traffic", "TunedPlan", "shape_class"]

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1

# score_gate tile-shape candidates (rows of 1024 slots per grid step).
# Measured on CPU-interpret the spread is ~11x across this range; on TPU
# the default 16 is near-flat but still worth confirming per shape.
BLOCK_ROWS_CANDIDATES = (4, 8, 16, 32, 64)

# ingest dispatch-fusion candidates, in quantum slices per lax.scan
# dispatch (0 = one dispatch per slice). Fusion never changes results —
# the scan body IS ingest_queries — so this is pure dispatch scheduling.
INGEST_FUSE_CANDIDATES = (0, 2, 4)


def cache_dir(override: Optional[str] = None) -> Path:
    if override is not None:
        return Path(override)
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-autotune"


def cache_path(cfg, override: Optional[str] = None) -> Path:
    return cache_dir(override) / f"{shape_class(cfg)}.json"


def _time_us(fn: Callable[[], object], repeats: int) -> float:
    """Best-of-``repeats`` wall time of ``fn`` in µs (after one warmup
    call that also absorbs jit compilation)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
# synthetic per-op workloads (shapes from cfg; content random but fixed)
# ---------------------------------------------------------------------------


def _rank_coefs(rk) -> Tuple[float, float, float, float]:
    return (rk.coef_condprob, rk.coef_pmi, rk.coef_llr, rk.coef_chi2)


def _score_lanes(cfg, key):
    C = cfg.cooc_capacity
    ks = jax.random.split(key, 8)
    u = lambda k: jax.random.uniform(k, (C,), jnp.float32, 0.0, 4.0)
    w_ab, w_a, w_b = u(ks[0]), u(ks[1]) + 1.0, u(ks[2]) + 1.0
    c_ab = jnp.ceil(u(ks[3]))
    c_a, c_b = c_ab + jnp.ceil(u(ks[4])), c_ab + jnp.ceil(u(ks[5]))
    ok = jax.random.uniform(ks[6], (C,)) < 0.7
    tw = jnp.sum(w_a)
    tc = jnp.sum(c_a)
    return w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc


def _score_gate_pair(cfg, key):
    """(kernel_fn(block_rows), jnp_fn) for the fused score+gate pass."""
    from ..kernels import ops as kops
    rk = cfg.rank
    lanes = _score_lanes(cfg, key)
    w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc = lanes
    coefs = _rank_coefs(rk)

    def kernel_fn(block_rows):
        return lambda: kops.score_gate(
            w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc, coefs=coefs,
            min_pair_weight=rk.min_pair_weight,
            min_src_weight=rk.min_src_weight,
            min_pair_count=rk.min_pair_count, block_rows=block_rows)

    @jax.jit
    def jnp_body(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc):
        ls = ranking.assoc_scores_jnp(w_ab, c_ab, w_a, w_b, c_a, c_b, tw, tc)
        score = ranking.combine_scores(rk, *ls)
        m = (ok & (w_ab >= rk.min_pair_weight) & (c_ab >= rk.min_pair_count)
             & (w_a >= rk.min_src_weight))
        return jnp.where(m, score, -jnp.inf)

    return kernel_fn, (lambda: jnp_body(*lanes))


def _bucket_topk_pair(cfg, key):
    from ..kernels import ops as kops
    rk = cfg.rank
    C, Q = cfg.cooc_capacity, cfg.query_capacity
    M = min(C, max(rk.top_k, int(C * min(rk.seg_arena_frac, 1.0))))
    R = min(Q, M, max(rk.source_cap(Q), 1))
    L = max(rk.bucket_rows, rk.top_k)
    grid = jnp.where(jax.random.uniform(key, (R, L)) < 0.8,
                     jax.random.uniform(jax.random.fold_in(key, 1), (R, L)),
                     -jnp.inf)
    K = rk.top_k
    jnp_fn = jax.jit(lambda g: jax.lax.top_k(g, K))
    return (lambda: kops.bucket_topk(grid, K)), (lambda: jnp_fn(grid))


def _region_rank_pair(cfg, key):
    from ..kernels import ops as kops
    rk = cfg.rank
    W = cfg.region_w
    C = cfg.cooc_capacity
    R = C // W
    ks = jax.random.split(key, 8)
    u = lambda k: jax.random.uniform(k, (R, W), jnp.float32, 0.0, 4.0)
    w_ab, w_a, w_b = u(ks[0]), u(ks[1]) + 1.0, u(ks[2]) + 1.0
    c_ab = jnp.ceil(u(ks[3]))
    c_a, c_b = c_ab + 1.0, c_ab + 1.0
    ok = jax.random.uniform(ks[4], (R, W)) < 0.7
    tw, tc = jnp.sum(w_a[:, 0]), jnp.sum(c_a[:, 0])
    K1 = min(rk.top_k, W)
    coefs = _rank_coefs(rk)

    def kernel_fn():
        return kops.region_rank(
            w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc, k=K1, coefs=coefs,
            min_pair_weight=rk.min_pair_weight,
            min_src_weight=rk.min_src_weight,
            min_pair_count=rk.min_pair_count)

    @jax.jit
    def jnp_body(w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc):
        ls = ranking.assoc_scores_jnp(w_ab, c_ab, w_a, w_b, c_a, c_b, tw, tc)
        score = ranking.combine_scores(rk, *ls)
        m = (ok & (w_ab >= rk.min_pair_weight) & (c_ab >= rk.min_pair_count)
             & (w_a >= rk.min_src_weight))
        g = jnp.where(m, score, -jnp.inf)
        vals, args = jax.lax.top_k(g, K1)
        return vals, args, jnp.sum(m.astype(jnp.int32), axis=1)

    args = (w_ab, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc)
    return kernel_fn, (lambda: jnp_body(*args))


def _chain_find_pair(cfg, key):
    from ..kernels import ops as kops
    W = cfg.region_w
    R = cfg.cooc_capacity // W
    MC = cfg.region_chain
    B = min(4096, max(256, cfg.ingest_quantum or 1024))
    ks = jax.random.split(key, 5)
    khi = jax.random.randint(ks[0], (R, W), 1, 1 << 30).astype(jnp.uint32)
    klo = jax.random.randint(ks[1], (R, W), 1, 1 << 30).astype(jnp.uint32)
    regs = jax.random.randint(ks[2], (B, MC), 0, R).astype(jnp.int32)
    regs = jnp.where(jnp.arange(MC)[None, :] < 2, regs, -1)  # short chains
    pick_r = jnp.clip(regs[:, 0], 0, R - 1)
    pick_w = jax.random.randint(ks[3], (B,), 0, W)
    hit = jax.random.uniform(ks[4], (B,)) < 0.5           # ~half hits
    dhi = jnp.where(hit, khi[pick_r, pick_w], jnp.uint32(1))
    dlo = jnp.where(hit, klo[pick_r, pick_w], jnp.uint32(1))
    act = jnp.ones((B,), bool)
    jnp_fn = jax.jit(stores._chain_find_jnp)
    return (lambda: kops.chain_find(khi, klo, regs, dhi, dlo, act)), \
        (lambda: jnp_fn(khi, klo, regs, dhi, dlo, act))


def _decay_prune_pair(cfg, key):
    C = cfg.cooc_capacity
    tab = stores.make_table(C, {"weight": jnp.float32, "count": jnp.float32,
                                "last_tick": jnp.int32})
    ks = jax.random.split(key, 3)
    kh = jax.random.randint(ks[0], (C,), 0, 1 << 30).astype(jnp.uint32)
    live = jax.random.uniform(ks[1], (C,)) < 0.5
    kh = jnp.where(live, kh | jnp.uint32(1), jnp.uint32(0))
    w = jnp.where(live, jax.random.uniform(ks[2], (C,), jnp.float32, 0, 4),
                  0.0)
    tab = tab._replace(key_hi=kh, key_lo=kh,
                       lanes={"weight": w, "count": jnp.ceil(w),
                              "last_tick": jnp.zeros((C,), jnp.int32)})
    dt = jnp.int32(max(cfg.decay_every, 1))

    def mk(use_kernel):
        return lambda: sweep_decay_prune(tab, dt, cfg=cfg.decay,
                                         weight_lanes=("weight",),
                                         use_kernel=use_kernel)

    return mk(True), mk(False)


def _ingest_fuse_timings(cfg, repeats: int) -> Dict[int, float]:
    """Time k quantum slices per dispatch for each fusion candidate.

    Uses the real ingest path (``ingest_queries`` / ``ingest_queries_stack``)
    on a synthetic event stream, so the winner reflects actual dispatch +
    store-update cost at the configured quantum.
    """
    from ..core import engine as eng
    Q = cfg.ingest_quantum
    if Q <= 0:
        return {0: 0.0}
    n = max(INGEST_FUSE_CANDIDATES[-1], 1)
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    B = n * Q
    u32 = lambda k: jax.random.randint(k, (B,), 1, 1 << 30).astype(jnp.uint32)
    # ~Q/8 distinct sessions so the session window actually forms pairs
    sess = jax.random.randint(ks[0], (B,), 0, max(Q // 8, 1))
    s_hi = (sess + 1).astype(jnp.uint32)
    s_lo = (sess.astype(jnp.uint32) * jnp.uint32(2654435761)
            + jnp.uint32(1))
    q_hi, q_lo = u32(ks[1]), u32(ks[2])
    src = jax.random.randint(ks[3], (B,), 0, len(cfg.source_weights)
                             ).astype(jnp.int32)
    valid = jnp.ones((B,), bool)
    arrs = (s_hi, s_lo, q_hi, q_lo, src, valid)
    state0 = eng.init_state(cfg)

    out: Dict[int, float] = {}
    for k_fuse in INGEST_FUSE_CANDIDATES:
        kk = max(k_fuse, 1)
        stacked = tuple(a.reshape(n // kk, kk, Q) for a in arrs) \
            if n % kk == 0 else None
        if stacked is None:
            continue

        def run(k_fuse=k_fuse, kk=kk, stacked=stacked):
            st = state0
            for i in range(n // kk):
                sub = tuple(a[i] for a in stacked)
                if k_fuse == 0:
                    st = eng.ingest_queries(st, *(x[0] for x in sub),
                                            cfg=cfg)
                else:
                    st = eng.ingest_queries_stack(st, *sub, cfg=cfg)
            return st

        out[k_fuse] = _time_us(run, repeats)
    return out


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


def measure_plan(cfg, *, repeats: int = 3, tune_ingest: bool = True
                 ) -> Tuple[TunedPlan, Dict[str, Optional[float]]]:
    """Benchmark every applicable hot-path candidate pair and build the
    winning plan. Returns ``(plan, timings_us)`` where timings record every
    candidate measured (``None`` = the kernel candidate raised)."""
    timings: Dict[str, Optional[float]] = {}
    choices: Dict[str, str] = {op: JNP for op in HOT_PATH_OPS}
    key = jax.random.PRNGKey(0)
    region = cfg.region_cooc

    def bench(name: str, fn) -> Optional[float]:
        try:
            t = _time_us(fn, repeats)
        except Exception:                     # Pallas unavailable / broken
            timings[name] = None
            return None
        timings[name] = t
        return t

    # -- score_gate (hash-layout ranking prologue) + its tile shape --
    block_rows = 16
    if not region:
        kfn, jfn = _score_gate_pair(cfg, jax.random.fold_in(key, 1))
        rows = cfg.cooc_capacity // 1024
        cands = [b for b in BLOCK_ROWS_CANDIDATES
                 if b <= rows and rows % b == 0] or [min(16, rows)]
        best_k, best_b = None, cands[0]
        for b in cands:
            t = bench(f"score_gate:kernel:blk{b}", kfn(b))
            if t is not None and (best_k is None or t < best_k):
                best_k, best_b = t, b
        t_j = bench("score_gate:jnp", jfn)
        block_rows = best_b
        if best_k is not None and t_j is not None and best_k < t_j:
            choices["score_gate"] = KERNEL

        kfn, jfn = _bucket_topk_pair(cfg, jax.random.fold_in(key, 2))
        t_k = bench("bucket_topk:kernel", kfn)
        t_j = bench("bucket_topk:jnp", jfn)
        if t_k is not None and t_j is not None and t_k < t_j:
            choices["bucket_topk"] = KERNEL
    else:
        # -- region layout: the fused region pass + the chain find --
        kfn, jfn = _region_rank_pair(cfg, jax.random.fold_in(key, 3))
        t_k = bench("region_rank:kernel", kfn)
        t_j = bench("region_rank:jnp", jfn)
        if t_k is not None and t_j is not None and t_k < t_j:
            choices["region_rank"] = KERNEL

        kfn, jfn = _chain_find_pair(cfg, jax.random.fold_in(key, 4))
        t_k = bench("chain_find:kernel", kfn)
        t_j = bench("chain_find:jnp", jfn)
        if t_k is not None and t_j is not None and t_k < t_j:
            choices["chain_find"] = KERNEL

    # -- decay/prune sweep (both layouts sweep the qstore; the hash layout
    # sweeps the cooc store too) --
    kfn, jfn = _decay_prune_pair(cfg, jax.random.fold_in(key, 5))
    t_k = bench("decay_prune:kernel", kfn)
    t_j = bench("decay_prune:jnp", jfn)
    if t_k is not None and t_j is not None and t_k < t_j:
        choices["decay_prune"] = KERNEL

    # -- ingest dispatch fusion --
    ingest_chunk = 0
    if tune_ingest and cfg.ingest_quantum > 0:
        fuse = _ingest_fuse_timings(cfg, repeats)
        for k_fuse, t in fuse.items():
            timings[f"ingest_fuse:{k_fuse}"] = t
        if fuse:
            best = min(fuse, key=fuse.get)
            ingest_chunk = best * cfg.ingest_quantum if best > 0 else 0

    plan = TunedPlan(**choices, score_block_rows=block_rows,
                     ingest_chunk=ingest_chunk,
                     backend=jax.default_backend(),
                     shape_class=shape_class(cfg))
    return plan, timings


def tune(cfg, *, cache: Optional[str] = None, force: bool = False,
         repeats: int = 3, tune_ingest: bool = True) -> TunedPlan:
    """Return the tuned plan for ``cfg`` — from the shape-class disk cache
    when present (no re-benchmark), measured and cached otherwise."""
    path = cache_path(cfg, cache)
    if not force and path.exists():
        try:
            rec = json.loads(path.read_text())
            if rec.get("version") == CACHE_VERSION:
                return TunedPlan.from_json(rec["plan"])
        except (ValueError, KeyError):
            pass                               # corrupt cache: re-measure
    plan, timings = measure_plan(cfg, repeats=repeats,
                                 tune_ingest=tune_ingest)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(
        {"version": CACHE_VERSION, "shape_class": shape_class(cfg),
         "backend": jax.default_backend(), "plan": plan.to_json(),
         "timings_us": timings}, indent=2, sort_keys=True))
    os.replace(tmp, path)
    return plan


def tune_engine_config(cfg, **kw):
    """``tune`` + attach: returns ``cfg`` with the winning plan installed
    (``EngineConfig.plan``; its ``__post_init__`` forwards it to the
    ranking config)."""
    return dataclasses.replace(cfg, plan=tune(cfg, **kw))


# ---------------------------------------------------------------------------
# roofline hooks: per-op HBM traffic models for the tuned hot paths
# ---------------------------------------------------------------------------


def hot_path_traffic(cfg) -> Dict[str, Dict[str, float]]:
    """Analytic bytes/flops per hot-path invocation, for
    ``roofline.hot_path_roofline`` rows (bytes dominate every one of these
    ops — they are table sweeps; flops are a lanes-linear estimate)."""
    C = float(cfg.cooc_capacity)
    rk = cfg.rank
    out: Dict[str, Dict[str, float]] = {}
    if not cfg.region_cooc:
        # 7 f32 input lanes read + 1 f32 score lane written
        out["score_gate"] = {"bytes": 8 * 4 * C, "flops": 60 * C}
        M = min(C, max(rk.top_k, int(C * min(rk.seg_arena_frac, 1.0))))
        R = min(cfg.query_capacity, M)
        L = max(rk.bucket_rows, rk.top_k)
        out["bucket_topk"] = {
            "bytes": 4.0 * R * L + 8.0 * R * rk.top_k,
            "flops": 3.0 * R * L * rk.top_k}
    else:
        W = float(cfg.region_w)
        out["region_rank"] = {
            "bytes": 8 * 4 * C + 8.0 * (C / W) * min(rk.top_k, int(W)),
            "flops": 60 * C}
        B = float(min(4096, max(256, cfg.ingest_quantum or 1024)))
        out["chain_find"] = {"bytes": B * 2 * (2 * 4 * W + 4),
                             "flops": B * 2 * 3 * W}
    # keys (2 u32) + 3 lanes read and written
    out["decay_prune"] = {"bytes": 2 * (2 + 3) * 4 * C, "flops": 6 * C}
    return out
