import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The ONLY entry point that forces 512 placeholder devices (set above before
any other import — jax locks the device count on first init). For every
cell this:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives params/optimizer/batch shardings from the arch's logical rules,
  3. jit(train_step | serve_step).lower(<ShapeDtypeStructs>).compile(),
  4. records memory_analysis + cost_analysis + collective bytes (roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_arch, list_archs
from ..distributed import sharding as shd
from ..models import api, transformer as tr
from ..training import optimizer as optim
from ..training.train_loop import TrainConfig, init_train_state, make_train_step
from . import roofline as rl
from .mesh import make_production_mesh


def _opt_config(cfg) -> optim.AdamWConfig:
    master = isinstance(cfg, tr.LMConfig) and cfg.dtype == "bfloat16"
    return optim.AdamWConfig(master_weights=master)


def _cache_shardings(mesh, caches_shape):
    """KV caches: [L, B, T, Hkv, D] — batch over dp, cache length over tp
    (kv-head counts rarely divide tp; the T dim always does)."""
    def spec(leaf):
        if leaf.ndim == 5:
            return NamedSharding(
                mesh, shd.resolve(None, "dp", "tp", None, None,
                                  shape=leaf.shape))
        if leaf.ndim >= 2:
            return NamedSharding(
                mesh, shd.resolve(None, "dp", *([None] * (leaf.ndim - 2)),
                                  shape=leaf.shape))
        return NamedSharding(mesh, P())
    return jax.tree.map(spec, caches_shape)


def _compile_for(cfg, spec, cell, mesh, accum=None):
    """Lower + compile one configuration; returns the compiled artifact.

    accum=None uses the memory policy (4-way for LM train); cost probes pass
    accum=1 — a trip-count-4 accumulation scan would be cost-counted once.
    """
    with shd.use_mesh(mesh):
        params_shape = api.abstract_params(cfg)
        rules = (api.sharding_rules(cfg) if cell.kind == "train"
                 else api.serve_rules(cfg))
        p_shard = shd.params_shardings(mesh, params_shape, rules)
        specs = api.input_specs(cfg, cell)
        baxis = api.batch_axis_for(cfg, cell)

        if cell.kind == "train":
            ocfg = _opt_config(cfg)
            # LM train cells: 4-way grad accumulation keeps the live
            # activation set within 16GB/chip (global batch unchanged).
            if accum is None:
                if isinstance(cfg, tr.LMConfig):
                    accum = 8 if cfg.moe else 4
                else:
                    accum = 1
            tcfg = TrainConfig(opt=ocfg, grad_accum=accum)
            state_shape = jax.eval_shape(
                lambda: init_train_state(
                    jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 params_shape), tcfg))
            # optimizer moments follow the param shardings
            def state_shardings(sub):
                return shd.params_shardings(mesh, sub, rules)
            s_shard = {k: (state_shardings(v) if k in ("m", "v", "master", "ef")
                           else NamedSharding(mesh, P()))
                       for k, v in state_shape["opt"].items()}
            s_shard = {"opt": s_shard}
            b_shard = shd.batch_shardings(mesh, specs["batch"],
                                          batch_axis=baxis)
            step = make_train_step(api.loss_fn(cfg), tcfg)
            fn = jax.jit(step,
                         in_shardings=(p_shard, s_shard, b_shard),
                         out_shardings=(p_shard, s_shard, None),
                         donate_argnums=(0, 1))   # alias state in/out
            lowered = fn.lower(params_shape, state_shape, specs["batch"])
        elif cell.kind in ("prefill", "decode"):
            caches_shape = specs["caches"]
            c_shard = _cache_shardings(mesh, caches_shape)
            tok_shard = shd.batch_shardings(mesh, specs["tokens"])
            sfn = api.serve_fn(cfg, cell)
            fn = jax.jit(sfn,
                         in_shardings=(p_shard, c_shard, tok_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(params_shape, caches_shape, specs["tokens"])
        else:  # serve / retrieval
            b_shard = shd.batch_shardings(mesh, specs["batch"],
                                          batch_axis=baxis)
            sfn = api.serve_fn(cfg, cell)
            fn = jax.jit(sfn, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(params_shape, specs["batch"])

        return lowered.compile()


def _costs(compiled, chips):
    ca = compiled.cost_analysis()
    # jaxlib has returned both a dict and a per-device *list* of dicts from
    # cost_analysis() across versions; normalize to one flat dict.
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    txt = compiled.as_text()
    coll, by_kind, counts = rl.collective_bytes(txt)
    # fusion-aware HBM traffic (see roofline.fusion_aware_bytes): XLA's raw
    # "bytes accessed" counts pre-fusion operand bytes and over-states HBM
    # traffic by >10x on a fusing backend; we report both, the roofline
    # memory term uses the fusion-aware estimate.
    return (float(ca.get("flops", 0.0)) * chips,
            float(rl.fusion_aware_bytes(txt)) * chips,
            float(coll), by_kind, counts,
            float(ca.get("bytes accessed", 0.0)) * chips)


def build_cell(arch_id: str, shape_name: str, multi_pod: bool
               ) -> Optional[Dict[str, Any]]:
    """Lower + compile one cell. Returns the roofline row (or skip record).

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so a scanned L-layer transformer under-reports by ~L. We
    therefore compile L=1 and L=2 twins of LM cells and extrapolate:
      cost(L) = cost(1) + (L-1) * (cost(2) - cost(1)).
    The FULL config is still compiled — that compile (and its
    memory_analysis) is the deliverable proving the cell fits and shards.
    """
    spec = get_arch(arch_id)
    cell = spec.cell(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if cell.skip:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": cell.skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = spec.config
    if spec.family == "gnn":
        from ..configs.gat_cora import adapt_config
        cfg = adapt_config(cfg, cell)
    if isinstance(cfg, tr.LMConfig):
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        cfg = api.adapt_lm_config(cfg, cell, dp_size=dp)

    t0 = time.time()
    compiled = _compile_for(cfg, spec, cell, mesh)
    t_compile = time.time() - t0

    flops_g, bytes_g, coll, by_kind, counts, bytes_raw = _costs(compiled, chips)
    if isinstance(cfg, tr.LMConfig) and cfg.n_layers > 2:
        # XLA counts a while body once regardless of trip count, so the
        # scanned stack under-reports by ~L. Probe with FULLY-UNROLLED
        # 2- and 4-layer twins: body = (cost(4) - cost(2)) / 2, then
        # cost(L) = cost(2) + (L - 2) * body.
        L = cfg.n_layers
        c2 = _costs(_compile_for(
            dataclasses.replace(cfg, n_layers=2, scan_unroll=2),
            spec, cell, mesh, accum=1), chips)
        c4 = _costs(_compile_for(
            dataclasses.replace(cfg, n_layers=4, scan_unroll=4),
            spec, cell, mesh, accum=1), chips)
        ext = lambda a2, a4: a2 + (L - 2) * max(a4 - a2, 0.0) / 2.0
        flops_g = ext(c2[0], c4[0])
        bytes_g = ext(c2[1], c4[1])
        coll = ext(c2[2], c4[2])
        by_kind = {k: int(ext(c2[3][k], c4[3][k])) for k in c2[3]}
        counts = {k: int(ext(c2[4][k], c4[4][k])) for k in c2[4]}
        bytes_raw = ext(c2[5], c4[5])

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(ma, "argument_size_in_bytes", None),
               "output_bytes": getattr(ma, "output_size_in_bytes", None),
               "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
               "peak_bytes": getattr(ma, "peak_memory_in_bytes", None)}
    except Exception:
        pass

    roof = rl.Roofline(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_global=flops_g,
        bytes_global=api.model_bytes(cfg, cell),   # analytic traffic model
        coll_bytes=coll,
        coll_by_kind=by_kind, coll_counts=counts,
        model_flops=api.model_flops(cfg, cell),
        peak_flops=rl_peak(), hbm_bw=rl_hbm(), link_bw=rl_link(),
        memory_per_device=mem)
    row = roof.row()
    row["hlo_bytes_raw"] = bytes_raw         # diagnostic: pre-fusion metric
    row["hlo_bytes_fusion_est"] = bytes_g    # diagnostic: HLO include-list
    row["status"] = "ok"
    row["compile_s"] = round(t_compile, 1)
    return row


def rl_peak():
    from .mesh import PEAK_FLOPS_BF16
    return PEAK_FLOPS_BF16


def rl_hbm():
    from .mesh import HBM_BW
    return HBM_BW


def rl_link():
    from .mesh import ICI_BW_PER_LINK
    return ICI_BW_PER_LINK


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for c in get_arch(a).shapes:
                cells.append((a, c.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    rows = []
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}/{shape_name}/{'2x16x16' if mp else '16x16'}"
            try:
                row = build_cell(arch_id, shape_name, mp)
                rows.append(row)
                if row["status"] == "ok":
                    mem = row.get("memory_per_device") or {}
                    print(f"OK   {tag}: bottleneck={row['bottleneck']} "
                          f"tC={row['t_compute_s']:.2e}s tM={row['t_memory_s']:.2e}s "
                          f"tX={row['t_collective_s']:.2e}s "
                          f"frac={row['roofline_fraction']:.3f} "
                          f"compile={row['compile_s']}s", flush=True)
                else:
                    print(f"SKIP {tag}: {row['reason']}", flush=True)
            except Exception as e:  # noqa: BLE001
                rows.append({"arch": arch_id, "shape": shape_name,
                             "mesh": "2x16x16" if mp else "16x16",
                             "status": "error", "error": str(e)[:2000]})
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"wrote {args.out}")
    n_ok = sum(1 for r in rows if r["status"] == "ok")
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    n_err = len(rows) - n_ok - n_skip
    print(f"SUMMARY ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
