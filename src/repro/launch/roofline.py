"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs_global / (chips x peak_FLOP/s)
  memory term     = HLO_bytes_global / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module
(SPMD compiles one program), so global = per-device x chips. Collective
bytes are not in cost_analysis: we parse the compiled HLO and sum the
result-shape bytes of every collective op (a device-bytes-moved proxy:
all-reduce moves ~2x this in a ring, all-gather receives exactly this;
we additionally report per-op-kind counts so the §Perf loop can see WHICH
collective dominates).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[2,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^ ]*\s*,?\s*)+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# Ops that genuinely materialize HBM traffic on a fusing (TPU) backend.
# Elementwise chains (convert/multiply/add/broadcast/select/...) fuse into
# their consumers on TPU and are excluded — the CPU backend leaves them
# top-level, which is why raw "bytes accessed" over-states traffic >10x.
_MATERIALIZING = (
    "dot", "convolution", "fusion",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "pad", "reverse", "sort", "rng", "rng-bit-generator",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "select-and-scatter", "custom-call", "cholesky",
    "triangular-solve",
)
# copy/transpose/reduce/elementwise are CPU-backend artifacts: on TPU they
# fuse into consumers (layout assignment + loop fusion), so they are not
# counted as HBM traffic.
_OPCODE_RE = re.compile(r"([a-z0-9\-]+)\(")


def fusion_aware_bytes(hlo_text: str) -> int:
    """Fusion-aware HBM traffic estimate from the COMPILED module.

    Sum 2x result bytes (write + downstream read) over instructions whose
    opcode genuinely materializes on TPU (_MATERIALIZING), + parameter
    bytes once. Result shapes of multi-output ops count every element.
    """
    total = 0
    in_fusion = False
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s:       # computation header
            in_fusion = "fused" in s.split("(")[0]
            continue
        if in_fusion or "= " not in line:
            continue
        rhs = line.split("= ", 1)[1]
        mop = _OPCODE_RE.search(rhs)
        if not mop:
            continue
        op = mop.group(1)
        shapes_str = rhs[: mop.start()]
        b = sum(shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(shapes_str))
        if op == "parameter":
            total += b
            continue
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in _MATERIALIZING or op.endswith("-done"):
            continue
        total += 2 * b
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int], Dict[str, int]]:
    """-> (total_bytes, bytes_by_kind, count_by_kind). Sums result shapes;
    `-done` ops are skipped (the `-start` carries the shape)."""
    total = 0
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        b = sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shapes))
        total += b
        by_kind[kind] += b
        counts[kind] += 1
    return total, by_kind, counts


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    coll_counts: Dict[str, int]
    model_flops: float
    peak_flops: float
    hbm_bw: float
    link_bw: float
    memory_per_device: Optional[Dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * self.link_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-at-peak time over the dominant-term time: how close
        the step is to the best this hardware could do on the useful math."""
        t_ideal = self.model_flops / (self.chips * self.peak_flops)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_ideal / max(t_bound, 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_bytes": self.coll_bytes,
            "coll_counts": {k: v for k, v in self.coll_counts.items() if v},
            "memory_per_device": self.memory_per_device,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, peak=None, hbm=None, link=None) -> Roofline:
    from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
    ca = compiled.cost_analysis() or {}
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll, by_kind, counts = collective_bytes(txt)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
        }
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_global=flops_dev * chips, bytes_global=bytes_dev * chips,
        coll_bytes=float(coll), coll_by_kind=by_kind, coll_counts=counts,
        model_flops=model_flops,
        peak_flops=peak or PEAK_FLOPS_BF16, hbm_bw=hbm or HBM_BW,
        link_bw=link or ICI_BW_PER_LINK,
        memory_per_device=mem)


def hot_path_roofline(name: str, *, bytes_touched: float, flops: float,
                      measured_us: float, peak=None, hbm=None) -> Dict:
    """Distance-to-roofline row for ONE measured hot-path op.

    The tuned engine ops (``autotune.hot_path_traffic`` supplies the
    analytic bytes/flops) are table sweeps: the hardware ceiling for each
    is ``max(bytes/HBM_BW, flops/peak)`` — no collectives, one device.
    ``roofline_fraction`` is ceiling-time over measured-time (1.0 = the op
    runs as fast as the memory system allows; CPU-interpret numbers are
    honest and small). Mirrors :meth:`Roofline.row` field names so both
    row kinds land in the same reports.
    """
    from .mesh import HBM_BW, PEAK_FLOPS_BF16
    peak = peak or PEAK_FLOPS_BF16
    hbm = hbm or HBM_BW
    t_mem = bytes_touched / hbm
    t_comp = flops / peak
    t_ceiling = max(t_mem, t_comp, 1e-30)
    t_meas = measured_us * 1e-6
    return {
        "op": name,
        "bytes_touched": bytes_touched,
        "model_flops": flops,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_measured_s": t_meas,
        "bottleneck": "memory" if t_mem >= t_comp else "compute",
        "roofline_fraction": t_ceiling / max(t_meas, 1e-30),
    }
