"""End-to-end search-assistance service launcher (paper Figure 4).

Runs the full deployed architecture on a synthetic stream: backend
engine(s) consuming the query hose + firehose, leader-elected persistence
every rank cycle, frontend replicas polling for fresh results, background
model + interpolation, and a periodic spelling job.

The stack is **restartable end to end**: the elected leader appends every
tick to a durable firehose log and snapshots BOTH engine states (real-time
and background) into delta-chained checkpoint dirs (changed slots only
between fulls — ``--full-every``). Kill the process and relaunch with
``--recover`` and it restores both engines from their snapshot chains,
replays the shared log tail faster than real time (ranking suppressed per
engine until its lag clears), rebuilds the interpolation cache, and keeps
serving from where it left off.

With ``--slo-ms`` set the live path runs under the overload controller
(``streaming/overload.py``): lag-adaptive micro-batching through the fused
``ingest_many`` scan plus the degradation ladder (shed rt ranking ->
stretch bg ranking -> admission-control ingest), every shed counted and
surfaced in the status line. ``--workload firehose`` swaps the synthetic
stream for the flash-crowd workload generator (``--spike-mult`` x volume
at ``--spike-at``), ``--tick-ms`` paces simulated arrivals so falling
behind real time shows up as lag, and ``--slow-io-ms`` injects disk
latency into the log writer (chaos knob).

With ``--compact-every N`` the leader periodically folds the sealed log
into a base snapshot (``streaming/compaction.py``): on-disk log bytes stay
bounded while replay-from-zero survives via the newest base — the fleet
path takes the same flag through ``FleetConfig.compact_every``.

With ``--fleet N`` the run switches to the self-healing replicated fleet
(``distributed.fleet.ServingFleet``): N full serving stacks replaying one
leader-written, epoch-fenced durable log, heartbeat failure detection,
lag-gated readmission, and hedged staleness-aware routing. The chaos
knobs ``--kill-leader-at`` (mid-segment) and ``--kill-follower-at``
demonstrate failover + self-healing live; requests keep being answered
throughout.

  python -m repro.launch.serve_assist --ticks 120 --out /tmp/assist
  python -m repro.launch.serve_assist --ticks 120 --out /tmp/assist --recover
  python -m repro.launch.serve_assist --ticks 120 --out /tmp/assist \\
      --slo-ms 80 --workload firehose --spike-mult 50 --tick-ms 40
  python -m repro.launch.serve_assist --ticks 48 --out /tmp/assist \\
      --fleet 3 --workload firehose --spike-at 6 \\
      --kill-leader-at 7 --kill-follower-at 12
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from ..core.background import AssistanceService, background_config
from ..core.engine import EngineConfig, SearchAssistanceEngine
from ..core.spelling import SpellConfig, spelling_cycle
from ..core import stores
from ..core.hashing import join_fp
from ..data.stream import StreamConfig, SyntheticStream, steve_jobs_scenario
from ..distributed.fault_tolerance import CheckpointManager, ReplicaGroup
from ..serving.serve import SuggestFrontend, ServerSet, pack_suggestions
from ..streaming import (FirehoseLogReader, FirehoseLogWriter, ReplayConfig,
                         FirehoseWorkload, SLOConfig, SpamSpec, SpikeSpec,
                         WorkloadConfig, recover_service, slow_io)


def _fmt(v, nd: int = 1):
    """Status-line formatting: a missing signal prints as '?', not None
    (lag is None before the first log segment seals; latency percentiles
    are None before the first overload-meta persist)."""
    if v is None:
        return "?"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def _run_fleet(args, ecfg, gen_tick, head, head_t0) -> None:
    """--fleet N: the self-healing replicated fleet, chaos knobs wired."""
    from ..distributed.fleet import FleetConfig, ServingFleet
    fleet = ServingFleet(args.out, ecfg,
                         FleetConfig(n_replicas=args.fleet,
                                     compact_every=args.compact_every,
                                     keep_bases=args.keep_bases))
    ss = fleet.serverset(timeout_s=0.25, max_retries=1)
    for t in range(args.ticks):
        ev, tw = gen_tick(t)
        if t == args.kill_leader_at:
            lead = fleet.leader()
            fleet.kill(lead, mid_segment=True)
            print(f"[t={t}] leader {lead} KILLED mid-segment (torn tail)")
        if t == args.kill_follower_at:
            victim = next((r.rid for r in fleet._replicas
                           if r.status == "live"
                           and r.rid != fleet.leader()), None)
            if victim is not None:
                fleet.kill(victim)
                print(f"[t={t}] follower {victim} killed")
        fleet.offer_tick(t, ev, tw)
        if t % 6 == 0 and t >= head_t0:
            res = ss.request_info(head, k=5)
            m = fleet.metrics()
            print(f"[t={t}] related('{head}') via replica {res.replica} "
                  f"(tick={_fmt(res.tick)} staleness={_fmt(res.staleness)}"
                  f"{' HEDGED' if res.hedged else ''}) "
                  f"{len(res.suggestions)} rows | leader={m['leader']} "
                  f"epoch={m['epoch']} "
                  f"status={[r['status'] for r in m['replicas'].values()]}")
    m = fleet.metrics()
    print(f"[done] fleet: {ss.n_requests} requests ({ss.n_hedged} hedged), "
          f"{m['n_failovers']} failovers, {m['n_recoveries']} recoveries, "
          f"log healed {m['n_healed_ticks']} ticks "
          f"({m['n_lost_ticks']} lost), epoch {m['epoch']}, "
          f"{m['n_compactions']} compactions "
          f"(floor={_fmt(m['log_floor_tick'])})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--out", default="/tmp/assist")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N self-healing fleet replicas instead of the "
                         "single-stack path (distributed.fleet)")
    ap.add_argument("--kill-leader-at", type=int, default=-1,
                    help="fleet chaos: kill the log-writer leader "
                         "mid-segment at this tick")
    ap.add_argument("--kill-follower-at", type=int, default=-1,
                    help="fleet chaos: kill a live follower at this tick")
    ap.add_argument("--fail-replica-at", type=int, default=-1,
                    help="tick at which backend replica 0 dies (failover demo)")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="tick at which the WHOLE stack exits mid-run "
                         "(relaunch with --recover to pick it back up)")
    ap.add_argument("--recover", action="store_true",
                    help="restore rt+bg engine state from the snapshot "
                         "chains and replay the log tail before serving")
    ap.add_argument("--full-every", type=int, default=4,
                    help="state-snapshot chain: one full every N snapshots, "
                         "deltas (changed slots only) in between")
    ap.add_argument("--use-kernel", action="store_true",
                    help="legacy: force ALL hot paths through Pallas "
                         "(overrides --autotune's measured plan)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure kernel-vs-jnp per hot path at startup "
                         "(cached per backend/shape class) and run the "
                         "winning plan")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="enable overload control with this per-tick step "
                         "latency SLO (0 = legacy per-tick path)")
    ap.add_argument("--workload", choices=("synthetic", "firehose"),
                    default="synthetic",
                    help="'firehose' = flash-crowd workload generator "
                         "(streaming/workload.py)")
    ap.add_argument("--spike-mult", type=float, default=50.0,
                    help="flash-crowd peak volume multiplier (firehose)")
    ap.add_argument("--spike-at", type=int, default=30,
                    help="flash-crowd onset tick (firehose)")
    ap.add_argument("--tick-ms", type=float, default=0.0,
                    help="simulated real-time budget per tick; processing "
                         "slower than this accrues lag (0 = no pacing)")
    ap.add_argument("--slow-io-ms", type=float, default=0.0,
                    help="inject this much latency into every log-segment "
                         "seal (chaos: degraded disk)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="fold the sealed log into a base snapshot every N "
                         "ticks: bounded on-disk bytes, replay-from-zero "
                         "kept alive via the base (0 = no compaction)")
    ap.add_argument("--keep-bases", type=int, default=2,
                    help="compaction fallback depth: old bases (and their "
                         "log tail) retained after each floor swap")
    args = ap.parse_args()

    if args.workload == "firehose":
        wl = FirehoseWorkload(WorkloadConfig(
            base_queries_per_tick=1024, base_tweets_per_tick=64,
            spikes=(SpikeSpec(t_start=args.spike_at, mult=args.spike_mult),),
            spam=SpamSpec()), seed=0)
        gen_tick, tok = wl.gen_tick, wl.tok
        head, head_t0 = "breaking0 term0", args.spike_at
    else:
        scfg, event = steve_jobs_scenario(
            base_cfg=StreamConfig(vocab_size=2048, queries_per_tick=1024,
                                  tweets_per_tick=128))
        stream = SyntheticStream(scfg, seed=0)
        gen_tick, tok = stream.gen_tick, stream.tok
        head, head_t0 = "steve jobs", event.t_start
    # use_kernel stays None unless the legacy flag is given — a bool here
    # force-overrides the tuned plan at every dispatch site.
    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 14, decay_every=6,
                        rank_every=12,
                        use_kernel=True if args.use_kernel else None)
    if args.autotune:
        from .autotune import tune_engine_config
        ecfg = tune_engine_config(ecfg)
        print("[assist] tuned plan:", ecfg.plan.variants())
    if args.fleet > 0:
        _run_fleet(args, ecfg, gen_tick, head, head_t0)
        return
    bgcfg = background_config(ecfg, rank_every_mult=3)

    rt_dir = os.path.join(args.out, "rt")
    bg_dir = os.path.join(args.out, "bg")
    spell_dir = os.path.join(args.out, "spell")
    log_dir = os.path.join(args.out, "log")
    state_rt = os.path.join(args.out, "state", "rt")
    state_bg = os.path.join(args.out, "state", "bg")
    rt_group = ReplicaGroup(args.replicas, CheckpointManager(rt_dir))
    # engine-STATE snapshots (the recovery path): delta-chained so the
    # cadence can match every rank cycle without a write-volume blowup
    state_rt_ckpt = CheckpointManager(state_rt, keep_n=4,
                                      full_interval=args.full_every)
    state_bg_ckpt = CheckpointManager(state_bg, keep_n=4,
                                      full_interval=args.full_every)

    start_tick = 0
    if args.recover:
        # recover_service handles engines with no snapshot yet (a crash
        # before the first persist): they cold-start and replay the whole
        # retained log, so resume always lands past the logged ticks.
        # allow_gap: a snapshot can be newer than the log's surviving tail
        # (unflushed ticks died with the crash) — resuming appends past the
        # hole is the paper's stance (§4.2: losing a little state is
        # tolerable), and later recoveries skip it instead of failing.
        FirehoseLogReader(log_dir).repair()   # drop torn-tail debris
        t0 = time.perf_counter()
        svc, rstats = recover_service(ecfg, state_rt_ckpt, state_bg_ckpt,
                                      log_dir,
                                      ReplayConfig(chunk_ticks=8,
                                                   allow_gap=True),
                                      bg_cfg=bgcfg)
        dt = time.perf_counter() - t0
        print(f"[recover] rt: replayed {rstats['rt']['n_ticks']} ticks from "
              f"snapshot {rstats['rt']['restored_step']}, bg: "
              f"{rstats['bg']['n_ticks']} ticks from "
              f"{rstats['bg']['restored_step']} "
              f"(fell_back={rstats['bg']['restore'].get('fell_back')}); "
              f"{dt:.1f}s to fresh tables")
        backends = [svc.rt]
        for i in range(1, args.replicas):
            eng = SearchAssistanceEngine(ecfg, name=f"rt{i}")
            eng.state = svc.rt.state       # replicated, not sharded
            eng.suggestions = dict(svc.rt.suggestions)
            backends.append(eng)
        bg_engine = svc.bg
        start_tick = int(svc.rt.state.tick)
    else:
        backends = [SearchAssistanceEngine(ecfg, name=f"rt{i}")
                    for i in range(args.replicas)]
        bg_engine = SearchAssistanceEngine(bgcfg, name="bg")

    writer = FirehoseLogWriter(log_dir, ticks_per_segment=8,
                               keep_segments=16)
    if args.slow_io_ms > 0:
        slow_io(writer, ("flush",), args.slow_io_ms / 1e3)
    compactor = None
    if args.compact_every > 0:
        from ..streaming.compaction import CompactionConfig, LogCompactor
        # folds under the names recover_service restores ("rt"/"bg")
        compactor = LogCompactor(
            log_dir, {"rt": ecfg, "bg": bgcfg},
            cfg=CompactionConfig(keep_bases=args.keep_bases))
    bg_ckpt = CheckpointManager(bg_dir)
    spell_ckpt = CheckpointManager(spell_dir)

    frontends = [SuggestFrontend(rt_dir, bg_dir, tok,
                                 spell_dir=spell_dir, log_dir=log_dir)
                 for _ in range(2)]
    serverset = ServerSet(frontends)

    # overload control (--slo-ms): one controller drives the whole stack —
    # leader rt engine + bg engine, with the follower replicas as mirrors
    # fed the same fused flushed stacks
    svc = None
    if args.slo_ms > 0:
        svc = AssistanceService(rt=backends[0], bg=bg_engine,
                                slo=SLOConfig(slo_ms=args.slo_ms),
                                mirrors=backends[1:])

    def log_all(tick, ev_a, tw_a):
        # the elected leader appends (the admitted batch) to the durable log
        for rid in rt_group.live():
            rt_group.log_append(rid, writer, tick, ev_a, tw_a)

    wall0 = time.perf_counter()
    for t in range(start_tick, args.ticks):
        ev, tw = gen_tick(t)
        if args.fail_replica_at == t:
            rt_group.fail(0)
            print(f"[t={t}] replica 0 FAILED; leader is now {rt_group.leader()}")

        if svc is not None:
            # simulated arrival pacing: ticks arrive every --tick-ms of
            # wall time; processing slower than that accrues lag the
            # controller must batch/shed away
            lag_hint = 0.0
            if args.tick_ms > 0:
                arrived = (time.perf_counter() - wall0) * 1e3 / args.tick_ms
                lag_hint = max(0.0, start_tick + arrived - t)
            res = svc.step(ev, tw, log_append=log_all, lag_hint=lag_hint)
            leader = rt_group.leader()
            ranked = res is not None and res.get("rt") is not None
            # persist on a rank cycle — and heartbeat at the same cadence
            # while ranking is shed, so frontends keep seeing fresh shed /
            # latency telemetry (and the leader keeps snapshotting state
            # for crash recovery) through a sustained overload. The
            # heartbeat re-persists the STALE table under its honest
            # ``tick`` (the last ranked tick), never claiming freshness.
            heartbeat = (not ranked and t > 0
                         and t % svc.rt.cfg.rank_every == 0)
            if (ranked or heartbeat) and leader is not None:
                done = int(svc.rt.state.tick) - 1   # stats watermark
                meta = {"layout": svc.rt.cfg.cooc_layout,
                        "overload": svc.overload.stats_snapshot()}
                if svc.rt.cfg.plan is not None:   # tuned variants -> metrics
                    meta["plan"] = svc.rt.cfg.plan.to_json()
                if ranked:
                    meta["tick"] = done             # last reflected tick
                elif svc.rt.last_rank_tick >= 0:
                    meta["tick"] = int(svc.rt.last_rank_tick) - 1
                if svc.rt.last_maintenance:
                    meta["maintenance"] = svc.rt.last_maintenance
                wrote = rt_group.persist(
                    leader, done, pack_suggestions(svc.rt.suggestions), meta)
                if wrote:
                    svc.save_snapshot(state_rt_ckpt, state_bg_ckpt)
                    print(f"[t={t}] leader persisted "
                          f"{len(svc.rt.suggestions)} rows"
                          f"{' (heartbeat)' if heartbeat else ''} at level "
                          f"{svc.overload.ladder.name} (snapshots: rt="
                          f"{state_rt_ckpt.last_save_kind}, bg="
                          f"{state_bg_ckpt.last_save_kind})")
            if res is not None and res.get("bg") is not None:
                bg_ckpt.save(t, pack_suggestions(svc.bg.suggestions),
                             meta={"tick": int(svc.bg.state.tick) - 1})
        else:
            log_all(t, ev, tw)
            results = []
            for rid, eng in enumerate(backends):
                if not rt_group.alive[rid]:
                    continue
                results.append((rid, eng.step(ev, tw)))
            bg_res = bg_engine.step(ev, tw)

            for rid, res in results:
                if res is not None:   # a rank cycle ran -> leader persists
                    eng = backends[rid]
                    meta = {"tick": t, "layout": eng.cfg.cooc_layout}
                    if eng.last_maintenance:  # freelist pressure -> frontends
                        meta["maintenance"] = eng.last_maintenance
                    if eng.cfg.plan is not None:  # tuned variants -> metrics
                        meta["plan"] = eng.cfg.plan.to_json()
                    wrote = rt_group.persist(
                        rid, t, pack_suggestions(eng.suggestions), meta)
                    if wrote:
                        # leader also snapshots BOTH engine states (delta-
                        # chained) so a crashed stack restores rt AND bg
                        eng.save_snapshot(state_rt_ckpt)
                        bg_engine.save_snapshot(state_bg_ckpt)
                        print(f"[t={t}] leader replica {rid} persisted "
                              f"{len(backends[rid].suggestions)} suggestion "
                              f"rows (state snapshots: rt="
                              f"{state_rt_ckpt.last_save_kind}/"
                              f"{state_rt_ckpt.last_save_bytes}B, bg="
                              f"{state_bg_ckpt.last_save_kind}/"
                              f"{state_bg_ckpt.last_save_bytes}B)")
            if bg_res is not None:
                bg_ckpt.save(t, pack_suggestions(bg_engine.suggestions),
                             meta={"tick": t})

        # leader folds the sealed log into a base on cadence (bounded
        # on-disk bytes; replay-from-zero survives via the base)
        if compactor is not None and t > 0 \
                and t % args.compact_every == 0 \
                and rt_group.leader() is not None:
            writer.flush()          # seal the tail so the floor reaches t
            compactor.assume_epoch(rt_group.epoch)
            cst = compactor.compact()
            if not cst.get("noop"):
                print(f"[t={t}] compacted: floor={cst['floor']} "
                      f"dropped {cst['n_segments_dropped']} segments "
                      f"({cst['wall_s']:.2f}s)")

        # periodic spelling job (paper: a Pig job over a long span)
        if t > 0 and t % 60 == 0:
            leader = rt_group.leader()
            if leader is not None:
                exp = stores.export_live(backends[leader].state.qstore)
                fps = join_fp(exp["key_hi"], exp["key_lo"])
                texts = [tok.text(int(f)) for f in fps]
                corr = spelling_cycle(fps, texts, exp["weight"],
                                      SpellConfig(use_kernel=args.use_kernel))
                if corr:
                    a = np.array(list(corr.keys()), np.uint64)
                    b = np.array([v[0] for v in corr.values()], np.uint64)
                    d = np.array([v[1] for v in corr.values()], np.float64)
                    spell_ckpt.save(t, [a, b, d])
                    print(f"[t={t}] spelling job: {len(corr)} corrections")

        # frontends poll every tick (paper: every minute)
        for f in frontends:
            f.poll()

        if t % 12 == 0 and t >= head_t0:
            sugg = serverset.request(head, k=5)
            m = frontends[0].metrics()
            line = (f"[t={t}] related('{head}') = "
                    f"{[(s, round(sc, 3)) for s, sc in sugg]} "
                    f"(rt_lag={_fmt(m['rt_lag_ticks'])} "
                    f"bg_lag={_fmt(m['bg_lag_ticks'])}")
            if svc is not None:
                line += (f" | p50/p95/p99="
                         f"{_fmt(m['step_p50_ms'])}/"
                         f"{_fmt(m['step_p95_ms'])}/"
                         f"{_fmt(m['step_p99_ms'])}ms"
                         f" level={_fmt(m['shed_level_name'])}"
                         f" shed={_fmt(m['n_shed_total'])}"
                         f" [live: level={svc.overload.ladder.name}"
                         f" shed={svc.overload.stats_snapshot()['n_shed_total']}]")
            print(line + ")")

        if args.crash_at == t:
            # no drain: buffered-but-unflushed ticks are already in the
            # durable log, so --recover replays them (bit-exact mid-shed)
            print(f"[t={t}] CRASH (simulated): relaunch with --recover "
                  f"--out {args.out}")
            return

    if svc is not None:
        svc.drain()
        print(f"[done] overload stats: {svc.overload.stats_snapshot()}")
    writer.close()
    print("final suggestions for head query:",
          serverset.request(head, k=8))


if __name__ == "__main__":
    main()
