"""End-to-end search-assistance service launcher (paper Figure 4).

Runs the full deployed architecture on a synthetic stream: backend
engine(s) consuming the query hose + firehose, leader-elected persistence
every rank cycle, frontend replicas polling for fresh results, background
model + interpolation, and a periodic spelling job.

  python -m repro.launch.serve_assist --ticks 120 --out /tmp/assist
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from ..core.background import background_config
from ..core.engine import EngineConfig, SearchAssistanceEngine
from ..core.spelling import SpellConfig, spelling_cycle
from ..core import stores
from ..core.hashing import join_fp
from ..data.stream import StreamConfig, SyntheticStream, steve_jobs_scenario
from ..distributed.fault_tolerance import CheckpointManager, ReplicaGroup
from ..serving.serve import SuggestFrontend, ServerSet, pack_suggestions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--out", default="/tmp/assist")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--fail-replica-at", type=int, default=-1,
                    help="tick at which backend replica 0 dies (failover demo)")
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()

    scfg, event = steve_jobs_scenario(
        base_cfg=StreamConfig(vocab_size=2048, queries_per_tick=1024,
                              tweets_per_tick=128))
    stream = SyntheticStream(scfg, seed=0)
    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 14, decay_every=6,
                        rank_every=12, use_kernel=args.use_kernel)

    rt_dir = os.path.join(args.out, "rt")
    bg_dir = os.path.join(args.out, "bg")
    spell_dir = os.path.join(args.out, "spell")
    rt_group = ReplicaGroup(args.replicas, CheckpointManager(rt_dir))
    # replicated backends (paper: replicated, not sharded)
    backends = [SearchAssistanceEngine(ecfg, name=f"rt{i}")
                for i in range(args.replicas)]
    bg_engine = SearchAssistanceEngine(background_config(ecfg), name="bg")
    bg_ckpt = CheckpointManager(bg_dir)
    spell_ckpt = CheckpointManager(spell_dir)

    frontends = [SuggestFrontend(rt_dir, bg_dir, stream.tok, spell_dir=spell_dir)
                 for _ in range(2)]
    serverset = ServerSet(frontends)
    head = "steve jobs"

    for t in range(args.ticks):
        ev, tw = stream.gen_tick(t)
        if args.fail_replica_at == t:
            rt_group.fail(0)
            print(f"[t={t}] replica 0 FAILED; leader is now {rt_group.leader()}")
        results = []
        for rid, eng in enumerate(backends):
            if not rt_group.alive[rid]:
                continue
            results.append((rid, eng.step(ev, tw)))
        bg_res = bg_engine.step(ev, tw)

        for rid, res in results:
            if res is not None:   # a rank cycle ran -> leader persists
                eng = backends[rid]
                meta = {"tick": t, "layout": eng.cfg.cooc_layout}
                if eng.last_maintenance:   # freelist pressure -> frontends
                    meta["maintenance"] = eng.last_maintenance
                wrote = rt_group.persist(
                    rid, t, pack_suggestions(eng.suggestions), meta)
                if wrote:
                    print(f"[t={t}] leader replica {rid} persisted "
                          f"{len(backends[rid].suggestions)} suggestion rows")
        if bg_res is not None:
            bg_ckpt.save(t, pack_suggestions(bg_engine.suggestions))

        # periodic spelling job (paper: a Pig job over a long span)
        if t > 0 and t % 60 == 0:
            leader = rt_group.leader()
            if leader is not None:
                exp = stores.export_live(backends[leader].state.qstore)
                fps = join_fp(exp["key_hi"], exp["key_lo"])
                texts = [stream.tok.text(int(f)) for f in fps]
                corr = spelling_cycle(fps, texts, exp["weight"],
                                      SpellConfig(use_kernel=args.use_kernel))
                if corr:
                    a = np.array(list(corr.keys()), np.uint64)
                    b = np.array([v[0] for v in corr.values()], np.uint64)
                    d = np.array([v[1] for v in corr.values()], np.float64)
                    spell_ckpt.save(t, [a, b, d])
                    print(f"[t={t}] spelling job: {len(corr)} corrections")

        # frontends poll every tick (paper: every minute)
        for f in frontends:
            f.poll()

        if t % 12 == 0 and t >= event.t_start:
            sugg = serverset.request(head, k=5)
            print(f"[t={t}] related('{head}') = "
                  f"{[(s, round(sc, 3)) for s, sc in sugg]}")

    print("final suggestions for head query:",
          serverset.request(head, k=8))


if __name__ == "__main__":
    main()
