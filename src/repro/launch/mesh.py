"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets the 512-device XLA flag before any
jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
