"""End-to-end training launcher with checkpoint/restart fault tolerance.

  python -m repro.launch.train --arch h2o-danube-1.8b --smoke \\
      --steps 200 --ckpt-dir /tmp/run1

Any arch id from the registry works; --smoke swaps in the reduced config
(the full configs need a pod). Resumes automatically from the newest
checkpoint in --ckpt-dir; --simulate-preemption N kills the process state
at step N and restarts from the checkpoint to prove the restart path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, list_archs
from ..data.lm_data import LMDataConfig, SyntheticTokenStream
from ..distributed.fault_tolerance import CheckpointManager
from ..models import api, transformer as tr
from ..models.api import ShapeCell
from ..training import optimizer as optim
from ..training.train_loop import TrainConfig, init_train_state, make_train_step


def make_batch_fn(cfg, arch_family: str, batch_size: int, seq_len: int):
    if isinstance(cfg, tr.LMConfig):
        data = SyntheticTokenStream(LMDataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len, batch_size=batch_size))
        return lambda step: {"tokens": jnp.asarray(data.batch(step))}
    cell_kind = {"gnn": ShapeCell("t", "train", {"n_nodes": 256, "n_edges": 1024,
                                                 "d_feat": cfg.d_in if hasattr(cfg, "d_in") else 32,
                                                 "n_classes": getattr(cfg, "n_classes", 5)}),
                 "recsys": ShapeCell("t", "train", {"batch": batch_size})}[arch_family]

    def fn(step):
        rng = np.random.default_rng(step)
        return api.make_inputs(rng, cfg, cell_kind)["batch"]
    return fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--simulate-preemption", type=int, default=-1)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke_config if args.smoke else spec.config
    tcfg = TrainConfig(
        opt=optim.AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps,
                              master_weights=not args.smoke),
        grad_accum=args.grad_accum, compress_grads=args.compress_grads)

    params = api.init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)
    start = 0
    if ckpt.latest_step() is not None:
        (params, state), start = ckpt.restore((params, state))
        start += 1
        print(f"resumed from checkpoint at step {start - 1}")

    step_fn = jax.jit(make_train_step(api.loss_fn(cfg), tcfg))
    batch_fn = make_batch_fn(cfg, spec.family, args.batch * args.grad_accum,
                             args.seq)

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        if step == args.simulate_preemption:
            print(f"[step {step}] simulated preemption — restart to resume")
            return
        batch = batch_fn(step)
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if step > 0 and step % args.ckpt_every == 0:
            ckpt.save(step, (params, state), {"loss": losses[-1]})
    dt = time.time() - t0
    n = args.steps - start
    print(f"trained {n} steps in {dt:.1f}s ({1000 * dt / max(n, 1):.1f} ms/step); "
          f"loss {losses[0] if losses else float('nan'):.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
