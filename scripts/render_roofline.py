"""Render dry-run JSON results as the EXPERIMENTS.md roofline tables."""
import json
import sys


def render(path: str, mesh: str = "16x16") -> str:
    with open(path) as f:
        data = json.load(f)
    rows = [r for r in data if r.get("mesh") == mesh]
    out = ["| arch/shape | bound | frac | useful | tC (s) | tM (s) | tX (s) | peak GB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        name = f"{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            out.append(f"| {name} | — | — | — | — | — | — | skip |")
            continue
        if r["status"] != "ok":
            out.append(f"| {name} | ERROR | | | | | | |")
            continue
        mem = (r.get("memory_per_device") or {}).get("peak_bytes") or 0
        out.append(
            f"| {name} | {r['bottleneck']} | {r['roofline_fraction']:.3f} "
            f"| {r['useful_flops_ratio']:.3f} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| {mem / 1e9:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else "16x16"))
