#!/usr/bin/env bash
# CI entry point: tier-1 tests + a fast benchmark smoke pass.
#
#   scripts/ci.sh             # full tier-1 + engine_perf smoke (~2 min)
#   SKIP_BENCH=1 scripts/ci.sh  # tests only
#
# Exits nonzero on any test failure or benchmark error. The smoke bench
# also writes machine-readable rows to results/BENCH_engine.json so the
# perf trajectory is comparable across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m pytest -x -q

if [ "${SKIP_BENCH:-0}" != "1" ]; then
    mkdir -p results
    python -m benchmarks.run --json results/BENCH_engine.json engine_perf
    # ranking smoke: lexsort-vs-segmented + region-vs-segmented rows
    python -m benchmarks.run --json results/BENCH_ranking.json ranking
    # recovery smoke: crash -> restore -> catch-up replay must beat real time
    python -m benchmarks.run --json results/BENCH_recovery.json recovery
    # store smoke: region-vs-fused-vs-twopass insert rows (the PR 4 layout)
    python -m benchmarks.run --json results/BENCH_store.json store
    # overload smoke: 50x flash crowd -> spike throughput, ticks-to-SLO
    # recovery, shed fraction (the degradation-ladder contract)
    python -m benchmarks.run --json results/BENCH_overload.json overload
    # fleet chaos smoke: leader kill mid-segment + follower kill under a
    # 50x spike -- zero failed requests, epoch-fenced failover, healed log
    python -m benchmarks.run --json results/BENCH_fleet.json fleet
    # compaction smoke: 2000-tick run -- on-disk bytes bounded by the
    # working set (vs linear growth), base+tail replay bit-exact vs
    # replay-from-zero, fold pause p95
    python -m benchmarks.run --json results/BENCH_compaction.json compaction
    # autotune smoke: tuned-vs-untuned rows per hot path (tuned must be
    # >= 0.95x the best candidate) + the 16384-batch cliff (tuned chunking
    # must hold within 25% of the 4096 peak) -- both asserted in-bench
    python -m benchmarks.run --json results/BENCH_autotune.json autotune
    # roofline smoke: distance-to-roofline rows for the tuned hot paths
    # (roofline_hot:*; the dry-run cell rows need a separate dryrun pass)
    python -m benchmarks.run --json results/BENCH_roofline.json roofline
fi
