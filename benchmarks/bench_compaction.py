"""R-compaction (storage): bounded retention with replay-from-zero.

Drives a long flat firehose run (default 2000 ticks) through TWO durable
logs fed the identical tick stream:

  * **linear** — compressed segments, no compaction: on-disk bytes grow
    with uptime (what the paper's "replay from an earlier point in the
    hose" costs if the hose must be kept forever);
  * **compacted** — a ``LogCompactor`` folds the sealed prefix into base
    snapshots every ``compact_every`` ticks: retention swaps to
    ``[oldest retained base, head]`` and disk stays at the working-set
    size no matter how long the run.

Reported rows:

  * ``compaction_disk_linear``   — final on-disk bytes without compaction
    (and bytes/tick growth rate);
  * ``compaction_disk_bounded``  — final on-disk bytes with compaction,
    the bound vs the steady-state working set (asserted ≤ 2x), and the
    reduction vs the linear log;
  * ``compaction_lane_ratio``    — per-lane segment compression (where the
    XOR-delta fingerprint transform pays, via ``lane_compression_report``);
  * ``compaction_fold``          — compaction cycle cost: median wall,
    p95 pause (the stall a leader's tick loop absorbs), ticks folded;
  * ``compaction_time_to_fresh`` — crash -> serving-fresh wall from the
    newest base + tail vs replay-from-zero over the full linear log —
    bit-exactness of the two states is ASSERTED, so the row doubles as
    the correctness check for the whole tier.

  PYTHONPATH=src python -m benchmarks.bench_compaction --ticks 600
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time
from typing import List

import jax
import numpy as np

from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.streaming import (CatchUpController, CompactionConfig,
                             FirehoseLogReader, FirehoseLogWriter,
                             FirehoseWorkload, LogCompactor, ReplayConfig,
                             WorkloadConfig, restore_from_base)
from repro.streaming.codec import lane_compression_report
from .common import Row

COMPACT_EVERY = 250       # fold cadence, in ticks
TICKS_PER_SEGMENT = 50
KEEP_BASES = 2
CHUNK_TICKS = 25          # fused replay chunk size (fold + recovery)


def _ecfg() -> EngineConfig:
    return EngineConfig(query_capacity=1 << 11, cooc_capacity=1 << 13,
                        session_capacity=1 << 10, session_window=3,
                        decay_every=4, prune_every=6, rank_every=0,
                        region_width=16, decay=DecayConfig(policy="lazy"))


def _wl(seed: int) -> FirehoseWorkload:
    # flat, constant-shape traffic: segments seal exactly on tick count,
    # so the disk trajectory measures retention policy, not bucket churn
    return FirehoseWorkload(WorkloadConfig(
        vocab_per_lang=128, n_langs=3, n_users=500,
        base_queries_per_tick=48, base_tweets_per_tick=6,
        min_bucket=64, min_tweet_bucket=8), seed=seed)


def _dir_bytes(d: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(d):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(root, f))
            except OSError:
                pass
    return total


def _states_equal(a, b) -> bool:
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    return ta == tb and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def run(seed: int = 3, n_ticks: int = 2000) -> List[Row]:
    out = tempfile.mkdtemp(prefix="bench_compaction_")
    try:
        return _run(out, seed, max(n_ticks, 2 * COMPACT_EVERY))
    finally:
        shutil.rmtree(out, ignore_errors=True)


def _run(out: str, seed: int, n_ticks: int) -> List[Row]:
    ecfg = _ecfg()
    wl = _wl(seed)
    lin_dir = os.path.join(out, "linear")
    cmp_dir = os.path.join(out, "compacted")
    w_lin = FirehoseLogWriter(lin_dir, ticks_per_segment=TICKS_PER_SEGMENT)
    w_cmp = FirehoseLogWriter(cmp_dir, ticks_per_segment=TICKS_PER_SEGMENT)
    comp = LogCompactor(cmp_dir, {"rt": ecfg},
                        cfg=CompactionConfig(keep_bases=KEEP_BASES,
                                             chunk_ticks=CHUNK_TICKS))

    fold_wall: List[float] = []
    cmp_bytes_post: List[int] = []   # compacted-dir bytes after each fold
    lane_ticks: List[dict] = []      # one segment's worth, for the lane row
    for t in range(n_ticks):
        ev, tw = wl.gen_tick(t)
        if len(lane_ticks) < TICKS_PER_SEGMENT:
            lane_ticks.append({"sess_fp": np.asarray(ev.sess_fp),
                               "q_fp": np.asarray(ev.q_fp),
                               "grams": np.asarray(tw.grams),
                               "src": np.asarray(ev.src)})
        w_lin.append(t, ev, tw)
        w_cmp.append(t, ev, tw)
        if (t + 1) % COMPACT_EVERY == 0:
            t0 = time.perf_counter()
            stats = comp.compact()
            fold_wall.append(time.perf_counter() - t0)
            assert not stats["noop"], stats
            cmp_bytes_post.append(_dir_bytes(cmp_dir))
    w_lin.close()
    w_cmp.close()

    lin_bytes = _dir_bytes(lin_dir)
    cmp_bytes = _dir_bytes(cmp_dir)
    # the steady-state working set: bases + the retained log tail right
    # after a fold, once the base chain is warm (the first fold's sample
    # has a single base and an empty tail — not steady state yet). The
    # compacted log must stay within ~2x of it forever: it peaks just
    # BEFORE the next fold, when compact_every more ticks of segments
    # have accumulated on top.
    working_set = max(cmp_bytes_post[1:])
    assert cmp_bytes <= 2.0 * working_set, \
        f"compacted log unbounded: {cmp_bytes} > 2x {working_set}"
    # the win over linear growth scales with uptime: two retained base
    # snapshots are a fixed cost, so only at the acceptance scale must
    # the compacted log be strictly smaller than the linear one
    if n_ticks >= 2000:
        assert cmp_bytes < lin_bytes / 2, (cmp_bytes, lin_bytes)

    # ---- time-to-fresh: newest base + tail vs replay-from-zero ----
    t0 = time.perf_counter()
    eng_base = SearchAssistanceEngine(ecfg, "rt")
    state, base_tick, _info = restore_from_base(cmp_dir, "rt",
                                                eng_base.state)
    eng_base.state = state
    r_cmp = FirehoseLogReader(cmp_dir)
    CatchUpController(eng_base, r_cmp,
                      ReplayConfig(chunk_ticks=CHUNK_TICKS)).catch_up(
        refresh=False)
    fresh_base_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    eng_zero = SearchAssistanceEngine(ecfg, "rt")
    r_lin = FirehoseLogReader(lin_dir)
    CatchUpController(eng_zero, r_lin,
                      ReplayConfig(chunk_ticks=CHUNK_TICKS)).catch_up(
        refresh=False)
    fresh_zero_s = time.perf_counter() - t0

    # replay-from-zero THROUGH the compacted+compressed tier is bit-exact
    # with replaying every tick of the uncompacted log from scratch
    assert int(eng_base.state.tick) == int(eng_zero.state.tick) == n_ticks
    assert _states_equal(eng_base.state, eng_zero.state), \
        "base+tail replay diverged from replay-from-zero"

    # lane ratios over a SEGMENT's worth of ticks — what actually lands
    # on disk; a single tick is too small for the container to pay
    lane_payload = {k: np.concatenate([d[k].reshape(-1) for d in lane_ticks])
                    for k in lane_ticks[0]}
    lane_rep = lane_compression_report(lane_payload)
    lane_txt = " ".join(
        f"{k}:{lane_rep[k]['ratio']:.1f}x"
        for k in ("sess_fp", "q_fp", "grams", "src") if k in lane_rep)
    fold_wall.sort()
    fold_p50 = fold_wall[len(fold_wall) // 2]
    fold_p95 = fold_wall[min(len(fold_wall) - 1,
                             int(len(fold_wall) * 0.95))]
    n_folds = comp.n_compactions
    tail_ticks = n_ticks - base_tick

    return [
        ("compaction_disk_linear", 0.0,
         f"{n_ticks} ticks uncompacted: {lin_bytes / 1e6:.2f} MB on disk "
         f"({lin_bytes / n_ticks:.0f} B/tick, grows with uptime)"),
        ("compaction_disk_bounded", 0.0,
         f"{n_ticks} ticks compacted every {COMPACT_EVERY}: "
         f"{cmp_bytes / 1e6:.2f} MB on disk = "
         f"{cmp_bytes / max(working_set, 1):.2f}x steady-state working set "
         f"({working_set / 1e6:.2f} MB), {lin_bytes / cmp_bytes:.1f}x "
         f"smaller than linear; {KEEP_BASES} bases retained"),
        ("compaction_lane_ratio", 0.0,
         f"segment compression per lane ({lane_txt}); fp lanes ride the "
         f"XOR-delta transform"),
        ("compaction_fold", fold_p50 * 1e6,
         f"{n_folds} folds of {COMPACT_EVERY} ticks: p50 "
         f"{fold_p50 * 1e3:.0f} ms, p95 pause {fold_p95 * 1e3:.0f} ms "
         f"(the leader tick that compacts absorbs this)"),
        ("compaction_time_to_fresh", fresh_base_s * 1e6,
         f"crash->fresh from base {base_tick} + {tail_ticks}-tick tail: "
         f"{fresh_base_s:.2f} s vs {fresh_zero_s:.2f} s replay-from-zero "
         f"({n_ticks} ticks, {fresh_zero_s / max(fresh_base_s, 1e-9):.1f}x"
         f" slower); states bit-exact"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=3,
                    help="workload seed")
    ap.add_argument("--ticks", type=int, default=2000,
                    help=f"run length in ticks (min {2 * COMPACT_EVERY})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(seed=args.seed, n_ticks=args.ticks):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
