"""C6 (§4.5): pairwise edit-distance job throughput (kernel vs oracle) and
correction quality on planted misspellings."""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.spelling import SpellConfig, encode_strings, spelling_cycle
from repro.data.stream import StreamConfig, SyntheticStream
from repro.kernels import ops, ref
from .common import Row, time_fn


def run() -> List[Row]:
    rng = np.random.default_rng(0)
    words = ["".join(chr(97 + c) for c in rng.integers(0, 26, rng.integers(5, 15)))
             for _ in range(256)]
    a_idx = rng.integers(0, 256, 4096)
    b_idx = rng.integers(0, 256, 4096)
    chars, lens = encode_strings(words, 16)
    ac, al = jnp.asarray(chars[a_idx]), jnp.asarray(lens[a_idx])
    bc, bl = jnp.asarray(chars[b_idx]), jnp.asarray(lens[b_idx])

    t_k = time_fn(lambda: ops.edit_distance(ac, al, bc, bl, use_kernel=True))
    t_r = time_fn(lambda: ops.edit_distance(ac, al, bc, bl, use_kernel=False))
    rows = [
        ("edit_distance_pallas_4096", t_k,
         f"{4096 / (t_k / 1e6):,.0f} pairs/s (interpret mode)"),
        ("edit_distance_ref_4096", t_r,
         f"{4096 / (t_r / 1e6):,.0f} pairs/s"),
    ]

    # correction quality on the stream's planted misspellings
    s = SyntheticStream(StreamConfig(vocab_size=512, n_misspell_targets=48),
                        seed=2)
    fps, texts, weights = [], [], []
    for i, q in enumerate(s.vocab):
        fps.append(int(s.fps[i]))
        texts.append(q)
        # head gets high weight; misspell variants low
        weights.append(2.0 if i in s.misspell_of else 500.0 / (1 + i))
    out = spelling_cycle(np.asarray(fps, np.uint64), texts,
                         np.asarray(weights), SpellConfig())
    hits = sum(1 for vi, ti in s.misspell_of.items()
               if out.get(int(s.fps[vi]), (None,))[0] == int(s.fps[ti]))
    rows.append(("spelling_recall", 0.0,
                 f"{hits}/{len(s.misspell_of)} planted misspellings corrected"))
    return rows
