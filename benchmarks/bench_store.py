"""Cooccurrence-store insert cost under the three probe strategies (§4.2
"stats collector" hot path): the source-major **region** layout vs the
fused find-or-claim **hash** path (PR 1) vs the pre-fusion **twopass**
reference — plus the region layout's state-size advantage.

Two workloads per capacity, both at the engine's steady-state batch size:

  * ``accum``  — every key already present (the accumulate-heavy steady
    state): the hash path pays its probe rounds of random [C] gathers, the
    region path ONE chain-depth round of contiguous W-wide tile gathers
    (plus the qstore src lookup that names the region).
  * ``fresh``  — every key new (a breaking-news burst): the hash path runs
    claim rounds with per-round conflict sorts; the region path computes
    append positions from fill counters with a single rank sort.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import stores
from repro.core.hashing import combine_fp_np, split_fp
from .common import Row, time_fn

Q_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))
C_MODES = Q_MODES + (("src_hi", "set"), ("src_lo", "set"),
                     ("dst_hi", "set"), ("dst_lo", "set"))
R_MODES = Q_MODES

# region geometry per cooc capacity: width grows with the expected pairs
# per source so chains stay shallow (128 would be the TPU-tiled choice).
WIDTHS = {16: 16, 18: 32, 20: 64}


def build_stores(logc: int, n_queries: int = 4096, seed: int = 0,
                 chain: int = 8):
    """qstore + hash cooc + region cooc filled with the same ~25%-load pair
    population (mirrors bench_ranking's setup)."""
    cap = 1 << logc
    n_pairs = cap // 4
    rng = np.random.default_rng(seed)
    q = stores.make_table(max(n_queries * 4, 1024), {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})
    qf = (rng.integers(1, 2**63, n_queries).astype(np.uint64)) | 1
    qh, ql = split_fp(qf)
    q = stores.insert_accumulate(
        q, jnp.asarray(qh), jnp.asarray(ql),
        {"weight": jnp.asarray(rng.random(n_queries, np.float32) * 50 + 1),
         "count": jnp.asarray(
             np.floor(rng.random(n_queries) * 100 + 1).astype(np.float32)),
         "last_tick": jnp.zeros(n_queries, jnp.int32)},
        jnp.ones(n_queries, bool), modes=Q_MODES)

    a = qf[rng.integers(0, n_queries, n_pairs)]
    b = qf[rng.integers(0, n_queries, n_pairs)]
    ah, al = split_fp(a)
    bh, bl = split_fp(b)
    ph, pl = combine_fp_np(ah, al, bh, bl)
    pw = (rng.random(n_pairs, np.float32) * 5 + 0.5)
    pc = np.floor(rng.random(n_pairs) * 20 + 1).astype(np.float32)

    hash_updates = {
        "weight": jnp.asarray(pw), "count": jnp.asarray(pc),
        "last_tick": jnp.zeros(n_pairs, jnp.int32),
        "src_hi": jnp.asarray(ah), "src_lo": jnp.asarray(al),
        "dst_hi": jnp.asarray(bh), "dst_lo": jnp.asarray(bl)}
    c = stores.make_table(cap, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
        "src_hi": jnp.uint32, "src_lo": jnp.uint32,
        "dst_hi": jnp.uint32, "dst_lo": jnp.uint32})
    c = stores.insert_accumulate(
        c, jnp.asarray(ph), jnp.asarray(pl), hash_updates,
        jnp.ones(n_pairs, bool), modes=C_MODES)

    rt = stores.make_region_table(cap, WIDTHS[logc], q.capacity, chain, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})
    rt = stores.region_insert_accumulate(
        rt, q, jnp.asarray(ah), jnp.asarray(al), jnp.asarray(bh),
        jnp.asarray(bl),
        {"weight": jnp.asarray(pw), "count": jnp.asarray(pc),
         "last_tick": jnp.zeros(n_pairs, jnp.int32)},
        jnp.ones(n_pairs, bool), modes=R_MODES)
    return q, c, rt, (qf, ah, al, bh, bl, ph, pl)


def _batch(rng, qf, B, fresh: bool):
    """B pair events; ``fresh`` draws dsts outside the seeded population."""
    n_queries = qf.shape[0]
    a = qf[rng.integers(0, n_queries, B)]
    if fresh:
        b = (rng.integers(1, 2**63, B).astype(np.uint64)) | 1
    else:
        b = qf[rng.integers(0, n_queries, B)]
    ah, al = split_fp(a)
    bh, bl = split_fp(b)
    ph, pl = combine_fp_np(ah, al, bh, bl)
    return (jnp.asarray(ah), jnp.asarray(al), jnp.asarray(bh),
            jnp.asarray(bl), jnp.asarray(ph), jnp.asarray(pl))


def run() -> List[Row]:
    rows: List[Row] = []
    B = 8192
    for logc in (16, 18):
        cap = 1 << logc
        q, c, rt, (qf, *_rest) = build_stores(logc, seed=logc)
        rng = np.random.default_rng(logc + 99)
        for mode in ("accum", "fresh"):
            ah, al, bh, bl, ph, pl = _batch(rng, qf, B, mode == "fresh")
            valid = jnp.ones(B, bool)
            w = jnp.asarray(rng.random(B, np.float32) + 0.5)
            cnt = jnp.ones(B, jnp.float32)
            lt = jnp.zeros(B, jnp.int32)
            hash_upd = {"weight": w, "count": cnt, "last_tick": lt,
                        "src_hi": ah, "src_lo": al,
                        "dst_hi": bh, "dst_lo": bl}
            reg_upd = {"weight": w, "count": cnt, "last_tick": lt}
            t_two = time_fn(lambda: stores.insert_accumulate_twopass(
                c, ph, pl, hash_upd, valid, modes=C_MODES))
            t_fused = time_fn(lambda: stores.insert_accumulate(
                c, ph, pl, hash_upd, valid, modes=C_MODES))
            t_reg = time_fn(lambda: stores.region_insert_accumulate(
                rt, q, ah, al, bh, bl, reg_upd, valid, modes=R_MODES))
            rows.append((f"insert_twopass_{mode}_c2e{logc}", t_two,
                         f"B={B} pre-fusion reference"))
            rows.append((f"insert_fused_{mode}_c2e{logc}", t_fused,
                         f"B={B} fused find-or-claim; "
                         f"x{t_two / max(t_fused, 1e-9):.2f} vs twopass"))
            rows.append((f"insert_region_{mode}_c2e{logc}", t_reg,
                         f"B={B} region layout (W={WIDTHS[logc]}); "
                         f"x{t_fused / max(t_reg, 1e-9):.2f} vs fused"))
        # state-size row: bytes per slot (keys + lanes + metadata)
        hash_b = sum(np.asarray(x).nbytes for x in
                     [c.key_hi, c.key_lo, *c.lanes.values()])
        reg_b = sum(np.asarray(x).nbytes for x in
                    [rt.key_hi, rt.key_lo, *rt.lanes.values(),
                     rt.chain_region, rt.chain_hi, rt.chain_lo,
                     rt.region_fill, rt.region_owner])
        rows.append((f"state_bytes_c2e{logc}", float(reg_b),
                     f"region {reg_b / cap:.1f} B/slot vs hash "
                     f"{hash_b / cap:.1f} B/slot "
                     f"(x{hash_b / reg_b:.2f} smaller)"))
    return rows
