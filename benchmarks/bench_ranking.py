"""C4 (§4.3 "Ranking cycles"): full ranking-cycle cost vs store size, the
sort-free segmented top-k vs the lexsort reference pipeline, and the fused
score/gate kernel vs the jnp path."""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import ranking, stores
from repro.core.hashing import split_fp
from repro.core.ranking import RankConfig
from .common import Row, time_fn


def _filled_stores(n_pairs: int, n_queries: int, seed=0, cooc_capacity=None):
    rng = np.random.default_rng(seed)
    q = stores.make_table(max(n_queries * 4, 1024), {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})
    qf = (rng.integers(1, 2**63, n_queries).astype(np.uint64)) | 1
    qh, ql = split_fp(qf)
    q = stores.insert_accumulate(
        q, jnp.asarray(qh), jnp.asarray(ql),
        {"weight": jnp.asarray(rng.random(n_queries, np.float32) * 50 + 1),
         "count": jnp.asarray(np.floor(rng.random(n_queries) * 100 + 1).astype(np.float32)),
         "last_tick": jnp.zeros(n_queries, jnp.int32)},
        jnp.ones(n_queries, bool),
        modes=(("weight", "add"), ("count", "add"), ("last_tick", "set")))
    c = stores.make_table(cooc_capacity or max(n_pairs * 4, 1024), {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
        "src_hi": jnp.uint32, "src_lo": jnp.uint32,
        "dst_hi": jnp.uint32, "dst_lo": jnp.uint32})
    a = qf[rng.integers(0, n_queries, n_pairs)]
    b = qf[rng.integers(0, n_queries, n_pairs)]
    from repro.core.hashing import combine_fp_np
    ah, al = split_fp(a)
    bh, bl = split_fp(b)
    ph, pl = combine_fp_np(ah, al, bh, bl)
    c = stores.insert_accumulate(
        c, jnp.asarray(ph), jnp.asarray(pl),
        {"weight": jnp.asarray(rng.random(n_pairs, np.float32) * 5 + 0.5),
         "count": jnp.asarray(np.floor(rng.random(n_pairs) * 20 + 1).astype(np.float32)),
         "last_tick": jnp.zeros(n_pairs, jnp.int32),
         "src_hi": jnp.asarray(ah), "src_lo": jnp.asarray(al),
         "dst_hi": jnp.asarray(bh), "dst_lo": jnp.asarray(bl)},
        jnp.ones(n_pairs, bool),
        modes=(("weight", "add"), ("count", "add"), ("last_tick", "set"),
               ("src_hi", "set"), ("src_lo", "set"),
               ("dst_hi", "set"), ("dst_lo", "set")))
    return q, c


def run() -> List[Row]:
    rows: List[Row] = []
    for n_pairs in (8192, 65536):
        q, c = _filled_stores(n_pairs, 2048)
        cfg = RankConfig()
        t = time_fn(lambda: ranking.ranking_cycle(c, q, cfg))
        rows.append((f"ranking_cycle_{n_pairs}p", t,
                     f"{n_pairs / (t / 1e6):,.0f} pairs/s"))
        cfg_k = dataclasses.replace(cfg, use_kernel=True)
        t_k = time_fn(lambda: ranking.ranking_cycle(c, q, cfg_k))
        rows.append((f"ranking_cycle_{n_pairs}p_pallas", t_k,
                     f"fused score/gate; x{t / max(t_k, 1e-9):.2f}"))
    rows += _bench_lexsort_vs_segmented()
    rows += _bench_region_vs_segmented()
    return rows


def _bench_region_vs_segmented() -> List[Row]:
    """The pure-reshape claim: the region layout's ranking cycle vs the
    segmented top-k over the SAME pair population (same capacities/load as
    `_bench_lexsort_vs_segmented`) — no compaction scatter, no grouping
    sort, no per-pair source lookups."""
    from .bench_store import build_stores, WIDTHS
    rows: List[Row] = []
    cfg = RankConfig()
    for logc in (16, 18, 20):
        q, c, rt, _ = build_stores(logc, seed=logc)
        iters = 3 if logc >= 20 else 5
        t_seg = time_fn(lambda: ranking.ranking_cycle(c, q, cfg),
                        iters=iters)
        t_reg = time_fn(lambda: ranking.ranking_cycle_region(rt, q, cfg),
                        iters=iters)
        rows.append((f"rank_region_c2e{logc}", t_reg,
                     f"region grid (W={WIDTHS[logc]}, pure reshape); "
                     f"x{t_seg / max(t_reg, 1e-9):.2f} vs segtopk"))
    return rows


def _bench_lexsort_vs_segmented() -> List[Row]:
    """The sort-free claim: segmented top-k vs the lexsort reference at
    fixed cooccurrence capacities with <= 25% live rows (the paper's
    steady-state load under the <= 50% prune policy)."""
    rows: List[Row] = []
    for logc in (16, 18, 20):
        cap = 1 << logc
        q, c = _filled_stores(cap // 4, 4096, seed=logc, cooc_capacity=cap)
        cfg = RankConfig()
        iters = 3 if logc >= 20 else 5
        t_lex = time_fn(lambda: ranking.ranking_cycle_lexsort(c, q, cfg),
                        iters=iters)
        t_seg = time_fn(lambda: ranking.ranking_cycle(c, q, cfg),
                        iters=iters)
        live_pct = 100.0 * int(c.live_count()) / cap
        rows.append((f"rank_lexsort_c2e{logc}", t_lex,
                     f"argsort+3-key lexsort, {live_pct:.0f}% live"))
        rows.append((f"rank_segtopk_c2e{logc}", t_seg,
                     f"segmented top-k (flat-key grouping); "
                     f"x{t_lex / max(t_seg, 1e-9):.2f} vs lexsort"))
    return rows
