"""C4 (§4.2-4.4): stats-collector ingest throughput.

The paper's bottleneck analysis: "each instance must consume the entire
firehose and query hose ... CPU is not a limiting resource". We measure
device events/sec for the query path and tweet path at the production
micro-batch size, plus the decay/prune cycle (fused Pallas vs 3-pass jnp).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, SearchAssistanceEngine, \
    ingest_queries, ingest_tweets, decay_cycle, init_state
from repro.core.hashing import split_fp
from repro.data.stream import StreamConfig, SyntheticStream
from .common import Row, time_fn


def run() -> List[Row]:
    cfg = EngineConfig(query_capacity=1 << 15, cooc_capacity=1 << 17,
                       session_capacity=1 << 14)
    scfg = StreamConfig(vocab_size=4096, queries_per_tick=4096,
                        tweets_per_tick=256, tweet_grams=8)
    stream = SyntheticStream(scfg, seed=0)
    state = init_state(cfg)
    ev, tw = stream.gen_tick(0)
    s_hi, s_lo = split_fp(ev.sess_fp)
    q_hi, q_lo = split_fp(ev.q_fp)
    g_hi, g_lo = split_fp(tw.grams)
    args_q = (jnp.asarray(s_hi), jnp.asarray(s_lo), jnp.asarray(q_hi),
              jnp.asarray(q_lo), jnp.asarray(ev.src, jnp.int32),
              jnp.asarray(ev.valid))
    # warm the state so tables aren't empty
    for t in range(3):
        e2, t2 = stream.gen_tick(t + 1)
        sh, sl = split_fp(e2.sess_fp)
        qh, ql = split_fp(e2.q_fp)
        state = ingest_queries(state, jnp.asarray(sh), jnp.asarray(sl),
                               jnp.asarray(qh), jnp.asarray(ql),
                               jnp.asarray(e2.src, jnp.int32),
                               jnp.asarray(e2.valid), cfg=cfg)

    t_q = time_fn(lambda s: ingest_queries(s, *args_q, cfg=cfg), state)
    t_t = time_fn(lambda s: ingest_tweets(s, jnp.asarray(g_hi),
                                          jnp.asarray(g_lo),
                                          jnp.asarray(tw.valid), cfg=cfg), state)
    t_d_jnp = time_fn(lambda s: decay_cycle(s, jnp.int32(6), cfg=cfg)[0], state)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    t_d_ker = time_fn(lambda s: decay_cycle(s, jnp.int32(6), cfg=cfg_k)[0], state)

    B, T = scfg.queries_per_tick, scfg.tweets_per_tick
    return [
        ("ingest_query_path", t_q, f"{B / (t_q / 1e6):,.0f} events/s/device"),
        ("ingest_tweet_path", t_t, f"{T / (t_t / 1e6):,.0f} tweets/s/device"),
        ("decay_prune_jnp", t_d_jnp, "3-pass jnp sweep"),
        ("decay_prune_pallas", t_d_ker,
         f"fused kernel (interpret); speedup x{t_d_jnp / max(t_d_ker, 1e-9):.2f}"),
    ]
