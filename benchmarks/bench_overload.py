"""R-overload (§1/§4): survive a 50x flash crowd within the step-latency SLO.

The paper's motivating scenario end to end: a calm Zipf firehose, then a
breaking-news spike that multiplies query volume ~50x within a few ticks
(``streaming/workload.py``), driven through the overload-controlled
serving stack (``streaming/overload.py``). Simulated arrival pacing turns
slow processing into lag, which the controller must absorb by fusing ticks
into ``ingest_many`` micro-batches and climbing the degradation ladder
(shed rt ranking -> stretch bg ranking -> admission-control ingest).

Reported rows:

  * ``overload_calm_step``        — per-tick step cost before the spike;
  * ``overload_spike_throughput`` — ingest rate through the spike window
    (events/s, with the peak per-tick cost);
  * ``overload_slo_recovery``     — ticks from the spike's plateau end
    until the ladder is back at level 0 with the SLO met (the
    "degrades gracefully, recovers to SLO within N ticks" property);
  * ``overload_shed_fraction``    — fraction of offered events shed over
    the whole run (every one of them counted, never silent);
  * ``overload_lag_bound``        — max/final lag in ticks (no unbounded
    growth under the spike).

A shape-enumeration warm pass (raw and level-3-admitted bucket shapes, at
K=1 and K=batch_max) compiles every dispatch the measured pass can hit
before pacing starts, so jit compiles never masquerade as lag.
"""
from __future__ import annotations

import time
from typing import List

from repro.core.background import AssistanceService
from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig
from repro.streaming import (FirehoseWorkload, SLOConfig, SpikeSpec,
                             WorkloadConfig, admit_events, admit_tweets)
from .common import Row

N_TICKS = 56
SPIKE_AT = 10
SPIKE = SpikeSpec(t_start=SPIKE_AT, mult=50.0, ramp_ticks=2.0,
                  plateau_ticks=6.0, decay_ticks=4.0)
PLATEAU_END = SPIKE_AT + 6


def _wl() -> FirehoseWorkload:
    return FirehoseWorkload(WorkloadConfig(
        base_queries_per_tick=512, base_tweets_per_tick=32,
        min_bucket=512, min_tweet_bucket=32, spikes=(SPIKE,)), seed=17)


def _ecfg() -> EngineConfig:
    return EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 13, rank_every=8,
                        decay=DecayConfig(policy="lazy"))


def _warm_shapes(slo: SLOConfig, batches) -> None:
    """Compile every (K, bucket-shape) dispatch the paced run can hit.

    The controller's shapes are data-dependent (adaptive K, level-3
    compaction), so a paced throwaway run can follow a different
    trajectory than the measured one and leave shapes cold — a single
    jit compile then reads as several ticks of "lag". Enumerate instead:
    each distinct raw tick shape and each distinct level-3 admitted
    shape, dispatched as a K=1 flush and a K=batch_max chunk."""
    svc = AssistanceService(_ecfg(), slo=slo)
    for level in (0, 3):
        svc.overload.ladder.force(level)
        seen = set()
        for ev, tw in batches:
            aev, _ = admit_events(ev, level, slo)
            atw, _ = admit_tweets(tw, level, slo)
            key = (aev.q_fp.shape[0],
                   None if atw is None else atw.grams.shape)
            if key in seen:
                continue
            seen.add(key)
            for lag in (0.0, 2.0 * slo.lag_batch * slo.batch_max):
                for _ in range(slo.batch_max):
                    svc.step(ev, tw, lag_hint=lag)
                svc.drain()
    svc.overload.ladder.force(None)


def _run_paced(slo: SLOConfig, tick_ms: float, batches) -> dict:
    """Drive the controlled service under simulated real-time arrivals:
    tick t arrives at t*tick_ms of wall time; processing slower than that
    accrues lag the controller must work off."""
    svc = AssistanceService(_ecfg(), slo=slo)
    lag_hist, level_hist, tick_ms_hist = [], [], []
    wall0 = time.perf_counter()
    for t, (ev, tw) in enumerate(batches):
        arrived = (time.perf_counter() - wall0) * 1e3 / tick_ms
        lag = max(0.0, arrived - t)
        t0 = time.perf_counter()
        svc.step(ev, tw, lag_hint=lag)
        tick_ms_hist.append((time.perf_counter() - t0) * 1e3)
        lag_hist.append(lag)
        level_hist.append(svc.overload.ladder.level)
    svc.drain()
    return {"svc": svc, "lag": lag_hist, "level": level_hist,
            "tick_ms": tick_ms_hist,
            "stats": svc.overload.stats_snapshot()}


def run() -> List[Row]:
    wl = _wl()
    batches = [wl.gen_tick(t) for t in range(N_TICKS)]
    n_offered = sum(int(ev.valid.sum()) for ev, _ in batches)

    # calibration + warm pass: un-paced (zero lag -> K=1, level 0 states
    # stay reachable), then a paced throwaway to warm the batched shapes
    warm = AssistanceService(_ecfg(), slo=SLOConfig(slo_ms=1e9))
    calm_ms = []
    for t, (ev, tw) in enumerate(batches):
        t0 = time.perf_counter()
        warm.step(ev, tw)
        if t < SPIKE_AT:
            calm_ms.append((time.perf_counter() - t0) * 1e3)
    warm.drain()
    calm_ms.sort()
    calm_med = calm_ms[len(calm_ms) // 2]

    # the SLO bench contract: a per-tick budget a calm tick easily meets
    # and a 50x spike tick cannot — the ladder has to earn the difference.
    # The profile is deliberately aggressive: escalate on the first hot
    # tick (a 50x tick burns ~6 budgets, so every unshedded one matters),
    # at level 3 hash-sample the WHOLE hose (tail_src=0) down to 8% —
    # which brings a plateau tick back under the tick budget — and score
    # p95 over a short rolling window so the spike's heavy ticks age out
    # and the ladder can actually cool down afterwards.
    tick_ms = max(8.0 * calm_med, 1.0)
    slo = SLOConfig(slo_ms=3.0 * tick_ms, latency_window=16,
                    batch_max=2, lag_batch=1.0,
                    up_lag=2.0, down_lag=1.0, up_ticks=1, down_ticks=2,
                    tail_src=0, tail_keep=0.08, compact_min=1024)
    _warm_shapes(slo, batches)                 # warm the (K, bucket) pairs
    r = _run_paced(slo, tick_ms, batches)      # measured

    stats = r["stats"]
    spike_ms = r["tick_ms"][SPIKE_AT:PLATEAU_END]
    spike_ev = sum(int(ev.valid.sum())
                   for ev, _ in batches[SPIKE_AT:PLATEAU_END])
    spike_s = sum(spike_ms) / 1e3
    # SLO recovery: first tick past the plateau at level 0 with its lag gone
    rec = next((t for t in range(PLATEAU_END, N_TICKS)
                if r["level"][t] == 0 and r["lag"][t] <= slo.down_lag),
               None)
    ticks_to_slo = -1 if rec is None else rec - PLATEAU_END
    shed_frac = stats["n_shed_events"] / max(n_offered, 1)
    max_lag, final_lag = max(r["lag"]), r["lag"][-1]

    return [
        ("overload_calm_step", calm_med * 1e3,
         f"tick_budget={tick_ms:.1f}ms slo_p95={slo.slo_ms:.1f}ms"),
        ("overload_spike_throughput", spike_s * 1e6 / max(spike_ev, 1),
         f"{spike_ev / max(spike_s, 1e-9):.0f} ev/s through a "
         f"{SPIKE.mult:.0f}x spike; peak_tick={max(spike_ms):.1f}ms"),
        ("overload_slo_recovery", max(ticks_to_slo, 0) * tick_ms * 1e3,
         f"ticks_to_slo={ticks_to_slo} max_level="
         f"{max(r['level'])} esc={stats['n_escalations']} "
         f"deesc={stats['n_deescalations']}"),
        ("overload_shed_fraction", stats["step_p95_ms"] * 1e3
         if stats["step_p95_ms"] else 0.0,
         f"shed={shed_frac:.3f} of {n_offered} offered ev "
         f"(+{stats['n_shed_tweets']} tweets, "
         f"{stats['n_shed_rank_rt'] + stats['n_shed_rank_bg']} ranks); "
         f"flushes={stats['n_flushes']}/{N_TICKS} ticks"),
        ("overload_lag_bound", final_lag * tick_ms * 1e3,
         f"max_lag={max_lag:.1f} final_lag={final_lag:.1f} ticks "
         f"(bounded: {'yes' if final_lag <= max(2.0, max_lag / 2) else 'NO'})"),
    ]
