"""§Perf — engine hillclimb iterations (the paper's own technique).

Each entry is one hypothesis -> change -> measure cycle on the stats
collector (see EXPERIMENTS.md §Perf for the narrative):

  P1 probe_rounds 16 -> 8   (hash probe gathers dominate the update pass)
  P2 micro-batch size sweep (amortize fixed dispatch/sort overheads)
  P3 session window 5 -> 3  (pair volume ~ W; quality/coverage tradeoff)
  P4 fused find-or-claim    (before/after: two-pass probe + [C] scatter-max
                             claim race vs single-sweep probe with
                             batch-local claim resolution + early exit)
  P5 ranking selection      (before/after: lexsort reference pipeline —
                             with/without argsort compaction — vs the
                             segmented top-k fast path)
  P6 decay policy           (before/after: eager full sweeps every
                             decay_every ticks vs lazy read-time decay with
                             prune-only sweeps at prune_every)
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stores
from repro.core.engine import EngineConfig, init_state, ingest_queries
from repro.core.hashing import split_fp
from repro.data.stream import StreamConfig, SyntheticStream
from .common import Row, time_fn


def _measure(ecfg: EngineConfig, batch: int, seed=0) -> float:
    stream = SyntheticStream(StreamConfig(vocab_size=4096,
                                          queries_per_tick=batch,
                                          tweets_per_tick=0), seed=seed)
    state = init_state(ecfg)
    for t in range(3):   # warm the tables
        ev, _ = stream.gen_tick(t)
        sh, sl = split_fp(ev.sess_fp)
        qh, ql = split_fp(ev.q_fp)
        state = ingest_queries(state, jnp.asarray(sh), jnp.asarray(sl),
                               jnp.asarray(qh), jnp.asarray(ql),
                               jnp.asarray(ev.src, jnp.int32),
                               jnp.asarray(ev.valid), cfg=ecfg)
    ev, _ = stream.gen_tick(5)
    sh, sl = split_fp(ev.sess_fp)
    qh, ql = split_fp(ev.q_fp)
    args = (jnp.asarray(sh), jnp.asarray(sl), jnp.asarray(qh),
            jnp.asarray(ql), jnp.asarray(ev.src, jnp.int32),
            jnp.asarray(ev.valid))
    return time_fn(lambda s: ingest_queries(s, *args, cfg=ecfg), state)


def run() -> List[Row]:
    rows: List[Row] = []
    base = EngineConfig(query_capacity=1 << 15, cooc_capacity=1 << 17,
                        session_capacity=1 << 14)

    # P1: probe rounds
    t16 = _measure(base, 4096)
    t8 = _measure(dataclasses.replace(base, probe_rounds=8), 4096)
    st8 = init_state(dataclasses.replace(base, probe_rounds=8))
    rows.append(("perf_P1_probe16", t16, f"{4096/(t16/1e6):,.0f} ev/s baseline"))
    rows.append(("perf_P1_probe8", t8,
                 f"{4096/(t8/1e6):,.0f} ev/s; x{t16/max(t8,1e-9):.2f} "
                 f"(drops must stay 0 at <=50% load)"))

    # P2: micro-batch size (fixed total events)
    for b in (1024, 4096, 16384):
        t = _measure(base, b)
        rows.append((f"perf_P2_batch{b}", t,
                     f"{b/(t/1e6):,.0f} ev/s ({t/b:.1f} us/event)"))

    # P3: session window
    for w in (5, 3):
        t = _measure(dataclasses.replace(base, session_window=w), 4096)
        rows.append((f"perf_P3_window{w}", t,
                     f"{4096/(t/1e6):,.0f} ev/s (pairs/event ~ {w})"))

    rows += _bench_insert_paths()
    rows += _bench_ranking_compaction()
    rows += _bench_decay_policies()
    return rows


_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))


def _bench_insert_paths() -> List[Row]:
    """P4: the store-insert hot path, before (two-pass, [C] scatter-max
    claims) vs after (single fused sweep, batch-local claims)."""
    rng = np.random.default_rng(7)
    C, B = 1 << 17, 20480          # cooc-store shape of the P2 workload
    t0 = stores.make_table(C, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})

    def batch(seed):
        r = np.random.default_rng(seed)
        fps = (r.integers(1, 40000, size=B).astype(np.uint64)
               * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)
        hi, lo = split_fp(fps)
        upd = {"weight": jnp.asarray(r.random(B), jnp.float32),
               "count": jnp.ones((B,), jnp.float32),
               "last_tick": jnp.zeros((B,), jnp.int32)}
        return jnp.asarray(hi), jnp.asarray(lo), upd, jnp.ones((B,), bool)

    # warm the table to a realistic mixed found/new load (~30% full)
    for s in range(2):
        hi, lo, upd, valid = batch(s)
        t0 = stores.insert_accumulate(t0, hi, lo, upd, valid, modes=_MODES)
    hi, lo, upd, valid = batch(5)

    rows: List[Row] = []
    t_old = time_fn(lambda t: stores.insert_accumulate_twopass(
        t, hi, lo, upd, valid, modes=_MODES), t0)
    t_new = time_fn(lambda t: stores.insert_accumulate(
        t, hi, lo, upd, valid, modes=_MODES), t0)
    rows.append(("perf_P4_insert_twopass", t_old,
                 f"{B/(t_old/1e6):,.0f} upd/s (pre-fusion reference)"))
    rows.append(("perf_P4_insert_fused", t_new,
                 f"{B/(t_new/1e6):,.0f} upd/s; x{t_old/max(t_new,1e-9):.2f} "
                 f"vs twopass"))
    return rows


def _bench_ranking_compaction() -> List[Row]:
    """P5: the lexsort reference with/without argsort compaction, and the
    segmented top-k fast path on the same stores."""
    from repro.core import ranking
    from repro.core.ranking import RankConfig

    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 13)
    stream = SyntheticStream(StreamConfig(vocab_size=2048,
                                          queries_per_tick=4096,
                                          tweets_per_tick=0), seed=1)
    state = init_state(ecfg)
    for t in range(4):
        ev, _ = stream.gen_tick(t)
        sh, sl = split_fp(ev.sess_fp)
        qh, ql = split_fp(ev.q_fp)
        state = ingest_queries(state, jnp.asarray(sh), jnp.asarray(sl),
                               jnp.asarray(qh), jnp.asarray(ql),
                               jnp.asarray(ev.src, jnp.int32),
                               jnp.asarray(ev.valid), cfg=ecfg)
    rows: List[Row] = []
    t_full = time_fn(lambda: ranking.ranking_cycle_lexsort(
        state.cooc, state.qstore, RankConfig(compact_frac=1.0)))
    t_cmp = time_fn(lambda: ranking.ranking_cycle_lexsort(
        state.cooc, state.qstore, RankConfig(compact_frac=0.5)))
    t_seg = time_fn(lambda: ranking.ranking_cycle(
        state.cooc, state.qstore, RankConfig()))
    rows.append(("perf_P5_rank_full", t_full, "full-capacity lexsort"))
    rows.append(("perf_P5_rank_compact", t_cmp,
                 f"compact_frac=0.5; x{t_full/max(t_cmp,1e-9):.2f} vs full"))
    rows.append(("perf_P5_rank_segtopk", t_seg,
                 f"segmented top-k; x{t_cmp/max(t_seg,1e-9):.2f} vs "
                 f"compacted lexsort"))
    return rows


def _bench_decay_policies() -> List[Row]:
    """P6: steady-state per-tick engine cost, eager decay sweeps every
    ``decay_every`` ticks vs the lazy policy (read-time decay; prune-only
    sweep at ``prune_every``). 48 measured ticks cover 8 eager sweeps and
    exactly one lazy prune, so both amortization schedules are priced in."""
    from repro.core.engine import SearchAssistanceEngine

    base = EngineConfig(query_capacity=1 << 15, cooc_capacity=1 << 17,
                        session_capacity=1 << 14, rank_every=0,
                        decay_every=6, prune_every=48)
    lazy = dataclasses.replace(
        base, decay=dataclasses.replace(base.decay, policy="lazy"))
    stream = SyntheticStream(StreamConfig(vocab_size=4096,
                                          queries_per_tick=4096,
                                          tweets_per_tick=0), seed=3)
    ticks = [stream.gen_tick(t)[0] for t in range(52)]
    rows: List[Row] = []
    times = {}
    for name, cfg in (("eager", base), ("lazy", lazy)):
        eng = SearchAssistanceEngine(cfg)
        for t in range(4):                      # warm tables + compile
            eng.step(ticks[t], None)
        jax.block_until_ready(eng.state.qstore.key_hi)
        t0 = time.perf_counter()
        for t in range(4, 52):
            eng.step(ticks[t], None)
        jax.block_until_ready(eng.state.qstore.key_hi)
        times[name] = (time.perf_counter() - t0) / 48 * 1e6
        sweeps = (f"{eng.n_decay_cycles} full sweeps" if name == "eager"
                  else f"{eng.n_prune_cycles} prune-only sweeps")
        rows.append((f"perf_P6_decay_{name}", times[name],
                     f"per-tick steady state, {sweeps} in 48 ticks"
                     + (f"; x{times['eager']/max(times[name],1e-9):.2f}"
                        f" vs eager" if name == "lazy" else "")))

    # maintenance path in isolation: the amortized per-tick cost of the
    # cycles themselves (full sweep every decay_every vs prune-only sweep
    # every prune_every) — the component the lazy policy removes.
    from repro.core.engine import decay_cycle, prune_cycle
    eng = SearchAssistanceEngine(base)
    for t in range(4):
        eng.step(ticks[t], None)
    st = eng.state
    t_sweep = time_fn(lambda s: decay_cycle(s, jnp.int32(6), cfg=base)[0], st)
    t_prune = time_fn(lambda s: prune_cycle(s, cfg=lazy)[0], st)
    rows.append(("perf_P6_maint_eager", t_sweep / base.decay_every,
                 f"full sweep {t_sweep:,.0f}us / {base.decay_every} ticks"))
    rows.append(("perf_P6_maint_lazy", t_prune / base.prune_every,
                 f"prune-only {t_prune:,.0f}us / {base.prune_every} ticks; "
                 f"x{(t_sweep / base.decay_every) / max(t_prune / base.prune_every, 1e-9):.2f}"
                 f" vs eager"))
    return rows
