"""§Perf — engine hillclimb iterations (the paper's own technique).

Each entry is one hypothesis -> change -> measure cycle on the stats
collector (see EXPERIMENTS.md §Perf for the narrative):

  P1 probe_rounds 16 -> 8   (hash probe gathers dominate the update pass)
  P2 micro-batch size sweep (amortize fixed dispatch/sort overheads)
  P3 session window 5 -> 3  (pair volume ~ W; quality/coverage tradeoff)
  P4 fused kernels          (decay sweep + scoring fusions; structural on
                             TPU, measured in interpret mode here)
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineConfig, init_state, ingest_queries
from repro.core.hashing import split_fp
from repro.data.stream import StreamConfig, SyntheticStream
from .common import Row, time_fn


def _measure(ecfg: EngineConfig, batch: int, seed=0) -> float:
    stream = SyntheticStream(StreamConfig(vocab_size=4096,
                                          queries_per_tick=batch,
                                          tweets_per_tick=0), seed=seed)
    state = init_state(ecfg)
    for t in range(3):   # warm the tables
        ev, _ = stream.gen_tick(t)
        sh, sl = split_fp(ev.sess_fp)
        qh, ql = split_fp(ev.q_fp)
        state = ingest_queries(state, jnp.asarray(sh), jnp.asarray(sl),
                               jnp.asarray(qh), jnp.asarray(ql),
                               jnp.asarray(ev.src, jnp.int32),
                               jnp.asarray(ev.valid), cfg=ecfg)
    ev, _ = stream.gen_tick(5)
    sh, sl = split_fp(ev.sess_fp)
    qh, ql = split_fp(ev.q_fp)
    args = (jnp.asarray(sh), jnp.asarray(sl), jnp.asarray(qh),
            jnp.asarray(ql), jnp.asarray(ev.src, jnp.int32),
            jnp.asarray(ev.valid))
    return time_fn(lambda s: ingest_queries(s, *args, cfg=ecfg), state)


def run() -> List[Row]:
    rows: List[Row] = []
    base = EngineConfig(query_capacity=1 << 15, cooc_capacity=1 << 17,
                        session_capacity=1 << 14)

    # P1: probe rounds
    t16 = _measure(base, 4096)
    t8 = _measure(dataclasses.replace(base, probe_rounds=8), 4096)
    st8 = init_state(dataclasses.replace(base, probe_rounds=8))
    rows.append(("perf_P1_probe16", t16, f"{4096/(t16/1e6):,.0f} ev/s baseline"))
    rows.append(("perf_P1_probe8", t8,
                 f"{4096/(t8/1e6):,.0f} ev/s; x{t16/max(t8,1e-9):.2f} "
                 f"(drops must stay 0 at <=50% load)"))

    # P2: micro-batch size (fixed total events)
    for b in (1024, 4096, 16384):
        t = _measure(base, b)
        rows.append((f"perf_P2_batch{b}", t,
                     f"{b/(t/1e6):,.0f} ev/s ({t/b:.1f} us/event)"))

    # P3: session window
    for w in (5, 3):
        t = _measure(dataclasses.replace(base, session_window=w), 4096)
        rows.append((f"perf_P3_window{w}", t,
                     f"{4096/(t/1e6):,.0f} ev/s (pairs/event ~ {w})"))
    return rows
