"""§Autotune — the measured plan vs the blind dispatch, per hot path.

Two claims, both asserted (a regression fails the bench, not just a row):

* **Tuned never loses.** For every hot-path op the tuner picks the
  measured winner of {kernel, jnp} (+ tile shape for ``score_gate``), so
  the tuned row must be >= 0.95x the best candidate — by construction the
  ratio is 1.0; the assert guards the plumbing (a plan that picks the
  loser, or a dispatch site that ignores the plan, trips it).
* **The large-batch cliff is dead.** Ingesting a 16384-event tick through
  one monolithic dispatch collapses throughput (the pre-PR behaviour,
  reproduced here with ``ingest_quantum=0``). Under quantum slicing + the
  tuned dispatch-fusion width, batch-16384 events/s must be within 25% of
  the batch-4096 peak.

Rows land in ``results/BENCH_autotune.json`` via the harness ``--json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.data.stream import StreamConfig, SyntheticStream
from repro.launch.autotune import measure_plan

from .common import Row

# one shape class for the whole bench: big enough that kernel-vs-jnp and
# the batch cliff are both real, small enough for a CI smoke
_CFG = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 16,
                    session_capacity=1 << 14, decay_every=0, rank_every=0,
                    ingest_quantum=4096)
_CLIFF_ITERS = 3


def _tuned_key(plan, op: str) -> str:
    if plan.uses_kernel(op):
        return (f"{op}:kernel:blk{plan.score_block_rows}"
                if op == "score_gate" else f"{op}:kernel")
    return f"{op}:jnp"


def _op_rows(plan, timings: Dict[str, Optional[float]]) -> List[Row]:
    rows: List[Row] = []
    rows.append(("autotune_plan", 0.0,
                 " ".join(f"{k}={v}" for k, v in plan.variants().items())))
    for op in ("score_gate", "bucket_topk", "region_rank", "chain_find",
               "decay_prune"):
        cands = {k: v for k, v in timings.items()
                 if k.startswith(op + ":") and v is not None}
        if not cands:
            continue
        t_tuned = cands[_tuned_key(plan, op)]
        best = min(cands.values())
        ratio = best / t_tuned
        kern = min((v for k, v in cands.items() if ":kernel" in k),
                   default=float("nan"))
        t_jnp = cands.get(f"{op}:jnp", float("nan"))
        rows.append((f"autotune_{op}", t_tuned,
                     f"tuned={'kernel' if plan.uses_kernel(op) else 'jnp'} "
                     f"kernel={kern:.1f}us jnp={t_jnp:.1f}us "
                     f"vs_best={ratio:.3f} speedup_vs_jnp={t_jnp/t_tuned:.2f}"))
        assert ratio >= 0.95, (
            f"{op}: tuned variant {t_tuned:.1f}us is worse than best "
            f"candidate {best:.1f}us (ratio {ratio:.3f} < 0.95)")
    fuse = {k: v for k, v in timings.items() if k.startswith("ingest_fuse:")}
    if fuse:
        rows.append(("autotune_ingest_fuse", min(fuse.values()),
                     " ".join(f"{k.split(':')[1]}q={v:.0f}us"
                              for k, v in sorted(fuse.items()))
                     + f" -> chunk={plan.ingest_chunk}"))
    return rows


def _throughput(cfg: EngineConfig, batch: int, seed: int = 0) -> float:
    """Steady-state engine ``step()`` ingest throughput, events/s."""
    eng = SearchAssistanceEngine(cfg)
    stream = SyntheticStream(StreamConfig(vocab_size=4096,
                                          queries_per_tick=batch,
                                          tweets_per_tick=0), seed=seed)
    times = []
    for t in range(2 + _CLIFF_ITERS):       # 2 warm ticks absorb compiles
        ev, _ = stream.gen_tick(t)
        t0 = time.perf_counter()
        eng.step(ev)
        jax.block_until_ready(eng.state)
        if t >= 2:
            times.append(time.perf_counter() - t0)
    times.sort()
    return batch / times[len(times) // 2]


def run() -> List[Row]:
    rows: List[Row] = []
    plan, timings = measure_plan(_CFG, repeats=2)
    rows += _op_rows(plan, timings)

    tuned = dataclasses.replace(_CFG, plan=plan)
    ev_s_4096 = _throughput(tuned, 4096)
    # pre-PR behaviour: the whole tick in ONE dispatch (no quantum cuts)
    mono = dataclasses.replace(_CFG, ingest_quantum=0)
    ev_s_mono = _throughput(mono, 16384)
    ev_s_tuned = _throughput(tuned, 16384)
    frac = ev_s_tuned / ev_s_4096
    rows.append(("autotune_ingest_4096", 4096 / ev_s_4096 * 1e6,
                 f"{ev_s_4096:.0f} ev/s (peak reference)"))
    rows.append(("autotune_ingest_16384_monolithic",
                 16384 / ev_s_mono * 1e6,
                 f"{ev_s_mono:.0f} ev/s (the cliff: one dispatch)"))
    rows.append(("autotune_ingest_16384_tuned", 16384 / ev_s_tuned * 1e6,
                 f"{ev_s_tuned:.0f} ev/s = {frac:.2f}x of 4096 peak "
                 f"(chunk={plan.ingest_chunk})"))
    assert frac >= 0.75, (
        f"batch-16384 tuned throughput {ev_s_tuned:.0f} ev/s is "
        f"{frac:.2f}x of the batch-4096 peak {ev_s_4096:.0f} ev/s "
        "(must be within 25%)")
    return rows
