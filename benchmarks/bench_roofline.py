"""§Roofline summary: reads the dry-run sweep output (results/*.json) and
prints the per-cell three-term roofline table rows. The dry-run itself is
run separately (512-device flag must be set before jax init):

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \\
      --out results/dryrun_baseline.json
"""
from __future__ import annotations

import json
import os
from typing import List

from .common import Row

RESULTS = [
    ("baseline", "results/dryrun_baseline.json"),
    ("optimized", "results/dryrun_optimized.json"),
]


def run() -> List[Row]:
    rows: List[Row] = []
    for tag, path in RESULTS:
        if not os.path.exists(path):
            rows.append((f"roofline_{tag}", 0.0, f"missing {path} (run dryrun)"))
            continue
        with open(path) as f:
            data = json.load(f)
        ok = [r for r in data if r.get("status") == "ok"]
        skip = [r for r in data if r.get("status") == "skipped"]
        err = [r for r in data if r.get("status") == "error"]
        rows.append((f"roofline_{tag}_cells", 0.0,
                     f"ok={len(ok)} skipped={len(skip)} errors={len(err)}"))
        for r in ok:
            name = f"{r['arch']}/{r['shape']}/{r['mesh']}"
            rows.append((
                f"roofline_{tag}:{name}", 0.0,
                f"bound={r['bottleneck']} frac={r['roofline_fraction']:.3f} "
                f"tC={r['t_compute_s']:.2e} tM={r['t_memory_s']:.2e} "
                f"tX={r['t_collective_s']:.2e}"))
    return rows
