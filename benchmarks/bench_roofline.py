"""§Roofline summary.

Two row families:

* dry-run cells: reads the sweep output (results/*.json) and prints the
  per-cell three-term roofline rows. The dry-run itself is run separately
  (512-device flag must be set before jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \\
        --out results/dryrun_baseline.json

* ``roofline_hot:*``: distance-to-roofline for every TUNED engine hot
  path — the autotuner measures each op under its winning variant and
  ``roofline.hot_path_roofline`` turns the analytic bytes/flops model
  (``autotune.hot_path_traffic``) into a fraction-of-memory-ceiling row.
  Always emitted (no dry-run files needed), both store layouts.
"""
from __future__ import annotations

import json
import os
from typing import List

from .common import Row

RESULTS = [
    ("baseline", "results/dryrun_baseline.json"),
    ("optimized", "results/dryrun_optimized.json"),
]


def _hot_path_rows() -> List[Row]:
    import dataclasses

    from repro.core.engine import EngineConfig
    from repro.launch.autotune import hot_path_traffic, measure_plan
    from repro.launch.roofline import hot_path_roofline

    from .bench_autotune import _tuned_key

    rows: List[Row] = []
    base = EngineConfig(query_capacity=1 << 13, cooc_capacity=1 << 15,
                        session_capacity=1 << 13)
    for layout in ("hash", "region"):
        cfg = dataclasses.replace(base, cooc_layout=layout)
        plan, timings = measure_plan(cfg, repeats=2, tune_ingest=False)
        for op, tf in hot_path_traffic(cfg).items():
            t_us = timings.get(_tuned_key(plan, op))
            if t_us is None:
                continue
            r = hot_path_roofline(op, bytes_touched=tf["bytes"],
                                  flops=tf["flops"], measured_us=t_us)
            rows.append((
                f"roofline_hot:{layout}:{op}", t_us,
                f"variant={'kernel' if plan.uses_kernel(op) else 'jnp'} "
                f"bound={r['bottleneck']} "
                f"frac={r['roofline_fraction']:.4f} "
                f"tM={r['t_memory_s']:.2e} tC={r['t_compute_s']:.2e}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = _hot_path_rows()
    for tag, path in RESULTS:
        if not os.path.exists(path):
            rows.append((f"roofline_{tag}", 0.0, f"missing {path} (run dryrun)"))
            continue
        with open(path) as f:
            data = json.load(f)
        ok = [r for r in data if r.get("status") == "ok"]
        skip = [r for r in data if r.get("status") == "skipped"]
        err = [r for r in data if r.get("status") == "error"]
        rows.append((f"roofline_{tag}_cells", 0.0,
                     f"ok={len(ok)} skipped={len(skip)} errors={len(err)}"))
        for r in ok:
            name = f"{r['arch']}/{r['shape']}/{r['mesh']}"
            rows.append((
                f"roofline_{tag}:{name}", 0.0,
                f"bound={r['bottleneck']} frac={r['roofline_fraction']:.3f} "
                f"tC={r['t_compute_s']:.2e} tM={r['t_memory_s']:.2e} "
                f"tX={r['t_collective_s']:.2e}"))
    return rows
