"""Benchmark harness — one module per paper table/claim (see DESIGN.md §0).

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run              # all
  PYTHONPATH=src python -m benchmarks.run churn latency  # subset
"""
from __future__ import annotations

import sys
import time
import traceback

BENCHES = ["churn", "ingest", "latency", "ranking", "spelling",
           "memory_coverage", "engine_perf", "roofline"]


def main() -> None:
    names = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},nan,ERROR: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# bench_{name} took {time.time() - t0:.1f}s", flush=True)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
