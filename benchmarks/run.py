"""Benchmark harness — one module per paper table/claim (see DESIGN.md §0).

Prints ``name,us_per_call,derived`` CSV; optionally writes the same rows as
JSON (name -> {us_per_call, derived}) so the perf trajectory is
machine-readable across PRs.

  PYTHONPATH=src python -m benchmarks.run                # all
  PYTHONPATH=src python -m benchmarks.run churn latency  # subset
  PYTHONPATH=src python -m benchmarks.run --json results/BENCH_engine.json engine_perf
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = ["churn", "ingest", "latency", "ranking", "recovery", "spelling",
           "store", "memory_coverage", "engine_perf", "roofline", "overload",
           "fleet", "compaction", "autotune"]


def main() -> None:
    # several benches (roofline, autotune cache snapshots) read/write
    # results/ relative to the repo root — make sure it exists up front
    os.makedirs("results", exist_ok=True)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as JSON: name -> "
                         "{us_per_call, derived}")
    ap.add_argument("benches", nargs="*", default=[],
                    help=f"subset of: {', '.join(BENCHES)} (default: all)")
    args = ap.parse_args()

    names = args.benches or BENCHES
    print("name,us_per_call,derived")
    failed = []
    rows = {}
    for name in names:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
                rows[row_name] = {"us_per_call": round(us, 1),
                                  "derived": derived}
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},nan,ERROR: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# bench_{name} took {time.time() - t0:.1f}s", flush=True)

    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json} ({len(rows)} rows)", flush=True)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
