"""Shared benchmark utilities: timing + result rows."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Tuple

import jax

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{t:.1f},{d}" for n, t, d in rows)
