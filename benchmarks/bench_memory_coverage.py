"""C5 (§4.4): the coverage <-> memory-footprint tradeoff.

"We can reduce memory consumption by only keeping track of frequently-
occurring query terms (above a threshold), but at the cost of coverage."
We sweep the prune threshold and the store capacity and report suggestion
coverage (fraction of distinct queries with >= 1 suggestion), plus the
count-min-sketch alternative's memory at equal counting fidelity.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.data.stream import StreamConfig, SyntheticStream
from .common import Row


def _coverage(ecfg: EngineConfig, n_ticks: int = 12) -> tuple:
    stream = SyntheticStream(StreamConfig(vocab_size=1024,
                                          queries_per_tick=1024,
                                          tweets_per_tick=64), seed=4)
    eng = SearchAssistanceEngine(ecfg)
    seen = set()
    for t in range(n_ticks):
        ev, tw = stream.gen_tick(t)
        seen.update(int(f) for f in ev.q_fp)
        eng.step(ev, tw)
    eng.run_rank_cycle()
    cov = len(set(eng.suggestions) & seen) / max(len(seen), 1)
    # store bytes: keys 8B + lanes
    q_bytes = ecfg.query_capacity * (8 + 12)
    c_bytes = ecfg.cooc_capacity * (8 + 12 + 16)
    drops = int(eng.state.cooc.n_dropped) + int(eng.state.qstore.n_dropped)
    return cov, (q_bytes + c_bytes) / 1e6, drops


def run() -> List[Row]:
    rows: List[Row] = []
    base = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 13, decay_every=4, rank_every=0)
    for thresh in (0.05, 0.5, 2.0):
        cfg = dataclasses.replace(
            base, decay=dataclasses.replace(base.decay,
                                            prune_threshold=thresh))
        cov, mb, drops = _coverage(cfg)
        rows.append((f"coverage_prune_{thresh}", 0.0,
                     f"coverage={cov:.3f} store={mb:.1f}MB drops={drops}"))
    for cap_shift in (15, 13):
        cfg = dataclasses.replace(base, cooc_capacity=1 << cap_shift)
        cov, mb, drops = _coverage(cfg)
        rows.append((f"coverage_cooc_cap_2^{cap_shift}", 0.0,
                     f"coverage={cov:.3f} store={mb:.1f}MB drops={drops}"))
    return rows


# ---------------------------------------------------------------------------
# --sweep: lazy-cadence coverage (pairs with bench_churn's churn sweep)
# ---------------------------------------------------------------------------

def run_sweep() -> List[Row]:
    """Coverage + drops across lazy (prune_every, decay_every) cadences.

    Measured: coverage is FLAT across cadences (0.658 at these settings —
    read-time decay keeps scores cadence-exact, and pruned entries were
    below threshold anyway), while probe-failure drops under capacity
    pressure rise with ``prune_every`` (4 at p12 -> 34 at p48+: dead
    entries crowd the probe sequences until the next sweep). See
    bench_churn.run_sweep for the recorded verdict + tuned defaults.
    """
    rows: List[Row] = []
    base = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 14,
                        session_capacity=1 << 13, rank_every=0,
                        decay=DecayConfig(policy="lazy",
                                          half_life_ticks=6.0))
    for prune_every in (12, 24, 48, 96):
        for decay_every in (3, 6, 12):
            cfg = dataclasses.replace(base, prune_every=prune_every,
                                      decay_every=decay_every)
            cov, mb, drops = _coverage(cfg, n_ticks=48)
            rows.append(
                (f"coverage_sweep_p{prune_every}_d{decay_every}", 0.0,
                 f"coverage={cov:.3f} store={mb:.1f}MB drops={drops}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="sweep lazy (prune_every, decay_every) cadences")
    rows = run_sweep() if ap.parse_args().sweep else run()
    print("\n".join(f"{n},{t:.1f},{d}" for n, t, d in rows))
