"""R1 (§4.2): crash recovery — catch-up replay must outrun real time.

The paper's durability story: persist results periodically; on a crash,
restart, rewind into the firehose, and "consume messages at a faster rate
than real time to catch up to the present" while frontends serve the last
persisted tables. This bench runs that loop end to end:

  1. live phase: the engine ingests N ticks while the leader appends every
     tick to the durable log and snapshots at each rank cycle;
  2. crash: the writer is killed mid-segment (failure injection — the torn
     tail must be detected and truncated, not replayed);
  3. recovery: restore the newest snapshot, replay the log tail through the
     fused ``ingest_many`` scan, rank at handoff.

Reported: live ingest rate, catch-up replay rate (and its multiple of both
the live rate and the *real-time* stream rate — the paper's bar), and the
time from "process restarted" to "fresh suggestions served".
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List

from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.data.stream import StreamConfig, SyntheticStream
from repro.distributed.fault_tolerance import CheckpointManager
from repro.streaming import (FirehoseLogReader, FirehoseLogWriter,
                             ReplayConfig, kill_writer_mid_segment,
                             recover_engine)
from .common import Row

N_TICKS = 42           # live phase length (crash happens at the end)
TICKS_PER_SEGMENT = 8
CHUNK_TICKS = 8


def _setup(out_dir: str):
    scfg = StreamConfig(vocab_size=2048, queries_per_tick=2048,
                        tweets_per_tick=64, tweet_words=4, tweet_grams=8,
                        tick_seconds=10.0)
    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 13, rank_every=12,
                        decay=DecayConfig(policy="lazy"))
    stream = SyntheticStream(scfg, seed=9)
    batches = [stream.gen_tick(t) for t in range(N_TICKS)]
    return scfg, ecfg, batches


def run() -> List[Row]:
    out = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        return _run(out)
    finally:
        shutil.rmtree(out, ignore_errors=True)


def _run(out: str) -> List[Row]:
    scfg, ecfg, batches = _setup(out)
    log_dir = os.path.join(out, "log")
    ck_dir = os.path.join(out, "ckpt")
    ckpt = CheckpointManager(ck_dir, keep_n=2)

    # ---- live phase (writer = elected leader) ----
    writer = FirehoseLogWriter(log_dir, ticks_per_segment=TICKS_PER_SEGMENT)
    live = SearchAssistanceEngine(ecfg)
    live.step(*batches[0])   # compile warmup tick (outside the timed loop)
    live = SearchAssistanceEngine(ecfg)
    t0 = time.perf_counter()
    for t, (ev, tw) in enumerate(batches):
        writer.append(t, ev, tw)
        if live.step(ev, tw) is not None:
            live.save_snapshot(ckpt)
    live_s = time.perf_counter() - t0
    live_tps = N_TICKS / live_s
    ev_per_tick = scfg.queries_per_tick + scfg.tweets_per_tick

    # ---- crash: kill the writer mid-segment (torn tail on disk) ----
    torn_file = kill_writer_mid_segment(writer)
    reader = FirehoseLogReader(log_dir)
    n_logged = (reader.last_tick() - reader.first_tick() + 1
                if reader.segments else 0)

    # ---- recovery: cold (includes ingest_many compile) and warm ----
    rcfg = ReplayConfig(chunk_ticks=CHUNK_TICKS)
    t0 = time.perf_counter()
    eng, stats = recover_engine(ecfg, ckpt, log_dir, rcfg)
    cold_s = time.perf_counter() - t0
    assert eng.suggestions, "recovery must hand off fresh suggestions"
    # catch-up throughput over a long tail: restore the OLDEST retained
    # snapshot (the realistic worst case — the newest write was lost with
    # the crash) and replay the full span to the log head. First pass
    # compiles the chunk shapes of this span, second pass measures.
    oldest = ckpt.steps()[0]
    recover_engine(ecfg, ckpt, log_dir, rcfg, step=oldest)
    _, stats2 = recover_engine(ecfg, ckpt, log_dir, rcfg, step=oldest)
    replay_tps = stats2["n_ticks"] / stats2["wall_s"]
    x_live = replay_tps / live_tps
    x_realtime = replay_tps * scfg.tick_seconds

    rows = [
        ("recovery_live_ingest", live_s / N_TICKS * 1e6,
         f"{live_tps:.1f} ticks/s = {live_tps * ev_per_tick:.0f} ev/s "
         f"(log+snapshots on)"),
        ("recovery_replay_catchup", stats2["wall_s"] / stats2["n_ticks"] * 1e6,
         f"{replay_tps:.1f} ticks/s over {stats2['n_ticks']} ticks in "
         f"{stats2['n_chunks']} chunks = x{x_live:.1f} live rate, "
         f"x{x_realtime:.0f} real-time rate (target >= 5x)"),
        ("recovery_time_to_fresh", cold_s * 1e6,
         f"restart->fresh-suggestions {cold_s:.2f}s cold (compile incl.), "
         f"{stats2['wall_s']:.2f}s warm for the {stats2['n_ticks']}-tick "
         f"tail; newest snapshot replayed ticks {stats['start_tick']}.."
         f"{stats['end_tick'] - 1}, {stats['n_rank_suppressed']} rank "
         f"cycles suppressed"),
        ("recovery_torn_tail", 0.0,
         f"crash mid-segment: torn file {'present' if torn_file else 'none'}"
         f", log truncated to {n_logged}/{N_TICKS} ticks "
         f"({N_TICKS - n_logged} lost with the torn tail, by design)"),
    ]
    return rows
