"""R1 (§4.2): crash recovery — catch-up replay must outrun real time.

The paper's durability story: persist results periodically; on a crash,
restart, rewind into the firehose, and "consume messages at a faster rate
than real time to catch up to the present" while frontends serve the last
persisted tables. This bench runs that loop end to end:

  1. live phase: the engine ingests N ticks while the leader appends every
     tick to the durable log and snapshots at each rank cycle;
  2. crash: the writer is killed mid-segment (failure injection — the torn
     tail must be detected and truncated, not replayed);
  3. recovery: restore the newest snapshot, replay the log tail through the
     fused ``ingest_many`` scan, rank at handoff.

Reported: live ingest rate, catch-up replay rate (and its multiple of both
the live rate and the *real-time* stream rate — the paper's bar), and the
time from "process restarted" to "fresh suggestions served".

Delta-vs-full snapshot cadence (rows ``recovery_snapshot_*`` /
``recovery_ttf_*``): a second pass snapshots the same run under two
policies — fulls at the rank cadence (every 12 ticks) vs a delta chain
(changed slots only, one full per 8 snapshots) at a 4x shorter cadence
(every 3 ticks) — and reports snapshot bytes written, worst-case replay
tail (one snapshot interval), and the warm time-to-fresh for each. The
delta chain's smaller write volume is what buys the shorter cadence, and
the shorter cadence is what cuts time-to-fresh.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List

from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.data.stream import StreamConfig, SyntheticStream
from repro.distributed.fault_tolerance import CheckpointManager
from repro.streaming import (FirehoseLogReader, FirehoseLogWriter,
                             ReplayConfig, kill_writer_mid_segment,
                             recover_engine)
from .common import Row

N_TICKS = 42           # live phase length (crash happens at the end)
TICKS_PER_SEGMENT = 8
CHUNK_TICKS = 8


def _setup(out_dir: str):
    scfg = StreamConfig(vocab_size=2048, queries_per_tick=2048,
                        tweets_per_tick=64, tweet_words=4, tweet_grams=8,
                        tick_seconds=10.0)
    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 17,
                        session_capacity=1 << 13, rank_every=12,
                        decay=DecayConfig(policy="lazy"))
    stream = SyntheticStream(scfg, seed=9)
    batches = [stream.gen_tick(t) for t in range(N_TICKS)]
    return scfg, ecfg, batches


def run() -> List[Row]:
    out = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        return _run(out)
    finally:
        shutil.rmtree(out, ignore_errors=True)


def _run(out: str) -> List[Row]:
    scfg, ecfg, batches = _setup(out)
    log_dir = os.path.join(out, "log")
    ck_dir = os.path.join(out, "ckpt")
    ckpt = CheckpointManager(ck_dir, keep_n=2)

    # ---- live phase (writer = elected leader) ----
    writer = FirehoseLogWriter(log_dir, ticks_per_segment=TICKS_PER_SEGMENT)
    live = SearchAssistanceEngine(ecfg)
    live.step(*batches[0])   # compile warmup tick (outside the timed loop)
    live = SearchAssistanceEngine(ecfg)
    t0 = time.perf_counter()
    for t, (ev, tw) in enumerate(batches):
        writer.append(t, ev, tw)
        if live.step(ev, tw) is not None:
            live.save_snapshot(ckpt)
    live_s = time.perf_counter() - t0
    live_tps = N_TICKS / live_s
    ev_per_tick = scfg.queries_per_tick + scfg.tweets_per_tick

    # ---- crash: kill the writer mid-segment (torn tail on disk) ----
    torn_file = kill_writer_mid_segment(writer)
    reader = FirehoseLogReader(log_dir)
    n_logged = (reader.last_tick() - reader.first_tick() + 1
                if reader.segments else 0)

    # ---- recovery: cold (includes ingest_many compile) and warm ----
    rcfg = ReplayConfig(chunk_ticks=CHUNK_TICKS)
    t0 = time.perf_counter()
    eng, stats = recover_engine(ecfg, ckpt, log_dir, rcfg)
    cold_s = time.perf_counter() - t0
    assert eng.suggestions, "recovery must hand off fresh suggestions"
    # catch-up throughput over a long tail: restore the OLDEST retained
    # snapshot (the realistic worst case — the newest write was lost with
    # the crash) and replay the full span to the log head. First pass
    # compiles the chunk shapes of this span, second pass measures.
    oldest = ckpt.steps()[0]
    recover_engine(ecfg, ckpt, log_dir, rcfg, step=oldest)
    _, stats2 = recover_engine(ecfg, ckpt, log_dir, rcfg, step=oldest)
    replay_tps = stats2["n_ticks"] / stats2["wall_s"]
    x_live = replay_tps / live_tps
    x_realtime = replay_tps * scfg.tick_seconds

    # ---- delta-vs-full snapshot cadence (same batches, fresh engine) ----
    # fulls at the rank cadence (12) vs a delta chain at a 4x shorter
    # cadence (3, one full per 8 snapshots = per 24 ticks). Same engine
    # trajectory either way.
    ck_fullcad = CheckpointManager(os.path.join(out, "ck_full"), keep_n=0)
    ck_delta = CheckpointManager(os.path.join(out, "ck_delta"), keep_n=0,
                                 full_interval=8)
    eng2 = SearchAssistanceEngine(ecfg)
    t_full, t_delta = [], []
    b_full, b_delta_all = [], []
    for t, (ev, tw) in enumerate(batches):
        eng2.step(ev, tw)
        if (t + 1) % 12 == 0:
            t0 = time.perf_counter()
            eng2.save_snapshot(ck_fullcad)
            t_full.append(time.perf_counter() - t0)
            b_full.append(ck_fullcad.last_save_bytes)
        if (t + 1) % 3 == 0:
            t0 = time.perf_counter()
            eng2.save_snapshot(ck_delta)
            t_delta.append(time.perf_counter() - t0)
            b_delta_all.append((ck_delta.last_save_kind,
                                ck_delta.last_save_bytes))
    b_delta = [b for k, b in b_delta_all if k == "delta"]
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    # per-tick write volume at each cadence (the delta chain pays one full
    # per 8 snapshots; amortize over all saves)
    wv_full = mean(b_full) / 12.0
    wv_delta = mean([b for _, b in b_delta_all]) / 3.0
    # worst-case time-to-fresh = replaying ONE snapshot interval: restore
    # the snapshot one interval behind the target. Warm = second call.
    full_steps = ck_fullcad.steps()
    recover_engine(ecfg, ck_fullcad, log_dir, rcfg, step=full_steps[-2],
                   target_tick=full_steps[-1])
    t0 = time.perf_counter()
    _, fstats = recover_engine(ecfg, ck_fullcad, log_dir, rcfg,
                               step=full_steps[-2],
                               target_tick=full_steps[-1])
    ttf_full = time.perf_counter() - t0
    # pick the newest delta-cadence target whose base (one interval back)
    # is itself a delta — the restore then really chain-walks.
    head = FirehoseLogReader(log_dir).last_tick()
    delta_steps = [s for s in ck_delta.steps() if s <= head + 1]
    d_target = next(s for s in reversed(delta_steps)
                    if s - 3 in delta_steps
                    and ck_delta.manifest(s - 3)["kind"] == "delta")
    recover_engine(ecfg, ck_delta, log_dir, rcfg, step=d_target - 3,
                   target_tick=d_target)
    t0 = time.perf_counter()
    _, dstats = recover_engine(ecfg, ck_delta, log_dir, rcfg,
                               step=d_target - 3, target_tick=d_target)
    ttf_delta = time.perf_counter() - t0

    rows = [
        ("recovery_live_ingest", live_s / N_TICKS * 1e6,
         f"{live_tps:.1f} ticks/s = {live_tps * ev_per_tick:.0f} ev/s "
         f"(log+snapshots on)"),
        ("recovery_replay_catchup", stats2["wall_s"] / stats2["n_ticks"] * 1e6,
         f"{replay_tps:.1f} ticks/s over {stats2['n_ticks']} ticks in "
         f"{stats2['n_chunks']} chunks = x{x_live:.1f} live rate, "
         f"x{x_realtime:.0f} real-time rate (target >= 5x)"),
        ("recovery_time_to_fresh", cold_s * 1e6,
         f"restart->fresh-suggestions {cold_s:.2f}s cold (compile incl.), "
         f"{stats2['wall_s']:.2f}s warm for the {stats2['n_ticks']}-tick "
         f"tail; newest snapshot replayed ticks {stats['start_tick']}.."
         f"{stats['end_tick'] - 1}, {stats['n_rank_suppressed']} rank "
         f"cycles suppressed"),
        ("recovery_torn_tail", 0.0,
         f"crash mid-segment: torn file {'present' if torn_file else 'none'}"
         f", log truncated to {n_logged}/{N_TICKS} ticks "
         f"({N_TICKS - n_logged} lost with the torn tail, by design)"),
        ("recovery_snapshot_full", mean(t_full) * 1e6,
         f"full snapshot every 12 ticks: {mean(b_full) / 1e6:.2f} MB/snap "
         f"= {wv_full / 1e3:.1f} KB/tick written"),
        ("recovery_snapshot_delta", mean(t_delta) * 1e6,
         f"delta chain every 3 ticks (full_interval=8): "
         f"{mean(b_delta) / 1e6:.2f} MB/delta "
         f"(x{mean(b_full) / max(mean(b_delta), 1):.1f} smaller than a "
         f"full) = {wv_delta / 1e3:.1f} KB/tick at 4x the cadence "
         f"(x{wv_delta / max(wv_full, 1e-9):.2f} the full-cadence "
         f"write volume)"),
        ("recovery_ttf_full_cadence", ttf_full * 1e6,
         f"worst-case time-to-fresh, full cadence: replay "
         f"{fstats['n_ticks']}-tick tail in {ttf_full:.3f}s warm"),
        ("recovery_ttf_delta_cadence", ttf_delta * 1e6,
         f"worst-case time-to-fresh, delta cadence: replay "
         f"{dstats['n_ticks']}-tick tail in {ttf_delta:.3f}s warm "
         f"(chain walk {dstats['restore']['chain_len']} members, "
         f"x{ttf_full / max(ttf_delta, 1e-9):.1f} faster to fresh)"),
    ]
    return rows
