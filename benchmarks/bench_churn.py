"""C1 (§2.3): churn of the top-K query set, hourly vs daily granularity.

Paper: ~17% of the top-1000 terms churn hour-over-hour; ~13% day-over-day
(daily churn is LOWER than hourly — aggregation smooths bursts). We verify
the synthetic stream reproduces the qualitative structure: substantial
hourly churn, lower daily churn.

``python -m benchmarks.bench_churn --sweep`` additionally sweeps the lazy
policy's maintenance cadences (``prune_every`` x ``decay_every``) against
*suggestion* churn between consecutive rank cycles — the quality-drift
check the lazy-decay ROADMAP item asked for (pair with the coverage sweep
in ``bench_memory_coverage.py``).
"""
from __future__ import annotations

from collections import Counter
from typing import List

import numpy as np

from repro.data.stream import EventSpec, StreamConfig, SyntheticStream
from .common import Row


def _topk(stream, t0: int, n_ticks: int, k: int):
    c = Counter()
    for t in range(t0, t0 + n_ticks):
        ev, _ = stream.gen_tick(t)
        c.update(ev.q_fp.tolist())
    return set(f for f, _ in c.most_common(k))


def run() -> List[Row]:
    # 1 tick = 10 s; hour = 360 ticks is too slow on CPU -> scale: 1 tick =
    # 5 min, hour = 12 ticks, day = 288. Rotating breaking events drive churn.
    events = tuple(
        EventSpec(name=f"ev{i}", terms=(f"breaking {i}", f"story {i}"),
                  t_start=40 * i + 10, ramp_ticks=4.0, plateau_ticks=20.0,
                  decay_ticks=30.0, peak_share=0.12)
        for i in range(12))
    cfg = StreamConfig(vocab_size=4096, queries_per_tick=4096,
                       tweets_per_tick=0, zipf_s=1.03, events=events)
    s = SyntheticStream(cfg, seed=3)
    K, hour = 200, 12
    hourly = []
    tops = [_topk(s, h * hour, hour, K) for h in range(8)]
    for a, b in zip(tops, tops[1:]):
        hourly.append(1.0 - len(a & b) / K)
    s2 = SyntheticStream(cfg, seed=3)
    day_a = _topk(s2, 0, 4 * hour, K)     # "day" = 4 pseudo-hours
    day_b = _topk(s2, 4 * hour, 4 * hour, K)
    daily = 1.0 - len(day_a & day_b) / K
    h_mean = float(np.mean(hourly))
    return [("churn_hourly_topK", 0.0,
             f"churn={h_mean:.3f} (paper: 0.17 on real logs)"),
            ("churn_daily_topK", 0.0,
             f"churn={daily:.3f} (paper: 0.13; must be < hourly: "
             f"{daily < h_mean})")]


# ---------------------------------------------------------------------------
# --sweep: lazy-cadence tuning against suggestion churn (ROADMAP item)
# ---------------------------------------------------------------------------

def _sugg_churn(prune_every: int, decay_every: int, n_ticks: int = 48,
                seed: int = 3) -> tuple:
    """Run the lazy-policy engine under capacity pressure and measure mean
    churn of the suggestion key set between consecutive rank cycles, the
    final cooc live-slot load, and probe-failure drops.

    Decay is fast (half life 6 ticks) so entries actually cross the prune
    threshold within the horizon — otherwise every cadence ties trivially.
    """
    from repro.core.decay import DecayConfig
    from repro.core.engine import EngineConfig, SearchAssistanceEngine

    events = tuple(
        EventSpec(name=f"ev{i}", terms=(f"breaking {i}", f"story {i}"),
                  t_start=12 * i + 4, ramp_ticks=4.0, plateau_ticks=10.0,
                  decay_ticks=12.0, peak_share=0.12)
        for i in range(3))
    cfg = StreamConfig(vocab_size=1024, queries_per_tick=1024,
                       tweets_per_tick=64, zipf_s=1.03, events=events)
    stream = SyntheticStream(cfg, seed=seed)
    ecfg = EngineConfig(query_capacity=1 << 13, cooc_capacity=1 << 15,
                        session_capacity=1 << 12, rank_every=6,
                        decay_every=decay_every, prune_every=prune_every,
                        decay=DecayConfig(policy="lazy",
                                          half_life_ticks=6.0))
    eng = SearchAssistanceEngine(ecfg)
    churns, prev = [], None
    for t in range(n_ticks):
        ev, tw = stream.gen_tick(t)
        if eng.step(ev, tw) is not None:
            cur = set(eng.suggestions)
            if prev:
                churns.append(1.0 - len(cur & prev) / max(len(prev), 1))
            prev = cur
    live_frac = float(np.asarray(eng.state.cooc.live_count())) \
        / eng.cfg.cooc_capacity
    drops = int(eng.state.cooc.n_dropped)
    return float(np.mean(churns)) if churns else 0.0, live_frac, drops


def run_sweep() -> List[Row]:
    """Sweep (prune_every, decay_every) under the lazy policy.

    Measured verdict (recorded in ROADMAP + EngineConfig defaults):
    suggestion churn is IDENTICAL across every cadence (0.122 at this
    sweep's settings) — read-time decay means pruning only reclaims slots,
    it never changes scores — and the paired coverage sweep is flat too
    (0.658). What moves is cooc live-slot load (0.244 at p12 -> 0.310 at
    p48/p96) and, under capacity pressure, probe-failure drops (4 -> 34).
    ``decay_every`` (session-eviction cadence under lazy) moves nothing.
    So the cadence is a pure memory-headroom/sweep-cost tradeoff:
    ``prune_every=24`` (the tuned EngineConfig default) matches 48's
    quality with visibly lower table load; ``decay_every=6`` stands.
    """
    rows: List[Row] = []
    for prune_every in (12, 24, 48, 96):
        for decay_every in (3, 6, 12):
            churn, live, drops = _sugg_churn(prune_every, decay_every)
            rows.append((f"churn_sweep_p{prune_every}_d{decay_every}", 0.0,
                         f"sugg_churn={churn:.3f} cooc_live={live:.3f} "
                         f"drops={drops}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="sweep lazy (prune_every, decay_every) cadences")
    rows = run_sweep() if ap.parse_args().sweep else run()
    print("\n".join(f"{n},{t:.1f},{d}" for n, t, d in rows))
