"""C1 (§2.3): churn of the top-K query set, hourly vs daily granularity.

Paper: ~17% of the top-1000 terms churn hour-over-hour; ~13% day-over-day
(daily churn is LOWER than hourly — aggregation smooths bursts). We verify
the synthetic stream reproduces the qualitative structure: substantial
hourly churn, lower daily churn.
"""
from __future__ import annotations

from collections import Counter
from typing import List

import numpy as np

from repro.data.stream import EventSpec, StreamConfig, SyntheticStream
from .common import Row


def _topk(stream, t0: int, n_ticks: int, k: int):
    c = Counter()
    for t in range(t0, t0 + n_ticks):
        ev, _ = stream.gen_tick(t)
        c.update(ev.q_fp.tolist())
    return set(f for f, _ in c.most_common(k))


def run() -> List[Row]:
    # 1 tick = 10 s; hour = 360 ticks is too slow on CPU -> scale: 1 tick =
    # 5 min, hour = 12 ticks, day = 288. Rotating breaking events drive churn.
    events = tuple(
        EventSpec(name=f"ev{i}", terms=(f"breaking {i}", f"story {i}"),
                  t_start=40 * i + 10, ramp_ticks=4.0, plateau_ticks=20.0,
                  decay_ticks=30.0, peak_share=0.12)
        for i in range(12))
    cfg = StreamConfig(vocab_size=4096, queries_per_tick=4096,
                       tweets_per_tick=0, zipf_s=1.03, events=events)
    s = SyntheticStream(cfg, seed=3)
    K, hour = 200, 12
    hourly = []
    tops = [_topk(s, h * hour, hour, K) for h in range(8)]
    for a, b in zip(tops, tops[1:]):
        hourly.append(1.0 - len(a & b) / K)
    s2 = SyntheticStream(cfg, seed=3)
    day_a = _topk(s2, 0, 4 * hour, K)     # "day" = 4 pseudo-hours
    day_b = _topk(s2, 4 * hour, 4 * hour, K)
    daily = 1.0 - len(day_a & day_b) / K
    h_mean = float(np.mean(hourly))
    return [("churn_hourly_topK", 0.0,
             f"churn={h_mean:.3f} (paper: 0.17 on real logs)"),
            ("churn_daily_topK", 0.0,
             f"churn={daily:.3f} (paper: 0.13; must be < hourly: "
             f"{daily < h_mean})")]
