"""C2/C3/C7 — the paper's core claim: end-to-end time-to-suggestion.

Injects the Figure-1 "steve jobs" breaking-news event into the stream and
measures, in SIMULATED time, when each architecture first surfaces a
related suggestion for the head query:

  * streaming engine (Take Two): rank cycle every 5 sim-minutes; target is
    the paper's <= 10 minutes;
  * Hadoop stack (Take One): same statistics recomputed hourly, availability
    gated by the §3 latency model (import lag + MR compute + stragglers),
    in both typical (2 h lag) and best-case (20 min incremental) variants.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.data.batch_pipeline import BatchPipeline, HadoopLatencyModel
from repro.data.stream import StreamConfig, SyntheticStream, steve_jobs_scenario
from .common import Row


def run() -> List[Row]:
    base = StreamConfig(vocab_size=1024, queries_per_tick=1024,
                        tweets_per_tick=64, tick_seconds=30.0)
    scfg, event = steve_jobs_scenario(base_cfg=base)
    scfg = dataclasses.replace(scfg, events=(
        dataclasses.replace(event, t_start=30),))
    event = scfg.events[0]
    stream = SyntheticStream(scfg, seed=1)
    head = stream.tok.query_fp(event.terms[0])
    related = {stream.tok.query_fp(t) for t in event.terms[1:]}

    ecfg = EngineConfig(query_capacity=1 << 14, cooc_capacity=1 << 16,
                        session_capacity=1 << 13,
                        decay_every=4, rank_every=10)  # 5 sim-min rank cycle
    eng = SearchAssistanceEngine(ecfg)
    hadoop = BatchPipeline(ecfg, HadoopLatencyModel(),
                           tick_seconds=scfg.tick_seconds, window_hours=2)
    # compress: 1 "hour" of logs = 20 ticks (10 sim-min) for tractability;
    # the latency MODEL still uses real-hour constants.
    hadoop.ticks_per_hour = 20

    t_event_s = event.t_start * scfg.tick_seconds
    stream_latency = None
    n_ticks = 90
    for t in range(n_ticks):
        ev, tw = stream.gen_tick(t)
        eng.step(ev, tw)
        hadoop.ingest_tick(ev, tw)
        if stream_latency is None and eng.suggestions:
            hits = {d for d, _ in eng.suggest_fp(head, k=8)}
            if hits & related:
                stream_latency = t * scfg.tick_seconds - t_event_s

    # Hadoop path: earliest completed batch job whose window saw the event
    # AND whose output contains the suggestion.
    def hadoop_latency(best_case: bool) -> float:
        model = HadoopLatencyModel()
        best = None
        for i, (sugg, _) in enumerate(hadoop.results):
            hits = {d for d, _ in sugg.get(int(head), [])}
            if hits & related:
                log_end = hadoop.hours[i].generated_at_s
                lag = (model.import_lag_best_s if best_case
                       else model.import_lag_s)
                done = log_end + lag + model.compute_time_s(hadoop.window_hours)
                if best is None or done < best:
                    best = done
        return best - t_event_s if best is not None else float("inf")

    lat_typ = hadoop_latency(best_case=False)
    lat_best = hadoop_latency(best_case=True)

    rows = [
        ("e2e_latency_streaming", 0.0,
         f"{stream_latency / 60:.1f} sim-min (target <= 10; paper §2.3)"
         if stream_latency is not None else "NEVER"),
        ("e2e_latency_hadoop_typical", 0.0,
         f"{lat_typ / 60:.0f} sim-min (2h import lag + MR; paper §3)"),
        ("e2e_latency_hadoop_bestcase", 0.0,
         f"{lat_best / 60:.0f} sim-min (20min incremental import)"),
    ]
    return rows
