"""R-fleet (robustness): self-healing replicated serving under chaos.

Runs the fleet chaos composition end to end, measured: a 3-replica
``ServingFleet`` rides a 50x flash crowd while (1) one replica answers
through a slow disk (client hedges around it), (2) the log-writer leader
is killed *mid-segment* (epoch-fenced failover, torn tail truncated,
survivors' rings heal the gap), and (3) a follower is killed during the
spike. Every tick a client request is routed through the ``ServerSet``;
the run only counts if **zero** requests fail throughout.

Reported rows:

  * ``fleet_tick``      — steady-state per-tick fleet cost (detect +
    leader append + N replica steps) while the fleet is whole;
  * ``fleet_request``   — median client request latency through the
    chaos run (and the zero-failures count);
  * ``fleet_failover``  — wall cost of the failover tick, plus the
    detection gap in ticks from leader kill to epoch bump;
  * ``fleet_recovery``  — wall cost of a readmission tick (snapshot
    restore + sealed-log catch-up + rejoin), kill->live gaps, healed
    vs lost log ticks;
  * ``fleet_hedge_rate`` — fraction of requests hedged (slow-disk window
    forces real hedges + timeouts).

Short mode is the default (it is the CI smoke); ``--seed``/``--ticks``
vary the chaos schedule's workload without editing the file:

  PYTHONPATH=src python -m benchmarks.bench_fleet --seed 5 --ticks 32
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time
from typing import List

from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig
from repro.distributed.fleet import FleetConfig, ServingFleet
from repro.streaming import (FirehoseWorkload, SpamSpec, SpikeSpec,
                             WorkloadConfig, slow_io)
from .common import Row

KILL_LEADER_AT = 7     # mid-segment (segment 4..7 is open)
KILL_FOLLOWER_AT = 12  # during the spike plateau


def _wl(seed: int) -> FirehoseWorkload:
    return FirehoseWorkload(WorkloadConfig(
        vocab_per_lang=128, n_langs=3, n_users=500,
        base_queries_per_tick=64, base_tweets_per_tick=8,
        min_bucket=64, min_tweet_bucket=8,
        spikes=(SpikeSpec(t_start=6, mult=50.0),),
        spam=SpamSpec(period=9, burst_ticks=2)), seed=seed)


def run(seed: int = 3, n_ticks: int = 24) -> List[Row]:
    out = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        return _run(out, seed, max(n_ticks, 16))
    finally:
        shutil.rmtree(out, ignore_errors=True)


def _run(out: str, seed: int, n_ticks: int) -> List[Row]:
    ecfg = EngineConfig(query_capacity=1 << 11, cooc_capacity=1 << 13,
                        session_capacity=1 << 10, session_window=3,
                        decay_every=4, prune_every=6, rank_every=5,
                        region_width=16, decay=DecayConfig(policy="lazy"))
    fcfg = FleetConfig(n_replicas=3, heartbeat_timeout=2, restart_after=1,
                       snapshot_every=8, ticks_per_segment=4)
    fleet = ServingFleet(out, ecfg, fcfg)
    wl = _wl(seed)
    ss = fleet.serverset(timeout_s=0.01, max_retries=1)
    slow_io(fleet.handles[2], ("related",), delay_s=0.05)

    probe = int(wl.fps[0])
    tick_wall = {}           # t -> offer_tick wall seconds
    req_wall = []
    failover_tick = None
    readmit_ticks = []       # ticks where n_recoveries bumped
    kill_tick = {}           # rid -> tick it was killed
    seen_down = set()        # rids observed non-live (detection lags kill)
    live_tick = {}           # rid -> tick it came back live
    prev_failovers = prev_recoveries = 0

    t = 0
    while t < n_ticks or (t < n_ticks + 16
                          and any(r.status != "live"
                                  for r in fleet._replicas)):
        ev, tw = wl.gen_tick(t)
        if t == KILL_LEADER_AT:
            fleet.handles[2]._slow_io_undo()
            lead = fleet.leader()
            fleet.kill(lead, mid_segment=True)
            kill_tick[lead] = t
        if t == KILL_FOLLOWER_AT:
            victim = next(r.rid for r in fleet._replicas
                          if r.status == "live" and r.rid != fleet.leader())
            fleet.kill(victim)
            kill_tick[victim] = t
        t0 = time.perf_counter()
        fleet.offer_tick(t, ev, tw)
        tick_wall[t] = time.perf_counter() - t0
        t0 = time.perf_counter()
        ss.request(probe)    # raises iff NO live replica answers
        req_wall.append(time.perf_counter() - t0)
        m = fleet.metrics()
        if m["n_failovers"] > prev_failovers and failover_tick is None:
            failover_tick = t
        if m["n_recoveries"] > prev_recoveries:
            readmit_ticks.append(t)
        prev_failovers = m["n_failovers"]
        prev_recoveries = m["n_recoveries"]
        for rid in list(kill_tick):
            if fleet._replicas[rid].status != "live":
                seen_down.add(rid)
            elif rid in seen_down and rid not in live_tick:
                live_tick[rid] = t
        t += 1

    m = fleet.metrics()
    assert all(r.status == "live" for r in fleet._replicas), m
    assert m["n_lost_ticks"] == 0, m

    # steady-state tick cost: whole fleet, post-compile, pre-chaos
    calm = [tick_wall[i] for i in range(2, KILL_LEADER_AT)]
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    req_wall.sort()
    req_p50 = req_wall[len(req_wall) // 2]
    gaps = {rid: live_tick[rid] - kill_tick[rid] for rid in kill_tick}
    readmit_wall = mean([tick_wall[i] for i in readmit_ticks])

    rows = [
        ("fleet_tick", mean(calm) * 1e6,
         f"3-replica fleet step (detect+append+3x ingest) "
         f"{mean(calm) * 1e3:.1f} ms/tick steady-state"),
        ("fleet_request", req_p50 * 1e6,
         f"{len(req_wall)} requests, 0 failures through 50x spike + "
         f"leader kill mid-segment + follower kill (p50 "
         f"{req_p50 * 1e3:.2f} ms)"),
        ("fleet_failover", tick_wall[failover_tick] * 1e6,
         f"leader killed t={KILL_LEADER_AT} mid-segment, detected + "
         f"epoch-fenced failover at t={failover_tick} "
         f"({failover_tick - KILL_LEADER_AT} ticks), final epoch "
         f"{m['epoch']}, {m['n_failovers']} failovers"),
        ("fleet_recovery", readmit_wall * 1e6,
         f"{m['n_recoveries']} replicas restarted + caught up; "
         f"kill->live gaps {sorted(gaps.values())} ticks; log healed "
         f"{m['n_healed_ticks']} ticks from survivor rings, "
         f"{m['n_lost_ticks']} lost"),
        ("fleet_hedge_rate", 0.0,
         f"{ss.n_hedged}/{ss.n_requests} requests hedged "
         f"({ss.n_hedged / max(ss.n_requests, 1):.1%}), "
         f"{ss.n_timeouts} slow-disk timeouts, "
         f"{ss.n_breaker_skips} breaker skips"),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=3,
                    help="workload seed (varies the chaos-run traffic)")
    ap.add_argument("--ticks", type=int, default=24,
                    help="chaos run length in ticks (min 16)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(seed=args.seed, n_ticks=args.ticks):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
