"""Unit + property tests for the hash-table stores."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import stores
from repro.core.hashing import split_fp, join_fp, combine_fp_np
from proptest import property_test

MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))


def _mk(capacity=1 << 12):
    return stores.make_table(capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})


def _ins(t, fps, w, tick=0, valid=None):
    hi, lo = split_fp(np.asarray(fps, np.uint64))
    n = len(fps)
    valid = np.ones(n, bool) if valid is None else valid
    return stores.insert_accumulate(
        t, jnp.asarray(hi), jnp.asarray(lo),
        {"weight": jnp.asarray(w, jnp.float32),
         "count": jnp.ones(n, jnp.float32),
         "last_tick": jnp.full(n, tick, jnp.int32)},
        jnp.asarray(valid), modes=MODES)


def _get(t, fps):
    hi, lo = split_fp(np.asarray(fps, np.uint64))
    vals, found, _ = stores.lookup(t, jnp.asarray(hi), jnp.asarray(lo))
    return vals, np.asarray(found)


@property_test(n_cases=6)
def test_insert_accumulate_matches_dict(rng):
    """Weights/counts must equal a dict-accumulated oracle (conservation)."""
    t = _mk()
    oracle = {}
    for _ in range(4):
        keys = rng.integers(1, 500, size=256).astype(np.uint64) * 2654435761
        w = rng.random(256).astype(np.float32)
        valid = rng.random(256) < 0.9
        t = _ins(t, keys, w, valid=valid)
        for k, ww, v in zip(keys, w, valid):
            if v:
                e = oracle.setdefault(int(k), [0.0, 0])
                e[0] += float(ww)
                e[1] += 1
    assert int(t.n_dropped) == 0
    exp = stores.export_live(t)
    fps = join_fp(exp["key_hi"], exp["key_lo"])
    assert set(int(f) for f in fps) == set(oracle)
    for f, w, c in zip(fps, exp["weight"], exp["count"]):
        ow, oc = oracle[int(f)]
        np.testing.assert_allclose(w, ow, rtol=1e-5)
        assert int(c) == oc


def test_lookup_missing():
    t = _mk()
    t = _ins(t, [111, 222], [1.0, 2.0])
    vals, found = _get(t, [111, 333, 222])
    assert list(found) == [True, False, True]
    assert float(vals["weight"][1]) == 0.0


def test_prune_then_reinsert_no_duplicates():
    """A pruned slot must be reusable without creating duplicate entries."""
    from repro.core.decay import DecayConfig, sweep_decay_prune
    t = _mk(1 << 10)
    keys = (np.arange(1, 400, dtype=np.uint64) * 0x9E3779B97F4A7C15) | 1
    t = _ins(t, keys, np.ones(len(keys)))
    # decay everything below threshold -> all pruned
    cfg = DecayConfig(half_life_ticks=1.0, prune_threshold=0.9)
    t, live, _ = sweep_decay_prune(t, jnp.int32(2), cfg=cfg)
    assert int(live) == 0
    # reinsert the same keys twice; counts must be exactly 2, live == N
    t = _ins(t, keys, np.ones(len(keys)))
    t = _ins(t, keys, np.ones(len(keys)))
    assert int(t.live_count()) == len(keys)
    vals, found = _get(t, keys)
    assert found.all()
    np.testing.assert_array_equal(np.asarray(vals["count"]), 2.0)


def test_probe_overflow_drops_counted():
    t = _mk(1 << 10)  # capacity 1024, probe_rounds 16
    keys = (np.arange(1, 2000, dtype=np.uint64) * 2654435761) | 1
    t = _ins(t, keys, np.ones(len(keys)))
    # more keys than capacity -> must drop and count, never corrupt
    assert int(t.n_dropped) > 0
    assert int(t.live_count()) <= 1024
    exp = stores.export_live(t)
    assert (exp["count"] == 1.0).all()


@property_test(n_cases=6)
def test_sessions_match_deque_model(rng):
    """Session pair emission == a python deque sliding-window model."""
    from collections import deque
    W = int(rng.integers(2, 6))
    st = stores.make_session_table(1 << 10, W)
    model = {}
    expected = []
    got = []
    for batch in range(3):
        B = 128
        sess = rng.integers(1, 20, size=B).astype(np.uint64) * 7919
        q = rng.integers(1, 50, size=B).astype(np.uint64) * 104729
        src = rng.integers(0, 3, size=B).astype(np.int32)
        valid = rng.random(B) < 0.95
        # python model (batch order per session)
        for s, qq, sc, v in zip(sess, q, src, valid):
            if not v:
                continue
            d = model.setdefault(int(s), deque(maxlen=W))
            for (p, psc) in d:
                if p != int(qq):
                    expected.append((p, int(qq)))
            d.append((int(qq), int(sc)))
        s_hi, s_lo = split_fp(sess)
        q_hi, q_lo = split_fp(q)
        st, pairs = stores.update_sessions(
            st, jnp.asarray(s_hi), jnp.asarray(s_lo), jnp.asarray(q_hi),
            jnp.asarray(q_lo), jnp.asarray(src), jnp.int32(batch),
            jnp.asarray(valid))
        pv = np.asarray(pairs.valid)
        sfp = join_fp(np.asarray(pairs.src_hi), np.asarray(pairs.src_lo))[pv]
        dfp = join_fp(np.asarray(pairs.dst_hi), np.asarray(pairs.dst_lo))[pv]
        got.extend(zip(sfp.tolist(), dfp.tolist()))
    assert sorted(got) == sorted(expected)


def test_set_lane_last_writer_wins():
    t = _mk()
    hi, lo = split_fp(np.array([7, 7, 7], dtype=np.uint64))
    t = stores.insert_accumulate(
        t, jnp.asarray(hi), jnp.asarray(lo),
        {"weight": jnp.ones(3, jnp.float32), "count": jnp.ones(3, jnp.float32),
         "last_tick": jnp.asarray([5, 9, 3], jnp.int32)},
        jnp.ones(3, bool), modes=MODES)
    vals, found = _get(t, [7])
    assert found.all()
    assert int(vals["last_tick"][0]) == 3  # batch-order last


def test_combine_fp_np_device_agree():
    import jax
    from repro.core.hashing import combine_fp_device
    rng = np.random.default_rng(0)
    a_hi = rng.integers(0, 2**32, 64, dtype=np.uint32)
    a_lo = rng.integers(0, 2**32, 64, dtype=np.uint32)
    b_hi = rng.integers(0, 2**32, 64, dtype=np.uint32)
    b_lo = rng.integers(0, 2**32, 64, dtype=np.uint32)
    d_hi, d_lo = combine_fp_device(jnp.asarray(a_hi), jnp.asarray(a_lo),
                                   jnp.asarray(b_hi), jnp.asarray(b_lo))
    n_hi, n_lo = combine_fp_np(a_hi, a_lo, b_hi, b_lo)
    np.testing.assert_array_equal(np.asarray(d_hi), n_hi)
    np.testing.assert_array_equal(np.asarray(d_lo), n_lo)
    # order sensitivity (directed pairs)
    r_hi, _ = combine_fp_device(jnp.asarray(b_hi), jnp.asarray(b_lo),
                                jnp.asarray(a_hi), jnp.asarray(a_lo))
    assert (np.asarray(d_hi) != np.asarray(r_hi)).any()
