"""End-to-end behaviour tests for the paper's system: stream + churn stats."""
import numpy as np

from repro.data.stream import StreamConfig, SyntheticStream, steve_jobs_scenario


def test_stream_deterministic():
    a = SyntheticStream(StreamConfig(vocab_size=128, queries_per_tick=64,
                                     tweets_per_tick=8), seed=1)
    b = SyntheticStream(StreamConfig(vocab_size=128, queries_per_tick=64,
                                     tweets_per_tick=8), seed=1)
    ea, _ = a.gen_tick(0)
    eb, _ = b.gen_tick(0)
    np.testing.assert_array_equal(ea.q_fp, eb.q_fp)
    np.testing.assert_array_equal(ea.sess_fp, eb.sess_fp)


def test_event_hockey_puck_shape():
    cfg, ev = steve_jobs_scenario()
    s = SyntheticStream(cfg, seed=0)
    shares = [s.event_share(t)[0] for t in range(0, 200, 5)]
    before = s.event_share(ev.t_start - 1)[0]
    peak = max(shares)
    late = s.event_share(ev.t_start + ev.plateau_ticks + 4 * ev.decay_ticks)[0]
    assert before == 0.0
    assert peak > 0.8 * ev.peak_share
    assert late < 0.2 * peak


def test_event_queries_dominate_stream_at_peak():
    cfg, ev = steve_jobs_scenario(base_cfg=StreamConfig(
        vocab_size=256, queries_per_tick=2048, tweets_per_tick=8))
    s = SyntheticStream(cfg, seed=0)
    head = s.tok.query_fp("steve jobs")
    t_peak = int(ev.t_start + ev.plateau_ticks // 2)
    evts, _ = s.gen_tick(t_peak)
    frac = float(np.mean(evts.q_fp == np.uint64(head)))
    # head term should be a visible fraction of the stream at the peak
    assert frac > 0.02, frac


def test_churn_is_nonzero_and_bounded():
    """§2.3: top-K query sets must churn over time, substantially but not
    completely (the paper measures 17%/hour for top-1000 on real data)."""
    cfg = StreamConfig(vocab_size=1024, queries_per_tick=4096,
                       tweets_per_tick=0, zipf_s=1.05)
    s = SyntheticStream(cfg, seed=2)
    K = 100
    def topk(t0, n_ticks=4):
        from collections import Counter
        c = Counter()
        for t in range(t0, t0 + n_ticks):
            ev, _ = s.gen_tick(t)
            c.update(ev.q_fp.tolist())
        return set(k for k, _ in c.most_common(K))
    a = topk(0)
    b = topk(4)
    churn = 1.0 - len(a & b) / K
    assert 0.0 < churn < 0.9, churn
