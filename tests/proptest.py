"""Seeded randomized property-test harness.

`hypothesis` is not installed in this offline container, so property-based
tests use this thin substitute: a decorator that re-runs a test body over N
deterministic seeds and reports the failing seed (no shrinking, but failures
are reproducible by construction).
"""
from __future__ import annotations

import functools

import numpy as np


def property_test(n_cases: int = 10, base_seed: int = 1234):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must not see the `rng` parameter
        # (it would treat it as a fixture).
        def wrapper():
            for case in range(n_cases):
                seed = base_seed + case * 7919
                rng = np.random.default_rng(seed)
                try:
                    fn(rng)
                except Exception as e:  # noqa: BLE001
                    raise AssertionError(
                        f"property failed at case={case} seed={seed}: {e}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
