"""Autotuner contract: plans change performance, never results.

Covers the four ISSUE-9 test obligations: plan serialization round-trips
(EngineConfig + snapshot meta), cache-hit determinism (same shape class ->
same plan, no re-benchmark), graceful all-jnp fallback when Pallas is
unavailable, and bit-exact engine parity between any two plans — plus the
derived-region-width mapping and the shared interpret resolver.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.engine import EngineConfig, SearchAssistanceEngine
from repro.core.plan import (JNP_PLAN, TunedPlan, all_kernel_plan,
                             default_region_width, shape_class)
from repro.data.stream import StreamConfig, SyntheticStream
from repro.kernels import resolve_interpret
from repro.launch import autotune


def _cfg(**kw):
    base = dict(query_capacity=1 << 10, cooc_capacity=1 << 12,
                session_capacity=1 << 10, session_window=4,
                decay_every=4, rank_every=6)
    base.update(kw)
    return EngineConfig(**base)


def _run(cfg, ticks=8, qpt=96):
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=80,
                                          queries_per_tick=qpt,
                                          tweets_per_tick=0), seed=5)
    eng = SearchAssistanceEngine(cfg)
    for t in range(ticks):
        ev, _ = stream.gen_tick(t)
        eng.step(ev)
    return eng


def _states_equal(a, b) -> bool:
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(leaves_a, leaves_b))


# ---------------------------------------------------------------------------
# plan object + serialization
# ---------------------------------------------------------------------------


def test_plan_roundtrip_json():
    plan = all_kernel_plan(score_block_rows=32, ingest_chunk=8192,
                           backend="cpu", shape_class="cpu-x-q10-c12-s10")
    assert TunedPlan.from_json(plan.to_json()) == plan
    assert TunedPlan.loads(plan.dumps()) == plan
    assert plan.uses_kernel("score_gate") and not JNP_PLAN.uses_kernel(
        "score_gate")


def test_plan_rejects_unknown_variant():
    with pytest.raises(ValueError):
        TunedPlan(score_gate="cuda")


def test_plan_propagates_to_rank_config():
    plan = all_kernel_plan()
    cfg = _cfg(plan=plan)
    assert cfg.rank.plan == plan
    assert cfg.kernel_on("decay_prune") and cfg.rank.kernel_on("score_gate")
    # legacy bool still wins over the plan at every site
    forced = _cfg(plan=plan, use_kernel=False)
    assert not forced.kernel_on("decay_prune")


def test_plan_rides_snapshot_meta(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    plan = TunedPlan(decay_prune="kernel", ingest_chunk=8192,
                     backend="cpu")
    eng = _run(_cfg(plan=plan), ticks=4)
    ckpt = CheckpointManager(str(tmp_path))
    eng.save_snapshot(ckpt)
    # restore WITHOUT a plan: the snapshot's tuning must re-attach
    eng2, _ = SearchAssistanceEngine.restore_from_snapshot(_cfg(), ckpt)
    assert eng2.cfg.plan == plan
    assert _states_equal(eng.state, eng2.state)
    # an explicitly configured plan wins over the snapshot's
    other = TunedPlan()
    eng3, _ = SearchAssistanceEngine.restore_from_snapshot(
        _cfg(plan=other), ckpt)
    assert eng3.cfg.plan == other


def test_metrics_surface_tuned_variants(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    from repro.serving.serve import SuggestFrontend, pack_suggestions
    plan = TunedPlan(bucket_topk="kernel", score_block_rows=32,
                     ingest_chunk=8192)
    eng = _run(_cfg(plan=plan), ticks=6)
    rt_dir = str(tmp_path / "rt")
    CheckpointManager(rt_dir).save(
        5, pack_suggestions(eng.suggestions),
        meta={"tick": 5, "plan": plan.to_json()})
    f = SuggestFrontend(rt_dir)
    f.poll()
    m = f.metrics()
    assert m["tuned_variants"]["bucket_topk"] == "kernel"
    assert m["tuned_variants"]["ingest_chunk"] == 8192
    # an untuned backend surfaces None, not a crash
    plain = str(tmp_path / "plain")
    CheckpointManager(plain).save(1, pack_suggestions(eng.suggestions),
                                  meta={"tick": 1})
    f2 = SuggestFrontend(plain)
    f2.poll()
    assert f2.metrics()["tuned_variants"] is None


# ---------------------------------------------------------------------------
# the tuner: cache determinism + graceful fallback
# ---------------------------------------------------------------------------


def test_cache_hit_determinism(tmp_path, monkeypatch):
    cfg = _cfg()
    p1 = autotune.tune(cfg, cache=str(tmp_path), repeats=1,
                       tune_ingest=False)
    assert p1.shape_class == shape_class(cfg)

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-benchmark")

    monkeypatch.setattr(autotune, "measure_plan", boom)
    p2 = autotune.tune(cfg, cache=str(tmp_path), repeats=1,
                       tune_ingest=False)
    assert p2 == p1
    # a different shape class misses the cache (and here: re-measures)
    with pytest.raises(AssertionError):
        autotune.tune(_cfg(cooc_capacity=1 << 13), cache=str(tmp_path),
                      repeats=1, tune_ingest=False)


def test_graceful_fallback_without_pallas(monkeypatch):
    from repro.kernels import ops as kops

    def boom(*a, **k):
        raise RuntimeError("no Pallas on this backend")

    for fn in ("score_gate", "bucket_topk", "region_rank", "chain_find",
               "decay_prune_table"):
        monkeypatch.setattr(kops, fn, boom)
    # drop compiled entries that already traced the real kernels (the
    # decay sweep is jitted with static use_kernel): a cache hit would
    # skip re-tracing and never reach the patched functions
    jax.clear_caches()
    for layout in ("hash", "region"):
        plan, timings = autotune.measure_plan(
            _cfg(cooc_layout=layout), repeats=1, tune_ingest=False)
        assert plan.variants() == {**JNP_PLAN.variants(),
                                   "score_block_rows":
                                       plan.score_block_rows}
        assert all(v is None for k, v in timings.items()
                   if ":kernel" in k)
        assert all(v is not None for k, v in timings.items()
                   if k.endswith(":jnp"))


# ---------------------------------------------------------------------------
# plans change performance only — engine results are plan-invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["hash", "region"])
def test_engine_state_bit_exact_across_plans(layout):
    plans = [None, JNP_PLAN,
             all_kernel_plan(),
             all_kernel_plan(score_block_rows=2)]
    engines = [_run(_cfg(cooc_layout=layout, plan=p)) for p in plans]
    for eng in engines[1:]:
        assert _states_equal(engines[0].state, eng.state)
    if layout == "region":
        # suggestion tables too (hash-layout kernel scores carry ~1e-3
        # fusion-rounding diffs vs jnp; states are exact in both layouts)
        for eng in engines[1:]:
            assert eng.suggestions == engines[0].suggestions


def test_ingest_chunking_bit_exact():
    """Quantum cut points are plan-independent; fusion width changes the
    dispatch count only — a ragged 3.5-quantum batch lands bit-identical
    under no plan, unfused, and fused-by-2 plans."""
    plans = [None, TunedPlan(ingest_chunk=0), TunedPlan(ingest_chunk=128)]
    engines = [_run(_cfg(ingest_quantum=64, plan=p), ticks=3, qpt=209)
               for p in plans]
    for eng in engines[1:]:
        assert _states_equal(engines[0].state, eng.state)


# ---------------------------------------------------------------------------
# satellites: derived region width + shared interpret resolver
# ---------------------------------------------------------------------------


def test_default_region_width_mapping():
    assert {c: default_region_width(1 << c) for c in (14, 16, 18, 20, 22)} \
        == {14: 8, 16: 16, 18: 32, 20: 64, 22: 128}
    assert default_region_width(1 << 10) == 8      # floor
    assert default_region_width(1 << 30) == 128    # ceiling
    assert _cfg(cooc_layout="region",
                cooc_capacity=1 << 16).region_w == 16
    assert _cfg(cooc_layout="region", cooc_capacity=1 << 16,
                region_width=8).region_w == 8      # explicit override wins


def test_resolve_interpret():
    native = jax.default_backend() in ("tpu",)
    assert resolve_interpret(None) == (not native)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
