"""Segmented top-k ranking + read-time lazy decay: parity and properties.

* ``ranking_cycle`` (sort-free segmented top-k) must emit the same
  suggestion tables as ``ranking_cycle_lexsort`` (the pre-segmented
  reference) up to tie order — including duplicate scores, near-empty and
  near-full stores.
* The lazy decay policy must be observationally equivalent to eager sweeps
  for exponential decay: read-time decayed lookups, rebase-on-write
  accumulation, prune-only sweeps, and the lazy engine end to end.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ranking, stores
from repro.core.decay import DecayConfig, lazy_decayed, prune_sweep, \
    sweep_decay_prune
from repro.core.hashing import combine_fp_np, join_fp, split_fp
from repro.core.ranking import RankConfig
from proptest import property_test

Q_MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))
C_MODES = Q_MODES + (("src_hi", "set"), ("src_lo", "set"),
                     ("dst_hi", "set"), ("dst_lo", "set"))


def _mk_stores(rng, n_queries, n_pairs, qcap, ccap, *, discrete=False,
               tick=0):
    """Random qstore + cooc pair store. ``discrete=True`` draws pair stats
    from a tiny value set so exact duplicate scores are common."""
    q = stores.make_table(qcap, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})
    qf = (rng.integers(1, 2**63, n_queries).astype(np.uint64)) | 1
    qh, ql = split_fp(qf)
    if discrete:
        qw = np.full(n_queries, 10.0, np.float32)
        qc = np.full(n_queries, 20.0, np.float32)
    else:
        qw = (rng.random(n_queries) * 50 + 1).astype(np.float32)
        qc = np.floor(rng.random(n_queries) * 100 + 1).astype(np.float32)
    q = stores.insert_accumulate(
        q, jnp.asarray(qh), jnp.asarray(ql),
        {"weight": jnp.asarray(qw), "count": jnp.asarray(qc),
         "last_tick": jnp.full(n_queries, tick, jnp.int32)},
        jnp.ones(n_queries, bool), modes=Q_MODES)

    c = stores.make_table(ccap, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32,
        "src_hi": jnp.uint32, "src_lo": jnp.uint32,
        "dst_hi": jnp.uint32, "dst_lo": jnp.uint32})
    if n_pairs == 0:
        return q, c
    a = qf[rng.integers(0, n_queries, n_pairs)]
    b = qf[rng.integers(0, n_queries, n_pairs)]
    ah, al = split_fp(a)
    bh, bl = split_fp(b)
    ph, pl = combine_fp_np(ah, al, bh, bl)
    if discrete:
        pw = rng.choice([1.0, 2.0], n_pairs).astype(np.float32)
        pc = rng.choice([2.0, 3.0], n_pairs).astype(np.float32)
    else:
        pw = (rng.random(n_pairs) * 5 + 0.5).astype(np.float32)
        pc = np.floor(rng.random(n_pairs) * 20 + 1).astype(np.float32)
    c = stores.insert_accumulate(
        c, jnp.asarray(ph), jnp.asarray(pl),
        {"weight": jnp.asarray(pw), "count": jnp.asarray(pc),
         "last_tick": jnp.full(n_pairs, tick, jnp.int32),
         "src_hi": jnp.asarray(ah), "src_lo": jnp.asarray(al),
         "dst_hi": jnp.asarray(bh), "dst_lo": jnp.asarray(bl)},
        jnp.ones(n_pairs, bool), modes=C_MODES)
    return q, c


def _assert_tables_match_up_to_ties(ta, tb):
    """Same sources, same score multisets per source; destinations must
    agree except within the score group tied at the top-k boundary (both
    paths may legitimately keep different members of a cut tie group).
    Scores compare within f32 tolerance: the two pipelines are jitted
    separately, so XLA's fusion reorders float ops, and the LLR lane's
    xlogx cancellation amplifies that to ~1e-3 relative (same bound as the
    assoc kernel tests)."""
    sa = ranking.suggestions_to_host(ta)
    sb = ranking.suggestions_to_host(tb)
    assert set(sa) == set(sb)
    assert int(ta.n_rows) == int(tb.n_rows)
    for f in sa:
        ra, rb = sa[f], sb[f]
        assert len(ra) == len(rb)
        scores_a = sorted((s for _, s in ra), reverse=True)
        scores_b = sorted((s for _, s in rb), reverse=True)
        np.testing.assert_allclose(scores_a, scores_b, rtol=2e-3, atol=1e-5)
        min_s = scores_a[-1]
        band = min_s + 2e-3 * abs(min_s) + 1e-5
        da = {d for d, s in ra if s > band}
        db = {d for d, s in rb if s > band}
        assert da == db


@property_test(n_cases=4)
def test_segmented_matches_lexsort_randomized(rng):
    """Random stores at <=50% load: segmented top-k == lexsort reference."""
    n_queries = int(rng.integers(64, 512))
    n_pairs = int(rng.integers(256, 2048))
    q, c = _mk_stores(rng, n_queries, n_pairs, 1 << 11, 1 << 13)
    cfg = RankConfig(top_k=int(rng.integers(2, 10)))
    seg = ranking.ranking_cycle(c, q, cfg)
    lex = ranking.ranking_cycle_lexsort(c, q, cfg)
    assert int(seg.n_overflow) == 0 and int(lex.n_overflow) == 0
    _assert_tables_match_up_to_ties(seg, lex)


@property_test(n_cases=3)
def test_segmented_matches_lexsort_duplicate_scores(rng):
    """Discrete-valued stats => many exact score ties, incl. tie groups cut
    at the top-k boundary."""
    q, c = _mk_stores(rng, 48, 1200, 1 << 10, 1 << 13, discrete=True)
    cfg = RankConfig(top_k=4)
    seg = ranking.ranking_cycle(c, q, cfg)
    lex = ranking.ranking_cycle_lexsort(c, q, cfg)
    _assert_tables_match_up_to_ties(seg, lex)


def test_segmented_matches_lexsort_near_empty_and_near_full():
    rng = np.random.default_rng(9)
    # near-empty: a single pair, and zero pairs
    q0, c0 = _mk_stores(rng, 8, 0, 1 << 10, 1 << 12)
    cfg = RankConfig()
    t0 = ranking.ranking_cycle(c0, q0, cfg)
    assert int(t0.n_rows) == 0
    assert ranking.suggestions_to_host(t0) == {}
    q1, c1 = _mk_stores(rng, 8, 1, 1 << 10, 1 << 12)
    _assert_tables_match_up_to_ties(
        ranking.ranking_cycle(c1, q1, cfg),
        ranking.ranking_cycle_lexsort(c1, q1, cfg))
    # near-full: >50% of capacity live, so gate-passing rows exceed any
    # 0.5-compaction cap — disable compaction on both paths for exactness.
    qf, cf = _mk_stores(rng, 256, 3400, 1 << 11, 1 << 12)
    assert int(cf.live_count()) > (1 << 11)
    cfg_full = RankConfig(compact_frac=1.0, seg_arena_frac=1.0)
    _assert_tables_match_up_to_ties(
        ranking.ranking_cycle(cf, qf, cfg_full),
        ranking.ranking_cycle_lexsort(cf, qf, cfg_full))
    # with a tiny selection arena the segmented path must COUNT its cut
    over = ranking.ranking_cycle(cf, qf, RankConfig(seg_arena_frac=0.05))
    assert int(over.n_overflow) > 0
    # a max_sources cut must also be counted, and n_rows must report the
    # rows actually emitted, not every source seen in the arena
    capped = ranking.ranking_cycle(cf, qf, RankConfig(max_sources=4))
    assert int(capped.n_rows) == 4
    assert len(ranking.suggestions_to_host(capped)) == 4
    assert int(capped.n_overflow) > 0


def test_segmented_kernel_path_matches_jnp_path():
    rng = np.random.default_rng(3)
    q, c = _mk_stores(rng, 256, 1500, 1 << 11, 1 << 13)
    cfg = RankConfig()
    a = ranking.ranking_cycle(c, q, cfg)
    b = ranking.ranking_cycle(c, q, dataclasses.replace(cfg, use_kernel=True))
    sa = ranking.suggestions_to_host(a)
    sb = ranking.suggestions_to_host(b)
    assert set(sa) == set(sb)
    for f in sa:
        np.testing.assert_allclose(sorted(s for _, s in sa[f]),
                                   sorted(s for _, s in sb[f]),
                                   rtol=5e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Read-time lazy decay == eager sweeps (exponential kind)
# ---------------------------------------------------------------------------

@property_test(n_cases=6)
def test_lazy_lookup_matches_eager_sweeps_arbitrary_gaps(rng):
    """lookup(decay_cfg, now) == lookup after n eager sweeps, any tick gap."""
    de = int(rng.integers(1, 6))
    n_sweeps = int(rng.integers(1, 9))
    cfg = DecayConfig(half_life_ticks=float(rng.uniform(2.0, 40.0)),
                      prune_threshold=0.0)
    cap = 1 << 9
    n = 200
    keys = (rng.integers(1, 2**63, n).astype(np.uint64)) | 1
    hi, lo = split_fp(keys)
    t = stores.make_table(cap, {"weight": jnp.float32, "count": jnp.float32,
                                "last_tick": jnp.int32})
    t = stores.insert_accumulate(
        t, jnp.asarray(hi), jnp.asarray(lo),
        {"weight": jnp.asarray(rng.random(n).astype(np.float32) * 5 + 0.1),
         "count": jnp.ones(n, jnp.float32),
         "last_tick": jnp.zeros(n, jnp.int32)},
        jnp.ones(n, bool), modes=Q_MODES)

    eager = t
    for _ in range(n_sweeps):
        eager, _, _ = sweep_decay_prune(eager, jnp.int32(de), cfg=cfg)
    now = jnp.int32(de * n_sweeps)

    v_lazy, f_lazy, _ = stores.lookup(t, jnp.asarray(hi), jnp.asarray(lo),
                                      decay_cfg=cfg, now=now)
    v_eager, f_eager, _ = stores.lookup(eager, jnp.asarray(hi),
                                        jnp.asarray(lo))
    np.testing.assert_array_equal(np.asarray(f_lazy), np.asarray(f_eager))
    np.testing.assert_allclose(np.asarray(v_lazy["weight"]),
                               np.asarray(v_eager["weight"]), rtol=1e-4)
    # non-decay lanes are untouched by the lazy view
    np.testing.assert_array_equal(np.asarray(v_lazy["count"]),
                                  np.asarray(v_eager["count"]))


@property_test(n_cases=4)
def test_lazy_rebase_on_write_matches_eager_accumulation(rng):
    """insert_accumulate under the lazy policy rebases the stored weight
    before adding; the decayed views must track eager sweeps exactly."""
    de = 3
    cfg = DecayConfig(half_life_ticks=float(rng.uniform(3.0, 20.0)),
                      prune_threshold=0.0)
    cap = 1 << 9
    n = 150
    keys = (rng.integers(1, 400, n).astype(np.uint64)
            * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)
    hi, lo = split_fp(keys)

    def batch(seed, tick):
        r = np.random.default_rng(seed)
        return {"weight": jnp.asarray(r.random(n).astype(np.float32) + 0.1),
                "count": jnp.ones(n, jnp.float32),
                "last_tick": jnp.full(n, tick, jnp.int32)}

    lanes = {"weight": jnp.float32, "count": jnp.float32,
             "last_tick": jnp.int32}
    lazy_t = stores.make_table(cap, lanes)
    eager_t = stores.make_table(cap, lanes)
    ones = jnp.ones(n, bool)
    hi_j, lo_j = jnp.asarray(hi), jnp.asarray(lo)

    # tick 0: both ingest raw
    lazy_t = stores.insert_accumulate(lazy_t, hi_j, lo_j, batch(1, 0), ones,
                                      modes=Q_MODES, decay_cfg=cfg,
                                      now=jnp.int32(0))
    eager_t = stores.insert_accumulate(eager_t, hi_j, lo_j, batch(1, 0),
                                       ones, modes=Q_MODES)
    # eager sweeps up to tick 2*de, then both ingest a second batch there
    for _ in range(2):
        eager_t, _, _ = sweep_decay_prune(eager_t, jnp.int32(de), cfg=cfg)
    now1 = jnp.int32(2 * de)
    lazy_t = stores.insert_accumulate(lazy_t, hi_j, lo_j, batch(2, 2 * de),
                                      ones, modes=Q_MODES, decay_cfg=cfg,
                                      now=now1)
    eager_t = stores.insert_accumulate(eager_t, hi_j, lo_j, batch(2, 2 * de),
                                       ones, modes=Q_MODES)
    # one more eager sweep; lazy just reads at tick 3*de
    eager_t, _, _ = sweep_decay_prune(eager_t, jnp.int32(de), cfg=cfg)
    now2 = jnp.int32(3 * de)

    v_lazy, found, _ = stores.lookup(lazy_t, hi_j, lo_j, decay_cfg=cfg,
                                     now=now2)
    v_eager, _, _ = stores.lookup(eager_t, hi_j, lo_j)
    assert bool(np.asarray(found).all())
    np.testing.assert_allclose(np.asarray(v_lazy["weight"]),
                               np.asarray(v_eager["weight"]), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(v_lazy["count"]),
                                  np.asarray(v_eager["count"]))


def test_prune_sweep_materializes_and_prunes():
    rng = np.random.default_rng(5)
    cfg = DecayConfig(half_life_ticks=4.0, prune_threshold=0.3)
    cap = 1 << 9
    n = 220
    keys = (rng.integers(1, 2**63, n).astype(np.uint64)) | 1
    hi, lo = split_fp(keys)
    w = rng.random(n).astype(np.float32) * 2
    t = stores.make_table(cap, {"weight": jnp.float32, "count": jnp.float32,
                                "last_tick": jnp.int32})
    t = stores.insert_accumulate(
        t, jnp.asarray(hi), jnp.asarray(lo),
        {"weight": jnp.asarray(w), "count": jnp.ones(n, jnp.float32),
         "last_tick": jnp.zeros(n, jnp.int32)},
        jnp.ones(n, bool), modes=Q_MODES)
    now = jnp.int32(8)   # two half lives -> w/4
    pruned, live, total, reclaimed = prune_sweep(t, now, cfg=cfg)
    exp_keep = (w * 0.25) >= cfg.prune_threshold
    assert int(live) == int(exp_keep.sum())
    assert 0 < int(live) < n
    # the satellite contract: the sweep reports how many slots it freed
    assert int(reclaimed) == n - int(exp_keep.sum())
    # survivors are re-anchored at `now` with the materialized weight
    v, found, _ = stores.lookup(pruned, jnp.asarray(hi), jnp.asarray(lo))
    np.testing.assert_array_equal(np.asarray(found), exp_keep)
    np.testing.assert_allclose(np.asarray(v["weight"])[exp_keep],
                               (w * 0.25)[exp_keep], rtol=1e-5)
    lt = np.asarray(v["last_tick"])[exp_keep]
    assert (lt == 8).all()
    # reading the pruned table lazily at a later tick continues the decay
    v2, _, _ = stores.lookup(pruned, jnp.asarray(hi), jnp.asarray(lo),
                             decay_cfg=cfg, now=jnp.int32(12))
    np.testing.assert_allclose(np.asarray(v2["weight"])[exp_keep],
                               (w * 0.125)[exp_keep], rtol=1e-5)


def test_lazy_ranking_cycle_matches_materialized_decay():
    """ranking_cycle(decay_cfg, now) == ranking_cycle over a table whose
    decay was materialized by the prune sweep (threshold 0)."""
    rng = np.random.default_rng(11)
    q, c = _mk_stores(rng, 256, 1500, 1 << 11, 1 << 13)
    cfg = RankConfig()
    dcfg = DecayConfig(half_life_ticks=10.0, prune_threshold=0.0)
    now = jnp.int32(7)
    lazy = ranking.ranking_cycle(c, q, cfg, decay_cfg=dcfg, now=now)
    q_mat, _, _, _ = prune_sweep(q, now, cfg=dcfg)
    c_mat, _, _, _ = prune_sweep(c, now, cfg=dcfg)
    mat = ranking.ranking_cycle(c_mat, q_mat, cfg)
    _assert_tables_match_up_to_ties(lazy, mat)


def test_lazy_engine_matches_eager_engine_on_aligned_ingest():
    """End to end: with ingestion at tick 0 only (so eager sweep counts and
    true elapsed ticks agree), the lazy engine — no decay sweeps at all,
    prune-only at prune_every — ranks identically to the eager engine."""
    from repro.core.engine import EngineConfig, SearchAssistanceEngine
    from repro.data.stream import StreamConfig, SyntheticStream

    base = dict(query_capacity=1 << 12, cooc_capacity=1 << 14,
                session_capacity=1 << 11, session_window=4,
                decay_every=4, rank_every=8, prune_every=8)
    dc = DecayConfig(half_life_ticks=12.0, prune_threshold=1e-4)
    eager = SearchAssistanceEngine(EngineConfig(**base, decay=dc))
    lazy = SearchAssistanceEngine(EngineConfig(
        **base, decay=dataclasses.replace(dc, policy="lazy")))

    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=150,
                                          queries_per_tick=512,
                                          tweets_per_tick=0), seed=4)
    ev, _ = stream.gen_tick(0)
    for t in range(17):
        eager.step(ev if t == 0 else None, None)
        lazy.step(ev if t == 0 else None, None)

    assert eager.n_decay_cycles > 0 and eager.n_prune_cycles == 0
    assert lazy.n_decay_cycles == 0 and lazy.n_prune_cycles > 0
    assert set(lazy.suggestions) == set(eager.suggestions)
    assert len(lazy.suggestions) > 0
    for f in lazy.suggestions:
        ls = sorted((s for _, s in lazy.suggestions[f]), reverse=True)
        es = sorted((s for _, s in eager.suggestions[f]), reverse=True)
        np.testing.assert_allclose(ls, es, rtol=1e-4, atol=1e-6)


def test_lazy_engine_prune_reclaims_slots():
    """Idle lazy engine: live entries persist untouched between prune
    sweeps, then the prune-only sweep reclaims decayed-out slots."""
    from repro.core.engine import EngineConfig, SearchAssistanceEngine
    from repro.data.stream import StreamConfig, SyntheticStream

    cfg = EngineConfig(query_capacity=1 << 12, cooc_capacity=1 << 14,
                       session_capacity=1 << 11, decay_every=2,
                       rank_every=0, prune_every=10,
                       decay=DecayConfig(half_life_ticks=2.0,
                                         prune_threshold=0.05,
                                         policy="lazy"))
    eng = SearchAssistanceEngine(cfg)
    stream = SyntheticStream(StreamConfig(vocab_size=128, n_users=80,
                                          queries_per_tick=256,
                                          tweets_per_tick=0), seed=8)
    ev, _ = stream.gen_tick(0)
    eng.step(ev, None)
    live0 = int(eng.state.qstore.live_count())
    assert live0 > 0
    for _ in range(1, 10):
        eng.step(None, None)
    # ticks 1..9: no sweep ran, stored weights untouched
    assert eng.n_prune_cycles == 0
    assert int(eng.state.qstore.live_count()) == live0
    eng.step(None, None)   # tick 10 -> prune sweep
    assert eng.n_prune_cycles == 1
    # 10 ticks = 5 half-lives: everything is far below the threshold
    assert int(eng.state.qstore.live_count()) < live0


# ---------------------------------------------------------------------------
# suggestions_to_host: explicit filler-key skip
# ---------------------------------------------------------------------------

def test_suggestions_to_host_skips_filler_src_key():
    """A row carrying the all-ones filler src key must be skipped even if a
    positive score leaked into it."""
    K = 4
    ones = np.uint32(0xFFFFFFFF)
    src_hi = jnp.asarray(np.array([1, ones, 0], np.uint32))
    src_lo = jnp.asarray(np.array([2, ones, 0], np.uint32))
    dst_hi = jnp.asarray(np.full((3, K), 3, np.uint32))
    dst_lo = jnp.asarray(np.full((3, K), 4, np.uint32))
    score = jnp.asarray(np.full((3, K), 0.5, np.float32))
    table = ranking.SuggestionTable(src_hi, src_lo, dst_hi, dst_lo, score,
                                    jnp.int32(1), jnp.int32(0))
    out = ranking.suggestions_to_host(table)
    assert set(out) == {int(join_fp(np.uint32(1), np.uint32(2)))}


def test_suggestions_to_host_on_overflowing_compaction():
    """Lexsort path with a pathologically small compaction buffer: the
    exported dict must contain neither the empty key nor the filler key."""
    rng = np.random.default_rng(2)
    q, c = _mk_stores(rng, 128, 2000, 1 << 11, 1 << 13)
    tiny = ranking.ranking_cycle_lexsort(
        c, q, RankConfig(compact_frac=1e-4))
    assert int(tiny.n_overflow) > 0
    out = ranking.suggestions_to_host(tiny)
    assert len(out) > 0
    filler_fp = int(join_fp(np.uint32(0xFFFFFFFF), np.uint32(0xFFFFFFFF)))
    assert 0 not in out and filler_fp not in out
