"""Optimizer, train loop, gradient compression, checkpointing, leader
election, elastic resharding."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.training import optimizer as optim
from repro.training.grad_compression import (compress_with_error_feedback,
                                             dequantize, init_error_feedback,
                                             quantize)
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step
from repro.distributed.fault_tolerance import (CheckpointManager, ReplicaGroup,
                                               elect_leader)
from proptest import property_test


def _quad_loss(params, batch):
    # convex quadratic: optimizer must drive it down
    r = params["w"] - batch["target"]
    return jnp.sum(r * r), {}


def test_adamw_descends_quadratic():
    params = {"w": jnp.ones((16,)) * 5.0}
    tcfg = TrainConfig(opt=optim.AdamWConfig(lr=0.1, warmup_steps=0,
                                             weight_decay=0.0,
                                             schedule="constant",
                                             master_weights=False))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(_quad_loss, tcfg))
    batch = {"target": jnp.zeros((16,))}
    losses = []
    for _ in range(60):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 1e-2 * losses[0]


def test_lr_schedule_warmup_cosine():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(optim.lr_at(cfg, 0)) == 0.0
    assert abs(float(optim.lr_at(cfg, 10)) - 1.0) < 1e-6
    assert float(optim.lr_at(cfg, 5)) == pytest.approx(0.5)
    assert float(optim.lr_at(cfg, 100)) == pytest.approx(cfg.min_lr_frac, rel=1e-3)


def test_grad_clipping():
    params = {"w": jnp.ones((4,))}
    cfg = optim.AdamWConfig(clip_norm=1.0, master_weights=False)
    st = optim.init_state(params, cfg)
    g = {"w": jnp.ones((4,)) * 100.0}
    _, _, m = optim.apply_updates(params, g, st, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_grad_accum_matches_full_batch():
    """accum over 4 microbatches == one step on the full batch."""
    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((8, 1)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((32, 8)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((32, 1)), jnp.float32)}
    base = TrainConfig(opt=optim.AdamWConfig(lr=0.01, warmup_steps=0,
                                             schedule="constant",
                                             master_weights=False))
    acc = TrainConfig(opt=base.opt, grad_accum=4)
    p1, _, m1 = make_train_step(loss, base)(params, init_train_state(params, base), batch)
    p2, _, m2 = make_train_step(loss, acc)(params, init_train_state(params, acc), batch)
    # microbatch losses average to ~the same; grads of MSE over equal splits
    # average exactly to the full-batch grad
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=2e-5, atol=1e-6)


@property_test(n_cases=5)
def test_quantize_roundtrip_bounds(rng):
    g = jnp.asarray(rng.standard_normal((256,)) * rng.random() * 10, jnp.float32)
    q, s = quantize(g)
    deq = dequantize(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the SUM of compressed grads over steps converges
    to the sum of true grads (bias vanishes)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((64,)), jnp.float32) * 0.01
    ef = init_error_feedback({"g": g_true})["g"] * 0  # zeros
    ef = {"g": jnp.zeros((64,), jnp.float32)}
    total = jnp.zeros((64,))
    for _ in range(50):
        out, ef = compress_with_error_feedback({"g": g_true}, ef)
        total = total + out["g"]
    np.testing.assert_allclose(np.asarray(total) / 50, np.asarray(g_true),
                               atol=1e-4)


def test_checkpoint_roundtrip_and_keep_n(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    for s in (10, 20, 30):
        ckpt.save(s, jax.tree.map(lambda x: x * s, tree))
    assert ckpt.steps() == [20, 30]      # keep_n retention
    restored, step = ckpt.restore(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                               np.arange(8) * 30)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"x": jnp.ones(4)})
    for name in os.listdir(tmp_path):
        assert not name.startswith(".tmp"), "tmp dir leaked"
    assert ckpt.latest_step() == 1


def test_checkpoint_gc_cleans_stale_tmp_dirs(tmp_path):
    """Retention removes ``.tmp_*`` debris left by crashed writers (past
    the TTL) but never a fresh tmp dir a live writer may still hold."""
    stale = tmp_path / ".tmp_crashed"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"partial")
    old = time.time() - 7200
    os.utime(stale, (old, old))
    fresh = tmp_path / ".tmp_live"
    fresh.mkdir()
    ckpt = CheckpointManager(str(tmp_path), tmp_ttl_s=3600)
    ckpt.save(1, {"x": jnp.ones(4)})     # save triggers gc
    names = os.listdir(tmp_path)
    assert ".tmp_crashed" not in names, "stale crashed-writer dir kept"
    assert ".tmp_live" in names, "fresh tmp dir must survive"


def test_restore_skips_manifestless_step_dirs(tmp_path):
    """A crashed writer can leave a ``step_*`` dir without MANIFEST.json
    (e.g. a partial copy); restore must fall back to the newest COMPLETE
    checkpoint instead of crashing on it."""
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(4, dtype=jnp.float32)}
    ckpt.save(1, tree)
    ckpt.save(2, jax.tree.map(lambda a: a * 2, tree))
    torn = tmp_path / "step_000000000003"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"torn")
    assert ckpt.steps() == [1, 2]
    assert ckpt.latest_step() == 2
    restored, step = ckpt.restore(tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["x"]), np.arange(4) * 2)
    assert ckpt.restore_host()["leaf_0"].shape == (4,)


def test_delta_checkpoint_chain_roundtrip(tmp_path):
    """full_interval > 1 writes delta steps (changed rows only) chained to
    the last full; every step restores exactly, manifests record the chain
    (kind/base_step/sha256/nbytes), and bf16 leaves survive the delta
    raw-view roundtrip."""
    ckpt = CheckpointManager(str(tmp_path), keep_n=0, full_interval=3)
    tree = {"a": jnp.zeros((64,), jnp.float32),
            "b": jnp.zeros((8, 2), jnp.bfloat16),
            "s": jnp.zeros((), jnp.int32)}
    states = {}
    cur = tree
    for s in range(1, 7):
        cur = {"a": cur["a"].at[s].set(float(s)),
               "b": cur["b"].at[s % 8, 0].set(s),
               "s": jnp.int32(s)}
        ckpt.save(s, cur)
        states[s] = cur
    kinds = {s: ckpt.manifest(s)["kind"] for s in ckpt.steps()}
    assert kinds == {1: "full", 2: "delta", 3: "delta", 4: "full",
                     5: "delta", 6: "delta"}
    assert ckpt.manifest(5)["base_step"] == 4
    assert ckpt.manifest(6)["base_step"] == 5
    for s in range(1, 7):
        man = ckpt.manifest(s)
        assert man["sha256"] and man["nbytes"] > 0
        restored, got = ckpt.restore(tree, s)
        assert got == s
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(restored[k], np.float32),
                np.asarray(states[s][k], np.float32), err_msg=f"{s}:{k}")
        assert restored["b"].dtype == jnp.bfloat16
    # restore_host walks the chain too (composed leaf_{i} arrays)
    host = ckpt.restore_host(6)
    np.testing.assert_array_equal(host["leaf_0"],
                                  np.asarray(states[6]["a"]))


def test_delta_shadow_does_not_alias_numpy_leaves(tmp_path):
    """The diff shadow must hold the as-saved content: a caller mutating
    its own numpy arrays in place between saves must still get a correct
    delta (np.asarray of a numpy leaf aliases the caller's buffer)."""
    ckpt = CheckpointManager(str(tmp_path), full_interval=4)
    a = np.zeros(10, np.float32)
    ckpt.save(1, {"x": a})
    a[0] = 5.0                      # in-place mutation of the SAME buffer
    ckpt.save(2, {"x": a})
    assert ckpt.last_save_kind == "delta"
    restored, _ = ckpt.restore({"x": jnp.zeros(10, jnp.float32)}, 2)
    assert float(restored["x"][0]) == 5.0


def test_full_interval_one_is_pure_fulls(tmp_path):
    """The default manager (full_interval=1) never writes deltas — the
    pre-delta behavior, byte-compatible manifests included."""
    ckpt = CheckpointManager(str(tmp_path), full_interval=1)
    for s in (1, 2, 3):
        ckpt.save(s, {"x": jnp.full((4,), s, jnp.float32)})
        assert ckpt.last_save_kind == "full"
        assert ckpt.manifest(s)["kind"] == "full"
        assert ckpt.manifest(s)["base_step"] is None


def test_leader_election_and_failover(tmp_path):
    group = ReplicaGroup(3, CheckpointManager(str(tmp_path)))
    assert group.leader() == 0
    assert group.persist(0, 1, {"x": jnp.ones(2)})
    assert not group.persist(1, 2, {"x": jnp.ones(2)})  # non-leader blocked
    group.fail(0)
    assert group.leader() == 1
    assert group.persist(1, 3, {"x": jnp.ones(2) * 3})
    step = group.recover(0)
    assert step == 3            # cold start from latest persisted state
    assert group.leader() == 0  # lowest id resumes leadership
    assert elect_leader([]) is None


def test_elastic_reshard_subprocess():
    """Checkpoint saved unsharded restores onto a different mesh shape."""
    import subprocess, sys, textwrap
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.elastic import reshard_for_mesh
        host = {"embed": np.ones((64, 16), np.float32),
                "blocks": {"attn": {"wq": np.ones((16, 32), np.float32)}}}
        rules = [(r"embed", ("tp", None)), (r"wq", (None, "tp"))]
        for shape, names in [((4, 2), ("data", "model")),
                             ((2, 2, 2), ("pod", "data", "model"))]:
            mesh = jax.make_mesh(shape, names)
            out = reshard_for_mesh(host, mesh, rules)
            assert out["embed"].sharding.spec[0] == "model", out["embed"].sharding
            assert float(out["embed"].sum()) == 64 * 16
        # too-fine mesh on a small dim -> clear error or replicate (dropped axis)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        out = reshard_for_mesh({"embed": np.ones((64, 16), np.float32)},
                               mesh, [(r"embed", ("tp", None))])
        print("ELASTIC_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout


def test_train_loop_smoke_lm_loss_decreases():
    from repro.configs import get_arch
    from repro.models import api
    from repro.data.lm_data import LMDataConfig, SyntheticTokenStream
    cfg = get_arch("h2o-danube-1.8b").smoke_config
    data = SyntheticTokenStream(LMDataConfig(vocab_size=cfg.vocab_size,
                                             seq_len=32, batch_size=8))
    tcfg = TrainConfig(opt=optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                                             total_steps=40,
                                             master_weights=False))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(api.loss_fn(cfg), tcfg))
    first = last = None
    for s in range(40):
        params, state, m = step(params, state,
                                {"tokens": jnp.asarray(data.batch(s))})
        if s == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)
