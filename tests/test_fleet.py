"""Self-healing replicated serving fleet (the ISSUE 7 chaos property).

Covers: the full chaos composition — a 50x firehose flash crowd with the
log-writer leader killed mid-segment AND a follower crashed, while a third
replica serves every request through a degraded (slow) disk — with zero
client request failures, the fenced zombie ex-leader rejected, the durable
log healed gap-free from the survivors' rings, and every recovered
replica's engine state bit-exact against an uninterrupted single-service
run over the same stream. Plus: lag-gated readmission (a recovering
replica is invisible to routing until its lag clears; a starved catch-up
budget keeps it out forever), and epoch fencing at the writer API level.

Everything is tick-clocked (no wall time in liveness decisions), so the
chaos schedule is exactly reproducible.
"""
import os

import numpy as np
import jax
import pytest

from repro.core.background import AssistanceService
from repro.core.decay import DecayConfig
from repro.core.engine import EngineConfig
from repro.distributed.fleet import FleetConfig, ServingFleet
from repro.streaming import (FirehoseLogReader, FirehoseLogWriter,
                             FirehoseWorkload, SpamSpec, SpikeSpec,
                             WorkloadConfig, WriterFencedError, log_bases,
                             log_epoch, slow_io)


def _cfg(policy="lazy", **kw):
    base = dict(query_capacity=1 << 11, cooc_capacity=1 << 13,
                session_capacity=1 << 10, session_window=3,
                decay_every=4, prune_every=6, rank_every=5,
                region_width=16, decay=DecayConfig(policy=policy))
    base.update(kw)
    return EngineConfig(**base)


def _wl(seed=3, spike_mult=50.0, spike_at=6, **kw):
    base = dict(vocab_per_lang=128, n_langs=3, n_users=500,
                base_queries_per_tick=64, base_tweets_per_tick=8,
                min_bucket=64, min_tweet_bucket=8,
                spikes=(SpikeSpec(t_start=spike_at, mult=spike_mult),),
                spam=SpamSpec(period=9, burst_ticks=2))
    base.update(kw)
    return FirehoseWorkload(WorkloadConfig(**base), seed=seed)


def _assert_states_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"state leaf {i}")


def _all_live(fleet):
    return all(r.status == "live" for r in fleet._replicas)


# ---------------------------------------------------------------------------
# The chaos property (ISSUE 7 acceptance): 50x spike + leader kill
# mid-segment + follower kill + slow disk — zero request failures, fenced
# zombie rejected, log gap-free, recovered replicas bit-exact.
# ---------------------------------------------------------------------------

def test_fleet_chaos_leader_and_follower_kill_under_spike(tmp_path):
    rt_cfg = _cfg()
    fcfg = FleetConfig(n_replicas=3, heartbeat_timeout=2, restart_after=1,
                       snapshot_every=8, ticks_per_segment=4)
    fleet = ServingFleet(str(tmp_path), rt_cfg, fcfg)
    wl = _wl(seed=3)                      # 50x flash crowd from t=6
    ref = AssistanceService(rt_cfg, alpha=fcfg.alpha, bg_cfg=fleet.bg_cfg)

    # chaos composition: the routing path itself is degraded too — replica
    # 2 answers through a slow disk while the fleet is whole; the client's
    # timeout discards its answers, so requests that try it first hedge.
    # (The injection is undone before the kills: once two replicas are down
    # the slow one may be the only fast-path survivor left.)
    ss = fleet.serverset(timeout_s=0.01, max_retries=1)
    slow_io(fleet.handles[2], ("related",), delay_s=0.05)

    probe = int(wl.fps[0])
    n_answered = 0
    torn = None
    t, n_ticks = 0, 24
    while t < n_ticks or (t < n_ticks + 16 and not _all_live(fleet)):
        ev, tw = wl.gen_tick(t)
        if t == 7:                        # kill the LEADER mid-segment
            fleet.handles[2]._slow_io_undo()
            assert fleet.leader() == 0
            torn = fleet.kill(0, mid_segment=True)
        if t == 12:                       # kill a follower (replica 2)
            assert fleet._replicas[2].status == "live" and fleet.leader() != 2
            fleet.kill(2)
        fleet.offer_tick(t, ev, tw)
        res = ss.request_info(probe)      # raises iff NO live replica answers
        assert isinstance(res.suggestions, list)
        n_answered += 1
        ref.step(ev, tw)
        t += 1

    # zero failed requests throughout the kills, failovers and recoveries
    assert n_answered == t >= n_ticks
    assert _all_live(fleet), fleet.metrics()
    assert torn is not None               # the crash really tore a segment
    # the slow replica forced real hedges (and timeouts) along the way
    assert ss.n_hedged > 0 and ss.n_timeouts > 0

    m = fleet.metrics()
    assert m["n_deaths_detected"] == 2 and m["n_recoveries"] == 2
    # failover 0->1 at detection, then 0 retakes on readmission
    assert m["n_failovers"] == 2 and m["epoch"] == 2
    assert m["leader"] == 0

    # the log healed gap-free from the survivors' rings: ticks the dead
    # leader had buffered (and the undetected-death window) were re-appended
    assert m["n_healed_ticks"] >= 3 and m["n_lost_ticks"] == 0
    fleet._replicas[fleet.leader()].writer.flush()
    reader = FirehoseLogReader(fleet.log_dir)
    ticks = [tk for tk, _, _ in reader.read_ticks(0)]
    assert ticks == list(range(t)), "durable log must be gap-free"

    # the fenced zombie: an ex-leader writer still at epoch 0 wakes up and
    # tries to append — rejected before any bytes land, manifest untouched
    epoch = log_epoch(fleet.log_dir)
    assert epoch == m["epoch"] == 2
    segs_before = [(s.first, s.last, s.sha256) for s in reader.segments]
    zombie = FirehoseLogWriter(fleet.log_dir, ticks_per_segment=4, epoch=0)
    with pytest.raises(WriterFencedError):
        zombie.append(t + 100, ev, tw)
    with pytest.raises(WriterFencedError):
        zombie.assume_epoch(1)            # cannot rewind the fence either
    assert log_epoch(fleet.log_dir) == epoch
    reader.refresh()
    assert [(s.first, s.last, s.sha256) for s in reader.segments] \
        == segs_before

    # every replica — the survivor AND both recovered ones — is bit-exact
    # against the uninterrupted single-service reference run
    states = fleet.states()
    assert set(states) == {0, 1, 2}
    for rid, (rt_state, bg_state) in states.items():
        _assert_states_equal(ref.rt.state, rt_state)
        _assert_states_equal(ref.bg.state, bg_state)
    assert fleet._replicas[0].n_restarts == 1
    assert fleet._replicas[1].n_restarts == 0
    assert fleet._replicas[2].n_restarts == 1


# ---------------------------------------------------------------------------
# Lag-gated readmission
# ---------------------------------------------------------------------------

def test_replica_readmitted_only_when_lag_clears(tmp_path):
    """A restarted replica recovers to the SEALED log head only — until a
    seal covers the current tick it stays ``recovering``: out of routing
    (``alive`` False), out of membership, invisible to clients. Readmission
    happens exactly when catch-up reaches the live tick, and the readmitted
    state is bit-exact with the uninterrupted run."""
    rt_cfg = _cfg()
    fcfg = FleetConfig(n_replicas=2, heartbeat_timeout=0, restart_after=1,
                       catchup_budget_ticks=6, ticks_per_segment=4,
                       snapshot_every=4)
    fleet = ServingFleet(str(tmp_path), rt_cfg, fcfg)
    # flat load, one query bucket size (spam bursts included), no tweet
    # lane: constant shapes mean segments seal exactly at ticks_per_segment
    # boundaries, so the readmission tick is exact
    wl = _wl(seed=5, spike_mult=1.0, min_bucket=256,
             base_tweets_per_tick=0)
    ref = AssistanceService(rt_cfg, alpha=fcfg.alpha, bg_cfg=fleet.bg_cfg)
    ss = fleet.serverset()
    probe = int(wl.fps[0])
    status_at = {}
    for t in range(12):
        ev, tw = wl.gen_tick(t)
        if t == 4:
            fleet.kill(1)                 # follower: no failover involved
        fleet.offer_tick(t, ev, tw)
        res = ss.request_info(probe)
        status_at[t] = fleet._replicas[1].status
        if status_at[t] != "live":
            # a dead/recovering replica is never routed to
            assert not fleet.handles[1].alive
            assert res.replica == 0 and res.attempts == 1
        ref.step(ev, tw)

    # killed before tick 4 -> detected at 4 -> restarted at 5 -> the log is
    # only sealed through 3 there, so it must WAIT (recovering) until the
    # segment 4..7 seals at tick 7, then catch up and rejoin
    assert status_at[4] == "dead"
    assert status_at[5] == status_at[6] == "recovering"
    assert status_at[7] == "live"
    assert fleet.metrics()["n_recoveries"] == 1
    assert fleet.metrics()["n_failovers"] == 0   # leader 0 never wavered
    for rid, (rt_state, bg_state) in fleet.states().items():
        _assert_states_equal(ref.rt.state, rt_state)
        _assert_states_equal(ref.bg.state, bg_state)


def test_starved_catchup_budget_keeps_replica_quarantined(tmp_path):
    """With a catch-up budget slower than the hose, a recovering replica
    can never clear its lag: the gate keeps it out of routing indefinitely
    (stale answers are never served from it) while the survivor answers."""
    rt_cfg = _cfg()
    fcfg = FleetConfig(n_replicas=2, heartbeat_timeout=0, restart_after=1,
                       catchup_budget_ticks=1, ticks_per_segment=4,
                       snapshot_every=4)
    fleet = ServingFleet(str(tmp_path), rt_cfg, fcfg)
    wl = _wl(seed=7, spike_mult=1.0, min_bucket=256,
             base_tweets_per_tick=0)    # constant shapes: exact seal points
    ss = fleet.serverset()
    probe = int(wl.fps[0])
    for t in range(14):
        ev, tw = wl.gen_tick(t)
        if t == 4:
            fleet.kill(1)
        fleet.offer_tick(t, ev, tw)
        res = ss.request_info(probe)
        if t >= 4:
            assert res.replica == 0
    rep = fleet._replicas[1]
    assert rep.status == "recovering" and not fleet.handles[1].alive
    assert fleet.metrics()["n_recoveries"] == 0
    # ... but it IS making (budgeted) progress behind the gate
    assert int(rep.service.rt.state.tick) > 4
    assert int(rep.service.rt.state.tick) < 15


# ---------------------------------------------------------------------------
# Compaction under chaos (PR 8 acceptance): the leader folds the log into
# bases on cadence WHILE being killed mid-segment — restarted replicas can
# only recover via base + tail (no snapshots at all), retention stays
# bounded, and every replica ends bit-exact vs the uninterrupted reference.
# ---------------------------------------------------------------------------

def test_fleet_chaos_compaction_concurrent_with_leader_kill(tmp_path):
    rt_cfg = _cfg()
    # snapshot_every=0: no persisted snapshots anywhere — cold restarts
    # MUST ride the compaction tier (the log below the floor is trimmed,
    # so a from-zero replay without the base hop would hit a hard gap)
    fcfg = FleetConfig(n_replicas=3, heartbeat_timeout=2, restart_after=1,
                       snapshot_every=0, ticks_per_segment=4,
                       compact_every=4, keep_bases=2)
    fleet = ServingFleet(str(tmp_path), rt_cfg, fcfg)
    wl = _wl(seed=3)                      # 50x flash crowd from t=6
    ref = AssistanceService(rt_cfg, alpha=fcfg.alpha, bg_cfg=fleet.bg_cfg)
    ss = fleet.serverset(timeout_s=0.5, max_retries=1)

    probe = int(wl.fps[0])
    n_answered = 0
    torn = None
    t, n_ticks = 0, 24
    while t < n_ticks or (t < n_ticks + 16 and not _all_live(fleet)):
        ev, tw = wl.gen_tick(t)
        if t == 7:                        # kill the LEADER mid-segment —
            assert fleet.leader() == 0    # right after the t=3 compaction
            torn = fleet.kill(0, mid_segment=True)
        fleet.offer_tick(t, ev, tw)
        res = ss.request_info(probe)      # raises iff NO live replica answers
        assert isinstance(res.suggestions, list)
        n_answered += 1
        ref.step(ev, tw)
        t += 1

    assert n_answered == t >= n_ticks
    assert _all_live(fleet), fleet.metrics()
    assert torn is not None               # the crash really tore a segment

    m = fleet.metrics()
    assert m["n_deaths_detected"] == 1 and m["n_recoveries"] == 1
    # compaction kept running across the failover: cycles landed both at
    # epoch 0 (t=3) and under the new leader's epoch
    assert m["n_compactions"] >= 3
    assert m["n_log_bases"] == fcfg.keep_bases
    assert m["log_floor_tick"] >= 12
    epochs = {int(b["epoch"]) for b in log_bases(fleet.log_dir)}
    assert max(epochs) >= 1               # a post-failover leader compacted

    # bounded retention: the log tail starts at the oldest retained base,
    # everything below it left the manifest AND the disk — yet the tail is
    # gap-free from there to the live head
    fleet._replicas[fleet.leader()].writer.flush()
    reader = FirehoseLogReader(fleet.log_dir)
    retain_floor = min(int(b["tick"]) for b in reader.bases)
    assert retain_floor > 0
    assert reader.first_tick() == min(s.first for s in reader.segments)
    assert reader.first_tick() <= retain_floor
    assert all(s.last >= retain_floor for s in reader.segments)
    ticks = [tk for tk, _, _ in reader.read_ticks(reader.first_tick())]
    assert ticks == list(range(reader.first_tick(), t)), \
        "compacted log tail must stay gap-free up to the head"

    # the restarted ex-leader really recovered through the base tier
    rec = fleet._replicas[0].last_recovery
    assert rec["rt"]["base"] is not None and rec["bg"]["base"] is not None
    assert rec["rt"]["base"]["base_tick"] > 0
    assert rec["rt"]["restored_step"] is None     # no snapshot existed

    # ... and every replica is bit-exact vs the uninterrupted reference
    states = fleet.states()
    assert set(states) == {0, 1, 2}
    for rid, (rt_state, bg_state) in states.items():
        _assert_states_equal(ref.rt.state, rt_state)
        _assert_states_equal(ref.bg.state, bg_state)
    assert fleet._replicas[0].n_restarts == 1
