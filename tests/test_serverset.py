"""ServerSet routing semantics: health, staleness, hedging, breakers.

Covers the failure paths with duck-typed fake replicas (no engines): dead
replicas skipped outright, all-dead raising a clear error, freshest-first
staleness ordering with round-robin tie-breaking, hedging on raise AND on
timeout (the slow answer is discarded), the per-replica circuit breaker
opening/half-open probing, retry passes with backoff, and the RouteResult
staleness tagging.
"""
import time

import pytest

from repro.serving.serve import RouteResult, ServerSet


class _Fake:
    """Duck-typed replica: scripted liveness/freshness/faults."""

    def __init__(self, name, tick=None, alive=True, fail=0, delay=0.0):
        self.name = name
        self.alive = alive
        self.tick = tick
        self.fail = fail            # raise on the first `fail` calls (-1 = always)
        self.delay = delay
        self.calls = 0

    def freshness_tick(self):
        return self.tick

    def related(self, query, k=8):
        self.calls += 1
        if self.fail == -1 or self.calls <= self.fail:
            raise ConnectionError(f"{self.name} is down")
        if self.delay:
            time.sleep(self.delay)
        return [(self.name, 1.0)]


def test_dead_replica_skipped_outright():
    dead, live = _Fake("dead", alive=False), _Fake("live", tick=4)
    ss = ServerSet([dead, live])
    res = ss.request_info("breaking news")
    assert res.suggestions == [("live", 1.0)]
    assert res.replica == 1 and res.attempts == 1 and not res.hedged
    assert dead.calls == 0, "a dead replica must never even be tried"


def test_all_dead_raises_clear_error():
    ss = ServerSet([_Fake("a", alive=False), _Fake("b", alive=False)])
    with pytest.raises(RuntimeError, match="no live frontend replicas"):
        ss.request("q")
    # all live but all failing exhausts every retry pass, then raises with
    # the per-replica errors in the message
    ss = ServerSet([_Fake("a", fail=-1), _Fake("b", fail=-1)], max_retries=1)
    with pytest.raises(RuntimeError, match="ConnectionError"):
        ss.request("q")
    assert ss.n_failures == 4    # 2 replicas x 2 passes


def test_staleness_ordering_picks_freshest():
    stale, fresh, mid = _Fake("stale", tick=5), _Fake("fresh", tick=9), \
        _Fake("mid", tick=7)
    ss = ServerSet([stale, fresh, mid])
    res = ss.request_info("q")
    assert res.suggestions == [("fresh", 1.0)]
    assert res.tick == 9 and res.staleness == 0 and not res.hedged
    # a replica with no freshness at all sorts last
    assert ServerSet([_Fake("unknown"), fresh]).request("q") \
        == [("fresh", 1.0)]


def test_hedge_to_next_freshest_and_staleness_tag():
    fresh = _Fake("fresh", tick=9, fail=-1)       # freshest but broken
    backup = _Fake("backup", tick=7)
    ss = ServerSet([backup, fresh])
    res = ss.request_info("q")
    assert res.suggestions == [("backup", 1.0)]
    assert res.hedged and res.attempts == 2
    # the answer is honest about being stale vs the freshest LIVE replica
    assert res.tick == 7 and res.staleness == 2
    assert ss.n_hedged == 1 and ss.n_failures == 1


def test_timeout_discards_slow_answer_and_hedges():
    slow = _Fake("slow", tick=9, delay=0.05)      # freshest but too slow
    fast = _Fake("fast", tick=8)
    ss = ServerSet([slow, fast], timeout_s=0.01)
    res = ss.request_info("q")
    assert res.suggestions == [("fast", 1.0)]     # slow answer discarded
    assert res.hedged and ss.n_timeouts == 1 and ss.n_failures == 1
    assert slow.calls == 1


def test_equal_freshness_rotates_round_robin():
    a, b = _Fake("a", tick=5), _Fake("b", tick=5)
    ss = ServerSet([a, b])
    served = {ss.request("q")[0][0] for _ in range(4)}
    assert served == {"a", "b"}, "equally-fresh replicas must share load"


def test_circuit_breaker_opens_and_half_open_probes():
    flaky = _Fake("flaky", tick=9, fail=-1)       # freshest, always failing
    ok = _Fake("ok", tick=5)
    ss = ServerSet([flaky, ok], breaker_failures=2, breaker_cooldown=4)
    # first two requests: flaky tried first (freshest), fails, hedged
    for _ in range(2):
        assert ss.request("q") == [("ok", 1.0)]
    assert flaky.calls == 2 and ss.n_hedged == 2
    # breaker now open: flaky demoted to last resort, not tried at all
    for _ in range(3):
        assert ss.request("q") == [("ok", 1.0)]
    assert flaky.calls == 2 and ss.n_breaker_skips >= 3
    assert ss.n_hedged == 2, "no hedges while the breaker shields the flaky"
    # cooldown expiry: one half-open probe goes through (and fails again)
    for _ in range(4):
        ss.request("q")
    assert flaky.calls >= 3
    # recovery: flaky comes back healthy; the probe closes the breaker and
    # freshest-first routing resumes
    flaky.fail = 0
    for _ in range(8):
        last = ss.request_info("q")
    assert last.suggestions == [("flaky", 1.0)] and last.staleness == 0


def test_retry_pass_with_backoff_recovers_transient_fault():
    # both replicas fail once (a transient blip), succeed on the retry pass
    a, b = _Fake("a", tick=3, fail=1), _Fake("b", tick=3, fail=1)
    ss = ServerSet([a, b], max_retries=1, backoff_s=0.001)
    res = ss.request_info("q")
    assert res.suggestions in ([("a", 1.0)], [("b", 1.0)])
    assert res.attempts == 3 and res.hedged
    assert ss.n_failures == 2
    # without any retry budget the same blip is fatal
    a2, b2 = _Fake("a", tick=3, fail=1), _Fake("b", tick=3, fail=1)
    with pytest.raises(RuntimeError):
        ServerSet([a2, b2], max_retries=0).request("q")


def test_route_result_fields_without_freshness():
    ss = ServerSet([_Fake("anon")])               # freshness unknown
    res = ss.request_info("q")
    assert isinstance(res, RouteResult)
    assert res.tick is None and res.staleness is None
    assert res.replica == 0 and res.attempts == 1
