"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (no NaNs)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.models import api
from repro.models.api import ShapeCell

# reduced shape cells per family (the FULL cells are dry-run only)
_SMOKE_CELLS = {
    "lm": {
        "train": ShapeCell("train_smoke", "train", {"batch": 2, "seq": 32}),
        "prefill": ShapeCell("prefill_smoke", "prefill",
                             {"batch": 2, "seq": 32, "cache_len": 32}),
        "decode": ShapeCell("decode_smoke", "decode",
                            {"batch": 2, "seq": 32, "cache_len": 32}),
    },
    "gnn": {
        "train": ShapeCell("graph_smoke", "train",
                           {"n_nodes": 64, "n_edges": 256, "d_feat": 32,
                            "n_classes": 5}),
    },
    "recsys": {
        "train": ShapeCell("train_smoke", "train", {"batch": 16}),
        "serve": ShapeCell("serve_smoke", "serve", {"batch": 8}),
        "retrieval": ShapeCell("retr_smoke", "retrieval",
                               {"batch": 1, "n_candidates": 128}),
    },
}

ALL_ARCHS = list_archs()


def _smoke_cfg(spec, cell):
    cfg = spec.smoke_config
    if spec.family == "gnn":
        from repro.configs.gat_cora import adapt_config
        cfg = adapt_config(cfg, cell)
    return cfg


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_arch_registry_complete(arch_id):
    spec = get_arch(arch_id)
    assert spec.arch_id == arch_id
    assert len(spec.shapes) == 4
    assert spec.source


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_train_step_smoke(arch_id):
    spec = get_arch(arch_id)
    cell = _SMOKE_CELLS[spec.family]["train"]
    cfg = _smoke_cfg(spec, cell)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    inputs = api.make_inputs(rng, cfg, cell)
    lf = api.loss_fn(cfg)
    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
        params, inputs["batch"])
    assert np.isfinite(float(loss)), arch_id
    gsq = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gsq) and gsq > 0, arch_id


@pytest.mark.parametrize("arch_id",
                         [a for a in ALL_ARCHS
                          if get_arch(a).family == "lm"])
def test_lm_serve_smoke(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke_config
    rng = np.random.default_rng(1)
    for kind in ("prefill", "decode"):
        cell = _SMOKE_CELLS["lm"][kind]
        inputs = api.make_inputs(rng, cfg, cell)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        fn = api.serve_fn(cfg, cell)
        logits, caches = fn(params, inputs["caches"], inputs["tokens"])
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
        assert logits.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize("arch_id",
                         [a for a in ALL_ARCHS
                          if get_arch(a).family == "recsys"])
def test_recsys_serve_and_retrieval_smoke(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.smoke_config
    rng = np.random.default_rng(2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    for kind in ("serve", "retrieval"):
        cell = _SMOKE_CELLS["recsys"][kind]
        inputs = api.make_inputs(rng, cfg, cell)
        fn = api.serve_fn(cfg, cell)
        out = fn(params, inputs["batch"])
        flat = jax.tree.leaves(out)
        for leaf in flat:
            arr = np.asarray(leaf, np.float32)
            assert np.isfinite(arr).all(), (arch_id, kind)


def test_full_configs_match_assignment():
    """The full configs must carry the exact assigned hyperparameters."""
    c = get_arch("granite-3-8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 12800, 49155)
    c = get_arch("qwen3-8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (36, 4096, 32, 8, 12288, 151936, True)
    c = get_arch("h2o-danube-1.8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2560, 32, 8, 6912, 32000)
    assert c.window > 0
    c = get_arch("mixtral-8x22b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (56, 6144, 48, 8, 32768)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff) == (8, 2, 16384)
    c = get_arch("qwen2-moe-a2.7b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (24, 2048, 16, 16, 151936)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff) == (60, 4, 1408)
    c = get_arch("gat-cora").config
    assert (c.n_layers, c.d_hidden, c.n_heads) == (2, 8, 8)
    c = get_arch("bst").config
    assert (c.embed_dim, c.seq_len, c.n_blocks, c.n_heads,
            c.mlp_dims) == (32, 20, 1, 8, (1024, 512, 256))
    c = get_arch("xdeepfm").config
    assert (c.n_fields, c.embed_dim, c.cin_layers,
            c.dnn_dims) == (39, 10, (200, 200, 200), (400, 400))
    c = get_arch("bert4rec").config
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (64, 2, 2, 200)
    c = get_arch("two-tower-retrieval").config
    assert (c.embed_dim, c.tower_mlp) == (256, (1024, 512, 256))


def test_long_500k_skip_annotations():
    """Pure full-attention archs must skip long_500k with a reason; SWA archs
    must run it."""
    for a in ("granite-3-8b", "qwen3-8b", "qwen2-moe-a2.7b"):
        assert get_arch(a).cell("long_500k").skip
    for a in ("h2o-danube-1.8b", "mixtral-8x22b"):
        assert get_arch(a).cell("long_500k").skip is None


def test_cell_count_is_40():
    n = sum(len(get_arch(a).shapes) for a in ALL_ARCHS)
    assert n == 40
