"""Sharded (beyond-paper) engine == unsharded engine, on 8 virtual devices.

Runs in a subprocess because the 8-device XLA flag must be set before jax
initializes (the main pytest process keeps the default 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.engine import EngineConfig, SearchAssistanceEngine
    from repro.core import sharded_engine as se
    from repro.core.hashing import split_fp
    from repro.data.stream import StreamConfig, SyntheticStream

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shard",))
    ecfg = EngineConfig(query_capacity=1<<12, cooc_capacity=1<<15,
                        session_capacity=1<<12, session_window=4,
                        decay_every=4, rank_every=0)
    scfg = se.ShardedConfig(base=ecfg, n_salts=2, hot_threshold=30.0,
                            route_capacity=1024)
    step = se.make_sharded_step(scfg, mesh)
    decay = se.make_sharded_decay(scfg, mesh)
    rank = se.make_sharded_rank(scfg, mesh)
    state = se.init_sharded_state(scfg, mesh)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=200,
                                          queries_per_tick=192,
                                          tweets_per_tick=0), seed=5)
    eng = SearchAssistanceEngine(ecfg)
    for t in range(6):
        ev, tw = stream.gen_tick(t)
        s_hi, s_lo = split_fp(ev.sess_fp); q_hi, q_lo = split_fp(ev.q_fp)
        state = step(state, jnp.asarray(s_hi), jnp.asarray(s_lo),
                     jnp.asarray(q_hi), jnp.asarray(q_lo),
                     jnp.asarray(ev.src, jnp.int32), jnp.asarray(ev.valid))
        eng.step(ev, None)
        if t > 0 and t % ecfg.decay_every == 0:
            state = decay(state, jnp.int32(ecfg.decay_every))
        state = state._replace(tick=state.tick + 1)
    assert np.asarray(state.n_route_drop).sum() == 0, "routing overflow"
    merged = se.merge_sharded_suggestions(rank(state), ecfg.rank.top_k)
    eng.run_rank_cycle()
    ref = eng.suggestions
    assert set(merged) == set(ref), (len(merged), len(ref))
    n_score_ok = 0
    for f in merged:
        ms = sorted([s for _, s in merged[f]], reverse=True)[:3]
        rs = sorted([s for _, s in ref[f]], reverse=True)[:3]
        np.testing.assert_allclose(ms, rs, rtol=5e-3, atol=1e-4)
        n_score_ok += 1
    print(f"SHARDED_OK {len(merged)} keys, {n_score_ok} score-matched")
""")


@pytest.mark.slow
def test_sharded_engine_matches_unsharded_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SHARDED_OK" in r.stdout


_REPLAY_SCRIPT = textwrap.dedent("""
    import tempfile
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.engine import EngineConfig
    from repro.core import sharded_engine as se
    from repro.core.decay import DecayConfig
    from repro.core.hashing import split_fp
    from repro.data.stream import StreamConfig, SyntheticStream
    from repro.distributed.fault_tolerance import CheckpointManager

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shard",))
    ecfg = EngineConfig(query_capacity=1<<12, cooc_capacity=1<<15,
                        session_capacity=1<<12, session_window=4,
                        decay_every=3, prune_every=5, rank_every=0,
                        decay=DecayConfig(policy="lazy"))
    scfg = se.ShardedConfig(base=ecfg, n_salts=2, hot_threshold=30.0,
                            route_capacity=1024)
    tick_step = se.make_sharded_tick_step(scfg, mesh)
    many = se.make_sharded_ingest_many(scfg, mesh)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=200,
                                          queries_per_tick=192,
                                          tweets_per_tick=0), seed=5)
    batches = []
    for t in range(8):
        ev, _ = stream.gen_tick(t)
        s_hi, s_lo = split_fp(ev.sess_fp); q_hi, q_lo = split_fp(ev.q_fp)
        batches.append(tuple(jnp.asarray(x) for x in
                       (s_hi, s_lo, q_hi, q_lo,
                        ev.src.astype(np.int32), ev.valid)))

    # uninterrupted live run (one full tick step per batch)
    live = se.init_sharded_state(scfg, mesh)
    for b in batches:
        live = tick_step(live, *b)

    # crash after tick 4: snapshot + parallel catch-up replay of the tail.
    # The snapshots are DELTA-CHAINED (full_interval=4): tick 2 writes the
    # full, tick 4 writes only the changed leading rows of each shard-
    # stacked leaf; restore composes the chain transparently.
    half = se.init_sharded_state(scfg, mesh)
    ckpt = CheckpointManager(tempfile.mkdtemp(), full_interval=4)
    for i, b in enumerate(batches[:4]):
        half = tick_step(half, *b)
        if i in (1, 3):
            se.save_sharded_snapshot(half, ckpt)
    assert ckpt.last_save_kind == "delta", ckpt.last_save_kind
    restored, log_tick = se.restore_sharded_snapshot(scfg, mesh, ckpt)
    assert log_tick == 4
    stacked = tuple(jnp.stack([b[i] for b in batches[4:]]) for i in range(6))
    caught_up = many(restored, *stacked)
    la, _ = jax.tree.flatten(live); lb, _ = jax.tree.flatten(caught_up)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"leaf {i}")
    print("SHARDED_REPLAY_OK tick", int(np.asarray(caught_up.tick)))
""")


@pytest.mark.slow
def test_sharded_replay_matches_live_subprocess():
    """Snapshot + fused parallel replay == uninterrupted sharded run
    (bit-for-bit), on 8 virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _REPLAY_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SHARDED_REPLAY_OK" in r.stdout


_RESHARD_SCRIPT = textwrap.dedent("""
    import tempfile
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.engine import EngineConfig
    from repro.core import sharded_engine as se
    from repro.core.decay import DecayConfig
    from repro.core.hashing import split_fp
    from repro.data.stream import StreamConfig, SyntheticStream
    from repro.distributed.elastic import live_reshard, sharded_pressure
    from repro.streaming.log import FirehoseLogWriter

    LAYOUT = "%(layout)s"
    devs = np.array(jax.devices())
    mesh2 = Mesh(devs[:2], ("shard",))
    mesh4 = Mesh(devs[:4], ("shard",))
    ecfg = EngineConfig(query_capacity=1<<12, cooc_capacity=1<<15,
                        session_capacity=1<<12, session_window=4,
                        decay_every=3, prune_every=5, rank_every=0,
                        cooc_layout=LAYOUT, region_width=16,
                        decay=DecayConfig(policy="lazy"))
    scfg = se.ShardedConfig(base=ecfg, n_salts=2, hot_threshold=30.0,
                            route_capacity=1024)
    step2 = se.make_sharded_tick_step(scfg, mesh2)
    step4 = se.make_sharded_tick_step(scfg, mesh4)
    rank2 = se.make_sharded_rank(scfg, mesh2)
    rank4 = se.make_sharded_rank(scfg, mesh4)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=200,
                                          queries_per_tick=192,
                                          tweets_per_tick=0), seed=5)
    batches, raw = [], []
    for t in range(12):
        ev, _ = stream.gen_tick(t)
        raw.append(ev)
        s_hi, s_lo = split_fp(ev.sess_fp); q_hi, q_lo = split_fp(ev.q_fp)
        batches.append(tuple(jnp.asarray(x) for x in
                       (s_hi, s_lo, q_hi, q_lo,
                        ev.src.astype(np.int32), ev.valid)))
    logd = tempfile.mkdtemp()
    w = FirehoseLogWriter(logd, ticks_per_segment=2)
    for t, ev in enumerate(raw[:10]):   # the log ends inside the split
        w.append(t, ev, None)           # window: ticks 10,11 are post-swap
    w.close()

    def top1(m):
        return {f: max(s for _, s in v) for f, v in m.items() if v}

    def run_with_live_split():
        # 2-shard live run to tick 8; the split window covers ticks 8-9:
        # the OLD layout keeps serving them while the snapshot is
        # re-partitioned to 4 shards and caught up from the shared log.
        st = se.init_sharded_state(scfg, mesh2)
        for b in batches[:8]:
            st = step2(st, *b)
        old = st
        for b in batches[8:10]:
            old = step2(old, *b)           # zero downtime: old serves 8,9
        new, stats = live_reshard(scfg, st, 4, mesh4, log_dir=logd,
                                  chunk_ticks=4)
        assert stats["old_n"] == 2 and stats["new_n"] == 4
        assert stats["replayed_ticks"] == 2, stats
        assert stats["n_pair_drop"] == 0 and stats["n_sess_drop"] == 0
        assert int(np.asarray(new.tick)) == 10 == int(np.asarray(old.tick))
        m_old = se.merge_sharded_suggestions(rank2(old), ecfg.rank.top_k)
        m_new = se.merge_sharded_suggestions(rank4(new), ecfg.rank.top_k)
        assert m_old, "old layout must answer throughout the window"
        # the handoff loses no queries ...
        assert set(m_new) == set(m_old), (len(m_new), len(m_old))
        # ... or mass: resharding consolidates salted duplicates by SUM,
        # while the live merge can only MAX over fragments - so per-query
        # top scores may only grow across the handoff
        t_old, t_new = top1(m_old), top1(m_new)
        assert all(t_new[f] >= t_old[f] - 1e-5 for f in t_old)
        for b in batches[10:]:             # swap: serve live on 4 shards
            new = step4(new, *b)
        return new

    a = run_with_live_split()
    b = run_with_live_split()
    # schedule parity: an identical split schedule is bit-reproducible
    la, ta = jax.tree.flatten(a); lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"leaf {i}")
    p = sharded_pressure(a, ecfg)
    assert p["route_drop"] == 0
    if LAYOUT == "region":
        assert 0.0 <= p["free_region_frac"] <= 1.0

    # scale back in: merge 4 -> 2 keeps every query answerable
    m4 = se.merge_sharded_suggestions(rank4(a), ecfg.rank.top_k)
    merged, mstats = live_reshard(scfg, a, 2, mesh2, log_dir=logd)
    assert mstats["new_n"] == 2 and mstats["replayed_ticks"] == 0
    m2 = se.merge_sharded_suggestions(rank2(merged), ecfg.rank.top_k)
    assert set(m2) == set(m4)
    print(f"RESHARD_OK {LAYOUT} {len(m2)} keys")
""")


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["hash", "region"])
def test_live_shard_split_merge_subprocess(layout):
    """Live 2->4 shard split under load (old layout answers the ticks that
    arrive during the window; the new layout catches up from the shared
    log), schedule-parity bit-exactness, no lost queries/mass across the
    handoff, and a 4->2 merge — on virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c",
                        _RESHARD_SCRIPT % {"layout": layout}], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "RESHARD_OK" in r.stdout


def test_shard_autoscaler_hysteresis():
    """Split/merge decisions need SUSTAINED pressure/idleness (hold_ticks),
    respect the min/max bounds, and trigger off any of the three signals
    (region freelist, replay lag, routing drops). Pure host logic."""
    from repro.distributed.elastic import AutoscaleConfig, ShardAutoscaler
    asc = ShardAutoscaler(AutoscaleConfig(hold_ticks=2, max_shards=8,
                                          min_shards=2))
    assert asc.observe(4, free_region_frac=0.05) == 4   # one spiky tick:
    assert asc.observe(4, free_region_frac=0.50) == 4   # no reshard (reset)
    assert asc.observe(4, free_region_frac=0.05) == 4
    assert asc.observe(4, free_region_frac=0.05) == 8   # held 2 -> split
    assert asc.observe(8, free_region_frac=0.90) == 8   # idleness holds too
    assert asc.observe(8, free_region_frac=0.90) == 4   # held 2 -> merge
    bounded = ShardAutoscaler(AutoscaleConfig(hold_ticks=1, max_shards=4,
                                              min_shards=4))
    assert bounded.observe(4, free_region_frac=0.01) == 4   # at max_shards
    assert bounded.observe(4, free_region_frac=0.90) == 4   # at min_shards
    multi = ShardAutoscaler(AutoscaleConfig(hold_ticks=1, max_shards=8))
    assert multi.observe(2, free_region_frac=None, lag_ticks=9.0) == 4
    assert multi.observe(2, free_region_frac=None, route_drop_rate=1.0) == 4
