"""Sharded (beyond-paper) engine == unsharded engine, on 8 virtual devices.

Runs in a subprocess because the 8-device XLA flag must be set before jax
initializes (the main pytest process keeps the default 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.engine import EngineConfig, SearchAssistanceEngine
    from repro.core import sharded_engine as se
    from repro.core.hashing import split_fp
    from repro.data.stream import StreamConfig, SyntheticStream

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shard",))
    ecfg = EngineConfig(query_capacity=1<<12, cooc_capacity=1<<15,
                        session_capacity=1<<12, session_window=4,
                        decay_every=4, rank_every=0)
    scfg = se.ShardedConfig(base=ecfg, n_salts=2, hot_threshold=30.0,
                            route_capacity=1024)
    step = se.make_sharded_step(scfg, mesh)
    decay = se.make_sharded_decay(scfg, mesh)
    rank = se.make_sharded_rank(scfg, mesh)
    state = se.init_sharded_state(scfg, mesh)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=200,
                                          queries_per_tick=192,
                                          tweets_per_tick=0), seed=5)
    eng = SearchAssistanceEngine(ecfg)
    for t in range(6):
        ev, tw = stream.gen_tick(t)
        s_hi, s_lo = split_fp(ev.sess_fp); q_hi, q_lo = split_fp(ev.q_fp)
        state = step(state, jnp.asarray(s_hi), jnp.asarray(s_lo),
                     jnp.asarray(q_hi), jnp.asarray(q_lo),
                     jnp.asarray(ev.src, jnp.int32), jnp.asarray(ev.valid))
        eng.step(ev, None)
        if t > 0 and t % ecfg.decay_every == 0:
            state = decay(state, jnp.int32(ecfg.decay_every))
        state = state._replace(tick=state.tick + 1)
    assert np.asarray(state.n_route_drop).sum() == 0, "routing overflow"
    merged = se.merge_sharded_suggestions(rank(state), ecfg.rank.top_k)
    eng.run_rank_cycle()
    ref = eng.suggestions
    assert set(merged) == set(ref), (len(merged), len(ref))
    n_score_ok = 0
    for f in merged:
        ms = sorted([s for _, s in merged[f]], reverse=True)[:3]
        rs = sorted([s for _, s in ref[f]], reverse=True)[:3]
        np.testing.assert_allclose(ms, rs, rtol=5e-3, atol=1e-4)
        n_score_ok += 1
    print(f"SHARDED_OK {len(merged)} keys, {n_score_ok} score-matched")
""")


@pytest.mark.slow
def test_sharded_engine_matches_unsharded_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SHARDED_OK" in r.stdout


_REPLAY_SCRIPT = textwrap.dedent("""
    import tempfile
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.engine import EngineConfig
    from repro.core import sharded_engine as se
    from repro.core.decay import DecayConfig
    from repro.core.hashing import split_fp
    from repro.data.stream import StreamConfig, SyntheticStream
    from repro.distributed.fault_tolerance import CheckpointManager

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("shard",))
    ecfg = EngineConfig(query_capacity=1<<12, cooc_capacity=1<<15,
                        session_capacity=1<<12, session_window=4,
                        decay_every=3, prune_every=5, rank_every=0,
                        decay=DecayConfig(policy="lazy"))
    scfg = se.ShardedConfig(base=ecfg, n_salts=2, hot_threshold=30.0,
                            route_capacity=1024)
    tick_step = se.make_sharded_tick_step(scfg, mesh)
    many = se.make_sharded_ingest_many(scfg, mesh)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=200,
                                          queries_per_tick=192,
                                          tweets_per_tick=0), seed=5)
    batches = []
    for t in range(8):
        ev, _ = stream.gen_tick(t)
        s_hi, s_lo = split_fp(ev.sess_fp); q_hi, q_lo = split_fp(ev.q_fp)
        batches.append(tuple(jnp.asarray(x) for x in
                       (s_hi, s_lo, q_hi, q_lo,
                        ev.src.astype(np.int32), ev.valid)))

    # uninterrupted live run (one full tick step per batch)
    live = se.init_sharded_state(scfg, mesh)
    for b in batches:
        live = tick_step(live, *b)

    # crash after tick 4: snapshot + parallel catch-up replay of the tail.
    # The snapshots are DELTA-CHAINED (full_interval=4): tick 2 writes the
    # full, tick 4 writes only the changed leading rows of each shard-
    # stacked leaf; restore composes the chain transparently.
    half = se.init_sharded_state(scfg, mesh)
    ckpt = CheckpointManager(tempfile.mkdtemp(), full_interval=4)
    for i, b in enumerate(batches[:4]):
        half = tick_step(half, *b)
        if i in (1, 3):
            se.save_sharded_snapshot(half, ckpt)
    assert ckpt.last_save_kind == "delta", ckpt.last_save_kind
    restored, log_tick = se.restore_sharded_snapshot(scfg, mesh, ckpt)
    assert log_tick == 4
    stacked = tuple(jnp.stack([b[i] for b in batches[4:]]) for i in range(6))
    caught_up = many(restored, *stacked)
    la, _ = jax.tree.flatten(live); lb, _ = jax.tree.flatten(caught_up)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"leaf {i}")
    print("SHARDED_REPLAY_OK tick", int(np.asarray(caught_up.tick)))
""")


@pytest.mark.slow
def test_sharded_replay_matches_live_subprocess():
    """Snapshot + fused parallel replay == uninterrupted sharded run
    (bit-for-bit), on 8 virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _REPLAY_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SHARDED_REPLAY_OK" in r.stdout
