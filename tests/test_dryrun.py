"""Dry-run machinery under test: one LM cell + one recsys cell compile on
the production meshes in a subprocess (512 virtual devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    from repro.launch.dryrun import build_cell   # sets XLA_FLAGS first
    for arch, shape, mp in [("h2o-danube-1.8b", "long_500k", True),
                            ("xdeepfm", "serve_p99", False),
                            ("granite-3-8b", "long_500k", False)]:
        row = build_cell(arch, shape, mp)
        assert row["status"] in ("ok", "skipped"), row
        if row["status"] == "ok":
            assert row["roofline_fraction"] >= 0
            mem = row.get("memory_per_device") or {}
            peak = mem.get("peak_bytes") or 0
            assert peak < 17e9, f"{arch}/{shape} exceeds 16GB: {peak/1e9:.1f}GB"
        print("CELL_OK", arch, shape, row["status"])
    print("DRYRUN_OK")
""")


@pytest.mark.slow
def test_dryrun_cells_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # dryrun.py sets its own
    env["PYTEST_ALLOW_DEVICES"] = "1"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "DRYRUN_OK" in r.stdout


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes, shape_bytes
    hlo = """
      %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[512]{0} all-reduce(%y), to_apply=%add
      %junk = f32[8]{0} add(%a, %b)
      %a2a = (s32[4]{0}, s32[4]{0}) all-to-all(%c, %d)
      %ppp = bf16[2,2]{1,0} collective-permute-start(%e)
      %qqq = bf16[2,2]{1,0} collective-permute-done(%ppp)
    """
    total, by_kind, counts = collective_bytes(hlo)
    assert by_kind["all-gather"] == 16 * 1024 * 2
    assert by_kind["all-reduce"] == 512 * 4
    assert by_kind["all-to-all"] == 4 * 4 * 2
    assert counts["collective-permute"] == 1   # -done skipped
    assert shape_bytes("bf16", "16,1024") == 32768


def test_fusion_aware_bytes_excludes_elementwise():
    from repro.launch.roofline import fusion_aware_bytes
    hlo = """
      %p0 = f32[1024]{0} parameter(0)
      %m = f32[1024]{0} multiply(%p0, %p0)
      %d = f32[64,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}
    """
    b = fusion_aware_bytes(hlo)
    # parameter once + dot result twice; multiply excluded (fuses on TPU)
    assert b == 1024 * 4 + 2 * 64 * 64 * 4
