"""Parity + property tests for the fused single-sweep store update path.

The fused find-or-claim probe (``stores._find_or_claim``) must preserve the
*semantics* of the pre-fusion two-pass reference (``insert_accumulate_twopass``,
kept verbatim): identical key -> accumulated-value maps, and exact n_dropped
accounting — a batch's unique keys are either fully applied or dropped and
counted, never partially applied or silently lost. Claim *winners* may differ
between the two conflict-resolution strategies, so near-full assertions are on
conservation, not on bit-identical placement.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import stores
from repro.core.decay import DecayConfig, sweep_decay_prune
from repro.core.hashing import split_fp, join_fp
from proptest import property_test

MODES = (("weight", "add"), ("count", "add"), ("last_tick", "set"))


def _mk(capacity):
    return stores.make_table(capacity, {
        "weight": jnp.float32, "count": jnp.float32, "last_tick": jnp.int32})


def _upd(n, w, tick=0):
    return {"weight": jnp.asarray(w, jnp.float32),
            "count": jnp.ones(n, jnp.float32),
            "last_tick": jnp.full(n, tick, jnp.int32)}


def _ins(fn, t, fps, w, valid=None, tick=0):
    fps = np.asarray(fps, np.uint64)
    hi, lo = split_fp(fps)
    n = len(fps)
    valid = np.ones(n, bool) if valid is None else valid
    return fn(t, jnp.asarray(hi), jnp.asarray(lo), _upd(n, w, tick),
              jnp.asarray(valid), modes=MODES)


def _table_dict(t):
    exp = stores.export_live(t)
    fps = join_fp(exp["key_hi"], exp["key_lo"])
    return {int(f): (float(w), float(c), int(lt)) for f, w, c, lt in
            zip(fps, exp["weight"], exp["count"], exp["last_tick"])}


@property_test(n_cases=6)
def test_fused_matches_twopass_collision_heavy(rng):
    """Small table + clustered keys: fused path == two-pass reference as a
    key->value map, with zero drops at <= 50% load on both paths."""
    cap = 1 << 9
    t_new, t_old = _mk(cap), _mk(cap)
    for batch in range(4):
        # ~200 distinct keys, heavily repeated within each batch
        keys = rng.integers(1, 200, size=256).astype(np.uint64) * 2654435761
        w = rng.random(256).astype(np.float32)
        valid = rng.random(256) < 0.9
        t_new = _ins(stores.insert_accumulate, t_new, keys, w,
                     valid=valid, tick=batch)
        t_old = _ins(stores.insert_accumulate_twopass, t_old, keys, w,
                     valid=valid, tick=batch)
    assert int(t_new.n_dropped) == 0
    assert int(t_old.n_dropped) == 0
    d_new, d_old = _table_dict(t_new), _table_dict(t_old)
    assert set(d_new) == set(d_old)
    for k in d_new:
        np.testing.assert_allclose(d_new[k][0], d_old[k][0], rtol=1e-5)
        assert d_new[k][1] == d_old[k][1]
        assert d_new[k][2] == d_old[k][2]


@property_test(n_cases=4)
def test_near_full_exact_drop_accounting(rng):
    """Near-full table: every attempted unique key is either fully applied
    (all its batch updates) or dropped and counted — exact conservation."""
    cap = 1 << 8
    for fn in (stores.insert_accumulate, stores.insert_accumulate_twopass):
        t = _mk(cap)
        oracle = {}
        attempted_total = 0
        for batch in range(3):
            # ~1.5x capacity distinct keys across the run -> forced overflow
            keys = (rng.integers(1, 400, size=300).astype(np.uint64)
                    * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)
            w = rng.random(300).astype(np.float32)
            t = _ins(fn, t, keys, w, tick=batch)
            for k, ww in zip(keys, w):
                e = oracle.setdefault(int(k), [0.0, 0])
                e[0] += float(ww)
                e[1] += 1
            attempted_total = len(oracle)
        dropped = int(t.n_dropped)
        live = int(t.live_count())
        assert dropped > 0, "test must actually exercise overflow"
        d = _table_dict(t)
        assert len(d) == live
        # surviving keys carry their COMPLETE accumulated history: a key
        # placed in batch b accumulates every later batch too, so any
        # mismatch would prove partial application.
        for k, (w_got, c_got, _) in d.items():
            # key must exist in the oracle and be fully accumulated from the
            # first batch that placed it; count is an integer number of hits
            assert k in oracle
            assert c_got == int(c_got)
            assert c_got <= oracle[k][1]
        # conservation: every unique key was either placed once or counted
        # dropped each batch it failed; placed+never-again-dropped keys
        # cannot exceed the attempted universe.
        assert live <= attempted_total
        assert live <= cap


def test_fused_drops_zero_at_half_load_exact_values():
    """<= 50% load: n_dropped stays 0 and values match a dict oracle."""
    cap = 1 << 10
    t = _mk(cap)
    rng = np.random.default_rng(3)
    oracle = {}
    for batch in range(4):
        keys = (rng.integers(1, cap // 2, size=512).astype(np.uint64)
                * np.uint64(0x2545F4914F6CDD1D)) | np.uint64(1)
        w = rng.random(512).astype(np.float32)
        t = _ins(stores.insert_accumulate, t, keys, w, tick=batch)
        for k, ww in zip(keys, w):
            e = oracle.setdefault(int(k), [0.0, 0])
            e[0] += float(ww)
            e[1] += 1
    assert int(t.n_dropped) == 0
    d = _table_dict(t)
    assert set(d) == set(oracle)
    for k, (w_got, c_got, _) in d.items():
        np.testing.assert_allclose(w_got, oracle[k][0], rtol=1e-5)
        assert c_got == oracle[k][1]


@property_test(n_cases=4)
def test_sessions_crowded_table_matches_deque_model(rng):
    """Session-store probe under crowding (~50% load incl. collisions) still
    emits exactly the sliding-window pairs of a python deque model."""
    from collections import deque
    W = int(rng.integers(2, 5))
    cap = 1 << 7
    st = stores.make_session_table(cap, W)
    model = {}
    expected, got = [], []
    for batch in range(3):
        B = 96
        sess = rng.integers(1, cap // 2, size=B).astype(np.uint64) * 7919
        q = rng.integers(1, 64, size=B).astype(np.uint64) * 104729
        src = rng.integers(0, 3, size=B).astype(np.int32)
        valid = rng.random(B) < 0.95
        for s, qq, sc, v in zip(sess, q, src, valid):
            if not v:
                continue
            d = model.setdefault(int(s), deque(maxlen=W))
            for (p, psc) in d:
                if p != int(qq):
                    expected.append((p, int(qq)))
            d.append((int(qq), int(sc)))
        s_hi, s_lo = split_fp(sess)
        q_hi, q_lo = split_fp(q)
        st, pairs = stores.update_sessions(
            st, jnp.asarray(s_hi), jnp.asarray(s_lo), jnp.asarray(q_hi),
            jnp.asarray(q_lo), jnp.asarray(src), jnp.int32(batch),
            jnp.asarray(valid))
        pv = np.asarray(pairs.valid)
        sfp = join_fp(np.asarray(pairs.src_hi), np.asarray(pairs.src_lo))[pv]
        dfp = join_fp(np.asarray(pairs.dst_hi), np.asarray(pairs.dst_lo))[pv]
        got.extend(zip(sfp.tolist(), dfp.tolist()))
    assert int(st.n_dropped) == 0
    assert sorted(got) == sorted(expected)


def test_fused_reuses_pruned_slots():
    """Prune-safety: the fused sweep must find keys past pruned (empty)
    slots on their probe sequence, and reuse those slots without dupes."""
    cap = 1 << 9
    t = _mk(cap)
    keys = (np.arange(1, 220, dtype=np.uint64) * 0x9E3779B97F4A7C15) | 1
    t = _ins(stores.insert_accumulate, t, keys, np.ones(len(keys)))
    # decay half the weight range below the prune threshold
    rng = np.random.default_rng(0)
    w = rng.random(len(keys)).astype(np.float32)
    t = _ins(stores.insert_accumulate, t, keys, w)
    cfg = DecayConfig(half_life_ticks=4.0, prune_threshold=1.2)
    t, live, _ = sweep_decay_prune(t, jnp.int32(2), cfg=cfg)
    assert 0 < int(live) < len(keys)
    # reinsert everything twice; no duplicates, exact counts
    t = _ins(stores.insert_accumulate, t, keys, np.ones(len(keys)))
    t = _ins(stores.insert_accumulate, t, keys, np.ones(len(keys)))
    assert int(t.live_count()) == len(keys)
    hi, lo = split_fp(keys)
    vals, found, _ = stores.lookup(t, jnp.asarray(hi), jnp.asarray(lo))
    assert np.asarray(found).all()


# ---------------------------------------------------------------------------
# Multi-lane decay sweep: fused kernel == jnp reference in decay.py
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C", [1024, 8192])
def test_multilane_decay_sweep_matches_jnp_reference(C):
    """kernel path (all lanes in one pass) == decay.py jnp reference."""
    rng = np.random.default_rng(C)
    lanes = {"weight": jnp.float32, "count": jnp.float32,
             "last_tick": jnp.int32, "src_hi": jnp.uint32,
             "src_lo": jnp.uint32}
    t = stores.make_table(C, lanes)
    n = C // 2
    keys = (rng.integers(1, 1 << 30, size=n).astype(np.uint64)
            * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)
    hi, lo = split_fp(keys)
    upds = {"weight": jnp.asarray(rng.random(n) * 2, jnp.float32),
            "count": jnp.ones(n, jnp.float32),
            "last_tick": jnp.full(n, 3, jnp.int32),
            "src_hi": jnp.asarray(rng.integers(1, 2**32, n), jnp.uint32),
            "src_lo": jnp.asarray(rng.integers(1, 2**32, n), jnp.uint32)}
    modes = (("weight", "add"), ("count", "add"), ("last_tick", "set"),
             ("src_hi", "set"), ("src_lo", "set"))
    t = stores.insert_accumulate(t, jnp.asarray(hi), jnp.asarray(lo), upds,
                                 jnp.ones(n, bool), modes=modes)
    cfg = DecayConfig(half_life_ticks=6.0, prune_threshold=0.4)
    t_ref, live_ref, tot_ref = sweep_decay_prune(
        t, jnp.int32(6), cfg=cfg, use_kernel=False)
    t_ker, live_ker, tot_ker = sweep_decay_prune(
        t, jnp.int32(6), cfg=cfg, use_kernel=True)
    assert int(live_ref) == int(live_ker)
    np.testing.assert_allclose(float(tot_ref), float(tot_ker), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(t_ref.key_hi),
                                  np.asarray(t_ker.key_hi))
    np.testing.assert_array_equal(np.asarray(t_ref.key_lo),
                                  np.asarray(t_ker.key_lo))
    for name in lanes:
        a, b = np.asarray(t_ref.lanes[name]), np.asarray(t_ker.lanes[name])
        if a.dtype == np.float32:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
        else:
            np.testing.assert_array_equal(a, b)


def test_decay_prune_multi_kernel_vs_ref_oracle():
    """Direct kernel-vs-oracle check incl. a second decayed weight lane."""
    from repro.kernels.decay_prune import decay_prune_multi
    from repro.kernels.ref import decay_prune_multi_ref
    C = 2048
    rng = np.random.default_rng(1)
    kh = rng.integers(0, 2**32, C, dtype=np.uint32)
    kl = rng.integers(0, 2**32, C, dtype=np.uint32)
    dead = rng.random(C) < 0.3
    kh[dead] = 0
    kl[dead] = 0
    w0 = jnp.asarray((rng.random(C) * 3).astype(np.float32))
    w1 = jnp.asarray((rng.random(C) * 5).astype(np.float32))
    cnt = jnp.asarray(np.floor(rng.random(C) * 9).astype(np.float32))
    tick = jnp.asarray(rng.integers(0, 100, C).astype(np.int32))
    args = (jnp.asarray(kh), jnp.asarray(kl), (w0, w1), (cnt, tick),
            jnp.float32(0.5), jnp.float32(0.3))
    got = decay_prune_multi(*args, interpret=True)
    exp = decay_prune_multi_ref(*args)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    for g, e in zip(got[2], exp[2]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-6)
    for g, e in zip(got[3], exp[3]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
    assert int(got[4]) == int(exp[4])
    np.testing.assert_allclose(float(got[5]), float(exp[5]), rtol=1e-4)


# ---------------------------------------------------------------------------
# Ranking compaction parity
# ---------------------------------------------------------------------------

def test_ranking_compaction_parity_and_overflow_counting():
    """Lexsort-path compaction machinery (the pre-segmented reference)."""
    import dataclasses
    from repro.core import ranking
    from repro.core.engine import EngineConfig, SearchAssistanceEngine
    from repro.data.stream import StreamConfig, SyntheticStream

    cfg = EngineConfig(query_capacity=1 << 12, cooc_capacity=1 << 14,
                       session_capacity=1 << 11, session_window=4,
                       rank_every=0, decay_every=0)
    eng = SearchAssistanceEngine(cfg)
    stream = SyntheticStream(StreamConfig(vocab_size=256, n_users=150,
                                          queries_per_tick=256,
                                          tweets_per_tick=0), seed=2)
    for t in range(6):
        ev, _ = stream.gen_tick(t)
        eng.step(ev, None)

    full = ranking.ranking_cycle_lexsort(
        eng.state.cooc, eng.state.qstore,
        dataclasses.replace(cfg.rank, compact_frac=1.0))
    comp = ranking.ranking_cycle_lexsort(
        eng.state.cooc, eng.state.qstore,
        dataclasses.replace(cfg.rank, compact_frac=0.5))
    assert int(full.n_overflow) == 0
    assert int(comp.n_overflow) == 0
    s_full = ranking.suggestions_to_host(full)
    s_comp = ranking.suggestions_to_host(comp)
    assert set(s_full) == set(s_comp)
    assert int(full.n_rows) == int(comp.n_rows)
    for f in s_full:
        a = sorted(s_full[f], key=lambda t: (-t[1], t[0]))
        b = sorted(s_comp[f], key=lambda t: (-t[1], t[0]))
        # rtol 1e-4, not 1e-6: compaction reorders the surviving pairs, so
        # the per-source f32 normalization sums accumulate in a different
        # order than the uncompacted pass — occasionally past 1e-6, which
        # made this flaky. A real parity break (missing pair, wrong
        # normalizer) shifts scores by >1e-2 here.
        np.testing.assert_allclose([s for _, s in a], [s for _, s in b],
                                   rtol=1e-4)
        assert {d for d, _ in a} == {d for d, _ in b}

    # a pathologically small compaction buffer must COUNT what it cuts, and
    # the cut must remove the globally LOWEST-scoring pairs — the best
    # suggestion always survives compaction.
    tiny = ranking.ranking_cycle_lexsort(
        eng.state.cooc, eng.state.qstore,
        dataclasses.replace(cfg.rank, compact_frac=1e-4))
    assert int(tiny.n_overflow) > 0
    s_tiny = ranking.suggestions_to_host(tiny)
    best_full = max(s for row in s_full.values() for _, s in row)
    best_tiny = max(s for row in s_tiny.values() for _, s in row)
    np.testing.assert_allclose(best_tiny, best_full, rtol=1e-4)


# ---------------------------------------------------------------------------
# Claim-sort key packing: winners deterministic-by-arrival
# ---------------------------------------------------------------------------

@property_test(n_cases=4)
def test_claim_winners_invariant_under_batch_permutation(rng):
    """Permuting a batch must leave the resulting table bit-identical: the
    packed (slot, batch idx) claim key makes winners a function of the
    deduped (sorted) key set, not of the input order or sort stability."""
    cap = 1 << 8
    n = 180
    # clustered keys -> heavy probe collisions -> many contended claims
    keys = (rng.integers(1, 90, size=n).astype(np.uint64)
            * np.uint64(0x9E3779B97F4A7C15)) | np.uint64(1)
    w = rng.random(n).astype(np.float32)
    # permutation-invariant updates: ADD lanes + a constant SET tick
    tables = []
    for perm in (np.arange(n), rng.permutation(n), rng.permutation(n)):
        t = _mk(cap)
        t = _ins(stores.insert_accumulate, t, keys[perm], w[perm], tick=7)
        tables.append(t)
    for t in tables[1:]:
        np.testing.assert_array_equal(np.asarray(tables[0].key_hi),
                                      np.asarray(t.key_hi))
        np.testing.assert_array_equal(np.asarray(tables[0].key_lo),
                                      np.asarray(t.key_lo))
        for name in tables[0].lanes:
            np.testing.assert_allclose(np.asarray(tables[0].lanes[name]),
                                       np.asarray(t.lanes[name]), rtol=1e-6)


def test_claim_winners_lexsort_fallback_matches_packed():
    """When log2(C) + log2(B) > 31 the packed key cannot fit u32; the
    lexsort fallback must pick the same winners (lowest batch index)."""
    from repro.core.stores import _claim_winners
    rng = np.random.default_rng(0)
    B = 1 << 12
    slots = jnp.asarray(rng.integers(0, 1 << 10, size=B), jnp.uint32)
    contend = jnp.asarray(rng.random(B) < 0.7)
    # C small enough to pack vs C huge enough to force the fallback
    won_packed = _claim_winners(slots, contend, B, 1 << 10)
    won_fallback = _claim_winners(slots, contend, B, 1 << 24)
    np.testing.assert_array_equal(np.asarray(won_packed),
                                  np.asarray(won_fallback))
    # exactly one winner per contended slot, and it is the first arrival
    sl = np.asarray(slots)
    cn = np.asarray(contend)
    wn = np.asarray(won_packed)
    for s in np.unique(sl[cn]):
        contenders = np.nonzero(cn & (sl == s))[0]
        winners = np.nonzero(wn & (sl == s))[0]
        assert len(winners) == 1 and winners[0] == contenders.min()
