"""Recovery subsystem semantics (§4.2 rewind/catch-up).

Covers: the segmented firehose log (roundtrip, seek, rotation, retention,
torn-tail truncation), EngineState snapshot round-trips, the fused
``ingest_many`` scan vs. sequential live stepping (bit-exact), the
crash-at-every-segment-boundary property (restore + replay == an
uninterrupted run, exact under lazy/exponential decay), replay-mode rank
suppression, frontend staleness metrics, and the leader-gated log writer.

Whole-stack additions: ``recover_service`` crash-at-every-segment-boundary
bit-exactness for the full rt + bg + interpolation stack (both decay
policies x both cooc layouts, over delta-chained snapshots), the
incremental-snapshot chain itself (delta restore == full restore
bit-for-bit, corrupt/torn-delta fallback to the newest intact full with the
longer replay tail, retention never stranding a delta without its base),
and the per-engine frontend staleness metrics.
"""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.background import AssistanceService, interpolate
from repro.core.decay import DecayConfig
from repro.core.engine import (EngineConfig, SearchAssistanceEngine,
                               TickStack, ingest_many)
from repro.core.hashing import split_fp
from repro.data.stream import StreamConfig, SyntheticStream
from repro.distributed.fault_tolerance import (CheckpointManager,
                                               ReplicaGroup,
                                               corrupt_snapshot)
from repro.serving.serve import SuggestFrontend, pack_suggestions
from repro.streaming import (CatchUpController, FirehoseLogReader,
                             FirehoseLogWriter, ReplayConfig, chunk_to_stack,
                             corrupt_segment, flaky_io,
                             kill_writer_mid_segment, recover_engine,
                             recover_service)
from proptest import property_test


def _cfg(policy="lazy", **kw):
    base = dict(query_capacity=1 << 11, cooc_capacity=1 << 13,
                session_capacity=1 << 10, session_window=3,
                decay_every=4, prune_every=6, rank_every=5,
                region_width=16, decay=DecayConfig(policy=policy))
    base.update(kw)
    return EngineConfig(**base)


def _bg_cfg(cfg: EngineConfig) -> EngineConfig:
    """A background config with cadences deliberately DIFFERENT from the
    rt engine's — replay must honor each engine's own cadence authority."""
    slow = dataclasses.replace(cfg.decay,
                               half_life_ticks=cfg.decay.half_life_ticks * 8,
                               prune_threshold=cfg.decay.prune_threshold * 0.5)
    return dataclasses.replace(cfg, decay=slow, rank_every=7,
                               decay_every=6, prune_every=9)


def _batches(n, seed=11, tweets=8):
    stream = SyntheticStream(
        StreamConfig(vocab_size=256, n_users=120, queries_per_tick=96,
                     tweets_per_tick=tweets, tweet_words=3, tweet_grams=4),
        seed=seed)
    return [stream.gen_tick(t) for t in range(n)]


def _stack(batches) -> TickStack:
    s_hi, s_lo = split_fp(np.stack([b[0].sess_fp for b in batches]))
    q_hi, q_lo = split_fp(np.stack([b[0].q_fp for b in batches]))
    g_hi, g_lo = split_fp(np.stack([b[1].grams for b in batches]))
    return TickStack(
        jnp.asarray(s_hi), jnp.asarray(s_lo), jnp.asarray(q_hi),
        jnp.asarray(q_lo),
        jnp.asarray(np.stack([b[0].src for b in batches]), jnp.int32),
        jnp.asarray(np.stack([b[0].valid for b in batches])),
        jnp.asarray(g_hi), jnp.asarray(g_lo),
        jnp.asarray(np.stack([b[1].valid for b in batches])))


def _assert_states_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"state leaf {i}")


# ---------------------------------------------------------------------------
# Log
# ---------------------------------------------------------------------------

def test_log_roundtrip_and_seek(tmp_path):
    batches = _batches(10)
    w = FirehoseLogWriter(str(tmp_path), ticks_per_segment=4)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    w.close()   # seals the partial tail segment (ticks 8-9)
    r = FirehoseLogReader(str(tmp_path))
    assert [(s.first, s.last) for s in r.segments] == [(0, 3), (4, 7), (8, 9)]
    assert (r.first_tick(), r.last_tick()) == (0, 9)
    # per-tick roundtrip is exact
    for (t, ev, tw), (oev, otw) in zip(r.read_ticks(0), batches):
        np.testing.assert_array_equal(ev.q_fp, oev.q_fp)
        np.testing.assert_array_equal(ev.sess_fp, oev.sess_fp)
        np.testing.assert_array_equal(ev.src, oev.src)
        np.testing.assert_array_equal(tw.grams, otw.grams)
    # seek lands mid-segment; re-chunking stays consecutive
    ticks = []
    for chunk in r.read_chunks(5, chunk_ticks=3):
        ticks.extend(chunk.ticks.tolist())
    assert ticks == [5, 6, 7, 8, 9]
    # monotonicity is enforced
    w2 = FirehoseLogWriter(str(tmp_path), ticks_per_segment=4)
    with pytest.raises(ValueError):
        w2.append(9, *batches[0])


def test_log_rotation_and_retention(tmp_path):
    batches = _batches(10)
    w = FirehoseLogWriter(str(tmp_path), ticks_per_segment=2,
                          keep_segments=2)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    r = FirehoseLogReader(str(tmp_path))
    assert [(s.first, s.last) for s in r.segments] == [(6, 7), (8, 9)]
    on_disk = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(on_disk) == 2, "retention must unlink old segment files"


def test_torn_tail_truncation(tmp_path):
    batches = _batches(8)
    w = FirehoseLogWriter(str(tmp_path), ticks_per_segment=3)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    # ticks 6,7 are buffered; the crash tears them onto disk unmanifested
    torn = kill_writer_mid_segment(w)
    assert torn is not None and os.path.exists(tmp_path / torn)
    with pytest.raises(RuntimeError):
        w.append(8, *batches[0])
    r = FirehoseLogReader(str(tmp_path))
    assert r.last_tick() == 5 and r.n_unmanifested_files == 1
    # a torn write INSIDE the manifested range truncates from there on
    corrupt_segment(str(tmp_path), r.segments[1])
    r.refresh()
    assert r.last_tick() == 2 and r.n_truncated_segments == 1
    assert r.repair() >= 1   # torn tail debris removed
    assert FirehoseLogReader(str(tmp_path)).n_unmanifested_files == 0


def test_reader_retries_transient_io_errors(tmp_path):
    """An NFS blip / EINTR-style transient read error must be absorbed by
    the reader's bounded retry-with-backoff, not surface as a hard replay
    failure (and not as a bogus torn-tail truncation during verify)."""
    batches = _batches(8)
    w = FirehoseLogWriter(str(tmp_path), ticks_per_segment=4)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    w.close()
    r = FirehoseLogReader(str(tmp_path), io_backoff_s=1e-4)
    # blip during verification: refresh() must still accept every segment
    flaky_io(r, ("_read_bytes",), n_failures=1)
    r.refresh()
    assert r.n_io_retries == 1 and r.n_truncated_segments == 0
    assert (r.first_tick(), r.last_tick()) == (0, 7)
    # blip during a chunk read mid-replay: the data still comes back exact
    flaky_io(r, ("_read_bytes",), n_failures=2)
    got = list(r.read_ticks(0))
    assert [t for t, _, _ in got] == list(range(8))
    np.testing.assert_array_equal(got[5][1].q_fp, batches[5][0].q_fp)
    assert r.n_io_retries == 3
    # a PERSISTENT fault exhausts the budget: verify treats the segment as
    # bad and truncates there (same stance as corruption) instead of hanging
    flaky_io(r, ("_read_bytes",), n_failures=100)
    r.refresh()
    assert r.segments == [] and r.n_truncated_segments == 2
    r._flaky_io_undo()
    assert r.refresh().last_tick() == 7   # fault cleared -> log intact
    # ... and during a read, the exhausted budget surfaces the real error
    flaky_io(r, ("_read_bytes",), n_failures=100)
    with pytest.raises(OSError):
        list(r.read_ticks(0))


def test_recovery_replay_through_flaky_io(tmp_path):
    """End-to-end: a transient read fault mid catch-up replay is retried
    and the recovered engine is still bit-exact."""
    cfg = _cfg("lazy")
    batches = _batches(8)
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=3)
    live = SearchAssistanceEngine(cfg)
    live.save_snapshot(ckpt)                      # offset 0: replay all
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        live.step(ev, tw)
    w.close()
    reader = FirehoseLogReader(str(tmp_path / "log"), io_backoff_s=1e-4)
    flaky_io(reader, ("_read_bytes",), n_failures=2)
    eng = SearchAssistanceEngine(cfg)
    ctl = CatchUpController(eng, reader, ReplayConfig(chunk_ticks=4))
    stats = ctl.catch_up()
    assert stats["n_ticks"] == 8
    assert reader.n_io_retries >= 1
    _assert_states_equal(live.state, eng.state)


# ---------------------------------------------------------------------------
# EngineState snapshots
# ---------------------------------------------------------------------------

def test_engine_state_snapshot_roundtrip(tmp_path):
    cfg = _cfg()
    eng = SearchAssistanceEngine(cfg)
    for t, (ev, tw) in enumerate(_batches(4)):
        eng.step(ev, tw)
    ckpt = CheckpointManager(str(tmp_path))
    eng.save_snapshot(ckpt)
    restored, log_tick = SearchAssistanceEngine.restore_from_snapshot(
        cfg, ckpt)
    assert log_tick == int(eng.state.tick) == 4
    _assert_states_equal(eng.state, restored.state)
    # dtypes survive the npz roundtrip
    for a, b in zip(jax.tree.flatten(eng.state)[0],
                    jax.tree.flatten(restored.state)[0]):
        assert a.dtype == b.dtype


# ---------------------------------------------------------------------------
# Fused multi-tick ingest == live stepping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["sweep", "lazy"])
def test_ingest_many_matches_step_loop(policy):
    cfg = _cfg(policy, decay_every=3, prune_every=5)
    batches = _batches(8)
    a = SearchAssistanceEngine(cfg)
    for ev, tw in batches:
        a.step(ev, tw)
    b = SearchAssistanceEngine(cfg)
    b.step_many(_stack(batches))
    _assert_states_equal(a.state, b.state)
    assert (a.n_prune_cycles, a.n_decay_cycles) == \
        (b.n_prune_cycles, b.n_decay_cycles)
    a.run_rank_cycle()
    b.run_rank_cycle()
    assert a.suggestions == b.suggestions


def test_ingest_many_queries_only():
    """A log without a firehose (B-only stack) replays the query path."""
    cfg = _cfg(rank_every=0)
    batches = _batches(4, tweets=0)
    w_batches = [(ev, None) for ev, _ in batches]
    a = SearchAssistanceEngine(cfg)
    for ev, _ in batches:
        a.step(ev, None)
    b = SearchAssistanceEngine(cfg)
    R, B = len(batches), batches[0][0].q_fp.shape[0]
    s_hi, s_lo = split_fp(np.stack([ev.sess_fp for ev, _ in batches]))
    q_hi, q_lo = split_fp(np.stack([ev.q_fp for ev, _ in batches]))
    stack = TickStack(
        jnp.asarray(s_hi), jnp.asarray(s_lo), jnp.asarray(q_hi),
        jnp.asarray(q_lo),
        jnp.asarray(np.stack([ev.src for ev, _ in batches]), jnp.int32),
        jnp.asarray(np.stack([ev.valid for ev, _ in batches])),
        jnp.zeros((R, 0, 0), jnp.uint32), jnp.zeros((R, 0, 0), jnp.uint32),
        jnp.zeros((R, 0), bool))
    b.state = ingest_many(b.state, stack, cfg=cfg)
    _assert_states_equal(a.state, b.state)


# ---------------------------------------------------------------------------
# Crash -> restore -> replay == uninterrupted run (the §4.2 property)
# ---------------------------------------------------------------------------

@property_test(n_cases=2)
def test_crash_at_every_segment_boundary(rng):
    """Crash after EVERY sealed segment; recovery must reproduce the
    uninterrupted run bit-for-bit (lazy + exponential decay => exact)."""
    seed = int(rng.integers(1 << 30))
    n_ticks, tps = 12, 3
    cfg = _cfg("lazy")
    batches = _batches(n_ticks, seed=seed)

    # live run: log every tick, snapshot at every rank cycle
    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        logd, ckd = os.path.join(tmp, "log"), os.path.join(tmp, "ck")
        ckpt = CheckpointManager(ckd, keep_n=10)
        w = FirehoseLogWriter(logd, ticks_per_segment=tps)
        live = SearchAssistanceEngine(cfg)
        states_at = {}
        for t, (ev, tw) in enumerate(batches):
            w.append(t, ev, tw)
            if live.step(ev, tw) is not None:
                live.save_snapshot(ckpt)
            states_at[t + 1] = live.state    # post-tick state (tick == t+1)
        w.close()

        for boundary in range(tps, n_ticks + 1, tps):
            # crash right after the segment [boundary-tps, boundary) sealed:
            # replay everything logged before the crash point
            steps = [s for s in ckpt.steps() if s <= boundary]
            if not steps:
                continue
            eng, stats = recover_engine(
                cfg, ckpt, logd, ReplayConfig(chunk_ticks=4),
                target_tick=boundary, step=steps[-1])
            assert int(eng.state.tick) == boundary
            _assert_states_equal(states_at[boundary], eng.state)
            # identical state => identical suggestion tables
            ref = SearchAssistanceEngine(cfg)
            ref.state = states_at[boundary]
            ref.run_rank_cycle()
            eng.run_rank_cycle()
            assert ref.suggestions == eng.suggestions


def test_replay_rank_suppression_and_handoff(tmp_path):
    cfg = _cfg(rank_every=2)
    batches = _batches(10)
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=5)
    fresh = SearchAssistanceEngine(cfg)
    fresh.save_snapshot(ckpt)    # snapshot at tick 0: replay everything
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    w.close()
    eng, stats = recover_engine(
        cfg, ckpt, str(tmp_path / "log"),
        ReplayConfig(chunk_ticks=4, rank_lag_ticks=3))
    assert stats["n_ticks"] == 10
    # rank boundaries 2,4,6,8: the lagging chunks suppress theirs, the
    # near-head chunks run one each, and fresh tables are left at handoff
    assert stats["n_rank_suppressed"] == 2
    assert stats["n_rank_run"] == 2
    assert eng.suggestions
    assert eng.last_rank_tick == int(eng.state.tick)


def test_replay_gap_detection(tmp_path):
    cfg = _cfg(rank_every=0)
    batches = _batches(8)
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    SearchAssistanceEngine(cfg).save_snapshot(ckpt)   # offset 0
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=2,
                          keep_segments=2)            # retention drops 0..3
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    with pytest.raises(ValueError, match="retention"):
        recover_engine(cfg, ckpt, str(tmp_path / "log"))
    eng, stats = recover_engine(
        cfg, ckpt, str(tmp_path / "log"),
        ReplayConfig(allow_gap=True))
    assert stats["n_skipped_gap_ticks"] == 4
    assert int(eng.state.tick) == 8


def test_replay_mid_log_gap(tmp_path):
    """A crash can tear ticks that a newer snapshot already covered; the
    restarted writer then resumes past them, leaving a hole mid-log.
    Recovery from an OLDER snapshot must skip the hole under allow_gap
    (and refuse without it), not fail forever."""
    cfg = _cfg(rank_every=0)
    batches = _batches(8)
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    SearchAssistanceEngine(cfg).save_snapshot(ckpt)   # offset 0
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=2)
    for t in (0, 1, 2, 3):
        w.append(t, *batches[t])
    w.close()
    w2 = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=2)
    for t in (6, 7):                      # ticks 4,5 died with the crash
        w2.append(t, *batches[t])
    w2.close()
    with pytest.raises(ValueError, match="log gap"):
        recover_engine(cfg, ckpt, str(tmp_path / "log"))
    eng, stats = recover_engine(cfg, ckpt, str(tmp_path / "log"),
                                ReplayConfig(chunk_ticks=4, allow_gap=True))
    assert stats["n_skipped_gap_ticks"] == 2
    assert stats["n_ticks"] == 6
    assert int(eng.state.tick) == 8


def test_replay_intra_segment_hole(tmp_path):
    """A hole INSIDE one segment (the writer only enforces monotonic, not
    consecutive, ticks — e.g. dropped leader-gated appends) must also be
    skippable under allow_gap, not permanently unrecoverable."""
    cfg = _cfg(rank_every=0)
    batches = _batches(7)
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    SearchAssistanceEngine(cfg).save_snapshot(ckpt)   # offset 0
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=8)
    for t in (0, 1, 2, 5, 6):                         # ticks 3,4 missing
        w.append(t, *batches[t])
    w.close()                                          # ONE segment
    with pytest.raises(ValueError, match="allow_gap"):
        recover_engine(cfg, ckpt, str(tmp_path / "log"))
    eng, stats = recover_engine(cfg, ckpt, str(tmp_path / "log"),
                                ReplayConfig(chunk_ticks=8, allow_gap=True))
    assert stats["n_skipped_gap_ticks"] == 2
    assert stats["n_ticks"] == 5
    assert int(eng.state.tick) == 7


def test_frontend_metrics_before_log_exists(tmp_path):
    """Frontends start independently of the backend lifecycle: a missing
    log directory is an empty log, not a crash."""
    f = SuggestFrontend(str(tmp_path / "rt"),
                        log_dir=str(tmp_path / "no_such_log"))
    m = f.metrics()
    assert m["log_head_tick"] is None and not m["catching_up"]


# ---------------------------------------------------------------------------
# Serving-side staleness + leader-gated log writer
# ---------------------------------------------------------------------------

def test_frontend_staleness_metrics(tmp_path):
    rt_dir, log_dir = str(tmp_path / "rt"), str(tmp_path / "log")
    cfg = _cfg()
    batches = _batches(10)
    w = FirehoseLogWriter(log_dir, ticks_per_segment=2)
    eng = SearchAssistanceEngine(cfg)
    rt_ckpt = CheckpointManager(rt_dir)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        if eng.step(ev, tw) is not None and t <= 5:
            # serve_assist convention: "tick" = last tick the tables reflect
            rt_ckpt.save(t, pack_suggestions(eng.suggestions),
                         meta={"tick": t})
    w.close()
    f = SuggestFrontend(rt_dir, log_dir=log_dir, stale_lag_ticks=2)
    f.poll()
    m = f.metrics()
    assert m["rt_step"] == 5 and m["rt_tick"] == 5
    # log holds ticks 0..9, tables reflect 0..5 -> 4 pending ticks (6..9)
    assert m["log_head_tick"] == 9 and m["lag_ticks"] == 4
    assert m["catching_up"], "far behind the log head -> stale"
    # engine-snapshot convention: "log_tick" = NEXT tick to replay; a
    # recovered backend persisting at the head makes the frontend fresh
    rt_ckpt.save(9, pack_suggestions(eng.suggestions),
                 meta={"log_tick": 10})
    f.poll()
    m = f.metrics()
    assert m["rt_tick"] == 9
    assert m["lag_ticks"] == 0 and not m["catching_up"]
    assert m["rt_age_s"] is not None and m["rt_age_s"] >= 0


def test_leader_gated_log_append(tmp_path):
    batches = _batches(3)
    group = ReplicaGroup(3, CheckpointManager(str(tmp_path / "ck")))
    w = FirehoseLogWriter(str(tmp_path / "log"), ticks_per_segment=1)
    assert group.log_append(0, w, 0, *batches[0])
    assert not group.log_append(1, w, 1, *batches[1])   # non-leader dropped
    group.fail(0)
    assert group.log_append(1, w, 1, *batches[1])       # failover continues
    r = FirehoseLogReader(str(tmp_path / "log"))
    assert (r.first_tick(), r.last_tick()) == (0, 1)


def test_stale_standby_writer_failover(tmp_path):
    """A standby replica's writer constructed before the old leader's
    seals must re-sync at segment start: its appends may neither rewind
    the tick space nor clobber the manifest's earlier segments."""
    batches = _batches(3)
    w_leader = FirehoseLogWriter(str(tmp_path), ticks_per_segment=1)
    w_standby = FirehoseLogWriter(str(tmp_path), ticks_per_segment=1)
    w_leader.append(0, *batches[0])
    w_leader.append(1, *batches[1])
    # failover: the standby (stale cached view) becomes the writer
    with pytest.raises(ValueError, match="non-monotonic"):
        w_standby.append(1, *batches[1])
    w_standby.append(2, *batches[2])
    r = FirehoseLogReader(str(tmp_path))
    assert [(s.first, s.last) for s in r.segments] == [(0, 0), (1, 1), (2, 2)]


# ---------------------------------------------------------------------------
# Whole-stack recovery: rt + bg + interpolation (the tentpole property)
# ---------------------------------------------------------------------------

def _run_live_service(cfg, bgc, batches, logd, rt_ckpt, bg_ckpt, tps,
                      snap_every=2):
    """Uninterrupted service run: log every tick, snapshot both engines
    every ``snap_every`` ticks. Returns (service, rt_states, bg_states)."""
    w = FirehoseLogWriter(str(logd), ticks_per_segment=tps)
    svc = AssistanceService(cfg, bg_cfg=bgc)
    rt_states, bg_states = {}, {}
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        svc.step(ev, tw)
        if (t + 1) % snap_every == 0:
            svc.save_snapshot(rt_ckpt, bg_ckpt)
        rt_states[t + 1] = svc.rt.state
        bg_states[t + 1] = svc.bg.state
    w.close()
    return svc, rt_states, bg_states


@pytest.mark.parametrize("policy,layout", [
    ("lazy", "hash"), ("sweep", "hash"),
    ("lazy", "region"), ("sweep", "region")])
def test_service_crash_at_every_segment_boundary(tmp_path, policy, layout):
    """Crash the WHOLE serving stack (rt + bg + interpolation cache) after
    every sealed log segment; ``recover_service`` must reproduce the
    uninterrupted run bit-for-bit — each engine restored from its own
    delta-chained snapshot and replayed from its own offset under its own
    cadence authority — and the interpolated suggestion dict with it."""
    n_ticks, tps = 9, 3
    cfg = _cfg(policy, cooc_layout=layout)
    bgc = _bg_cfg(cfg)
    batches = _batches(n_ticks, seed=17)
    logd = tmp_path / "log"
    # delta-chained snapshots ON the recovery hot path (full_interval=3)
    rt_ckpt = CheckpointManager(str(tmp_path / "rt"), keep_n=20,
                                full_interval=3)
    bg_ckpt = CheckpointManager(str(tmp_path / "bg"), keep_n=20,
                                full_interval=3)
    _, rt_states, bg_states = _run_live_service(
        cfg, bgc, batches, logd, rt_ckpt, bg_ckpt, tps)

    for boundary in range(tps, n_ticks + 1, tps):
        rt_steps = [s for s in rt_ckpt.steps() if s <= boundary]
        bg_steps = [s for s in bg_ckpt.steps() if s <= boundary]
        if not rt_steps or not bg_steps:
            continue
        # asymmetric offsets: rt restores its newest snapshot, bg an older
        # one (the realistic case — the halves snapshot independently)
        rec, stats = recover_service(
            cfg, rt_ckpt, bg_ckpt, str(logd), ReplayConfig(chunk_ticks=4),
            bg_cfg=bgc, target_tick=boundary,
            rt_step=rt_steps[-1],
            bg_step=bg_steps[-2] if len(bg_steps) > 1 else bg_steps[-1])
        assert int(rec.rt.state.tick) == boundary
        assert int(rec.bg.state.tick) == boundary
        _assert_states_equal(rt_states[boundary], rec.rt.state)
        _assert_states_equal(bg_states[boundary], rec.bg.state)
        # identical states => identical per-engine tables AND identical
        # interpolated frontend dict
        ref_rt = SearchAssistanceEngine(cfg)
        ref_rt.state = rt_states[boundary]
        ref_rt.run_rank_cycle()
        ref_bg = SearchAssistanceEngine(bgc)
        ref_bg.state = bg_states[boundary]
        ref_bg.run_rank_cycle()
        rec.rt.run_rank_cycle()
        rec.bg.run_rank_cycle()
        rec.refresh_cache()
        assert rec.rt.suggestions == ref_rt.suggestions
        assert rec.bg.suggestions == ref_bg.suggestions
        assert rec.suggestions == interpolate(
            ref_rt.suggestions, ref_bg.suggestions, rec.alpha)


def test_recover_service_cold_engines(tmp_path):
    """A service that crashed before its first persist cold-starts both
    engines and replays the whole retained log — still bit-exact."""
    cfg = _cfg("lazy")
    bgc = _bg_cfg(cfg)
    batches = _batches(6, seed=5)
    logd = tmp_path / "log"
    rt_ckpt = CheckpointManager(str(tmp_path / "rt"))
    bg_ckpt = CheckpointManager(str(tmp_path / "bg"))
    w = FirehoseLogWriter(str(logd), ticks_per_segment=3)
    live = AssistanceService(cfg, bg_cfg=bgc)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        live.step(ev, tw)
    w.close()
    rec, stats = recover_service(cfg, rt_ckpt, bg_ckpt, str(logd),
                                 ReplayConfig(chunk_ticks=4), bg_cfg=bgc)
    assert stats["rt"]["restored_step"] is None
    assert stats["rt"]["n_ticks"] == stats["bg"]["n_ticks"] == 6
    _assert_states_equal(live.rt.state, rec.rt.state)
    _assert_states_equal(live.bg.state, rec.bg.state)


# ---------------------------------------------------------------------------
# Incremental (delta) snapshot chains
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["hash", "region"])
def test_delta_chain_restore_equals_full(tmp_path, layout):
    """Delta-chain restore == full-snapshot restore, bit for bit, at every
    step (both cooc layouts: region metadata — chain directory, fills,
    freelist — rides delta snapshots too), and deltas are smaller."""
    cfg = _cfg("lazy", cooc_layout=layout)
    eng = SearchAssistanceEngine(cfg)
    ck_full = CheckpointManager(str(tmp_path / "full"), keep_n=0)
    ck_delta = CheckpointManager(str(tmp_path / "delta"), keep_n=0,
                                 full_interval=3)
    full_bytes, delta_bytes = [], []
    for t, (ev, tw) in enumerate(_batches(8, seed=3)):
        eng.step(ev, tw)
        eng.save_snapshot(ck_full)
        full_bytes.append(ck_full.last_save_bytes)
        eng.save_snapshot(ck_delta)
        if ck_delta.last_save_kind == "delta":
            delta_bytes.append(ck_delta.last_save_bytes)
    assert len(delta_bytes) >= 4, "chain must actually contain deltas"
    for step in ck_full.steps():
        a, sa = ck_full.restore(eng.state, step)
        b, sb = ck_delta.restore(eng.state, step)
        assert sa == sb == step
        _assert_states_equal(a, b)
    assert max(delta_bytes) < min(full_bytes), \
        "a delta snapshot must write fewer bytes than any full"


def test_corrupt_delta_mid_chain_falls_back(tmp_path):
    """A corrupt/torn delta mid-chain falls back to the newest intact FULL
    snapshot; recovery replays the longer log tail and still reproduces
    the uninterrupted run bit-for-bit."""
    cfg = _cfg("lazy")
    batches = _batches(12, seed=7)
    logd = str(tmp_path / "log")
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep_n=0, full_interval=4)
    w = FirehoseLogWriter(logd, ticks_per_segment=3)
    live = SearchAssistanceEngine(cfg)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        live.step(ev, tw)
        if (t + 1) % 2 == 0:
            live.save_snapshot(ckpt)   # steps 2f 4d 6d 8d 10f 12d
    w.close()
    kinds = {s: ckpt.manifest(s)["kind"] for s in ckpt.steps()}
    assert kinds == {2: "full", 4: "delta", 6: "delta", 8: "delta",
                     10: "full", 12: "delta"}

    # intact chain first: newest snapshot (12 = delta on full 10) restores
    eng, stats = recover_engine(cfg, ckpt, logd)
    assert not stats["restore"]["fell_back"]
    _assert_states_equal(live.state, eng.state)

    # corrupt a MID-chain delta (6): restoring 8 must fall back to full 2
    # and replay the longer tail 2..12 — same final state
    corrupt_snapshot(ckpt, 6)
    eng, stats = recover_engine(cfg, ckpt, logd, step=8)
    assert stats["restore"] == {"requested": 8, "restored": 2,
                                "chain_len": 1, "fell_back": True}
    assert stats["n_ticks"] == 10
    _assert_states_equal(live.state, eng.state)

    # corrupt the newest FULL (10): the newest delta's chain breaks too;
    # fallback skips past it to full 2
    corrupt_snapshot(ckpt, 10)
    eng, stats = recover_engine(cfg, ckpt, logd)
    assert stats["restore"]["fell_back"] and \
        stats["restore"]["restored"] == 2
    _assert_states_equal(live.state, eng.state)

    # no intact full at all -> recovery fails loudly
    corrupt_snapshot(ckpt, 2)
    with pytest.raises(FileNotFoundError, match="intact full"):
        recover_engine(cfg, ckpt, logd)


def test_delta_retention_never_strands(tmp_path):
    """keep_n retention must never unlink a full (or intermediate delta)
    that a retained delta's chain still references — every retained step
    stays restorable at all times."""
    cfg = _cfg("lazy", rank_every=0)
    eng = SearchAssistanceEngine(cfg)
    ckpt = CheckpointManager(str(tmp_path), keep_n=2, full_interval=3)
    for t, (ev, tw) in enumerate(_batches(8, seed=9)):
        eng.step(ev, tw)
        eng.save_snapshot(ckpt)
        states = {}
        for s in ckpt.steps():
            # chain-walk every retained step via manifests only: each
            # member must exist, ending at a full
            cur, hops = s, 0
            while True:
                man = ckpt.manifest(cur)   # raises if stranded
                if man["kind"] == "full":
                    break
                cur = man["base_step"]
                hops += 1
                assert hops <= ckpt.full_interval
            restored, got = ckpt.restore(eng.state, s)
            assert got == s and not ckpt.last_restore["fell_back"]
            states[s] = restored
        _assert_states_equal(eng.state, states[max(states)])
    # kinds ran 1f 2d 3d 4f 5d 6d 7f 8d: the newest keep_n=2 steps are
    # {7, 8} and 8's base is the full 7 — nothing else may survive
    assert set(ckpt.steps()) == {7, 8}
    assert ckpt.manifest(8)["base_step"] == 7
    assert ckpt.manifest(7)["kind"] == "full"


def test_torn_manifest_falls_back(tmp_path):
    """A torn/garbled MANIFEST.json at the newest step (steps() lists the
    dir, json.load fails) must not kill recovery: the layout pre-check
    skips it and the chain walk falls back to the newest intact full."""
    cfg = _cfg("lazy")
    batches = _batches(6, seed=13)
    logd = str(tmp_path / "log")
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep_n=0, full_interval=3)
    w = FirehoseLogWriter(logd, ticks_per_segment=3)
    live = SearchAssistanceEngine(cfg)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
        live.step(ev, tw)
        if (t + 1) % 2 == 0:
            live.save_snapshot(ckpt)    # steps 2f 4d 6d
    w.close()
    man_path = os.path.join(ckpt._step_dir(6), "MANIFEST.json")
    with open(man_path, "w") as f:
        f.write('{"step": 6, "kind"')   # torn mid-write
    eng, stats = recover_engine(cfg, ckpt, logd)
    assert stats["restore"]["fell_back"]
    assert stats["restore"]["restored"] == 2
    _assert_states_equal(live.state, eng.state)


def test_service_engine_only_injection():
    """AssistanceService(rt=engine) without any config must derive the bg
    config from the injected engine's cfg, not crash."""
    eng = SearchAssistanceEngine(_cfg("lazy"))
    svc = AssistanceService(rt=eng)
    assert svc.rt is eng
    assert svc.bg.cfg.rank_every == eng.cfg.rank_every * 12


def test_delta_shape_change_forces_full(tmp_path):
    """A tree whose structure/shape changed since the shadow (e.g. a
    different engine config) must be written as a full, never a bogus
    delta."""
    ckpt = CheckpointManager(str(tmp_path), full_interval=4)
    ckpt.save(1, {"x": jnp.arange(8, dtype=jnp.float32)})
    ckpt.save(2, {"x": jnp.arange(8, dtype=jnp.float32) * 2})
    assert ckpt.last_save_kind == "delta"
    ckpt.save(3, {"x": jnp.arange(16, dtype=jnp.float32)})
    assert ckpt.last_save_kind == "full"
    restored, _ = ckpt.restore({"x": jnp.zeros(16, jnp.float32)}, 3)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(16))
    # a freshly constructed manager has no shadow: first save is full
    ckpt2 = CheckpointManager(str(tmp_path), full_interval=4)
    ckpt2.save(4, {"x": jnp.arange(16, dtype=jnp.float32)})
    assert ckpt2.last_save_kind == "full"


# ---------------------------------------------------------------------------
# Per-engine frontend staleness (operators see BOTH halves catch up)
# ---------------------------------------------------------------------------

def test_frontend_bg_metrics(tmp_path):
    rt_dir, bg_dir = str(tmp_path / "rt"), str(tmp_path / "bg")
    log_dir = str(tmp_path / "log")
    batches = _batches(10)
    w = FirehoseLogWriter(log_dir, ticks_per_segment=2)
    for t, (ev, tw) in enumerate(batches):
        w.append(t, ev, tw)
    w.close()
    rt_ckpt, bg_ckpt = CheckpointManager(rt_dir), CheckpointManager(bg_dir)
    # rt persisted at the head, bg far behind (its half is still replaying)
    rt_ckpt.save(9, pack_suggestions({1: [(2, 1.0)]}), meta={"log_tick": 10})
    bg_ckpt.save(3, pack_suggestions({1: [(3, 0.5)]}), meta={"tick": 3})
    f = SuggestFrontend(rt_dir, bg_dir, log_dir=log_dir, stale_lag_ticks=2)
    f.poll()
    m = f.metrics()
    assert m["rt_tick"] == 9 and m["rt_lag_ticks"] == 0
    assert not m["rt_catching_up"] and not m["catching_up"]
    assert m["bg_step"] == 3 and m["bg_tick"] == 3
    assert m["bg_age_s"] is not None and m["bg_age_s"] >= 0
    # log holds ticks 0..9, bg tables reflect 0..3 -> 6 pending bg ticks
    assert m["bg_lag_ticks"] == 6 and m["bg_catching_up"]
    # bg catches up to the head -> its flag clears independently of rt
    bg_ckpt.save(9, pack_suggestions({1: [(3, 0.5)]}), meta={"tick": 9})
    f.poll()
    m = f.metrics()
    assert m["bg_lag_ticks"] == 0 and not m["bg_catching_up"]
