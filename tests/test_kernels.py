"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decay_prune import decay_prune
from repro.kernels.assoc_score import assoc_score
from repro.kernels.edit_distance import edit_distance
from repro.kernels.flash_attention import flash_attention
from repro.core.spelling import encode_strings
from proptest import property_test


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("C", [1024, 4096, 32768])
@pytest.mark.parametrize("factor,thresh", [(0.5, 0.1), (0.99, 0.0), (0.1, 2.0)])
def test_decay_prune_sweep(C, factor, thresh):
    rng = np.random.default_rng(C + int(factor * 100))
    kh = rng.integers(0, 2**32, C, dtype=np.uint32)
    kl = rng.integers(0, 2**32, C, dtype=np.uint32)
    dead = rng.random(C) < 0.4
    kh[dead] = 0
    kl[dead] = 0
    w = (rng.random(C) * 3).astype(np.float32)
    got = decay_prune(jnp.asarray(kh), jnp.asarray(kl), jnp.asarray(w),
                      jnp.float32(factor), jnp.float32(thresh), interpret=True)
    exp = ref.decay_prune_ref(jnp.asarray(kh), jnp.asarray(kl), jnp.asarray(w),
                              jnp.float32(factor), jnp.float32(thresh))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(exp[2]), rtol=1e-6)
    assert int(got[3]) == int(exp[4])
    np.testing.assert_allclose(float(got[4]), float(exp[5]), rtol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("C", [1024, 8192])
@pytest.mark.parametrize("coefs", [(1.0, 0.15, 0.02, 0.0), (0.5, 1.0, 0.0, 0.3)])
def test_assoc_score_sweep(C, coefs):
    rng = np.random.default_rng(C)
    mk = lambda s: jnp.asarray((rng.random(C) * s).astype(np.float32))
    w_ab, c_ab = mk(5), jnp.floor(mk(20))
    w_a, w_b = mk(50) + 1, mk(50) + 1
    c_a = jnp.maximum(c_ab, jnp.floor(mk(100)))
    c_b = jnp.maximum(c_ab, jnp.floor(mk(100)))
    tw, tc = jnp.float32(1e4), jnp.float32(2e4)
    got = assoc_score(w_ab, c_ab, w_a, w_b, c_a, c_b, tw, tc,
                      coefs=coefs, interpret=True)
    exp = ref.assoc_score_ref(w_ab, c_ab, w_a, w_b, c_a, c_b, tw, tc, coefs)
    # LLR's xlogx cancellation amplifies f32 rounding differences between
    # the fused kernel and XLA's op ordering; 5e-3 rel is the honest bound.
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=5e-3, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("C", [1024, 8192])
@pytest.mark.parametrize("half_life", [None, 6.0])
def test_score_gate_sweep(C, half_life):
    """Fused decay+scoring+gating kernel == jnp oracle (incl. -inf gates)."""
    from repro.kernels.topk_select import score_gate
    rng = np.random.default_rng(C + int(half_life or 0))
    mk = lambda s: jnp.asarray((rng.random(C) * s).astype(np.float32))
    w_ab, c_ab = mk(5), jnp.floor(mk(20))
    w_a, w_b = mk(50), mk(50)
    c_a = jnp.maximum(c_ab, jnp.floor(mk(100)))
    c_b = jnp.maximum(c_ab, jnp.floor(mk(100)))
    ok = jnp.asarray(rng.random(C) < 0.8)
    lt = jnp.asarray(rng.integers(0, 20, C).astype(np.int32))
    now = jnp.float32(25.0)
    tw, tc = jnp.float32(1e4), jnp.float32(2e4)
    coefs = (1.0, 0.15, 0.02, 0.0)
    gates = dict(min_pair_weight=0.25, min_src_weight=0.5, min_pair_count=1.0)
    got = score_gate(w_ab, c_ab, w_a, w_b, c_a, c_b, ok.astype(jnp.float32),
                     lt, tw, tc, now, coefs=coefs, half_life=half_life,
                     interpret=True, **gates)
    w_eff = w_ab if half_life is None else \
        w_ab * jnp.exp2(-jnp.maximum(now - lt, 0) / half_life)
    exp = ref.score_gate_ref(w_eff, c_ab, w_a, w_b, c_a, c_b, ok, tw, tc,
                             coefs, **gates)
    got_np, exp_np = np.asarray(got), np.asarray(exp)
    np.testing.assert_array_equal(np.isneginf(got_np), np.isneginf(exp_np))
    fin = ~np.isneginf(exp_np)
    np.testing.assert_allclose(got_np[fin], exp_np[fin], rtol=5e-3, atol=1e-4)
    assert np.isneginf(got_np).any() and fin.any()


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,k", [((256, 64), 8), ((1000, 32), 4),
                                     ((7, 130), 8)])
def test_bucket_topk_matches_lax_top_k(shape, k):
    """Iterated masked-argmax kernel == lax.top_k, incl. duplicate values,
    all--inf rows and rows with fewer than k finite entries."""
    from repro.kernels.topk_select import bucket_topk
    R, L = shape
    rng = np.random.default_rng(R)
    g = np.floor(rng.random((R, L)).astype(np.float32) * 20)  # many ties
    g[rng.random((R, L)) < 0.3] = -np.inf
    g[0, :] = -np.inf
    g[-1, : max(L - 2, 0)] = -np.inf                           # < k finite
    grid = jnp.asarray(g)
    vals, args = bucket_topk(grid, k, interpret=True)
    ref_vals, ref_args = ref.bucket_topk_ref(grid, k)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref_vals))
    fin = ~np.isneginf(np.asarray(ref_vals))
    np.testing.assert_array_equal(np.asarray(args)[fin],
                                  np.asarray(ref_args)[fin])


# ---------------------------------------------------------------------------
def _brute_osa(a, b, fc=1.5):
    la, lb = len(a), len(b)
    D = np.zeros((la + 1, lb + 1))
    for i in range(1, la + 1):
        D[i][0] = fc + (i - 1)
    for j in range(1, lb + 1):
        D[0][j] = fc + (j - 1)
    for i in range(1, la + 1):
        for j in range(1, lb + 1):
            sw = fc if (i == 1 or j == 1) else 1.0
            iw = fc if j == 1 else 1.0
            dw = fc if i == 1 else 1.0
            d = min(D[i - 1][j - 1] + (0 if a[i - 1] == b[j - 1] else sw),
                    D[i][j - 1] + iw, D[i - 1][j] + dw)
            if i >= 2 and j >= 2 and a[i - 2] == b[j - 1] and a[i - 1] == b[j - 2]:
                tw = fc if (i == 2 or j == 2) else 1.0
                d = min(d, D[i - 2][j - 2] + tw)
            D[i][j] = d
    return D[la][lb]


@property_test(n_cases=4)
def test_edit_distance_property(rng):
    L = 16
    pairs = []
    for _ in range(48):
        n1, n2 = rng.integers(0, 13), rng.integers(0, 13)
        a = "".join(chr(97 + c) for c in rng.integers(0, 6, n1))
        b = "".join(chr(97 + c) for c in rng.integers(0, 6, n2))
        pairs.append((a, b))
    pairs += [("justin bieber", "justin beiber"), ("same", "same"), ("", "")]
    A, B = zip(*pairs)
    ac, al = encode_strings(list(A), L)
    bc, bl = encode_strings(list(B), L)
    for fc in (1.0, 1.5):
        d_k = np.asarray(edit_distance(jnp.asarray(ac), jnp.asarray(al),
                                       jnp.asarray(bc), jnp.asarray(bl),
                                       first_char_cost=fc, interpret=True))
        d_r = np.asarray(ref.edit_distance_ref(jnp.asarray(ac), jnp.asarray(al),
                                               jnp.asarray(bc), jnp.asarray(bl), fc))
        d_b = np.array([_brute_osa(a, b, fc) for a, b in pairs])
        np.testing.assert_allclose(d_r, d_b, atol=1e-5)
        np.testing.assert_allclose(d_k, d_b, atol=1e-5)


def test_edit_distance_identity_and_symmetry_of_cost():
    ac, al = encode_strings(["hello world"], 16)
    d = edit_distance(jnp.asarray(ac), jnp.asarray(al), jnp.asarray(ac),
                      jnp.asarray(al), interpret=True)
    assert float(d[0]) == 0.0


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [
    # (B, Hq, Hkv, Tq, Tk, D, causal, window)
    (2, 4, 2, 64, 64, 32, True, 0),
    (1, 8, 8, 128, 128, 16, True, 16),
    (2, 4, 1, 1, 64, 32, True, 0),       # decode: single query token
    (1, 2, 2, 37, 61, 8, False, 0),       # ragged, bidirectional
    (1, 4, 2, 96, 96, 64, True, 32),      # GQA + SWA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, Hq, Hkv, Tq, Tk, D, causal, window = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.standard_normal((B, Hq, Tq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


def test_flash_attention_custom_vjp_matches_ref_grad():
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    f_k = lambda q, k, v: jnp.sum(ops.flash_attention(q, k, v, True, 0) ** 2)
    f_r = lambda q, k, v: jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)
    g_k = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)
